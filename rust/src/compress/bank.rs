//! Quantized-at-rest estimate banks for the million-node event engine.
//!
//! A [`QuantBank`] is the scale replacement for a `Vec<EstimateTracker>`:
//! semantically a bank of n per-node estimate vectors ŷᵢ = init + Σ C(Δ),
//! but stored as the *committed wire frames* instead of dense f64 rows.
//! The wire codec is lossless over the lossy code (`decode(wire)` is
//! exactly what both endpoints committed — the [`crate::compress`] module
//! contract), so replaying a node's frames over its base with the same
//! `+=` visitor order reproduces the dense tracker value **bit for bit**
//! (`tests/prop.rs` pins this across all compressor kinds).
//!
//! Memory model:
//! * a node that never transmitted costs O(1) — its row *is* the shared
//!   `init_row`, no per-node allocation;
//! * a lightly-active node costs its committed frame bytes (e.g. ~q/64 of
//!   dense for qsgdQ);
//! * once a node's resident frames would exceed one dense row (m·8 bytes)
//!   the slot compacts: the materialized row becomes the new base and the
//!   frames drop, bounding any slot at ≤ 2 dense rows.
//!
//! Dense rows are materialized only while a node is *active*, through a
//! small LRU pool of scratch rows ([`ScratchPool`]); the pool is pure
//! cache — eviction never loses state — and is therefore not serialized.
//! Compaction depends only on the committed frame sequence, never on pool
//! state, so snapshots of a resumed run stay byte-identical.

use super::wire;
use super::Compressed;
use crate::snapshot::codec::{Pack, Reader, Writer};

/// Dense scratch rows for the currently-active nodes, recycled LRU. The
/// capacity bounds resident dense rows regardless of fleet size; a linear
/// stamp scan is fine at this size (≤ 64 entries).
#[derive(Debug)]
struct ScratchPool {
    cap: usize,
    stamp: u64,
    entries: Vec<PoolEntry>,
}

#[derive(Debug)]
struct PoolEntry {
    node: usize,
    stamp: u64,
    row: Box<[f64]>,
}

impl ScratchPool {
    fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), stamp: 0, entries: Vec::new() }
    }

    fn find(&mut self, node: usize) -> Option<usize> {
        let idx = self.entries.iter().position(|e| e.node == node)?;
        self.stamp += 1;
        self.entries[idx].stamp = self.stamp;
        Some(idx)
    }

    /// Claim a slot for `node` (not currently pooled): reuse the LRU row
    /// once at capacity, else allocate. The returned row holds garbage —
    /// the caller fills it.
    fn claim(&mut self, node: usize, m: usize) -> usize {
        self.stamp += 1;
        if self.entries.len() < self.cap {
            self.entries.push(PoolEntry {
                node,
                stamp: self.stamp,
                row: vec![0.0; m].into_boxed_slice(),
            });
            return self.entries.len() - 1;
        }
        let idx = self
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.stamp)
            .map(|(i, _)| i)
            .expect("pool capacity is >= 1");
        self.entries[idx].node = node;
        self.entries[idx].stamp = self.stamp;
        idx
    }

    fn drop_node(&mut self, node: usize) {
        if let Some(idx) = self.entries.iter().position(|e| e.node == node) {
            self.entries.swap_remove(idx);
        }
    }
}

/// One node's at-rest state. `base == None` means the shared init row;
/// `last_true` exists only in the EF-off ablation (`None` there means
/// "never transmitted", i.e. the init row).
#[derive(Debug, Default)]
struct NodeSlot {
    base: Option<Box<[f64]>>,
    frames: Vec<Box<[u8]>>,
    frames_bytes: usize,
    last_true: Option<Box<[f64]>>,
}

impl NodeSlot {
    fn is_trivial(&self) -> bool {
        self.base.is_none() && self.frames.is_empty() && self.last_true.is_none()
    }
}

/// A bank of n per-node estimate vectors stored quantized-at-rest. Drop-in
/// for the engine's `Vec<EstimateTracker>` banks: `commit_frame`,
/// `peek_delta_into`, `note_sent` and `row` (≡ `estimate`) carry the same
/// semantics, assertions and bit-level arithmetic as
/// [`crate::compress::error_feedback::EstimateTracker`].
#[derive(Debug)]
pub struct QuantBank {
    n: usize,
    m: usize,
    feedback: bool,
    /// The shared initial estimate (x⁰ for x̂, zeros for û): the implicit
    /// base/last_true of every slot that has no state of its own.
    init_row: Vec<f64>,
    slots: Vec<NodeSlot>,
    /// Pure cache of materialized rows — never serialized.
    pool: ScratchPool,
}

/// Dense scratch rows kept resident at once (the "active set" bound).
const POOL_CAP: usize = 64;

impl QuantBank {
    pub fn new(n: usize, init_row: Vec<f64>, feedback: bool) -> Self {
        Self {
            n,
            m: init_row.len(),
            feedback,
            init_row,
            slots: (0..n).map(|_| NodeSlot::default()).collect(),
            pool: ScratchPool::new(POOL_CAP.min(n.max(1))),
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn dim(&self) -> usize {
        self.m
    }

    pub fn feedback_enabled(&self) -> bool {
        self.feedback
    }

    /// Resident at-rest bytes across all slots (frames + dense bases +
    /// EF-off last-sent rows; excludes the bounded scratch pool) — the
    /// quantity the scale bench reports.
    pub fn resident_bytes(&self) -> usize {
        self.slots
            .iter()
            .map(|s| {
                s.frames_bytes
                    + s.base.as_ref().map_or(0, |b| b.len() * 8)
                    + s.last_true.as_ref().map_or(0, |b| b.len() * 8)
            })
            .sum()
    }

    /// Materialize node `i`'s dense row (base + frame replay) in the
    /// scratch pool and return it. Bitwise equal to the dense tracker's
    /// `estimate()` — the replay applies the identical `row[j] += v`
    /// sequence the tracker's `commit_frame` calls applied.
    pub fn row(&mut self, i: usize) -> &[f64] {
        let idx = self.ensure_row(i);
        &self.pool.entries[idx].row
    }

    fn ensure_row(&mut self, i: usize) -> usize {
        if let Some(idx) = self.pool.find(i) {
            return idx;
        }
        let idx = self.pool.claim(i, self.m);
        let slot = &self.slots[i];
        let row = &mut self.pool.entries[idx].row;
        match &slot.base {
            Some(b) => row.copy_from_slice(b),
            None => row.copy_from_slice(&self.init_row),
        }
        for frame in &slot.frames {
            replay_frame(frame, self.m, row).expect("committed frame replays");
        }
        idx
    }

    /// Apply a committed wire frame to node `i`: ŷᵢ += C(Δ). Same
    /// dimension/finiteness contract as `EstimateTracker::commit_frame`.
    pub fn commit_frame(&mut self, i: usize, c: &Compressed) -> anyhow::Result<()> {
        let fm = c.frame_dim()?;
        assert_eq!(
            fm,
            self.m,
            "commit length mismatch: message has {} coords, tracker {}",
            fm,
            self.m
        );
        let mut finite = true;
        match self.pool.find(i) {
            // row resident: fold the entries in directly (one pass)
            Some(idx) => {
                let row = &mut self.pool.entries[idx].row;
                c.for_each_entry(|j, v| {
                    finite &= v.is_finite();
                    row[j] += v;
                })?;
            }
            // at rest: the frame is appended below; scan for finiteness only
            None => {
                c.for_each_entry(|_, v| finite &= v.is_finite())?;
            }
        }
        assert!(
            finite,
            "non-finite dequantized delta would poison the estimate bank permanently"
        );
        let slot = &mut self.slots[i];
        slot.frames_bytes += c.wire.len();
        slot.frames.push(c.wire.clone().into_boxed_slice());
        if slot.frames_bytes > self.m * 8 {
            self.compact(i);
        }
        Ok(())
    }

    /// Fold the frame sequence into a dense base. Depends only on the
    /// committed frames (deterministic across pool states), and the result
    /// is bitwise the materialized row, so `row()` before and after
    /// compaction agree.
    fn compact(&mut self, i: usize) {
        let idx = self.ensure_row(i);
        let dense: Box<[f64]> = self.pool.entries[idx].row.to_vec().into_boxed_slice();
        let slot = &mut self.slots[i];
        slot.base = Some(dense);
        slot.frames.clear();
        slot.frames_bytes = 0;
    }

    /// The Δ a sender should compress, without committing to the
    /// transmission — `EstimateTracker::peek_delta_into` semantics: EF-on
    /// base is the estimate row, EF-off base is the last *sent* iterate.
    pub fn peek_delta_into(&mut self, i: usize, current: &[f64], out: &mut Vec<f64>) {
        assert_eq!(
            current.len(),
            self.m,
            "delta base length mismatch: iterate has {} coords, tracker {}",
            current.len(),
            self.m
        );
        out.clear();
        if self.feedback {
            let base = self.row(i);
            out.extend(current.iter().zip(base.iter()).map(|(c, b)| c - b));
        } else {
            let base: &[f64] = match &self.slots[i].last_true {
                Some(lt) => lt,
                None => &self.init_row,
            };
            out.extend(current.iter().zip(base.iter()).map(|(c, b)| c - b));
        }
    }

    /// Record a realized transmission (EF-off delta base; no-op with EF on,
    /// matching the tracker).
    pub fn note_sent(&mut self, i: usize, current: &[f64]) {
        if self.feedback {
            return;
        }
        assert_eq!(current.len(), self.m, "note_sent length mismatch");
        match &mut self.slots[i].last_true {
            Some(lt) => lt.copy_from_slice(current),
            lt @ None => *lt = Some(current.to_vec().into_boxed_slice()),
        }
    }

    /// Owned copy of node `i`'s dense estimate (accessor convenience).
    pub fn estimate(&mut self, i: usize) -> Vec<f64> {
        self.row(i).to_vec()
    }
}

/// ŷ += decode(frame), streaming — the same entry visitor (hence the same
/// f64 addition sequence) as `EstimateTracker::commit_frame`.
fn replay_frame(frame: &[u8], m: usize, row: &mut [f64]) -> anyhow::Result<()> {
    for e in wire::entries(frame, m)? {
        let (j, v) = e?;
        row[j] += v;
    }
    Ok(())
}

/// Serialized form: feedback flag, init row, then per-slot base / frames /
/// last_true. The scratch pool is cache and is rebuilt empty. Packing is
/// canonical in the at-rest state, and the at-rest state is a
/// deterministic function of the commit history, so pack∘unpack∘pack is
/// byte-stable and resumed-run snapshots stay byte-identical.
impl Pack for QuantBank {
    fn pack(&self, w: &mut Writer) {
        w.put_bool(self.feedback);
        w.put_usize(self.n);
        self.init_row.pack(w);
        for s in &self.slots {
            pack_opt_row(w, &s.base);
            w.put_usize(s.frames.len());
            for f in &s.frames {
                w.put_bytes(f);
            }
            pack_opt_row(w, &s.last_true);
        }
    }

    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let feedback = r.get_bool()?;
        let n = r.get_usize()?;
        let init_row = Vec::<f64>::unpack(r)?;
        let m = init_row.len();
        let mut slots = Vec::with_capacity(n);
        for i in 0..n {
            let base = unpack_opt_row(r)?;
            if let Some(b) = &base {
                anyhow::ensure!(
                    b.len() == m,
                    "snapshot bank: node {i} base has {} coords, bank dim {m}",
                    b.len()
                );
            }
            let n_frames = r.get_usize()?;
            let mut frames = Vec::with_capacity(n_frames.min(1024));
            let mut frames_bytes = 0usize;
            for _ in 0..n_frames {
                let f = r.get_bytes()?;
                anyhow::ensure!(
                    wire::frame_dim(&f)? == m,
                    "snapshot bank: node {i} holds a frame of the wrong dimension"
                );
                frames_bytes += f.len();
                frames.push(f.into_boxed_slice());
            }
            let last_true = unpack_opt_row(r)?;
            if let Some(lt) = &last_true {
                anyhow::ensure!(
                    !feedback,
                    "snapshot bank: last_true present with error feedback on"
                );
                anyhow::ensure!(
                    lt.len() == m,
                    "snapshot bank: node {i} last_true has {} coords, bank dim {m}",
                    lt.len()
                );
            }
            slots.push(NodeSlot { base, frames, frames_bytes, last_true });
        }
        Ok(Self {
            n,
            m,
            feedback,
            init_row,
            slots,
            pool: ScratchPool::new(POOL_CAP.min(n.max(1))),
        })
    }
}

fn pack_opt_row(w: &mut Writer, row: &Option<Box<[f64]>>) {
    match row {
        None => w.put_bool(false),
        Some(b) => {
            w.put_bool(true);
            w.put_usize(b.len());
            for &v in b.iter() {
                w.put_f64(v);
            }
        }
    }
}

fn unpack_opt_row(r: &mut Reader<'_>) -> anyhow::Result<Option<Box<[f64]>>> {
    if !r.get_bool()? {
        return Ok(None);
    }
    let len = r.get_len()?;
    let mut v = Vec::with_capacity(len);
    for _ in 0..len {
        v.push(r.get_f64()?);
    }
    Ok(Some(v.into_boxed_slice()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::error_feedback::EstimateTracker;
    use crate::compress::{Compressor, CompressorKind};
    use crate::util::rng::Pcg64;

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// The core contract: an identical commit/peek/note_sent history drives
    /// the quantized-at-rest bank and the dense trackers to bitwise-equal
    /// estimates and deltas — across eviction, replay and compaction.
    #[test]
    fn matches_dense_trackers_bitwise() {
        for feedback in [true, false] {
            let m = 48;
            let n = 5;
            let mut rng = Pcg64::seed_from_u64(42);
            let init = rng.normal_vec(m, 0.0, 1.0);
            let mut bank = QuantBank::new(n, init.clone(), feedback);
            // tiny pool forces eviction + replay constantly
            bank.pool = ScratchPool::new(2);
            let mut dense: Vec<EstimateTracker> =
                (0..n).map(|_| EstimateTracker::new(init.clone(), feedback)).collect();
            let comp = CompressorKind::Qsgd { bits: 3 }.build();
            let mut iterates: Vec<Vec<f64>> = (0..n).map(|_| init.clone()).collect();
            for round in 0..40 {
                let i = round % n;
                for v in &mut iterates[i] {
                    *v += 0.3 * rng.standard_normal();
                }
                let (mut da, mut db) = (Vec::new(), Vec::new());
                bank.peek_delta_into(i, &iterates[i], &mut da);
                dense[i].peek_delta_into(&iterates[i], &mut db);
                assert_eq!(bits(&da), bits(&db), "round {round} delta");
                if round % 7 == 3 {
                    continue; // skipped dispatch: no note_sent, no commit
                }
                bank.note_sent(i, &iterates[i]);
                dense[i].note_sent(&iterates[i]);
                let c = comp.compress(&da, &mut rng);
                bank.commit_frame(i, &c).unwrap();
                dense[i].commit_frame(&c).unwrap();
            }
            for i in 0..n {
                assert_eq!(
                    bits(bank.row(i)),
                    bits(dense[i].estimate()),
                    "node {i} feedback={feedback}"
                );
            }
        }
    }

    /// Same bitwise round-trip across all 8 compressor kinds the repo
    /// exercises (the satellite-test matrix; the randomized-interleaving
    /// version lives in tests/prop.rs).
    #[test]
    fn round_trips_bitwise_for_all_compressor_kinds() {
        let kinds = [
            CompressorKind::Identity,
            CompressorKind::Identity32,
            CompressorKind::Qsgd { bits: 2 },
            CompressorKind::Qsgd { bits: 3 },
            CompressorKind::Qsgd { bits: 11 },
            CompressorKind::Sign,
            CompressorKind::TopK { frac_permille: 100 },
            CompressorKind::RandK { frac_permille: 100 },
        ];
        for kind in kinds {
            let m = 64;
            let comp = kind.build();
            let mut rng = Pcg64::seed_from_u64(7);
            let init = vec![0.0; m];
            let mut bank = QuantBank::new(1, init.clone(), true);
            let mut tracker = EstimateTracker::new(init, true);
            let mut y = vec![0.0; m];
            for _ in 0..30 {
                for v in &mut y {
                    *v += 0.2 * rng.standard_normal();
                }
                let mut d = Vec::new();
                bank.peek_delta_into(0, &y, &mut d);
                let c = comp.compress(&d, &mut rng);
                bank.commit_frame(0, &c).unwrap();
                // drive the tracker with ITS delta base (must agree)
                let mut dt = Vec::new();
                tracker.peek_delta_into(&y, &mut dt);
                assert_eq!(bits(&d), bits(&dt), "kind={}", kind.label());
                tracker.commit_frame(&c).unwrap();
            }
            assert_eq!(bits(bank.row(0)), bits(tracker.estimate()), "kind={}", kind.label());
        }
    }

    /// Idle nodes hold no per-node allocation; committed frames are bounded
    /// at ≤ one dense row per slot before compaction folds them away.
    #[test]
    fn memory_is_o_active() {
        let m = 32;
        let n = 10_000;
        let mut rng = Pcg64::seed_from_u64(3);
        let mut bank = QuantBank::new(n, vec![0.0; m], true);
        assert_eq!(bank.resident_bytes(), 0, "idle fleet costs nothing at rest");
        let comp = CompressorKind::Qsgd { bits: 3 }.build();
        // hammer a handful of nodes; the rest stay trivial
        for round in 0..200 {
            let i = round % 7;
            let d = rng.normal_vec(m, 0.0, 1.0);
            let c = comp.compress(&d, &mut rng);
            bank.commit_frame(i, &c).unwrap();
        }
        assert!(bank.slots.iter().skip(7).all(NodeSlot::is_trivial));
        // each active slot: ≤ dense base + one dense row of frames
        for s in bank.slots.iter().take(7) {
            assert!(s.frames_bytes <= m * 8, "compaction bounds resident frames");
        }
        assert!(bank.resident_bytes() <= 7 * 2 * m * 8 + 7 * 64);
    }

    #[test]
    fn pack_round_trip_is_byte_stable() {
        let m = 16;
        let mut rng = Pcg64::seed_from_u64(9);
        let mut bank = QuantBank::new(4, rng.normal_vec(m, 0.0, 1.0), false);
        let comp = CompressorKind::Qsgd { bits: 4 }.build();
        for round in 0..10 {
            let i = round % 4;
            let y = rng.normal_vec(m, 0.0, 1.0);
            let mut d = Vec::new();
            bank.peek_delta_into(i, &y, &mut d);
            bank.note_sent(i, &y);
            let c = comp.compress(&d, &mut rng);
            bank.commit_frame(i, &c).unwrap();
        }
        let rows: Vec<Vec<f64>> = (0..4).map(|i| bank.row(i).to_vec()).collect();
        let mut w = Writer::new();
        bank.pack(&mut w);
        let body = w.into_inner();
        let mut r = Reader::new(&body);
        let mut back = QuantBank::unpack(&mut r).unwrap();
        r.finish().unwrap();
        for i in 0..4 {
            assert_eq!(bits(&rows[i]), bits(back.row(i)), "node {i}");
        }
        let mut w2 = Writer::new();
        back.pack(&mut w2);
        assert_eq!(body, w2.into_inner(), "pack∘unpack∘pack byte-stable");
    }

    #[test]
    fn unpack_rejects_corrupt_slots() {
        let bank = QuantBank::new(2, vec![0.0; 8], true);
        let mut w = Writer::new();
        bank.pack(&mut w);
        let mut bytes = w.into_inner();
        // truncation is an error, never a panic
        bytes.truncate(bytes.len() - 1);
        let mut r = Reader::new(&bytes);
        assert!(
            QuantBank::unpack(&mut r).is_err() || r.finish().is_err(),
            "truncated bank body must fail to decode"
        );
    }

    #[test]
    #[should_panic(expected = "poison the estimate bank")]
    fn non_finite_frame_fails_loudly() {
        let mut bank = QuantBank::new(1, vec![0.0; 3], true);
        let c = Compressed { wire: wire::encode_dense64(&[1.0, f64::NAN, 0.0]) };
        let _ = bank.commit_frame(0, &c);
    }

    #[test]
    #[should_panic(expected = "commit length mismatch")]
    fn wrong_dimension_frame_fails_loudly() {
        let mut bank = QuantBank::new(1, vec![0.0; 3], true);
        let c = Compressed { wire: wire::encode_dense64(&[1.0, 2.0]) };
        let _ = bank.commit_frame(0, &c);
    }
}
