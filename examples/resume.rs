//! Checkpoint → kill → resume quickstart on the n = 256 LASSO preset.
//!
//! ```text
//! cargo run --release --example resume
//! ```
//!
//! Runs the event engine under straggler latency for 60 consensus rounds
//! three ways:
//!
//! 1. straight through (the reference trajectory);
//! 2. to round 30, snapshotting to `out/resume-quickstart.qsnap`, then
//!    **dropping the engine and the problem** (the simulated crash);
//! 3. reloading the snapshot, re-deriving the problem from the seed, and
//!    resuming rounds 31–60.
//!
//! The resumed trajectory must be bit-identical to the reference — z,
//! per-link wire bits, RNG streams — which is exactly what
//! `qadmm run --checkpoint-every K` / `--resume-from FILE` give long runs
//! for free. See README § "Checkpoint / resume".

use std::path::PathBuf;

use qadmm::admm::engine::EventEngine;
use qadmm::admm::runner::trial_seed;
use qadmm::admm::sim::TrialRngs;
use qadmm::comm::latency::LatencyModel;
use qadmm::comm::profile::LinkConfig;
use qadmm::compress::CompressorKind;
use qadmm::config::{presets, EngineKind, ExperimentConfig, ProblemKind};
use qadmm::problems::lasso::{LassoConfig, LassoProblem};
use qadmm::snapshot;
use qadmm::util::timer::Stopwatch;

/// The n = 256 LASSO configuration the topology/downlink sweeps use,
/// trimmed to quickstart length.
fn preset_n256() -> ExperimentConfig {
    let mut cfg = presets::ci_lasso();
    cfg.name = "resume-quickstart".into();
    cfg.problem = ProblemKind::Lasso { m: 128, h: 16, n: 256, rho: 50.0, theta: 0.1 };
    cfg.compressor = CompressorKind::Qsgd { bits: 3 };
    cfg.engine = EngineKind::Event;
    cfg.tau = 4;
    cfg.p_min = 64;
    cfg.iters = 60;
    cfg.mc_trials = 1;
    cfg.eval_every = 10;
    // heterogeneous stragglers: the checkpoint lands with updates still on
    // the virtual wire, the case worth demonstrating
    cfg.link = LinkConfig {
        compute: LatencyModel::Mixture { fast: 0.002, slow: 0.25, p_slow: 0.15 },
        uplink: LatencyModel::Exp(0.01),
        downlink: LatencyModel::Exp(0.01),
        clock_drift: 0.05,
    };
    cfg
}

fn make_problem(cfg: &ExperimentConfig) -> anyhow::Result<(LassoProblem, TrialRngs)> {
    let lcfg = match cfg.problem {
        ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
        _ => unreachable!(),
    };
    let mut rngs = TrialRngs::new(trial_seed(cfg.seed, 0));
    let mut p = LassoProblem::generate(lcfg, &mut rngs.data)?;
    p.set_reference_optimum(1.0); // quickstart: skip the F* reference solve
    Ok((p, rngs))
}

fn main() -> anyhow::Result<()> {
    let cfg = preset_n256();
    let ck_round = cfg.iters / 2;
    let ck_path = PathBuf::from("out/resume-quickstart.qsnap");
    println!(
        "resume quickstart: n=256 LASSO, {} rounds, checkpoint at round {ck_round}",
        cfg.iters
    );

    // ---- 1. the reference: straight through ----
    let clock = Stopwatch::new();
    let (mut p_ref, rngs) = make_problem(&cfg)?;
    let mut reference = EventEngine::new(&cfg, &mut p_ref, rngs)?;
    for _ in 0..cfg.iters {
        reference.step_round()?;
    }
    println!(
        "  straight run:  {} rounds in {:.2}s (virtual {:.1}s)",
        cfg.iters,
        clock.elapsed_secs(),
        reference.stats().virtual_time
    );

    // ---- 2. run to the checkpoint, snapshot, and "crash" ----
    let (mut p_a, rngs) = make_problem(&cfg)?;
    let mut engine = EventEngine::new(&cfg, &mut p_a, rngs)?;
    for _ in 0..ck_round {
        engine.step_round()?;
    }
    snapshot::write_file(&ck_path, &engine.snapshot_meta(), &engine.snapshot_body())?;
    let snap_bytes = std::fs::metadata(&ck_path)?.len();
    drop(engine);
    drop(p_a); // everything the first process held is gone
    println!(
        "  checkpointed:  round {ck_round} -> {} ({:.1} KiB)",
        ck_path.display(),
        snap_bytes as f64 / 1024.0
    );

    // ---- 3. a "new process": read the file, re-derive, resume ----
    let (meta, body) = snapshot::read_file(&ck_path)?;
    anyhow::ensure!(
        snapshot::config_resume_digest(&meta.config) == cfg.resume_digest(),
        "snapshot belongs to a different experiment"
    );
    println!(
        "  resuming:      engine={} round={} n={} m={} (problem re-derived from seed {})",
        meta.engine, meta.round, meta.n, meta.m, meta.seed
    );
    let (mut p_b, _) = make_problem(&cfg)?;
    let mut resumed = EventEngine::resume(&cfg, &mut p_b, &body)?;
    while resumed.stats().rounds < cfg.iters {
        resumed.step_round()?;
    }

    // ---- the contract: bit-identical continuation ----
    anyhow::ensure!(
        reference.z() == resumed.z(),
        "resumed z differs from the straight run"
    );
    anyhow::ensure!(
        reference.staleness() == resumed.staleness(),
        "resumed staleness differs"
    );
    anyhow::ensure!(
        reference.rng_digest() == resumed.rng_digest(),
        "resumed RNG streams differ"
    );
    anyhow::ensure!(
        reference.accounting().total_bits() == resumed.accounting().total_bits(),
        "resumed wire-bit totals differ"
    );
    println!(
        "  OK: resumed run is bit-identical (z, staleness, {} wire bits, RNG states)",
        resumed.accounting().total_bits()
    );
    println!(
        "same flow from the CLI:\n  qadmm run --preset ci-lasso --engine event --trials 1 \
         --checkpoint-every {ck_round} --checkpoint {}\n  qadmm run ... --resume-from {}",
        ck_path.display(),
        ck_path.display()
    );
    Ok(())
}
