//! Figure/table drivers: each regenerates one piece of the paper's
//! evaluation (§5) — the same workload, parameters, baselines and summary
//! rows — writing CSV series under `out/` and printing headline numbers.

pub mod ablation;
pub mod deploy;
pub mod downlink;
pub mod fig3;
pub mod fig4;
pub mod resume;
pub mod topology;
pub mod trigger;

use crate::admm::runner::McResult;
use crate::metrics::RunRecorder;

/// One (configuration → averaged curves) pair produced by a driver.
pub struct Series {
    pub label: String,
    pub result: McResult,
}

impl Series {
    pub fn mean_recorder(&self) -> RunRecorder {
        self.result.mean_recorder()
    }

    pub fn write_csv(&self, dir: &std::path::Path, stem: &str) -> anyhow::Result<()> {
        let path = dir.join(format!("{stem}_{}.csv", self.label));
        self.mean_recorder().write_csv(&path)?;
        Ok(())
    }
}

/// Milestone table shared by the figure drivers: value of a metric at a few
/// x positions along both axes (iterations / communication bits).
pub fn milestones(rec: &RunRecorder, metric: impl Fn(&crate::metrics::IterRecord) -> f64) -> String {
    let n = rec.records.len();
    if n == 0 {
        return "  (no records)".into();
    }
    let picks = [n / 10, n / 4, n / 2, (3 * n) / 4, n - 1];
    let mut out = String::new();
    for &i in &picks {
        let r = &rec.records[i.min(n - 1)];
        out.push_str(&format!(
            "  iter {:>6}  bits/param {:>12.1}  metric {:>12.4e}\n",
            r.iter,
            r.comm_bits,
            metric(r)
        ));
    }
    out
}
