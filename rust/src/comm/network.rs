//! Thread-backed star network for the deployed (non-simulated) runtime:
//! std::sync::mpsc channels wrapped with bit accounting, injected per-link
//! latency (uplink sleeps on send, downlink sleeps on delivery, compute
//! sleeps via [`NodeEndpoint::inject_compute_delay`]), duplicate injection
//! (failure testing) and sequence-number deduplication at the receiver.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::accounting::CommAccounting;
use super::message::{NodeToServer, ServerToNode};
use super::profile::LinkProfile;
use crate::util::rng::Pcg64;

/// Fault-injection knobs for a link (per direction).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultSpec {
    /// Probability a message is delivered twice (receiver must dedup).
    pub dup_prob: f64,
}

/// Shared accounting handle (server + nodes update concurrently).
pub type SharedAccounting = Arc<Mutex<CommAccounting>>;

/// Node-side endpoint of the star.
pub struct NodeEndpoint {
    pub node: usize,
    to_server: Sender<NodeToServer>,
    from_server: Receiver<ServerToNode>,
    accounting: SharedAccounting,
    profile: LinkProfile,
    faults: FaultSpec,
    rng: Pcg64,
    seq: u64,
}

impl NodeEndpoint {
    /// Send with accounting + injected uplink latency + optional duplication.
    pub fn send(&mut self, mut msg: NodeToServer) -> anyhow::Result<()> {
        match &mut msg {
            NodeToServer::Update { seq, .. } | NodeToServer::Skip { seq, .. } => {
                *seq = self.seq;
                self.seq += 1;
            }
            NodeToServer::InitFull { .. }
            | NodeToServer::ShutdownAck { .. }
            | NodeToServer::Leave { .. } => {}
        }
        // A Skip is the *absence* of a transmission: neither bits nor the
        // per-link message counter may move (the event trigger's zero-
        // steady-state-uplink contract is asserted against both). The
        // shutdown ack and a synthesized leave are control plane and
        // likewise leave the books untouched. The uplink latency and
        // duplicate injection below still apply — the arrival signal
        // itself propagates like any other delivery.
        if matches!(msg, NodeToServer::Update { .. } | NodeToServer::InitFull { .. }) {
            let bits = msg.wire_bits();
            self.accounting.lock().unwrap().record_uplink(self.node, bits);
        }
        let delay = self.profile.sample_uplink(&mut self.rng);
        if delay > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(delay));
        }
        if self.rng.bernoulli(self.faults.dup_prob) {
            self.to_server
                .send(msg.clone())
                .map_err(|_| anyhow::anyhow!("server hung up"))?;
        }
        self.to_server.send(msg).map_err(|_| anyhow::anyhow!("server hung up"))
    }

    /// Blocking receive; the downlink transit of the delivered message is
    /// injected here, on the receiving side, so a slow downlink delays this
    /// node without stalling the server's broadcast loop.
    pub fn recv(&mut self) -> anyhow::Result<ServerToNode> {
        let msg =
            self.from_server.recv().map_err(|_| anyhow::anyhow!("server hung up"))?;
        let delay = self.profile.sample_downlink(&mut self.rng);
        if delay > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(delay));
        }
        Ok(msg)
    }

    /// Non-blocking receive (backlog draining for stragglers — the backlog
    /// is already late, so no additional downlink sleep is injected).
    pub fn try_recv(&self) -> Option<ServerToNode> {
        self.from_server.try_recv().ok()
    }

    /// Injected local-compute time, scaled by the node's clock drift
    /// (called by the worker after each local update).
    pub fn inject_compute_delay(&mut self) {
        let delay = self.profile.sample_compute(&mut self.rng);
        if delay > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(delay));
        }
    }
}

/// Where a server-side downlink message goes: per-node mpsc senders (the
/// in-process star and the old pump bridge), or a single shared bus the
/// deploy reactor implements — one `broadcast` call hands over the whole
/// round instead of n clones through n channels.
///
/// Accounting moves with the bytes: in `Channels` mode the endpoint
/// charges eq. (20) on send (delivery is the channel push); in `Bus` mode
/// the sink's owner charges each link when the frame actually completes on
/// that link's socket, so the endpoint charges nothing and a broadcast to
/// a detached node costs nothing.
pub trait DownlinkSink: Send {
    fn unicast(&self, node: usize, msg: ServerToNode) -> anyhow::Result<()>;
    /// Deliver one message to every attached node. The implementation owns
    /// fan-out (shared encode, per-recipient variants) and per-link
    /// accounting at write completion.
    fn broadcast(&self, msg: ServerToNode) -> anyhow::Result<()>;
}

enum Downlink {
    Channels(Vec<Sender<ServerToNode>>),
    Bus { sink: Box<dyn DownlinkSink>, n: usize },
}

/// Server-side endpoint: fan-in from all nodes + the downlink fan-out.
pub struct ServerEndpoint {
    from_nodes: Receiver<NodeToServer>,
    down: Downlink,
    accounting: SharedAccounting,
    /// Last seen uplink sequence number per node, for dedup.
    last_seq: Vec<Option<u64>>,
}

impl ServerEndpoint {
    /// Blocking receive with duplicate suppression.
    pub fn recv(&mut self) -> anyhow::Result<NodeToServer> {
        loop {
            let msg =
                self.from_nodes.recv().map_err(|_| anyhow::anyhow!("all nodes hung up"))?;
            if !self.is_duplicate(&msg) {
                return Ok(msg);
            }
        }
    }

    pub fn recv_timeout(&mut self, timeout: Duration) -> anyhow::Result<Option<NodeToServer>> {
        loop {
            match self.from_nodes.recv_timeout(timeout) {
                Ok(msg) => {
                    if !self.is_duplicate(&msg) {
                        return Ok(Some(msg));
                    }
                }
                Err(RecvTimeoutError::Timeout) => return Ok(None),
                Err(RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("all nodes hung up")
                }
            }
        }
    }

    /// Drain whatever is still in flight during shutdown; node hang-ups are
    /// expected here (workers exit once they see Shutdown).
    pub fn drain(&mut self, quiet: Duration) {
        loop {
            match self.from_nodes.recv_timeout(quiet) {
                Ok(_) => continue,
                Err(_) => return,
            }
        }
    }

    fn is_duplicate(&mut self, msg: &NodeToServer) -> bool {
        match msg {
            NodeToServer::Update { node, seq, .. } | NodeToServer::Skip { node, seq } => {
                if self.last_seq[*node] == Some(*seq) {
                    return true;
                }
                self.last_seq[*node] = Some(*seq);
                false
            }
            // control messages carry no sequence number: init is
            // idempotent at the server, acks/leaves are level-triggered
            NodeToServer::InitFull { .. }
            | NodeToServer::ShutdownAck { .. }
            | NodeToServer::Leave { .. } => false,
        }
    }

    /// Unicast to one node (accounted in `Channels` mode; a `Bus` sink
    /// charges at write completion instead).
    pub fn send(&self, node: usize, msg: ServerToNode) -> anyhow::Result<()> {
        match &self.down {
            Downlink::Channels(to_nodes) => {
                self.accounting.lock().unwrap().record_downlink(node, msg.wire_bits());
                to_nodes[node].send(msg).map_err(|_| anyhow::anyhow!("node {node} hung up"))
            }
            Downlink::Bus { sink, .. } => sink.unicast(node, msg),
        }
    }

    /// Broadcast: in `Channels` mode each link is charged separately (as
    /// in eq. 20) and gets its own clone; in `Bus` mode this is **one**
    /// sink call — the sink encodes once and shares the bytes across every
    /// attached writer.
    pub fn broadcast(&self, msg: &ServerToNode) -> anyhow::Result<()> {
        match &self.down {
            Downlink::Channels(to_nodes) => {
                for node in 0..to_nodes.len() {
                    self.send(node, msg.clone())?;
                }
                Ok(())
            }
            Downlink::Bus { sink, .. } => sink.broadcast(msg.clone()),
        }
    }

    pub fn n_nodes(&self) -> usize {
        match &self.down {
            Downlink::Channels(to_nodes) => to_nodes.len(),
            Downlink::Bus { n, .. } => *n,
        }
    }
}

/// Build a star network: one server endpoint + N node endpoints, each
/// with its own per-link [`LinkProfile`]. `extra_links` appends accounting
/// slots after the node links (indices n..n+extra) for server-colocated
/// aggregator hops ([`crate::topology`]) — they carry no channel, only
/// charged bits.
pub fn star(
    n_nodes: usize,
    profiles: &[LinkProfile],
    faults: FaultSpec,
    seed: u64,
    extra_links: usize,
) -> (ServerEndpoint, Vec<NodeEndpoint>, SharedAccounting) {
    assert_eq!(profiles.len(), n_nodes);
    let accounting: SharedAccounting =
        Arc::new(Mutex::new(CommAccounting::new(n_nodes + extra_links)));
    let (up_tx, up_rx) = channel::<NodeToServer>();
    let mut to_nodes = Vec::with_capacity(n_nodes);
    let mut endpoints = Vec::with_capacity(n_nodes);
    let mut root = Pcg64::seed_from_u64(seed);
    for node in 0..n_nodes {
        let (down_tx, down_rx) = channel::<ServerToNode>();
        to_nodes.push(down_tx);
        endpoints.push(NodeEndpoint {
            node,
            to_server: up_tx.clone(),
            from_server: down_rx,
            accounting: accounting.clone(),
            profile: profiles[node],
            faults,
            rng: root.fork(node as u64),
            seq: 0,
        });
    }
    let server = ServerEndpoint {
        from_nodes: up_rx,
        down: Downlink::Channels(to_nodes),
        accounting: accounting.clone(),
        last_seq: vec![None; n_nodes],
    };
    (server, endpoints, accounting)
}

/// Build the channel half of a socket deployment: a [`ServerEndpoint`] for
/// the unchanged [`crate::coordinator::server::ServerLoop`], plus the raw
/// uplink `Sender` (cloned into per-connection reader threads) and the
/// per-node downlink `Receiver`s (owned by per-node writer pumps that
/// forward onto whatever socket the node is currently attached to).
///
/// The endpoint's internal accounting is a **throwaway**: in the deploy
/// shape bits are charged where bytes actually move — readers charge the
/// uplink on a decoded frame, pumps charge the downlink on a completed
/// write — so the endpoint's send-side charging must not double-count, and
/// a broadcast to a detached node must cost nothing. The caller keeps its
/// own [`SharedAccounting`] for the real books.
pub fn bridged(
    n_nodes: usize,
) -> (ServerEndpoint, Sender<NodeToServer>, Vec<Receiver<ServerToNode>>) {
    let (up_tx, up_rx) = channel::<NodeToServer>();
    let mut to_nodes = Vec::with_capacity(n_nodes);
    let mut down_rxs = Vec::with_capacity(n_nodes);
    for _ in 0..n_nodes {
        let (down_tx, down_rx) = channel::<ServerToNode>();
        to_nodes.push(down_tx);
        down_rxs.push(down_rx);
    }
    let server = ServerEndpoint {
        from_nodes: up_rx,
        down: Downlink::Channels(to_nodes),
        accounting: Arc::new(Mutex::new(CommAccounting::new(n_nodes))),
        last_seq: vec![None; n_nodes],
    };
    (server, up_tx, down_rxs)
}

/// Bridge for the reactor deployment: the downlink is a [`DownlinkSink`]
/// the socket reactor implements — `broadcast` hands the whole round over
/// in **one** call (shared encode, zero per-node clones) and all downlink
/// accounting happens sink-side at write completion. The uplink receiver
/// is supplied by the caller (the reactor hub owns the matching `Sender`
/// and clones it into its I/O shards). The endpoint's internal accounting
/// stays a throwaway, exactly as in [`bridged`].
pub fn bridged_sink(
    n_nodes: usize,
    from_nodes: Receiver<NodeToServer>,
    sink: Box<dyn DownlinkSink>,
) -> ServerEndpoint {
    ServerEndpoint {
        from_nodes,
        down: Downlink::Bus { sink, n: n_nodes },
        accounting: Arc::new(Mutex::new(CommAccounting::new(n_nodes))),
        last_seq: vec![None; n_nodes],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn update(node: usize, iter: u64) -> NodeToServer {
        NodeToServer::Update { node, iter, seq: 0, dx_wire: vec![0; 8], du_wire: vec![0; 8] }
    }

    #[test]
    fn roundtrip_with_accounting() {
        let (mut server, mut nodes, acc) =
            star(2, &[LinkProfile::none(); 2], FaultSpec::default(), 1, 0);
        nodes[0].send(update(0, 0)).unwrap();
        nodes[1].send(update(1, 0)).unwrap();
        for _ in 0..2 {
            let msg = server.recv().unwrap();
            assert!(matches!(msg, NodeToServer::Update { .. }));
        }
        server
            .broadcast(&ServerToNode::Consensus {
                iter: 0,
                included: vec![0, 1],
                dz_wire: vec![0; 4],
                last: false,
            })
            .unwrap();
        assert!(matches!(nodes[0].recv().unwrap(), ServerToNode::Consensus { .. }));
        assert!(matches!(nodes[1].recv().unwrap(), ServerToNode::Consensus { .. }));
        let acc = acc.lock().unwrap();
        assert_eq!(acc.total_uplink_bits(), 2 * (12 + 16) * 8);
        // header + payload per link (the inclusion list is control plane
        // and not charged — eq. 20 counts data)
        assert_eq!(acc.total_downlink_bits(), 2 * (12 + 4) * 8);
    }

    #[test]
    fn duplicates_are_suppressed() {
        let (mut server, mut nodes, _acc) = star(
            1,
            &[LinkProfile::none()],
            FaultSpec { dup_prob: 1.0 }, // every message duplicated
            2,
            0,
        );
        nodes[0].send(update(0, 0)).unwrap();
        nodes[0].send(update(0, 1)).unwrap();
        let a = server.recv().unwrap();
        let b = server.recv().unwrap();
        // seq 0 then seq 1 — the duplicates in between were dropped
        match (a, b) {
            (
                NodeToServer::Update { seq: s1, .. },
                NodeToServer::Update { seq: s2, .. },
            ) => {
                assert_eq!((s1, s2), (0, 1));
            }
            _ => panic!("wrong kinds"),
        }
        // nothing further pending
        assert!(server.recv_timeout(Duration::from_millis(50)).unwrap().is_none());
    }

    /// A skipped dispatch shares the node's sequence counter (dedup covers
    /// it) but leaves the uplink books — bits *and* message count — fully
    /// untouched: it is the absence of a transmission.
    #[test]
    fn skip_is_deduplicated_but_never_accounted() {
        let (mut server, mut nodes, acc) = star(
            1,
            &[LinkProfile::none()],
            FaultSpec { dup_prob: 1.0 }, // every message duplicated
            5,
            0,
        );
        nodes[0].send(NodeToServer::Skip { node: 0, seq: 0 }).unwrap();
        nodes[0].send(update(0, 1)).unwrap();
        match server.recv().unwrap() {
            NodeToServer::Skip { node: 0, seq: 0 } => {}
            other => panic!("expected the skip first, got {other:?}"),
        }
        match server.recv().unwrap() {
            NodeToServer::Update { seq: 1, .. } => {}
            other => panic!("expected the update, got {other:?}"),
        }
        // the duplicates in between were dropped by the shared seq counter
        assert!(server.recv_timeout(Duration::from_millis(50)).unwrap().is_none());
        let acc = acc.lock().unwrap();
        assert_eq!(acc.total_uplink_bits(), (12 + 16) * 8); // the Update only
        assert_eq!(acc.link(0).uplink_msgs, 1);
    }

    #[test]
    fn recv_timeout_times_out() {
        let (mut server, _nodes, _acc) =
            star(1, &[LinkProfile::none()], FaultSpec::default(), 3, 0);
        let got = server.recv_timeout(Duration::from_millis(20)).unwrap();
        assert!(got.is_none());
    }

    /// A `Bus`-mode endpoint hands a broadcast to the sink exactly once
    /// (no per-node clones) and charges nothing itself: the reactor books
    /// each link at write completion.
    #[test]
    fn bus_endpoint_broadcasts_once_and_charges_nothing() {
        struct CountSink {
            bcasts: Arc<Mutex<Vec<ServerToNode>>>,
            unis: Arc<Mutex<Vec<(usize, ServerToNode)>>>,
        }
        impl DownlinkSink for CountSink {
            fn unicast(&self, node: usize, msg: ServerToNode) -> anyhow::Result<()> {
                self.unis.lock().unwrap().push((node, msg));
                Ok(())
            }
            fn broadcast(&self, msg: ServerToNode) -> anyhow::Result<()> {
                self.bcasts.lock().unwrap().push(msg);
                Ok(())
            }
        }
        let bcasts = Arc::new(Mutex::new(Vec::new()));
        let unis = Arc::new(Mutex::new(Vec::new()));
        let sink = CountSink { bcasts: bcasts.clone(), unis: unis.clone() };
        let (up_tx, up_rx) = channel();
        let mut server = bridged_sink(3, up_rx, Box::new(sink));
        assert_eq!(server.n_nodes(), 3);
        server
            .broadcast(&ServerToNode::Consensus {
                iter: 0,
                included: vec![0, 2],
                dz_wire: vec![1, 2, 3],
                last: false,
            })
            .unwrap();
        server.send(1, ServerToNode::Shutdown).unwrap();
        assert_eq!(bcasts.lock().unwrap().len(), 1, "one sink call per broadcast");
        assert!(matches!(unis.lock().unwrap()[0], (1, ServerToNode::Shutdown)));
        // uplink still flows through the raw sender
        up_tx.send(update(2, 0)).unwrap();
        assert!(matches!(server.recv().unwrap(), NodeToServer::Update { node: 2, .. }));
    }

    /// The bridged endpoint forwards raw messages both ways and leaves the
    /// caller's books alone: its internal accounting is a throwaway the
    /// deploy transport never reads (bytes are charged at the sockets).
    #[test]
    fn bridged_endpoint_routes_without_charging_the_caller() {
        let (mut server, up_tx, down_rxs) = bridged(2);
        up_tx.send(update(1, 0)).unwrap();
        assert!(matches!(server.recv().unwrap(), NodeToServer::Update { node: 1, .. }));
        server.send(0, ServerToNode::Shutdown).unwrap();
        assert!(matches!(down_rxs[0].recv().unwrap(), ServerToNode::Shutdown));
        assert!(down_rxs[1].try_recv().is_err()); // unicast, not broadcast
        // control messages pass the dedup untouched
        up_tx.send(NodeToServer::ShutdownAck { node: 0 }).unwrap();
        up_tx.send(NodeToServer::Leave { node: 1 }).unwrap();
        assert!(matches!(server.recv().unwrap(), NodeToServer::ShutdownAck { node: 0 }));
        assert!(matches!(server.recv().unwrap(), NodeToServer::Leave { node: 1 }));
    }
}
