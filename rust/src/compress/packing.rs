//! Bit-level packing: fixed-width fields, sign-magnitude levels, and
//! Elias-γ for sparse index gaps. This is what turns "q bits per scalar"
//! from an accounting fiction into actual wire bytes.

/// Little-endian bit writer.
#[derive(Default)]
pub struct BitWriter {
    bytes: Vec<u8>,
    /// Bits used in the last byte (0 ⇒ byte boundary).
    bit_pos: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append the low `width` bits of `value` (LSB first).
    pub fn put(&mut self, value: u64, width: u32) {
        debug_assert!(width <= 64);
        debug_assert!(width == 64 || value < (1u64 << width));
        let mut remaining = width;
        let mut v = value;
        while remaining > 0 {
            if self.bit_pos == 0 {
                self.bytes.push(0);
            }
            let free = 8 - self.bit_pos;
            let take = free.min(remaining);
            let last = self.bytes.last_mut().unwrap();
            *last |= ((v & ((1u64 << take) - 1)) as u8) << self.bit_pos;
            v >>= take;
            self.bit_pos = (self.bit_pos + take) % 8;
            remaining -= take;
        }
    }

    /// Elias-γ code for `value ≥ 1`: ⌊log₂v⌋ zeros, then v's bits (MSB=1 first).
    pub fn put_elias_gamma(&mut self, value: u64) {
        debug_assert!(value >= 1);
        let nbits = 64 - value.leading_zeros();
        for _ in 0..nbits - 1 {
            self.put(0, 1);
        }
        // emit MSB-first so the reader can detect the leading 1
        for i in (0..nbits).rev() {
            self.put((value >> i) & 1, 1);
        }
    }

    pub fn bit_len(&self) -> u64 {
        if self.bytes.is_empty() {
            0
        } else {
            (self.bytes.len() as u64 - 1) * 8
                + if self.bit_pos == 0 { 8 } else { self.bit_pos as u64 }
        }
    }

    pub fn finish(self) -> Vec<u8> {
        self.bytes
    }
}

/// Little-endian bit reader.
pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos: u64, // absolute bit position
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Self { bytes, pos: 0 }
    }

    pub fn get(&mut self, width: u32) -> anyhow::Result<u64> {
        debug_assert!(width <= 64);
        let mut out = 0u64;
        let mut got = 0u32;
        while got < width {
            let byte_idx = (self.pos / 8) as usize;
            anyhow::ensure!(byte_idx < self.bytes.len(), "bitstream underrun");
            let bit_off = (self.pos % 8) as u32;
            let avail = 8 - bit_off;
            let take = avail.min(width - got);
            let chunk = ((self.bytes[byte_idx] >> bit_off) as u64) & ((1u64 << take) - 1);
            out |= chunk << got;
            got += take;
            self.pos += take as u64;
        }
        Ok(out)
    }

    pub fn get_elias_gamma(&mut self) -> anyhow::Result<u64> {
        let mut zeros = 0u32;
        loop {
            if self.get(1)? == 1 {
                break;
            }
            zeros += 1;
            anyhow::ensure!(zeros < 64, "corrupt elias-gamma code");
        }
        let mut value = 1u64;
        for _ in 0..zeros {
            value = (value << 1) | self.get(1)?;
        }
        Ok(value)
    }
}

/// Pack signed levels in `[-S, S]` with sign-magnitude at `q` bits each:
/// 1 sign bit + (q−1) magnitude bits, where `S = 2^(q−1) − 1`.
pub fn pack_levels(levels: &[i32], q: u8) -> Vec<u8> {
    let s = (1i32 << (q - 1)) - 1;
    let mut w = BitWriter::new();
    for &lvl in levels {
        debug_assert!(lvl.abs() <= s, "level {lvl} out of range for q={q}");
        let sign = (lvl < 0) as u64;
        let mag = lvl.unsigned_abs() as u64;
        w.put(sign | (mag << 1), q as u32);
    }
    w.finish()
}

/// Inverse of [`pack_levels`].
pub fn unpack_levels(bytes: &[u8], m: usize, q: u8) -> anyhow::Result<Vec<i32>> {
    let mut r = BitReader::new(bytes);
    let mut out = Vec::with_capacity(m);
    for _ in 0..m {
        let field = r.get(q as u32)?;
        let sign = field & 1;
        let mag = (field >> 1) as i32;
        out.push(if sign == 1 { -mag } else { mag });
    }
    Ok(out)
}

/// Exact packed size in bytes for `m` levels at `q` bits.
pub fn packed_len(m: usize, q: u8) -> usize {
    (m * q as usize).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn bitwriter_roundtrip_mixed_widths() {
        let mut w = BitWriter::new();
        let fields = [(5u64, 3u32), (1023, 10), (0, 1), (1, 1), (u32::MAX as u64, 32), (7, 7)];
        for (v, width) in fields {
            w.put(v, width);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for (v, width) in fields {
            assert_eq!(r.get(width).unwrap(), v);
        }
    }

    #[test]
    fn elias_gamma_roundtrip() {
        let mut w = BitWriter::new();
        let values = [1u64, 2, 3, 7, 8, 100, 12345, u32::MAX as u64];
        for v in values {
            w.put_elias_gamma(v);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for v in values {
            assert_eq!(r.get_elias_gamma().unwrap(), v);
        }
    }

    #[test]
    fn levels_roundtrip_all_q() {
        let mut rng = Pcg64::seed_from_u64(3);
        for q in 2u8..=10 {
            let s = (1i32 << (q - 1)) - 1;
            let levels: Vec<i32> =
                (0..777).map(|_| rng.gen_range((2 * s + 1) as usize) as i32 - s).collect();
            let bytes = pack_levels(&levels, q);
            assert_eq!(bytes.len(), packed_len(777, q));
            let back = unpack_levels(&bytes, 777, q).unwrap();
            assert_eq!(back, levels);
        }
    }

    #[test]
    fn packed_len_is_q_bits_per_scalar() {
        assert_eq!(packed_len(8, 3), 3); // 24 bits
        assert_eq!(packed_len(1, 3), 1);
        assert_eq!(packed_len(1000, 3), 375);
    }

    #[test]
    fn underrun_is_error() {
        let mut r = BitReader::new(&[0xFF]);
        assert!(r.get(9).is_err());
        assert!(unpack_levels(&[0x01], 100, 3).is_err());
    }

    #[test]
    fn bit_len_tracks_exactly() {
        let mut w = BitWriter::new();
        w.put(1, 3);
        assert_eq!(w.bit_len(), 3);
        w.put(1, 6);
        assert_eq!(w.bit_len(), 9);
    }
}
