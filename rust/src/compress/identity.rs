//! Identity "compressor": full-precision f64 wire — the unquantized
//! async-ADMM baseline the paper compares against. Its wire size is what
//! the ~90% reduction headline is measured relative to.

use super::{sanitize, Compressed, Compressor};
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "identity".into()
    }

    fn compress(&self, delta: &[f64], rng: &mut Pcg64) -> Compressed {
        let mut out = Compressed::empty();
        self.compress_into(delta, rng, &mut out);
        out
    }

    /// Pooled-buffer variant: clears and refills `out`, reusing capacity —
    /// no steady-state allocation. The frame comes from the same
    /// [`super::wire::encode_dense64_into`] encoder `compress` uses.
    /// Lossless for finite inputs; non-finite coordinates are dropped
    /// (0.0) like every other compressor, so a diverged delta cannot
    /// poison the receiving estimate bank even on the baseline path.
    fn compress_into(&self, delta: &[f64], _rng: &mut Pcg64, out: &mut Compressed) {
        if delta.iter().all(|v| v.is_finite()) {
            super::wire::encode_dense64_into(delta, &mut out.wire);
        } else {
            let clean: Vec<f64> = delta.iter().map(|&v| sanitize(v)).collect();
            super::wire::encode_dense64_into(&clean, &mut out.wire);
        }
    }
}

/// Dense fp32 wire — the paper's "full precision (e.g., 32-bits per
/// scalar)" baseline accounting. The f64→f32 rounding is a (tiny, unbiased
/// only in effect) compression whose residual error feedback absorbs, so
/// the dequantized value is the decoded f32 (sender mirror == receiver).
#[derive(Clone, Copy, Debug)]
pub struct Identity32;

impl Compressor for Identity32 {
    fn name(&self) -> String {
        "identity32".into()
    }

    fn compress(&self, delta: &[f64], rng: &mut Pcg64) -> Compressed {
        let mut out = Compressed::empty();
        self.compress_into(delta, rng, &mut out);
        out
    }

    /// Pooled-buffer variant via [`super::wire::encode_dense32_into`] —
    /// one source of truth for the dense32 frame. Non-finite coordinates
    /// are dropped (0.0), as on every other compressor.
    fn compress_into(&self, delta: &[f64], _rng: &mut Pcg64, out: &mut Compressed) {
        if delta.iter().all(|v| v.is_finite()) {
            super::wire::encode_dense32_into(delta, &mut out.wire);
        } else {
            let clean: Vec<f64> = delta.iter().map(|&v| sanitize(v)).collect();
            super::wire::encode_dense32_into(&clean, &mut out.wire);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lossless() {
        let delta = vec![1.0, -2.5, 1e-17, 0.0];
        let c = Identity.compress(&delta, &mut Pcg64::seed_from_u64(0));
        assert_eq!(c.dequantized().unwrap(), delta);
        assert_eq!(Identity.decode(&c.wire, 4).unwrap(), delta);
        assert_eq!(c.wire.len(), 5 + 4 * 8);
    }
}
