//! Top-k sparsifier [10,14]: keep the k largest-magnitude coordinates,
//! zero the rest. Indices gap-coded with Elias-γ on the wire.
//! Biased, so it *requires* error feedback to converge — which is exactly
//! what the EF ablation demonstrates.

use super::wire::encode_topk;
use super::{sanitize, Compressed, Compressor};
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug)]
pub struct TopK {
    frac: f64,
}

impl TopK {
    pub fn new(frac: f64) -> Self {
        assert!(frac > 0.0 && frac <= 1.0, "topk fraction must be in (0, 1]");
        Self { frac }
    }

    pub fn k_for(&self, m: usize) -> usize {
        ((self.frac * m as f64).ceil() as usize).clamp(1, m)
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        // Round-trip with `CompressorKind::parse`: a fraction below 0.0005
        // used to round to "topk0", which the parser (rightly) rejects —
        // clamp to the 1..=1000 permille range the parser accepts.
        format!("topk{}", ((self.frac * 1000.0).round() as u64).clamp(1, 1000))
    }

    fn compress(&self, delta: &[f64], _rng: &mut Pcg64) -> Compressed {
        let m = delta.len();
        let k = self.k_for(m);
        let mut order: Vec<usize> = (0..m).collect();
        // Selection runs on the sanitized magnitudes under `total_cmp`: the
        // seed's `partial_cmp(..).unwrap()` aborted the whole run on a
        // single NaN coordinate (select_nth panics on incomparable keys),
        // and a selected ±∞ would have ridden the wire into the estimate
        // banks. Non-finite coordinates rank as 0 and encode as 0.0 —
        // dropped from the update, not transmitted as poison.
        order.select_nth_unstable_by(k - 1, |&a, &b| {
            sanitize(delta[b]).abs().total_cmp(&sanitize(delta[a]).abs())
        });
        let mut keep: Vec<usize> = order[..k].to_vec();
        keep.sort_unstable();
        let entries: Vec<(usize, f64)> =
            keep.iter().map(|&i| (i, sanitize(delta[i]))).collect();
        Compressed { wire: encode_topk(m, &entries) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_largest_magnitudes() {
        let delta = vec![0.1, -5.0, 0.2, 3.0, -0.05];
        let c = TopK::new(0.4).compress(&delta, &mut Pcg64::seed_from_u64(0));
        assert_eq!(c.dequantized().unwrap(), vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn decode_matches() {
        let mut rng = Pcg64::seed_from_u64(1);
        let delta = rng.normal_vec(400, 0.0, 1.0);
        let t = TopK::new(0.05);
        let c = t.compress(&delta, &mut rng);
        let dq = c.dequantized().unwrap();
        assert_eq!(t.decode(&c.wire, 400).unwrap(), dq);
        assert_eq!(dq.iter().filter(|&&v| v != 0.0).count(), t.k_for(400));
    }

    #[test]
    fn k_at_least_one() {
        assert_eq!(TopK::new(0.001).k_for(10), 1);
        assert_eq!(TopK::new(1.0).k_for(10), 10);
    }

    /// Regression: a single NaN coordinate aborted the run inside
    /// `select_nth_unstable_by` (partial_cmp().unwrap() on incomparable
    /// keys). Non-finite coordinates now rank as 0 and encode as 0.0.
    #[test]
    fn non_finite_inputs_neither_panic_nor_reach_the_wire() {
        let mut rng = Pcg64::seed_from_u64(3);
        let t = TopK::new(0.5);
        let delta = vec![f64::NAN, 5.0, f64::INFINITY, -3.0, f64::NEG_INFINITY, 0.1];
        let c = t.compress(&delta, &mut rng);
        let dq = c.dequantized().unwrap();
        assert!(dq.iter().all(|v| v.is_finite()));
        // the finite magnitudes win the selection
        assert_eq!(dq[1], 5.0);
        assert_eq!(dq[3], -3.0);
        assert_eq!(t.decode(&c.wire, 6).unwrap(), dq);
        // all-NaN input degrades to an all-zero update
        let c = t.compress(&[f64::NAN; 8], &mut rng);
        assert!(c.dequantized().unwrap().iter().all(|&v| v == 0.0));
    }

    /// Regression: name() rounded fractions below 0.0005 to "topk0", which
    /// `CompressorKind::parse` rejects — the label must stay parseable.
    #[test]
    fn name_round_trips_through_parse_for_tiny_fractions() {
        use crate::compress::CompressorKind;
        for frac in [0.0001, 0.0004, 0.001, 0.05, 1.0] {
            let name = TopK::new(frac).name();
            CompressorKind::parse(&name)
                .unwrap_or_else(|e| panic!("frac={frac}: '{name}' unparseable: {e}"));
        }
        assert_eq!(TopK::new(0.0001).name(), "topk1");
        assert_eq!(TopK::new(1.0).name(), "topk1000");
    }

    #[test]
    fn wire_much_smaller_than_dense_for_sparse_k() {
        let mut rng = Pcg64::seed_from_u64(2);
        let delta = rng.normal_vec(10_000, 0.0, 1.0);
        let c = TopK::new(0.01).compress(&delta, &mut rng);
        assert!(c.wire.len() < 10_000 * 8 / 10, "wire={}", c.wire.len());
    }
}
