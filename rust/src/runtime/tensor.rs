//! Host-side tensor values crossing the rust ⇄ PJRT boundary.

/// A dense host tensor (row-major) in one of the dtypes the artifacts use.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F64(Vec<f64>, Vec<usize>),
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    pub fn scalar_f64(x: f64) -> Self {
        Tensor::F64(vec![x], vec![])
    }

    pub fn scalar_f32(x: f32) -> Self {
        Tensor::F32(vec![x], vec![])
    }

    pub fn vec_f64(v: Vec<f64>) -> Self {
        let n = v.len();
        Tensor::F64(v, vec![n])
    }

    pub fn vec_f32(v: Vec<f32>) -> Self {
        let n = v.len();
        Tensor::F32(v, vec![n])
    }

    pub fn vec_i32(v: Vec<i32>) -> Self {
        let n = v.len();
        Tensor::I32(v, vec![n])
    }

    /// f64 data reinterpreted as f32 with the given shape (NN boundary).
    pub fn f32_from_f64(v: &[f64], shape: Vec<usize>) -> Self {
        debug_assert_eq!(v.len(), shape.iter().product::<usize>());
        Tensor::F32(v.iter().map(|&x| x as f32).collect(), shape)
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F64(_, s) | Tensor::F32(_, s) | Tensor::I32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F64(v, _) => v.len(),
            Tensor::F32(v, _) => v.len(),
            Tensor::I32(v, _) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            Tensor::F64(..) => "f64",
            Tensor::F32(..) => "f32",
            Tensor::I32(..) => "i32",
        }
    }

    pub fn as_f64(&self) -> anyhow::Result<&[f64]> {
        match self {
            Tensor::F64(v, _) => Ok(v),
            t => anyhow::bail!("expected f64 tensor, got {}", t.dtype_name()),
        }
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            Tensor::F32(v, _) => Ok(v),
            t => anyhow::bail!("expected f32 tensor, got {}", t.dtype_name()),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            Tensor::I32(v, _) => Ok(v),
            t => anyhow::bail!("expected i32 tensor, got {}", t.dtype_name()),
        }
    }

    /// Any numeric tensor widened to f64 (convenience at the NN boundary).
    pub fn to_f64_vec(&self) -> Vec<f64> {
        match self {
            Tensor::F64(v, _) => v.clone(),
            Tensor::F32(v, _) => v.iter().map(|&x| x as f64).collect(),
            Tensor::I32(v, _) => v.iter().map(|&x| x as f64).collect(),
        }
    }

    /// Scalar extraction (rank-0 or single-element).
    pub fn scalar(&self) -> anyhow::Result<f64> {
        anyhow::ensure!(self.len() == 1, "tensor has {} elements, wanted 1", self.len());
        Ok(self.to_f64_vec()[0])
    }

    /// Upload to a device buffer (the fast execution path: `execute_b`
    /// avoids the Literal layout conversion that costs ~10× the transfer).
    #[cfg(feature = "xla-runtime")]
    pub fn to_buffer(&self, client: &xla::PjRtClient) -> anyhow::Result<xla::PjRtBuffer> {
        let res = match self {
            Tensor::F64(v, s) => client.buffer_from_host_buffer(v, s, None),
            Tensor::F32(v, s) => client.buffer_from_host_buffer(v, s, None),
            Tensor::I32(v, s) => client.buffer_from_host_buffer(v, s, None),
        };
        res.map_err(|e| anyhow::anyhow!("host->device transfer: {e:?}"))
    }

    #[cfg(feature = "xla-runtime")]
    pub fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F64(v, _) => xla::Literal::vec1(v),
            Tensor::F32(v, _) => xla::Literal::vec1(v),
            Tensor::I32(v, _) => xla::Literal::vec1(v),
        };
        lit.reshape(&dims).map_err(|e| anyhow::anyhow!("reshape literal: {e:?}"))
    }

    #[cfg(feature = "xla-runtime")]
    pub fn from_literal(lit: &xla::Literal) -> anyhow::Result<Tensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow::anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let ty = lit.ty().map_err(|e| anyhow::anyhow!("literal dtype: {e:?}"))?;
        match ty {
            xla::ElementType::F64 => Ok(Tensor::F64(
                lit.to_vec::<f64>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
                dims,
            )),
            xla::ElementType::F32 => Ok(Tensor::F32(
                lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
                dims,
            )),
            xla::ElementType::S32 => Ok(Tensor::I32(
                lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
                dims,
            )),
            other => anyhow::bail!("unsupported output element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_shapes() {
        let t = Tensor::vec_f64(vec![1.0, 2.0, 3.0]);
        assert_eq!(t.shape(), &[3]);
        assert_eq!(t.len(), 3);
        assert_eq!(t.dtype_name(), "f64");
        assert_eq!(Tensor::scalar_f32(1.0).shape(), &[] as &[usize]);
    }

    #[test]
    fn accessors_enforce_dtype() {
        let t = Tensor::vec_i32(vec![1, 2]);
        assert!(t.as_i32().is_ok());
        assert!(t.as_f64().is_err());
        assert_eq!(t.to_f64_vec(), vec![1.0, 2.0]);
    }

    #[test]
    fn f32_from_f64_casts() {
        let t = Tensor::f32_from_f64(&[1.5, -2.5], vec![2]);
        assert_eq!(t.as_f32().unwrap(), &[1.5f32, -2.5f32]);
    }

    #[test]
    fn scalar_extraction() {
        assert_eq!(Tensor::scalar_f64(4.25).scalar().unwrap(), 4.25);
        assert!(Tensor::vec_f64(vec![1.0, 2.0]).scalar().is_err());
    }

    #[cfg(feature = "xla-runtime")]
    #[test]
    fn literal_roundtrip_f64() {
        let t = Tensor::F64(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[cfg(feature = "xla-runtime")]
    #[test]
    fn literal_roundtrip_scalar_and_i32() {
        for t in [Tensor::scalar_f32(7.5), Tensor::vec_i32(vec![-1, 0, 9])] {
            let lit = t.to_literal().unwrap();
            assert_eq!(Tensor::from_literal(&lit).unwrap(), t);
        }
    }
}
