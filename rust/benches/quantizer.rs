//! Hot-path microbenches for the compressor C(Δ): quantize / dequantize /
//! full compress (incl. wire packing) / decode, across sizes and q. This is
//! the L3 perf target (EXPERIMENTS.md §Perf).
//!
//!     cargo bench --bench quantizer     (QADMM_BENCH_FAST=1 for smoke)

use qadmm::bench_harness::Bencher;
use qadmm::compress::qsgd::Qsgd;
use qadmm::compress::{Compressor, CompressorKind};
use qadmm::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Pcg64::seed_from_u64(1);

    for &m in &[200usize, 10_000, 1_000_000] {
        let delta = rng.normal_vec(m, 0.0, 1.0);
        let noise = rng.uniform_vec_f64(m);
        let q = Qsgd::new(3);
        b.bench_val(&format!("qsgd3/quantize_with_noise/m={m}"), m, || {
            q.quantize_with_noise(&delta, &noise)
        });
        let (levels, norm) = q.quantize_with_noise(&delta, &noise);
        b.bench_val(&format!("qsgd3/dequantize/m={m}"), m, || {
            q.dequantize(&levels, norm)
        });
        b.bench_val(&format!("qsgd3/compress_full(rng+pack)/m={m}"), m, || {
            q.compress(&delta, &mut rng)
        });
        let wire = q.from_levels(&levels, norm).wire;
        b.bench_val(&format!("qsgd3/decode/m={m}"), m, || {
            q.decode(&wire, m).unwrap()
        });
    }

    // q sweep at fixed size
    let m = 100_000;
    let delta = rng.normal_vec(m, 0.0, 1.0);
    for q in [2u8, 3, 4, 8] {
        let c = Qsgd::new(q);
        b.bench_val(&format!("qsgd{q}/compress_full/m={m}"), m, || {
            c.compress(&delta, &mut rng)
        });
    }

    // other compressor families at the same size
    for kind in [
        CompressorKind::Sign,
        CompressorKind::TopK { frac_permille: 10 },
        CompressorKind::RandK { frac_permille: 10 },
        CompressorKind::Identity,
    ] {
        let c = kind.build();
        b.bench_val(&format!("{}/compress_full/m={m}", kind.label()), m, || {
            c.compress(&delta, &mut rng)
        });
    }

    b.finish("quantizer");
}
