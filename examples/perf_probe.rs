//! §Perf probe: component timings for the codec hot path and the PJRT
//! dispatch chain (direct runtime vs compute-service channel hop).
use qadmm::compress::qsgd::Qsgd;
use qadmm::compress::Compressor;
use qadmm::runtime::service::ComputeService;
use qadmm::runtime::tensor::Tensor;
use qadmm::runtime::Runtime;
use qadmm::util::rng::Pcg64;
use std::time::Instant;

fn time<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    for _ in 0..3 { std::hint::black_box(f()); }
    let t = Instant::now();
    for _ in 0..reps { std::hint::black_box(f()); }
    t.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    // --- codec ---
    let mut rng = Pcg64::seed_from_u64(1);
    let m = 1_000_000;
    let delta = rng.normal_vec(m, 0.0, 1.0);
    let q = Qsgd::new(3);
    let fused = time(20, || q.compress(&delta, &mut rng));
    let refr = time(20, || q.compress_reference(&delta, &mut rng));
    println!("codec: fused {:.2}ms ({:.1}M/s) vs reference {:.2}ms ({:.1}M/s)",
        fused*1e3, m as f64/fused/1e6, refr*1e3, m as f64/refr/1e6);

    // --- PJRT dispatch chain (lasso_node_step, m=200) ---
    if !std::path::Path::new("artifacts/manifest.json").exists() { return; }
    let rt = Runtime::open(std::path::Path::new("artifacts")).unwrap();
    let mm = 200;
    let minv = Tensor::F64(rng.normal_vec(mm*mm, 0.0, 0.01), vec![mm, mm]);
    let vecs: Vec<Tensor> = (0..7).map(|_| Tensor::vec_f64(rng.normal_vec(mm, 0.0, 1.0))).collect();
    let inputs = || {
        let mut v = vec![minv.clone()];
        v.extend(vecs.iter().cloned());
        v.push(Tensor::scalar_f64(500.0));
        v.push(Tensor::scalar_f64(3.0));
        v
    };
    let ins = inputs();
    let direct = time(200, || rt.call("lasso_node_step", &ins).unwrap());
    println!("pjrt: direct Runtime::call lasso_node_step = {:.1}µs", direct*1e6);
    // literal creation alone
    let lit = time(200, || {
        ins.iter().map(|t| t.to_literal().unwrap()).collect::<Vec<_>>()
    });
    println!("pjrt: literal creation alone = {:.1}µs", lit*1e6);
    let svc = ComputeService::start("artifacts".into(), vec!["lasso_node_step".into()]).unwrap();
    let client = svc.client();
    let via_svc = time(200, || client.call("lasso_node_step", inputs()).unwrap());
    println!("pjrt: via ComputeService channel = {:.1}µs", via_svc*1e6);
    // tiny artifact for fixed-cost floor
    let qd = Tensor::vec_f64(rng.normal_vec(200, 0.0, 1.0));
    let qn = Tensor::vec_f64(rng.uniform_vec_f64(200));
    let qi = vec![qd, qn, Tensor::scalar_f64(3.0)];
    let tiny = time(200, || rt.call("quantize_f64_m200", &qi).unwrap());
    println!("pjrt: direct quantize_f64_m200 (tiny) = {:.1}µs", tiny*1e6);
}
// appended probe: execute_b with cached constant buffers (run via second main shim not used)
