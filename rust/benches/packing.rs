//! Bit-packing microbenches: pack/unpack of q-bit sign-magnitude levels and
//! the Elias-γ sparse index coder.

use qadmm::bench_harness::Bencher;
use qadmm::compress::packing::{pack_levels, unpack_levels, BitReader, BitWriter};
use qadmm::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Pcg64::seed_from_u64(2);
    let m = 1_000_000;

    for q in [3u8, 8] {
        let s = (1i32 << (q - 1)) - 1;
        let levels: Vec<i32> =
            (0..m).map(|_| rng.gen_range((2 * s + 1) as usize) as i32 - s).collect();
        b.bench_val(&format!("pack_levels/q={q}/m={m}"), m, || pack_levels(&levels, q));
        let packed = pack_levels(&levels, q);
        b.bench_val(&format!("unpack_levels/q={q}/m={m}"), m, || {
            unpack_levels(&packed, m, q).unwrap()
        });
    }

    // Elias-γ gap coding (top-k index stream)
    let gaps: Vec<u64> = (0..100_000).map(|_| 1 + rng.gen_range(1000) as u64).collect();
    b.bench_val("elias_gamma/write/100k", gaps.len(), || {
        let mut w = BitWriter::new();
        for &g in &gaps {
            w.put_elias_gamma(g);
        }
        w.finish()
    });
    let mut w = BitWriter::new();
    for &g in &gaps {
        w.put_elias_gamma(g);
    }
    let bytes = w.finish();
    b.bench_val("elias_gamma/read/100k", gaps.len(), || {
        let mut r = BitReader::new(&bytes);
        let mut acc = 0u64;
        for _ in 0..gaps.len() {
            acc = acc.wrapping_add(r.get_elias_gamma().unwrap());
        }
        acc
    });

    b.finish("packing");
}
