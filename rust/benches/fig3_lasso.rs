//! Figure-3 regeneration bench: runs the (reduced) LASSO experiment for
//! τ ∈ {1, 3} × {QADMM, baseline} on the native backend and prints the
//! paper's series milestones + headline reduction, with wall-clock timing.
//!
//! Scale with env: QADMM_FIG3_ITERS / QADMM_FIG3_TRIALS (defaults 250 / 2;
//! the paper's setting is 700 / 10 via `qadmm fig3` or the example).

use qadmm::config::Backend;
use qadmm::exp::fig3::{run, Fig3Options};
use qadmm::util::timer::Stopwatch;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let opts = Fig3Options {
        taus: vec![1, 3],
        iters: env_usize("QADMM_FIG3_ITERS", 250),
        mc_trials: env_usize("QADMM_FIG3_TRIALS", 2),
        backend: Backend::Native,
        out_dir: "out".into(),
        artifact_dir: "artifacts".into(),
        target: 1e-8,
    };
    let sw = Stopwatch::new();
    let summary = run(&opts).expect("fig3 run");
    for s in &summary.series {
        println!("--- fig3 {} ---", s.label);
        print!("{}", qadmm::exp::milestones(&s.mean_recorder(), |r| r.accuracy));
    }
    for h in &summary.headline {
        println!("{h}");
    }
    println!(
        "fig3 bench: {} iters x {} trials x 4 configs in {:.2}s",
        opts.iters,
        opts.mc_trials,
        sw.elapsed_secs()
    );
}
