//! Event-trigger / adaptive-quantization ablation: δ × level-schedule vs
//! fixed QSGD on bits-to-target.
//!
//! Two problem families, both Fig. 3-style scales: the exact-update LASSO
//! (Woodbury closed-form local solve) and the inexact-update logistic
//! regression (K gradient steps, the related work's [5]–[8] workload). For
//! each, the grid crosses the dead-band δ ∈ {0, δ_lo, δ_hi} with the
//! adaptive level schedule on/off; the δ=0 + fixed cell *is* today's QSGD
//! baseline (byte-for-byte — the parity suites assert it), so every other
//! row reads as a savings (or regression) against it on the same axis:
//! normalized communication bits to reach the accuracy target (eq. 20).
//!
//! Invoke with `qadmm trigger [--iters N] [--trials N] [--target X]
//! [--quick]`.

use crate::admm::runner::{self, ProblemFactory};
use crate::compress::CompressorKind;
use crate::config::{presets, EngineKind, ExperimentConfig, OracleConfig, ProblemKind};
use crate::metrics::summary;
use crate::problems::lasso::{LassoConfig, LassoProblem};
use crate::problems::logreg::{LogRegConfig, LogRegProblem};
use crate::problems::Problem;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct TriggerRow {
    pub label: String,
    pub family: String,
    pub delta: f64,
    pub adapt: bool,
    pub final_accuracy: f64,
    pub bits_to_target: Option<f64>,
    pub total_bits: f64,
}

impl TriggerRow {
    pub fn render(&self) -> String {
        format!(
            "{:40} final_acc {:>10.3e}  bits@target {:>12}  total_bits/param {:>12.1}",
            self.label,
            self.final_accuracy,
            self.bits_to_target
                .map(|b| format!("{b:.1}"))
                .unwrap_or_else(|| "n/a".into()),
            self.total_bits
        )
    }
}

pub struct TriggerSweepOptions {
    pub iters: usize,
    pub mc_trials: usize,
    pub target: f64,
    /// Restrict to the LASSO family (CI / smoke); the full grid adds the
    /// inexact logistic-regression family.
    pub quick: bool,
}

impl Default for TriggerSweepOptions {
    fn default() -> Self {
        Self { iters: 300, mc_trials: 2, target: 1e-6, quick: false }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Lasso,
    LogReg,
}

impl Family {
    fn label(self) -> &'static str {
        match self {
            Family::Lasso => "lasso",
            Family::LogReg => "logreg",
        }
    }
}

/// Dead-band grid per family. The EF-adjusted deltas shrink with the
/// residual, so δ only starts suppressing sends once a node is close to
/// consensus — the useful range sits a few decades under the initial
/// delta magnitude (~O(1) for both generated problem families).
fn deltas() -> [f64; 3] {
    [0.0, 1e-6, 1e-4]
}

fn sweep_cfg(family: Family, delta: f64, adapt: bool, opts: &TriggerSweepOptions) -> ExperimentConfig {
    let mut cfg = presets::ci_lasso();
    // Problem dims ride in cfg.problem even for logreg (the engines read
    // only n from it; the actual instance comes from the factory).
    cfg.problem = ProblemKind::Lasso { m: 64, h: 8, n: 32, rho: 500.0, theta: 0.1 };
    cfg.name = format!(
        "trigger-{}-d{delta:.0e}-{}",
        family.label(),
        if adapt { "adapt" } else { "fixed" }
    );
    cfg.compressor = CompressorKind::Qsgd { bits: 4 };
    cfg.engine = EngineKind::Event;
    cfg.tau = 4;
    cfg.p_min = 8;
    cfg.iters = opts.iters;
    cfg.mc_trials = opts.mc_trials;
    cfg.eval_every = 1;
    cfg.oracle = OracleConfig { p_slow: 0.1, p_fast: 0.8, regroup_each_call: false };
    cfg.trigger.delta = delta;
    cfg.trigger.adapt = adapt;
    cfg
}

fn run_one(cfg: &ExperimentConfig, family: Family, opts: &TriggerSweepOptions) -> anyhow::Result<McRow> {
    let (m, h, n, rho) = match cfg.problem {
        ProblemKind::Lasso { m, h, n, rho, .. } => (m, h, n, rho),
        _ => unreachable!(),
    };
    let mut factory: Box<ProblemFactory> = match family {
        Family::Lasso => {
            let lcfg = LassoConfig { m, h, n, rho, theta: 0.1 };
            Box::new(move |_seed, data_rng: &mut Pcg64| {
                Ok(Box::new(LassoProblem::generate(lcfg, data_rng)?) as Box<dyn Problem>)
            })
        }
        Family::LogReg => {
            let lcfg =
                LogRegConfig { m, h, n, rho: 2.0, gamma: 1.0, k_steps: 8, lr: 0.02 };
            Box::new(move |_seed, data_rng: &mut Pcg64| {
                Ok(Box::new(LogRegProblem::generate(lcfg, data_rng)?) as Box<dyn Problem>)
            })
        }
    };
    let res = runner::run_mc(cfg, factory.as_mut())?;
    drop(factory);
    let rec = res.mean_recorder();
    Ok(McRow {
        final_accuracy: *res.mean_accuracy.last().unwrap(),
        bits_to_target: summary::bits_to_accuracy(&rec.records, opts.target),
        total_bits: *res.mean_comm_bits.last().unwrap(),
    })
}

struct McRow {
    final_accuracy: f64,
    bits_to_target: Option<f64>,
    total_bits: f64,
}

/// Run the δ × schedule grid, printing one table per problem family.
pub fn run(opts: &TriggerSweepOptions) -> anyhow::Result<Vec<TriggerRow>> {
    let families: &[Family] =
        if opts.quick { &[Family::Lasso] } else { &[Family::Lasso, Family::LogReg] };
    let mut all = Vec::new();
    for &family in families {
        println!(
            "--- trigger sweep: {} (delta x level-schedule; delta=0 fixed = today's QSGD) ---",
            family.label()
        );
        for adapt in [false, true] {
            for delta in deltas() {
                let cfg = sweep_cfg(family, delta, adapt, opts);
                let r = run_one(&cfg, family, opts)?;
                let row = TriggerRow {
                    label: format!(
                        "{} delta={delta:.0e} levels={}",
                        family.label(),
                        if adapt { "adaptive" } else { "fixed" }
                    ),
                    family: family.label().into(),
                    delta,
                    adapt,
                    final_accuracy: r.final_accuracy,
                    bits_to_target: r.bits_to_target,
                    total_bits: r.total_bits,
                };
                println!("{}", row.render());
                all.push(row);
            }
        }
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One tiny grid point per family end-to-end: the sweep config (with
    /// the trigger enabled) validates and the run completes sanely.
    #[test]
    fn one_grid_point_runs_per_family() {
        let opts = TriggerSweepOptions { iters: 8, mc_trials: 1, target: 1e-6, quick: true };
        for family in [Family::Lasso, Family::LogReg] {
            let mut cfg = sweep_cfg(family, 1e-5, true, &opts);
            cfg.problem = ProblemKind::Lasso { m: 16, h: 6, n: 8, rho: 50.0, theta: 0.1 };
            cfg.p_min = 2;
            cfg.validate().unwrap();
            let r = run_one(&cfg, family, &opts).unwrap();
            assert!(r.final_accuracy.is_finite());
            assert!(r.total_bits > 0.0);
        }
    }

    /// The dead-band must not cost bits: at equal iteration count a δ > 0
    /// run can only suppress transmissions, so its total accounted uplink
    /// traffic is bounded by the δ = 0 baseline's.
    #[test]
    fn dead_band_never_increases_total_bits() {
        let opts = TriggerSweepOptions { iters: 12, mc_trials: 1, target: 1e-6, quick: true };
        let shrink = |mut cfg: ExperimentConfig| {
            cfg.problem = ProblemKind::Lasso { m: 16, h: 6, n: 8, rho: 50.0, theta: 0.1 };
            cfg.p_min = 2;
            cfg
        };
        let base = run_one(&shrink(sweep_cfg(Family::Lasso, 0.0, false, &opts)), Family::Lasso, &opts)
            .unwrap();
        let gated = run_one(&shrink(sweep_cfg(Family::Lasso, 1e-3, false, &opts)), Family::Lasso, &opts)
            .unwrap();
        assert!(
            gated.total_bits <= base.total_bits + 1e-9,
            "dead-band run charged more bits than the always-send baseline \
             ({} > {})",
            gated.total_bits,
            base.total_bits
        );
    }
}
