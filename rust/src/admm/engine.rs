//! Event-driven virtual-time QADMM engine (Algorithm 1 at 1000+ nodes).
//!
//! The sequential simulator ([`super::sim`]) advances in lockstep rounds;
//! the threaded coordinator ([`crate::coordinator`]) burns real wall-clock
//! on injected `thread::sleep` latency. This engine keeps the *semantics*
//! of genuine asynchrony — per-node compute and network delays, the
//! server firing on `P` arrivals, force-waiting any node at staleness τ−1 —
//! but advances a **virtual clock** through a binary-heap event queue
//! ([`super::events`]), so a 1000-node straggler run finishes in
//! milliseconds of wall time.
//!
//! Timeline per consensus round (each delay leg drawn from the node's
//! [`LinkProfile`] — compute scaled by its clock drift, uplink and
//! downlink on the server's clock):
//! 1. the server fires: consensus over the estimate banks, compressed Δz
//!    broadcast (accounted per link), scheduler advance (oracle selection +
//!    τ−1 forcing — the same [`super::scheduler::Scheduler`] the simulator
//!    uses, consuming the same oracle RNG stream). The broadcast does
//!    **not** land instantly: each node gets a `DownlinkArrive` event at
//!    `now + downlink_delay` (clamped monotone per link, so broadcasts
//!    never overtake each other) with the Δz payload queued in its FIFO
//!    inbox;
//! 2. `DownlinkArrive` commits Δz into the node's private ẑ **mirror** —
//!    the server's `zhat` bank and a node's view of it are now distinct
//!    states that agree only once every broadcast has landed. If the node
//!    was selected at fire time (and idle), its local update starts *here*:
//!    all dispatches born in one virtual instant run as one batch through
//!    [`crate::problems::Problem::local_update_batch`] (worker-pool
//!    parallel for native LASSO, merged in node order), each item reading
//!    its own mirror; deltas are compressed with per-node RNG forks and a
//!    `ComputeDone` event is scheduled at `+ compute_delay / clock_rate`
//!    (fast-clocked nodes finish sooner);
//! 3. `ComputeDone` accounts the uplink and schedules `MsgArrive` at
//!    `+ uplink_delay`; `MsgArrive` commits the dequantized deltas into
//!    the server's estimate banks and joins the sparse arrival set;
//! 4. between distinct virtual instants the server checks the trigger:
//!    |arrivals| ≥ P **and** every node whose staleness has reached τ−1
//!    has arrived. Nodes selected while still in flight are not
//!    re-dispatched (at most one update in flight per node, the Fig. 2
//!    cadence), and their eventual arrival counts toward the next round.
//!
//! **Parity contract** (see `tests/engine_parity.rs`): with zero delay on
//! every link leg and the identity compressor, every broadcast and every
//! arrival lands in the same virtual instant as its dispatch, each mirror
//! equals the server's `zhat`, rounds coincide exactly with simulator
//! iterations, and the `z` trajectory and bit accounting are bit-identical
//! to [`super::sim::AsyncSim`]. Any nonzero downlink leg breaks the
//! collapse: nodes compute against a stale ẑ, which is precisely the
//! asymmetric staleness of the paper's Fig. 2.

use std::collections::{BTreeSet, VecDeque};
use std::sync::Arc;

use crate::comm::accounting::CommAccounting;
use crate::comm::message::{INIT_BITS_PER_SCALAR, MSG_HEADER_BYTES};
use crate::comm::profile::{per_node_profiles, LinkProfile};
use crate::compress::error_feedback::EstimateTracker;
use crate::compress::Compressor;
use crate::config::ExperimentConfig;
use crate::metrics::{IterRecord, RunRecorder};
use crate::problems::{LocalUpdateItem, Problem};
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

use super::events::{EventKind, EventQueue};
use super::oracle::AsyncOracle;
use super::scheduler::Scheduler;
use super::sim::TrialRngs;

/// A compressed update sitting in a node's outbox / on the virtual wire.
struct InFlightMsg {
    dx: Vec<f64>,
    du: Vec<f64>,
    bits: u64,
    loss: f64,
}

/// One broadcast on a node's downlink: the dequantized Δz (shared across
/// all n links) and whether the node should start a local update when it
/// lands (it was selected and idle at fire time).
struct DownlinkPacket {
    dz: Arc<Vec<f64>>,
    dispatch: bool,
}

/// Timeline counters the property tests assert on.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Consensus rounds fired so far.
    pub rounds: usize,
    /// Virtual seconds elapsed.
    pub virtual_time: f64,
    /// Events processed (ComputeDone + MsgArrive + DownlinkArrive).
    pub events: u64,
    /// Local updates dispatched.
    pub dispatches: u64,
    /// Smallest arrival set that ever triggered a round (must be ≥ P);
    /// `None` until the first round fires, so reading stats early can
    /// never leak a `usize::MAX` sentinel to callers.
    pub min_arrivals: Option<usize>,
    /// Largest per-node staleness counter ever observed (must be ≤ τ−1).
    pub max_staleness: usize,
}

pub struct EventEngine<'a> {
    cfg: &'a ExperimentConfig,
    problem: &'a mut dyn Problem,
    compressor: Box<dyn Compressor>,
    m: usize,
    n: usize,
    // true iterates
    x: Vec<Vec<f64>>,
    u: Vec<Vec<f64>>,
    z: Vec<f64>,
    // server-side estimate banks (committed only on MsgArrive)
    xhat: Vec<EstimateTracker>,
    uhat: Vec<EstimateTracker>,
    zhat: EstimateTracker,
    /// Each node's private view of ẑ: advances only when a broadcast
    /// lands on its downlink (`DownlinkArrive`), never at fire time.
    /// `dispatch` reads this, not `zhat`.
    z_mirror: Vec<Vec<f64>>,
    /// Per-node FIFO of broadcasts in downlink transit.
    downlink_inbox: Vec<VecDeque<DownlinkPacket>>,
    /// Last scheduled downlink arrival per node (monotonicity clamp: a
    /// later broadcast never overtakes an earlier one on the same link).
    downlink_last: Vec<f64>,
    /// Nodes whose downlink landed with a dispatch flag in the instant
    /// being drained; flushed as one batch between instants.
    pending_dispatch: Vec<usize>,
    /// Sparse arrival set for the round being assembled (no n ≤ 64 mask).
    arrived: BTreeSet<usize>,
    /// Node has an update computing or in transit (one in flight max).
    busy: Vec<bool>,
    in_flight: Vec<Option<InFlightMsg>>,
    /// Loss delivered with each node's last arrival (round-loss fallback).
    arrived_loss: Vec<f64>,
    /// Persistent consensus-input buffers (n×m each): refreshed from the
    /// estimate banks at every fire instead of reallocated — at 1024×10k
    /// that is 160 MB of allocator churn per round saved.
    xs_buf: Vec<Vec<f64>>,
    us_buf: Vec<Vec<f64>>,
    scheduler: Scheduler,
    oracle: AsyncOracle,
    accounting: CommAccounting,
    queue: EventQueue,
    /// Per-node link profiles: compute/uplink/downlink legs + clock drift
    /// (straggler heterogeneity).
    links: Vec<LinkProfile>,
    rng_latency: Pcg64,
    rng_oracle: Pcg64,
    /// Per-node quantizer streams (forked once; order-independent).
    node_quant: Vec<Pcg64>,
    /// Server-side quantizer stream for the broadcast compression.
    server_quant: Pcg64,
    /// Per-node batch-sampling streams for inexact problems.
    node_batch: Vec<Pcg64>,
    recorder: RunRecorder,
    clock: Stopwatch,
    vtime: f64,
    stats: EngineStats,
}

impl<'a> EventEngine<'a> {
    /// Initialize per Algorithm 1 lines 1–9 — the exact same full-precision
    /// exchange (and accounting) as [`super::sim::AsyncSim::new`] — then
    /// dispatch A₀ = V at virtual time 0.
    pub fn new(
        cfg: &'a ExperimentConfig,
        problem: &'a mut dyn Problem,
        mut rngs: TrialRngs,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        let m = problem.dim();
        let n = problem.n_nodes();
        let ef = cfg.error_feedback;
        let x0 = problem.init_x(&mut rngs.init);
        anyhow::ensure!(x0.len() == m, "init_x returned wrong dimension");
        let x: Vec<Vec<f64>> = vec![x0.clone(); n];
        let u: Vec<Vec<f64>> = vec![vec![0.0; m]; n];

        let mut accounting = CommAccounting::new(n);
        for i in 0..n {
            accounting.record_uplink(
                i,
                MSG_HEADER_BYTES * 8 + 2 * m as u64 * INIT_BITS_PER_SCALAR,
            );
        }
        let xhat: Vec<EstimateTracker> =
            (0..n).map(|_| EstimateTracker::new(x0.clone(), ef)).collect();
        let uhat: Vec<EstimateTracker> =
            (0..n).map(|_| EstimateTracker::new(vec![0.0; m], ef)).collect();
        let xs: Vec<Vec<f64>> = xhat.iter().map(|t| t.estimate().to_vec()).collect();
        let us: Vec<Vec<f64>> = uhat.iter().map(|t| t.estimate().to_vec()).collect();
        let z = problem.consensus(&xs, &us)?;
        accounting.record_broadcast(MSG_HEADER_BYTES * 8 + m as u64 * INIT_BITS_PER_SCALAR);
        let zhat = EstimateTracker::new(z.clone(), ef);

        // Every node's mirror starts at the full-precision z⁰ it received
        // in the (synchronous) init broadcast.
        let z_mirror = vec![z.clone(); n];
        let oracle = AsyncOracle::new(n, cfg.oracle, &mut rngs.oracle);
        let mut qroot = rngs.quant;
        let node_quant: Vec<Pcg64> = (0..n).map(|i| qroot.fork(i as u64)).collect();
        let server_quant = qroot.fork(n as u64);
        let mut broot = rngs.batches;
        let node_batch: Vec<Pcg64> = (0..n).map(|i| broot.fork(i as u64)).collect();

        let mut engine = Self {
            compressor: cfg.compressor.build(),
            m,
            n,
            x,
            u,
            z,
            xhat,
            uhat,
            zhat,
            z_mirror,
            downlink_inbox: (0..n).map(|_| VecDeque::new()).collect(),
            downlink_last: vec![0.0; n],
            pending_dispatch: Vec::new(),
            arrived: BTreeSet::new(),
            busy: vec![false; n],
            in_flight: (0..n).map(|_| None).collect(),
            arrived_loss: vec![0.0; n],
            xs_buf: vec![vec![0.0; m]; n],
            us_buf: vec![vec![0.0; m]; n],
            scheduler: Scheduler::new(n, cfg.tau, cfg.p_min),
            oracle,
            accounting,
            queue: EventQueue::new(),
            server_quant,
            links: per_node_profiles(cfg.link, n),
            // per-trial stream: MC trials must be independent replicates
            // over network randomness, not replays of one delay sequence
            rng_latency: rngs.latency,
            rng_oracle: rngs.oracle,
            node_quant,
            node_batch,
            recorder: RunRecorder::new(),
            clock: Stopwatch::new(),
            vtime: 0.0,
            stats: EngineStats::default(),
            cfg,
            problem,
        };
        // A₀ = V: every node computes first (same as the simulator).
        let all: Vec<usize> = (0..n).collect();
        engine.dispatch(&all)?;
        Ok(engine)
    }

    /// Advance virtual time until exactly one more consensus round fires —
    /// the event-driven analogue of [`super::sim::AsyncSim::step`].
    pub fn step_round(&mut self) -> anyhow::Result<()> {
        loop {
            // Flush local updates born in the instant just drained: every
            // node whose downlink landed here (with a dispatch flag) runs
            // in one batch, so uniform delays keep the worker-pool fan-out
            // of the zero-latency timeline.
            if !self.pending_dispatch.is_empty() {
                let mut nodes = std::mem::take(&mut self.pending_dispatch);
                nodes.sort_unstable();
                self.dispatch(&nodes)?;
            }
            if self.trigger_satisfied() {
                return self.fire();
            }
            let Some(t) = self.queue.peek_time() else {
                anyhow::bail!(
                    "event queue drained before the trigger (round {}, {} arrivals, staleness {:?})",
                    self.stats.rounds,
                    self.arrived.len(),
                    self.scheduler.staleness()
                );
            };
            debug_assert!(t >= self.vtime, "virtual time went backwards");
            self.vtime = t;
            // Consume the whole virtual instant before re-checking the
            // trigger: simultaneous arrivals are indistinguishable in
            // virtual time, so the server sees them as one batch. This is
            // what makes the zero-latency timeline collapse onto the
            // sequential simulator's rounds.
            while self.queue.peek_time() == Some(t) {
                let ev = self.queue.pop().unwrap();
                self.handle(ev.kind)?;
            }
        }
    }

    /// |arrivals| ≥ P and every τ−1-stale node has reported.
    fn trigger_satisfied(&self) -> bool {
        if self.arrived.len() < self.cfg.p_min {
            return false;
        }
        let tau = self.cfg.tau;
        self.scheduler
            .staleness()
            .iter()
            .enumerate()
            .all(|(i, &d)| d + 1 < tau || self.arrived.contains(&i))
    }

    fn handle(&mut self, kind: EventKind) -> anyhow::Result<()> {
        self.stats.events += 1;
        match kind {
            EventKind::ComputeDone { node } => {
                let msg = self.in_flight[node]
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("ComputeDone without outbox (node {node})"))?;
                self.accounting.record_uplink(node, msg.bits);
                let delay = self.links[node].sample_uplink(&mut self.rng_latency);
                self.queue.push(self.vtime + delay, EventKind::MsgArrive { node });
            }
            EventKind::MsgArrive { node } => {
                let msg = self.in_flight[node]
                    .take()
                    .ok_or_else(|| anyhow::anyhow!("MsgArrive without payload (node {node})"))?;
                self.xhat[node].commit(&msg.dx);
                self.uhat[node].commit(&msg.du);
                self.arrived_loss[node] = msg.loss;
                self.arrived.insert(node);
                self.busy[node] = false;
            }
            EventKind::DownlinkArrive { node } => {
                let pkt = self.downlink_inbox[node].pop_front().ok_or_else(|| {
                    anyhow::anyhow!("DownlinkArrive with empty inbox (node {node})")
                })?;
                for (zm, d) in self.z_mirror[node].iter_mut().zip(pkt.dz.iter()) {
                    *zm += d;
                }
                if pkt.dispatch {
                    self.pending_dispatch.push(node);
                }
            }
        }
        Ok(())
    }

    /// One consensus round: mirrors `AsyncSim::step`'s server phase —
    /// consensus, compressed broadcast, scheduler advance, eval — then
    /// puts the broadcast (with the next selection's dispatch flags) on
    /// every node's downlink.
    fn fire(&mut self) -> anyhow::Result<()> {
        let batch = self.arrived.len();
        debug_assert!(batch >= self.cfg.p_min);
        let train_loss: f64 = self.arrived.iter().map(|&i| self.arrived_loss[i]).sum();

        for (buf, t) in self.xs_buf.iter_mut().zip(&self.xhat) {
            buf.copy_from_slice(t.estimate());
        }
        for (buf, t) in self.us_buf.iter_mut().zip(&self.uhat) {
            buf.copy_from_slice(t.estimate());
        }
        self.z = self.problem.consensus(&self.xs_buf, &self.us_buf)?;
        let dz = self.zhat.make_delta(&self.z);
        let cz = self.compressor.compress(&dz, &mut self.server_quant);
        self.accounting.record_broadcast(MSG_HEADER_BYTES * 8 + cz.wire_bits());
        self.zhat.commit(&cz.dequantized);
        // One shared payload for all n downlinks; the node mirrors commit
        // it when their DownlinkArrive fires, not here.
        let dz_payload = Arc::new(cz.dequantized);

        let arrived_mask: Vec<bool> = (0..self.n).map(|i| self.arrived.contains(&i)).collect();
        let next = self
            .scheduler
            .advance(&arrived_mask, || self.oracle.sample(&mut self.rng_oracle));
        self.arrived.clear();
        self.stats.rounds += 1;
        self.stats.virtual_time = self.vtime;
        self.stats.min_arrivals =
            Some(self.stats.min_arrivals.map_or(batch, |prev| prev.min(batch)));
        let max_d = self.scheduler.staleness().iter().copied().max().unwrap_or(0);
        self.stats.max_staleness = self.stats.max_staleness.max(max_d);
        debug_assert!(max_d + 1 <= self.cfg.tau, "staleness bound violated: {max_d}");

        if self.stats.rounds % self.cfg.eval_every == 0 {
            let metrics = self.problem.evaluate(&self.x, &self.u, &self.z)?;
            self.recorder.push(IterRecord {
                iter: self.stats.rounds,
                comm_bits: self.accounting.normalized_bits(self.m),
                accuracy: metrics.accuracy,
                test_acc: metrics.test_acc,
                loss: if metrics.loss.is_nan() {
                    train_loss / batch.max(1) as f64
                } else {
                    metrics.loss
                },
                active_nodes: batch,
                wall_s: self.clock.elapsed_secs(),
            });
        }

        // Put the broadcast on every downlink. A selected idle node is
        // marked busy *now* (it cannot be re-selected while the broadcast
        // is in transit) but starts computing only when its DownlinkArrive
        // fires and its mirror has caught up.
        for i in 0..self.n {
            let dispatch = next[i] && !self.busy[i];
            if dispatch {
                self.busy[i] = true;
            }
            self.downlink_inbox[i]
                .push_back(DownlinkPacket { dz: Arc::clone(&dz_payload), dispatch });
            let delay = self.links[i].sample_downlink(&mut self.rng_latency);
            let at = (self.vtime + delay).max(self.downlink_last[i]);
            self.downlink_last[i] = at;
            self.queue.push(at, EventKind::DownlinkArrive { node: i });
        }
        Ok(())
    }

    /// Fan the local updates of `nodes` (ascending) out through the
    /// problem's batch hook (worker-pool parallel where supported), each
    /// item reading the node's own ẑ **mirror** — never the server's
    /// `zhat`, which may be ahead of what this node has received — apply
    /// the primal/dual updates in node order, compress with per-node RNG
    /// forks, and put the messages on the virtual wire.
    fn dispatch(&mut self, nodes: &[usize]) -> anyhow::Result<()> {
        if nodes.is_empty() {
            return Ok(());
        }
        let results = {
            let u = &self.u;
            let x = &self.x;
            let zm = &self.z_mirror;
            let mut items: Vec<LocalUpdateItem<'_>> = Vec::with_capacity(nodes.len());
            // O(|nodes|) carve-out of the per-node RNG forks (split_at_mut
            // is pointer arithmetic): with fragmented downlink arrivals a
            // round can flush n single-node batches, so an O(n) scan per
            // flush would make the round quadratic in n.
            let mut rest: &mut [Pcg64] = &mut self.node_batch;
            let mut offset = 0usize;
            for &i in nodes {
                let (_, tail) = rest.split_at_mut(i - offset);
                let (rng, tail) = tail.split_first_mut().expect("node id out of range");
                items.push(LocalUpdateItem {
                    node: i,
                    zhat: &zm[i],
                    u: &u[i],
                    x_prev: &x[i],
                    rng,
                });
                rest = tail;
                offset = i + 1;
            }
            self.problem.local_update_batch(&mut items)?
        };
        anyhow::ensure!(results.len() == nodes.len(), "batch result count mismatch");
        for (&node, (x_new, loss)) in nodes.iter().zip(results) {
            anyhow::ensure!(x_new.len() == self.m, "local_update wrong dim");
            // eq. (9b): u ← u + (x_new − ẑᵢ), against the node's mirror
            for j in 0..self.m {
                self.u[node][j] += x_new[j] - self.z_mirror[node][j];
            }
            self.x[node] = x_new;
            // eqs. (10)–(14): compress deltas against the node's estimate
            // bank (== the server bank: its previous update has landed)
            let dx = self.xhat[node].make_delta(&self.x[node]);
            let du = self.uhat[node].make_delta(&self.u[node]);
            let cx = self.compressor.compress(&dx, &mut self.node_quant[node]);
            let cu = self.compressor.compress(&du, &mut self.node_quant[node]);
            let bits = MSG_HEADER_BYTES * 8 + cx.wire_bits() + cu.wire_bits();
            self.in_flight[node] =
                Some(InFlightMsg { dx: cx.dequantized, du: cu.dequantized, bits, loss });
            self.busy[node] = true;
            self.stats.dispatches += 1;
            let delay = self.links[node].sample_compute(&mut self.rng_latency);
            self.queue.push(self.vtime + delay, EventKind::ComputeDone { node });
        }
        Ok(())
    }

    pub fn run(mut self, rounds: usize) -> anyhow::Result<RunRecorder> {
        for _ in 0..rounds {
            self.step_round()?;
        }
        Ok(self.recorder)
    }

    // ---- state accessors (tests + invariant checks) ----

    pub fn z(&self) -> &[f64] {
        &self.z
    }

    pub fn accounting(&self) -> &CommAccounting {
        &self.accounting
    }

    pub fn recorder(&self) -> &RunRecorder {
        &self.recorder
    }

    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    pub fn virtual_time(&self) -> f64 {
        self.vtime
    }

    pub fn staleness(&self) -> &[usize] {
        self.scheduler.staleness()
    }

    /// Node `i`'s current view of ẑ (advances only on downlink arrival).
    pub fn z_mirror(&self, node: usize) -> &[f64] {
        &self.z_mirror[node]
    }

    /// The server's own ẑ estimate (what the mirrors converge to once
    /// every broadcast has landed).
    pub fn z_estimate(&self) -> &[f64] {
        self.zhat.estimate()
    }
}
