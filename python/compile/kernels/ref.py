"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

These are deliberately written as straight-line jnp with no tiling so a
mismatch against the kernels localizes to the kernel's block schedule.
"""

import jax.numpy as jnp


def quantize_ref(delta, noise, s):
    """Reference C(Δ) of eq. (17). Same semantics as kernels.quantize."""
    dtype = delta.dtype
    s = jnp.asarray(s, dtype=dtype)
    norm = jnp.max(jnp.abs(delta))
    nonzero = norm > 0
    safe_norm = jnp.where(nonzero, norm, jnp.ones_like(norm))
    y = jnp.abs(delta) / safe_norm * s
    p = jnp.minimum(jnp.floor(y), s - 1.0)
    frac = y - p
    lvl = p + (noise < frac).astype(dtype)
    sgn = jnp.sign(delta)
    val = jnp.where(nonzero, norm * sgn * lvl / s, jnp.zeros_like(delta))
    lvl_signed = jnp.where(nonzero, sgn * lvl, jnp.zeros_like(lvl)).astype(jnp.int32)
    return val, lvl_signed, norm


def soft_threshold_ref(v, kappa):
    """Reference prox of κ‖·‖₁."""
    kappa = jnp.asarray(kappa, dtype=v.dtype)
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - kappa, 0.0)


def dequantize_ref(levels, norm, s):
    """Inverse of the wire encoding: value = norm · level / S."""
    return levels.astype(norm.dtype) * norm / s
