//! The topology parity contract.
//!
//! Two safety rails guard the hierarchical fan-in:
//!
//! 1. **Star is untouched** — `topology = star` allocates no tier state
//!    and runs the exact pre-PR code path, so `tests/engine_parity.rs`
//!    passes unchanged. (Not re-proved here; this file pins the *new*
//!    half.)
//! 2. **The degenerate tree collapses onto the star** — `tree:1` puts one
//!    aggregator above every leaf. With the identity compressor the
//!    forward carries the child's deltas bit-for-bit (a single Kahan fold
//!    from zero is exact, and identity re-quantization is lossless), and
//!    at zero link delay the forwards fold in ascending id order — the
//!    star's order. The z-trajectory and staleness must therefore be
//!    **bit-identical** to the star's, in both the sequential simulator
//!    and the event engine; only the comm accounting differs (the
//!    aggregator hop is charged per link, as it must be).
//!
//! Beyond the degenerate pin, any tree/gossip configuration must be
//! bit-exact *between* the two in-process engines at zero link delay
//! (same folds, same flush order, same routing draws), and a tree under
//! real per-link delays must still uphold every scheduling invariant.

use qadmm::admm::engine::EventEngine;
use qadmm::admm::sim::{AsyncSim, TrialRngs};
use qadmm::comm::latency::LatencyModel;
use qadmm::comm::profile::LinkConfig;
use qadmm::compress::CompressorKind;
use qadmm::config::{presets, EngineKind, ExperimentConfig, OracleConfig, ProblemKind};
use qadmm::problems::lasso::{LassoConfig, LassoProblem};
use qadmm::topology::TopologyKind;

fn base_cfg(n: usize, tau: usize, p_min: usize) -> ExperimentConfig {
    let mut cfg = presets::ci_lasso();
    cfg.name = format!("topo-parity-n{n}-tau{tau}-p{p_min}");
    cfg.problem = ProblemKind::Lasso { m: 24, h: 18, n, rho: 30.0, theta: 0.1 };
    cfg.compressor = CompressorKind::Identity; // zero quantizer randomness
    cfg.tau = tau;
    cfg.p_min = p_min;
    cfg.iters = 40;
    cfg.mc_trials = 1;
    cfg.eval_every = 1;
    cfg.oracle = OracleConfig { p_slow: 0.1, p_fast: 0.8, regroup_each_call: false };
    cfg.link = LinkConfig::none();
    cfg
}

fn lasso_of(cfg: &ExperimentConfig) -> LassoConfig {
    match cfg.problem {
        ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
        _ => unreachable!(),
    }
}

/// Per-round (z, staleness, comm bits) series from the simulator.
fn run_sim(cfg: &ExperimentConfig) -> (Vec<Vec<f64>>, Vec<Vec<usize>>, Vec<u64>) {
    let lcfg = lasso_of(cfg);
    let mut rngs = TrialRngs::new(cfg.seed);
    let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
    let mut sim = AsyncSim::new(cfg, &mut p, rngs).unwrap();
    let (mut zs, mut ds, mut bits) = (Vec::new(), Vec::new(), Vec::new());
    for _ in 0..cfg.iters {
        sim.step().unwrap();
        zs.push(sim.z().to_vec());
        ds.push(sim.staleness().to_vec());
        bits.push(sim.accounting().total_bits());
    }
    (zs, ds, bits)
}

/// The same series from the event engine.
fn run_event(cfg: &ExperimentConfig) -> (Vec<Vec<f64>>, Vec<Vec<usize>>, Vec<u64>) {
    let lcfg = lasso_of(cfg);
    let mut rngs = TrialRngs::new(cfg.seed);
    let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
    let mut eng = EventEngine::new(cfg, &mut p, rngs).unwrap();
    let (mut zs, mut ds, mut bits) = (Vec::new(), Vec::new(), Vec::new());
    for _ in 0..cfg.iters {
        eng.step_round().unwrap();
        zs.push(eng.z().to_vec());
        ds.push(eng.staleness().to_vec());
        bits.push(eng.accounting().total_bits());
    }
    (zs, ds, bits)
}

fn assert_z_bitwise(a: &[Vec<f64>], b: &[Vec<f64>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: round count");
    for (r, (za, zb)) in a.iter().zip(b).enumerate() {
        for (x, y) in za.iter().zip(zb) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: z diverged at round {r}");
        }
    }
}

/// The headline pin: tree-of-depth-1 with one aggregator per node must
/// reproduce the star's z-trajectory and staleness bit-for-bit, across
/// *both* in-process engines — while its accounting visibly charges the
/// extra hop.
#[test]
fn degenerate_tree_matches_star_bitwise_in_both_engines() {
    for (tau, p_min) in [(3usize, 1usize), (1, 4), (4, 2)] {
        let star = base_cfg(4, tau, p_min);
        let mut tree = base_cfg(4, tau, p_min);
        tree.topology = TopologyKind::Tree { fanout: 1 };
        tree.p_tier = 1;

        let (z_star_sim, d_star_sim, bits_star_sim) = run_sim(&star);
        let (z_star_eng, d_star_eng, bits_star_eng) = run_event(&star);
        let (z_tree_sim, d_tree_sim, bits_tree_sim) = run_sim(&tree);
        let (z_tree_eng, d_tree_eng, bits_tree_eng) = run_event(&tree);

        // all four z-trajectories coincide exactly
        assert_z_bitwise(&z_star_sim, &z_star_eng, "star sim vs event");
        assert_z_bitwise(&z_star_sim, &z_tree_sim, "star vs degenerate tree (sim)");
        assert_z_bitwise(&z_star_sim, &z_tree_eng, "star vs degenerate tree (event)");
        assert_eq!(d_star_sim, d_star_eng, "staleness star sim/event");
        assert_eq!(d_star_sim, d_tree_sim, "staleness star vs tree (sim)");
        assert_eq!(d_star_sim, d_tree_eng, "staleness star vs tree (event)");

        // bits agree within each topology (sim vs event) ...
        assert_eq!(bits_star_sim, bits_star_eng, "star bits sim/event");
        assert_eq!(bits_tree_sim, bits_tree_eng, "tree bits sim/event");
        // ... and the tree charges strictly more: the aggregator hop is a
        // real link, not free relabeling
        for (s, t) in bits_star_sim.iter().zip(&bits_tree_sim) {
            assert!(t > s, "aggregator hop must be charged (star {s}, tree {t})");
        }
    }
}

/// General (non-degenerate) trees and gossip are *different* algorithms
/// from the star — but each must still be bit-exact between the two
/// in-process engines at zero link delay: same folds, same ascending
/// flush order, same topology RNG draws.
#[test]
fn tree_and_gossip_are_bit_exact_across_engines_at_zero_delay() {
    for topology in [
        TopologyKind::Tree { fanout: 3 },
        TopologyKind::Tree { fanout: 8 }, // single aggregator over all 8
        TopologyKind::Gossip { k: 3 },
    ] {
        for p_tier in [1usize, 2] {
            let mut cfg = base_cfg(8, 3, 2);
            cfg.name = format!("topo-parity-{}-pt{p_tier}", topology.label());
            cfg.topology = topology;
            cfg.p_tier = p_tier;
            // identity compressor: the engines draw their quantizer noise
            // from different stream layouts, so the bitwise claim (like
            // engine_parity's) is made with zero quantizer randomness
            cfg.compressor = CompressorKind::Identity;
            let (z_sim, d_sim, bits_sim) = run_sim(&cfg);
            let (z_eng, d_eng, bits_eng) = run_event(&cfg);
            assert_z_bitwise(&z_sim, &z_eng, &cfg.name);
            assert_eq!(d_sim, d_eng, "{}: staleness", cfg.name);
            assert_eq!(bits_sim, bits_eng, "{}: bits", cfg.name);
        }
    }
}

/// A non-degenerate tree changes the trajectory (the aggregator folds a
/// whole group before the server sees it — different summation grouping,
/// different bits): the parity pin above must not be vacuous.
#[test]
fn non_degenerate_tree_differs_from_star() {
    let star = base_cfg(8, 3, 2);
    let mut tree = base_cfg(8, 3, 2);
    tree.topology = TopologyKind::Tree { fanout: 4 };
    let (z_star, _, _) = run_sim(&star);
    let (z_tree, _, _) = run_sim(&tree);
    assert!(
        z_star.iter().zip(&z_tree).any(|(a, b)| a != b),
        "fanout-4 tree left the z-trajectory identical to the star"
    );
}

/// Under real per-link delays (compute, uplink, downlink, drift) a tree
/// run must uphold every scheduling invariant: ≥ P arrivals per fire,
/// staleness ≤ τ−1 end-to-end (each hop consumes the same τ budget), and
/// aggregator forwards actually flowing.
#[test]
fn tree_under_latency_upholds_scheduling_invariants() {
    let n = 24;
    let mut cfg = base_cfg(n, 4, n / 4);
    cfg.name = "topo-latency-tree".into();
    cfg.compressor = CompressorKind::Qsgd { bits: 3 };
    cfg.topology = TopologyKind::Tree { fanout: 6 };
    cfg.p_tier = 3;
    cfg.iters = 30;
    cfg.engine = EngineKind::Event;
    cfg.link = LinkConfig {
        compute: LatencyModel::Exp(0.01),
        uplink: LatencyModel::Exp(0.01),
        downlink: LatencyModel::Exp(0.02),
        clock_drift: 0.2,
    };
    let lcfg = lasso_of(&cfg);
    let mut rngs = TrialRngs::new(cfg.seed);
    let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
    p.set_reference_optimum(1.0);
    let mut eng = EventEngine::new(&cfg, &mut p, rngs).unwrap();
    for _ in 0..cfg.iters {
        eng.step_round().unwrap();
        let max_d = eng.staleness().iter().copied().max().unwrap();
        assert!(max_d + 1 <= cfg.tau, "staleness bound broken under tree fan-in");
    }
    let stats = eng.stats();
    assert_eq!(stats.rounds, cfg.iters);
    assert!(stats.min_arrivals.expect("rounds fired") >= cfg.p_min);
    assert!(stats.agg_forwards > 0, "no aggregator traffic in a tree run");
    assert!(stats.virtual_time > 0.0);
    // every forward carries at least one delivered child update
    assert!(stats.agg_forwards <= stats.dispatches);
    assert_eq!(eng.tier().unwrap().n_aggregators(), 4);
}

/// Determinism at scale with the tier active: two identical gossip runs
/// under latency produce identical results (routing comes from the
/// dedicated per-trial topology stream, not from timing).
#[test]
fn gossip_run_is_deterministic() {
    let mut cfg = base_cfg(16, 3, 4);
    cfg.name = "topo-gossip-determinism".into();
    cfg.compressor = CompressorKind::Qsgd { bits: 3 };
    cfg.topology = TopologyKind::Gossip { k: 4 };
    cfg.p_tier = 2;
    cfg.iters = 20;
    cfg.link = LinkConfig {
        compute: LatencyModel::Exp(0.01),
        uplink: LatencyModel::Exp(0.01),
        downlink: LatencyModel::None,
        clock_drift: 0.0,
    };
    let lcfg = lasso_of(&cfg);
    let run = || {
        let mut rngs = TrialRngs::new(cfg.seed);
        let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
        p.set_reference_optimum(1.0);
        let mut eng = EventEngine::new(&cfg, &mut p, rngs).unwrap();
        for _ in 0..cfg.iters {
            eng.step_round().unwrap();
        }
        (eng.z().to_vec(), eng.accounting().total_bits(), eng.stats().agg_forwards)
    };
    let (z1, b1, f1) = run();
    let (z2, b2, f2) = run();
    assert_eq!(z1, z2);
    assert_eq!(b1, b2);
    assert_eq!(f1, f2);
}
