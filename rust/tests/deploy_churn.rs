//! Deployment churn and robustness: workers that die abruptly mid-run,
//! workers that rejoin, and raw connections that speak garbage. The
//! invariants under test:
//!
//! * an abrupt death (socket severed, no goodbye) evicts the node — the
//!   P/τ trigger never wedges on it and the run completes;
//! * a rejoin re-handshakes into a fresh bank slot (full-precision
//!   re-init + fresh ẑ basis) and participates through the drain;
//! * the per-link byte books reconcile **exactly** against the charged
//!   eq. (20) bits through all of it — eviction, discarded in-flight
//!   broadcasts, rejoin, drain;
//! * malformed frames (truncated/oversized length prefix, garbage
//!   handshake, unknown kinds) get a clean rejection, never a panic, an
//!   unbounded allocation, or a wedged server.

use std::io::Write;
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::Mutex;
use std::thread::JoinHandle;
use std::time::Duration;

use qadmm::config::ExperimentConfig;
use qadmm::deploy::server::{serve, ServeOptions};
use qadmm::deploy::transport::Endpoint;
use qadmm::deploy::worker::{run_worker, WorkerOptions, WorkerReport};
use qadmm::exp::deploy::{make_native_problem, smoke_cfg};

fn sock_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("qadmm-{tag}-{}.sock", std::process::id()))
}

fn spawn_worker(
    cfg: &ExperimentConfig,
    ep: &Endpoint,
    opts: WorkerOptions,
) -> JoinHandle<anyhow::Result<WorkerReport>> {
    let (cfg, ep) = (cfg.clone(), ep.clone());
    std::thread::spawn(move || run_worker(&cfg, make_native_problem(&cfg)?, &ep, &opts))
}

/// Node 0 severs its connection after 3 updates, then comes back and
/// re-handshakes; nodes 1..n run straight through. The run must complete,
/// drain cleanly, and reconcile to the byte.
#[test]
fn abrupt_death_evicts_and_rejoin_rehandshakes() {
    // long enough that the fleet is still mid-run when node 0 returns
    // (~30ms after its death); short enough to stay a unit-scale test
    let cfg = smoke_cfg(3, 10_000);
    let listen = Endpoint::Uds(sock_path("churn"));
    let opts = ServeOptions { idle_timeout: Duration::from_secs(10) };
    let handles: Mutex<Vec<JoinHandle<anyhow::Result<WorkerReport>>>> = Mutex::new(Vec::new());

    let report = serve(&cfg, make_native_problem(&cfg).unwrap(), &listen, &opts, |ep| {
        let mut hs = handles.lock().unwrap();
        // node 0, first life: dies without a goodbye after 3 updates, then
        // (same thread) waits for the eviction to land and rejoins
        {
            let (cfg, ep) = (cfg.clone(), ep.clone());
            hs.push(std::thread::spawn(move || {
                let mut first = WorkerOptions::new(0);
                first.die_after_updates = Some(3);
                let died = run_worker(&cfg, make_native_problem(&cfg)?, &ep, &first)?;
                anyhow::ensure!(died.updates_sent == 3, "died after {}", died.updates_sent);
                anyhow::ensure!(!died.acked_shutdown, "a severed worker cannot have acked");
                // let the server process the EOF -> Leave before returning;
                // a rejoin racing its own eviction is rejected ("already
                // attached"), so retry through the window
                std::thread::sleep(Duration::from_millis(25));
                let mut last_err = None;
                for _ in 0..200 {
                    match run_worker(&cfg, make_native_problem(&cfg)?, &ep, &WorkerOptions::new(0))
                    {
                        Ok(r) => return Ok(r),
                        Err(e) => {
                            last_err = Some(e);
                            std::thread::sleep(Duration::from_millis(5));
                        }
                    }
                }
                Err(last_err.unwrap())
            }));
        }
        for node in 1..3 {
            hs.push(spawn_worker(&cfg, ep, WorkerOptions::new(node)));
        }
        Ok(())
    })
    .expect("run must complete despite the churn");

    let mut reports = Vec::new();
    for h in handles.into_inner().unwrap() {
        reports.push(h.join().expect("worker thread panicked").expect("worker failed"));
    }
    // the rejoined node 0 and both survivors all saw the drain through
    for (i, r) in reports.iter().enumerate() {
        assert!(r.acked_shutdown, "worker thread {i} did not ack the drain: {r:?}");
    }
    assert!(
        reports[0].rounds_applied > 0,
        "rejoined node 0 never applied a consensus round"
    );
    // eviction + discarded broadcasts + rejoin: still exact, per link
    qadmm::deploy::reconcile(&report.books, &report.accounting).unwrap();
    assert!(!report.timeline.rounds.is_empty());
}

/// Raw garbage on the socket: every malformed opener is rejected cleanly
/// (no panic, no allocation from a lying length prefix) and the server
/// keeps serving the legitimate fleet to a reconciled finish.
#[test]
fn malformed_frames_never_wedge_the_server() {
    let cfg = smoke_cfg(2, 120);
    let path = sock_path("fuzz");
    let listen = Endpoint::Uds(path.clone());
    let opts = ServeOptions { idle_timeout: Duration::from_secs(10) };
    let handles: Mutex<Vec<JoinHandle<anyhow::Result<WorkerReport>>>> = Mutex::new(Vec::new());

    let report = serve(&cfg, make_native_problem(&cfg).unwrap(), &listen, &opts, |ep| {
        // the legitimate fleet first, so the run is underway while the
        // garbage arrives
        let mut hs = handles.lock().unwrap();
        for node in 0..2 {
            hs.push(spawn_worker(&cfg, ep, WorkerOptions::new(node)));
        }
        drop(hs);

        let attacks: &[&[u8]] = &[
            b"\x02\x00",                          // truncated length prefix
            b"\x02\x00\x00\x00\x01",              // truncated body (len says 2, has 1)
            b"\xff\xff\xff\xff garbage",          // oversized: > MAX_FRAME_BYTES
            b"\x00\x00\x00\x00",                  // zero-length frame (no kind byte)
            b"\x05\x00\x00\x00\x63hey!",          // unknown kind 99
            b"\x09\x00\x00\x00\x01\xde\xad\xbe\xef\xba\xad\xf0\x0d", // garbage Hello
        ];
        for bytes in attacks {
            let mut s = UnixStream::connect(&path)?;
            let _ = s.write_all(bytes);
            // half-open or closed, the server must shrug either way
            let _ = s.shutdown(std::net::Shutdown::Write);
        }

        // a worker whose config digest disagrees is told why and turned away
        let mut other = cfg.clone();
        other.seed ^= 1;
        let err = run_worker(&other, make_native_problem(&other)?, ep, &WorkerOptions::new(1))
            .unwrap_err();
        anyhow::ensure!(
            err.to_string().contains("rejected"),
            "digest mismatch gave the wrong error: {err}"
        );
        Ok(())
    })
    .expect("server must survive the fuzz");

    for h in handles.into_inner().unwrap() {
        let r = h.join().unwrap().unwrap();
        assert!(r.acked_shutdown);
    }
    // none of the garbage connections may have leaked onto the books
    qadmm::deploy::reconcile(&report.books, &report.accounting).unwrap();
}
