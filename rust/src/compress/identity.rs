//! Identity "compressor": full-precision f64 wire — the unquantized
//! async-ADMM baseline the paper compares against. Its wire size is what
//! the ~90% reduction headline is measured relative to.

use super::wire::encode_dense64;
use super::{Compressed, Compressor};
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "identity".into()
    }

    fn compress(&self, delta: &[f64], _rng: &mut Pcg64) -> Compressed {
        Compressed { dequantized: delta.to_vec(), wire: encode_dense64(delta) }
    }

    /// Pooled-buffer variant: clears and refills `out`, reusing capacity —
    /// no steady-state allocation. The frame comes from the same
    /// [`super::wire::encode_dense64_into`] encoder `compress` uses.
    fn compress_into(&self, delta: &[f64], _rng: &mut Pcg64, out: &mut Compressed) {
        out.dequantized.clear();
        out.dequantized.extend_from_slice(delta);
        super::wire::encode_dense64_into(delta, &mut out.wire);
    }
}

/// Dense fp32 wire — the paper's "full precision (e.g., 32-bits per
/// scalar)" baseline accounting. The f64→f32 rounding is a (tiny, unbiased
/// only in effect) compression whose residual error feedback absorbs, so
/// the dequantized value is the decoded f32 (sender mirror == receiver).
#[derive(Clone, Copy, Debug)]
pub struct Identity32;

impl Compressor for Identity32 {
    fn name(&self) -> String {
        "identity32".into()
    }

    fn compress(&self, delta: &[f64], _rng: &mut Pcg64) -> Compressed {
        let wire = super::wire::encode_dense32(delta);
        let dequantized = delta.iter().map(|&x| x as f32 as f64).collect();
        Compressed { dequantized, wire }
    }

    /// Pooled-buffer variant via [`super::wire::encode_dense32_into`] —
    /// one source of truth for the dense32 frame.
    fn compress_into(&self, delta: &[f64], _rng: &mut Pcg64, out: &mut Compressed) {
        out.dequantized.clear();
        out.dequantized.extend(delta.iter().map(|&x| x as f32 as f64));
        super::wire::encode_dense32_into(delta, &mut out.wire);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lossless() {
        let delta = vec![1.0, -2.5, 1e-17, 0.0];
        let c = Identity.compress(&delta, &mut Pcg64::seed_from_u64(0));
        assert_eq!(c.dequantized, delta);
        assert_eq!(Identity.decode(&c.wire, 4).unwrap(), delta);
        assert_eq!(c.wire.len(), 5 + 4 * 8);
    }
}
