//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Input shapes/dtypes are validated on every call so a
//! drifted artifact set fails loudly at the boundary, not inside XLA.

use std::collections::BTreeMap;
use std::path::Path;

use super::tensor::Tensor;
use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f64" | "f32" | "i32"
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<String>,
}

impl ArtifactSpec {
    pub fn validate_inputs(&self, inputs: &[Tensor]) -> anyhow::Result<()> {
        anyhow::ensure!(
            inputs.len() == self.inputs.len(),
            "got {} inputs, expected {}",
            inputs.len(),
            self.inputs.len()
        );
        for (t, spec) in inputs.iter().zip(&self.inputs) {
            anyhow::ensure!(
                t.shape() == spec.shape.as_slice(),
                "input '{}': shape {:?} != expected {:?}",
                spec.name,
                t.shape(),
                spec.shape
            );
            anyhow::ensure!(
                t.dtype_name() == spec.dtype,
                "input '{}': dtype {} != expected {}",
                spec.name,
                t.dtype_name(),
                spec.dtype
            );
        }
        Ok(())
    }

    /// Index of a named output (panics on unknown name — a programmer error).
    pub fn output_index(&self, name: &str) -> usize {
        self.outputs
            .iter()
            .position(|o| o == name)
            .unwrap_or_else(|| panic!("artifact has no output '{name}' ({:?})", self.outputs))
    }
}

/// Flat-parameter layout entry for a NN architecture (He init in rust).
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub fan_in: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub params: BTreeMap<String, Vec<ParamSpec>>,
    pub consts: BTreeMap<String, usize>,
}

impl Manifest {
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} ({e}); run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let root = Json::parse(text)?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in root
            .expect("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("'artifacts' is not an object"))?
        {
            let inputs = entry
                .expect("inputs")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("inputs not an array"))?
                .iter()
                .map(parse_tensor_spec)
                .collect::<anyhow::Result<Vec<_>>>()?;
            let outputs = entry
                .expect("outputs")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("outputs not an array"))?
                .iter()
                .map(|o| {
                    o.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow::anyhow!("output name not a string"))
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let file = entry
                .expect("file")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("file not a string"))?
                .to_string();
            artifacts.insert(name.clone(), ArtifactSpec { file, inputs, outputs });
        }

        let mut params = BTreeMap::new();
        if let Some(pobj) = root.get("params").and_then(Json::as_obj) {
            for (arch, list) in pobj {
                let specs = list
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("params.{arch} not an array"))?
                    .iter()
                    .map(parse_param_spec)
                    .collect::<anyhow::Result<Vec<_>>>()?;
                params.insert(arch.clone(), specs);
            }
        }

        let mut consts = BTreeMap::new();
        if let Some(cobj) = root.get("consts").and_then(Json::as_obj) {
            for (k, v) in cobj {
                consts.insert(
                    k.clone(),
                    v.as_usize().ok_or_else(|| anyhow::anyhow!("const {k} not a usize"))?,
                );
            }
        }
        Ok(Self { artifacts, params, consts })
    }

    pub fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown artifact '{name}' (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn param_specs(&self, arch: &str) -> anyhow::Result<&[ParamSpec]> {
        self.params
            .get(arch)
            .map(Vec::as_slice)
            .ok_or_else(|| anyhow::anyhow!("no param specs for arch '{arch}'"))
    }

    pub fn const_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.consts
            .get(key)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("manifest const '{key}' missing"))
    }
}

fn parse_tensor_spec(j: &Json) -> anyhow::Result<TensorSpec> {
    let name = j
        .expect("name")?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("tensor name not a string"))?
        .to_string();
    let shape = j
        .expect("shape")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("shape not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let dtype = j
        .expect("dtype")?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("dtype not a string"))?
        .to_string();
    Ok(TensorSpec { name, shape, dtype })
}

fn parse_param_spec(j: &Json) -> anyhow::Result<ParamSpec> {
    Ok(ParamSpec {
        name: j
            .expect("name")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("param name"))?
            .to_string(),
        shape: j
            .expect("shape")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("param shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
            .collect::<anyhow::Result<Vec<_>>>()?,
        offset: j.expect("offset")?.as_usize().ok_or_else(|| anyhow::anyhow!("offset"))?,
        size: j.expect("size")?.as_usize().ok_or_else(|| anyhow::anyhow!("size"))?,
        fan_in: j.expect("fan_in")?.as_usize().ok_or_else(|| anyhow::anyhow!("fan_in"))?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "q": {"file": "q.hlo.txt",
              "inputs": [{"name": "delta", "shape": [8], "dtype": "f64"},
                         {"name": "s", "shape": [], "dtype": "f64"}],
              "outputs": ["values", "levels"], "meta": {}}
      },
      "params": {"mlp": [{"name": "fc0_w", "shape": [4, 2], "offset": 0,
                           "size": 8, "fan_in": 4}]},
      "consts": {"mlp_m": 10}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("q").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[1].shape, Vec::<usize>::new());
        assert_eq!(a.output_index("levels"), 1);
        assert_eq!(m.param_specs("mlp").unwrap()[0].fan_in, 4);
        assert_eq!(m.const_usize("mlp_m").unwrap(), 10);
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn validates_inputs() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("q").unwrap();
        let good = vec![Tensor::vec_f64(vec![0.0; 8]), Tensor::scalar_f64(3.0)];
        a.validate_inputs(&good).unwrap();
        let wrong_shape = vec![Tensor::vec_f64(vec![0.0; 7]), Tensor::scalar_f64(3.0)];
        assert!(a.validate_inputs(&wrong_shape).is_err());
        let wrong_dtype = vec![Tensor::vec_f32(vec![0.0; 8]), Tensor::scalar_f64(3.0)];
        assert!(a.validate_inputs(&wrong_dtype).is_err());
        let wrong_count = vec![Tensor::scalar_f64(3.0)];
        assert!(a.validate_inputs(&wrong_count).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let path = Path::new("artifacts/manifest.json");
        if !path.exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(path).unwrap();
        assert!(m.artifacts.contains_key("lasso_node_step"));
        assert_eq!(m.const_usize("cnn_m").unwrap(), 246_026);
        assert_eq!(m.const_usize("lasso_m").unwrap(), 200);
    }
}
