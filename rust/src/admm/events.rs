//! Virtual-time event substrate for the event-driven engine.
//!
//! A calendar queue (bucketed timing wheel) over `(time, seq)` where `time`
//! is virtual seconds and `seq` is the insertion order. Ties on `time` are
//! broken by insertion order, which makes the whole timeline deterministic:
//! two runs that push the same events in the same order pop them in the
//! same order, even when every delay is 0.0 (the parity configuration,
//! where the engine must replay the sequential simulator bit-for-bit).
//!
//! ## Why a calendar queue
//!
//! The binary heap this replaces costs O(log n) per push/pop; at n = 10^6
//! nodes a single consensus round schedules ~n downlink events and the log
//! factor dominates the timeline. The calendar queue hashes each event into
//! a bucket of its virtual "day" (`day = time / width`) and pops by
//! scanning forward from the current day — O(1) amortized per operation
//! when `width` tracks the mean event spacing, which the periodic rebuilds
//! maintain.
//!
//! ## Determinism argument
//!
//! Pop order never depends on the bucket geometry. `day(t) = (t / width)
//! as u64` is monotone in `t` for any fixed positive width (division by a
//! positive constant and the saturating f64→u64 cast are both monotone),
//! so `day(a) > day(b)` implies `a > b`: the earliest event always lives
//! in the first nonempty bucket, every bucket is kept sorted by
//! `(time, seq)`, and the overflow list only holds events of strictly
//! later days than anything in the wheel. The popped sequence is therefore
//! the exact `(time, seq)` total order — the same stream the heap
//! produced, bit-for-bit — regardless of how width/bucket-count heuristics
//! carve up the timeline. Parity and snapshot tests pin this: snapshots
//! serialize the *canonically sorted* event list, never the geometry.

use std::collections::VecDeque;

use crate::snapshot::codec::{Pack, Reader, Writer};

/// What happened at a virtual instant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Node finished its local primal update (uplink send begins).
    ComputeDone { node: usize },
    /// Node's compressed update arrived at the server.
    MsgArrive { node: usize },
    /// The server's compressed Δz broadcast reached this node's ẑ mirror
    /// (payloads ride the shared broadcast window; arrival times are
    /// clamped monotone per link, so broadcasts never overtake each other).
    DownlinkArrive { node: usize },
    /// An intermediate aggregator's re-quantized partial sum reached the
    /// server (non-star topologies only): the payload rides a per-agg FIFO
    /// with monotone arrival clamps, exactly like the downlink deliveries,
    /// and carries the arrival credit of every child folded into it.
    AggregateArrive { agg: usize },
}

impl EventKind {
    /// Stable label for timeline recordings ([`crate::snapshot::timeline`]).
    pub fn label(&self) -> &'static str {
        match self {
            EventKind::ComputeDone { .. } => "compute-done",
            EventKind::MsgArrive { .. } => "msg-arrive",
            EventKind::DownlinkArrive { .. } => "downlink-arrive",
            EventKind::AggregateArrive { .. } => "aggregate-arrive",
        }
    }

    /// The node (or aggregator) index the event belongs to.
    pub fn index(&self) -> usize {
        match *self {
            EventKind::ComputeDone { node }
            | EventKind::MsgArrive { node }
            | EventKind::DownlinkArrive { node } => node,
            EventKind::AggregateArrive { agg } => agg,
        }
    }
}

/// One scheduled event. Ordered by `(time, seq)` with `f64::total_cmp`,
/// so NaN-free timelines have a total deterministic order.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub time: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.total_cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// Bucket count floor; also the size an empty queue starts at.
const MIN_BUCKETS: usize = 16;
/// Bucket count ceiling: bounds the wheel's own footprint (~32 B/bucket)
/// to tens of MB even for multi-million-event timelines.
const MAX_BUCKETS: usize = 1 << 20;

/// Calendar-queue timeline: O(1) amortized push/pop over bucketed virtual
/// days, exact `(time, seq)` pop order (see the module docs).
#[derive(Debug)]
pub struct EventQueue {
    /// One sorted run of events per virtual day of the current "year"
    /// (`year_base .. year_base + buckets.len()` in day units).
    buckets: Vec<VecDeque<Event>>,
    /// Seconds per day. Rebuilds re-fit it to the mean event spacing; any
    /// positive finite value is *correct*, only speed depends on it.
    width: f64,
    /// Day index mapped to `buckets[0]`.
    year_base: u64,
    /// Events of days at/after the end of the current year, unsorted.
    /// Everything here is strictly later than everything in the wheel.
    overflow: Vec<Event>,
    /// Total scheduled events (wheel + overflow).
    len: usize,
    /// Cached global minimum (always a wheel resident when `len > 0`).
    front: Option<Event>,
    next_seq: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQueue {
    pub fn new() -> Self {
        Self {
            buckets: vec![VecDeque::new(); MIN_BUCKETS],
            width: 1.0,
            year_base: 0,
            overflow: Vec::new(),
            len: 0,
            front: None,
            next_seq: 0,
        }
    }

    /// Schedule `kind` at virtual time `time` (seconds). Delays must be
    /// finite and non-negative: a NaN or negative time would silently
    /// corrupt the total order, so this is a hard error in release builds
    /// too, not a debug assertion.
    pub fn push(&mut self, time: f64, kind: EventKind) {
        assert!(time.is_finite() && time >= 0.0, "bad virtual time {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.insert_event(Event { time, seq, kind });
        if self.len > 2 * self.buckets.len() && self.buckets.len() < MAX_BUCKETS {
            let evs = self.drain_all();
            self.rebuild_with(evs);
        }
    }

    pub fn pop(&mut self) -> Option<Event> {
        let e = self.front?;
        let idx = (self.day(e.time) - self.year_base) as usize;
        let popped = self.buckets[idx].pop_front();
        debug_assert_eq!(popped.map(|p| p.seq), Some(e.seq), "front cache out of sync");
        self.len -= 1;
        // The new minimum is the head of the first nonempty bucket at or
        // after the popped one (earlier buckets are empty: the popped event
        // was the global minimum and day() is monotone in time).
        self.front = None;
        for b in &self.buckets[idx..] {
            if let Some(f) = b.front() {
                self.front = Some(*f);
                break;
            }
        }
        if self.front.is_none() && !self.overflow.is_empty() {
            // Year exhausted: re-anchor the wheel on the overflow events.
            let evs = std::mem::take(&mut self.overflow);
            self.rebuild_with(evs);
        } else if self.len * 4 < self.buckets.len() && self.buckets.len() > MIN_BUCKETS {
            let evs = self.drain_all();
            self.rebuild_with(evs);
        }
        Some(e)
    }

    /// Virtual time of the next event, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.front.map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The next sequence number this queue will assign (== total events
    /// ever scheduled; surfaced in `EngineStats`).
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// All scheduled events, in unspecified order (snapshot validation).
    pub fn events(&self) -> impl Iterator<Item = &Event> {
        self.buckets.iter().flat_map(VecDeque::iter).chain(self.overflow.iter())
    }

    fn day(&self, time: f64) -> u64 {
        // `as` saturates: a huge quotient maps to u64::MAX, which is still
        // monotone — correctness never depends on the width choice.
        (time / self.width) as u64
    }

    fn drain_all(&mut self) -> Vec<Event> {
        let mut evs = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            evs.extend(b.drain(..));
        }
        evs.append(&mut self.overflow);
        evs
    }

    /// Re-fit the geometry to `evs` (all currently scheduled events) and
    /// redistribute them. O(len log len); amortized away by the doubling /
    /// halving triggers and year advances that call it.
    fn rebuild_with(&mut self, mut evs: Vec<Event>) {
        self.len = evs.len();
        let nb = self.len.next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
        let (mut tmin, mut tmax) = (f64::INFINITY, 0.0f64);
        for e in &evs {
            tmin = tmin.min(e.time);
            tmax = tmax.max(e.time);
        }
        // Mean spacing as the day width; degenerate spans (empty queue,
        // one instant) fall back to 1.0 — still correct, possibly slower.
        let w = (tmax - tmin) / self.len.max(1) as f64;
        self.width = if w.is_finite() && w > 0.0 { w } else { 1.0 };
        self.year_base = if self.len == 0 { 0 } else { self.day(tmin) };
        self.buckets.clear();
        self.buckets.resize(nb, VecDeque::new());
        self.overflow.clear();
        evs.sort();
        self.front = evs.first().copied();
        for e in evs {
            // day(e) >= year_base == day(tmin) by monotonicity
            let off = self.day(e.time) - self.year_base;
            if (off as usize) < nb {
                self.buckets[off as usize].push_back(e); // sorted input: append keeps order
            } else {
                self.overflow.push(e);
            }
        }
    }

    fn insert_event(&mut self, e: Event) {
        let d = self.day(e.time);
        if self.front.is_none() || d < self.year_base {
            // Empty queue, or a push into a day the year has advanced past
            // (possible right after an overflow re-anchor: virtual "now"
            // trails the earliest remaining event). Re-anchor on the full
            // set — at most once per year advance, so amortized O(1).
            let mut evs = self.drain_all();
            evs.push(e);
            self.rebuild_with(evs);
            return;
        }
        let nb = self.buckets.len();
        let off = d - self.year_base;
        if (off as usize) < nb {
            let b = &mut self.buckets[off as usize];
            // Equal-time bursts arrive in ascending seq: append is O(1)
            // and the common case; out-of-order times fall back to a
            // sorted insert.
            if b.back().map_or(true, |last| *last < e) {
                b.push_back(e);
            } else {
                let pos = b.partition_point(|x| *x < e);
                b.insert(pos, e);
            }
            if self.front.map_or(true, |f| e < f) {
                self.front = Some(e);
            }
        } else {
            // Strictly later day than every wheel event: cannot be the min.
            self.overflow.push(e);
        }
        self.len += 1;
    }
}

impl Pack for EventKind {
    fn pack(&self, w: &mut Writer) {
        let (tag, idx): (u8, usize) = match *self {
            EventKind::ComputeDone { node } => (0, node),
            EventKind::MsgArrive { node } => (1, node),
            EventKind::DownlinkArrive { node } => (2, node),
            EventKind::AggregateArrive { agg } => (3, agg),
        };
        w.put_u8(tag);
        w.put_usize(idx);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let tag = r.get_u8()?;
        let idx = r.get_usize()?;
        Ok(match tag {
            0 => EventKind::ComputeDone { node: idx },
            1 => EventKind::MsgArrive { node: idx },
            2 => EventKind::DownlinkArrive { node: idx },
            3 => EventKind::AggregateArrive { agg: idx },
            other => anyhow::bail!("unknown event kind tag {other}"),
        })
    }
}

impl Pack for Event {
    fn pack(&self, w: &mut Writer) {
        w.put_f64(self.time);
        w.put_u64(self.seq);
        self.kind.pack(w);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let time = r.get_f64()?;
        anyhow::ensure!(
            time.is_finite() && time >= 0.0,
            "snapshot event has bad virtual time {time}"
        );
        let seq = r.get_u64()?;
        let kind = EventKind::unpack(r)?;
        Ok(Self { time, seq, kind })
    }
}

/// Snapshots serialize the queue as a *sorted* `(time, seq)` list — the
/// bucket geometry is an implementation detail, but the sorted order is
/// canonical, so pack∘unpack∘pack is byte-stable (and byte-identical to
/// the binary-heap era: snapshot version compatibility is free).
impl Pack for EventQueue {
    fn pack(&self, w: &mut Writer) {
        let mut evs: Vec<Event> = self.events().copied().collect();
        evs.sort();
        evs.pack(w);
        w.put_u64(self.next_seq);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let evs = Vec::<Event>::unpack(r)?;
        let next_seq = r.get_u64()?;
        for e in &evs {
            anyhow::ensure!(
                e.seq < next_seq,
                "snapshot event seq {} not below counter {next_seq}",
                e.seq
            );
        }
        let mut q = Self::new();
        q.rebuild_with(evs);
        q.next_seq = next_seq;
        Ok(q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::MsgArrive { node: 0 });
        q.push(0.5, EventKind::ComputeDone { node: 1 });
        q.push(1.0, EventKind::ComputeDone { node: 2 });
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|e| e.time)).collect();
        assert_eq!(times, vec![0.5, 1.0, 2.0]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for node in 0..5 {
            q.push(0.0, EventKind::ComputeDone { node });
        }
        for node in 0..5 {
            let e = q.pop().unwrap();
            assert_eq!(e.kind, EventKind::ComputeDone { node });
        }
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_pushes_stay_deterministic() {
        // two identical push sequences produce identical pop sequences
        let run = || {
            let mut q = EventQueue::new();
            q.push(1.0, EventKind::ComputeDone { node: 0 });
            q.push(1.0, EventKind::MsgArrive { node: 1 });
            q.push(0.0, EventKind::ComputeDone { node: 2 });
            q.push(1.0, EventKind::ComputeDone { node: 3 });
            std::iter::from_fn(|| q.pop().map(|e| (e.time, e.kind))).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    /// S1 regression: a non-finite or negative virtual time must be a hard
    /// error in release builds, not a debug assertion.
    #[test]
    fn push_rejects_bad_virtual_times_in_release() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1e-9] {
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut q = EventQueue::new();
                q.push(bad, EventKind::ComputeDone { node: 0 });
            }));
            assert!(caught.is_err(), "time {bad} was accepted");
        }
    }

    /// Far-future events land in the overflow list (day beyond the current
    /// year) and still pop in exact (time, seq) order after the wheel
    /// re-anchors — including a push *below* the re-anchored year.
    #[test]
    fn overflow_and_year_advance_preserve_total_order() {
        let mut q = EventQueue::new();
        for node in 0..64 {
            q.push(node as f64 * 0.01, EventKind::ComputeDone { node });
        }
        // far-future cluster, way past the dense year
        for node in 0..8 {
            q.push(1e9 + node as f64, EventKind::MsgArrive { node });
        }
        let mut last = (-1.0, 0u64);
        for _ in 0..60 {
            let e = q.pop().unwrap();
            assert!((e.time, e.seq) > last, "order inverted at {:?}", (e.time, e.seq));
            last = (e.time, e.seq);
        }
        // now push below the drained region again (virtual "now" trails)
        q.push(0.9, EventKind::DownlinkArrive { node: 3 });
        let next = q.pop().unwrap();
        assert_eq!(next.time, 0.9);
        let mut prev = (next.time, next.seq);
        while let Some(e) = q.pop() {
            assert!((e.time, e.seq) > prev);
            prev = (e.time, e.seq);
        }
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn queue_snapshot_restores_order_and_seq_counter() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::ComputeDone { node: 0 });
        q.push(1.0, EventKind::MsgArrive { node: 1 });
        q.push(0.5, EventKind::DownlinkArrive { node: 2 });
        q.push(2.0, EventKind::AggregateArrive { agg: 0 });
        let _ = q.pop(); // consume one so next_seq != len
        let mut w = Writer::new();
        q.pack(&mut w);
        let bytes = w.into_inner();
        let mut restored = EventQueue::unpack(&mut Reader::new(&bytes)).unwrap();
        // restored queue pops identically AND assigns the same future seqs
        q.push(1.0, EventKind::ComputeDone { node: 9 });
        restored.push(1.0, EventKind::ComputeDone { node: 9 });
        loop {
            let (a, b) = (q.pop(), restored.pop());
            assert_eq!(a.map(|e| (e.time, e.seq, e.kind)), b.map(|e| (e.time, e.seq, e.kind)));
            if a.is_none() {
                break;
            }
        }
        // pack is canonical: repacking the restored queue is byte-identical
        let mut q2 = EventQueue::new();
        q2.push(3.0, EventKind::MsgArrive { node: 4 });
        q2.push(1.0, EventKind::ComputeDone { node: 2 });
        let mut w1 = Writer::new();
        q2.pack(&mut w1);
        let restored2 = EventQueue::unpack(&mut Reader::new(w1.as_slice())).unwrap();
        let mut w2 = Writer::new();
        restored2.pack(&mut w2);
        assert_eq!(w1.as_slice(), w2.as_slice());
    }

    #[test]
    fn queue_unpack_rejects_bad_times_and_seqs() {
        // NaN time
        let mut w = Writer::new();
        vec![Event { time: f64::NAN, seq: 0, kind: EventKind::ComputeDone { node: 0 } }]
            .pack(&mut w);
        w.put_u64(1);
        assert!(EventQueue::unpack(&mut Reader::new(w.as_slice())).is_err());
        // seq not below the counter
        let mut w = Writer::new();
        vec![Event { time: 0.0, seq: 5, kind: EventKind::ComputeDone { node: 0 } }]
            .pack(&mut w);
        w.put_u64(5);
        assert!(EventQueue::unpack(&mut Reader::new(w.as_slice())).is_err());
        // unknown kind tag
        let mut w = Writer::new();
        w.put_usize(1);
        w.put_f64(0.0);
        w.put_u64(0);
        w.put_u8(9);
        w.put_usize(0);
        w.put_u64(1);
        assert!(EventQueue::unpack(&mut Reader::new(w.as_slice())).is_err());
    }

    #[test]
    fn peek_time_matches_next_pop() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(3.5, EventKind::MsgArrive { node: 9 });
        q.push(0.25, EventKind::MsgArrive { node: 4 });
        assert_eq!(q.peek_time(), Some(0.25));
        assert_eq!(q.pop().unwrap().time, 0.25);
        assert_eq!(q.peek_time(), Some(3.5));
        assert_eq!(q.len(), 1);
        assert_eq!(q.next_seq(), 2);
    }

    /// Grow/shrink rebuilds (len crossing 2·buckets and buckets/4) must be
    /// invisible to pop order.
    #[test]
    fn resize_rebuilds_preserve_order() {
        let mut q = EventQueue::new();
        let mut reference = Vec::new();
        // enough same-instant + spread events to force several doublings
        for i in 0..500usize {
            let t = if i % 3 == 0 { 7.25 } else { (i as f64 * 0.618).fract() * 100.0 };
            q.push(t, EventKind::ComputeDone { node: i });
            reference.push((t, i as u64));
        }
        reference.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        // drain far enough to trigger the shrink path too
        for want in &reference {
            let e = q.pop().unwrap();
            assert_eq!((e.time, e.seq), *want);
        }
        assert!(q.is_empty());
    }
}
