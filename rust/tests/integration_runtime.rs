//! Integration tests over the PJRT runtime: every artifact loads, compiles
//! and agrees with the native f64 implementations — the HLO-vs-native
//! parity suite. Skipped gracefully when `artifacts/` has not been built.

use qadmm::compress::qsgd::Qsgd;
use qadmm::problems::lasso::{LassoConfig, LassoProblem};
use qadmm::problems::Problem;
use qadmm::runtime::tensor::Tensor;
use qadmm::runtime::Runtime;
use qadmm::solver::prox;
use qadmm::util::rng::Pcg64;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(std::path::Path::new("artifacts")).expect("open runtime"))
}

#[test]
fn quantize_artifact_is_bit_identical_to_native_f64() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg64::seed_from_u64(42);
    for q in [2u8, 3, 5, 8] {
        let qsgd = Qsgd::new(q);
        let delta = rng.normal_vec(200, 0.0, 2.0);
        let noise = rng.uniform_vec_f64(200);
        let out = rt
            .call(
                "quantize_f64_m200",
                &[
                    Tensor::vec_f64(delta.clone()),
                    Tensor::vec_f64(noise.clone()),
                    Tensor::scalar_f64(qsgd.s() as f64),
                ],
            )
            .unwrap();
        let (levels, norm) = qsgd.quantize_with_noise(&delta, &noise);
        assert_eq!(out[1].as_i32().unwrap(), levels.as_slice(), "q={q}");
        assert_eq!(out[2].scalar().unwrap(), norm, "q={q}");
        // dequantized values identical to the wire-side reconstruction
        let deq = qsgd.dequantize(&levels, norm);
        let hlo_vals = out[0].as_f64().unwrap();
        for (a, b) in hlo_vals.iter().zip(&deq) {
            assert!((a - b).abs() < 1e-15);
        }
    }
}

#[test]
fn quantize_artifact_zero_vector() {
    let Some(rt) = runtime() else { return };
    let out = rt
        .call(
            "quantize_f64_m200",
            &[
                Tensor::vec_f64(vec![0.0; 200]),
                Tensor::vec_f64(vec![0.5; 200]),
                Tensor::scalar_f64(3.0),
            ],
        )
        .unwrap();
    assert!(out[0].as_f64().unwrap().iter().all(|&v| v == 0.0));
    assert!(out[1].as_i32().unwrap().iter().all(|&l| l == 0));
    assert_eq!(out[2].scalar().unwrap(), 0.0);
}

#[test]
fn soft_threshold_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg64::seed_from_u64(7);
    let v = rng.normal_vec(200, 0.0, 1.0);
    for kappa in [0.0, 0.3, 2.0] {
        let out = rt
            .call(
                "soft_threshold_f64_m200",
                &[Tensor::vec_f64(v.clone()), Tensor::scalar_f64(kappa)],
            )
            .unwrap();
        let native = prox::soft_threshold(&v, kappa);
        for (a, b) in out[0].as_f64().unwrap().iter().zip(&native) {
            assert!((a - b).abs() < 1e-15, "kappa={kappa}");
        }
    }
}

fn paper_lasso(rng: &mut Pcg64) -> LassoProblem {
    LassoProblem::generate(
        LassoConfig { m: 200, h: 100, n: 16, rho: 500.0, theta: 0.1 },
        rng,
    )
    .unwrap()
}

fn service() -> Option<qadmm::runtime::service::ComputeService> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(
        qadmm::runtime::service::ComputeService::start("artifacts".into(), vec![])
            .expect("compute service"),
    )
}

#[test]
fn lasso_node_step_hlo_matches_native() {
    let Some(svc) = service() else { return };
    let mut rng = Pcg64::seed_from_u64(3);
    let mut native = paper_lasso(&mut rng);
    let mut rng2 = Pcg64::seed_from_u64(3);
    let mut hlo =
        paper_lasso(&mut rng2).with_hlo(Box::new(svc.client()), 200, 16).unwrap();
    let zhat = rng.normal_vec(200, 0.0, 1.0);
    let u = rng.normal_vec(200, 0.0, 0.1);
    let x_prev = vec![0.0; 200];
    for node in [0usize, 7, 15] {
        let (xn, _) = native.local_update(node, &zhat, &u, &x_prev, &mut rng).unwrap();
        let (xh, _) = hlo.local_update(node, &zhat, &u, &x_prev, &mut rng).unwrap();
        for (a, b) in xn.iter().zip(&xh) {
            assert!((a - b).abs() < 1e-8, "node {node}: {a} vs {b}");
        }
    }
}

/// Regression: two problem *instances* sharing one compute service must not
/// collide in the pinned-constant cache (each instance gets a namespace).
#[test]
fn pinned_consts_do_not_collide_across_instances() {
    let Some(svc) = service() else { return };
    let make = |seed: u64| {
        let mut rng = Pcg64::seed_from_u64(seed);
        let native = paper_lasso(&mut rng);
        let mut rng2 = Pcg64::seed_from_u64(seed);
        let hlo = paper_lasso(&mut rng2).with_hlo(Box::new(svc.client()), 200, 16).unwrap();
        (native, hlo)
    };
    let (mut nat_a, mut hlo_a) = make(100);
    let (mut nat_b, mut hlo_b) = make(200); // different data!
    let mut rng = Pcg64::seed_from_u64(7);
    let zhat = rng.normal_vec(200, 0.0, 1.0);
    let u = rng.normal_vec(200, 0.0, 0.1);
    let x_prev = vec![0.0; 200];
    // interleave calls: A then B then A again
    let mut check = |nat: &mut LassoProblem, hlo: &mut LassoProblem, rng: &mut Pcg64| {
        let (xn, _) = nat.local_update(0, &zhat, &u, &x_prev, rng).unwrap();
        let (xh, _) = hlo.local_update(0, &zhat, &u, &x_prev, rng).unwrap();
        for (a, b) in xn.iter().zip(&xh) {
            assert!((a - b).abs() < 1e-8, "instance collision: {a} vs {b}");
        }
    };
    check(&mut nat_a, &mut hlo_a, &mut rng);
    check(&mut nat_b, &mut hlo_b, &mut rng);
    check(&mut nat_a, &mut hlo_a, &mut rng);
}

// NOTE: the `lasso_server_step` artifact (and its HLO-vs-native parity
// test) is retired: no runtime path reaches it — the per-round server prox
// runs native-f64 via `Problem::consensus_from_sum` on every backend. The
// remaining kernels below are the HLO parity surface.

#[test]
fn lasso_lagrangian_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg64::seed_from_u64(5);
    let p = paper_lasso(&mut rng);
    let x: Vec<Vec<f64>> = (0..16).map(|_| rng.normal_vec(200, 0.0, 1.0)).collect();
    let u: Vec<Vec<f64>> = (0..16).map(|_| rng.normal_vec(200, 0.0, 0.1)).collect();
    let z = rng.normal_vec(200, 0.0, 1.0);
    let native_lag = p.lagrangian(
        &qadmm::problems::Arena::from_rows(&x),
        &qadmm::problems::Arena::from_rows(&u),
        &z,
    );
    let (ata, atb2, btb) = p.gram_tensors();
    let out = rt
        .call(
            "lasso_lagrangian",
            &[
                Tensor::F64(x.concat(), vec![16, 200]),
                Tensor::F64(u.concat(), vec![16, 200]),
                Tensor::vec_f64(z),
                Tensor::F64(ata, vec![16, 200, 200]),
                Tensor::F64(atb2, vec![16, 200]),
                Tensor::vec_f64(btb),
                Tensor::scalar_f64(0.1),
                Tensor::scalar_f64(500.0),
            ],
        )
        .unwrap();
    let hlo_lag = out[0].scalar().unwrap();
    let rel = (native_lag - hlo_lag).abs() / native_lag.abs();
    assert!(rel < 1e-12, "native={native_lag} hlo={hlo_lag}");
}

#[test]
fn artifact_input_validation_rejects_bad_shapes() {
    let Some(rt) = runtime() else { return };
    let err = rt
        .call(
            "quantize_f64_m200",
            &[
                Tensor::vec_f64(vec![0.0; 100]), // wrong length
                Tensor::vec_f64(vec![0.5; 200]),
                Tensor::scalar_f64(3.0),
            ],
        )
        .unwrap_err();
    assert!(err.to_string().contains("shape"), "{err}");
    let err = rt.call("nonexistent", &[]).unwrap_err();
    assert!(err.to_string().contains("unknown artifact"), "{err}");
}

#[test]
fn f32_quantize_artifact_matches_native_within_f32() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg64::seed_from_u64(9);
    let delta64 = rng.normal_vec(1024, 0.0, 1.0);
    let noise64 = rng.uniform_vec_f64(1024);
    let delta32: Vec<f32> = delta64.iter().map(|&x| x as f32).collect();
    let noise32: Vec<f32> = noise64.iter().map(|&x| x as f32).collect();
    let out = rt
        .call(
            "quantize_f32_m1024",
            &[
                Tensor::vec_f32(delta32.clone()),
                Tensor::vec_f32(noise32.clone()),
                Tensor::scalar_f32(3.0),
            ],
        )
        .unwrap();
    // native twin in f64 over the f32-rounded inputs: levels can differ only
    // on knife-edge rounding; check ≥99% agreement + value bound
    let qsgd = Qsgd::new(3);
    let (levels, norm) = qsgd.quantize_with_noise(
        &delta32.iter().map(|&x| x as f64).collect::<Vec<_>>(),
        &noise32.iter().map(|&x| x as f64).collect::<Vec<_>>(),
    );
    let hlo_levels = out[1].as_i32().unwrap();
    let agree = hlo_levels.iter().zip(&levels).filter(|(a, b)| a == b).count();
    assert!(agree >= 1014, "only {agree}/1024 levels agree");
    assert!((out[2].scalar().unwrap() - norm).abs() < 1e-6);
}
