//! Incremental server consensus state: the running sum s = Σᵢ(x̂ᵢ + ûᵢ).
//!
//! The paper's server (Algorithm 1 lines 27–43) recomputes the consensus
//! input v = mean(x̂ + û) from every node's estimate bank on every round,
//! an O(n·m) sweep even though only P ≤ n nodes arrived. But the banks
//! evolve *only* by dequantized deltas: `MsgArrive` commits x̂ᵢ += C(Δxᵢ),
//! ûᵢ += C(Δuᵢ) and nothing else ever touches them. So the server can
//! carry s across rounds and fold each arrival in as
//!
//! ```text
//!     s ← s + C(Δxᵢ) + C(Δuᵢ)          (O(m) per arrival)
//! ```
//!
//! after which one fire is `z = prox(s/n)` — O(m) total via
//! [`crate::problems::Problem::consensus_from_sum`] — instead of O(n·m).
//! At n = 1024, m = 10240 that turns a ~160 MB bank sweep per round into a
//! few hundred KB of arrival folds.
//!
//! # Floating-point drift and the two defenses
//!
//! The incremental s is *not* bitwise the recomputed Σ(x̂ᵢ + ûᵢ): addition
//! is non-associative, and after many folds the rounding errors of the two
//! evaluation orders diverge. Two mechanisms keep the gap far below the
//! quantization noise the algorithm already tolerates:
//!
//! * **Kahan compensation on every fold** ([`ConsensusAccumulator::fold`]):
//!   each coordinate keeps a running compensation term, so the error of the
//!   incremental sum stays O(ε)·Σ|δ| instead of growing with the number of
//!   folds. The property suite (`tests/prop.rs`) drives 10k folds without
//!   refresh and bounds the gap at ≤ 1e-10 relative.
//! * **Periodic full recompute** ([`ConsensusAccumulator::refresh`], every
//!   `refresh_every` rounds, default on — see
//!   [`crate::config::ExperimentConfig::consensus_refresh_every`]): the sum
//!   and its compensation are rebuilt from the banks in node order, washing
//!   out whatever drift accumulated. This is the only remaining O(n·m)
//!   server work, amortized to O(n·m / K) per round; `refresh_every = 0`
//!   disables it entirely (the Kahan bound still holds).
//!
//! # Determinism contract
//!
//! The sequential simulator and the event engine share this type and fold
//! in the same order at zero latency (ascending node id within a virtual
//! instant), so the `tests/engine_parity.rs` bit-identity contract holds
//! through the incremental path: same folds, same refresh rounds, same
//! bits. The threaded coordinator folds in real arrival order — no bitwise
//! claim there, only the ≤1e-10 drift bound.

use crate::snapshot::codec::{Pack, Reader, Writer};

/// A Kahan-compensated running vector sum: the *mergeable partial sum*
/// primitive shared by the server's [`ConsensusAccumulator`] and the
/// per-aggregator pending buffers of hierarchical fan-in topologies
/// ([`crate::topology::AggregatorTier`]). Each coordinate carries its
/// compensation term, so the represented value stays within O(ε)·Σ|δ| of
/// the exact sum regardless of fold count, and two independently
/// accumulated partials can be [`KahanVec::merge`]d without losing either
/// side's low-order bits.
#[derive(Clone, Debug)]
pub struct KahanVec {
    sum: Vec<f64>,
    /// Per-coordinate compensation: the low-order error the last addition
    /// *included* (subtracted from the next addend).
    comp: Vec<f64>,
}

impl KahanVec {
    pub fn zeros(m: usize) -> Self {
        Self { sum: vec![0.0; m], comp: vec![0.0; m] }
    }

    pub fn dim(&self) -> usize {
        self.sum.len()
    }

    /// The represented value (the compensated running sum).
    pub fn value(&self) -> &[f64] {
        &self.sum
    }

    #[inline]
    pub fn kahan_add(sum: &mut f64, comp: &mut f64, v: f64) {
        let y = v - *comp;
        let t = *sum + y;
        *comp = (t - *sum) - y;
        *sum = t;
    }

    /// s += v, compensated per coordinate.
    pub fn add(&mut self, v: &[f64]) {
        debug_assert_eq!(v.len(), self.sum.len());
        for (j, (s, c)) in self.sum.iter_mut().zip(self.comp.iter_mut()).enumerate() {
            Self::kahan_add(s, c, v[j]);
        }
    }

    /// s −= v (error-feedback residual after a compressed forward).
    pub fn sub(&mut self, v: &[f64]) {
        debug_assert_eq!(v.len(), self.sum.len());
        for (j, (s, c)) in self.sum.iter_mut().zip(self.comp.iter_mut()).enumerate() {
            Self::kahan_add(s, c, -v[j]);
        }
    }

    /// Paired fold s += a + b in one pass (the consensus arrival shape).
    pub fn fold2(&mut self, a: &[f64], b: &[f64]) {
        debug_assert_eq!(a.len(), self.sum.len());
        debug_assert_eq!(b.len(), self.sum.len());
        for (j, (s, c)) in self.sum.iter_mut().zip(self.comp.iter_mut()).enumerate() {
            Self::kahan_add(s, c, a[j]);
            Self::kahan_add(s, c, b[j]);
        }
    }

    /// Fold another partial sum in, preserving its compensation: the true
    /// value of `other` is `sum − comp` to working precision, so the merge
    /// adds `other.sum` and then corrects by `−other.comp`. No runtime
    /// path calls this yet — it is the composition primitive for
    /// multi-level aggregator trees (aggregators of aggregators merge
    /// their children's partials; see the ROADMAP topology follow-up) and
    /// is kept pinned by its unit test until that tier lands.
    pub fn merge(&mut self, other: &KahanVec) {
        debug_assert_eq!(other.dim(), self.dim());
        for (j, (s, c)) in self.sum.iter_mut().zip(self.comp.iter_mut()).enumerate() {
            Self::kahan_add(s, c, other.sum[j]);
            Self::kahan_add(s, c, -other.comp[j]);
        }
    }

    pub fn reset(&mut self) {
        self.sum.iter_mut().for_each(|v| *v = 0.0);
        self.comp.iter_mut().for_each(|v| *v = 0.0);
    }
}

/// Running Kahan-compensated Σᵢ(x̂ᵢ + ûᵢ) with a periodic full-recompute
/// refresh. See the module docs for fold/finalize/refresh semantics.
#[derive(Clone, Debug)]
pub struct ConsensusAccumulator {
    /// s = Σᵢ(x̂ᵢ + ûᵢ) with per-coordinate compensation.
    state: KahanVec,
    /// Full recompute cadence in consensus rounds (0 = never).
    refresh_every: usize,
}

impl ConsensusAccumulator {
    pub fn new(m: usize, refresh_every: usize) -> Self {
        Self { state: KahanVec::zeros(m), refresh_every }
    }

    pub fn dim(&self) -> usize {
        self.state.dim()
    }

    /// The current running sum s (pass to
    /// [`crate::problems::Problem::consensus_from_sum`]).
    pub fn sum(&self) -> &[f64] {
        self.state.value()
    }

    /// Fold one arrival's dequantized deltas: s += C(Δx) + C(Δu), O(m).
    /// Must be called with exactly the vectors committed into the estimate
    /// banks (the [`crate::compress::Compressed::dequantized`] payloads) so
    /// that s keeps tracking Σᵢ(x̂ᵢ + ûᵢ).
    pub fn fold(&mut self, dx: &[f64], du: &[f64]) {
        self.state.fold2(dx, du);
    }

    /// True when the round about to fire (1-based) is a refresh round. Both
    /// in-process engines call this with their shared round counter, so at
    /// parity they refresh on identical rounds.
    pub fn refresh_due(&self, round: usize) -> bool {
        self.refresh_every > 0 && round % self.refresh_every == 0
    }

    /// Full recompute from the estimate banks, in iteration order, resetting
    /// the compensation: the O(n·m) drift wash-out. `rows` yields each
    /// node's (x̂ᵢ, ûᵢ) estimate slices.
    pub fn refresh<'b>(&mut self, rows: impl Iterator<Item = (&'b [f64], &'b [f64])>) {
        self.state.reset();
        for (x, u) in rows {
            self.fold(x, u);
        }
    }
}

impl Pack for KahanVec {
    fn pack(&self, w: &mut Writer) {
        self.sum.pack(w);
        self.comp.pack(w);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let sum = Vec::<f64>::unpack(r)?;
        let comp = Vec::<f64>::unpack(r)?;
        anyhow::ensure!(
            sum.len() == comp.len(),
            "snapshot kahan vec: sum/compensation length mismatch"
        );
        Ok(Self { sum, comp })
    }
}

/// The compensation terms travel with the sum: restoring only `value()`
/// would discard the low-order bits and break the bit-identity contract on
/// the very next fold.
impl Pack for ConsensusAccumulator {
    fn pack(&self, w: &mut Writer) {
        self.state.pack(w);
        w.put_usize(self.refresh_every);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        Ok(Self { state: KahanVec::unpack(r)?, refresh_every: r.get_usize()? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn fold_tracks_plain_sum_on_small_inputs() {
        let mut acc = ConsensusAccumulator::new(3, 0);
        acc.fold(&[1.0, 2.0, 3.0], &[0.5, 0.5, 0.5]);
        acc.fold(&[-1.0, 0.0, 1.0], &[0.0, 0.0, 0.0]);
        assert_eq!(acc.sum(), &[0.5, 2.5, 4.5]);
    }

    #[test]
    fn refresh_matches_direct_fold_from_zero() {
        let mut rng = Pcg64::seed_from_u64(3);
        let m = 17;
        let xs: Vec<Vec<f64>> = (0..5).map(|_| rng.normal_vec(m, 0.0, 1.0)).collect();
        let us: Vec<Vec<f64>> = (0..5).map(|_| rng.normal_vec(m, 0.0, 1.0)).collect();
        let mut a = ConsensusAccumulator::new(m, 4);
        a.refresh(xs.iter().zip(&us).map(|(x, u)| (x.as_slice(), u.as_slice())));
        let mut b = ConsensusAccumulator::new(m, 4);
        for (x, u) in xs.iter().zip(&us) {
            b.fold(x, u);
        }
        assert_eq!(a.sum(), b.sum());
    }

    #[test]
    fn refresh_cadence() {
        let acc = ConsensusAccumulator::new(1, 5);
        assert!(!acc.refresh_due(1));
        assert!(!acc.refresh_due(4));
        assert!(acc.refresh_due(5));
        assert!(acc.refresh_due(10));
        let never = ConsensusAccumulator::new(1, 0);
        for r in 1..100 {
            assert!(!never.refresh_due(r));
        }
    }

    /// A single `add` from zero is exact (the compensation starts at 0 and
    /// the addend lands unrounded): this is what keeps the degenerate
    /// one-child-per-aggregator tree bit-identical to the star fan-in.
    #[test]
    fn kahan_vec_single_add_from_zero_is_exact() {
        let mut rng = Pcg64::seed_from_u64(17);
        let v = rng.normal_vec(33, 0.0, 3.0);
        let mut k = KahanVec::zeros(33);
        k.add(&v);
        assert_eq!(k.value(), v.as_slice());
        // and subtracting it back lands exactly on zero
        k.sub(&v);
        assert!(k.value().iter().all(|&x| x == 0.0));
    }

    /// Merging two independently accumulated partials matches folding both
    /// streams into one accumulator, to working precision.
    #[test]
    fn kahan_vec_merge_matches_joint_fold() {
        let mut rng = Pcg64::seed_from_u64(23);
        let m = 16;
        let a_stream: Vec<Vec<f64>> = (0..500).map(|_| rng.normal_vec(m, 0.0, 1e6)).collect();
        let b_stream: Vec<Vec<f64>> = (0..500).map(|_| rng.normal_vec(m, 0.0, 1e-6)).collect();
        let mut a = KahanVec::zeros(m);
        let mut b = KahanVec::zeros(m);
        let mut joint = KahanVec::zeros(m);
        for (va, vb) in a_stream.iter().zip(&b_stream) {
            a.add(va);
            b.add(vb);
            joint.add(va);
            joint.add(vb);
        }
        a.merge(&b);
        let norm = joint.value().iter().fold(1.0f64, |mx, v| mx.max(v.abs()));
        for (x, y) in a.value().iter().zip(joint.value()) {
            assert!((x - y).abs() <= 1e-12 * norm, "merge {x} vs joint {y}");
        }
    }

    /// Kahan beats naive summation on an adversarial magnitude mix.
    #[test]
    fn kahan_compensates_magnitude_spread() {
        let m = 1;
        let mut acc = ConsensusAccumulator::new(m, 0);
        let mut naive = 0.0f64;
        let big = 1e14;
        acc.fold(&[big], &[0.0]);
        naive += big;
        for _ in 0..10_000 {
            acc.fold(&[0.1], &[0.0]);
            naive += 0.1;
        }
        acc.fold(&[-big], &[0.0]);
        naive += -big;
        let exact = 1000.0;
        let kahan_err = (acc.sum()[0] - exact).abs();
        let naive_err = (naive - exact).abs();
        assert!(kahan_err <= 1e-9, "kahan err {kahan_err}");
        assert!(naive_err > kahan_err, "naive {naive_err} vs kahan {kahan_err}");
    }
}
