//! Figure 3 (LASSO): accuracy (eq. 19) vs iterations and vs communication
//! bits, QADMM (q = 3) against unquantized async ADMM, τ ∈ {1, 3}.
//! Headline: ~90.62% fewer bits to reach accuracy 1e-10.

use std::collections::HashMap;
use std::path::Path;

use crate::admm::runner::{self, ProblemFactory};
use crate::compress::CompressorKind;
use crate::config::{presets, Backend, ExperimentConfig, ProblemKind};
use crate::metrics::summary;
use crate::problems::lasso::{LassoConfig, LassoProblem};
use crate::problems::Problem;
use crate::runtime::service::ComputeService;
use crate::util::rng::Pcg64;

use super::Series;

pub struct Fig3Options {
    pub taus: Vec<usize>,
    pub iters: usize,
    pub mc_trials: usize,
    pub backend: Backend,
    pub out_dir: std::path::PathBuf,
    pub artifact_dir: std::path::PathBuf,
    /// Accuracy target for the headline reduction number.
    pub target: f64,
}

impl Default for Fig3Options {
    fn default() -> Self {
        Self {
            taus: vec![1, 3],
            iters: presets::fig3(3).iters,
            mc_trials: presets::fig3(3).mc_trials,
            backend: Backend::Hlo,
            out_dir: "out".into(),
            artifact_dir: "artifacts".into(),
            target: 1e-10,
        }
    }
}

pub struct Fig3Summary {
    pub series: Vec<Series>,
    pub headline: Vec<String>,
}

fn lasso_cfg_of(cfg: &ExperimentConfig) -> LassoConfig {
    match cfg.problem {
        ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
        _ => unreachable!("fig3 is a LASSO experiment"),
    }
}

pub fn run(opts: &Fig3Options) -> anyhow::Result<Fig3Summary> {
    std::fs::create_dir_all(&opts.out_dir)?;
    // One compute service shared by every trial (HLO backend).
    let service = match opts.backend {
        Backend::Hlo => Some(ComputeService::start(
            opts.artifact_dir.clone(),
            vec!["lasso_node_step".into()],
        )?),
        Backend::Native => None,
    };
    // F* depends only on the trial data — cache per trial seed so the
    // QADMM/baseline/τ variants share it.
    let mut fstar_cache: HashMap<u64, f64> = HashMap::new();

    let mut series = Vec::new();
    let mut headline = Vec::new();
    for &tau in &opts.taus {
        let mut per_tau: Vec<(String, crate::metrics::RunRecorder)> = Vec::new();
        for compressor in [CompressorKind::Qsgd { bits: 3 }, CompressorKind::Identity32] {
            let mut cfg = presets::fig3(tau);
            cfg.iters = opts.iters;
            cfg.mc_trials = opts.mc_trials;
            cfg.compressor = compressor;
            cfg.backend = opts.backend;
            let label = format!(
                "tau{tau}_{}",
                if matches!(compressor, CompressorKind::Qsgd { .. }) {
                    "qadmm"
                } else {
                    "baseline"
                }
            );
            let lcfg = lasso_cfg_of(&cfg);
            let backend = opts.backend;
            let svc = service.as_ref();
            let cache = &mut fstar_cache;
            let mut factory: Box<ProblemFactory> =
                Box::new(move |seed: u64, data_rng: &mut Pcg64| {
                    let mut p = LassoProblem::generate(lcfg, data_rng)?;
                    if backend == Backend::Hlo {
                        let client = svc.expect("service").client();
                        p = p.with_hlo(Box::new(client), lcfg.m, lcfg.n)?;
                    }
                    if let Some(&f) = cache.get(&seed) {
                        p.set_reference_optimum(f);
                    } else {
                        let f = p.reference_optimum(6000);
                        cache.insert(seed, f);
                    }
                    Ok(Box::new(p) as Box<dyn Problem>)
                });
            let result = runner::run_mc(&cfg, factory.as_mut())?;
            drop(factory);
            let s = Series { label: label.clone(), result };
            s.write_csv(&opts.out_dir, "fig3")?;
            per_tau.push((label, s.mean_recorder()));
            series.push(s);
        }
        // headline: bits to reach the accuracy target (QADMM vs baseline)
        let q = summary::bits_to_accuracy(&per_tau[0].1.records, opts.target);
        let b = summary::bits_to_accuracy(&per_tau[1].1.records, opts.target);
        headline.push(summary::headline_row(
            &format!("Fig3 LASSO tau={tau}"),
            &format!("accuracy {:.0e}", opts.target),
            q,
            b,
        ));
    }
    Ok(Fig3Summary { series, headline })
}

/// Reduced-size variant for CI / integration tests (native backend).
pub fn quick(out_dir: &Path) -> anyhow::Result<Fig3Summary> {
    run(&Fig3Options {
        taus: vec![3],
        iters: 200,
        mc_trials: 2,
        backend: Backend::Native,
        out_dir: out_dir.to_path_buf(),
        artifact_dir: "artifacts".into(),
        target: 1e-8,
    })
}
