//! 1-bit sign compressor (signSGD [11] with the ℓ₁/M scale of EF-signSGD
//! [12]): C(Δ) = (‖Δ‖₁/M) · sign(Δ). The extreme point of the
//! bits-vs-fidelity ablation.

use super::wire::encode_sign;
use super::{sanitize, Compressed, Compressor};
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug)]
pub struct SignSgd;

impl Compressor for SignSgd {
    fn name(&self) -> String {
        "sign".into()
    }

    fn compress(&self, delta: &[f64], _rng: &mut Pcg64) -> Compressed {
        let m = delta.len().max(1);
        // non-finite coordinates contribute 0 to the ℓ₁ scale (one ∞ would
        // otherwise blow the scale — and thus every coordinate — to ∞)
        let scale = delta.iter().map(|x| sanitize(*x).abs()).sum::<f64>() / m as f64;
        let negs: Vec<bool> = delta.iter().map(|&x| sanitize(x) < 0.0).collect();
        Compressed { wire: encode_sign(&negs, scale) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_signs_and_l1_scale() {
        let delta = vec![2.0, -4.0, 0.5, -0.5, 1.0];
        let c = SignSgd.compress(&delta, &mut Pcg64::seed_from_u64(0));
        let scale = 8.0 / 5.0;
        assert_eq!(c.dequantized().unwrap(), vec![scale, -scale, scale, -scale, scale]);
    }

    #[test]
    fn wire_is_about_one_bit_per_scalar() {
        let delta = vec![1.0; 800];
        let c = SignSgd.compress(&delta, &mut Pcg64::seed_from_u64(0));
        // 5-byte frame header + 8-byte scale + 100 bytes of bitmap
        assert_eq!(c.wire.len(), 5 + 8 + 100);
        assert_eq!(SignSgd.decode(&c.wire, 800).unwrap(), c.dequantized().unwrap());
    }

    #[test]
    fn zero_vector_gives_zero_scale() {
        let c = SignSgd.compress(&[0.0; 16], &mut Pcg64::seed_from_u64(0));
        assert!(c.dequantized().unwrap().iter().all(|&v| v == 0.0));
    }
}
