//! ComputeService: a dedicated thread that owns the (non-`Send`) PJRT
//! client and serves artifact executions over channels — the executor
//! process of the threaded deployment. Node workers and the server thread
//! hold cloneable [`ComputeClient`] handles.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use super::tensor::Tensor;
use super::{Exec, Runtime};

enum Request {
    Call {
        name: String,
        inputs: Vec<Tensor>,
        reply: Sender<anyhow::Result<Vec<Tensor>>>,
    },
    /// Prefixed call: `consts` is Some only the first time a (name, key)
    /// pair is seen by this client — the service pins them on device.
    CallPrefixed {
        name: String,
        key: u64,
        consts: Option<Vec<Tensor>>,
        varying: Vec<Tensor>,
        reply: Sender<anyhow::Result<Vec<Tensor>>>,
    },
    /// Evict pinned constants for a retired problem instance.
    DropConsts { name: String, keys: Vec<u64> },
    Shutdown,
}

/// Cloneable handle to the compute thread.
#[derive(Clone)]
pub struct ComputeClient {
    tx: Sender<Request>,
    /// (name, key) pairs whose constants this client already shipped.
    registered: std::sync::Arc<std::sync::Mutex<std::collections::HashSet<(String, u64)>>>,
}

impl ComputeClient {
    pub fn call(&self, name: &str, inputs: Vec<Tensor>) -> anyhow::Result<Vec<Tensor>> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request::Call { name: name.to_string(), inputs, reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("compute service is down"))?;
        reply_rx.recv().map_err(|_| anyhow::anyhow!("compute service dropped the reply"))?
    }

    pub fn call_prefixed(
        &self,
        name: &str,
        key: u64,
        consts: &[Tensor],
        varying: Vec<Tensor>,
    ) -> anyhow::Result<Vec<Tensor>> {
        let cache_key = (name.to_string(), key);
        let consts_opt = {
            let mut reg = self.registered.lock().unwrap();
            if reg.contains(&cache_key) {
                None
            } else {
                reg.insert(cache_key);
                Some(consts.to_vec())
            }
        };
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request::CallPrefixed {
                name: name.to_string(),
                key,
                consts: consts_opt,
                varying,
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("compute service is down"))?;
        reply_rx.recv().map_err(|_| anyhow::anyhow!("compute service dropped the reply"))?
    }
}

impl Exec for ComputeClient {
    fn call(&self, name: &str, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        ComputeClient::call(self, name, inputs.to_vec())
    }

    fn call_prefixed(
        &self,
        name: &str,
        key: u64,
        consts: &[Tensor],
        varying: &[Tensor],
    ) -> anyhow::Result<Vec<Tensor>> {
        ComputeClient::call_prefixed(self, name, key, consts, varying.to_vec())
    }

    fn drop_consts(&self, name: &str, keys: &[u64]) {
        let mut reg = self.registered.lock().unwrap();
        for &k in keys {
            reg.remove(&(name.to_string(), k));
        }
        let _ = self
            .tx
            .send(Request::DropConsts { name: name.to_string(), keys: keys.to_vec() });
    }
}

/// The service: spawn, hand out clients, then `shutdown()` (or drop).
pub struct ComputeService {
    tx: Sender<Request>,
    handle: Option<JoinHandle<()>>,
}

impl ComputeService {
    /// Start the service for the given artifact directory; `warmup` names
    /// are compiled before the first request is accepted.
    pub fn start(artifact_dir: PathBuf, warmup: Vec<String>) -> anyhow::Result<Self> {
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        let handle = std::thread::Builder::new()
            .name("qadmm-compute".into())
            .spawn(move || Self::run(artifact_dir, warmup, rx, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("compute service died during startup"))??;
        Ok(Self { tx, handle: Some(handle) })
    }

    fn run(
        dir: PathBuf,
        warmup: Vec<String>,
        rx: Receiver<Request>,
        ready: Sender<anyhow::Result<()>>,
    ) {
        let runtime = match Runtime::open(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                let _ = ready.send(Err(e));
                return;
            }
        };
        let names: Vec<&str> = warmup.iter().map(String::as_str).collect();
        if let Err(e) = runtime.warmup(&names) {
            let _ = ready.send(Err(e));
            return;
        }
        let _ = ready.send(Ok(()));
        while let Ok(req) = rx.recv() {
            match req {
                Request::Call { name, inputs, reply } => {
                    let _ = reply.send(runtime.call(&name, &inputs));
                }
                Request::CallPrefixed { name, key, consts, varying, reply } => {
                    let _ = reply.send(runtime.call_prefixed(
                        &name,
                        key,
                        consts.as_deref(),
                        &varying,
                    ));
                }
                Request::DropConsts { name, keys } => {
                    runtime.drop_consts(&name, &keys);
                }
                Request::Shutdown => break,
            }
        }
    }

    pub fn client(&self) -> ComputeClient {
        ComputeClient {
            tx: self.tx.clone(),
            registered: std::sync::Arc::new(std::sync::Mutex::new(
                std::collections::HashSet::new(),
            )),
        }
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
