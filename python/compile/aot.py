"""AOT compile path: lower every L2 graph to HLO *text* + a JSON manifest.

Usage: cd python && python -m compile.aot --out ../artifacts

HLO text (NOT `lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()`)
is the interchange format: jax ≥ 0.5 emits protos with 64-bit instruction
ids which the xla crate's runtime (xla_extension 0.5.1) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

The manifest records, for every artifact, the ordered input/output names,
shapes and dtypes — the rust runtime validates its call signatures against
it at load time — plus the flat parameter layouts of the NN architectures
so the coordinator can He-initialize layer-by-layer with its own RNG.
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model, nn  # noqa: E402

# Experiment dimensions (paper §5; see DESIGN.md per-experiment index).
LASSO_M = 200
LASSO_N = 16
CNN_M = nn.CNN_PARAMS
CNN_N = 3
CNN_K = 10           # inner Adam steps per ADMM iteration
CNN_B = 64           # inner batch size
MLP_M = nn.MLP_PARAMS
MLP_N = 4            # nodes used by the threaded e2e driver
MLP_K = 5
MLP_B = 32
EVAL_B = 256         # test-set evaluation batch


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def f64(*shape):
    return spec(shape, jnp.float64)


def f32(*shape):
    return spec(shape, jnp.float32)


def i32(*shape):
    return spec(shape, jnp.int32)


def quantize_entry(delta, noise, s):
    from compile.kernels.quantize import quantize

    return quantize(delta, noise, s)


def soft_threshold_entry(v, kappa):
    from compile.kernels.soft_threshold import soft_threshold

    return (soft_threshold(v, kappa),)


def registry():
    """name → (fn, [(input_name, ShapeDtypeStruct)], [output_name], meta)."""
    arts = {}

    def add(name, fn, inputs, outputs, **meta):
        arts[name] = (fn, inputs, outputs, meta)

    m, n = LASSO_M, LASSO_N
    add(
        "quantize_f64_m200", quantize_entry,
        [("delta", f64(m)), ("noise", f64(m)), ("s", f64())],
        ["values", "levels", "norm"],
    )
    add(
        "quantize_f32_m1024", quantize_entry,
        [("delta", f32(1024)), ("noise", f32(1024)), ("s", f32())],
        ["values", "levels", "norm"],
    )
    add(
        "soft_threshold_f64_m200", soft_threshold_entry,
        [("v", f64(m)), ("kappa", f64())],
        ["out"],
    )
    add(
        "lasso_node_step", model.lasso_node_step,
        [("minv", f64(m, m)), ("atb2", f64(m)), ("zhat", f64(m)),
         ("u", f64(m)), ("xhat", f64(m)), ("uhat", f64(m)),
         ("noise_x", f64(m)), ("noise_u", f64(m)),
         ("rho", f64()), ("s", f64())],
        ["x_new", "u_new", "cx_val", "cx_lvl", "cx_norm",
         "cu_val", "cu_lvl", "cu_norm"],
        m=m,
    )
    # lasso_server_step is retired: the rust server prox runs native-f64 via
    # Problem::consensus_from_sum on every backend, so no runtime path ever
    # dispatched the stacked-bank artifact (re-add as a fused fold+prox
    # kernel if the server step moves on-device).
    add(
        "lasso_lagrangian", model.lasso_lagrangian,
        [("x", f64(n, m)), ("u", f64(n, m)), ("z", f64(m)),
         ("ata", f64(n, m, m)), ("atb2", f64(n, m)), ("btb", f64(n)),
         ("theta", f64()), ("rho", f64())],
        ["lagrangian"],
        m=m, n=n,
    )

    def nn_updates(prefix, mm, kk, bb, img_shape, local_fn, eval_fn):
        add(
            f"{prefix}_local_update", local_fn,
            [("flat", f32(mm)), ("m", f32(mm)), ("v", f32(mm)), ("t", f32()),
             ("u", f32(mm)), ("zhat", f32(mm)), ("xhat", f32(mm)),
             ("uhat", f32(mm)),
             ("bx", f32(kk, bb, *img_shape)), ("by", i32(kk, bb)),
             ("noise_x", f32(mm)), ("noise_u", f32(mm)),
             ("rho", f32()), ("lr", f32()), ("s", f32())],
            ["x_new", "m_new", "v_new", "t_new", "u_new",
             "cx_val", "cx_lvl", "cx_norm", "cu_val", "cu_lvl", "cu_norm",
             "loss"],
            m=mm, k=kk, b=bb,
        )
        add(
            f"{prefix}_eval", eval_fn,
            [("flat", f32(mm)), ("x", f32(EVAL_B, *img_shape)),
             ("y", i32(EVAL_B))],
            ["correct", "loss"],
            m=mm, b=EVAL_B,
        )

    nn_updates("cnn", CNN_M, CNN_K, CNN_B, (28, 28, 1),
               model.cnn_local_update, model.cnn_eval)
    nn_updates("mlp", MLP_M, MLP_K, MLP_B, (784,),
               model.mlp_local_update, model.mlp_eval)

    for prefix, mm, nn_nodes in (("cnn", CNN_M, CNN_N), ("mlp", MLP_M, MLP_N)):
        add(
            f"{prefix}_server_step", model.nn_server_step,
            [("xhat", f32(nn_nodes, mm)), ("uhat", f32(nn_nodes, mm)),
             ("zhat", f32(mm)), ("noise_z", f32(mm)), ("s", f32())],
            ["z_new", "cz_val", "cz_lvl", "cz_norm"],
            m=mm, n=nn_nodes,
        )
    return arts


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def dtype_name(dt) -> str:
    return {"float32": "f32", "float64": "f64", "int32": "i32"}[jnp.dtype(dt).name]


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--out", default="../artifacts")
    parser.add_argument("--only", default=None,
                        help="comma-separated artifact names (for iteration)")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    arts = registry()
    only = set(args.only.split(",")) if args.only else None
    manifest = {"artifacts": {}, "params": {}, "consts": {}}
    for name, (fn, inputs, outputs, meta) in arts.items():
        if only and name not in only:
            continue
        specs = [s for _, s in inputs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [
                {"name": iname, "shape": list(s.shape), "dtype": dtype_name(s.dtype)}
                for iname, s in inputs
            ],
            "outputs": outputs,
            "meta": meta,
        }
        print(f"  lowered {name:28s} -> {fname} ({len(text)} chars)")

    manifest["params"]["cnn"] = nn.cnn_param_specs()
    manifest["params"]["mlp"] = nn.mlp_param_specs()
    manifest["consts"] = {
        "lasso_m": LASSO_M, "lasso_n": LASSO_N,
        "cnn_m": CNN_M, "cnn_n": CNN_N, "cnn_k": CNN_K, "cnn_b": CNN_B,
        "mlp_m": MLP_M, "mlp_n": MLP_N, "mlp_k": MLP_K, "mlp_b": MLP_B,
        "eval_b": EVAL_B,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
