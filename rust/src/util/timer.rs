//! Wall-clock helpers for the bench harness and experiment logs.

use std::time::Instant;

/// Simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_secs();
        self.start = Instant::now();
        e
    }
}

/// Human format: picks ns/µs/ms/s.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.2}s")
    }
}

/// Human format for counts: 1.2K / 3.4M / 5.6G.
pub fn fmt_count(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{x:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(fmt_duration(2.5e-9), "2.5ns");
        assert_eq!(fmt_duration(2.5e-6), "2.50µs");
        assert_eq!(fmt_duration(2.5e-3), "2.50ms");
        assert_eq!(fmt_duration(2.5), "2.50s");
        assert_eq!(fmt_count(999.0), "999");
        assert_eq!(fmt_count(1.5e3), "1.50K");
        assert_eq!(fmt_count(2.5e6), "2.50M");
        assert_eq!(fmt_count(3.5e9), "3.50G");
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::new();
        let a = sw.elapsed_secs();
        let b = sw.elapsed_secs();
        assert!(b >= a && a >= 0.0);
    }
}
