//! Figure-4 regeneration bench (reduced): federated NN training, QADMM vs
//! unquantized baseline, printing test-accuracy milestones + the headline
//! bit reduction, with wall-clock timing. Defaults to the fast MLP variant;
//! set QADMM_FIG4_ARCH=cnn for the paper's 6-layer CNN (M = 246,026).
//!
//! Scale with env: QADMM_FIG4_ITERS / QADMM_FIG4_TRIALS / QADMM_FIG4_TRAIN.

use qadmm::exp::fig4::{run, Fig4Options};
use qadmm::problems::nn::NnArch;
use qadmm::util::timer::Stopwatch;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("(artifacts not built; skipping fig4 bench)");
        return;
    }
    let arch = match std::env::var("QADMM_FIG4_ARCH").as_deref() {
        Ok("cnn") => NnArch::Cnn,
        _ => NnArch::Mlp,
    };
    let opts = Fig4Options {
        arch,
        iters: env_usize("QADMM_FIG4_ITERS", 20),
        mc_trials: env_usize("QADMM_FIG4_TRIALS", 1),
        n_train: env_usize("QADMM_FIG4_TRAIN", 1500),
        n_test: 512,
        target: 0.9,
        out_dir: "out".into(),
        artifact_dir: "artifacts".into(),
        data_dir: "data/mnist".into(),
    };
    let sw = Stopwatch::new();
    let summary = run(&opts).expect("fig4 run");
    for s in &summary.series {
        println!("--- fig4 {} ---", s.label);
        print!("{}", qadmm::exp::milestones(&s.mean_recorder(), |r| r.test_acc));
    }
    for h in &summary.headline {
        println!("{h}");
    }
    println!(
        "fig4 bench: arch={arch:?} {} iters x {} trials x 2 configs in {:.2}s",
        opts.iters,
        opts.mc_trials,
        sw.elapsed_secs()
    );
}
