//! The intermediate-aggregator tier: runtime state of a non-star fan-in.
//!
//! One [`AggregatorTier`] instance lives beside each engine (sequential
//! simulator, event engine, threaded server) and owns, per aggregator g:
//!
//! * `pending_g` — the Kahan-compensated sum of child deltas received
//!   since the last upstream forward, *plus* the re-quantization residual
//!   of previous forwards (error feedback per hop). A child arrival folds
//!   its wire frame straight in — O(k) for sparse compressors, O(m)
//!   dense — without materializing a dequantized vector.
//! * `ŝ_g` — the server-side estimate of g's forwarded partial sum, the
//!   exact analogue of the star's per-node estimate banks: it advances
//!   only by dequantized forwarded deltas, so the server's periodic
//!   consensus refresh rebuilds s = Σ_g ŝ_g in O(A·m) instead of the
//!   star's O(n·m) — refreshing from the *leaf* banks would teleport
//!   information past the aggregator hop without paying its wire bits.
//!
//! Determinism contract: at zero link delay the event engine delivers and
//! flushes in ascending id order within each virtual instant — the same
//! order the sequential simulator uses — so tree/gossip runs are bit-exact
//! across the two in-process engines, and the degenerate tree (fanout 1,
//! identity compressor) reproduces the star bit-for-bit: a single child
//! delta folded into a zeroed Kahan buffer is exact, the identity forward
//! carries it unchanged, and `ŝ_g` then replays the leaf bank's commits.

use super::TopologyKind;
use crate::compress::{Compressed, Compressor};
use crate::problems::accumulator::KahanVec;
use crate::problems::Arena;
use crate::snapshot::codec::{Pack, Reader, Writer};
use crate::util::rng::Pcg64;

/// One re-quantized partial-sum forward in flight toward the server.
pub struct AggForward {
    /// Compressed Δ of the aggregator's x-partial (what the server folds).
    pub cx: Compressed,
    /// Compressed Δ of the aggregator's u-partial.
    pub cu: Compressed,
    /// The leaves folded into this forward, with the local loss each one
    /// reported (control plane: arrival credit for the server's scheduler).
    pub children: Vec<(usize, f64)>,
}

pub struct AggregatorTier {
    kind: TopologyKind,
    n_aggs: usize,
    /// Per-tier arrival threshold P_g: forward once this many children are
    /// pending (or earlier, when no further child update is in flight).
    p_tier: usize,
    /// Error feedback on: keep the re-quantization residual in the pending
    /// buffer; off: drop it (pure delta coding across the hop).
    error_feedback: bool,
    pending_x: Vec<KahanVec>,
    pending_u: Vec<KahanVec>,
    children: Vec<Vec<(usize, f64)>>,
    /// Child updates routed to each aggregator but not yet delivered
    /// (computing or on the leaf-hop wire).
    in_transit: Vec<usize>,
    /// The aggregator each leaf's in-flight update was routed to.
    assigned: Vec<Option<usize>>,
    /// Server-side estimates of each aggregator's forwarded partial sums
    /// (plain adds, mirroring `EstimateTracker::commit`).
    sx: Arena,
    su: Arena,
    forwards: u64,
}

impl AggregatorTier {
    /// `None` for the star (no tier: engines keep their original fan-in).
    pub fn new(
        kind: TopologyKind,
        n_leaves: usize,
        m: usize,
        p_tier: usize,
        error_feedback: bool,
    ) -> Option<Self> {
        let n_aggs = kind.n_aggregators(n_leaves);
        if n_aggs == 0 {
            return None;
        }
        Some(Self {
            kind,
            n_aggs,
            p_tier: p_tier.max(1),
            error_feedback,
            pending_x: (0..n_aggs).map(|_| KahanVec::zeros(m)).collect(),
            pending_u: (0..n_aggs).map(|_| KahanVec::zeros(m)).collect(),
            children: vec![Vec::new(); n_aggs],
            in_transit: vec![0; n_aggs],
            assigned: vec![None; n_leaves],
            sx: Arena::zeros(n_aggs, m),
            su: Arena::zeros(n_aggs, m),
            forwards: 0,
        })
    }

    pub fn n_aggregators(&self) -> usize {
        self.n_aggs
    }

    /// The deterministic init-exchange parent (see
    /// [`TopologyKind::static_parent`]).
    pub fn static_parent(&self, leaf: usize) -> usize {
        self.kind.static_parent(leaf)
    }

    /// Upstream forwards performed so far (wire-bits property tests).
    pub fn forwards(&self) -> u64 {
        self.forwards
    }

    /// Seed ŝ_g with a leaf's full-precision init state (Algorithm 1
    /// lines 1–4 aggregated at the static parent). Plain adds, so the
    /// degenerate tree's ŝ banks start exactly like the star's leaf banks.
    pub fn seed_partial(&mut self, agg: usize, x0: &[f64], u0: &[f64]) {
        for (s, v) in self.sx.row_mut(agg).iter_mut().zip(x0) {
            *s += v;
        }
        for (s, v) in self.su.row_mut(agg).iter_mut().zip(u0) {
            *s += v;
        }
    }

    /// Route a freshly dispatched leaf update to its aggregator. Tree
    /// routing is static and draws nothing; gossip draws one relay index
    /// from the dedicated topology stream per dispatch.
    pub fn route(&mut self, leaf: usize, rng: &mut Pcg64) -> usize {
        let agg = match self.kind {
            TopologyKind::Star => unreachable!("star has no aggregator tier"),
            TopologyKind::Tree { fanout } => leaf / fanout,
            TopologyKind::Gossip { .. } => rng.gen_range(self.n_aggs),
        };
        debug_assert!(self.assigned[leaf].is_none(), "leaf {leaf} already in flight");
        self.assigned[leaf] = Some(agg);
        self.in_transit[agg] += 1;
        agg
    }

    /// A child's compressed deltas landed at its aggregator: fold the wire
    /// frames into the pending partial sum (O(k) sparse, O(m) dense) and
    /// record the arrival credit. Returns the aggregator id (the caller's
    /// "touched" set).
    pub fn deliver(
        &mut self,
        leaf: usize,
        cx: &Compressed,
        cu: &Compressed,
        loss: f64,
    ) -> anyhow::Result<usize> {
        let agg = self.assigned[leaf].take().expect("delivery without a routed update");
        self.in_transit[agg] -= 1;
        cx.fold_into(&mut self.pending_x[agg])?;
        cu.fold_into(&mut self.pending_u[agg])?;
        self.children[agg].push((leaf, loss));
        Ok(agg)
    }

    /// Forward condition: ≥ P_g children pending, or nothing further in
    /// flight toward this aggregator (so a partial batch never wedges the
    /// server's P/τ trigger).
    pub fn ready(&self, agg: usize) -> bool {
        !self.children[agg].is_empty()
            && (self.children[agg].len() >= self.p_tier || self.in_transit[agg] == 0)
    }

    pub fn has_pending(&self, agg: usize) -> bool {
        !self.children[agg].is_empty()
    }

    /// ‖pending_g‖∞ across both halves — what an upstream forward *would*
    /// move the server's banks by. The event trigger's aggregator dead-band
    /// gates on this: below δ the forward is withheld (see
    /// [`Self::credit_only_flush`]). Non-finite pending mass reports +∞,
    /// forcing the forward out of the dead-band.
    pub fn pending_inf_norm(&self, agg: usize) -> f64 {
        crate::admm::trigger::inf_norm(self.pending_x[agg].value())
            .max(crate::admm::trigger::inf_norm(self.pending_u[agg].value()))
    }

    /// The dead-band analogue of [`Self::flush`]: the aggregator reports
    /// "children arrived, nothing worth forwarding". The children's arrival
    /// credits are handed back (they must reach the server's P/τ trigger —
    /// a silent aggregator may never wedge liveness), but the pending Kahan
    /// mass stays put to keep accumulating (so `tracked_mass` is conserved),
    /// no compressor runs, no RNG is drawn, and `forwards` does not advance
    /// (zero wire bits: the caller charges nothing).
    pub fn credit_only_flush(&mut self, agg: usize) -> Vec<(usize, f64)> {
        debug_assert!(self.has_pending(agg), "credit-only flush of an empty aggregator");
        std::mem::take(&mut self.children[agg])
    }

    /// Re-quantize the pending partial delta for the upstream hop: compress
    /// both halves with the aggregator's quantizer stream, retain the
    /// compression residual in the pending buffer (error feedback) or drop
    /// it (EF-off ablation), and hand back the forward payload. The caller
    /// charges the wire bits to link n + agg and delivers the payload
    /// upstream (instantly in the simulator, after the aggregator's uplink
    /// leg in the event engine).
    pub fn flush(
        &mut self,
        agg: usize,
        compressor: &dyn Compressor,
        rng: &mut Pcg64,
    ) -> AggForward {
        debug_assert!(self.has_pending(agg), "flush of an empty aggregator");
        let cx = compressor.compress(self.pending_x[agg].value(), rng);
        let cu = compressor.compress(self.pending_u[agg].value(), rng);
        if self.error_feedback {
            // the frames were just encoded by the compressor, so decoding
            // them cannot fail — the residual is pending − decode(wire)
            cx.sub_from(&mut self.pending_x[agg]).expect("just-encoded frame must decode");
            cu.sub_from(&mut self.pending_u[agg]).expect("just-encoded frame must decode");
        } else {
            self.pending_x[agg].reset();
            self.pending_u[agg].reset();
        }
        self.forwards += 1;
        AggForward { cx, cu, children: std::mem::take(&mut self.children[agg]) }
    }

    /// Server side of a forward's arrival: ŝ_g += C(Δpartial), consumed
    /// straight from the wire frames. The caller folds the same frames into
    /// its global [`crate::problems::accumulator::ConsensusAccumulator`] so
    /// s keeps tracking Σ_g ŝ_g. Like `EstimateTracker::commit_frame`, a
    /// sparse frame leaves unvisited coordinates untouched (plain `+= 0.0`
    /// would only have normalized a stray −0.0 anyway, and every runtime
    /// switched to frame commits together).
    pub fn commit(&mut self, agg: usize, cx: &Compressed, cu: &Compressed) -> anyhow::Result<()> {
        let row = self.sx.row_mut(agg);
        cx.for_each_entry(|j, d| row[j] += d)?;
        let row = self.su.row_mut(agg);
        cu.for_each_entry(|j, d| row[j] += d)?;
        Ok(())
    }

    /// (ŝx_g, ŝu_g) rows for the consensus refresh — O(A·m) total.
    pub fn refresh_rows(&self) -> impl Iterator<Item = (&[f64], &[f64])> {
        self.sx.rows().zip(self.su.rows())
    }

    /// Σ_g(ŝ_g + pending_g) per coordinate: everything that ever arrived
    /// anywhere in the tier. The conservation property tests pin this
    /// against Σ_leaves(x̂ᵢ + ûᵢ).
    pub fn tracked_mass(&self) -> Vec<f64> {
        let m = self.sx.dim();
        let mut total = KahanVec::zeros(m);
        for g in 0..self.n_aggs {
            total.add(self.sx.row(g));
            total.add(self.su.row(g));
            total.add(self.pending_x[g].value());
            total.add(self.pending_u[g].value());
        }
        total.value().to_vec()
    }
}

impl AggregatorTier {
    /// The topology this tier realizes (snapshot/resume validation).
    pub fn kind(&self) -> TopologyKind {
        self.kind
    }

    /// Per-tier threshold (snapshot/resume validation).
    pub fn p_tier(&self) -> usize {
        self.p_tier
    }

    /// Whether the re-quantization residual is retained per hop.
    pub fn error_feedback(&self) -> bool {
        self.error_feedback
    }
}

impl Pack for AggForward {
    fn pack(&self, w: &mut Writer) {
        self.cx.pack(w);
        self.cu.pack(w);
        self.children.pack(w);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        Ok(Self {
            cx: Compressed::unpack(r)?,
            cu: Compressed::unpack(r)?,
            children: Vec::<(usize, f64)>::unpack(r)?,
        })
    }
}

/// A tier snapshot is self-contained: topology, thresholds, every pending
/// Kahan partial (sum *and* compensation — the per-hop error-feedback
/// residual lives there), the routed/in-transit bookkeeping, and the
/// server-side ŝ_g estimate banks.
impl Pack for AggregatorTier {
    fn pack(&self, w: &mut Writer) {
        self.kind.label().pack(w);
        w.put_usize(self.n_aggs);
        w.put_usize(self.p_tier);
        w.put_bool(self.error_feedback);
        self.pending_x.pack(w);
        self.pending_u.pack(w);
        self.children.pack(w);
        self.in_transit.pack(w);
        self.assigned.pack(w);
        self.sx.pack(w);
        self.su.pack(w);
        w.put_u64(self.forwards);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let kind = TopologyKind::parse(&String::unpack(r)?)?;
        let n_aggs = r.get_usize()?;
        let p_tier = r.get_usize()?;
        let error_feedback = r.get_bool()?;
        let pending_x = Vec::<KahanVec>::unpack(r)?;
        let pending_u = Vec::<KahanVec>::unpack(r)?;
        let children = Vec::<Vec<(usize, f64)>>::unpack(r)?;
        let in_transit = Vec::<usize>::unpack(r)?;
        let assigned = Vec::<Option<usize>>::unpack(r)?;
        let sx = Arena::unpack(r)?;
        let su = Arena::unpack(r)?;
        let forwards = r.get_u64()?;
        anyhow::ensure!(n_aggs >= 1, "snapshot tier: zero aggregators");
        anyhow::ensure!(p_tier >= 1, "snapshot tier: p_tier must be >= 1");
        anyhow::ensure!(
            kind.n_aggregators(assigned.len()) == n_aggs,
            "snapshot tier: {} aggregators inconsistent with {} leaves under {}",
            n_aggs,
            assigned.len(),
            kind.label()
        );
        for v in [pending_x.len(), pending_u.len(), children.len(), in_transit.len()] {
            anyhow::ensure!(v == n_aggs, "snapshot tier: per-aggregator table length mismatch");
        }
        anyhow::ensure!(
            sx.n_rows() == n_aggs && su.n_rows() == n_aggs && sx.dim() == su.dim(),
            "snapshot tier: partial-sum bank shape mismatch"
        );
        for k in pending_x.iter().chain(&pending_u) {
            anyhow::ensure!(
                k.dim() == sx.dim(),
                "snapshot tier: pending buffer width {} != bank width {}",
                k.dim(),
                sx.dim()
            );
        }
        for (leaf, a) in assigned.iter().enumerate() {
            if let Some(g) = a {
                anyhow::ensure!(*g < n_aggs, "snapshot tier: leaf {leaf} routed out of range");
            }
        }
        for group in &children {
            for (leaf, _) in group {
                anyhow::ensure!(
                    *leaf < assigned.len(),
                    "snapshot tier: pending child {leaf} out of range"
                );
            }
        }
        Ok(Self {
            kind,
            n_aggs,
            p_tier,
            error_feedback,
            pending_x,
            pending_u,
            children,
            in_transit,
            assigned,
            sx,
            su,
            forwards,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CompressorKind;

    fn tier(kind: TopologyKind, n: usize, m: usize, p_tier: usize) -> AggregatorTier {
        AggregatorTier::new(kind, n, m, p_tier, true).expect("non-star tier")
    }

    /// A raw dense64 frame — bypasses the compressors (and their input
    /// sanitization), so tests can also put non-finite values on the wire.
    fn frame(v: &[f64]) -> Compressed {
        Compressed { wire: crate::compress::wire::encode_dense64(v) }
    }

    #[test]
    fn star_has_no_tier() {
        assert!(AggregatorTier::new(TopologyKind::Star, 8, 4, 1, true).is_none());
    }

    #[test]
    fn tree_routes_statically_and_batches_to_p_tier() {
        let mut rng = Pcg64::seed_from_u64(1);
        let mut t = tier(TopologyKind::Tree { fanout: 2 }, 4, 3, 2);
        assert_eq!(t.route(0, &mut rng), 0);
        assert_eq!(t.route(1, &mut rng), 0);
        assert_eq!(t.route(2, &mut rng), 1);
        // first child lands; sibling still in transit and P_g = 2 → wait
        let agg = t.deliver(0, &frame(&[1.0, 0.0, 0.0]), &frame(&[0.0; 3]), 0.5).unwrap();
        assert_eq!(agg, 0);
        assert!(!t.ready(0));
        // second child completes the batch
        t.deliver(1, &frame(&[0.0, 2.0, 0.0]), &frame(&[0.0; 3]), 0.25).unwrap();
        assert!(t.ready(0));
        // aggregator 1: one pending child, none in transit — must flush
        // even though the P_g = 2 batch is incomplete
        t.deliver(2, &frame(&[0.0, 0.0, 4.0]), &frame(&[0.0; 3]), 0.0).unwrap();
        assert!(t.ready(1), "no sibling in flight: partial batch must flush");

        let comp = CompressorKind::Identity.build();
        let fw = t.flush(0, comp.as_ref(), &mut rng);
        assert_eq!(fw.cx.dequantized().unwrap(), vec![1.0, 2.0, 0.0]);
        assert_eq!(fw.children, vec![(0, 0.5), (1, 0.25)]);
        assert!(!t.has_pending(0));
        // identity compression leaves no residual
        assert!(t.pending_x[0].value().iter().all(|&v| v == 0.0));
        t.commit(0, &fw.cx, &fw.cu).unwrap();
        assert_eq!(t.sx.row(0), &[1.0, 2.0, 0.0]);
        assert_eq!(t.forwards(), 1);
    }

    #[test]
    fn gossip_routes_within_bounds_and_conserves_mass() {
        let mut rng = Pcg64::seed_from_u64(7);
        let (n, m, k) = (12usize, 5usize, 3usize);
        let mut t = tier(TopologyKind::Gossip { k }, n, m, 1);
        let comp = CompressorKind::Qsgd { bits: 3 }.build();
        let mut true_mass = vec![0.0f64; m];
        for round in 0..20 {
            for leaf in 0..n {
                let agg = t.route(leaf, &mut rng);
                assert!(agg < k);
                let dx = rng.normal_vec(m, 0.0, 1.0);
                let du = rng.normal_vec(m, 0.0, 0.5);
                for j in 0..m {
                    true_mass[j] += dx[j] + du[j];
                }
                let agg = t.deliver(leaf, &frame(&dx), &frame(&du), 0.0).unwrap();
                if t.ready(agg) && round % 2 == 0 {
                    // leave some rounds pending: mass must be conserved
                    // whether or not a forward happened
                    let fw = t.flush(agg, comp.as_ref(), &mut rng);
                    t.commit(agg, &fw.cx, &fw.cu).unwrap();
                }
            }
        }
        let tracked = t.tracked_mass();
        let norm = true_mass.iter().fold(1.0f64, |a, v| a.max(v.abs()));
        for (a, b) in tracked.iter().zip(&true_mass) {
            assert!((a - b).abs() <= 1e-10 * norm, "tracked {a} vs true {b}");
        }
    }

    /// A dead-banded forward surrenders the arrival credits but keeps the
    /// pending mass accumulating — conservation must hold across it, and no
    /// wire-side state (forwards counter, ŝ banks) may move.
    #[test]
    fn credit_only_flush_retains_mass_and_returns_credits() {
        let mut rng = Pcg64::seed_from_u64(11);
        let mut t = tier(TopologyKind::Tree { fanout: 2 }, 4, 3, 1);
        t.route(0, &mut rng);
        t.deliver(0, &frame(&[1e-9, 0.0, 0.0]), &frame(&[0.0; 3]), 0.5).unwrap();
        assert!(t.ready(0));
        assert!(t.pending_inf_norm(0) <= 1e-6);
        let before = t.tracked_mass();
        let credits = t.credit_only_flush(0);
        assert_eq!(credits, vec![(0, 0.5)]);
        assert!(!t.has_pending(0));
        assert_eq!(t.forwards(), 0);
        assert_eq!(t.tracked_mass(), before);
        // the withheld mass rides along with the next real delivery
        t.route(1, &mut rng);
        t.deliver(1, &frame(&[0.5, 0.0, 0.0]), &frame(&[0.0; 3]), 0.0).unwrap();
        assert!((t.pending_inf_norm(0) - (0.5 + 1e-9)).abs() < 1e-15);
        // non-finite pending mass must report +∞ (never dead-banded).
        // `frame` writes raw dense64, so the NaN actually reaches the fold
        // (the compressors would have sanitized it away).
        t.route(3, &mut rng);
        t.deliver(3, &frame(&[f64::NAN, 0.0, 0.0]), &frame(&[0.0; 3]), 0.0).unwrap();
        assert_eq!(t.pending_inf_norm(1), f64::INFINITY);
    }

    /// EF keeps the residual; EF-off drops it (the §4.1 ablation per hop).
    #[test]
    fn error_feedback_toggles_residual() {
        let mut rng = Pcg64::seed_from_u64(3);
        let comp = CompressorKind::Qsgd { bits: 2 }.build();
        let delta = rng.normal_vec(8, 0.0, 1.0);
        for (ef, residual_expected) in [(true, true), (false, false)] {
            let mut t = AggregatorTier::new(TopologyKind::Tree { fanout: 4 }, 4, 8, 1, ef)
                .unwrap();
            let mut r = Pcg64::seed_from_u64(9);
            t.route(0, &mut r);
            t.deliver(0, &frame(&delta), &frame(&delta), 0.0).unwrap();
            let _ = t.flush(0, comp.as_ref(), &mut r);
            let has_residual = t.pending_x[0].value().iter().any(|&v| v != 0.0);
            assert_eq!(has_residual, residual_expected, "ef={ef}");
        }
    }

    /// The degenerate one-child tree with identity compression forwards the
    /// child's deltas bit-for-bit and replays them into ŝ_g exactly — the
    /// unit-level half of the star parity contract.
    #[test]
    fn degenerate_tree_identity_forward_is_exact() {
        let mut rng = Pcg64::seed_from_u64(5);
        let comp = CompressorKind::Identity.build();
        let mut t = tier(TopologyKind::Tree { fanout: 1 }, 3, 6, 1);
        let mut bank = vec![0.0f64; 6];
        for _ in 0..50 {
            let dx = rng.normal_vec(6, 0.0, 1.0);
            let du = rng.normal_vec(6, 0.0, 0.1);
            t.route(1, &mut rng);
            t.deliver(1, &frame(&dx), &frame(&du), 0.0).unwrap();
            assert!(t.ready(1));
            let fw = t.flush(1, comp.as_ref(), &mut rng);
            assert_eq!(
                fw.cx.dequantized().unwrap(),
                dx,
                "forward must carry the child delta exactly"
            );
            assert_eq!(fw.cu.dequantized().unwrap(), du);
            t.commit(1, &fw.cx, &fw.cu).unwrap();
            for (b, d) in bank.iter_mut().zip(&dx) {
                *b += d;
            }
        }
        // ŝ_g replayed the same adds in the same order as a leaf bank would
        assert_eq!(t.sx.row(1), bank.as_slice());
    }
}
