//! MNIST data substrate.
//!
//! Two sources behind one interface:
//! * **IDX parser** — if the real MNIST files exist under `data/mnist/`
//!   (`train-images-idx3-ubyte` etc.), they are used.
//! * **Synthetic MNIST** — the offline substitution (DESIGN.md §3): each
//!   digit class is a fixed stroke template (polylines in the unit square)
//!   rasterized at 28×28 with a Gaussian pen, then randomly translated,
//!   rotated, scaled, and pixel-noised. Class-consistent, learnable, and
//!   exercises the identical federated-training code path (same CNN, same
//!   M, same wire traffic).

use crate::util::rng::Pcg64;

pub const IMG_SIDE: usize = 28;
pub const IMG_PIXELS: usize = IMG_SIDE * IMG_SIDE;
pub const N_CLASSES: usize = 10;

/// A labeled image set, pixels in [0,1], row-major 28×28.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub images: Vec<f32>, // len = n · 784
    pub labels: Vec<i32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * IMG_PIXELS..(i + 1) * IMG_PIXELS]
    }

    /// Split into `n` near-equal shards (random assignment, like the paper's
    /// random partition of the 60k training examples).
    pub fn split(&self, n: usize, rng: &mut Pcg64) -> Vec<Dataset> {
        let mut order: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut order);
        let mut shards = vec![Dataset::default(); n];
        for (pos, &idx) in order.iter().enumerate() {
            let s = &mut shards[pos % n];
            s.images.extend_from_slice(self.image(idx));
            s.labels.push(self.labels[idx]);
        }
        shards
    }
}

// --------------------------------------------------------------------------
// Synthetic generator
// --------------------------------------------------------------------------

/// Stroke templates per class: polylines in [0,1]².
fn class_strokes(digit: usize) -> Vec<Vec<(f64, f64)>> {
    let circle = |cx: f64, cy: f64, rx: f64, ry: f64| -> Vec<(f64, f64)> {
        (0..=16)
            .map(|k| {
                let t = k as f64 / 16.0 * std::f64::consts::TAU;
                (cx + rx * t.cos(), cy + ry * t.sin())
            })
            .collect()
    };
    match digit {
        0 => vec![circle(0.5, 0.5, 0.22, 0.3)],
        1 => vec![vec![(0.38, 0.3), (0.52, 0.16), (0.52, 0.84)]],
        2 => vec![vec![
            (0.3, 0.3),
            (0.38, 0.18),
            (0.6, 0.16),
            (0.7, 0.3),
            (0.62, 0.45),
            (0.35, 0.72),
            (0.3, 0.82),
            (0.72, 0.82),
        ]],
        3 => vec![vec![
            (0.32, 0.2),
            (0.55, 0.15),
            (0.68, 0.28),
            (0.52, 0.45),
            (0.68, 0.62),
            (0.55, 0.82),
            (0.3, 0.78),
        ]],
        4 => vec![
            vec![(0.62, 0.15), (0.3, 0.58), (0.75, 0.58)],
            vec![(0.62, 0.35), (0.62, 0.85)],
        ],
        5 => vec![vec![
            (0.7, 0.17),
            (0.36, 0.17),
            (0.33, 0.45),
            (0.55, 0.42),
            (0.7, 0.55),
            (0.66, 0.74),
            (0.42, 0.83),
            (0.3, 0.73),
        ]],
        6 => {
            let mut bottom = circle(0.5, 0.62, 0.18, 0.2);
            bottom.truncate(17);
            vec![vec![(0.62, 0.14), (0.42, 0.38), (0.34, 0.6)], bottom]
        }
        7 => vec![vec![(0.28, 0.18), (0.72, 0.18), (0.46, 0.84)]],
        8 => vec![circle(0.5, 0.32, 0.16, 0.15), circle(0.5, 0.66, 0.19, 0.18)],
        9 => {
            vec![circle(0.52, 0.34, 0.17, 0.17), vec![(0.69, 0.34), (0.66, 0.6), (0.56, 0.84)]]
        }
        _ => panic!("digit out of range"),
    }
}

fn dist_to_segment(px: f64, py: f64, (x1, y1): (f64, f64), (x2, y2): (f64, f64)) -> f64 {
    let (dx, dy) = (x2 - x1, y2 - y1);
    let len2 = dx * dx + dy * dy;
    let t = if len2 == 0.0 {
        0.0
    } else {
        (((px - x1) * dx + (py - y1) * dy) / len2).clamp(0.0, 1.0)
    };
    let (cx, cy) = (x1 + t * dx, y1 + t * dy);
    ((px - cx) * (px - cx) + (py - cy) * (py - cy)).sqrt()
}

/// Render one digit with random affine jitter + pixel noise.
pub fn render_digit(digit: usize, rng: &mut Pcg64) -> Vec<f32> {
    let strokes = class_strokes(digit);
    // affine jitter: rotation, scale, translation
    let ang = (rng.uniform_f64() - 0.5) * 0.3; // ±0.15 rad
    let scale = 0.9 + 0.2 * rng.uniform_f64();
    let (tx, ty) = ((rng.uniform_f64() - 0.5) * 0.12, (rng.uniform_f64() - 0.5) * 0.12);
    let (ca, sa) = (ang.cos(), ang.sin());
    let xform = |(x, y): (f64, f64)| -> (f64, f64) {
        let (cx, cy) = (x - 0.5, y - 0.5);
        let (rx, ry) = (ca * cx - sa * cy, sa * cx + ca * cy);
        (0.5 + scale * rx + tx, 0.5 + scale * ry + ty)
    };
    let strokes: Vec<Vec<(f64, f64)>> =
        strokes.iter().map(|s| s.iter().map(|&p| xform(p)).collect()).collect();

    let sigma = 0.028 + 0.008 * rng.uniform_f64(); // pen width jitter
    let mut img = vec![0.0f32; IMG_PIXELS];
    for py in 0..IMG_SIDE {
        for px in 0..IMG_SIDE {
            let (fx, fy) =
                ((px as f64 + 0.5) / IMG_SIDE as f64, (py as f64 + 0.5) / IMG_SIDE as f64);
            let mut best = f64::INFINITY;
            for stroke in &strokes {
                for w in stroke.windows(2) {
                    best = best.min(dist_to_segment(fx, fy, w[0], w[1]));
                }
            }
            let v = (-0.5 * (best / sigma) * (best / sigma)).exp();
            img[py * IMG_SIDE + px] = v as f32;
        }
    }
    // intensity jitter + additive noise, clamp to [0,1]
    let gain = 0.85 + 0.3 * rng.uniform_f64();
    for v in &mut img {
        let noisy = *v as f64 * gain + 0.05 * rng.standard_normal();
        *v = noisy.clamp(0.0, 1.0) as f32;
    }
    img
}

/// Generate a balanced synthetic dataset of `n` examples.
pub fn synthetic(n: usize, rng: &mut Pcg64) -> Dataset {
    let mut ds = Dataset::default();
    ds.images.reserve(n * IMG_PIXELS);
    ds.labels.reserve(n);
    for i in 0..n {
        let digit = i % N_CLASSES;
        ds.images.extend_from_slice(&render_digit(digit, rng));
        ds.labels.push(digit as i32);
    }
    // shuffle example order (labels + images together)
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut out = Dataset::default();
    out.images.reserve(n * IMG_PIXELS);
    out.labels.reserve(n);
    for &i in &order {
        out.images.extend_from_slice(ds.image(i));
        out.labels.push(ds.labels[i]);
    }
    out
}

// --------------------------------------------------------------------------
// IDX parser (real MNIST, if present)
// --------------------------------------------------------------------------

fn read_u32_be(b: &[u8], off: usize) -> u32 {
    u32::from_be_bytes(b[off..off + 4].try_into().unwrap())
}

/// Parse an IDX3 image file + IDX1 label file into a Dataset.
pub fn parse_idx(images: &[u8], labels: &[u8]) -> anyhow::Result<Dataset> {
    anyhow::ensure!(images.len() >= 16 && read_u32_be(images, 0) == 0x0803, "bad image magic");
    anyhow::ensure!(labels.len() >= 8 && read_u32_be(labels, 0) == 0x0801, "bad label magic");
    let n = read_u32_be(images, 4) as usize;
    anyhow::ensure!(read_u32_be(labels, 4) as usize == n, "image/label count mismatch");
    let rows = read_u32_be(images, 8) as usize;
    let cols = read_u32_be(images, 12) as usize;
    anyhow::ensure!(rows == IMG_SIDE && cols == IMG_SIDE, "expected 28x28");
    anyhow::ensure!(images.len() == 16 + n * IMG_PIXELS, "truncated image file");
    anyhow::ensure!(labels.len() == 8 + n, "truncated label file");
    let mut ds = Dataset::default();
    ds.images = images[16..].iter().map(|&b| b as f32 / 255.0).collect();
    ds.labels = labels[8..].iter().map(|&b| b as i32).collect();
    Ok(ds)
}

/// Load real MNIST from `dir` if present; otherwise synthesize
/// (`n_train`, `n_test`) examples from `seed`.
pub fn load_or_synthesize(
    dir: &std::path::Path,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> anyhow::Result<(Dataset, Dataset, &'static str)> {
    let train_images = dir.join("train-images-idx3-ubyte");
    if train_images.exists() {
        let train = parse_idx(
            &std::fs::read(&train_images)?,
            &std::fs::read(dir.join("train-labels-idx1-ubyte"))?,
        )?;
        let test = parse_idx(
            &std::fs::read(dir.join("t10k-images-idx3-ubyte"))?,
            &std::fs::read(dir.join("t10k-labels-idx1-ubyte"))?,
        )?;
        return Ok((train, test, "mnist-idx"));
    }
    let mut rng = Pcg64::seed_from_u64(seed ^ 0x6d6e_6973_7421);
    let train = synthetic(n_train, &mut rng);
    let test = synthetic(n_test, &mut rng);
    Ok((train, test, "synthetic"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_classes_in_range() {
        let mut rng = Pcg64::seed_from_u64(1);
        for d in 0..N_CLASSES {
            let img = render_digit(d, &mut rng);
            assert_eq!(img.len(), IMG_PIXELS);
            assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
            // the pen must actually draw something
            let mass: f32 = img.iter().sum();
            assert!(mass > 10.0, "digit {d} too faint: {mass}");
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // mean intra-class L2 distance should be smaller than inter-class
        let mut rng = Pcg64::seed_from_u64(2);
        let per = 8;
        let mut imgs: Vec<Vec<Vec<f32>>> = Vec::new();
        for d in 0..N_CLASSES {
            imgs.push((0..per).map(|_| render_digit(d, &mut rng)).collect());
        }
        let d2 = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum()
        };
        let mut intra = 0.0;
        let mut intra_n = 0;
        let mut inter = 0.0;
        let mut inter_n = 0;
        for c1 in 0..N_CLASSES {
            for i in 0..per {
                for j in i + 1..per {
                    intra += d2(&imgs[c1][i], &imgs[c1][j]);
                    intra_n += 1;
                }
                let c2 = (c1 + 1) % N_CLASSES;
                inter += d2(&imgs[c1][i], &imgs[c2][i]);
                inter_n += 1;
            }
        }
        let (intra, inter) = (intra / intra_n as f64, inter / inter_n as f64);
        assert!(inter > 1.5 * intra, "inter={inter} intra={intra}");
    }

    #[test]
    fn synthetic_is_balanced_and_deterministic() {
        let mut rng = Pcg64::seed_from_u64(3);
        let ds = synthetic(100, &mut rng);
        assert_eq!(ds.len(), 100);
        for c in 0..N_CLASSES {
            assert_eq!(ds.labels.iter().filter(|&&l| l == c as i32).count(), 10);
        }
        let mut rng2 = Pcg64::seed_from_u64(3);
        let ds2 = synthetic(100, &mut rng2);
        assert_eq!(ds.images, ds2.images);
        assert_eq!(ds.labels, ds2.labels);
    }

    #[test]
    fn split_partitions_everything() {
        let mut rng = Pcg64::seed_from_u64(4);
        let ds = synthetic(50, &mut rng);
        let shards = ds.split(3, &mut rng);
        assert_eq!(shards.iter().map(Dataset::len).sum::<usize>(), 50);
        assert!(shards.iter().all(|s| s.len() >= 16));
    }

    #[test]
    fn idx_parser_roundtrip() {
        // build a tiny fake IDX pair
        let n = 3;
        let mut images = Vec::new();
        images.extend_from_slice(&0x0803u32.to_be_bytes());
        images.extend_from_slice(&(n as u32).to_be_bytes());
        images.extend_from_slice(&28u32.to_be_bytes());
        images.extend_from_slice(&28u32.to_be_bytes());
        images.extend(std::iter::repeat_n(128u8, n * IMG_PIXELS));
        let mut labels = Vec::new();
        labels.extend_from_slice(&0x0801u32.to_be_bytes());
        labels.extend_from_slice(&(n as u32).to_be_bytes());
        labels.extend_from_slice(&[7u8, 0, 9]);
        let ds = parse_idx(&images, &labels).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.labels, vec![7, 0, 9]);
        assert!((ds.image(0)[0] - 128.0 / 255.0).abs() < 1e-6);
        // corrupt magic fails
        images[0] = 9;
        assert!(parse_idx(&images, &labels).is_err());
    }
}
