//! End-to-end integration over the ADMM core: convergence, invariants of
//! the estimate banks, exact bit accounting, EF ablation behaviour, the
//! threaded coordinator (including failure injection), and sequential-vs-
//! threaded agreement in quality.

use qadmm::admm::runner::{self, ProblemFactory};
use qadmm::admm::sim::{AsyncSim, TrialRngs};
use qadmm::comm::network::FaultSpec;
use qadmm::compress::CompressorKind;
use qadmm::config::{presets, ExperimentConfig, ProblemKind};
use qadmm::problems::lasso::{LassoConfig, LassoProblem};
use qadmm::problems::Problem;
use qadmm::util::rng::Pcg64;

fn lasso_factory(cfg: LassoConfig) -> Box<ProblemFactory<'static>> {
    Box::new(move |_seed, data_rng: &mut Pcg64| {
        Ok(Box::new(LassoProblem::generate(cfg, data_rng)?) as Box<dyn Problem>)
    })
}

fn ci_cfg() -> (ExperimentConfig, LassoConfig) {
    let cfg = presets::ci_lasso();
    let l = match cfg.problem {
        ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
        _ => unreachable!(),
    };
    (cfg, l)
}

/// The server's estimate x̂ᵢ must stay within one quantization interval of
/// the node's true xᵢ for every *updated* node — the error-feedback
/// telescoping identity, live inside the full algorithm.
#[test]
fn estimate_banks_track_true_iterates() {
    let (mut cfg, l) = ci_cfg();
    cfg.iters = 60;
    let mut rngs = TrialRngs::new(99);
    let mut problem = LassoProblem::generate(l, &mut rngs.data).unwrap();
    let mut sim = AsyncSim::new(&cfg, &mut problem, rngs).unwrap();
    let s = 3.0; // q = 3
    for _ in 0..cfg.iters {
        sim.step().unwrap();
        for i in 0..l.n {
            let x = sim.x().row(i);
            let xe = sim.x_estimate(i);
            let err = x.iter().zip(xe).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
            // bound: ‖Δ‖∞/S of the last transmitted delta ≤ a loose cap on
            // the iterate scale
            let scale = x.iter().map(|v| v.abs()).fold(0.1f64, f64::max);
            assert!(err <= scale / s + 1e-9, "node {i}: err={err} scale={scale}");
        }
    }
}

/// Wire accounting must equal the analytic formula exactly for qsgd:
/// init (2·32M up + 32M down per node, the paper's 32-bit rate — see
/// `tests/accounting_parity.rs` for the cross-runtime contract) + per
/// active node (header + 2 frames) + one broadcast per iteration.
#[test]
fn bit_accounting_matches_analytic_formula() {
    let (mut cfg, l) = ci_cfg();
    cfg.iters = 25;
    let q = 3u32;
    let mut rngs = TrialRngs::new(5);
    let mut problem = LassoProblem::generate(l, &mut rngs.data).unwrap();
    let mut sim = AsyncSim::new(&cfg, &mut problem, rngs).unwrap();
    let m = l.m as u64;
    let header = 12 * 8u64;
    // init: N uplinks of 2 dense64 vectors + broadcast of 1 dense64 vector
    let mut expect = l.n as u64 * (header + 2 * m * 32) + l.n as u64 * (header + m * 32);
    let qsgd_frame = |m: u64| 8 * (1 + 4 + 1 + 8) + (m * q as u64).div_ceil(8) * 8;
    let mut active_total = 0u64;
    for _ in 0..cfg.iters {
        sim.step().unwrap();
        let active = sim.recorder().last().unwrap().active_nodes as u64;
        active_total += active;
    }
    expect += active_total * (header + 2 * qsgd_frame(m));
    expect += cfg.iters as u64 * l.n as u64 * (header + qsgd_frame(m));
    assert_eq!(sim.accounting().total_bits(), expect);
}

/// With EF disabled and an unbiased compressor the run still converges
/// (qsgd), but with the biased top-k compressor EF must make the
/// difference — the §4.1 argument as an executable test.
#[test]
fn error_feedback_rescues_biased_compressor() {
    let (mut cfg, l) = ci_cfg();
    cfg.iters = 300;
    cfg.mc_trials = 1;
    cfg.compressor = CompressorKind::TopK { frac_permille: 150 };

    cfg.error_feedback = true;
    let mut f = lasso_factory(l);
    let with_ef = runner::run_mc(&cfg, f.as_mut()).unwrap();
    cfg.error_feedback = false;
    let mut f = lasso_factory(l);
    let without_ef = runner::run_mc(&cfg, f.as_mut()).unwrap();

    let a = *with_ef.mean_accuracy.last().unwrap();
    let b = *without_ef.mean_accuracy.last().unwrap();
    assert!(a < 1e-4, "top-k with EF should converge: {a}");
    assert!(b > a * 10.0, "EF should dominate for biased compression: ef={a} no_ef={b}");
}

/// τ=1 (synchronous) has every node active in every iteration.
#[test]
fn tau_one_runs_synchronously() {
    let (mut cfg, l) = ci_cfg();
    cfg.tau = 1;
    cfg.iters = 30;
    let mut rngs = TrialRngs::new(3);
    let mut problem = LassoProblem::generate(l, &mut rngs.data).unwrap();
    let mut sim = AsyncSim::new(&cfg, &mut problem, rngs).unwrap();
    for _ in 0..cfg.iters {
        sim.step().unwrap();
        assert_eq!(sim.recorder().last().unwrap().active_nodes, l.n);
    }
}

/// All practical compressor families drive the CI LASSO to reasonable
/// accuracy. (q = 2, i.e. S = 1 ternary quantization, is *not* here: its
/// per-element noise is a full ‖Δ‖∞ interval and the exact-update LASSO
/// loop amplifies it — see the q-sweep ablation, which records exactly
/// that failure mode.)
#[test]
fn all_compressors_converge_with_ef() {
    let (mut cfg, l) = ci_cfg();
    cfg.iters = 350;
    cfg.mc_trials = 1;
    for kind in [
        CompressorKind::Qsgd { bits: 3 },
        CompressorKind::Qsgd { bits: 8 },
        CompressorKind::Sign,
        CompressorKind::TopK { frac_permille: 200 },
        CompressorKind::RandK { frac_permille: 300 },
        CompressorKind::Identity,
    ] {
        cfg.compressor = kind;
        let mut f = lasso_factory(l);
        let res = runner::run_mc(&cfg, f.as_mut()).unwrap();
        let acc = *res.mean_accuracy.last().unwrap();
        assert!(acc < 1e-3, "{} final accuracy {acc}", kind.label());
    }
}

/// Threaded coordinator on the native LASSO problem: converges, and its
/// quality is comparable to the sequential simulator at equal rounds.
#[test]
fn threaded_lasso_matches_sequential_quality() {
    let (mut cfg, l) = ci_cfg();
    cfg.iters = 150;
    cfg.p_min = 2;
    // sequential reference
    let mut f = lasso_factory(l);
    let seq = runner::run_mc(&cfg, f.as_mut()).unwrap();
    let seq_acc = *seq.mean_accuracy.last().unwrap();

    // threaded run on identical data (same trial seed)
    let seed = runner::trial_seed(cfg.seed, 0);
    let mut rngs = TrialRngs::new(seed);
    let problem = LassoProblem::generate(l, &mut rngs.data).unwrap();
    let outcome = qadmm::coordinator::run_threaded(
        &cfg,
        Box::new(problem),
        FaultSpec::default(),
    )
    .unwrap();
    let thr_acc = outcome.recorder.last().unwrap().accuracy;
    assert!(thr_acc < 1e-5, "threaded accuracy {thr_acc}");
    assert!(
        thr_acc < seq_acc * 1e4 + 1e-6,
        "threaded {thr_acc} should be in the same regime as sequential {seq_acc}"
    );
    assert!(outcome.normalized_bits > 0.0);
}

/// Hierarchical fan-in end-to-end: a 2-tier tree (re-quantized aggregator
/// hop, EF per hop) still drives the CI LASSO to the same accuracy regime
/// as the star, and its accounting includes the aggregator links.
#[test]
fn tree_fan_in_converges_on_ci_lasso() {
    let (mut cfg, l) = ci_cfg();
    cfg.iters = 300;
    cfg.mc_trials = 1;
    let mut f = lasso_factory(l);
    let star = runner::run_mc(&cfg, f.as_mut()).unwrap();
    cfg.topology = qadmm::topology::TopologyKind::Tree { fanout: 2 };
    cfg.p_tier = 2;
    let mut f = lasso_factory(l);
    let tree = runner::run_mc(&cfg, f.as_mut()).unwrap();
    let star_acc = *star.mean_accuracy.last().unwrap();
    let tree_acc = *tree.mean_accuracy.last().unwrap();
    assert!(tree_acc < 1e-4, "tree fan-in should converge: {tree_acc}");
    assert!(
        tree_acc < star_acc * 1e3 + 1e-6,
        "tree {tree_acc} should be in the star's regime {star_acc}"
    );
    // the aggregator hop costs wire bits the star does not pay
    let star_bits = *star.mean_comm_bits.last().unwrap();
    let tree_bits = *tree.mean_comm_bits.last().unwrap();
    assert!(tree_bits > star_bits, "aggregator links must be charged");
}

/// The threaded deployment runs the colocated aggregator tier: a tree run
/// over real threads converges and charges the aggregator links.
#[test]
fn threaded_tree_converges() {
    let (mut cfg, l) = ci_cfg();
    cfg.iters = 120;
    cfg.p_min = 2;
    cfg.topology = qadmm::topology::TopologyKind::Tree { fanout: 2 };
    cfg.p_tier = 1;
    let seed = runner::trial_seed(cfg.seed, 0);
    let mut rngs = TrialRngs::new(seed);
    let problem = LassoProblem::generate(l, &mut rngs.data).unwrap();
    let outcome = qadmm::coordinator::run_threaded(
        &cfg,
        Box::new(problem),
        FaultSpec::default(),
    )
    .unwrap();
    let acc = outcome.recorder.last().unwrap().accuracy;
    assert!(acc < 1e-4, "threaded tree accuracy {acc}");
    // uplink totals include the aggregator links (n + ceil(n/2) of them)
    assert!(outcome.uplink_bits > 0 && outcome.normalized_bits > 0.0);
}

/// Failure injection: heavy message duplication must not change the result
/// (sequence-number dedup) — estimates stay consistent and the run converges.
#[test]
fn threaded_survives_duplicate_injection() {
    let (mut cfg, l) = ci_cfg();
    cfg.iters = 120;
    cfg.p_min = 1;
    let seed = runner::trial_seed(cfg.seed, 0);
    let mut rngs = TrialRngs::new(seed);
    let problem = LassoProblem::generate(l, &mut rngs.data).unwrap();
    let outcome = qadmm::coordinator::run_threaded(
        &cfg,
        Box::new(problem),
        FaultSpec { dup_prob: 0.5 },
    )
    .unwrap();
    let acc = outcome.recorder.last().unwrap().accuracy;
    assert!(acc < 1e-4, "convergence under duplication: {acc}");
}

/// The baseline (identity) and QADMM converge to the same optimum; QADMM
/// uses an order of magnitude fewer bits.
#[test]
fn headline_reduction_holds_on_ci_lasso() {
    let (mut cfg, l) = ci_cfg();
    cfg.iters = 400;
    cfg.mc_trials = 2;
    let mut f = lasso_factory(l);
    let q = runner::run_mc(&cfg, f.as_mut()).unwrap();
    cfg.compressor = CompressorKind::Identity;
    let mut f = lasso_factory(l);
    let b = runner::run_mc(&cfg, f.as_mut()).unwrap();
    let target = 1e-8;
    let qb = qadmm::metrics::summary::bits_to_accuracy(&q.mean_recorder().records, target)
        .expect("qadmm reaches 1e-8");
    let bb = qadmm::metrics::summary::bits_to_accuracy(&b.mean_recorder().records, target)
        .expect("baseline reaches 1e-8");
    let reduction = qadmm::metrics::summary::reduction_pct(qb, bb);
    assert!(
        reduction > 80.0,
        "expected ≥80% bit reduction (paper: ~90%), got {reduction:.1}%"
    );
}
