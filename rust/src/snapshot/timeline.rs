//! Recorded virtual timelines: what the event engine actually did, written
//! down so other runtimes can replay it.
//!
//! A recording has two granularities:
//!
//! * **rounds** — per consensus fire: the virtual fire time, the arrival
//!   set the server folded (ascending node ids, exactly the engine's
//!   `arrived` set), and the dispatch set (nodes selected *and* idle, i.e.
//!   the ones whose local update this broadcast started). This is the part
//!   the threaded replay bridge consumes: it pins each node's update to
//!   the consensus round that incorporated it in the recording, so a
//!   deployment-shaped run reproduces the engine's partial-participation
//!   schedule without any wall-clock sleeps.
//! * **events** — the realized `(time, seq, kind, idx)` stream the event
//!   queue popped, for audit and offline analysis (who computed when, what
//!   overtook what). Replay does not need it; `--record-timeline` logs it
//!   so a schedule can be *explained*, not just reproduced.
//!
//! The format is plain JSON via [`crate::util::json`] — recordings are
//! meant to be read, diffed and committed as CI artifacts; binary density
//! matters for snapshots (engine arenas), not for schedules.

use std::path::Path;

use crate::util::json::Json;

/// Format version written into every recording.
pub const TIMELINE_VERSION: usize = 1;

/// Cap on the recorded audit event stream. Replay needs only the
/// per-round arrival sets (always recorded in full); the `(time, seq,
/// kind)` stream is O(rounds·n) and would dominate memory on the long
/// 10k-node runs this subsystem targets, so past this many events the
/// recorder stops appending and sets an explicit `events_truncated`
/// marker — a bounded recording that says so, never a silent one.
pub const MAX_RECORDED_EVENTS: usize = 1_000_000;

/// One popped event of the virtual timeline.
#[derive(Clone, Debug, PartialEq)]
pub struct TimelineEvent {
    pub time: f64,
    pub seq: u64,
    /// Event kind label (`compute-done` | `msg-arrive` | `downlink-arrive`
    /// | `aggregate-arrive`).
    pub kind: String,
    /// The node (or aggregator) the event belongs to.
    pub idx: usize,
}

/// One consensus round as the engine realized it.
#[derive(Clone, Debug, PartialEq)]
pub struct TimelineRound {
    /// Virtual time of the fire.
    pub time: f64,
    /// Ascending node ids whose updates this round incorporated.
    pub arrivals: Vec<usize>,
    /// Ascending node ids dispatched by this round's broadcast (selected
    /// and idle at fire time). Informational for the threaded bridge —
    /// deployment nodes recompute on inclusion — but it pins the oracle's
    /// realized schedule for audit.
    pub dispatches: Vec<usize>,
}

/// A full recorded run of the event engine.
#[derive(Clone, Debug, PartialEq)]
pub struct RecordedTimeline {
    /// Engine that produced the recording (`event`).
    pub engine: String,
    /// Fleet size the recording is valid for.
    pub n: usize,
    /// Base seed of the recorded run (provenance; replay does not use it).
    pub seed: u64,
    pub rounds: Vec<TimelineRound>,
    pub events: Vec<TimelineEvent>,
    /// True when the event stream hit [`MAX_RECORDED_EVENTS`] and later
    /// events were dropped (the rounds are always complete).
    pub events_truncated: bool,
}

impl RecordedTimeline {
    pub fn new(engine: &str, n: usize, seed: u64) -> Self {
        Self {
            engine: engine.to_string(),
            n,
            seed,
            rounds: Vec::new(),
            events: Vec::new(),
            events_truncated: false,
        }
    }

    pub fn push_event(&mut self, time: f64, seq: u64, kind: &str, idx: usize) {
        if self.events.len() >= MAX_RECORDED_EVENTS {
            self.events_truncated = true;
            return;
        }
        self.events.push(TimelineEvent { time, seq, kind: kind.to_string(), idx });
    }

    pub fn push_round(&mut self, time: f64, arrivals: Vec<usize>, dispatches: Vec<usize>) {
        self.rounds.push(TimelineRound { time, arrivals, dispatches });
    }

    pub fn to_json(&self) -> Json {
        let rounds = self
            .rounds
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("t", Json::Num(r.time)),
                    (
                        "arrivals",
                        Json::Arr(r.arrivals.iter().map(|&i| Json::Num(i as f64)).collect()),
                    ),
                    (
                        "dispatches",
                        Json::Arr(
                            r.dispatches.iter().map(|&i| Json::Num(i as f64)).collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let events = self
            .events
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("t", Json::Num(e.time)),
                    ("seq", Json::Num(e.seq as f64)),
                    ("kind", Json::Str(e.kind.clone())),
                    ("idx", Json::Num(e.idx as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::Num(TIMELINE_VERSION as f64)),
            ("engine", Json::Str(self.engine.clone())),
            ("n", Json::Num(self.n as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("rounds", Json::Arr(rounds)),
            ("events", Json::Arr(events)),
            ("events_truncated", Json::Bool(self.events_truncated)),
        ])
    }

    /// Parse and validate a recording. Arrival/dispatch sets must be
    /// strictly ascending and in `0..n`, so the replay bridge can index
    /// node tables without bounds anxiety.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let version = j
            .expect("version")?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("timeline version must be an integer"))?;
        anyhow::ensure!(
            version == TIMELINE_VERSION,
            "timeline version {version} not supported (expected {TIMELINE_VERSION})"
        );
        let engine = j
            .expect("engine")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("timeline engine must be a string"))?
            .to_string();
        let n = j
            .expect("n")?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("timeline n must be an integer"))?;
        anyhow::ensure!(n >= 1, "timeline n must be >= 1");
        let seed = j
            .expect("seed")?
            .as_f64()
            .filter(|s| *s >= 0.0 && s.fract() == 0.0)
            .ok_or_else(|| anyhow::anyhow!("timeline seed must be a non-negative integer"))?
            as u64;

        let id_list = |v: &Json, what: &str| -> anyhow::Result<Vec<usize>> {
            let arr = v
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("timeline {what} must be an array"))?;
            let mut out = Vec::with_capacity(arr.len());
            for item in arr {
                let id = item
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("timeline {what} entry is not an id"))?;
                anyhow::ensure!(id < n, "timeline {what} id {id} out of range (n = {n})");
                if let Some(&last) = out.last() {
                    anyhow::ensure!(
                        id > last,
                        "timeline {what} ids must be strictly ascending"
                    );
                }
                out.push(id);
            }
            Ok(out)
        };

        let rounds_json = j
            .expect("rounds")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("timeline rounds must be an array"))?;
        let mut rounds = Vec::new();
        for (i, rj) in rounds_json.iter().enumerate() {
            let time = rj
                .expect("t")?
                .as_f64()
                .filter(|t| t.is_finite() && *t >= 0.0)
                .ok_or_else(|| anyhow::anyhow!("round {i}: bad fire time"))?;
            let arrivals = id_list(rj.expect("arrivals")?, "arrivals")?;
            anyhow::ensure!(!arrivals.is_empty(), "round {i}: empty arrival set");
            let dispatches = id_list(rj.expect("dispatches")?, "dispatches")?;
            rounds.push(TimelineRound { time, arrivals, dispatches });
        }
        anyhow::ensure!(!rounds.is_empty(), "timeline has no rounds");

        let events_json = j
            .expect("events")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("timeline events must be an array"))?;
        let mut events = Vec::new();
        for (i, ej) in events_json.iter().enumerate() {
            events.push(TimelineEvent {
                time: ej
                    .expect("t")?
                    .as_f64()
                    .filter(|t| t.is_finite() && *t >= 0.0)
                    .ok_or_else(|| anyhow::anyhow!("event {i}: bad time"))?,
                seq: ej
                    .expect("seq")?
                    .as_f64()
                    .filter(|s| *s >= 0.0 && s.fract() == 0.0)
                    .ok_or_else(|| anyhow::anyhow!("event {i}: bad seq"))?
                    as u64,
                kind: ej
                    .expect("kind")?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("event {i}: bad kind"))?
                    .to_string(),
                idx: ej
                    .expect("idx")?
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("event {i}: bad idx"))?,
            });
        }
        let events_truncated =
            j.get("events_truncated").and_then(Json::as_bool).unwrap_or(false);
        Ok(Self { engine, n, seed, rounds, events, events_truncated })
    }

    pub fn write(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read timeline {}: {e}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("timeline {} is not json: {e}", path.display()))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RecordedTimeline {
        let mut tl = RecordedTimeline::new("event", 4, 99);
        tl.push_event(0.0, 0, "compute-done", 2);
        tl.push_event(0.5, 3, "msg-arrive", 2);
        tl.push_round(0.5, vec![0, 2], vec![1, 3]);
        tl.push_round(1.25, vec![1, 3], vec![0]);
        tl
    }

    #[test]
    fn json_round_trip() {
        let tl = sample();
        let back = RecordedTimeline::from_json(&tl.to_json()).unwrap();
        assert_eq!(back, tl);
        // the explicit-truncation marker survives the round trip too
        let mut capped = sample();
        capped.events_truncated = true;
        let back = RecordedTimeline::from_json(&capped.to_json()).unwrap();
        assert!(back.events_truncated);
    }

    #[test]
    fn rejects_malformed_recordings() {
        let tl = sample();
        // out-of-range id
        let mut bad = tl.clone();
        bad.rounds[0].arrivals = vec![0, 9];
        assert!(RecordedTimeline::from_json(&bad.to_json()).is_err());
        // non-ascending arrivals
        let mut bad = tl.clone();
        bad.rounds[0].arrivals = vec![2, 0];
        assert!(RecordedTimeline::from_json(&bad.to_json()).is_err());
        // empty arrival set
        let mut bad = tl.clone();
        bad.rounds[1].arrivals.clear();
        assert!(RecordedTimeline::from_json(&bad.to_json()).is_err());
        // no rounds at all
        let mut bad = tl.clone();
        bad.rounds.clear();
        assert!(RecordedTimeline::from_json(&bad.to_json()).is_err());
        // wrong version
        let mut j = tl.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("version".into(), Json::Num(999.0));
        }
        assert!(RecordedTimeline::from_json(&j).is_err());
        // garbage
        assert!(RecordedTimeline::from_json(&Json::Num(3.0)).is_err());
    }

    #[test]
    fn file_round_trip() {
        let tl = sample();
        let dir = std::env::temp_dir().join("qadmm-timeline-test");
        let path = dir.join("tl.json");
        tl.write(&path).unwrap();
        let back = RecordedTimeline::load(&path).unwrap();
        assert_eq!(back, tl);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
