//! Event-engine scaling sweep: n ∈ {16, 128, 1024} nodes.
//!
//! The headline configuration is the acceptance bar for the virtual-time
//! engine: **n = 1024 nodes, m = 10240-dim LASSO, 200 consensus rounds,
//! heterogeneous straggler latency — in seconds of wall-clock, not hours**
//! (the threaded runtime would sleep through every injected delay; the
//! sequential simulator has no notion of stragglers at all). Feasible
//! because the LASSO Woodbury solver never forms an m×m inverse (h ≪ m)
//! and the per-node fan-out runs on the worker pool.
//!
//! `QADMM_BENCH_FAST=1` shrinks the sweep for CI smoke runs.

use qadmm::admm::engine::EventEngine;
use qadmm::admm::sim::TrialRngs;
use qadmm::comm::latency::LatencyModel;
use qadmm::config::{presets, EngineKind, OracleConfig, ProblemKind};
use qadmm::problems::lasso::{LassoConfig, LassoProblem};
use qadmm::util::timer::{fmt_count, Stopwatch};

struct Sweep {
    n: usize,
    m: usize,
    h: usize,
    rounds: usize,
}

fn run_sweep(s: &Sweep) -> anyhow::Result<()> {
    let mut cfg = presets::ci_lasso();
    cfg.name = format!("engine-scale-n{}", s.n);
    cfg.problem = ProblemKind::Lasso { m: s.m, h: s.h, n: s.n, rho: 50.0, theta: 0.1 };
    cfg.engine = EngineKind::Event;
    cfg.tau = 4;
    cfg.p_min = (s.n / 4).max(1);
    cfg.iters = s.rounds;
    cfg.mc_trials = 1;
    cfg.eval_every = s.rounds; // one final eval; per-round eval is O(n·h·m)
    cfg.oracle = OracleConfig { p_slow: 0.1, p_fast: 0.8, regroup_each_call: false };
    // Straggler mixture in *virtual* seconds: a threaded run would sleep
    // ~rounds × slow-tail of real time; the engine only does arithmetic.
    cfg.latency = LatencyModel::Mixture { fast: 0.002, slow: 0.25, p_slow: 0.15 };

    let gen_clock = Stopwatch::new();
    let mut rngs = TrialRngs::new(cfg.seed);
    let mut problem = LassoProblem::generate(
        LassoConfig { m: s.m, h: s.h, n: s.n, rho: 50.0, theta: 0.1 },
        &mut rngs.data,
    )?;
    // The accuracy metric needs F*, which costs thousands of reference
    // rounds — irrelevant for a throughput bench.
    problem.set_reference_optimum(1.0);
    let gen_s = gen_clock.elapsed_secs();

    let clock = Stopwatch::new();
    let mut engine = EventEngine::new(&cfg, &mut problem, rngs)?;
    for _ in 0..s.rounds {
        engine.step_round()?;
    }
    let wall = clock.elapsed_secs();
    let stats = engine.stats();
    println!(
        "n={:5} m={:6} h={:3} rounds={:4}  wall {:7.2}s (gen {:5.2}s)  virtual {:8.2}s  \
         speedup {:>9}x  events/s {:>9}  dispatches {}",
        s.n,
        s.m,
        s.h,
        s.rounds,
        wall,
        gen_s,
        stats.virtual_time,
        fmt_count(stats.virtual_time / wall.max(1e-9)),
        fmt_count(stats.events as f64 / wall.max(1e-9)),
        stats.dispatches,
    );
    if s.n >= 1024 && wall >= 10.0 {
        println!("  !! acceptance bar missed: n={} took {wall:.2}s (target < 10s)", s.n);
    }
    Ok(())
}

fn main() {
    let fast = std::env::var("QADMM_BENCH_FAST").is_ok();
    let sweeps = if fast {
        vec![
            Sweep { n: 16, m: 200, h: 100, rounds: 50 },
            Sweep { n: 128, m: 512, h: 16, rounds: 20 },
            Sweep { n: 1024, m: 10_240, h: 4, rounds: 10 },
        ]
    } else {
        vec![
            Sweep { n: 16, m: 200, h: 100, rounds: 200 },
            Sweep { n: 128, m: 2048, h: 16, rounds: 200 },
            Sweep { n: 1024, m: 10_240, h: 4, rounds: 200 },
        ]
    };
    println!("--- engine_scale: event-driven virtual-time QADMM ---");
    for s in &sweeps {
        if let Err(e) = run_sweep(s) {
            eprintln!("n={}: {e:#}", s.n);
            std::process::exit(1);
        }
    }
    println!("--- engine_scale: {} sweeps done ---", sweeps.len());
}
