//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`), compile
//! them once on the CPU PJRT client, and execute them from the L3 hot path.
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! PJRT handles are not `Send`, so multi-threaded deployments go through
//! [`service::ComputeService`] — a dedicated thread that owns the client
//! and serves typed requests over channels (the same shape as a real
//! accelerator-executor process).
//!
//! The `xla` native dependency (and with it `XLA_EXTENSION_DIR`) is only
//! required under the **`xla-runtime`** feature (on by default). Building
//! with `--no-default-features` swaps [`Runtime`] for a stub whose
//! constructor fails with a clear error, so the pure-native stack (LASSO,
//! all three engines on `Backend::Native`, the compressors, the tests)
//! compiles and runs without the XLA toolchain.

pub mod artifacts;
pub mod service;
pub mod tensor;

#[cfg(feature = "xla-runtime")]
use std::cell::RefCell;
#[cfg(feature = "xla-runtime")]
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "xla-runtime")]
use std::path::PathBuf;

use artifacts::Manifest;
use tensor::Tensor;

/// A compiled-artifact registry bound to one PJRT client.
#[cfg(feature = "xla-runtime")]
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Device-resident constant inputs, keyed by (artifact, caller key):
    /// per-node factors (e.g. the LASSO (2AᵀA+ρI)⁻¹) are uploaded once and
    /// reused every iteration (§Perf).
    consts: RefCell<HashMap<(String, u64), Vec<xla::PjRtBuffer>>>,
}

#[cfg(feature = "xla-runtime")]
impl Runtime {
    /// Open `dir` (containing `manifest.json` + HLO text files) on the CPU
    /// PJRT client.
    pub fn open(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            manifest,
            dir: dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
            consts: RefCell::new(HashMap::new()),
        })
    }

    /// Default artifact location: `$QADMM_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> anyhow::Result<Self> {
        let dir = std::env::var("QADMM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(Path::new(&dir))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) one artifact.
    fn ensure_compiled(&self, name: &str) -> anyhow::Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        crate::util::log::debug("runtime", &format!("compiled artifact '{name}'"));
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Pre-compile a set of artifacts (pays the XLA compile cost up front).
    pub fn warmup(&self, names: &[&str]) -> anyhow::Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    /// Execute an artifact with shape/dtype validation against the manifest.
    pub fn call(&self, name: &str, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let spec = self.manifest.artifact(name)?;
        spec.validate_inputs(inputs)
            .map_err(|e| anyhow::anyhow!("artifact '{name}': {e}"))?;
        self.ensure_compiled(name)?;
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| t.to_buffer(&self.client))
            .collect::<anyhow::Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.execute_buffers(name, &refs, spec.outputs.len())
    }

    /// Execute with a device-resident constant *prefix*: `consts` is
    /// uploaded once per (artifact, key) and reused on every subsequent
    /// call (pass `None` once registered); only `varying` crosses the
    /// host/device boundary. ~12× cheaper dispatch than the Literal path
    /// for small models (§Perf).
    pub fn call_prefixed(
        &self,
        name: &str,
        key: u64,
        consts: Option<&[Tensor]>,
        varying: &[Tensor],
    ) -> anyhow::Result<Vec<Tensor>> {
        let spec = self.manifest.artifact(name)?;
        self.ensure_compiled(name)?;
        let cache_key = (name.to_string(), key);
        if !self.consts.borrow().contains_key(&cache_key) {
            let consts = consts.ok_or_else(|| {
                anyhow::anyhow!("artifact '{name}' key {key}: constants never registered")
            })?;
            // validate the full concatenation once, at registration
            let all: Vec<Tensor> = consts.iter().chain(varying.iter()).cloned().collect();
            spec.validate_inputs(&all)
                .map_err(|e| anyhow::anyhow!("artifact '{name}': {e}"))?;
            let uploaded: Vec<xla::PjRtBuffer> = consts
                .iter()
                .map(|t| t.to_buffer(&self.client))
                .collect::<anyhow::Result<_>>()?;
            self.consts.borrow_mut().insert(cache_key.clone(), uploaded);
        } else {
            let n_consts = self.consts.borrow()[&cache_key].len();
            anyhow::ensure!(
                n_consts + varying.len() == spec.inputs.len(),
                "artifact '{name}': {} varying inputs + {n_consts} consts != {} expected",
                varying.len(),
                spec.inputs.len()
            );
        }
        let varying_bufs: Vec<xla::PjRtBuffer> = varying
            .iter()
            .map(|t| t.to_buffer(&self.client))
            .collect::<anyhow::Result<_>>()?;
        let consts_cache = self.consts.borrow();
        let const_bufs = consts_cache.get(&cache_key).expect("inserted above");
        let refs: Vec<&xla::PjRtBuffer> =
            const_bufs.iter().chain(varying_bufs.iter()).collect();
        self.execute_buffers(name, &refs, spec.outputs.len())
    }

    fn execute_buffers(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
        n_outputs: usize,
    ) -> anyhow::Result<Vec<Tensor>> {
        let cache = self.cache.borrow();
        let exe = cache.get(name).expect("compiled by caller");
        let result = exe
            .execute_b(args)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple, even 1-ary.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple result of {name}: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == n_outputs,
            "artifact '{name}' returned {} outputs, manifest says {n_outputs}",
            parts.len()
        );
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Evict pinned constants (called when a problem instance retires).
    pub fn drop_consts(&self, name: &str, keys: &[u64]) {
        let mut cache = self.consts.borrow_mut();
        for &k in keys {
            cache.remove(&(name.to_string(), k));
        }
    }

    /// Number of artifacts compiled so far (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Number of pinned constant sets (diagnostics).
    pub fn pinned_const_sets(&self) -> usize {
        self.consts.borrow().len()
    }
}

/// Stub that takes [`Runtime`]'s place when the crate is built with
/// `--no-default-features`: every signature is preserved so the service,
/// the problems layer and the CLI compile unchanged, but construction
/// fails — the `Infallible` field makes the post-construction methods
/// statically unreachable.
#[cfg(not(feature = "xla-runtime"))]
pub struct Runtime {
    manifest: Manifest,
    no_xla: std::convert::Infallible,
}

#[cfg(not(feature = "xla-runtime"))]
impl Runtime {
    pub fn open(_dir: &Path) -> anyhow::Result<Self> {
        anyhow::bail!(
            "this build has no PJRT/XLA support: rebuild with the `xla-runtime` \
             feature (on by default) to execute HLO artifacts"
        )
    }

    pub fn open_default() -> anyhow::Result<Self> {
        Self::open(Path::new("artifacts"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn warmup(&self, _names: &[&str]) -> anyhow::Result<()> {
        match self.no_xla {}
    }

    pub fn call(&self, _name: &str, _inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        match self.no_xla {}
    }

    pub fn call_prefixed(
        &self,
        _name: &str,
        _key: u64,
        _consts: Option<&[Tensor]>,
        _varying: &[Tensor],
    ) -> anyhow::Result<Vec<Tensor>> {
        match self.no_xla {}
    }

    pub fn drop_consts(&self, _name: &str, _keys: &[u64]) {
        match self.no_xla {}
    }

    pub fn compiled_count(&self) -> usize {
        match self.no_xla {}
    }

    pub fn pinned_const_sets(&self) -> usize {
        match self.no_xla {}
    }
}

/// Anything that can execute a named artifact: the in-process [`Runtime`]
/// (single-threaded simulator) or a [`service::ComputeClient`] (threaded
/// deployment). Problems are written against this trait.
pub trait Exec {
    fn call(&self, name: &str, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>>;

    /// Execute with a cacheable constant input prefix (see
    /// [`Runtime::call_prefixed`]). The default just concatenates — backends
    /// with device memory override it to pin the constants.
    fn call_prefixed(
        &self,
        name: &str,
        _key: u64,
        consts: &[Tensor],
        varying: &[Tensor],
    ) -> anyhow::Result<Vec<Tensor>> {
        let all: Vec<Tensor> = consts.iter().chain(varying.iter()).cloned().collect();
        self.call(name, &all)
    }

    /// Evict pinned constants; default no-op for backends without a cache.
    fn drop_consts(&self, _name: &str, _keys: &[u64]) {}
}

impl Exec for Runtime {
    fn call(&self, name: &str, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        Runtime::call(self, name, inputs)
    }

    fn call_prefixed(
        &self,
        name: &str,
        key: u64,
        consts: &[Tensor],
        varying: &[Tensor],
    ) -> anyhow::Result<Vec<Tensor>> {
        Runtime::call_prefixed(self, name, key, Some(consts), varying)
    }
}

impl Exec for std::rc::Rc<Runtime> {
    fn call(&self, name: &str, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        Runtime::call(self, name, inputs)
    }

    fn call_prefixed(
        &self,
        name: &str,
        key: u64,
        consts: &[Tensor],
        varying: &[Tensor],
    ) -> anyhow::Result<Vec<Tensor>> {
        Runtime::call_prefixed(self, name, key, Some(consts), varying)
    }
}
