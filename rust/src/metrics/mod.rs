//! Metrics: per-iteration records (accuracy eq. 19, communication bits
//! eq. 20, test accuracy/loss), CSV/JSON emission, and headline summaries
//! (bits-to-target reduction percentages).

pub mod summary;

use crate::snapshot::codec::{Pack, Reader, Writer};
use crate::util::json::Json;

/// One measured point along a run.
#[derive(Clone, Debug)]
pub struct IterRecord {
    pub iter: usize,
    /// Cumulative communication bits normalized by M (eq. 20).
    pub comm_bits: f64,
    /// |L − F*| / F* for convex problems (eq. 19); NaN if not applicable.
    pub accuracy: f64,
    /// Test-set classification accuracy in [0,1]; NaN if not applicable.
    pub test_acc: f64,
    /// Training loss (NN) or augmented Lagrangian value (LASSO).
    pub loss: f64,
    /// |A_r|: how many nodes updated this iteration.
    pub active_nodes: usize,
    /// Wall-clock seconds since run start.
    pub wall_s: f64,
}

/// Collects the records of one run (one MC trial).
#[derive(Clone, Debug, Default)]
pub struct RunRecorder {
    pub records: Vec<IterRecord>,
}

impl RunRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: IterRecord) {
        self.records.push(r);
    }

    pub fn csv_header() -> &'static str {
        "iter,comm_bits,accuracy,test_acc,loss,active_nodes,wall_s"
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from(Self::csv_header());
        out.push('\n');
        for r in &self.records {
            out.push_str(&format!(
                "{},{:.6e},{:.6e},{:.6},{:.6e},{},{:.4}\n",
                r.iter, r.comm_bits, r.accuracy, r.test_acc, r.loss, r.active_nodes, r.wall_s
            ));
        }
        out
    }

    pub fn write_csv(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }

    pub fn series(&self, f: impl Fn(&IterRecord) -> f64) -> Vec<f64> {
        self.records.iter().map(f).collect()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.records
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("iter", Json::Num(r.iter as f64)),
                        ("comm_bits", Json::Num(r.comm_bits)),
                        ("accuracy", Json::Num(r.accuracy)),
                        ("test_acc", Json::Num(r.test_acc)),
                        ("loss", Json::Num(r.loss)),
                        ("active_nodes", Json::Num(r.active_nodes as f64)),
                        ("wall_s", Json::Num(r.wall_s)),
                    ])
                })
                .collect(),
        )
    }

    pub fn last(&self) -> Option<&IterRecord> {
        self.records.last()
    }
}

impl Pack for IterRecord {
    fn pack(&self, w: &mut Writer) {
        w.put_usize(self.iter);
        w.put_f64(self.comm_bits);
        w.put_f64(self.accuracy);
        w.put_f64(self.test_acc);
        w.put_f64(self.loss);
        w.put_usize(self.active_nodes);
        w.put_f64(self.wall_s);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        Ok(Self {
            iter: r.get_usize()?,
            comm_bits: r.get_f64()?,
            accuracy: r.get_f64()?,
            test_acc: r.get_f64()?,
            loss: r.get_f64()?,
            active_nodes: r.get_usize()?,
            wall_s: r.get_f64()?,
        })
    }
}

/// The metric series rides in the snapshot so a resumed run emits one
/// continuous CSV. `wall_s` of pre-checkpoint records keeps the original
/// process's clock — it is the one field excluded from the bit-identity
/// contract (wall time is not run state).
impl Pack for RunRecorder {
    fn pack(&self, w: &mut Writer) {
        self.records.pack(w);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        Ok(Self { records: Vec::<IterRecord>::unpack(r)? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(iter: usize, acc: f64, bits: f64) -> IterRecord {
        IterRecord {
            iter,
            comm_bits: bits,
            accuracy: acc,
            test_acc: f64::NAN,
            loss: 1.0,
            active_nodes: 4,
            wall_s: 0.1,
        }
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut r = RunRecorder::new();
        r.push(rec(0, 1.0, 64.0));
        r.push(rec(1, 0.1, 128.0));
        let csv = r.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("iter,"));
        assert_eq!(lines[1].split(',').count(), 7);
    }

    #[test]
    fn series_extracts() {
        let mut r = RunRecorder::new();
        r.push(rec(0, 1.0, 64.0));
        r.push(rec(1, 0.5, 128.0));
        assert_eq!(r.series(|x| x.accuracy), vec![1.0, 0.5]);
        assert_eq!(r.last().unwrap().iter, 1);
    }

    #[test]
    fn json_serializes_nan_as_null() {
        let mut r = RunRecorder::new();
        r.push(rec(0, 1.0, 64.0));
        let text = r.to_json().to_string_compact();
        assert!(text.contains("\"test_acc\":null"));
    }
}
