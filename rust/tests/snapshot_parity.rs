//! The snapshot subsystem's acceptance contract (ISSUE 5):
//!
//! 1. **Resume parity** — for both in-process engines, a run checkpointed
//!    at round k and resumed (problem re-derived from the seed, every
//!    other piece of state from the snapshot) is *bit-identical* to the
//!    same seed run straight through: per-round z trajectories, per-round
//!    staleness vectors, per-link wire-bit totals, the metric series
//!    (minus wall clock) and the final state of every RNG stream — across
//!    star, tree and gossip topologies, with the event engine under
//!    nonzero delay on every link leg (so the checkpoint lands with
//!    events in flight and payloads on the virtual wire).
//! 2. **Recorded-timeline bridge** — the threaded runtime replaying an
//!    event-engine recording reproduces that engine's arrival sets and
//!    round count exactly.

use qadmm::admm::engine::EventEngine;
use qadmm::admm::runner::trial_seed;
use qadmm::admm::sim::{AsyncSim, TrialRngs};
use qadmm::comm::latency::LatencyModel;
use qadmm::comm::network::FaultSpec;
use qadmm::comm::profile::LinkConfig;
use qadmm::compress::CompressorKind;
use qadmm::config::{presets, EngineKind, ExperimentConfig, ProblemKind};
use qadmm::problems::lasso::{LassoConfig, LassoProblem};
use qadmm::snapshot;
use qadmm::topology::TopologyKind;

const ITERS: usize = 36;
const K: usize = 17; // checkpoint round: not a refresh multiple on purpose

fn cfg_for(engine: EngineKind, topo: TopologyKind) -> ExperimentConfig {
    let mut cfg = presets::ci_lasso();
    cfg.name = format!("snapshot-parity-{}-{}", engine.label(), topo.label());
    cfg.problem = ProblemKind::Lasso { m: 20, h: 10, n: 12, rho: 30.0, theta: 0.1 };
    cfg.compressor = CompressorKind::Qsgd { bits: 3 };
    cfg.engine = engine;
    cfg.topology = topo;
    cfg.p_tier = 2;
    cfg.tau = 3;
    cfg.p_min = 3;
    cfg.iters = ITERS;
    cfg.mc_trials = 1;
    cfg.eval_every = 1;
    cfg.consensus_refresh_every = 8; // refresh rounds straddle the checkpoint
    if engine == EngineKind::Event {
        cfg.link = LinkConfig {
            compute: LatencyModel::Exp(0.01),
            uplink: LatencyModel::Exp(0.015),
            downlink: LatencyModel::Exp(0.02),
            clock_drift: 0.15,
        };
    }
    cfg
}

fn make_problem(cfg: &ExperimentConfig) -> (LassoProblem, TrialRngs) {
    let lcfg = match cfg.problem {
        ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
        _ => unreachable!(),
    };
    let mut rngs = TrialRngs::new(trial_seed(cfg.seed, 0));
    let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
    p.set_reference_optimum(1.0);
    (p, rngs)
}

/// Everything the contract compares, bitwise.
#[derive(PartialEq, Debug)]
struct Trace {
    z: Vec<Vec<u64>>,
    staleness: Vec<Vec<usize>>,
    links: Vec<(u64, u64, u64, u64)>,
    records: Vec<(usize, u64, u64, u64, usize)>,
    rng_digest: u64,
}

fn links_of(acc: &qadmm::comm::accounting::CommAccounting) -> Vec<(u64, u64, u64, u64)> {
    (0..acc.n_nodes())
        .map(|i| {
            let l = acc.link(i);
            (l.uplink_bits, l.downlink_bits, l.uplink_msgs, l.downlink_msgs)
        })
        .collect()
}

fn records_of(rec: &qadmm::metrics::RunRecorder) -> Vec<(usize, u64, u64, u64, usize)> {
    // wall_s excluded: wall time is not run state
    rec.records
        .iter()
        .map(|r| {
            (r.iter, r.comm_bits.to_bits(), r.accuracy.to_bits(), r.loss.to_bits(), r.active_nodes)
        })
        .collect()
}

fn run_seq(cfg: &ExperimentConfig, interrupt: Option<usize>) -> Trace {
    let (mut problem, rngs) = make_problem(cfg);
    let mut sim = AsyncSim::new(cfg, &mut problem, rngs).unwrap();
    let mut z = Vec::new();
    let mut staleness = Vec::new();
    let k = interrupt.unwrap_or(cfg.iters);
    for _ in 0..k {
        sim.step().unwrap();
        z.push(sim.z().iter().map(|v| v.to_bits()).collect());
        staleness.push(sim.staleness().to_vec());
    }
    if k < cfg.iters {
        // full container round-trip, then a cold resume on a re-derived problem
        let bytes = snapshot::encode(&sim.snapshot_meta(), &sim.snapshot_body());
        drop(sim);
        let (meta, body) = snapshot::decode(&bytes).unwrap();
        assert_eq!(meta.round, k);
        assert_eq!(meta.engine, "seq");
        assert_eq!(
            snapshot::config_resume_digest(&meta.config),
            cfg.resume_digest(),
            "snapshot header must carry the resumable config identity"
        );
        let (mut problem2, _) = make_problem(cfg);
        let mut sim = AsyncSim::resume(cfg, &mut problem2, &body).unwrap();
        while sim.iter() < cfg.iters {
            sim.step().unwrap();
            z.push(sim.z().iter().map(|v| v.to_bits()).collect());
            staleness.push(sim.staleness().to_vec());
        }
        return Trace {
            z,
            staleness,
            links: links_of(sim.accounting()),
            records: records_of(sim.recorder()),
            rng_digest: sim.rng_digest(),
        };
    }
    Trace {
        z,
        staleness,
        links: links_of(sim.accounting()),
        records: records_of(sim.recorder()),
        rng_digest: sim.rng_digest(),
    }
}

fn run_event(cfg: &ExperimentConfig, interrupt: Option<usize>) -> Trace {
    let (mut problem, rngs) = make_problem(cfg);
    let mut eng = EventEngine::new(cfg, &mut problem, rngs).unwrap();
    let mut z = Vec::new();
    let mut staleness = Vec::new();
    let k = interrupt.unwrap_or(cfg.iters);
    for _ in 0..k {
        eng.step_round().unwrap();
        z.push(eng.z().iter().map(|v| v.to_bits()).collect());
        staleness.push(eng.staleness().to_vec());
    }
    if k < cfg.iters {
        let bytes = snapshot::encode(&eng.snapshot_meta(), &eng.snapshot_body());
        drop(eng);
        let (meta, body) = snapshot::decode(&bytes).unwrap();
        assert_eq!(meta.round, k);
        assert_eq!(meta.engine, "event");
        let (mut problem2, _) = make_problem(cfg);
        let mut eng = EventEngine::resume(cfg, &mut problem2, &body).unwrap();
        while eng.stats().rounds < cfg.iters {
            eng.step_round().unwrap();
            z.push(eng.z().iter().map(|v| v.to_bits()).collect());
            staleness.push(eng.staleness().to_vec());
        }
        return Trace {
            z,
            staleness,
            links: links_of(eng.accounting()),
            records: records_of(eng.recorder()),
            rng_digest: eng.rng_digest(),
        };
    }
    Trace {
        z,
        staleness,
        links: links_of(eng.accounting()),
        records: records_of(eng.recorder()),
        rng_digest: eng.rng_digest(),
    }
}

fn assert_cell(engine: EngineKind, topo: TopologyKind) {
    let cfg = cfg_for(engine, topo);
    let (straight, resumed) = match engine {
        EngineKind::Seq => (run_seq(&cfg, None), run_seq(&cfg, Some(K))),
        EngineKind::Event => (run_event(&cfg, None), run_event(&cfg, Some(K))),
        EngineKind::Threaded => unreachable!(),
    };
    assert_eq!(straight.z, resumed.z, "{}: z trajectory", cfg.name);
    assert_eq!(straight.staleness, resumed.staleness, "{}: staleness", cfg.name);
    assert_eq!(straight.links, resumed.links, "{}: per-link wire bits", cfg.name);
    assert_eq!(straight.records, resumed.records, "{}: metric series", cfg.name);
    assert_eq!(straight.rng_digest, resumed.rng_digest, "{}: final RNG states", cfg.name);
}

#[test]
fn seq_resume_is_bit_identical_across_topologies() {
    for topo in
        [TopologyKind::Star, TopologyKind::Tree { fanout: 4 }, TopologyKind::Gossip { k: 3 }]
    {
        assert_cell(EngineKind::Seq, topo);
    }
}

#[test]
fn event_resume_is_bit_identical_across_topologies_under_latency() {
    for topo in
        [TopologyKind::Star, TopologyKind::Tree { fanout: 4 }, TopologyKind::Gossip { k: 3 }]
    {
        assert_cell(EngineKind::Event, topo);
    }
}

/// Trigger-enabled resume: the dead-band + adaptive-schedule state
/// (per-node stage counters, anchor scales, skip tally) rides in the
/// snapshot body, so a run checkpointed with δ > 0 and the adaptive
/// schedule on must continue bit-identically — same contract as the
/// disabled cells above, *not* a weaker one. A resume under flipped
/// trigger knobs must be refused (the packed state would disagree with
/// the config's plan).
#[test]
fn trigger_enabled_resume_is_bit_identical() {
    for engine in [EngineKind::Seq, EngineKind::Event] {
        let mut cfg = cfg_for(engine, TopologyKind::Star);
        cfg.name = format!("snapshot-parity-trigger-{}", engine.label());
        // qsgd(3) from cfg_for: the schedule starts at 2 bits and can
        // refine to the configured 3, so stage state is genuinely live
        cfg.trigger.delta = 1e-4;
        cfg.trigger.adapt = true;
        cfg.validate().unwrap();
        let (straight, resumed) = match engine {
            EngineKind::Seq => (run_seq(&cfg, None), run_seq(&cfg, Some(K))),
            EngineKind::Event => (run_event(&cfg, None), run_event(&cfg, Some(K))),
            EngineKind::Threaded => unreachable!(),
        };
        assert_eq!(straight.z, resumed.z, "{}: z trajectory", cfg.name);
        assert_eq!(straight.staleness, resumed.staleness, "{}: staleness", cfg.name);
        assert_eq!(straight.links, resumed.links, "{}: per-link wire bits", cfg.name);
        assert_eq!(straight.records, resumed.records, "{}: metric series", cfg.name);
        assert_eq!(straight.rng_digest, resumed.rng_digest, "{}: RNG states", cfg.name);
    }

    // flipping the trigger plan invalidates the snapshot
    let mut cfg = cfg_for(EngineKind::Event, TopologyKind::Star);
    cfg.trigger.delta = 1e-4;
    cfg.trigger.adapt = true;
    let (mut problem, rngs) = make_problem(&cfg);
    let mut eng = EventEngine::new(&cfg, &mut problem, rngs).unwrap();
    for _ in 0..3 {
        eng.step_round().unwrap();
    }
    let body = eng.snapshot_body();
    drop(eng);
    let mut flipped = cfg.clone();
    flipped.trigger.delta = 0.0;
    flipped.trigger.adapt = false;
    let (mut p2, _) = make_problem(&flipped);
    assert!(
        EventEngine::resume(&flipped, &mut p2, &body).is_err(),
        "resume accepted a snapshot whose trigger state disagrees with the config"
    );
    assert_ne!(
        cfg.resume_digest(),
        flipped.resume_digest(),
        "digest must change when the trigger knobs change"
    );
}

/// Back-to-back resumes (checkpoint, resume, checkpoint again, resume
/// again) keep the contract: state round-trips are closed under
/// composition, the long-run operating mode.
#[test]
fn chained_resumes_stay_bit_identical() {
    let cfg = cfg_for(EngineKind::Event, TopologyKind::Star);
    let straight = run_event(&cfg, None);

    let (mut problem, rngs) = make_problem(&cfg);
    let mut z = Vec::new();
    let mut staleness = Vec::new();
    let mut body: Vec<u8>;
    {
        let mut eng = EventEngine::new(&cfg, &mut problem, rngs).unwrap();
        for _ in 0..9 {
            eng.step_round().unwrap();
            z.push(eng.z().iter().map(|v| v.to_bits()).collect());
            staleness.push(eng.staleness().to_vec());
        }
        body = eng.snapshot_body();
    }
    let (mut p2, _) = make_problem(&cfg);
    {
        let mut eng = EventEngine::resume(&cfg, &mut p2, &body).unwrap();
        for _ in 0..11 {
            eng.step_round().unwrap();
            z.push(eng.z().iter().map(|v| v.to_bits()).collect());
            staleness.push(eng.staleness().to_vec());
        }
        body = eng.snapshot_body();
    }
    let (mut p3, _) = make_problem(&cfg);
    let mut eng = EventEngine::resume(&cfg, &mut p3, &body).unwrap();
    while eng.stats().rounds < cfg.iters {
        eng.step_round().unwrap();
        z.push(eng.z().iter().map(|v| v.to_bits()).collect());
        staleness.push(eng.staleness().to_vec());
    }
    assert_eq!(straight.z, z, "chained resumes diverged");
    assert_eq!(straight.staleness, staleness);
    assert_eq!(straight.rng_digest, eng.rng_digest());
    assert_eq!(straight.links, links_of(eng.accounting()));
}

/// A resume under a *different* config identity must be refused by the
/// digest check the runner applies (changing τ mid-run would produce a
/// trajectory belonging to neither plan).
#[test]
fn resume_digest_detects_config_drift() {
    let cfg = cfg_for(EngineKind::Event, TopologyKind::Star);
    let (mut problem, rngs) = make_problem(&cfg);
    let mut eng = EventEngine::new(&cfg, &mut problem, rngs).unwrap();
    for _ in 0..3 {
        eng.step_round().unwrap();
    }
    let meta = eng.snapshot_meta();
    let mut other = cfg.clone();
    other.tau = cfg.tau + 2;
    assert_ne!(
        snapshot::config_resume_digest(&meta.config),
        other.resume_digest(),
        "digest must change when tau changes"
    );
    let mut longer = cfg.clone();
    longer.iters = cfg.iters * 10;
    longer.name = "same-run-more-rounds".into();
    assert_eq!(
        snapshot::config_resume_digest(&meta.config),
        longer.resume_digest(),
        "digest must permit extending the run"
    );
}

/// Structural config mismatches must be caught by `resume` itself even
/// when the caller skips the digest check: wrong fleet size, wrong
/// topology, wrong EF mode.
#[test]
fn resume_rejects_mismatched_state() {
    let cfg = cfg_for(EngineKind::Event, TopologyKind::Tree { fanout: 4 });
    let (mut problem, rngs) = make_problem(&cfg);
    let mut eng = EventEngine::new(&cfg, &mut problem, rngs).unwrap();
    for _ in 0..2 {
        eng.step_round().unwrap();
    }
    let body = eng.snapshot_body();
    drop(eng);

    // topology flip: tier state present, config says star
    let mut star = cfg.clone();
    star.topology = TopologyKind::Star;
    let (mut p2, _) = make_problem(&star);
    assert!(EventEngine::resume(&star, &mut p2, &body).is_err());

    // EF flip
    let mut no_ef = cfg.clone();
    no_ef.error_feedback = false;
    let (mut p3, _) = make_problem(&no_ef);
    assert!(EventEngine::resume(&no_ef, &mut p3, &body).is_err());

    // different fleet
    let mut small = cfg.clone();
    small.problem = ProblemKind::Lasso { m: 20, h: 10, n: 6, rho: 30.0, theta: 0.1 };
    small.p_min = 3;
    let (mut p4, _) = make_problem(&small);
    assert!(EventEngine::resume(&small, &mut p4, &body).is_err());

    // τ change (scheduler state disagrees)
    let mut tau = cfg.clone();
    tau.tau = cfg.tau + 1;
    let (mut p5, _) = make_problem(&tau);
    assert!(EventEngine::resume(&tau, &mut p5, &body).is_err());
}

/// The recorded-timeline bridge: the threaded runtime, driven by a
/// recording instead of wall-clock sleeps, reproduces the event engine's
/// arrival sets and round count exactly.
#[test]
fn threaded_replay_reproduces_recorded_arrival_sets() {
    let mut cfg = presets::ci_lasso();
    cfg.name = "snapshot-parity-bridge".into();
    cfg.engine = EngineKind::Event;
    cfg.iters = 18;
    cfg.mc_trials = 1;
    cfg.eval_every = cfg.iters;
    cfg.tau = 4;
    cfg.p_min = 2;
    // stragglers: the recording must contain genuinely partial rounds
    cfg.link = LinkConfig {
        compute: LatencyModel::Exp(0.004),
        uplink: LatencyModel::Exp(0.006),
        downlink: LatencyModel::None,
        clock_drift: 0.0,
    };
    let (mut problem, rngs) = make_problem(&cfg);
    let mut eng = EventEngine::new(&cfg, &mut problem, rngs).unwrap();
    eng.record_timeline();
    for _ in 0..cfg.iters {
        eng.step_round().unwrap();
    }
    let tl = eng.take_timeline().expect("recording enabled");
    drop(eng);
    assert_eq!(tl.rounds.len(), cfg.iters);
    assert!(
        tl.rounds.iter().any(|r| r.arrivals.len() < 4),
        "recording should contain partial-participation rounds"
    );
    assert!(!tl.events.is_empty(), "recording should carry the event stream");
    // json round-trip before replay (what the CLI file path does)
    let tl =
        qadmm::snapshot::timeline::RecordedTimeline::from_json(&tl.to_json()).unwrap();

    let mut thr = cfg.clone();
    thr.engine = EngineKind::Threaded;
    let (problem, _) = make_problem(&thr);
    let outcome = qadmm::coordinator::run_threaded_replay(
        &thr,
        Box::new(problem),
        FaultSpec::default(),
        &tl,
    )
    .unwrap();
    assert_eq!(outcome.round_arrivals.len(), tl.rounds.len(), "round count");
    for (r, round) in tl.rounds.iter().enumerate() {
        assert_eq!(
            outcome.round_arrivals[r], round.arrivals,
            "replay arrival set diverged at round {r}"
        );
    }
}

/// Replay refuses recordings it cannot honor.
#[test]
fn threaded_replay_validates_inputs() {
    let mut tl = qadmm::snapshot::timeline::RecordedTimeline::new("event", 4, 7);
    tl.push_round(0.0, vec![0, 1, 2, 3], vec![]);
    let mut cfg = presets::ci_lasso();
    cfg.engine = EngineKind::Threaded;
    // wrong fleet size
    let mut big = tl.clone();
    big.n = 9;
    let (p, _) = make_problem(&cfg);
    assert!(qadmm::coordinator::run_threaded_replay(
        &cfg,
        Box::new(p),
        FaultSpec::default(),
        &big
    )
    .is_err());
    // non-star topology
    let mut tiered = cfg.clone();
    tiered.topology = TopologyKind::Tree { fanout: 2 };
    let (p, _) = make_problem(&tiered);
    assert!(qadmm::coordinator::run_threaded_replay(
        &tiered,
        Box::new(p),
        FaultSpec::default(),
        &tl
    )
    .is_err());
    // wrong engine label
    let mut wrong = tl.clone();
    wrong.engine = "seq".into();
    let (p, _) = make_problem(&cfg);
    assert!(qadmm::coordinator::run_threaded_replay(
        &cfg,
        Box::new(p),
        FaultSpec::default(),
        &wrong
    )
    .is_err());
}
