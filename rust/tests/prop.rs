//! Property-based tests with an in-tree generator (proptest is not in the
//! offline crate universe): randomized inputs over many seeds, with the
//! failing seed printed for reproduction.

use qadmm::admm::engine::EventEngine;
use qadmm::admm::scheduler::Scheduler;
use qadmm::admm::sim::{AsyncSim, TrialRngs};
use qadmm::comm::latency::LatencyModel;
use qadmm::comm::message::{INIT_BITS_PER_SCALAR, MSG_HEADER_BYTES};
use qadmm::comm::profile::LinkConfig;
use qadmm::compress::error_feedback::EstimateTracker;
use qadmm::compress::packing::{pack_levels, unpack_levels};
use qadmm::compress::{Compressor, CompressorKind};
use qadmm::config::{presets, OracleConfig, ProblemKind};
use qadmm::problems::accumulator::{ConsensusAccumulator, KahanVec};
use qadmm::snapshot::codec::{Pack, Writer};
use qadmm::problems::lasso::{LassoConfig, LassoProblem};
use qadmm::topology::TopologyKind;
use qadmm::util::rng::Pcg64;

/// Run `f` over `cases` random seeds; panic with the seed on failure.
fn for_all(cases: usize, base: u64, f: impl Fn(&mut Pcg64)) {
    for c in 0..cases {
        let seed = base.wrapping_add(c as u64);
        let mut rng = Pcg64::seed_from_u64(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            panic!("property failed for seed {seed}: {e:?}");
        }
    }
}

fn random_vec(rng: &mut Pcg64) -> Vec<f64> {
    let m = 1 + rng.gen_range(600);
    let scale = 10f64.powf(rng.uniform_f64() * 8.0 - 4.0); // 1e-4 .. 1e4
    match rng.gen_range(4) {
        0 => vec![0.0; m],                                      // degenerate
        1 => (0..m).map(|_| rng.standard_normal() * scale).collect(),
        2 => {
            // sparse
            let mut v = vec![0.0; m];
            for _ in 0..1 + m / 10 {
                let i = rng.gen_range(m);
                v[i] = rng.standard_normal() * scale;
            }
            v
        }
        _ => (0..m).map(|i| ((i as f64) - m as f64 / 2.0) * scale).collect(), // ramp
    }
}

/// The tentpole's correctness contract: the incrementally folded server
/// sum (Kahan + periodic refresh) matches a full recompute of Σ(x̂+û) to
/// ≤ 1e-10 relative error, across random fleet sizes, arrival patterns
/// (random P per round), compressor families, and refresh cadences
/// (including "never"). The banks evolve exactly as in the engines: each
/// arrival commits its dequantized deltas, and the accumulator folds the
/// *same* vectors.
#[test]
fn prop_incremental_consensus_sum_matches_full_recompute() {
    let kinds = [
        CompressorKind::Identity,
        CompressorKind::Identity32,
        CompressorKind::Qsgd { bits: 3 },
        CompressorKind::Qsgd { bits: 8 },
        CompressorKind::Sign,
        CompressorKind::TopK { frac_permille: 150 },
        CompressorKind::RandK { frac_permille: 250 },
    ];
    for_all(40, 202, |rng| {
        let n = 2 + rng.gen_range(16);
        let m = 1 + rng.gen_range(96);
        let refresh = [0usize, 1, 3, 7, 64][rng.gen_range(5)];
        let comp = kinds[rng.gen_range(kinds.len())].build();
        let scale = 10f64.powf(rng.uniform_f64() * 6.0 - 3.0); // 1e-3..1e3

        let mut xhat: Vec<EstimateTracker> = (0..n)
            .map(|_| EstimateTracker::new(rng.normal_vec(m, 0.0, scale), true))
            .collect();
        let mut uhat: Vec<EstimateTracker> = (0..n)
            .map(|_| EstimateTracker::new(rng.normal_vec(m, 0.0, scale), true))
            .collect();
        let mut acc = ConsensusAccumulator::new(m, refresh);
        acc.refresh(xhat.iter().zip(&uhat).map(|(x, u)| (x.estimate(), u.estimate())));

        for round in 1..=25usize {
            // a random arrival set of size P ∈ [1, n]
            let p = 1 + rng.gen_range(n);
            for node in rng.choose_k(n, p) {
                let dx = comp.compress(&rng.normal_vec(m, 0.0, scale), rng);
                let du = comp.compress(&rng.normal_vec(m, 0.0, scale), rng);
                xhat[node].commit_frame(&dx).unwrap();
                uhat[node].commit_frame(&du).unwrap();
                acc.fold_frames(&dx, &du).unwrap();
            }
            if acc.refresh_due(round) {
                acc.refresh(xhat.iter().zip(&uhat).map(|(x, u)| (x.estimate(), u.estimate())));
            }
            // full recompute reference
            let mut full = vec![0.0; m];
            for (x, u) in xhat.iter().zip(&uhat) {
                for (j, f) in full.iter_mut().enumerate() {
                    *f += x.estimate()[j] + u.estimate()[j];
                }
            }
            let norm = full.iter().fold(1.0f64, |a, v| a.max(v.abs()));
            for (j, (s, f)) in acc.sum().iter().zip(&full).enumerate() {
                assert!(
                    (s - f).abs() <= 1e-10 * norm,
                    "round {round} coord {j}: inc={s} full={f} (norm {norm})"
                );
            }
        }
    });
}

/// Drift bound without any refresh: 10k Kahan folds stay within 1e-10
/// relative of a from-scratch recompute — the `refresh_every = 0`
/// configuration is safe on long runs, not just the refreshed default.
#[test]
fn kahan_drift_bounded_over_10k_folds_without_refresh() {
    let (n, m) = (8usize, 64usize);
    let mut rng = Pcg64::seed_from_u64(909);
    let mut xhat: Vec<EstimateTracker> =
        (0..n).map(|_| EstimateTracker::new(rng.normal_vec(m, 0.0, 1.0), true)).collect();
    let mut uhat: Vec<EstimateTracker> =
        (0..n).map(|_| EstimateTracker::new(rng.normal_vec(m, 0.0, 1.0), true)).collect();
    let mut acc = ConsensusAccumulator::new(m, 0); // never refreshed
    acc.refresh(xhat.iter().zip(&uhat).map(|(x, u)| (x.estimate(), u.estimate())));
    let q = CompressorKind::Qsgd { bits: 3 }.build();
    for _ in 0..10_000 {
        let node = rng.gen_range(n);
        let dx = q.compress(&rng.normal_vec(m, 0.0, 0.1), &mut rng);
        let du = q.compress(&rng.normal_vec(m, 0.0, 0.1), &mut rng);
        xhat[node].commit_frame(&dx).unwrap();
        uhat[node].commit_frame(&du).unwrap();
        acc.fold_frames(&dx, &du).unwrap();
    }
    let mut full = vec![0.0; m];
    for (x, u) in xhat.iter().zip(&uhat) {
        for (j, f) in full.iter_mut().enumerate() {
            *f += x.estimate()[j] + u.estimate()[j];
        }
    }
    let norm = full.iter().fold(1.0f64, |a, v| a.max(v.abs()));
    for (s, f) in acc.sum().iter().zip(&full) {
        assert!(
            (s - f).abs() <= 1e-10 * norm,
            "10k-fold drift: inc={s} full={f} (norm {norm})"
        );
    }
}

/// Full Kahan state (sum + compensation) as bytes, for bitwise equality
/// asserts that see through `-0.0 == 0.0` and pending-compensation drift.
fn kahan_bytes(k: &KahanVec) -> Vec<u8> {
    let mut w = Writer::new();
    k.pack(&mut w);
    w.into_inner()
}

/// Tentpole bitwise contract: folding a wire frame straight into a Kahan
/// accumulator (`fold_into`) is bit-for-bit identical to materializing the
/// dequantized vector and dense-adding it — across every compressor kind,
/// random dimensions/scales, nonzero starting states with pending
/// compensation, and non-finite-poisoned inputs (the compressors sanitize
/// those; the two fold paths must agree either way). The zero-skip
/// invariant in `kahan_add` is what makes the O(k) sparse fold exact.
#[test]
fn prop_fused_fold_into_bitwise_matches_materialized_fold() {
    let kinds = [
        CompressorKind::Identity,
        CompressorKind::Identity32,
        CompressorKind::Qsgd { bits: 2 },
        CompressorKind::Qsgd { bits: 3 },
        CompressorKind::Qsgd { bits: 11 },
        CompressorKind::Sign,
        CompressorKind::TopK { frac_permille: 120 },
        CompressorKind::RandK { frac_permille: 250 },
    ];
    let poisons = [f64::NAN, f64::INFINITY, f64::NEG_INFINITY];
    for_all(60, 2424, |rng| {
        let mut delta = random_vec(rng);
        let m = delta.len();
        if rng.gen_range(2) == 0 {
            for _ in 0..1 + rng.gen_range(m.min(4)) {
                let i = rng.gen_range(m);
                delta[i] = poisons[rng.gen_range(poisons.len())];
            }
        }
        // ill-conditioned starting state: a huge and a tiny vector leave
        // nonzero compensation terms behind, so the assert also covers the
        // "fold into dirty Kahan state" case the server hot path lives in
        let big: Vec<f64> = (0..m).map(|_| rng.standard_normal() * 1e12).collect();
        let small: Vec<f64> = (0..m).map(|_| rng.standard_normal()).collect();
        for kind in kinds {
            let c = kind.build().compress(&delta, rng);
            let mut fused = KahanVec::zeros(m);
            let mut dense = KahanVec::zeros(m);
            for acc in [&mut fused, &mut dense] {
                acc.add(&big);
                acc.add(&small);
            }
            c.fold_into(&mut fused).unwrap();
            dense.add(&c.dequantized().unwrap());
            assert_eq!(
                kahan_bytes(&fused),
                kahan_bytes(&dense),
                "fused fold diverged for kind={} m={m}",
                kind.label()
            );
        }
    });
}

/// Coordinate-sharded folds are a pure range partition of per-coordinate
/// Kahan state: any shard count (including the serial shards=1 and more
/// shards than the host has cores) produces bitwise-identical sum *and*
/// compensation to the unsharded kernel.
#[test]
fn prop_sharded_fold_bitwise_identical_across_shard_counts() {
    for_all(40, 2525, |rng| {
        let m = 1 + rng.gen_range(2000);
        let a: Vec<f64> = (0..m).map(|_| rng.standard_normal() * 1e9).collect();
        let b: Vec<f64> = (0..m).map(|_| rng.standard_normal()).collect();
        let c: Vec<f64> = (0..m).map(|_| rng.standard_normal() * 1e-6).collect();
        let mut serial = KahanVec::zeros(m);
        serial.fold2(&a, &b);
        serial.fold2(&c, &a);
        let want = kahan_bytes(&serial);
        for shards in [1usize, 3, 8] {
            let mut k = KahanVec::zeros(m);
            k.fold2_sharded(&a, &b, shards);
            k.fold2_sharded(&c, &a, shards);
            assert_eq!(kahan_bytes(&k), want, "shards={shards} m={m}");
        }
    });
}

#[test]
fn prop_packing_roundtrips() {
    for_all(300, 11, |rng| {
        let q = 2 + rng.gen_range(13) as u8; // 2..=14
        let s = (1i32 << (q - 1)) - 1;
        let m = 1 + rng.gen_range(400);
        let levels: Vec<i32> =
            (0..m).map(|_| rng.gen_range((2 * s + 1) as usize) as i32 - s).collect();
        let bytes = pack_levels(&levels, q);
        assert_eq!(unpack_levels(&bytes, m, q).unwrap(), levels);
    });
}

#[test]
fn prop_decode_equals_dequantized_for_every_compressor() {
    let kinds = [
        CompressorKind::Identity,
        CompressorKind::Qsgd { bits: 2 },
        CompressorKind::Qsgd { bits: 3 },
        CompressorKind::Qsgd { bits: 11 },
        CompressorKind::Sign,
        CompressorKind::TopK { frac_permille: 37 },
        CompressorKind::RandK { frac_permille: 211 },
    ];
    for_all(150, 22, |rng| {
        let delta = random_vec(rng);
        for kind in kinds {
            let c = kind.build();
            let out = c.compress(&delta, rng);
            let decoded = c.decode(&out.wire, delta.len()).unwrap();
            assert_eq!(decoded, out.dequantized().unwrap(), "{}", kind.label());
        }
    });
}

#[test]
fn prop_qsgd_error_bounded_and_sign_preserving() {
    for_all(200, 33, |rng| {
        let q = 2 + rng.gen_range(7) as u8;
        let comp = CompressorKind::Qsgd { bits: q }.build();
        let delta = random_vec(rng);
        let out = comp.compress(&delta, rng);
        let norm = delta.iter().fold(0.0f64, |m, x| m.max(x.abs()));
        let s = ((1i32 << (q - 1)) - 1) as f64;
        for (d, v) in delta.iter().zip(&out.dequantized().unwrap()) {
            assert!((d - v).abs() <= norm / s * (1.0 + 1e-12) + 1e-300);
            assert!(*v == 0.0 || v.signum() == d.signum());
        }
    });
}

#[test]
fn prop_scheduler_never_exceeds_staleness_bound() {
    for_all(100, 44, |rng| {
        let n = 2 + rng.gen_range(30);
        let tau = 1 + rng.gen_range(6);
        let p_min = 1 + rng.gen_range(n);
        let p_sel = rng.uniform_f64();
        let mut sched = Scheduler::new(n, tau, p_min);
        let mut active = vec![true; n];
        let mut last_active = vec![0usize; n];
        for round in 1..=120usize {
            let mut oracle_rng = rng.fork(round as u64);
            let next = sched.advance(&active, || {
                (0..n).map(|_| oracle_rng.bernoulli(p_sel)).collect()
            });
            assert!(next.iter().filter(|&&a| a).count() >= p_min);
            for i in 0..n {
                if next[i] {
                    last_active[i] = round;
                } else {
                    // the bounded-delay guarantee
                    assert!(
                        round - last_active[i] <= tau - 1 || tau == 1,
                        "node {i} stale for {} with tau={tau}",
                        round - last_active[i]
                    );
                }
            }
            active = next;
        }
    });
}

/// Both in-process engines uphold the paper's scheduling guarantees for
/// randomized (n, τ, P): every consensus round incorporates ≥ P arrivals,
/// and no node's staleness ever exceeds τ−1 (the server force-waits). The
/// event engine additionally runs under heterogeneous Exp delays, so the
/// invariants are exercised on a genuinely asynchronous timeline, not just
/// the lockstep one.
#[test]
fn prop_engines_enforce_arrival_and_staleness_bounds() {
    for_all(10, 77, |rng| {
        let n = 2 + rng.gen_range(10);
        let tau = 1 + rng.gen_range(4);
        let p_min = 1 + rng.gen_range(n);
        let mut cfg = presets::ci_lasso();
        cfg.name = format!("prop-n{n}-tau{tau}-p{p_min}");
        cfg.problem = ProblemKind::Lasso { m: 8, h: 5, n, rho: 20.0, theta: 0.1 };
        cfg.tau = tau;
        cfg.p_min = p_min;
        cfg.iters = 30;
        cfg.mc_trials = 1;
        cfg.eval_every = 1;
        cfg.seed = rng.next_u64();
        cfg.oracle = OracleConfig {
            p_slow: rng.uniform_f64(),
            p_fast: rng.uniform_f64(),
            regroup_each_call: rng.bernoulli(0.5),
        };
        let lcfg = LassoConfig { m: 8, h: 5, n, rho: 20.0, theta: 0.1 };

        // sequential simulator
        let mut rngs = TrialRngs::new(cfg.seed);
        let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
        p.set_reference_optimum(1.0); // metric value irrelevant here
        let mut sim = AsyncSim::new(&cfg, &mut p, rngs).unwrap();
        for _ in 0..cfg.iters {
            sim.step().unwrap();
            let active = sim.recorder().last().unwrap().active_nodes;
            assert!(active >= p_min, "sim round with {active} < P={p_min}");
            let max_d = sim.staleness().iter().copied().max().unwrap();
            assert!(max_d + 1 <= tau, "sim staleness {max_d} breaks tau={tau}");
        }

        // event engine under straggler delays on *every* link leg: delayed
        // compute, uplink AND downlink, plus drifted node clocks — the
        // scheduling guarantees may not depend on the ẑ broadcast landing
        // promptly
        cfg.link = LinkConfig {
            compute: LatencyModel::Exp(0.01),
            uplink: LatencyModel::Exp(0.01),
            downlink: LatencyModel::Exp(0.02),
            clock_drift: 0.2,
        };
        cfg.engine = qadmm::config::EngineKind::Event;
        let mut rngs = TrialRngs::new(cfg.seed);
        let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
        p.set_reference_optimum(1.0);
        let mut eng = EventEngine::new(&cfg, &mut p, rngs).unwrap();
        for _ in 0..cfg.iters {
            eng.step_round().unwrap();
            let max_d = eng.staleness().iter().copied().max().unwrap();
            assert!(max_d + 1 <= tau, "engine staleness {max_d} breaks tau={tau}");
        }
        let stats = eng.stats();
        assert_eq!(stats.rounds, cfg.iters);
        let min_arrivals = stats.min_arrivals.expect("rounds fired");
        assert!(min_arrivals >= p_min, "engine fired on {min_arrivals} < P={p_min}");
        assert!(stats.max_staleness + 1 <= tau);
        assert!(stats.virtual_time >= 0.0 && stats.virtual_time.is_finite());
    });
}

/// A nonzero downlink delay must measurably change the z-trajectory: the
/// ẑ broadcast lands late and per-node, so the server fires on arrival
/// batches the instant-delivery run never assembles. Identity compression
/// keeps both runs free of quantizer noise, so any divergence is
/// attributable to delivery timing alone.
#[test]
fn prop_downlink_delay_changes_z_trajectory() {
    for_all(8, 99, |rng| {
        let n = 4 + rng.gen_range(8);
        let tau = 3 + rng.gen_range(3);
        let mut cfg = presets::ci_lasso();
        cfg.name = format!("prop-downlink-n{n}-tau{tau}");
        cfg.problem = ProblemKind::Lasso { m: 8, h: 5, n, rho: 20.0, theta: 0.1 };
        cfg.compressor = CompressorKind::Identity;
        cfg.tau = tau;
        cfg.p_min = 1;
        cfg.iters = 25;
        cfg.mc_trials = 1;
        cfg.eval_every = 1;
        cfg.seed = rng.next_u64();
        cfg.engine = qadmm::config::EngineKind::Event;
        let lcfg = LassoConfig { m: 8, h: 5, n, rho: 20.0, theta: 0.1 };

        let run = |link: LinkConfig| {
            let mut cfg = cfg.clone();
            cfg.link = link;
            let mut rngs = TrialRngs::new(cfg.seed);
            let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
            p.set_reference_optimum(1.0);
            let mut eng = EventEngine::new(&cfg, &mut p, rngs).unwrap();
            let mut zs = Vec::new();
            for _ in 0..cfg.iters {
                eng.step_round().unwrap();
                zs.push(eng.z().to_vec());
                let max_d = eng.staleness().iter().copied().max().unwrap();
                assert!(max_d + 1 <= tau, "staleness bound broken");
            }
            zs
        };
        let instant = run(LinkConfig::none());
        let delayed = run(LinkConfig {
            compute: LatencyModel::None,
            uplink: LatencyModel::None,
            downlink: LatencyModel::Exp(0.1),
            clock_drift: 0.0,
        });
        assert_ne!(
            instant, delayed,
            "Exp downlink delay left all {} rounds bit-identical",
            cfg.iters
        );
    });
}

/// Hierarchical fan-in accounting identity: a tree run's total wire bits
/// decompose exactly into per-link charges — init (leaf + aggregator +
/// broadcast), one leaf-hop frame per dispatch, one aggregator-hop frame
/// per forward, one broadcast frame per round per leaf — under random
/// fanouts, per-tier thresholds and compressor families whose frame size
/// is a function of m alone (identity / qsgd / sign; the sparsifiers'
/// frames are value-dependent, so they cannot be predicted from counts).
#[test]
fn prop_tree_wire_bits_equal_sum_of_per_link_charges() {
    let kinds = [
        CompressorKind::Identity,
        CompressorKind::Qsgd { bits: 3 },
        CompressorKind::Qsgd { bits: 8 },
        CompressorKind::Sign,
    ];
    for_all(12, 404, |rng| {
        let n = 4 + rng.gen_range(12);
        let m = 4 + rng.gen_range(24);
        let fanout = 1 + rng.gen_range(n);
        let p_tier = 1 + rng.gen_range(fanout.min(4));
        let kind = kinds[rng.gen_range(kinds.len())];
        let mut cfg = presets::ci_lasso();
        cfg.name = format!("prop-treebits-n{n}-f{fanout}");
        cfg.problem = ProblemKind::Lasso { m, h: 3, n, rho: 20.0, theta: 0.1 };
        cfg.compressor = kind;
        cfg.tau = 3;
        cfg.p_min = 1 + rng.gen_range(n);
        cfg.iters = 15;
        cfg.mc_trials = 1;
        cfg.eval_every = cfg.iters;
        cfg.seed = rng.next_u64();
        cfg.engine = qadmm::config::EngineKind::Event;
        cfg.topology = TopologyKind::Tree { fanout };
        cfg.p_tier = p_tier;
        cfg.link = LinkConfig {
            compute: LatencyModel::Exp(0.01),
            uplink: LatencyModel::Exp(0.01),
            downlink: LatencyModel::Exp(0.01),
            clock_drift: 0.1,
        };
        let n_aggs = cfg.topology.n_aggregators(n);
        let lcfg = LassoConfig { m, h: 3, n, rho: 20.0, theta: 0.1 };
        let mut rngs = TrialRngs::new(cfg.seed);
        let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
        p.set_reference_optimum(1.0);
        let mut eng = EventEngine::new(&cfg, &mut p, rngs).unwrap();
        for _ in 0..cfg.iters {
            eng.step_round().unwrap();
        }
        let stats = eng.stats();

        // frame size is a pure function of m for these families
        let frame_bits = cfg
            .compressor
            .build()
            .compress(&vec![0.0; m], &mut Pcg64::seed_from_u64(0))
            .wire_bits();
        let hdr = MSG_HEADER_BYTES * 8;
        let acc = eng.accounting();
        // per-link message counts: 1 init frame per link, then update /
        // forward frames (a dispatch still computing or on the wire at run
        // end has not been charged yet, so the counters are the truth)
        let leaf_msgs: u64 = (0..n).map(|i| acc.link(i).uplink_msgs - 1).sum();
        let agg_msgs: u64 = (0..n_aggs).map(|g| acc.link(n + g).uplink_msgs - 1).sum();
        assert_eq!(agg_msgs, stats.agg_forwards, "forward count vs aggregator links");
        assert!(leaf_msgs <= stats.dispatches, "more charges than dispatches");
        let init = (n + n_aggs) as u64 * (hdr + 2 * m as u64 * INIT_BITS_PER_SCALAR)
            + n as u64 * (hdr + m as u64 * INIT_BITS_PER_SCALAR);
        // init + leaf-hop frames + aggregator-hop frames + broadcasts
        let expect = init
            + leaf_msgs * (hdr + 2 * frame_bits)
            + stats.agg_forwards * (hdr + 2 * frame_bits)
            + (stats.rounds as u64) * n as u64 * (hdr + frame_bits);
        assert_eq!(
            acc.total_bits(),
            expect,
            "n={n} fanout={fanout} p_tier={p_tier} kind={} (msgs={} forwards={} rounds={})",
            kind.label(),
            leaf_msgs,
            stats.agg_forwards,
            stats.rounds
        );
        assert!(stats.agg_forwards > 0, "tree run produced no aggregator traffic");
    });
}

/// Gossip conservation: at every point of a randomized-relay run, the mass
/// Σ_g(ŝ_g + pending_g) tracked by the tier equals Σ_leaves(x̂ᵢ + ûᵢ) to
/// Kahan precision — re-quantization moves error into the pending residual,
/// it never creates or destroys Σ(x̂+û) mass — and the server's incremental
/// sum s tracks the committed part Σ_g ŝ_g.
#[test]
fn prop_gossip_rounds_preserve_mass() {
    for_all(10, 505, |rng| {
        let n = 4 + rng.gen_range(10);
        let m = 4 + rng.gen_range(24);
        let k = 1 + rng.gen_range(n.min(5));
        let mut cfg = presets::ci_lasso();
        cfg.name = format!("prop-gossipmass-n{n}-k{k}");
        cfg.problem = ProblemKind::Lasso { m, h: 3, n, rho: 20.0, theta: 0.1 };
        cfg.compressor = CompressorKind::Qsgd { bits: 3 };
        cfg.tau = 3;
        cfg.p_min = 1 + rng.gen_range(n);
        cfg.iters = 20;
        cfg.mc_trials = 1;
        cfg.eval_every = cfg.iters;
        cfg.seed = rng.next_u64();
        cfg.engine = qadmm::config::EngineKind::Event;
        cfg.topology = TopologyKind::Gossip { k };
        cfg.p_tier = 1 + rng.gen_range(3);
        cfg.link = LinkConfig {
            compute: LatencyModel::Exp(0.01),
            uplink: LatencyModel::Exp(0.02),
            downlink: LatencyModel::Exp(0.01),
            clock_drift: 0.1,
        };
        let lcfg = LassoConfig { m, h: 3, n, rho: 20.0, theta: 0.1 };
        let mut rngs = TrialRngs::new(cfg.seed);
        let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
        p.set_reference_optimum(1.0);
        let mut eng = EventEngine::new(&cfg, &mut p, rngs).unwrap();
        for round in 0..cfg.iters {
            eng.step_round().unwrap();
            // Σ_leaves(x̂+û): what the tier is supposed to be carrying.
            // (Compare through a Kahan fold so the reference itself does
            // not drown the bound in naive-summation error.)
            let mut bank_mass = ConsensusAccumulator::new(m, 0);
            for i in 0..n {
                let (xi, ui) = (eng.x_estimate(i), eng.u_estimate(i));
                bank_mass.fold(&xi, &ui);
            }
            let tracked = eng.fan_in_tracked_mass().expect("gossip run has a tier");
            let norm = bank_mass.sum().iter().fold(1.0f64, |a, v| a.max(v.abs()));
            for (j, (t, b)) in tracked.iter().zip(bank_mass.sum()).enumerate() {
                assert!(
                    (t - b).abs() <= 1e-10 * norm,
                    "round {round} coord {j}: tier mass {t} vs bank mass {b}"
                );
            }
        }
        assert!(eng.stats().agg_forwards > 0, "gossip run produced no relay traffic");
    });
}

/// decode() must be total: for *every* compressor family, truncating the
/// frame yields Err (never a panic, never a wrong-length vector), and
/// arbitrary byte corruption yields Err or a correct-length vector.
#[test]
fn prop_decode_on_truncated_or_corrupt_frames_never_panics() {
    let kinds = [
        CompressorKind::Identity,
        CompressorKind::Identity32,
        CompressorKind::Qsgd { bits: 2 },
        CompressorKind::Qsgd { bits: 3 },
        CompressorKind::Qsgd { bits: 11 },
        CompressorKind::Sign,
        CompressorKind::TopK { frac_permille: 120 },
        CompressorKind::RandK { frac_permille: 200 },
    ];
    for_all(40, 88, |rng| {
        let m = 1 + rng.gen_range(96);
        let delta: Vec<f64> = (0..m).map(|_| rng.standard_normal() * 3.0).collect();
        for kind in kinds {
            let c = kind.build();
            let wire = c.compress(&delta, rng).wire;
            // every strict prefix is rejected
            for cut in 0..wire.len() {
                assert!(
                    c.decode(&wire[..cut], m).is_err(),
                    "{}: truncation to {cut}/{} bytes accepted",
                    kind.label(),
                    wire.len()
                );
            }
            // random single-bit corruption never panics
            for _ in 0..24 {
                let mut w = wire.clone();
                let i = rng.gen_range(w.len());
                w[i] ^= 1 << rng.gen_range(8);
                match c.decode(&w, m) {
                    Ok(v) => assert_eq!(v.len(), m, "{}", kind.label()),
                    Err(_) => {}
                }
            }
        }
    });
}

#[test]
fn prop_wire_decode_rejects_corruption_or_stays_sane() {
    // flipping bytes must never panic; it either errors or returns a
    // finite-length vector (decoder robustness)
    for_all(150, 55, |rng| {
        let delta = random_vec(rng);
        let comp = CompressorKind::Qsgd { bits: 3 }.build();
        let mut wire = comp.compress(&delta, rng).wire;
        let idx = rng.gen_range(wire.len());
        wire[idx] ^= 1 << rng.gen_range(8);
        match comp.decode(&wire, delta.len()) {
            Ok(v) => assert_eq!(v.len(), delta.len()),
            Err(_) => {}
        }
    });
}

/// Snapshot round-trip (ISSUE 5 satellite): serialize `RunState` at a
/// random round under random n/P/τ/compressor/topology (event engine under
/// nonzero delays on every leg, so the snapshot catches events in flight),
/// restore onto a seed-re-derived problem, and require the continued
/// trajectory — z, staleness, per-link wire bits, final RNG states — to be
/// bit-exact against the uninterrupted run.
#[test]
fn prop_snapshot_resume_continues_bit_exact() {
    let kinds = [
        CompressorKind::Identity,
        CompressorKind::Qsgd { bits: 3 },
        CompressorKind::Qsgd { bits: 8 },
        CompressorKind::Sign,
        CompressorKind::TopK { frac_permille: 150 },
        CompressorKind::RandK { frac_permille: 250 },
    ];
    for_all(8, 707, |rng| {
        let n = 3 + rng.gen_range(8);
        let m = 4 + rng.gen_range(16);
        let tau = 2 + rng.gen_range(3);
        let p_min = 1 + rng.gen_range(n);
        let iters = 12 + rng.gen_range(10);
        let k = 1 + rng.gen_range(iters - 1);
        let mut cfg = presets::ci_lasso();
        cfg.name = format!("prop-snap-n{n}-tau{tau}-p{p_min}-k{k}");
        cfg.problem = ProblemKind::Lasso { m, h: 4, n, rho: 25.0, theta: 0.1 };
        cfg.compressor = kinds[rng.gen_range(kinds.len())];
        cfg.tau = tau;
        cfg.p_min = p_min;
        cfg.iters = iters;
        cfg.mc_trials = 1;
        cfg.eval_every = 1;
        cfg.consensus_refresh_every = [0usize, 1, 5][rng.gen_range(3)];
        cfg.seed = rng.next_u64() >> 12; // keep header json integer-exact
        cfg.topology = match rng.gen_range(3) {
            0 => TopologyKind::Star,
            1 => TopologyKind::Tree { fanout: 1 + rng.gen_range(n) },
            _ => TopologyKind::Gossip { k: 1 + rng.gen_range(n.min(4)) },
        };
        cfg.p_tier = 1 + rng.gen_range(3);
        cfg.engine = qadmm::config::EngineKind::Event;
        cfg.link = LinkConfig {
            compute: LatencyModel::Exp(0.01),
            uplink: LatencyModel::Exp(0.01),
            downlink: LatencyModel::Exp(0.015),
            clock_drift: 0.1,
        };
        let lcfg = LassoConfig { m, h: 4, n, rho: 25.0, theta: 0.1 };

        let make = |cfg: &qadmm::config::ExperimentConfig| {
            let mut rngs = TrialRngs::new(cfg.seed);
            let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
            p.set_reference_optimum(1.0);
            (p, rngs)
        };

        // straight run
        let (mut p1, rngs1) = make(&cfg);
        let mut straight = EventEngine::new(&cfg, &mut p1, rngs1).unwrap();
        let mut z_straight: Vec<Vec<u64>> = Vec::new();
        for _ in 0..iters {
            straight.step_round().unwrap();
            z_straight.push(straight.z().iter().map(|v| v.to_bits()).collect());
        }

        // interrupted at k + resumed through the full container
        let (mut p2, rngs2) = make(&cfg);
        let mut eng = EventEngine::new(&cfg, &mut p2, rngs2).unwrap();
        let mut z_resumed: Vec<Vec<u64>> = Vec::new();
        for _ in 0..k {
            eng.step_round().unwrap();
            z_resumed.push(eng.z().iter().map(|v| v.to_bits()).collect());
        }
        let bytes = qadmm::snapshot::encode(&eng.snapshot_meta(), &eng.snapshot_body());
        drop(eng);
        let (meta, body) = qadmm::snapshot::decode(&bytes).unwrap();
        assert_eq!(meta.round, k);
        let (mut p3, _) = make(&cfg);
        let mut eng = EventEngine::resume(&cfg, &mut p3, &body).unwrap();
        while eng.stats().rounds < iters {
            eng.step_round().unwrap();
            z_resumed.push(eng.z().iter().map(|v| v.to_bits()).collect());
        }

        assert_eq!(z_straight, z_resumed, "{}: z diverged after resume", cfg.name);
        assert_eq!(
            straight.staleness(),
            eng.staleness(),
            "{}: staleness diverged",
            cfg.name
        );
        assert_eq!(straight.rng_digest(), eng.rng_digest(), "{}: rng states", cfg.name);
        for i in 0..straight.accounting().n_nodes() {
            let (a, b) = (straight.accounting().link(i), eng.accounting().link(i));
            assert_eq!(
                (a.uplink_bits, a.downlink_bits, a.uplink_msgs, a.downlink_msgs),
                (b.uplink_bits, b.downlink_bits, b.uplink_msgs, b.downlink_msgs),
                "{}: link {i} wire bits diverged",
                cfg.name
            );
        }
    });
}

/// Snapshot decode totality (mirrors the wire-frame truncation/corruption
/// props): every strict prefix of a real snapshot container is `Err`, and
/// arbitrary single-bit corruption is `Err` or a clean decode — never a
/// panic, never an unbounded allocation. The raw body (checksum stripped)
/// is also fed straight to `EventEngine::resume`, which must likewise
/// error or succeed without panicking.
#[test]
fn prop_snapshot_decode_on_truncated_or_corrupt_bytes_never_panics() {
    let mut cfg = presets::ci_lasso();
    cfg.name = "prop-snap-totality".into();
    cfg.engine = qadmm::config::EngineKind::Event;
    cfg.iters = 6;
    cfg.mc_trials = 1;
    cfg.eval_every = 1;
    cfg.topology = TopologyKind::Tree { fanout: 2 };
    cfg.link = LinkConfig {
        compute: LatencyModel::Exp(0.01),
        uplink: LatencyModel::Exp(0.01),
        downlink: LatencyModel::Exp(0.01),
        clock_drift: 0.1,
    };
    let lcfg = match cfg.problem {
        ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
        _ => unreachable!(),
    };
    let mut rngs = TrialRngs::new(cfg.seed);
    let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
    p.set_reference_optimum(1.0);
    let mut eng = EventEngine::new(&cfg, &mut p, rngs).unwrap();
    for _ in 0..cfg.iters {
        eng.step_round().unwrap();
    }
    let meta = eng.snapshot_meta();
    let body = eng.snapshot_body();
    drop(eng);
    let container = qadmm::snapshot::encode(&meta, &body);

    // every strict prefix of the container is rejected (sampled stride +
    // the interesting boundaries, so the loop stays O(container))
    let stride = (container.len() / 192).max(1);
    let mut cuts: Vec<usize> = (0..container.len()).step_by(stride).collect();
    cuts.extend([0, 1, 7, 8, 12, container.len() - 9, container.len() - 1]);
    for cut in cuts {
        assert!(
            qadmm::snapshot::decode(&container[..cut]).is_err(),
            "container prefix of {cut}/{} bytes accepted",
            container.len()
        );
    }

    // random bit flips across the container: Err or clean decode
    let mut flip_rng = Pcg64::seed_from_u64(31337);
    for _ in 0..200 {
        let mut bad = container.clone();
        let i = flip_rng.gen_range(bad.len());
        bad[i] ^= 1 << flip_rng.gen_range(8);
        let _ = qadmm::snapshot::decode(&bad);
    }

    // raw-body abuse (checksum bypassed): truncations and flips straight
    // into resume() — must error or produce a usable engine, never panic
    let mut p2 = LassoProblem::generate(lcfg, &mut TrialRngs::new(cfg.seed).data).unwrap();
    p2.set_reference_optimum(1.0);
    for cut in (0..body.len()).step_by((body.len() / 96).max(1)) {
        assert!(
            EventEngine::resume(&cfg, &mut p2, &body[..cut]).is_err(),
            "truncated body of {cut}/{} bytes resumed",
            body.len()
        );
    }
    for _ in 0..120 {
        let mut bad = body.clone();
        let i = flip_rng.gen_range(bad.len());
        bad[i] ^= 1 << flip_rng.gen_range(8);
        let _ = EventEngine::resume(&cfg, &mut p2, &bad);
    }
}

/// Totality on poisoned inputs (the bugfix satellites): every compressor
/// family must accept deltas containing NaN/±inf without panicking — the
/// TopK comparator and the QSGD norm were the historical offenders — and
/// the dequantized output it commits into the EF banks must be entirely
/// finite (a single NaN there poisons x̂ forever through the telescoped
/// estimate stream). The wire frame must still decode to exactly the
/// sanitized dequantized vector.
#[test]
fn prop_compressors_total_on_non_finite_inputs() {
    let kinds = [
        CompressorKind::Identity,
        CompressorKind::Identity32,
        CompressorKind::Qsgd { bits: 2 },
        CompressorKind::Qsgd { bits: 3 },
        CompressorKind::Qsgd { bits: 11 },
        CompressorKind::Sign,
        CompressorKind::TopK { frac_permille: 120 },
        CompressorKind::RandK { frac_permille: 250 },
    ];
    let poisons = [f64::NAN, f64::INFINITY, f64::NEG_INFINITY];
    for_all(60, 1212, |rng| {
        let mut delta = random_vec(rng);
        // poison 1..=min(m, 5) random coordinates (possibly all of a tiny vec)
        let m = delta.len();
        for _ in 0..1 + rng.gen_range(m.min(5)) {
            let i = rng.gen_range(m);
            delta[i] = poisons[rng.gen_range(poisons.len())];
        }
        for kind in kinds {
            let c = kind.build();
            let out = c.compress(&delta, rng);
            assert_eq!(out.frame_dim().unwrap(), m, "{}", kind.label());
            let dq = out.dequantized().unwrap();
            for (j, v) in dq.iter().enumerate() {
                assert!(
                    v.is_finite(),
                    "{}: non-finite dequantized[{j}] = {v} leaked into the EF bank",
                    kind.label()
                );
            }
            let decoded = c.decode(&out.wire, m).unwrap();
            assert_eq!(decoded, dq, "{}", kind.label());
        }
    });
}

/// Trigger liveness at δ → ∞ (the wedge hazard the ISSUE calls out): with
/// a dead-band no delta can ever exceed, every dispatch is skipped — yet
/// the server must keep firing rounds (a skip is an arrival for the P/τ
/// trigger, and the τ−1 force-wait drags silent nodes in), the staleness
/// bound must hold, and the uplink books must show **exactly** the init
/// exchange: zero steady-state uplink bits, zero steady-state uplink
/// messages, on every node link of both in-process runtimes.
#[test]
fn prop_trigger_dead_band_liveness_and_zero_steady_state_uplink() {
    for_all(10, 1313, |rng| {
        let n = 2 + rng.gen_range(8);
        let m = 4 + rng.gen_range(12);
        let tau = 2 + rng.gen_range(4);
        let p_min = 1 + rng.gen_range(n);
        let mut cfg = presets::ci_lasso();
        cfg.name = format!("prop-trigger-n{n}-tau{tau}-p{p_min}");
        cfg.problem = ProblemKind::Lasso { m, h: 4, n, rho: 25.0, theta: 0.1 };
        cfg.compressor = CompressorKind::Qsgd { bits: 4 };
        cfg.tau = tau;
        cfg.p_min = p_min;
        cfg.iters = 20;
        cfg.mc_trials = 1;
        cfg.eval_every = 1;
        cfg.seed = rng.next_u64();
        cfg.trigger.delta = 1e300; // no finite delta passes the gate
        cfg.trigger.adapt = rng.bernoulli(0.5);
        let lcfg = LassoConfig { m, h: 4, n, rho: 25.0, theta: 0.1 };
        let hdr = MSG_HEADER_BYTES * 8;
        let init_bits = hdr + 2 * m as u64 * INIT_BITS_PER_SCALAR;

        // sequential simulator
        let mut rngs = TrialRngs::new(cfg.seed);
        let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
        p.set_reference_optimum(1.0);
        let mut sim = AsyncSim::new(&cfg, &mut p, rngs).unwrap();
        for _ in 0..cfg.iters {
            sim.step().unwrap();
            let active = sim.recorder().last().unwrap().active_nodes;
            assert!(active >= p_min, "sim wedged: round fired on {active} < P");
            let max_d = sim.staleness().iter().copied().max().unwrap();
            assert!(max_d + 1 <= tau, "sim staleness {max_d} breaks tau={tau}");
        }
        assert!(sim.trigger().skipped() > 0, "nothing was dead-banded");
        for i in 0..n {
            let l = sim.accounting().link(i);
            assert_eq!(
                (l.uplink_bits, l.uplink_msgs),
                (init_bits, 1),
                "sim node {i}: steady-state uplink traffic under an infinite dead-band"
            );
        }

        // event engine under delays on every leg
        cfg.engine = qadmm::config::EngineKind::Event;
        cfg.link = LinkConfig {
            compute: LatencyModel::Exp(0.01),
            uplink: LatencyModel::Exp(0.01),
            downlink: LatencyModel::Exp(0.015),
            clock_drift: 0.1,
        };
        let mut rngs = TrialRngs::new(cfg.seed);
        let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
        p.set_reference_optimum(1.0);
        let mut eng = EventEngine::new(&cfg, &mut p, rngs).unwrap();
        for _ in 0..cfg.iters {
            eng.step_round().unwrap();
            let max_d = eng.staleness().iter().copied().max().unwrap();
            assert!(max_d + 1 <= tau, "engine staleness {max_d} breaks tau={tau}");
        }
        let stats = eng.stats();
        assert_eq!(stats.rounds, cfg.iters, "engine wedged under the dead-band");
        assert!(stats.min_arrivals.expect("rounds fired") >= p_min);
        for i in 0..n {
            let l = eng.accounting().link(i);
            assert_eq!(
                (l.uplink_bits, l.uplink_msgs),
                (init_bits, 1),
                "engine node {i}: steady-state uplink traffic under an infinite dead-band"
            );
        }
    });
}

/// Million-node tentpole, timeline half: the calendar queue pops the exact
/// `(time, seq, kind)` stream a reference binary heap produces, under
/// randomized interleavings of pushes and pops that include equal-time
/// bursts (order falls back to seq alone), far-future outliers (overflow
/// + year re-anchoring) and full drains (shrink rebuilds). The engines'
/// determinism contract rides on this order being exact, not approximate.
#[test]
fn prop_calendar_queue_pops_identical_stream_to_reference_heap() {
    use qadmm::admm::events::{Event, EventKind, EventQueue};
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    fn random_kind(rng: &mut Pcg64) -> EventKind {
        match rng.gen_range(4) {
            0 => EventKind::ComputeDone { node: rng.gen_range(64) },
            1 => EventKind::MsgArrive { node: rng.gen_range(64) },
            2 => EventKind::DownlinkArrive { node: rng.gen_range(64) },
            _ => EventKind::AggregateArrive { agg: rng.gen_range(8) },
        }
    }

    for_all(25, 4242, |rng| {
        let mut q = EventQueue::new();
        let mut reference: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
        // `now` advances exactly like the engine's virtual clock: pushes
        // schedule at now + delay, so times never go behind the frontier
        let mut now = 0.0f64;
        let pop_both = |q: &mut EventQueue,
                            reference: &mut BinaryHeap<Reverse<Event>>,
                            now: &mut f64| {
            let got = q.pop();
            let want = reference.pop().map(|Reverse(e)| e);
            match (got, want) {
                (Some(g), Some(w)) => {
                    assert_eq!(
                        (g.time.to_bits(), g.seq),
                        (w.time.to_bits(), w.seq),
                        "calendar ({}, {}) vs heap ({}, {})",
                        g.time,
                        g.seq,
                        w.time,
                        w.seq
                    );
                    assert_eq!(g.kind, w.kind, "kind diverged at seq {}", g.seq);
                    *now = g.time;
                }
                (None, None) => {}
                (g, w) => panic!("pop divergence: calendar {g:?} vs heap {w:?}"),
            }
        };
        for _ in 0..300 {
            // a burst of same-instant events forces the seq tie-break;
            // the far-future arm lands past the wheel's year (overflow)
            let delay = match rng.gen_range(6) {
                0 => 0.0,
                1 | 2 => rng.uniform_f64() * 3.0,
                3 => rng.uniform_f64() * 1e4,
                4 => 1e7 * (1.0 + rng.uniform_f64()),
                _ => rng.uniform_f64() * 1e-6,
            };
            let t = now + delay;
            for _ in 0..1 + rng.gen_range(4) {
                let kind = random_kind(rng);
                let seq = q.next_seq();
                q.push(t, kind);
                reference.push(Reverse(Event { time: t, seq, kind }));
            }
            assert_eq!(q.len(), reference.len());
            for _ in 0..rng.gen_range(4) {
                pop_both(&mut q, &mut reference, &mut now);
            }
            // occasional full drain exercises the shrink path and the
            // overflow re-anchor, then the timeline keeps going
            if rng.gen_range(40) == 0 {
                while !q.is_empty() {
                    pop_both(&mut q, &mut reference, &mut now);
                }
            }
        }
        while !q.is_empty() || !reference.is_empty() {
            pop_both(&mut q, &mut reference, &mut now);
        }
    });
}

/// Million-node tentpole, memory half: the quantized-at-rest bank is
/// bitwise-indistinguishable from a fleet of dense `EstimateTracker`s —
/// same committed frames, same estimate rows down to the sign of zero —
/// across every compressor family, EF on/off, interleaved reads (which
/// move rows through the scratch pool) and enough traffic per node to
/// trigger frame compaction. This is what makes swapping the engines'
/// banks out from under the parity suites sound.
#[test]
fn prop_quant_bank_bitwise_matches_dense_trackers() {
    use qadmm::compress::bank::QuantBank;

    let kinds = [
        CompressorKind::Identity,
        CompressorKind::Identity32,
        CompressorKind::Qsgd { bits: 2 },
        CompressorKind::Qsgd { bits: 3 },
        CompressorKind::Qsgd { bits: 11 },
        CompressorKind::Sign,
        CompressorKind::TopK { frac_permille: 100 },
        CompressorKind::RandK { frac_permille: 100 },
    ];
    let poisons = [f64::NAN, f64::INFINITY, f64::NEG_INFINITY];
    for_all(40, 5353, |rng| {
        let n = 1 + rng.gen_range(12);
        let m = 1 + rng.gen_range(48);
        let feedback = rng.bernoulli(0.5);
        let kind = kinds[rng.gen_range(kinds.len())];
        let comp = kind.build();
        let scale = 10f64.powf(rng.uniform_f64() * 6.0 - 3.0); // 1e-3..1e3
        let init_row = rng.normal_vec(m, 0.0, scale);
        let mut bank = QuantBank::new(n, init_row.clone(), feedback);
        let mut dense: Vec<EstimateTracker> =
            (0..n).map(|_| EstimateTracker::new(init_row.clone(), feedback)).collect();

        let bits = |v: &[f64]| -> Vec<u64> { v.iter().map(|x| x.to_bits()).collect() };
        for step in 0..60 {
            let i = rng.gen_range(n);
            let mut delta = rng.normal_vec(m, 0.0, scale);
            if rng.gen_range(8) == 0 {
                // compressors sanitize non-finite inputs; both banks must
                // commit the same sanitized frame
                let j = rng.gen_range(m);
                delta[j] = poisons[rng.gen_range(poisons.len())];
            }
            let c = comp.compress(&delta, rng);
            bank.commit_frame(i, &c).unwrap();
            dense[i].commit_frame(&c).unwrap();
            if rng.bernoulli(0.3) {
                // interleaved reads rotate rows through the scratch pool
                let j = rng.gen_range(n);
                assert_eq!(
                    bits(bank.row(j)),
                    bits(dense[j].estimate()),
                    "kind={} step={step} node={j}: row read diverged",
                    kind.label()
                );
            }
        }
        for i in 0..n {
            assert_eq!(
                bits(&bank.estimate(i)),
                bits(dense[i].estimate()),
                "kind={} node={i} (n={n} m={m} feedback={feedback}): final estimate",
                kind.label()
            );
        }
    });
}

#[test]
fn prop_json_roundtrip_numbers() {
    use qadmm::util::json::Json;
    for_all(300, 66, |rng| {
        let x = match rng.gen_range(3) {
            0 => (rng.next_u64() % (1 << 53)) as f64,
            1 => rng.standard_normal() * 10f64.powf(rng.uniform_f64() * 200.0 - 100.0),
            _ => -((rng.next_u64() % 1000) as f64),
        };
        let text = Json::Num(x).to_string_compact();
        let back = Json::parse(&text).unwrap();
        let y = back.as_f64().unwrap();
        let rel = if x == 0.0 { y.abs() } else { ((x - y) / x).abs() };
        assert!(rel < 1e-12, "{x} -> {text} -> {y}");
    });
}
