//! Deterministic, seedable PCG64 RNG (PCG-XSL-RR 128/64) + distributions.
//!
//! Every stochastic choice in the system — data generation, NN init, the
//! `simulate-async()` oracle, quantizer noise, batch sampling, latency
//! draws — flows from one of these generators, so whole Monte-Carlo trials
//! replay bit-exactly from a `u64` seed. Independent streams are derived
//! with [`Pcg64::fork`] (distinct odd increments), mirroring how `jax`
//! splits keys.

/// SplitMix64: seed-expansion PRNG (Steele et al.), used to derive PCG state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

const PCG_MUL: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

/// PCG-XSL-RR 128/64 (O'Neill). 2^128 period, 64-bit outputs.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // must be odd
}

impl Pcg64 {
    /// Seed via SplitMix64 expansion of a single `u64`.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = (((sm.next_u64() as u128) << 64) | sm.next_u64() as u128) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_add(state);
        rng.next_u64();
        rng
    }

    /// Derive an independent stream: same entropy family, distinct sequence.
    pub fn fork(&mut self, stream: u64) -> Pcg64 {
        let mut sm = SplitMix64::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = (((sm.next_u64() as u128) << 64) | sm.next_u64() as u128) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_add(state);
        rng.next_u64();
        rng
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MUL).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of mantissa entropy.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` with 24 bits of mantissa entropy.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// The raw `(state, inc)` pair — the *complete* generator state, for
    /// snapshots and RNG-state digests ([`crate::snapshot`]). Restoring
    /// via [`Self::from_raw_parts`] continues the exact output sequence.
    pub fn raw_parts(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Self::raw_parts`]. The increment is
    /// forced odd (a PCG invariant); any other `(state, inc)` pair is a
    /// valid generator, so restore is total.
    pub fn from_raw_parts(state: u128, inc: u128) -> Self {
        Self { state, inc: inc | 1 }
    }

    /// Uniform integer in `[0, bound)` (Lemire-style rejection).
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be positive");
        let bound = bound as u64;
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            // low-bias multiply-shift
            let (hi, lo) = {
                let wide = (r as u128) * (bound as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= threshold {
                return hi as usize;
            }
        }
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform_f64() < p
    }

    /// Standard normal via Box-Muller (pairs cached).
    pub fn standard_normal(&mut self) -> f64 {
        // Marsaglia polar method: no trig, numerically tame.
        loop {
            let u = 2.0 * self.uniform_f64() - 1.0;
            let v = 2.0 * self.uniform_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    pub fn normal_vec(&mut self, n: usize, mean: f64, std: f64) -> Vec<f64> {
        (0..n).map(|_| mean + std * self.standard_normal()).collect()
    }

    pub fn uniform_vec_f64(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.uniform_f64()).collect()
    }

    pub fn uniform_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.uniform_f32()).collect()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.uniform_f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.gen_range(i + 1);
            slice.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` (partial Fisher-Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

impl crate::snapshot::codec::Pack for Pcg64 {
    fn pack(&self, w: &mut crate::snapshot::codec::Writer) {
        w.put_u128(self.state);
        w.put_u128(self.inc);
    }
    fn unpack(r: &mut crate::snapshot::codec::Reader<'_>) -> anyhow::Result<Self> {
        let state = r.get_u128()?;
        let inc = r.get_u128()?;
        Ok(Self::from_raw_parts(state, inc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fork_streams_are_independent_and_deterministic() {
        let mut root1 = Pcg64::seed_from_u64(7);
        let mut root2 = Pcg64::seed_from_u64(7);
        let mut f1 = root1.fork(3);
        let mut f2 = root2.fork(3);
        for _ in 0..32 {
            assert_eq!(f1.next_u64(), f2.next_u64());
        }
        let mut g = root1.fork(4);
        let same = (0..64).filter(|_| f1.next_u64() == g.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_moments() {
        let mut rng = Pcg64::seed_from_u64(3);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let u = rng.uniform_f64();
            assert!((0.0..1.0).contains(&u));
            sum += u;
            sumsq += u * u;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed_from_u64(4);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.standard_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn gen_range_unbiased_and_in_bounds() {
        let mut rng = Pcg64::seed_from_u64(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            let k = rng.gen_range(7);
            counts[k] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn choose_k_distinct() {
        let mut rng = Pcg64::seed_from_u64(6);
        for _ in 0..100 {
            let mut v = rng.choose_k(20, 8);
            v.sort_unstable();
            v.dedup();
            assert_eq!(v.len(), 8);
            assert!(v.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn raw_parts_restore_continues_the_sequence() {
        let mut a = Pcg64::seed_from_u64(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let (state, inc) = a.raw_parts();
        let mut b = Pcg64::from_raw_parts(state, inc);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // pack/unpack is the same restore
        use crate::snapshot::codec::{Pack, Reader, Writer};
        let mut w = Writer::new();
        a.pack(&mut w);
        let bytes = w.into_inner();
        let mut c = Pcg64::unpack(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(a.next_u64(), c.next_u64());
        assert_eq!(a.uniform_f64(), c.uniform_f64());
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::seed_from_u64(8);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(2.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.5).abs() < 0.05, "mean={mean}");
    }
}
