//! Leveled stderr logger, controlled by `QADMM_LOG` (error|warn|info|debug).
//!
//! Deliberately tiny: one global level read once, macro-free call sites.

use std::sync::OnceLock;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

fn level() -> Level {
    static LEVEL: OnceLock<Level> = OnceLock::new();
    *LEVEL.get_or_init(|| match std::env::var("QADMM_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    })
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, target: &str, msg: &str) {
    if enabled(l) {
        eprintln!("[{:5}] {target}: {msg}", format!("{l:?}").to_uppercase());
    }
}

pub fn info(target: &str, msg: &str) {
    log(Level::Info, target, msg);
}

pub fn warn(target: &str, msg: &str) {
    log(Level::Warn, target, msg);
}

pub fn debug(target: &str, msg: &str) {
    log(Level::Debug, target, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Debug);
        assert!(enabled(Level::Error));
    }
}
