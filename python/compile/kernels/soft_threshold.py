"""L1 Pallas kernel: soft-thresholding, the prox of κ‖·‖₁.

    S_κ(v)_m = sgn(v_m) · max(|v_m| − κ, 0)

This is the closed-form consensus update (eq. 15) for LASSO:
    z ← S_{θ/(ρN)}( mean_i(x̂_i + û_i) ).
Elementwise VPU work, tiled like the quantizer.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256


def _soft_threshold_kernel(v_ref, kappa_ref, o_ref):
    v = v_ref[...]
    kappa = kappa_ref[0]
    o_ref[...] = jnp.sign(v) * jnp.maximum(jnp.abs(v) - kappa, 0.0)


@functools.partial(jax.jit, static_argnames=("block",))
def soft_threshold(v, kappa, *, block=BLOCK):
    """Elementwise prox of κ‖·‖₁ over a rank-1 tensor."""
    if v.ndim != 1:
        raise ValueError(f"soft_threshold expects rank-1 input, got {v.shape}")
    m = v.shape[0]
    dtype = v.dtype
    kappa_arr = jnp.asarray(kappa, dtype=dtype).reshape((1,))
    pad = (-m) % block
    v_p = jnp.pad(v, (0, pad)) if pad else v
    mp = m + pad
    out = pl.pallas_call(
        _soft_threshold_kernel,
        grid=(mp // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), dtype),
        interpret=True,
    )(v_p, kappa_arr)
    return out[:m] if pad else out
