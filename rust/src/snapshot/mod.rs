//! Snapshot/replay subsystem: serializable run state, checkpoint/resume of
//! the virtual timeline, and recorded-timeline replay.
//!
//! The paper's experiments are long asynchronous runs whose *trajectory*
//! is the result — a 10k-node run that dies at round 9k used to restart
//! from zero, and a straggler schedule the event engine discovered could
//! not be reproduced in the threaded deployment. This module owns the
//! three layers that fix that:
//!
//! * [`codec`] — the in-house versioned binary codec ([`codec::Pack`]):
//!   every piece of mutable per-run state — engine arenas, the event queue
//!   and its seq counter, per-node FIFO inboxes and monotone clamps,
//!   consensus accumulators, aggregator-tier partials, error-feedback
//!   residuals, estimate banks, comm accounting, and every forked PCG64
//!   stream — packs into one canonical byte body, and unpacks back with
//!   full bounds/tag validation (truncation or corruption is `Err`, never
//!   a panic).
//! * [`SnapshotMeta`] + the container ([`codec::encode_container`]) — a
//!   human-readable JSON header (engine, round, dimensions, full config)
//!   in front of the checksummed binary body. `write_file`/`read_file`
//!   wrap that in atomic-rename file IO.
//! * [`timeline`] — recorded `(time, seq, kind)` event streams + per-round
//!   arrival/dispatch sets from the event engine, replayable by the
//!   threaded runtime ([`crate::coordinator::run_threaded_replay`]).
//!
//! # What a snapshot does and does not capture
//!
//! Captured: everything the engines mutate per round (see the field lists
//! in [`crate::admm::engine`] / [`crate::admm::sim`]), so a resumed run is
//! **bit-identical** to the uninterrupted one — z trajectory, staleness,
//! per-link wire bits, RNG states (`tests/snapshot_parity.rs`). Not
//! captured: the problem *data* (re-derived from the seed by the problem
//! factory — storing n·h·m matrices would dwarf the state), wall-clock
//! timestamps (`wall_s` in the metric records restarts with the resumed
//! process), and any state a problem holds outside the engine (native
//! LASSO/logreg hold none; NN runtime state lives in the compute service,
//! so NN runs refuse to checkpoint rather than resume wrong).

pub mod codec;
pub mod timeline;

use std::path::Path;

use crate::util::json::Json;

/// Human-readable snapshot header: enough to identify the run without
/// decoding the body, plus the full config for resume validation.
#[derive(Clone, Debug)]
pub struct SnapshotMeta {
    /// Engine that wrote the snapshot (`seq` | `event`).
    pub engine: String,
    /// Consensus rounds completed at capture time.
    pub round: usize,
    /// Fleet size.
    pub n: usize,
    /// Model dimension M.
    pub m: usize,
    /// Base seed (the problem factory re-derives data from it).
    pub seed: u64,
    /// The full experiment config JSON at capture time.
    pub config: Json,
}

impl SnapshotMeta {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("qadmm-run-snapshot".into())),
            ("engine", Json::Str(self.engine.clone())),
            ("round", Json::Num(self.round as f64)),
            ("n", Json::Num(self.n as f64)),
            ("m", Json::Num(self.m as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("config", self.config.clone()),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        anyhow::ensure!(
            j.get("kind").and_then(Json::as_str) == Some("qadmm-run-snapshot"),
            "not a qadmm run snapshot header"
        );
        let field = |k: &str| -> anyhow::Result<usize> {
            j.expect(k)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("snapshot header '{k}' must be an integer"))
        };
        Ok(Self {
            engine: j
                .expect("engine")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("snapshot header 'engine' must be a string"))?
                .to_string(),
            round: field("round")?,
            n: field("n")?,
            m: field("m")?,
            seed: field("seed")? as u64,
            config: j.expect("config")?.clone(),
        })
    }
}

/// The portion of a config that must match for a resume to be sound:
/// everything except the run *length* knobs (`iters`, `mc_trials`), the
/// cosmetic `name`, and the observation-only `metrics_sample` (it changes
/// which nodes the loss is *measured* on, never the trajectory itself) —
/// resuming with more rounds than the original plan is exactly the
/// long-run use case, but resuming under a different compressor, topology,
/// τ, latency model or seed would silently produce a trajectory that
/// belongs to neither run.
pub fn config_resume_digest(config: &Json) -> String {
    match config {
        Json::Obj(map) => {
            let mut m = map.clone();
            m.remove("iters");
            m.remove("mc_trials");
            m.remove("name");
            m.remove("metrics_sample");
            Json::Obj(m).to_string_compact()
        }
        other => other.to_string_compact(),
    }
}

/// Encode a snapshot (header + body) into one container byte vector.
pub fn encode(meta: &SnapshotMeta, body: &[u8]) -> Vec<u8> {
    codec::encode_container(&meta.to_json(), body)
}

/// Decode a container produced by [`encode`].
pub fn decode(bytes: &[u8]) -> anyhow::Result<(SnapshotMeta, Vec<u8>)> {
    let (header, body) = codec::decode_container(bytes)?;
    Ok((SnapshotMeta::from_json(&header)?, body))
}

/// Write a snapshot with write-to-tmp + fsync + atomic rename: a crash
/// mid-write must not destroy the previous checkpoint, and a crash right
/// *after* the rename must not leave a renamed-but-unflushed file — the
/// whole point is surviving crashes, so the tmp file is synced to disk
/// before it replaces the old snapshot.
pub fn write_file(path: &Path, meta: &SnapshotMeta, body: &[u8]) -> anyhow::Result<()> {
    use std::io::Write as _;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let tmp = path.with_extension("qsnap.tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&encode(meta, body))?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// [`write_file`] without ever materializing the body: the engine packs
/// straight into a spilling [`codec::Writer`] draining to the tmp file, so
/// checkpointing a multi-GB arena costs ~1 MiB of codec memory instead of
/// a second copy of the state. The container layout (and therefore the
/// on-disk bytes) is identical to the buffered path — the unknown-upfront
/// `body_len` is a placeholder patched in place once the stream finishes.
pub fn write_file_streamed(
    path: &Path,
    meta: &SnapshotMeta,
    emit: impl FnOnce(&mut codec::Writer),
) -> anyhow::Result<()> {
    use std::io::{Seek as _, SeekFrom, Write as _};
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let tmp = path.with_extension("qsnap.tmp");
    let mut f = std::fs::File::create(&tmp)?;
    let header_text = meta.to_json().to_string_pretty();
    f.write_all(&codec::MAGIC)?;
    f.write_all(&codec::VERSION.to_le_bytes())?;
    f.write_all(&(header_text.len() as u32).to_le_bytes())?;
    f.write_all(header_text.as_bytes())?;
    let body_len_at = f.stream_position()?;
    f.write_all(&0u64.to_le_bytes())?; // patched below once body_len is known
    {
        // The clone shares the file cursor, so when the stream finishes
        // (flushing its BufWriter), `f` sits exactly at the end of the body.
        let sink = std::io::BufWriter::new(f.try_clone()?);
        let mut w = codec::Writer::with_sink(Box::new(sink));
        emit(&mut w);
        let (body_len, checksum) = w.finish_stream()?;
        f.write_all(&checksum.to_le_bytes())?;
        f.seek(SeekFrom::Start(body_len_at))?;
        f.write_all(&body_len.to_le_bytes())?;
    }
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)?;
    Ok(())
}

pub fn read_file(path: &Path) -> anyhow::Result<(SnapshotMeta, Vec<u8>)> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("cannot read snapshot {}: {e}", path.display()))?;
    decode(&bytes).map_err(|e| anyhow::anyhow!("snapshot {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn meta() -> SnapshotMeta {
        SnapshotMeta {
            engine: "event".into(),
            round: 31,
            n: 16,
            m: 200,
            seed: 2025,
            config: presets::ci_lasso().to_json(),
        }
    }

    #[test]
    fn meta_round_trips() {
        let m = meta();
        let back = SnapshotMeta::from_json(&m.to_json()).unwrap();
        assert_eq!(back.engine, "event");
        assert_eq!(back.round, 31);
        assert_eq!((back.n, back.m, back.seed), (16, 200, 2025));
        assert_eq!(back.config, m.config);
    }

    #[test]
    fn encode_decode_round_trips() {
        let body = vec![9u8; 1000];
        let bytes = encode(&meta(), &body);
        let (m, b) = decode(&bytes).unwrap();
        assert_eq!(m.round, 31);
        assert_eq!(b, body);
    }

    #[test]
    fn digest_ignores_length_knobs_but_not_semantics() {
        let base = presets::ci_lasso();
        let mut longer = base.clone();
        longer.iters = 100_000;
        longer.mc_trials = 1;
        longer.name = "renamed".into();
        assert_eq!(
            config_resume_digest(&base.to_json()),
            config_resume_digest(&longer.to_json())
        );
        let mut different = base.clone();
        different.tau = base.tau + 1;
        assert_ne!(
            config_resume_digest(&base.to_json()),
            config_resume_digest(&different.to_json())
        );
        let mut compressor = base.clone();
        compressor.compressor = crate::compress::CompressorKind::Sign;
        assert_ne!(
            config_resume_digest(&base.to_json()),
            config_resume_digest(&compressor.to_json())
        );
    }

    #[test]
    fn file_round_trip_is_atomic_renamed() {
        let dir = std::env::temp_dir().join("qadmm-snapshot-test");
        let path = dir.join("run.qsnap");
        write_file(&path, &meta(), &[1, 2, 3]).unwrap();
        assert!(!path.with_extension("qsnap.tmp").exists(), "tmp file left behind");
        let (m, b) = read_file(&path).unwrap();
        assert_eq!(m.n, 16);
        assert_eq!(b, vec![1, 2, 3]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The streamed writer must leave exactly the bytes `write_file`
    /// would — the placeholder-patch framing is invisible on disk.
    #[test]
    fn streamed_file_is_byte_identical_to_buffered() {
        let dir = std::env::temp_dir().join("qadmm-snapshot-stream-test");
        let buffered = dir.join("buffered.qsnap");
        let streamed = dir.join("streamed.qsnap");
        let body: Vec<u8> = (0..300_000u32).flat_map(|i| i.to_le_bytes()).collect();
        write_file(&buffered, &meta(), &body).unwrap();
        write_file_streamed(&streamed, &meta(), |w| {
            // feed in uneven pieces so spill boundaries fall mid-value
            for chunk in body.chunks(777) {
                for &b in chunk {
                    w.put_u8(b);
                }
            }
        })
        .unwrap();
        assert!(!streamed.with_extension("qsnap.tmp").exists(), "tmp file left behind");
        let a = std::fs::read(&buffered).unwrap();
        let b = std::fs::read(&streamed).unwrap();
        assert_eq!(a, b, "streamed container differs from buffered");
        let (m, back) = read_file(&streamed).unwrap();
        assert_eq!(m.round, 31);
        assert_eq!(back, body);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn digest_ignores_metrics_sample() {
        let base = presets::ci_lasso();
        let mut sampled = base.clone();
        sampled.metrics_sample = 7;
        assert_eq!(
            config_resume_digest(&base.to_json()),
            config_resume_digest(&sampled.to_json())
        );
    }

    #[test]
    fn non_snapshot_header_rejected() {
        let j = Json::obj(vec![("kind", Json::Str("something-else".into()))]);
        assert!(SnapshotMeta::from_json(&j).is_err());
    }
}
