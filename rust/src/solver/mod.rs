//! Native numeric substrates: dense linear algebra, proximal operators and
//! centralized reference solvers (used for the exact LASSO primal update,
//! the F* reference optimum, and HLO-vs-native parity tests).

pub mod cg;
pub mod fista;
pub mod linalg;
pub mod prox;
