//! Server-side bounded-staleness scheduling (Algorithm 1 lines 27–40):
//! per-node staleness counters d_i, forced inclusion at d_i = τ−1, and the
//! minimum-arrivals threshold P.

use crate::snapshot::codec::{Pack, Reader, Writer};

/// Bookkeeping for the async trigger rule. `advance` consumes the active
//  set of iteration r plus an oracle draw and produces A_{r+1}.
#[derive(Clone, Debug)]
pub struct Scheduler {
    d: Vec<usize>,
    tau: usize,
    p_min: usize,
}

impl Scheduler {
    pub fn new(n: usize, tau: usize, p_min: usize) -> Self {
        assert!(tau >= 1 && (1..=n).contains(&p_min));
        Self { d: vec![0; n], tau, p_min }
    }

    /// Algorithm 1 lines 28–40. `oracle` draws additional samples if the
    /// assembled A_{r+1} is smaller than P (the server keeps waiting for
    /// arrivals until at least P nodes have reported).
    ///
    /// Counter semantics: d_i is the node's staleness *after* round r. Any
    /// node whose staleness has reached τ−1 is forced into A_{r+1} (the
    /// server waits for it), so no update is ever older than τ iterations
    /// and τ = 1 degenerates to the synchronous algorithm — every node is
    /// forced every round, exactly the paper's "τ=1 corresponds to the
    /// synchronous case".
    pub fn advance(
        &mut self,
        active_r: &[bool],
        mut oracle: impl FnMut() -> Vec<bool>,
    ) -> Vec<bool> {
        let n = self.d.len();
        debug_assert_eq!(active_r.len(), n);
        for i in 0..n {
            if active_r[i] {
                self.d[i] = 0;
            } else {
                self.d[i] += 1;
            }
        }
        let mut next = oracle();
        debug_assert_eq!(next.len(), n);
        for i in 0..n {
            if self.d[i] >= self.tau - 1 {
                next[i] = true;
            }
        }
        // P-threshold: |A_{r+1}| ≥ P (merge further oracle draws, i.e. the
        // server waits longer so more nodes complete). A pathological
        // oracle that never selects anyone is broken out of by forcing the
        // stalest nodes — the server just waits for them. A running active
        // count (updated on each false→true flip) replaces the full recount
        // per attempt and per forced node, which was O(n²) and dominated
        // `advance` at n ≥ 4096 under sparse oracles.
        let mut active = next.iter().filter(|&&a| a).count();
        let mut attempts = 0usize;
        while active < self.p_min {
            attempts += 1;
            if attempts > 1000 {
                let mut order: Vec<usize> = (0..n).collect();
                order.sort_by_key(|&i| std::cmp::Reverse(self.d[i]));
                for &i in &order {
                    if active >= self.p_min {
                        break;
                    }
                    if !next[i] {
                        next[i] = true;
                        active += 1;
                    }
                }
                break;
            }
            for (dst, extra) in next.iter_mut().zip(oracle()) {
                if extra && !*dst {
                    *dst = true;
                    active += 1;
                }
            }
        }
        next
    }

    pub fn staleness(&self) -> &[usize] {
        &self.d
    }

    pub fn tau(&self) -> usize {
        self.tau
    }

    pub fn p_min(&self) -> usize {
        self.p_min
    }
}

impl Pack for Scheduler {
    fn pack(&self, w: &mut Writer) {
        self.d.pack(w);
        w.put_usize(self.tau);
        w.put_usize(self.p_min);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let d = Vec::<usize>::unpack(r)?;
        let tau = r.get_usize()?;
        let p_min = r.get_usize()?;
        anyhow::ensure!(tau >= 1, "snapshot scheduler: tau must be >= 1");
        anyhow::ensure!(
            (1..=d.len()).contains(&p_min),
            "snapshot scheduler: p_min {p_min} out of 1..={}",
            d.len()
        );
        // the τ−1 bound is a run invariant; a counter past it is corruption
        for (i, &di) in d.iter().enumerate() {
            anyhow::ensure!(
                di + 1 <= tau,
                "snapshot scheduler: node {i} staleness {di} breaks tau={tau}"
            );
        }
        Ok(Self { d, tau, p_min })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tau_one_is_synchronous() {
        let mut s = Scheduler::new(4, 1, 1);
        let all = vec![true; 4];
        // even with an oracle that picks nobody, every node is forced
        let next = s.advance(&all, || vec![false; 4]);
        assert_eq!(next, vec![true; 4]);
        let next2 = s.advance(&next, || vec![false; 4]);
        assert_eq!(next2, vec![true; 4]);
    }

    #[test]
    fn no_node_skips_more_than_tau_minus_one() {
        let tau = 3;
        let mut s = Scheduler::new(5, tau, 1);
        let mut active = vec![true; 5];
        let mut skipped = vec![0usize; 5];
        let mut rng = crate::util::rng::Pcg64::seed_from_u64(9);
        for _ in 0..500 {
            let next = s.advance(&active, || (0..5).map(|_| rng.bernoulli(0.3)).collect());
            for i in 0..5 {
                if next[i] {
                    skipped[i] = 0;
                } else {
                    skipped[i] += 1;
                    assert!(skipped[i] <= tau - 1, "node {i} skipped {}", skipped[i]);
                }
            }
            active = next;
        }
    }

    #[test]
    fn p_threshold_is_enforced() {
        let mut s = Scheduler::new(6, 10, 3);
        let mut calls = 0;
        let next = s.advance(&vec![true; 6], || {
            calls += 1;
            // each draw picks exactly one distinct node
            let mut v = vec![false; 6];
            v[calls % 6] = true;
            v
        });
        assert!(next.iter().filter(|&&a| a).count() >= 3);
        assert!(calls >= 3);
    }

    /// The worst case for the P-threshold loop: a huge population whose
    /// oracle never selects anyone, so the 1000-attempt merge runs dry and
    /// the stalest-first forcing has to fill the entire batch. With the
    /// running count this is O(attempts·n + n log n); the old per-attempt
    /// recount made it O(n²) and visibly hung at this size.
    #[test]
    fn never_selecting_oracle_at_4096_nodes_fills_p_quickly() {
        let n = 4096;
        let mut s = Scheduler::new(n, 2, n);
        let start = std::time::Instant::now();
        let next = s.advance(&vec![true; n], || vec![false; n]);
        assert_eq!(next.iter().filter(|&&a| a).count(), n);
        // generous bound: the whole call is a few million boolean ops
        assert!(
            start.elapsed() < std::time::Duration::from_secs(5),
            "P-threshold loop took {:?}",
            start.elapsed()
        );
        // and a partial fill stops exactly at P
        let mut s = Scheduler::new(n, 2, 7);
        let next = s.advance(&vec![true; n], || vec![false; n]);
        assert_eq!(next.iter().filter(|&&a| a).count(), 7);
    }

    #[test]
    fn staleness_counters_track() {
        let mut s = Scheduler::new(3, 5, 1);
        // node 2 never active via oracle
        let a0 = vec![true, true, false];
        let next = s.advance(&a0, || vec![true, true, false]);
        assert_eq!(s.staleness(), &[0, 0, 1]);
        let _ = s.advance(&next, || vec![true, true, false]);
        assert_eq!(s.staleness(), &[0, 0, 2]);
    }
}
