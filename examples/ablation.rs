//! Design-choice ablations on the Fig-3 LASSO workload: quantizer
//! resolution q, error feedback on/off, compressor families, and the
//! asynchrony knobs (τ, P). Prints one table per sweep.
//!
//!     cargo run --release --example ablation -- [--iters 400] [--trials 3]

use qadmm::exp::ablation::{run_all, AblationOptions};
use qadmm::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let opts = AblationOptions {
        iters: args.usize("iters", 400),
        mc_trials: args.usize("trials", 3),
        target: args.f64("target", 1e-8),
    };
    args.finish()?;
    let rows = run_all(&opts)?;
    println!("\n{} ablation rows total", rows.len());
    Ok(())
}
