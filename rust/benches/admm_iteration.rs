//! Per-iteration cost of the QADMM loop, per layer:
//! * native LASSO node step / server step (L3 math only)
//! * HLO LASSO node step (PJRT dispatch + compute; the server step runs
//!   native-f64 on every backend since the lasso_server_step artifact was
//!   retired)
//! * HLO MLP local update (K-step fused Adam scan)
//! * one full sequential simulator iteration (everything together)
//!
//! This measures the fused-HLO vs dispatch-overhead tradeoff the §Perf pass
//! optimizes. Artifact-backed benches skip when artifacts are missing.

use qadmm::admm::sim::{AsyncSim, TrialRngs};
use qadmm::bench_harness::Bencher;
use qadmm::config::presets;
use qadmm::problems::lasso::{LassoConfig, LassoProblem};
use qadmm::problems::nn::{NnArch, NnProblem};
use qadmm::problems::Problem;
use qadmm::runtime::artifacts::Manifest;
use qadmm::runtime::service::ComputeService;
use qadmm::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Pcg64::seed_from_u64(4);
    let paper = LassoConfig { m: 200, h: 100, n: 16, rho: 500.0, theta: 0.1 };

    // --- native LASSO ---
    let mut p = LassoProblem::generate(paper, &mut rng).unwrap();
    let zhat = rng.normal_vec(200, 0.0, 1.0);
    let u = rng.normal_vec(200, 0.0, 0.1);
    let x_prev = vec![0.0; 200];
    b.bench_val("lasso/native/node_step/m=200", 1, || {
        p.local_update(0, &zhat, &u, &x_prev, &mut rng).unwrap()
    });
    let xhat: Vec<Vec<f64>> = (0..16).map(|_| rng.normal_vec(200, 0.0, 1.0)).collect();
    let uhat: Vec<Vec<f64>> = (0..16).map(|_| rng.normal_vec(200, 0.0, 0.1)).collect();
    b.bench_val("lasso/native/server_step/n=16", 1, || {
        p.consensus(&xhat, &uhat).unwrap()
    });

    // --- one full simulator iteration (native backend, paper dims) ---
    let cfg = {
        let mut c = presets::fig3(3);
        c.backend = qadmm::config::Backend::Native;
        c
    };
    let rngs = TrialRngs::new(7);
    let mut rng2 = Pcg64::seed_from_u64(7);
    let mut prob = LassoProblem::generate(paper, &mut rng2).unwrap();
    prob.set_reference_optimum(1.0); // metric value irrelevant for timing
    let mut sim = AsyncSim::new(&cfg, &mut prob, rngs).unwrap();
    b.bench("lasso/sim/full_iteration(native)", 1, || {
        sim.step().unwrap();
    });

    // --- HLO-backed benches ---
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let svc = ComputeService::start("artifacts".into(), vec![]).unwrap();
        let manifest = Manifest::load(std::path::Path::new("artifacts/manifest.json")).unwrap();
        let mut hp = LassoProblem::generate(paper, &mut rng)
            .unwrap()
            .with_hlo(Box::new(svc.client()), 200, 16)
            .unwrap();
        // warm the executable caches
        let _ = hp.local_update(0, &zhat, &u, &x_prev, &mut rng).unwrap();
        b.bench_val("lasso/hlo/node_step/m=200", 1, || {
            hp.local_update(0, &zhat, &u, &x_prev, &mut rng).unwrap()
        });
        // (the lasso_server_step artifact is retired — the server prox runs
        // native-f64 via consensus_from_sum on every backend, so there is
        // no HLO server-step dispatch left to time)

        // MLP local update: K=5 fused Adam steps, M=50,890
        let mut nn = NnProblem::new(
            NnArch::Mlp,
            4,
            1.0,
            1e-3,
            Box::new(svc.client()),
            &manifest,
            800,
            256,
            std::path::Path::new("data/mnist"),
            11,
        )
        .unwrap();
        let m = nn.dim();
        let flat = nn.init_x(&mut rng);
        let zeros = vec![0.0; m];
        let _ = nn.local_update(0, &flat, &zeros, &flat, &mut rng).unwrap();
        b.bench_val("mlp/hlo/local_update(K=5,B=32)", 1, || {
            nn.local_update(0, &flat, &zeros, &flat, &mut rng).unwrap()
        });
        b.bench_val("mlp/hlo/eval(test=256)", 1, || {
            nn.test_metrics(&flat).unwrap()
        });
    } else {
        println!("(artifacts not built; skipping HLO benches)");
    }

    b.finish("admm_iteration");
}
