//! `qadmm serve`: the socket-facing server, as a sharded readiness-driven
//! reactor. A small fixed pool of I/O threads (≈ `available_parallelism`,
//! capped at [`MAX_IO_THREADS`]) each owns many **nonblocking** connections
//! multiplexed with `poll(2)` ([`super::transport::poll_fds`]): per-
//! connection [`FrameCursor`] read state machines replace the old blocking
//! reader-thread-per-connection, bounded per-connection write queues with
//! slow-consumer eviction replace the writer-pump-per-node, and a wake pipe
//! lets [`ServerLoop`] output and the stop flag interrupt a poll promptly.
//! The server runs `io_threads + 1` threads total regardless of fleet size
//! (the `+1` is the caller's thread driving the **unchanged** fold path via
//! [`crate::comm::network::bridged_sink`]) — not the old `2n + 1`.
//!
//! Broadcast discipline: one round's `Consensus` differs per recipient only
//! in the `included` flag bit, so the frame is encoded **once** and the
//! excluded variant is a byte-copy with one flag flipped — two shared
//! `Arc<[u8]>` buffers serve the whole fleet instead of n encodes of n
//! `dz_wire` clones.
//!
//! Accounting discipline: eq. (20) bits are charged **where bytes move**,
//! exactly as before — uplink when a complete frame decodes, downlink when
//! a frame fully drains to the socket — but the tallies land in plain
//! per-connection `u64`s owned by the reactor shard and fold into the
//! global [`super::LinkBytes`] books / [`CommAccounting`] once per poll
//! batch and definitively on detach/teardown. The hot path takes zero
//! global locks, and [`super::reconcile`] still holds the two ledgers to
//! exact equality: partial frames (read or write) are never booked and
//! never charged, so both sides count the identical set of frames. A
//! broadcast to a detached (departed) node is discarded unwritten and
//! charges nothing: only realized transmissions exist.

use std::collections::VecDeque;
use std::io::ErrorKind;
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::comm::accounting::CommAccounting;
use crate::comm::message::{NodeToServer, ServerToNode};
use crate::comm::network::{self, DownlinkSink, SharedAccounting};
use crate::config::ExperimentConfig;
use crate::coordinator::server::ServerLoop;
use crate::coordinator::SharedProblem;
use crate::metrics::RunRecorder;
use crate::problems::Problem;
use crate::snapshot::codec::fnv1a64;
use crate::snapshot::timeline::RecordedTimeline;
use crate::topology::TopologyKind;
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

use super::frame::{Frame, FLAG_INCLUDED, PROTO_VERSION};
use super::transport::{
    poll_fds, BufferPool, CursorStep, Endpoint, FrameCursor, Listener, PollFd, Stream, WakePipe,
    Waker, POLLIN, POLLOUT, POLL_SLICE,
};
use super::{new_books, Books, LinkBytes};

/// Ceiling on the I/O shard pool: beyond this, more threads buy contention,
/// not throughput, for a frame-sized workload.
pub const MAX_IO_THREADS: usize = 8;

pub struct ServeOptions {
    /// A connected worker that goes silent for this long (half-open
    /// socket, hung process) is evicted — the P/τ trigger never waits on
    /// it again. Also bounds the server's own stall timeout.
    pub idle_timeout: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { idle_timeout: Duration::from_secs(30) }
    }
}

/// Reactor tuning, separate from [`ServeOptions`] so existing literal
/// constructions of the latter keep compiling. Defaults suit production;
/// tests shrink `write_queue_limit` to provoke slow-consumer eviction.
pub struct ReactorOptions {
    /// I/O shard count; `None` = `min(available_parallelism, MAX_IO_THREADS)`.
    pub io_threads: Option<usize>,
    /// A connection still holding more than this many queued frames after
    /// a drain attempt is a slow consumer: it is detached, its unwritten
    /// frames are discarded (uncharged), and a `Leave` is synthesized.
    pub write_queue_limit: usize,
}

impl Default for ReactorOptions {
    fn default() -> Self {
        Self { io_threads: None, write_queue_limit: 1024 }
    }
}

/// Everything one `serve` run produced, for reporting and verification.
pub struct ServeReport {
    pub recorder: RunRecorder,
    /// The captured production schedule (always recorded: wall-clock round
    /// times + arrival sets; the loadgen latency percentiles and the
    /// capture→replay smoke both read it).
    pub timeline: RecordedTimeline,
    /// Per-link socket byte counters — one side of the reconciliation.
    pub books: Vec<LinkBytes>,
    /// The charged eq. (20) books — the other side.
    pub accounting: CommAccounting,
    pub wall_s: f64,
    /// Reactor shard count this run used (the server's thread total is
    /// `io_threads + 1`, fleet-size independent).
    pub io_threads: usize,
}

/// The 8-byte config digest carried in the `Hello` handshake: FNV-1a over
/// the resume digest (the config JSON minus run-length fields), so a
/// worker launched with a different experiment is rejected at connect
/// time instead of corrupting the run.
pub fn config_digest(cfg: &ExperimentConfig) -> Vec<u8> {
    fnv1a64(cfg.resume_digest().as_bytes()).to_le_bytes().to_vec()
}

/// One downlink message, encoded once and shared by every writer. For
/// `Consensus` the two per-recipient variants (included / not) are the
/// same bytes except the flag bit, so `excl` is a one-byte-patched copy.
struct DownMsg {
    /// `Some(node)` = unicast (rejoin `InitZ`); `None` = broadcast.
    target: Option<usize>,
    /// Frame bytes for included recipients.
    incl: Arc<[u8]>,
    /// Frame bytes for excluded recipients (identical length and charge).
    excl: Arc<[u8]>,
    /// Sorted node ids that get `incl`; `None` = everyone does.
    included: Option<Vec<u32>>,
    /// eq. (20) bits charged per recipient on write completion (0 for
    /// uncharged control frames).
    charged_bits: u64,
    /// `socket_extra_bytes` per recipient.
    extra: u64,
}

enum ShardCmd {
    /// A freshly accepted connection this shard now owns.
    Adopt(Stream),
    /// Downlink traffic from the fold loop.
    Down(Arc<DownMsg>),
}

struct ShardHandle {
    inbox: Arc<Mutex<VecDeque<ShardCmd>>>,
    waker: Waker,
}

impl ShardHandle {
    fn push(&self, cmd: ShardCmd) {
        self.inbox.lock().unwrap().push_back(cmd);
        self.waker.wake();
    }
}

/// Shared state between the I/O shards, the sink, and `serve` itself.
struct Hub {
    n: usize,
    m: usize,
    digest: Vec<u8>,
    up_tx: Sender<NodeToServer>,
    accounting: SharedAccounting,
    books: Books,
    /// Slot claim: a second connection for an attached node is rejected.
    attached: Vec<AtomicBool>,
    /// Per-node uplink sequence stamps. Global across reconnects: the
    /// [`crate::comm::network::ServerEndpoint`] dedup compares against the
    /// last seen seq, so a rejoining node must not restart at a value its
    /// previous life just used.
    seqs: Vec<AtomicU64>,
    /// Which shard owns each node's current connection (valid while
    /// attached; unicasts route through it, and a stale value just lands
    /// the message on a shard with no such conn — discarded uncharged).
    node_shard: Vec<AtomicUsize>,
    shards: Vec<ShardHandle>,
    stop: AtomicBool,
    idle: Duration,
    write_queue_limit: usize,
    /// A fatal `accept()` failure, surfaced to `serve`'s caller instead of
    /// spinning silently forever.
    listener_err: Mutex<Option<String>>,
}

impl Hub {
    fn wake_all(&self) {
        for sh in &self.shards {
            sh.waker.wake();
        }
    }

    fn send_down(&self, msg: ServerToNode, target: Option<usize>) {
        let dm = Arc::new(encode_down(msg, target));
        match target {
            Some(node) => {
                let shard = self.node_shard[node].load(Ordering::SeqCst);
                self.shards[shard].push(ShardCmd::Down(dm));
            }
            None => {
                for sh in &self.shards {
                    sh.push(ShardCmd::Down(dm.clone()));
                }
            }
        }
    }
}

/// The [`DownlinkSink`] the unchanged [`ServerLoop`] writes into: one call
/// per broadcast, shared-encoded, fanned to the shards' inboxes.
struct ReactorSink(Arc<Hub>);

impl DownlinkSink for ReactorSink {
    fn unicast(&self, node: usize, msg: ServerToNode) -> Result<()> {
        self.0.send_down(msg, Some(node));
        Ok(())
    }

    fn broadcast(&self, msg: ServerToNode) -> Result<()> {
        self.0.send_down(msg, None);
        Ok(())
    }
}

/// Encode one downlink message into its shared wire form. `Consensus` is
/// encoded once with `included: true`; the excluded variant is the same
/// buffer with the flag bit cleared (byte 5 = first body byte = flags).
fn encode_down(msg: ServerToNode, target: Option<usize>) -> DownMsg {
    let charged = matches!(msg, ServerToNode::Consensus { .. } | ServerToNode::InitZ { .. });
    let charged_bits = if charged { msg.wire_bits() } else { 0 };
    match msg {
        ServerToNode::Consensus { iter, included, dz_wire, last } => {
            let f = Frame::Consensus { round: iter as u32, included: true, last, dz_wire };
            let extra = f.socket_extra_bytes();
            let incl_bytes = f.encode();
            let mut excl_bytes = incl_bytes.clone();
            excl_bytes[5] &= !FLAG_INCLUDED;
            DownMsg {
                target,
                incl: incl_bytes.into(),
                excl: excl_bytes.into(),
                included: Some(included),
                charged_bits,
                extra,
            }
        }
        ServerToNode::InitZ { z0 } => {
            let f = Frame::InitZ { z0 };
            let extra = f.socket_extra_bytes();
            let bytes: Arc<[u8]> = f.encode().into();
            DownMsg { target, incl: bytes.clone(), excl: bytes, included: None, charged_bits, extra }
        }
        ServerToNode::Shutdown => {
            let f = Frame::Shutdown;
            let extra = f.socket_extra_bytes();
            let bytes: Arc<[u8]> = f.encode().into();
            DownMsg { target, incl: bytes.clone(), excl: bytes, included: None, charged_bits, extra }
        }
    }
}

/// One queued downlink frame on a connection; charged + booked only when
/// the last byte reaches the kernel.
struct WriteItem {
    bytes: Arc<[u8]>,
    off: usize,
    charged_bits: u64,
    extra: u64,
}

/// Per-connection byte/charge tallies — plain u64s owned by the shard,
/// folded into the global books once per poll batch and on detach.
#[derive(Default)]
struct ConnCounters {
    up_total: u64,
    up_extra: u64,
    up_bits: u64,
    up_msgs: u64,
    down_total: u64,
    down_extra: u64,
    down_bits: u64,
    down_msgs: u64,
}

impl ConnCounters {
    fn dirty(&self) -> bool {
        (self.up_total | self.down_total) != 0
    }
}

/// How a connection leaves the reactor.
#[derive(Clone, Copy, PartialEq)]
enum Fate {
    /// Orderly: acked drain, server stop, or a pre-handshake reject —
    /// no `Leave` is synthesized.
    CloseClean,
    /// The peer died or misbehaved after attaching: synthesize the
    /// `Leave` it could not send.
    CloseEvict,
}

struct Conn {
    stream: Stream,
    /// `None` until the handshake accepts; rejected/garbage connections
    /// never earn a node id and so never touch the books.
    node: Option<usize>,
    cursor: FrameCursor,
    wq: VecDeque<WriteItem>,
    counters: ConnCounters,
    last_rx: Instant,
    acked: bool,
    /// Reject path: flush the queued `Reject` frame, then close.
    close_after_drain: bool,
    gone: Option<Fate>,
}

impl Conn {
    fn new(stream: Stream) -> Self {
        Self {
            stream,
            node: None,
            cursor: FrameCursor::new(),
            wq: VecDeque::new(),
            counters: ConnCounters::default(),
            last_rx: Instant::now(),
            acked: false,
            close_after_drain: false,
            gone: None,
        }
    }

    fn queue_control(&mut self, frame: &Frame) {
        let bytes: Arc<[u8]> = frame.encode().into();
        let extra = bytes.len() as u64; // control frames charge 0 bits
        self.wq.push_back(WriteItem { bytes, off: 0, charged_bits: 0, extra });
    }
}

/// Exponential backoff state for resource-exhausted `accept()` (EMFILE and
/// friends). While backing off, the listener leaves the poll set entirely —
/// a level-triggered readable listener that cannot accept would otherwise
/// spin the shard at 100%.
struct AcceptBackoff {
    consecutive: u32,
    until: Option<Instant>,
}

impl AcceptBackoff {
    fn new() -> Self {
        Self { consecutive: 0, until: None }
    }

    fn accepting(&self) -> bool {
        self.until.is_none_or(|t| Instant::now() >= t)
    }

    fn bump(&mut self) {
        let delay = Duration::from_millis(10u64 << self.consecutive.min(8));
        self.until = Some(Instant::now() + delay.min(Duration::from_secs(2)));
        self.consecutive = self.consecutive.saturating_add(1);
    }

    fn clear(&mut self) {
        self.consecutive = 0;
        self.until = None;
    }
}

enum AcceptClass {
    /// This one connection died in the queue; keep accepting.
    Transient,
    /// fd/buffer/memory exhaustion: back off, the table may drain.
    Resource,
    /// The listener itself is broken: surface it and stop the run.
    Fatal,
}

fn classify_accept_error(e: &std::io::Error) -> AcceptClass {
    match e.kind() {
        ErrorKind::ConnectionAborted | ErrorKind::ConnectionReset | ErrorKind::Interrupted => {
            AcceptClass::Transient
        }
        _ => match e.raw_os_error() {
            // EMFILE, ENFILE, ENOBUFS, ENOMEM
            Some(24) | Some(23) | Some(105) | Some(12) => AcceptClass::Resource,
            _ => AcceptClass::Fatal,
        },
    }
}

fn default_io_threads() -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .clamp(1, MAX_IO_THREADS)
}

/// Run a deployment server: bind `listen`, call `on_ready` with the
/// resolved endpoint (TCP port 0 becomes the real port — this is where a
/// harness spawns its workers), then drive [`ServerLoop`] to completion
/// over the sockets and return the reconciled report.
pub fn serve<F>(
    cfg: &ExperimentConfig,
    problem: Box<dyn Problem + Send>,
    listen: &Endpoint,
    opts: &ServeOptions,
    on_ready: F,
) -> Result<ServeReport>
where
    F: FnOnce(&Endpoint) -> Result<()>,
{
    serve_tuned(cfg, problem, listen, opts, &ReactorOptions::default(), on_ready)
}

/// [`serve`] with explicit reactor tuning (shard count, write-queue bound).
pub fn serve_tuned<F>(
    cfg: &ExperimentConfig,
    problem: Box<dyn Problem + Send>,
    listen: &Endpoint,
    opts: &ServeOptions,
    reactor: &ReactorOptions,
    on_ready: F,
) -> Result<ServeReport>
where
    F: FnOnce(&Endpoint) -> Result<()>,
{
    cfg.validate()?;
    ensure!(
        cfg.topology == TopologyKind::Star,
        "deploy serves the star fan-in only (aggregators are in-process engines)"
    );
    let n = problem.n_nodes();
    let m = problem.dim();
    let io_threads = reactor.io_threads.unwrap_or_else(default_io_threads).max(1);

    let (listener, resolved) = Listener::bind(listen)?;
    let accounting: SharedAccounting = Arc::new(Mutex::new(CommAccounting::new(n)));
    let (up_tx, up_rx) = channel::<NodeToServer>();

    let mut pipes = Vec::with_capacity(io_threads);
    let mut handles = Vec::with_capacity(io_threads);
    for _ in 0..io_threads {
        let wp = WakePipe::new()?;
        handles.push(ShardHandle {
            inbox: Arc::new(Mutex::new(VecDeque::new())),
            waker: wp.waker(),
        });
        pipes.push(wp);
    }

    let hub = Arc::new(Hub {
        n,
        m,
        digest: config_digest(cfg),
        up_tx,
        accounting: accounting.clone(),
        books: new_books(n),
        attached: (0..n).map(|_| AtomicBool::new(false)).collect(),
        seqs: (0..n).map(|_| AtomicU64::new(0)).collect(),
        node_shard: (0..n).map(|_| AtomicUsize::new(0)).collect(),
        shards: handles,
        stop: AtomicBool::new(false),
        idle: opts.idle_timeout,
        write_queue_limit: reactor.write_queue_limit,
        listener_err: Mutex::new(None),
    });

    let ep = network::bridged_sink(n, up_rx, Box::new(ReactorSink(hub.clone())));

    let mut threads = Vec::with_capacity(io_threads);
    let mut listener = Some(listener);
    for (id, wp) in pipes.into_iter().enumerate() {
        let hub = hub.clone();
        let l = if id == 0 { listener.take() } else { None };
        threads.push(
            std::thread::Builder::new()
                .name(format!("qadmm-io-{id}"))
                .spawn(move || shard_loop(&hub, id, wp, l))?,
        );
    }

    // Same state derivation as `run_threaded`: workers re-derive the
    // identical x⁰ from the shared seed, the digest guarantees they can.
    let mut root = Pcg64::seed_from_u64(cfg.seed ^ 0x7468_7265_6164);
    let mut init_rng = root.fork(100);
    let shared: SharedProblem = Arc::new(Mutex::new(problem));
    let x0 = shared.lock().unwrap().init_x(&mut init_rng);
    let clock = Stopwatch::new();
    let mut srv =
        ServerLoop::new(ep, shared, accounting.clone(), cfg, x0, m, root.fork(300));
    srv.set_record("deploy", cfg.seed);
    srv.stall_timeout = opts.idle_timeout.max(Duration::from_secs(5));

    let run_res = match on_ready(&resolved) {
        Ok(()) => srv.run(), // consumes srv; drops the endpoint + sink
        Err(e) => Err(e),
    };

    // teardown in every path: stop the socket side, then read the books
    hub.stop.store(true, Ordering::SeqCst);
    hub.wake_all();
    for t in threads {
        t.join().map_err(|_| anyhow::anyhow!("reactor shard panicked"))?;
    }

    // a fatal listener failure explains a stalled run far better than the
    // downstream stall it causes
    let run_res = match hub.listener_err.lock().unwrap().take() {
        Some(le) => run_res.map_err(|e| e.context(format!("listener failed: {le}"))),
        None => run_res,
    };
    let out = run_res?;
    let books = hub.books.lock().unwrap().clone();
    let accounting = accounting.lock().unwrap().clone();
    Ok(ServeReport {
        recorder: out.recorder,
        timeline: out.timeline.expect("deploy server always records"),
        books,
        accounting,
        wall_s: clock.elapsed_secs(),
        io_threads,
    })
}

/// One reactor shard: poll its wake pipe + (shard 0) the listener + every
/// owned connection; drain the inbox; run the per-connection read/write
/// state machines; sweep idle peers; fold the dirty byte counters.
fn shard_loop(hub: &Arc<Hub>, id: usize, wake: WakePipe, listener: Option<Listener>) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut pool = BufferPool::new();
    let mut backoff = AcceptBackoff::new();
    let mut next_shard = 0usize;
    let mut fds: Vec<PollFd> = Vec::new();

    while !hub.stop.load(Ordering::Relaxed) {
        // --- build the poll set ---
        fds.clear();
        fds.push(PollFd::new(wake.as_raw_fd(), POLLIN));
        if let Some(l) = &listener {
            if backoff.accepting() {
                fds.push(PollFd::new(l.as_raw_fd(), POLLIN));
            }
        }
        let base = fds.len();
        for c in &conns {
            let mut ev = POLLIN;
            if !c.wq.is_empty() {
                ev |= POLLOUT;
            }
            fds.push(PollFd::new(c.stream.as_raw_fd(), ev));
        }

        if poll_fds(&mut fds, POLL_SLICE).is_err() {
            // poll itself failing (ENOMEM) is transient-or-fatal; a short
            // sleep keeps a broken shard from spinning while stop decides
            std::thread::sleep(Duration::from_millis(10));
        }
        wake.drain();
        if hub.stop.load(Ordering::Relaxed) {
            break;
        }

        // --- readable connections (index-stable: nothing mutates the vec) ---
        let polled = conns.len();
        for i in 0..polled {
            if fds[base + i].readable() && conns[i].gone.is_none() {
                handle_readable(hub, id, &mut conns[i], &mut pool);
            }
        }

        // --- inbox: adopted connections and downlink traffic ---
        let cmds: Vec<ShardCmd> = {
            let mut inbox = hub.shards[id].inbox.lock().unwrap();
            inbox.drain(..).collect()
        };
        for cmd in cmds {
            match cmd {
                ShardCmd::Adopt(stream) => conns.push(Conn::new(stream)),
                ShardCmd::Down(dm) => deliver(&dm, &mut conns),
            }
        }

        // --- accept (shard 0) ---
        if let Some(l) = &listener {
            if backoff.accepting() {
                accept_batch(hub, l, &mut backoff, &mut next_shard, &mut conns);
            }
        }

        // --- write drains + slow-consumer eviction ---
        for c in conns.iter_mut() {
            if c.gone.is_none() && !c.wq.is_empty() {
                flush_writes(c);
            }
            if c.gone.is_none() && c.wq.len() > hub.write_queue_limit {
                // slow consumer: unwritten frames are discarded uncharged
                c.gone = Some(Fate::CloseEvict);
            }
        }

        // --- idle sweep ---
        for c in conns.iter_mut() {
            if c.gone.is_none() && c.last_rx.elapsed() >= hub.idle {
                c.gone = Some(if c.node.is_some() {
                    Fate::CloseEvict
                } else {
                    Fate::CloseClean
                });
            }
        }

        // --- detach the departed, fold the dirty ---
        conns.retain_mut(|c| match c.gone {
            Some(fate) => {
                detach(hub, c, fate);
                false
            }
            None => true,
        });
        fold_dirty(hub, &mut conns);
    }

    // stop: orderly teardown — fold every book, no Leave synthesis (the
    // fold loop has already finished; these are not evictions)
    for c in conns.iter_mut() {
        fold_conn(hub, c);
        if let Some(node) = c.node {
            hub.attached[node].store(false, Ordering::SeqCst);
        }
        c.stream.shutdown();
    }
    // the listener drops here (shard 0) — removes the UDS socket file
}

/// Accept everything pending, classifying errors: transient ones skip the
/// dead connection, resource exhaustion backs the listener off the poll
/// set exponentially, and a fatal listener error stops the run and is
/// surfaced to `serve` instead of spinning forever.
fn accept_batch(
    hub: &Arc<Hub>,
    listener: &Listener,
    backoff: &mut AcceptBackoff,
    next_shard: &mut usize,
    conns: &mut Vec<Conn>,
) {
    loop {
        match listener.accept() {
            Ok(Some(stream)) => {
                backoff.clear();
                let target = *next_shard;
                *next_shard = (*next_shard + 1) % hub.shards.len();
                if target == 0 {
                    conns.push(Conn::new(stream));
                } else {
                    hub.shards[target].push(ShardCmd::Adopt(stream));
                }
            }
            Ok(None) => return, // drained
            Err(e) => match classify_accept_error(&e) {
                AcceptClass::Transient => continue,
                AcceptClass::Resource => {
                    backoff.bump();
                    return;
                }
                AcceptClass::Fatal => {
                    *hub.listener_err.lock().unwrap() = Some(e.to_string());
                    hub.stop.store(true, Ordering::SeqCst);
                    hub.wake_all();
                    return;
                }
            },
        }
    }
}

/// Append one downlink message to every connection it addresses. Detached
/// nodes simply have no connection here: the message evaporates uncharged.
fn deliver(dm: &DownMsg, conns: &mut [Conn]) {
    for c in conns.iter_mut() {
        if c.gone.is_some() || c.close_after_drain {
            continue;
        }
        let Some(node) = c.node else { continue };
        if let Some(target) = dm.target {
            if target != node {
                continue;
            }
        }
        let bytes = match &dm.included {
            None => dm.incl.clone(),
            Some(list) => {
                if list.binary_search(&(node as u32)).is_ok() {
                    dm.incl.clone()
                } else {
                    dm.excl.clone()
                }
            }
        };
        c.wq.push_back(WriteItem { bytes, off: 0, charged_bits: dm.charged_bits, extra: dm.extra });
    }
}

/// Drain the write queue as far as the socket allows. Books and charges
/// move only when a frame's **last** byte reaches the kernel; a write
/// error marks the connection for eviction with the partial frame
/// uncounted on both ledgers.
fn flush_writes(c: &mut Conn) {
    while let Some(item) = c.wq.front_mut() {
        match c.stream.write_nb(&item.bytes[item.off..]) {
            Ok(0) => {
                c.gone = Some(if c.node.is_some() { Fate::CloseEvict } else { Fate::CloseClean });
                return;
            }
            Ok(n) => {
                item.off += n;
                if item.off == item.bytes.len() {
                    c.counters.down_total += item.bytes.len() as u64;
                    c.counters.down_extra += item.extra;
                    if item.charged_bits > 0 {
                        c.counters.down_bits += item.charged_bits;
                        c.counters.down_msgs += 1;
                    }
                    c.wq.pop_front();
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                // write half died first: evict (Leave synthesized if attached)
                c.gone = Some(if c.node.is_some() { Fate::CloseEvict } else { Fate::CloseClean });
                return;
            }
        }
    }
    if c.close_after_drain && c.wq.is_empty() {
        // reject delivered; the connection was never on the books
        c.gone = Some(Fate::CloseClean);
    }
}

/// Pull every complete frame the socket has buffered through the cursor,
/// dispatching each into the fold loop. Sets `c.gone` on close/violation.
fn handle_readable(hub: &Arc<Hub>, shard_id: usize, c: &mut Conn, pool: &mut BufferPool) {
    loop {
        match c.cursor.step(&mut c.stream, pool) {
            Ok(CursorStep::Frame(frame, bytes)) => {
                c.last_rx = Instant::now();
                if c.close_after_drain {
                    continue; // rejected peer babbling: ignore, stay off the books
                }
                match c.node {
                    None => {
                        if !handshake(hub, shard_id, c, frame, bytes) {
                            return;
                        }
                    }
                    Some(node) => {
                        if !dispatch_frame(hub, c, node, frame, bytes) {
                            return;
                        }
                    }
                }
            }
            Ok(CursorStep::NeedMore) => return,
            Ok(CursorStep::Eof) => {
                c.gone = Some(match c.node {
                    // EOF without an ack is an abrupt death (synthesize the
                    // Leave); with the ack it is the orderly drain close
                    Some(_) if !c.acked => Fate::CloseEvict,
                    _ => Fate::CloseClean,
                });
                return;
            }
            Err(_) => {
                // torn frame / lying prefix / undecodable garbage
                c.gone = Some(match c.node {
                    Some(_) => Fate::CloseEvict,
                    None => Fate::CloseClean, // garbage opener: never attached
                });
                return;
            }
        }
    }
}

/// Validate the `Hello` opener and claim the node's slot. Returns false if
/// the connection is done for (rejected connections flush their `Reject`
/// and close; they never touch the per-link books).
fn handshake(hub: &Arc<Hub>, shard_id: usize, c: &mut Conn, frame: Frame, bytes: u64) -> bool {
    let Frame::Hello { proto, node, m, digest } = frame else {
        // first frame was not Hello: drop silently, as ever
        c.gone = Some(Fate::CloseClean);
        return false;
    };
    let reason = if proto != PROTO_VERSION {
        Some(format!("protocol version {proto} != {PROTO_VERSION}"))
    } else if digest != hub.digest {
        Some("config digest mismatch".to_string())
    } else if m as usize != hub.m {
        Some(format!("dimension {} != {m}", hub.m))
    } else if node as usize >= hub.n {
        Some(format!("node id {node} out of range (n={})", hub.n))
    } else {
        None
    };
    if let Some(reason) = reason {
        c.queue_control(&Frame::Reject { reason });
        c.close_after_drain = true;
        return true; // keep alive long enough to flush the Reject
    }
    let node = node as usize;
    if hub.attached[node].swap(true, Ordering::SeqCst) {
        c.queue_control(&Frame::Reject { reason: format!("node {node} already attached") });
        c.close_after_drain = true;
        return true;
    }
    // accepted: this connection is on the books from its Hello onward
    // (handshake frames are pure framing extra — charged 0 by eq. 20)
    c.node = Some(node);
    hub.node_shard[node].store(shard_id, Ordering::SeqCst);
    c.counters.up_total += bytes;
    c.counters.up_extra += bytes; // Hello charges 0: extra == total
    c.queue_control(&Frame::Welcome);
    true
}

/// Translate one post-handshake frame into the fold loop's message, with
/// the same validation, seq stamping, and charging as the old per-
/// connection reader. Returns false when the connection is finished.
fn dispatch_frame(hub: &Arc<Hub>, c: &mut Conn, node: usize, frame: Frame, bytes: u64) -> bool {
    c.counters.up_total += bytes;
    c.counters.up_extra += frame.socket_extra_bytes();
    let msg = match frame {
        Frame::InitFull { node: fnode, x0, u0 } if fnode as usize == node => {
            NodeToServer::InitFull { node, x0, u0 }
        }
        Frame::Update { node: fnode, dx_wire, du_wire } if fnode as usize == node => {
            let seq = hub.seqs[node].fetch_add(1, Ordering::SeqCst);
            NodeToServer::Update { node, iter: 0, seq, dx_wire, du_wire }
        }
        Frame::Skip { node: fnode } if fnode as usize == node => {
            let seq = hub.seqs[node].fetch_add(1, Ordering::SeqCst);
            NodeToServer::Skip { node, seq }
        }
        Frame::ShutdownAck { node: fnode } if fnode as usize == node => {
            c.acked = true;
            NodeToServer::ShutdownAck { node }
        }
        // wrong-node claim or a frame kind a worker must not send: a
        // protocol violation after the handshake evicts
        _ => {
            c.gone = Some(Fate::CloseEvict);
            return false;
        }
    };
    // eq. (20) charge at the byte-moving point; control frames (skip/ack)
    // stay off the books, like every other runtime
    if matches!(msg, NodeToServer::Update { .. } | NodeToServer::InitFull { .. }) {
        c.counters.up_bits += msg.wire_bits();
        c.counters.up_msgs += 1;
    }
    if hub.up_tx.send(msg).is_err() {
        // the fold loop finished first: orderly close
        c.gone = Some(Fate::CloseClean);
        return false;
    }
    true
}

/// Fold one connection's local counters into the global books and the
/// charged eq. (20) ledger. Exactness: everything in the counters
/// describes *completed* frames only.
fn fold_conn(hub: &Hub, c: &mut Conn) {
    let Some(node) = c.node else { return };
    if !c.counters.dirty() {
        return;
    }
    let k = std::mem::take(&mut c.counters);
    {
        let mut books = hub.books.lock().unwrap();
        books[node].up_total += k.up_total;
        books[node].up_extra += k.up_extra;
        books[node].down_total += k.down_total;
        books[node].down_extra += k.down_extra;
    }
    if (k.up_msgs | k.down_msgs) != 0 {
        let mut acc = hub.accounting.lock().unwrap();
        if k.up_msgs != 0 {
            acc.record_uplink_batch(node, k.up_msgs, k.up_bits);
        }
        if k.down_msgs != 0 {
            acc.record_downlink_batch(node, k.down_msgs, k.down_bits);
        }
    }
}

/// Amortized fold: once per poll batch, not per frame — the recorder's
/// mid-run `comm_bits` stays current to within one wakeup while the frame
/// hot path touches no global lock.
fn fold_dirty(hub: &Hub, conns: &mut [Conn]) {
    for c in conns.iter_mut() {
        fold_conn(hub, c);
    }
}

/// Remove a connection from the run: definitive counter fold, slot
/// release, and (for evictions) the synthesized `Leave` the worker could
/// not send. Queued-unwritten frames are discarded uncharged.
fn detach(hub: &Hub, c: &mut Conn, fate: Fate) {
    fold_conn(hub, c);
    if let Some(node) = c.node {
        hub.attached[node].store(false, Ordering::SeqCst);
        if fate == Fate::CloseEvict {
            let _ = hub.up_tx.send(NodeToServer::Leave { node });
        }
    }
    c.stream.shutdown();
}
