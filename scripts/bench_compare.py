#!/usr/bin/env python3
"""Diff two BENCH_engine.json snapshots and emit a markdown delta table.

Used by the non-blocking `bench-trajectory` CI job: the committed
BENCH_engine.json (if any) is the baseline, the fresh bench run is the
current snapshot, and the table lands in the job summary so the perf
trajectory is visible per PR without gating merges on noisy runners.

Robustness contract: the two files come from *different revisions* of the
bench, so any section / record / field may exist on only one side or have
the wrong type — every such case degrades to "n/a" or a note, never a
crash (the job is informational and always exits 0).

Stdlib only.

Usage:
    bench_compare.py --current BENCH_engine.json \
        [--baseline path/to/previous.json] [--summary $GITHUB_STEP_SUMMARY]
"""

import argparse
import json
import sys

# section name -> (key fields, timing metric)
SECTIONS = {
    "sweeps": (["label", "n", "m", "tau"], "wall_s"),
    "scale_xl": (["n", "m", "tau"], "wall_s"),
    "server_round": (["n", "m", "p"], "inc_round_us"),
    "server_round_nn": (["n", "m", "p", "k"], "fused_round_us"),
    "deploy_loadgen": (["nodes"], "rounds_per_s"),
    "trigger": (["n", "delta", "adapt"], "wall_s"),
}

# metrics where a larger number is an improvement (throughput), so the
# delta arrows and the regression gate run in the opposite direction from
# the timing/memory metrics
HIGHER_IS_BETTER = {("deploy_loadgen", "rounds_per_s")}

# soft regression gates: (section, metric) pairs checked against
# --warn-threshold. peak_rss_mb guards the million-node O(active)-memory
# work the same way inc_round_us guards the server hot path, and
# deploy_loadgen rounds/s guards the reactor socket server (direction
# flipped: a *drop* past the threshold warns).
GATES = [
    ("server_round", "inc_round_us"),
    ("scale_xl", "peak_rss_mb"),
    ("deploy_loadgen", "rounds_per_s"),
]


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"(bench_compare: could not read {path}: {e})", file=sys.stderr)
        return None
    if not isinstance(doc, dict):
        print(f"(bench_compare: {path} is not a JSON object; ignoring)", file=sys.stderr)
        return None
    return doc


def records_of(doc, name):
    """A section's record list, tolerating absent/mistyped sections."""
    recs = (doc or {}).get(name)
    if not isinstance(recs, list):
        return []
    return [r for r in recs if isinstance(r, dict)]


def is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def fmt_delta(old, new, higher_is_better=False):
    """Relative change, signed; n/a when either cell is missing/zero."""
    if not is_num(old) or old == 0 or not is_num(new):
        return "n/a"
    pct = 100.0 * (new - old) / old
    worse, better = (pct < -10.0, pct > 10.0) if higher_is_better \
        else (pct > 10.0, pct < -10.0)
    arrow = "🔺" if worse else ("✅" if better else "·")
    return f"{pct:+.1f}% {arrow}"


def index_section(records, key_fields):
    out = {}
    for rec in records:
        key = tuple(rec.get(k) for k in key_fields)
        out[key] = rec
    return out


def section_table(name, key_fields, metric, baseline, current):
    """Markdown table for one section, keyed on key_fields, timing `metric`.

    Tolerates the section (or any record/field) being present in only one
    of baseline/current: missing baseline cells render as n/a, and
    baseline-only rows are appended with an em-dash current cell so a
    dropped configuration is visible instead of vanishing.
    """
    cur = index_section(records_of(current, name), key_fields)
    base = index_section(records_of(baseline, name), key_fields)
    if not cur and not base:
        return f"\n_(no `{name}` records in either snapshot)_\n"
    lines = [
        f"\n### {name}\n",
        "| " + " | ".join(key_fields) + f" | {metric} (base) | {metric} (now) | delta |",
        "|" + "---|" * (len(key_fields) + 3),
    ]

    def cell(v):
        return f"{v:.3f}" if is_num(v) else "—"

    hib = (name, metric) in HIGHER_IS_BETTER
    for key, rec in cur.items():
        old = base.get(key, {}).get(metric)
        new = rec.get(metric)
        cells = [str(k) for k in key] + [cell(old), cell(new), fmt_delta(old, new, hib)]
        lines.append("| " + " | ".join(cells) + " |")
    for key in (k for k in base if k not in cur):
        old = base[key].get(metric)
        cells = [str(k) for k in key] + [cell(old), "—", "n/a (dropped)"]
        lines.append("| " + " | ".join(cells) + " |")
    return "\n".join(lines) + "\n"


def one_sided_sections(baseline, current):
    """Names of list-valued sections present in exactly one snapshot."""
    def sections(doc):
        return {k for k, v in (doc or {}).items() if isinstance(v, list)}

    cur, base = sections(current), sections(baseline)
    notes = []
    for name in sorted(base - cur):
        notes.append(f"- section `{name}` exists only in the baseline")
    for name in sorted(cur - base):
        notes.append(f"- section `{name}` exists only in the current snapshot")
    return notes


def scale_xl_memory_table(baseline, current):
    """Extra columns for the million-node section: the timing table above
    only shows wall_s, but scale_xl's acceptance metric is peak RSS, with
    the queue high-water mark as the O(n)-not-O(rounds·n) witness."""
    key_fields = SECTIONS["scale_xl"][0]
    cur = index_section(records_of(current, "scale_xl"), key_fields)
    base = index_section(records_of(baseline, "scale_xl"), key_fields)
    if not cur:
        return ""
    lines = [
        "\n### scale_xl memory\n",
        "| " + " | ".join(key_fields)
        + " | peak_rss_mb (base) | peak_rss_mb (now) | delta"
        + " | queue_peak | events_scheduled |",
        "|" + "---|" * (len(key_fields) + 5),
    ]

    def cell(v):
        return f"{v:.1f}" if is_num(v) else "—"

    for key, rec in cur.items():
        old = base.get(key, {}).get("peak_rss_mb")
        new = rec.get("peak_rss_mb")
        qp, ev = rec.get("queue_peak"), rec.get("events_scheduled")
        cells = [str(k) for k in key] + [
            cell(old),
            cell(new),
            fmt_delta(old, new),
            f"{qp:.0f}" if is_num(qp) else "—",
            f"{ev:.0f}" if is_num(ev) else "—",
        ]
        lines.append("| " + " | ".join(cells) + " |")
    return "\n".join(lines) + "\n"


def regression_warnings(baseline, current, threshold, name, metric):
    """Rows of `name` whose `metric` regressed beyond threshold.

    Direction-aware: for timing/memory metrics a regression is the ratio
    new/old exceeding the threshold; for HIGHER_IS_BETTER metrics
    (throughput) it is old/new exceeding it — a drop.

    Soft gate only: the caller prints a prominent warning but still exits 0
    (runner noise must never block a merge on its own).
    """
    key_fields = SECTIONS[name][0]
    hib = (name, metric) in HIGHER_IS_BETTER
    cur = index_section(records_of(current, name), key_fields)
    base = index_section(records_of(baseline, name), key_fields)
    warns = []
    for key, rec in cur.items():
        old = base.get(key, {}).get(metric)
        new = rec.get(metric)
        if not (is_num(old) and old > 0 and is_num(new) and new > 0):
            continue
        ratio = old / new if hib else new / old
        if ratio > threshold:
            warns.append((key, old, new, ratio))
    return warns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True)
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--summary", default=None,
                    help="file to append the markdown to (e.g. $GITHUB_STEP_SUMMARY)")
    ap.add_argument("--warn-threshold", type=float, default=None,
                    help="soft regression gate: warn prominently when a "
                         "gated metric (server_round inc_round_us, "
                         "scale_xl peak_rss_mb, deploy_loadgen rounds_per_s "
                         "— the last direction-flipped: a drop warns) moves "
                         "past THRESHOLD x its committed baseline (never "
                         "fails the job)")
    args = ap.parse_args()

    current = load(args.current)
    if current is None:
        print("bench_compare: no current snapshot; nothing to compare")
        return
    baseline = load(args.baseline) if args.baseline else None

    out = ["## engine_scale bench trajectory"]
    if baseline is None:
        out.append(
            "\n_No committed baseline found — this snapshot becomes the "
            "first point of the trajectory._\n"
        )
    for doc, label in ((baseline, "baseline"), (current, "current")):
        prov = (doc or {}).get("provenance")
        if isinstance(prov, str):
            out.append(f"\n_{label} provenance: {prov}_\n")
    mode = "fast (QADMM_BENCH_FAST)" if current.get("fast") else "full"
    out.append(f"\nmode: {mode}\n")
    for name, (key_fields, metric) in SECTIONS.items():
        out.append(section_table(name, key_fields, metric, baseline, current))
    mem_table = scale_xl_memory_table(baseline, current)
    if mem_table:
        out.append(mem_table)
    notes = one_sided_sections(baseline, current)
    if baseline is not None and notes:
        out.append("\n" + "\n".join(notes) + "\n")
    if args.warn_threshold is not None and baseline is not None:
        for name, metric in GATES:
            warns = regression_warnings(
                baseline, current, args.warn_threshold, name, metric
            )
            if not warns:
                continue
            key_fields = SECTIONS[name][0]
            block = [
                "\n> [!WARNING]",
                f"> ## ⚠️ {name} `{metric}` regressed more than "
                f"{args.warn_threshold:.2f}x vs the committed baseline",
                "> Non-blocking (runners are noisy), but check before "
                "merging a hot-path change:",
            ]
            for key, old, new, ratio in warns:
                label = ", ".join(f"{f}={v}" for f, v in zip(key_fields, key))
                block.append(
                    f"> - {label}: {old:.1f} → {new:.1f} ({ratio:.2f}x)"
                )
            out.append("\n".join(block) + "\n")
    text = "\n".join(out)

    print(text)
    if args.summary:
        try:
            with open(args.summary, "a") as f:
                f.write(text + "\n")
        except OSError as e:
            print(f"(bench_compare: could not append to summary: {e})",
                  file=sys.stderr)


if __name__ == "__main__":
    main()
