//! Socket transport for the deployment: one abstraction over TCP and
//! Unix-domain sockets (std-only — no async runtime and no extra crates;
//! readiness comes from a thin `poll(2)` wrapper over the raw fds std
//! already exposes), plus both framed read disciplines the two sides need:
//!
//! - the **server** is a readiness-driven reactor: connections are
//!   nonblocking, and [`FrameCursor`] reassembles `[u32 len][u8 kind][body]`
//!   frames across poll wakeups with per-shard pooled body buffers — a
//!   partial frame costs a cursor, never a blocked thread;
//! - the **worker** keeps the simple blocking loop ([`read_frame`]), which
//!   polls in short slices so a raised stop flag wins at the next slice
//!   boundary between frames (a peer trickling bytes can no longer hold
//!   teardown hostage until the idle budget expires);
//! - a peer that goes quiet past the idle budget is reported as
//!   [`ReadOutcome::IdleTimeout`] — the half-open-connection case TCP
//!   keepalives are too slow for — so the server can evict it and the
//!   P/τ trigger never wedges on a dead worker;
//! - a clean EOF **between** frames is orderly close; an EOF or garbage
//!   **inside** a frame is an `Err`.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::{AsRawFd, RawFd};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::frame::{Frame, MAX_FRAME_BYTES};

/// How long one blocking read slice lasts before the loop re-checks the
/// stop flag and the idle budget (worker side); also the reactor's maximum
/// poll timeout, bounding how stale an idle sweep can be.
pub const POLL_SLICE: Duration = Duration::from_millis(100);

// ---------------------------------------------------------------------------
// poll(2), std-only
//
// The reactor needs readiness multiplexing over a few hundred fds. std has
// no portable API for that, and the container policy is "no new crates", so
// this is the raw libc call declared directly: `pollfd` is a stable part of
// the POSIX ABI (fd: int, events: short, revents: short) and `nfds_t` is
// unsigned long on Linux (unsigned int elsewhere).
// ---------------------------------------------------------------------------

pub const POLLIN: i16 = 0x001;
pub const POLLOUT: i16 = 0x004;
pub const POLLERR: i16 = 0x008;
pub const POLLHUP: i16 = 0x010;
pub const POLLNVAL: i16 = 0x020;

/// One entry in a `poll(2)` set — ABI-identical to `struct pollfd`.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PollFd {
    pub fd: RawFd,
    pub events: i16,
    pub revents: i16,
}

impl PollFd {
    pub fn new(fd: RawFd, events: i16) -> Self {
        Self { fd, events, revents: 0 }
    }

    /// Error conditions (HUP/ERR/NVAL) are reported as readable so the
    /// owner's read path observes the failure and closes the connection.
    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLHUP | POLLERR | POLLNVAL) != 0
    }

    pub fn writable(&self) -> bool {
        self.revents & (POLLOUT | POLLHUP | POLLERR | POLLNVAL) != 0
    }
}

#[cfg(target_os = "linux")]
type NfdsT = std::os::raw::c_ulong;
#[cfg(not(target_os = "linux"))]
type NfdsT = std::os::raw::c_uint;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: std::os::raw::c_int) -> std::os::raw::c_int;
}

/// Wait for readiness on a set of fds. Returns the number of entries with
/// nonzero `revents` (0 on timeout). EINTR retries with the full timeout —
/// callers tolerate the jitter, and the wake pipe bounds real latency.
pub fn poll_fds(fds: &mut [PollFd], timeout: Duration) -> std::io::Result<usize> {
    let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
    loop {
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = std::io::Error::last_os_error();
        if err.kind() != ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

/// The reactor's wake channel: a nonblocking socketpair standing in for a
/// self-pipe (std exposes `UnixStream::pair`, not `pipe(2)`). The read end
/// sits in the shard's poll set; [`Waker`]s are cheap clonable handles to
/// the write end that any thread can fire.
pub struct WakePipe {
    rx: UnixStream,
    tx: Arc<UnixStream>,
}

impl WakePipe {
    pub fn new() -> Result<WakePipe> {
        let (rx, tx) = UnixStream::pair().context("wake pipe")?;
        rx.set_nonblocking(true)?;
        tx.set_nonblocking(true)?;
        Ok(WakePipe { rx, tx: Arc::new(tx) })
    }

    pub fn waker(&self) -> Waker {
        Waker(self.tx.clone())
    }

    pub fn as_raw_fd(&self) -> RawFd {
        self.rx.as_raw_fd()
    }

    /// Swallow every pending wake byte (level-triggered poll would
    /// otherwise spin on them).
    pub fn drain(&self) {
        let mut buf = [0u8; 256];
        while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
    }
}

/// Handle to a [`WakePipe`]'s write end. `wake` is wait-free: a full pipe
/// means a wake is already pending, which is all a level wake needs.
#[derive(Clone)]
pub struct Waker(Arc<UnixStream>);

impl Waker {
    pub fn wake(&self) {
        let _ = (&*self.0).write(&[1u8]);
    }
}

/// A deployment endpoint address: `tcp:HOST:PORT` or `uds:/path/to.sock`
/// (a bare path containing `/` is accepted as UDS for convenience).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Endpoint {
    Tcp(String),
    Uds(PathBuf),
}

impl Endpoint {
    pub fn parse(s: &str) -> Result<Endpoint> {
        if let Some(addr) = s.strip_prefix("tcp:") {
            ensure!(addr.contains(':'), "tcp endpoint needs HOST:PORT, got '{addr}'");
            Ok(Endpoint::Tcp(addr.to_string()))
        } else if let Some(path) = s.strip_prefix("uds:") {
            Ok(Endpoint::Uds(PathBuf::from(path)))
        } else if s.contains('/') {
            Ok(Endpoint::Uds(PathBuf::from(s)))
        } else {
            bail!("endpoint '{s}' is neither tcp:HOST:PORT nor uds:/path")
        }
    }

    pub fn label(&self) -> String {
        match self {
            Endpoint::Tcp(a) => format!("tcp:{a}"),
            Endpoint::Uds(p) => format!("uds:{}", p.display()),
        }
    }
}

/// A connected stream over either transport. Cloning duplicates the OS
/// handle (the worker's writer can own a half independently).
pub enum Stream {
    Tcp(TcpStream),
    Uds(UnixStream),
}

impl AsRawFd for Stream {
    fn as_raw_fd(&self) -> RawFd {
        match self {
            Stream::Tcp(s) => s.as_raw_fd(),
            Stream::Uds(s) => s.as_raw_fd(),
        }
    }
}

impl Stream {
    fn connect_once(ep: &Endpoint) -> std::io::Result<Stream> {
        Ok(match ep {
            Endpoint::Tcp(addr) => Stream::Tcp(TcpStream::connect(addr)?),
            Endpoint::Uds(path) => Stream::Uds(UnixStream::connect(path)?),
        })
    }

    pub fn connect(ep: &Endpoint) -> Result<Stream> {
        Stream::connect_once(ep).with_context(|| format!("connect {}", ep.label()))
    }

    /// Connect with bounded exponential backoff on transient failures. A
    /// full loadgen burst can overflow the listen backlog (ECONNREFUSED /
    /// ECONNRESET on the SYN), and a worker process racing `serve`'s bind
    /// can see ENOENT on the socket path — both deserve a retry, not a
    /// permanently dead worker. Hard errors (EACCES, unroutable address)
    /// fail immediately.
    pub fn connect_retry(ep: &Endpoint, attempts: u32, base_backoff: Duration) -> Result<Stream> {
        let attempts = attempts.max(1);
        let mut delay = base_backoff;
        let mut last: Option<std::io::Error> = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                std::thread::sleep(delay);
                delay = (delay * 2).min(Duration::from_secs(2));
            }
            match Stream::connect_once(ep) {
                Ok(s) => return Ok(s),
                Err(e) if transient_connect_error(&e) => last = Some(e),
                Err(e) => {
                    return Err(e).with_context(|| format!("connect {}", ep.label()));
                }
            }
        }
        Err(last.expect("at least one attempt ran"))
            .with_context(|| format!("connect {} failed after {attempts} attempts", ep.label()))
    }

    pub fn try_clone(&self) -> Result<Stream> {
        Ok(match self {
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
            Stream::Uds(s) => Stream::Uds(s.try_clone()?),
        })
    }

    pub fn set_read_timeout(&self, t: Option<Duration>) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(t)?,
            Stream::Uds(s) => s.set_read_timeout(t)?,
        }
        Ok(())
    }

    pub fn set_nonblocking(&self, nb: bool) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nb)?,
            Stream::Uds(s) => s.set_nonblocking(nb)?,
        }
        Ok(())
    }

    /// Disable Nagle on TCP (frames are latency-sensitive and small); a
    /// no-op on UDS.
    pub fn tune(&self) {
        if let Stream::Tcp(s) = self {
            let _ = s.set_nodelay(true);
        }
    }

    /// Best-effort full shutdown, unblocking any thread mid-read.
    pub fn shutdown(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            Stream::Uds(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }

    fn read_impl(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Uds(s) => s.read(buf),
        }
    }

    /// One nonblocking write attempt; the raw io::Result lets the reactor
    /// distinguish WouldBlock (keep queued) from a dead peer (evict).
    pub fn write_nb(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Uds(s) => s.write(buf),
        }
    }

    /// Write one encoded frame and flush; returns the bytes put on the
    /// socket (the worker's byte-counter input). Blocking-mode streams only.
    pub fn write_frame(&mut self, frame: &Frame) -> Result<u64> {
        let bytes = frame.encode();
        match self {
            Stream::Tcp(s) => {
                s.write_all(&bytes)?;
                s.flush()?;
            }
            Stream::Uds(s) => {
                s.write_all(&bytes)?;
                s.flush()?;
            }
        }
        Ok(bytes.len() as u64)
    }
}

fn transient_connect_error(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        ErrorKind::ConnectionRefused
            | ErrorKind::ConnectionReset
            | ErrorKind::ConnectionAborted
            | ErrorKind::TimedOut
            | ErrorKind::NotFound
            | ErrorKind::AddrNotAvailable
            | ErrorKind::WouldBlock
            | ErrorKind::Interrupted
    )
}

// ---------------------------------------------------------------------------
// Nonblocking frame reassembly (server side)
// ---------------------------------------------------------------------------

/// Recycles frame body buffers within one reactor shard, so the steady
/// state allocates nothing per frame: `take` hands back a cleared buffer
/// sized to the frame, `put` keeps it unless it is oversized or the pool
/// is full (a one-off 200 MB init frame must not pin 200 MB forever).
pub struct BufferPool {
    bufs: Vec<Vec<u8>>,
}

/// Buffers above this capacity are dropped instead of pooled.
const POOL_MAX_BUF_BYTES: usize = 1 << 20;
/// At most this many idle buffers are retained per pool.
const POOL_MAX_BUFS: usize = 16;

impl Default for BufferPool {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferPool {
    pub fn new() -> Self {
        Self { bufs: Vec::new() }
    }

    pub fn take(&mut self, len: usize) -> Vec<u8> {
        let mut b = self.bufs.pop().unwrap_or_default();
        b.clear();
        b.resize(len, 0);
        b
    }

    pub fn put(&mut self, buf: Vec<u8>) {
        if buf.capacity() <= POOL_MAX_BUF_BYTES && self.bufs.len() < POOL_MAX_BUFS {
            self.bufs.push(buf);
        }
    }
}

/// One step of [`FrameCursor::step`].
#[derive(Debug)]
pub enum CursorStep {
    /// A complete decoded frame plus its total socket footprint in bytes
    /// (length prefix included).
    Frame(Frame, u64),
    /// The socket has no more data right now; re-arm POLLIN and return.
    NeedMore,
    /// Orderly close: EOF on a frame boundary.
    Eof,
}

/// Per-connection read state machine: reassembles `[u32 len][u8 kind+body]`
/// frames from a **nonblocking** stream across poll wakeups. A single-byte-
/// at-a-time sender costs cursor arithmetic, never a blocked thread, and a
/// lying length prefix is rejected before any buffer is sized from it.
///
/// Exactness contract: byte counts are reported only for **complete**
/// frames — a partial frame at eviction/teardown was never handed to the
/// caller and so is neither booked nor charged, keeping both reconciliation
/// ledgers describing the identical set of frames.
#[derive(Default)]
pub struct FrameCursor {
    len_buf: [u8; 4],
    len_got: usize,
    body: Vec<u8>,
    body_got: usize,
}

impl FrameCursor {
    pub fn new() -> Self {
        Self::default()
    }

    /// True once any byte of the next frame has been consumed (an EOF here
    /// is a torn frame, not an orderly close).
    pub fn mid_frame(&self) -> bool {
        self.len_got > 0 || !self.body.is_empty()
    }

    /// Pull as much as the socket has: returns the next complete frame,
    /// or `NeedMore` on WouldBlock, or `Eof` on a clean boundary close.
    /// Call in a loop to drain a readable socket (frames already buffered
    /// by the kernel decode without another poll wakeup).
    pub fn step(&mut self, s: &mut Stream, pool: &mut BufferPool) -> Result<CursorStep> {
        loop {
            if self.body.is_empty() {
                match s.read_impl(&mut self.len_buf[self.len_got..4]) {
                    Ok(0) => {
                        if self.len_got == 0 {
                            return Ok(CursorStep::Eof);
                        }
                        bail!("connection closed mid-frame ({} of 4 header bytes)", self.len_got);
                    }
                    Ok(n) => {
                        self.len_got += n;
                        if self.len_got == 4 {
                            let len = u32::from_le_bytes(self.len_buf);
                            ensure!(
                                (1..=MAX_FRAME_BYTES).contains(&len),
                                "frame length {len} outside (0, {MAX_FRAME_BYTES}]"
                            );
                            self.body = pool.take(len as usize);
                            self.body_got = 0;
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        return Ok(CursorStep::NeedMore)
                    }
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(e) => return Err(e.into()),
                }
            } else {
                match s.read_impl(&mut self.body[self.body_got..]) {
                    Ok(0) => bail!(
                        "connection closed mid-frame ({} of {} body bytes)",
                        self.body_got,
                        self.body.len()
                    ),
                    Ok(n) => {
                        self.body_got += n;
                        if self.body_got == self.body.len() {
                            let decoded = Frame::decode(self.body[0], &self.body[1..]);
                            let bytes = 4 + self.body.len() as u64;
                            pool.put(std::mem::take(&mut self.body));
                            self.len_got = 0;
                            return Ok(CursorStep::Frame(decoded?, bytes));
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        return Ok(CursorStep::NeedMore)
                    }
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(e) => return Err(e.into()),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Blocking framed reads (worker side)
// ---------------------------------------------------------------------------

/// What one framed-read attempt produced.
pub enum ReadOutcome {
    /// A complete, decoded frame plus its total socket footprint in bytes
    /// (length prefix included) — the reader's byte-counter input.
    Frame(Frame, u64),
    /// Orderly close: EOF on a frame boundary.
    Eof,
    /// The peer went silent past the idle budget (half-open connection).
    IdleTimeout,
    /// The stop flag was raised mid-wait; no complete frame was consumed.
    Stopped,
}

/// Read exactly `buf.len()` bytes, polling in [`POLL_SLICE`] slices.
/// `mid_frame` is true once part of a frame has been consumed — then EOF
/// and idle both become hard errors (a frame must never be torn). A raised
/// stop flag wins at the next slice boundary regardless of how many header
/// bytes have trickled in (teardown discards them uncounted); only a
/// mid-*body* stop is an error, because the caller has already sized a
/// buffer from the prefix and a silent discard would be indistinguishable
/// from a torn frame.
fn read_full(
    s: &mut Stream,
    buf: &mut [u8],
    stop: &AtomicBool,
    idle: Duration,
    mid_frame: bool,
) -> Result<Option<ReadOutcome>> {
    let mut got = 0usize;
    let mut quiet_since = Instant::now();
    while got < buf.len() {
        match s.read_impl(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 && !mid_frame {
                    return Ok(Some(ReadOutcome::Eof));
                }
                bail!("connection closed mid-frame ({got} of {} bytes)", buf.len());
            }
            Ok(n) => {
                got += n;
                quiet_since = Instant::now();
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) {
                    if mid_frame {
                        bail!("stopped mid-frame ({got} of {} bytes)", buf.len());
                    }
                    return Ok(Some(ReadOutcome::Stopped));
                }
                if quiet_since.elapsed() >= idle {
                    if got == 0 && !mid_frame {
                        return Ok(Some(ReadOutcome::IdleTimeout));
                    }
                    bail!("peer idle mid-frame ({got} of {} bytes)", buf.len());
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(None)
}

/// Read one `[u32 len][u8 kind][body]` frame. The length prefix is
/// validated against [`MAX_FRAME_BYTES`] before the body buffer is sized —
/// a garbage prefix costs at most 4 bytes of reading, never an allocation.
/// The stream must have a read timeout set (≤ [`POLL_SLICE`] granularity
/// is applied by the caller via `set_read_timeout`).
pub fn read_frame(s: &mut Stream, stop: &AtomicBool, idle: Duration) -> Result<ReadOutcome> {
    let mut len_buf = [0u8; 4];
    if let Some(out) = read_full(s, &mut len_buf, stop, idle, false)? {
        return Ok(out);
    }
    let len = u32::from_le_bytes(len_buf);
    ensure!(
        (1..=MAX_FRAME_BYTES).contains(&len),
        "frame length {len} outside (0, {MAX_FRAME_BYTES}]"
    );
    let mut body = vec![0u8; len as usize];
    if read_full(s, &mut body, stop, idle, true)?.is_some() {
        unreachable!("mid-frame reads error instead of yielding an outcome");
    }
    let frame = Frame::decode(body[0], &body[1..])?;
    Ok(ReadOutcome::Frame(frame, 4 + len as u64))
}

/// Blocking frame read for the worker side: no stop flag, a generous idle
/// budget (the server may legitimately be quiet while other nodes hold up
/// a round).
pub fn read_frame_blocking(s: &mut Stream, idle: Duration) -> Result<ReadOutcome> {
    static NEVER: AtomicBool = AtomicBool::new(false);
    read_frame(s, &NEVER, idle)
}

/// A bound listener over either transport, in non-blocking accept mode so
/// the reactor can park it in a poll set.
pub enum Listener {
    Tcp(TcpListener),
    Uds(UnixListener, PathBuf),
}

impl AsRawFd for Listener {
    fn as_raw_fd(&self) -> RawFd {
        match self {
            Listener::Tcp(l) => l.as_raw_fd(),
            Listener::Uds(l, _) => l.as_raw_fd(),
        }
    }
}

impl Listener {
    /// Bind and report the *resolved* endpoint (TCP port 0 resolves to the
    /// kernel-assigned port — what the loadgen/smoke connect back to).
    pub fn bind(ep: &Endpoint) -> Result<(Listener, Endpoint)> {
        match ep {
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
                let local = l.local_addr()?;
                l.set_nonblocking(true)?;
                Ok((Listener::Tcp(l), Endpoint::Tcp(local.to_string())))
            }
            Endpoint::Uds(path) => {
                // a stale socket file from a crashed server blocks rebinding
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .with_context(|| format!("bind {}", path.display()))?;
                l.set_nonblocking(true)?;
                Ok((Listener::Uds(l, path.clone()), Endpoint::Uds(path.clone())))
            }
        }
    }

    /// Non-blocking accept: `Ok(None)` when nothing is pending. The raw
    /// io::Error is preserved so the caller can classify transient vs
    /// resource-exhaustion vs fatal listener failures. Accepted streams
    /// come back nonblocking and tuned — reactor-ready.
    pub fn accept(&self) -> std::io::Result<Option<Stream>> {
        let res = match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            Listener::Uds(l, _) => l.accept().map(|(s, _)| Stream::Uds(s)),
        };
        match res {
            Ok(s) => {
                s.tune();
                match &s {
                    Stream::Tcp(t) => t.set_nonblocking(true)?,
                    Stream::Uds(u) => u.set_nonblocking(true)?,
                }
                Ok(Some(s))
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e),
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Uds(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair() -> (Stream, Stream) {
        let (a, b) = UnixStream::pair().unwrap();
        (Stream::Uds(a), Stream::Uds(b))
    }

    #[test]
    fn endpoint_parsing() {
        assert_eq!(
            Endpoint::parse("tcp:127.0.0.1:4700").unwrap(),
            Endpoint::Tcp("127.0.0.1:4700".into())
        );
        assert_eq!(
            Endpoint::parse("uds:/tmp/q.sock").unwrap(),
            Endpoint::Uds(PathBuf::from("/tmp/q.sock"))
        );
        assert_eq!(
            Endpoint::parse("/tmp/q.sock").unwrap(),
            Endpoint::Uds(PathBuf::from("/tmp/q.sock"))
        );
        assert!(Endpoint::parse("tcp:noport").is_err());
        assert!(Endpoint::parse("gibberish").is_err());
    }

    /// One frame over a real UDS pair: written bytes == read bytes ==
    /// encoded length, and the frame survives intact.
    #[test]
    fn frame_roundtrip_over_uds() {
        let (mut a, mut b) = pair();
        b.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let f = Frame::Update { node: 3, dx_wire: vec![1, 2, 3, 4], du_wire: vec![5, 6] };
        let wrote = a.write_frame(&f).unwrap();
        let stop = AtomicBool::new(false);
        match read_frame(&mut b, &stop, Duration::from_secs(1)).unwrap() {
            ReadOutcome::Frame(got, bytes) => {
                assert_eq!(got, f);
                assert_eq!(bytes, wrote);
            }
            _ => panic!("expected a frame"),
        }
        // orderly close → Eof at the boundary
        drop(a);
        match read_frame(&mut b, &stop, Duration::from_secs(1)).unwrap() {
            ReadOutcome::Eof => {}
            _ => panic!("expected eof"),
        }
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        let (mut a, mut b) = pair();
        b.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        if let Stream::Uds(s) = &mut a {
            s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        }
        let stop = AtomicBool::new(false);
        let err = read_frame(&mut b, &stop, Duration::from_secs(1)).unwrap_err();
        assert!(err.to_string().contains("outside"), "{err}");
    }

    #[test]
    fn idle_peer_times_out_cleanly() {
        let (_a, mut b) = pair();
        b.set_read_timeout(Some(Duration::from_millis(10))).unwrap();
        let stop = AtomicBool::new(false);
        match read_frame(&mut b, &stop, Duration::from_millis(30)).unwrap() {
            ReadOutcome::IdleTimeout => {}
            _ => panic!("expected idle timeout"),
        }
    }

    /// The stop-flag blind spot, fixed: a peer that has trickled *part* of
    /// a length prefix no longer holds teardown until the idle budget —
    /// stop wins at the next poll slice between frames.
    #[test]
    fn stop_wins_with_partial_header_bytes() {
        let (mut a, mut b) = pair();
        b.set_read_timeout(Some(Duration::from_millis(5))).unwrap();
        if let Stream::Uds(s) = &mut a {
            s.write_all(&[0x07, 0x00]).unwrap(); // half a length prefix
        }
        let stop = AtomicBool::new(true);
        let t0 = Instant::now();
        // idle budget is huge; only the stop flag can end this promptly
        match read_frame(&mut b, &stop, Duration::from_secs(3600)).unwrap() {
            ReadOutcome::Stopped => {}
            _ => panic!("expected Stopped"),
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "stop did not win promptly");
    }

    /// Single-byte-at-a-time writer vs the nonblocking cursor: the frame
    /// reassembles across arbitrarily torn reads, byte counts stay exact,
    /// and the body buffer comes from / returns to the pool.
    #[test]
    fn cursor_reassembles_partial_frames() {
        let (mut a, mut b) = pair();
        b.set_nonblocking(true).unwrap();
        let f = Frame::Update { node: 9, dx_wire: vec![1, 2, 3, 4, 5], du_wire: vec![6, 7] };
        let enc = f.encode();

        let mut pool = BufferPool::new();
        let mut cur = FrameCursor::new();
        let mut got = None;
        for (i, byte) in enc.iter().enumerate() {
            if let Stream::Uds(s) = &mut a {
                s.write_all(&[*byte]).unwrap();
            }
            match cur.step(&mut b, &mut pool).unwrap() {
                CursorStep::Frame(frame, bytes) => {
                    assert_eq!(i, enc.len() - 1, "frame completed early");
                    assert_eq!(bytes, enc.len() as u64);
                    got = Some(frame);
                }
                CursorStep::NeedMore => {
                    assert!(i < enc.len() - 1, "NeedMore after the last byte");
                    assert!(cur.mid_frame());
                }
                CursorStep::Eof => panic!("spurious eof"),
            }
        }
        assert_eq!(got.expect("frame never completed"), f);
        assert!(!cur.mid_frame());

        // second frame reuses the pooled body buffer; then a clean Eof
        let f2 = Frame::Skip { node: 1 };
        let wrote = a.write_frame(&f2).unwrap();
        match cur.step(&mut b, &mut pool).unwrap() {
            CursorStep::Frame(frame, bytes) => {
                assert_eq!(frame, f2);
                assert_eq!(bytes, wrote);
            }
            other => panic!("expected frame, got {other:?}"),
        }
        drop(a);
        assert!(matches!(cur.step(&mut b, &mut pool).unwrap(), CursorStep::Eof));
    }

    /// EOF mid-frame through the cursor is a torn frame, not an orderly
    /// close — and a lying length prefix is rejected before allocation.
    #[test]
    fn cursor_rejects_torn_and_oversized_frames() {
        let (mut a, mut b) = pair();
        b.set_nonblocking(true).unwrap();
        if let Stream::Uds(s) = &mut a {
            s.write_all(&[0x05, 0x00]).unwrap(); // half a header, then die
        }
        drop(a);
        let mut pool = BufferPool::new();
        let mut cur = FrameCursor::new();
        let err = cur.step(&mut b, &mut pool).unwrap_err();
        assert!(err.to_string().contains("mid-frame"), "{err}");

        let (mut a2, mut b2) = pair();
        b2.set_nonblocking(true).unwrap();
        if let Stream::Uds(s) = &mut a2 {
            s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        }
        let mut cur2 = FrameCursor::new();
        let err = cur2.step(&mut b2, &mut pool).unwrap_err();
        assert!(err.to_string().contains("outside"), "{err}");
    }

    /// The wake pipe interrupts a poll promptly and drains level-clean.
    #[test]
    fn wake_pipe_interrupts_poll() {
        let wp = WakePipe::new().unwrap();
        let waker = wp.waker();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            waker.wake();
        });
        let mut fds = [PollFd::new(wp.as_raw_fd(), POLLIN)];
        let t0 = Instant::now();
        let n = poll_fds(&mut fds, Duration::from_secs(10)).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].readable());
        assert!(t0.elapsed() < Duration::from_secs(5));
        wp.drain();
        // drained: an immediate poll now times out
        let mut fds = [PollFd::new(wp.as_raw_fd(), POLLIN)];
        assert_eq!(poll_fds(&mut fds, Duration::from_millis(1)).unwrap(), 0);
        t.join().unwrap();
    }

    #[test]
    fn connect_retry_gives_up_on_hard_failure_fast() {
        // nothing listens here and nothing will: NotFound is transient
        // (bind race) so it retries, but the attempt budget bounds it
        let ep = Endpoint::Uds(PathBuf::from("/tmp/qadmm-definitely-absent.sock"));
        let t0 = Instant::now();
        let err = Stream::connect_retry(&ep, 3, Duration::from_millis(1)).unwrap_err();
        assert!(err.to_string().contains("after 3 attempts"), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(5));
    }
}
