//! Figure 4 (MNIST CNN): test classification accuracy vs iterations and vs
//! communication bits, QADMM (q = 3, τ = 3, N = 3, inexact primal = 10 Adam
//! steps) against unquantized async ADMM.
//! Headline: ~91.02% fewer bits to reach 95% test accuracy.

use crate::admm::runner::{self, ProblemFactory};
use crate::compress::CompressorKind;
use crate::config::{presets, ProblemKind};
use crate::metrics::summary;
use crate::problems::nn::{NnArch, NnProblem};
use crate::problems::Problem;
use crate::runtime::artifacts::Manifest;
use crate::runtime::service::ComputeService;
use crate::util::rng::Pcg64;

use super::Series;

pub struct Fig4Options {
    pub arch: NnArch,
    pub iters: usize,
    pub mc_trials: usize,
    /// Training examples per run (paper: 60k; CPU default is smaller).
    pub n_train: usize,
    pub n_test: usize,
    pub out_dir: std::path::PathBuf,
    pub artifact_dir: std::path::PathBuf,
    pub data_dir: std::path::PathBuf,
    /// Test-accuracy target for the headline reduction number.
    pub target: f64,
}

impl Default for Fig4Options {
    fn default() -> Self {
        Self {
            arch: NnArch::Cnn,
            iters: presets::fig4().iters,
            mc_trials: presets::fig4().mc_trials,
            n_train: 3000,
            n_test: 1024,
            out_dir: "out".into(),
            artifact_dir: "artifacts".into(),
            data_dir: "data/mnist".into(),
            target: 0.95,
        }
    }
}

pub struct Fig4Summary {
    pub series: Vec<Series>,
    pub headline: Vec<String>,
}

pub fn run(opts: &Fig4Options) -> anyhow::Result<Fig4Summary> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let prefix = opts.arch.prefix();
    let service = ComputeService::start(
        opts.artifact_dir.clone(),
        vec![format!("{prefix}_local_update"), format!("{prefix}_eval")],
    )?;
    let manifest = Manifest::load(&opts.artifact_dir.join("manifest.json"))?;

    let mut series = Vec::new();
    let mut rows: Vec<crate::metrics::RunRecorder> = Vec::new();
    for compressor in [CompressorKind::Qsgd { bits: 3 }, CompressorKind::Identity32] {
        let mut cfg = presets::fig4();
        cfg.iters = opts.iters;
        cfg.mc_trials = opts.mc_trials;
        cfg.compressor = compressor;
        if opts.arch == NnArch::Mlp {
            let (n, rho, lr) = match cfg.problem {
                ProblemKind::Cnn { n, rho, lr } => (n, rho, lr),
                _ => unreachable!(),
            };
            cfg.problem = ProblemKind::Mlp { n: n.max(3), rho, lr };
        }
        let label = if compressor == CompressorKind::Identity32 {
            "baseline".to_string()
        } else {
            "qadmm".to_string()
        };
        let (n_nodes, rho, lr) = match cfg.problem {
            ProblemKind::Cnn { n, rho, lr } | ProblemKind::Mlp { n, rho, lr } => (n, rho, lr),
            _ => unreachable!(),
        };
        let arch = opts.arch;
        let svc = &service;
        let mfst = &manifest;
        let mut factory: Box<ProblemFactory> =
            Box::new(move |seed: u64, _data_rng: &mut Pcg64| {
                let p = NnProblem::new(
                    arch,
                    n_nodes,
                    rho,
                    lr,
                    Box::new(svc.client()),
                    mfst,
                    opts.n_train,
                    opts.n_test,
                    &opts.data_dir,
                    seed,
                )?;
                Ok(Box::new(p) as Box<dyn Problem>)
            });
        let result = runner::run_mc(&cfg, factory.as_mut())?;
        drop(factory);
        let s = Series { label: format!("{prefix}_{label}"), result };
        s.write_csv(&opts.out_dir, "fig4")?;
        rows.push(s.mean_recorder());
        series.push(s);
    }

    let q = summary::bits_to_test_acc(&rows[0].records, opts.target);
    let b = summary::bits_to_test_acc(&rows[1].records, opts.target);
    let headline = vec![summary::headline_row(
        &format!("Fig4 {} classifier", prefix.to_uppercase()),
        &format!("{:.0}% test accuracy", opts.target * 100.0),
        q,
        b,
    )];
    Ok(Fig4Summary { series, headline })
}
