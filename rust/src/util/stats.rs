//! Streaming and batch statistics used by metrics and the bench harness.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated quantile on a sorted copy; `q` in [0, 1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Elementwise mean across equally-long series (for MC-trial averaging).
pub fn mean_series(series: &[Vec<f64>]) -> Vec<f64> {
    assert!(!series.is_empty());
    let len = series[0].len();
    assert!(series.iter().all(|s| s.len() == len), "ragged series");
    let mut out = vec![0.0; len];
    for s in series {
        for (o, x) in out.iter_mut().zip(s) {
            *o += x;
        }
    }
    for o in &mut out {
        *o /= series.len() as f64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(o.min(), 1.0);
        assert_eq!(o.max(), 16.0);
        assert_eq!(o.count(), 5);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn mean_series_averages() {
        let s = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert_eq!(mean_series(&s), vec![2.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn mean_series_rejects_ragged() {
        mean_series(&[vec![1.0], vec![1.0, 2.0]]);
    }
}
