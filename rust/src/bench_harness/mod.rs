//! In-house micro/meso benchmark harness (criterion is not available in
//! the offline crate universe). Warmup + adaptive sampling, robust stats,
//! optional throughput units, and a one-line-per-bench report identical
//! across all `cargo bench` targets.

use crate::util::stats;
use crate::util::timer::{fmt_count, fmt_duration, Stopwatch};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
    pub p99_s: f64,
    /// items/sec if `items_per_call` was set.
    pub throughput: Option<f64>,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        let tp = match self.throughput {
            Some(t) => format!("  [{} items/s]", fmt_count(t)),
            None => String::new(),
        };
        format!(
            "{:44} median {:>10}  mean {:>10}  min {:>10}  p99 {:>10}  (n={}){}",
            self.name,
            fmt_duration(self.median_s),
            fmt_duration(self.mean_s),
            fmt_duration(self.min_s),
            fmt_duration(self.p99_s),
            self.samples,
            tp
        )
    }
}

pub struct Bencher {
    /// Target measurement time per bench (seconds).
    pub target_time: f64,
    /// Max samples per bench.
    pub max_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        // QADMM_BENCH_FAST=1 shrinks budgets for CI smoke runs.
        let fast = std::env::var("QADMM_BENCH_FAST").is_ok();
        Self {
            target_time: if fast { 0.2 } else { 1.0 },
            max_samples: if fast { 10 } else { 50 },
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Benchmark `f`; `items_per_call` (if nonzero) yields a throughput.
    pub fn bench<F: FnMut()>(&mut self, name: &str, items_per_call: usize, mut f: F) {
        // Warmup: run until ~10% of target time has elapsed (at least once).
        let warm = Stopwatch::new();
        loop {
            f();
            if warm.elapsed_secs() > self.target_time * 0.1 {
                break;
            }
        }
        // Calibrate inner batch so one sample takes ≥ ~200µs (timer noise).
        let t0 = Instant::now();
        f();
        let single = t0.elapsed().as_secs_f64().max(1e-9);
        let batch = (2e-4 / single).ceil().max(1.0) as usize;

        let mut samples = Vec::new();
        let total = Stopwatch::new();
        while samples.len() < self.max_samples && total.elapsed_secs() < self.target_time {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / batch as f64);
        }
        let mean_s = stats::mean(&samples);
        let result = BenchResult {
            name: name.to_string(),
            samples: samples.len(),
            mean_s,
            median_s: stats::median(&samples),
            min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            p99_s: stats::quantile(&samples, 0.99),
            throughput: (items_per_call > 0).then(|| items_per_call as f64 / mean_s),
        };
        println!("{}", result.report_line());
        self.results.push(result);
    }

    /// Benchmark with a value-producing closure (guards against DCE).
    pub fn bench_val<T, F: FnMut() -> T>(&mut self, name: &str, items_per_call: usize, mut f: F) {
        self.bench(name, items_per_call, || {
            std::hint::black_box(f());
        });
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn finish(self, suite: &str) {
        println!("--- {suite}: {} benches done ---", self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_sane_stats() {
        let mut b = Bencher { target_time: 0.05, max_samples: 8, results: vec![] };
        let mut acc = 0u64;
        b.bench_val("noop-ish", 100, || {
            acc = acc.wrapping_add(1);
            acc
        });
        let r = &b.results()[0];
        assert!(r.samples >= 1);
        assert!(r.min_s <= r.median_s && r.median_s <= r.p99_s + 1e-12);
        assert!(r.throughput.unwrap() > 0.0);
    }
}
