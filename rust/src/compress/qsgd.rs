//! The paper's compressor: stochastic multi-level quantization (eq. 17).
//!
//! With q bits per scalar, S = 2^(q−1) − 1 intervals on [0, 1]. Each
//! normalized magnitude |Δ_m|/‖Δ‖_max lands in [p/S, (p+1)/S] and rounds up
//! with probability equal to its fractional position (unbiased), then sign
//! and magnitude are restored. This file is the *bit-exact native twin* of
//! the Pallas kernel `python/compile/kernels/quantize.py` — an integration
//! test feeds both the same noise and asserts identical levels.

use super::wire::encode_qsgd;
use super::{sanitize, Compressed, Compressor};
use crate::util::rng::Pcg64;

/// ‖Δ‖_max over the *finite* coordinates only. A single ∞ used to make
/// `norm = inf`, collapsing every level to 0 and dequantizing the ∞
/// coordinate to `inf · 0 / S = NaN` — which `EstimateTracker::commit`
/// then folded into the estimate bank permanently (EF never recovers).
/// Non-finite coordinates are instead dropped from the frame (level 0,
/// dequantized +0.0); [`EstimateTracker::commit`] asserts the bank stays
/// finite. For all-finite input this is bitwise the old fold (`f64::max`
/// already ignored NaN; the guard only changes ±∞ handling).
///
/// [`EstimateTracker::commit`]: super::error_feedback::EstimateTracker::commit
fn finite_max_norm(delta: &[f64]) -> f64 {
    delta.iter().fold(0.0f64, |m, x| if x.is_finite() { m.max(x.abs()) } else { m })
}

#[derive(Clone, Copy, Debug)]
pub struct Qsgd {
    bits: u8,
}

impl Qsgd {
    pub fn new(bits: u8) -> Self {
        assert!((2..=16).contains(&bits), "qsgd bits must be in 2..=16 (got {bits})");
        Self { bits }
    }

    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// S = 2^(q−1) − 1.
    pub fn s(&self) -> i32 {
        (1i32 << (self.bits - 1)) - 1
    }

    /// Deterministic quantization given explicit noise ∈ [0,1)^M.
    /// Mirrors the Pallas kernel operation-for-operation:
    ///   y = |d| / norm * S;  p = min(⌊y⌋, S−1);  lvl = p + [noise < y−p].
    pub fn quantize_with_noise(&self, delta: &[f64], noise: &[f64]) -> (Vec<i32>, f64) {
        assert_eq!(delta.len(), noise.len());
        let s = self.s() as f64;
        let norm = finite_max_norm(delta);
        if norm == 0.0 {
            return (vec![0; delta.len()], 0.0);
        }
        let levels = delta
            .iter()
            .zip(noise)
            .map(|(&d, &n)| {
                let d = sanitize(d);
                let y = d.abs() / norm * s;
                let p = y.floor().min(s - 1.0);
                let frac = y - p;
                let lvl = p + if n < frac { 1.0 } else { 0.0 };
                let signed = if d < 0.0 { -lvl } else if d > 0.0 { lvl } else { 0.0 };
                signed as i32
            })
            .collect();
        (levels, norm)
    }

    /// Dequantize levels: value = norm · lvl / S (the wire-side inverse).
    pub fn dequantize(&self, levels: &[i32], norm: f64) -> Vec<f64> {
        let s = self.s() as f64;
        levels.iter().map(|&l| norm * l as f64 / s).collect()
    }

    /// Build a [`Compressed`] from levels produced elsewhere (e.g. by the
    /// HLO artifact, which runs the same kernel) — packs the wire frame;
    /// both ends dequantize from the wire representation, so sender and
    /// receiver stay bit-identical by construction.
    pub fn from_levels(&self, levels: &[i32], norm: f64) -> Compressed {
        Compressed { wire: encode_qsgd(levels, norm, self.bits) }
    }
}

impl Qsgd {
    /// Reference (two-pass, allocation-heavy) compress path. Kept as the
    /// correctness oracle for the fused hot path below; draws the same RNG
    /// stream, so `compress == compress_reference` bit-for-bit.
    pub fn compress_reference(&self, delta: &[f64], rng: &mut Pcg64) -> Compressed {
        let noise: Vec<f64> = (0..delta.len()).map(|_| rng.uniform_f64()).collect();
        let (levels, norm) = self.quantize_with_noise(delta, &noise);
        self.from_levels(&levels, norm)
    }
}

impl Compressor for Qsgd {
    fn name(&self) -> String {
        format!("qsgd{}", self.bits)
    }

    /// Hot path (§Perf): one pass with inline RNG produces the signed
    /// levels (no separate noise vector, no second quantize pass), then
    /// the chunked bit packer emits the payload. Bit-identical to
    /// [`Self::compress_reference`] — the operation order
    /// (|d| / norm * s) matches quantize_with_noise and the Pallas kernel
    /// exactly; dequantization happens only at the consumers, off the wire.
    fn compress(&self, delta: &[f64], rng: &mut Pcg64) -> Compressed {
        let mut out = Compressed::empty();
        self.compress_into(delta, rng, &mut out);
        out
    }

    /// In-place variant of the fused hot path: writes into `out`'s pooled
    /// wire buffer (cleared, capacity reused) so the engine's dispatch loop
    /// performs no steady-state allocation per message. Bit-identical to
    /// [`Self::compress`].
    fn compress_into(&self, delta: &[f64], rng: &mut Pcg64, out: &mut Compressed) {
        let m = delta.len();
        let s = self.s() as f64;
        let norm = finite_max_norm(delta);

        // frame header (layout of wire::encode_qsgd): tag, m, q, norm
        let payload_len = super::packing::packed_len(m, self.bits);
        let wire = &mut out.wire;
        wire.clear();
        wire.reserve(14 + payload_len);
        super::wire::frame_header_into(wire, super::wire::TAG_QSGD, m);
        wire.push(self.bits);
        wire.extend_from_slice(&norm.to_le_bytes());

        if norm == 0.0 {
            // zero vector: burn the RNG draws so the stream position matches
            // the reference path, and emit an all-zero payload
            for _ in 0..m {
                rng.uniform_f64();
            }
            wire.resize(14 + payload_len, 0);
            return;
        }

        let header = wire.len();
        wire.resize(header + payload_len, 0);
        let payload = &mut wire[header..];
        let q = self.bits as u32;
        let mut acc: u64 = 0;
        let mut nbits: u32 = 0;
        let mut byte_pos = 0usize;
        for i in 0..m {
            let d = sanitize(delta[i]);
            let y = d.abs() / norm * s;
            let p = y.floor().min(s - 1.0);
            let frac = y - p;
            let lvl = p + (rng.uniform_f64() < frac) as u64 as f64;
            // Zero levels must carry a +0 sign bit regardless of the input's
            // sign: `lvl.copysign(d)` would mark −0.0 inputs negative,
            // diverging bitwise from compress_reference (whose sign branch
            // tests `d < 0.0`, false for −0.0) and from the Pallas kernel —
            // breaking the documented bit-exact twin claim.
            let signed = if lvl == 0.0 { 0.0 } else { lvl.copysign(d) };
            // sign-magnitude field, identical to packing::pack_levels
            let field = (signed.is_sign_negative() && lvl > 0.0) as u64 | ((lvl as u64) << 1);
            acc |= field << nbits;
            nbits += q;
            while nbits >= 8 {
                payload[byte_pos] = acc as u8;
                byte_pos += 1;
                acc >>= 8;
                nbits -= 8;
            }
        }
        if nbits > 0 {
            payload[byte_pos] = acc as u8;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn s_levels() {
        assert_eq!(Qsgd::new(2).s(), 1);
        assert_eq!(Qsgd::new(3).s(), 3);
        assert_eq!(Qsgd::new(4).s(), 7);
        assert_eq!(Qsgd::new(8).s(), 127);
    }

    #[test]
    fn max_element_is_exact() {
        let q = Qsgd::new(3);
        let delta = [0.1, -3.0, 0.5];
        let noise = [0.999, 0.999, 0.999];
        let (levels, norm) = q.quantize_with_noise(&delta, &noise);
        assert_eq!(norm, 3.0);
        assert_eq!(levels[1], -3);
        assert_eq!(q.dequantize(&levels, norm)[1], -3.0);
    }

    #[test]
    fn zero_vector() {
        let q = Qsgd::new(3);
        let (levels, norm) = q.quantize_with_noise(&[0.0; 10], &[0.5; 10]);
        assert_eq!(norm, 0.0);
        assert!(levels.iter().all(|&l| l == 0));
    }

    #[test]
    fn error_bounded_by_one_interval() {
        let q = Qsgd::new(4);
        let mut rng = Pcg64::seed_from_u64(1);
        let delta = rng.normal_vec(500, 0.0, 3.0);
        let c = q.compress(&delta, &mut rng);
        let norm = delta.iter().fold(0.0f64, |m, x| m.max(x.abs()));
        let bound = norm / q.s() as f64;
        for (d, v) in delta.iter().zip(&c.dequantized().unwrap()) {
            assert!((d - v).abs() <= bound + 1e-12);
        }
    }

    #[test]
    fn unbiased_over_noise() {
        let q = Qsgd::new(3);
        let mut rng = Pcg64::seed_from_u64(2);
        let delta = rng.normal_vec(64, 0.0, 1.0);
        let trials = 4000;
        let mut acc = vec![0.0; 64];
        for _ in 0..trials {
            let c = q.compress(&delta, &mut rng);
            for (a, v) in acc.iter_mut().zip(&c.dequantized().unwrap()) {
                *a += v;
            }
        }
        let norm = delta.iter().fold(0.0f64, |m, x| m.max(x.abs()));
        let tol = 6.0 * (norm / (2.0 * q.s() as f64)) / (trials as f64).sqrt();
        for (a, d) in acc.iter().zip(&delta) {
            assert!((a / trials as f64 - d).abs() < tol);
        }
    }

    #[test]
    fn wire_is_q_bits_per_scalar_plus_header() {
        let q = Qsgd::new(3);
        let mut rng = Pcg64::seed_from_u64(3);
        let delta = rng.normal_vec(1000, 0.0, 1.0);
        let c = q.compress(&delta, &mut rng);
        // 14-byte header + ceil(1000·3/8)
        assert_eq!(c.wire.len(), 14 + 375);
        let decoded = q.decode(&c.wire, 1000).unwrap();
        assert_eq!(decoded, c.dequantized().unwrap());
    }

    #[test]
    fn levels_within_pack_range() {
        let q = Qsgd::new(2); // S = 1: the coarsest valid quantizer
        let mut rng = Pcg64::seed_from_u64(4);
        let delta = rng.normal_vec(333, 0.0, 1.0);
        let c = q.compress(&delta, &mut rng);
        let dq = c.dequantized().unwrap();
        assert!(dq.iter().all(|v| v.is_finite()));
        let decoded = q.decode(&c.wire, 333).unwrap();
        assert_eq!(decoded, dq);
    }

    #[test]
    fn fused_compress_equals_reference_bitwise() {
        let mut rng = Pcg64::seed_from_u64(13);
        for q in [2u8, 3, 5, 8, 12] {
            let c = Qsgd::new(q);
            for m in [1usize, 7, 256, 1000] {
                let delta = rng.normal_vec(m, 0.0, 2.0);
                let a = c.compress(&delta, &mut Pcg64::seed_from_u64(99));
                let b = c.compress_reference(&delta, &mut Pcg64::seed_from_u64(99));
                assert_eq!(a.wire, b.wire, "q={q} m={m}");
                // zero vector too (RNG stream position must also match)
                let mut r1 = Pcg64::seed_from_u64(5);
                let mut r2 = Pcg64::seed_from_u64(5);
                let z = vec![0.0; m];
                assert_eq!(c.compress(&z, &mut r1).wire, c.compress_reference(&z, &mut r2).wire);
                assert_eq!(r1.next_u64(), r2.next_u64());
            }
        }
    }

    /// Regression: a −0.0 input must produce +0.0 dequantized output on
    /// the fused path, bit-identical to the reference path and the wire.
    #[test]
    fn negative_zero_input_is_bitwise_identical_to_reference() {
        for q in [2u8, 3, 8] {
            let c = Qsgd::new(q);
            let delta = [1.5, -0.0, 0.0, -2.0, -0.0];
            let a = c.compress(&delta, &mut Pcg64::seed_from_u64(17));
            let b = c.compress_reference(&delta, &mut Pcg64::seed_from_u64(17));
            assert_eq!(a.wire, b.wire, "q={q}");
            // the −0.0 inputs dequantize to +0.0 exactly
            let dq = a.dequantized().unwrap();
            assert_eq!(dq[1].to_bits(), 0.0f64.to_bits());
            assert_eq!(dq[4].to_bits(), 0.0f64.to_bits());
        }
    }

    /// Regression: an ∞ coordinate used to make norm = inf, collapse every
    /// level to 0, and dequantize the ∞ itself to NaN (`inf · 0 / S`) —
    /// poisoning the estimate bank at commit. Non-finite coordinates are
    /// dropped (level 0, +0.0), the finite ones quantize against the finite
    /// norm, and fused stays bitwise-equal to reference.
    #[test]
    fn non_finite_coordinates_are_dropped_not_poisonous() {
        for q in [2u8, 3, 8] {
            let c = Qsgd::new(q);
            let delta =
                [f64::INFINITY, 1.5, f64::NAN, -2.0, f64::NEG_INFINITY, 0.25];
            let a = c.compress(&delta, &mut Pcg64::seed_from_u64(23));
            let b = c.compress_reference(&delta, &mut Pcg64::seed_from_u64(23));
            assert_eq!(a.wire, b.wire, "q={q}");
            let dq = a.dequantized().unwrap();
            assert!(dq.iter().all(|v| v.is_finite()), "q={q}");
            // finite norm: the largest finite magnitude, so the -2.0 slot
            // stays exact at max-noise and the non-finite slots carry 0
            assert_eq!(dq[0], 0.0);
            assert_eq!(dq[2], 0.0);
            assert_eq!(dq[4], 0.0);
            // all-non-finite vector behaves like the zero vector, with the
            // RNG stream position still aligned across the two paths
            let bad = [f64::NAN, f64::INFINITY];
            let mut r1 = Pcg64::seed_from_u64(3);
            let mut r2 = Pcg64::seed_from_u64(3);
            let x = c.compress(&bad, &mut r1);
            let y = c.compress_reference(&bad, &mut r2);
            assert_eq!(x.wire, y.wire);
            assert!(x.dequantized().unwrap().iter().all(|&v| v == 0.0));
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
    }

    #[test]
    fn deterministic_given_rng_seed() {
        let q = Qsgd::new(3);
        let delta: Vec<f64> = (0..100).map(|i| (i as f64 - 50.0) * 0.1).collect();
        let a = q.compress(&delta, &mut Pcg64::seed_from_u64(7));
        let b = q.compress(&delta, &mut Pcg64::seed_from_u64(7));
        assert_eq!(a.wire, b.wire);
    }
}
