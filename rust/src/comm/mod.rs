//! Simulated star-topology network: messages, per-link bit accounting
//! (the paper's communication metric, eq. 20), per-link latency
//! decomposition (compute/uplink/downlink + clock drift) shared by the
//! event engine and the threaded runtime, and failure injection
//! (duplicates / stragglers).

pub mod accounting;
pub mod latency;
pub mod message;
pub mod network;
pub mod profile;
