//! Socket transport for the deployment: one abstraction over TCP and
//! Unix-domain sockets (std-only — no async runtime; the server is
//! thread-per-connection, which is the right shape for hundreds of
//! workers, not millions of sockets), plus the framed read path with the
//! interruptible/idle semantics the server's liveness story needs:
//!
//! - reads poll in short slices so a reader thread notices the stop flag
//!   promptly instead of blocking forever on a silent peer;
//! - a peer that goes quiet for longer than the idle timeout is reported
//!   as [`ReadOutcome::IdleTimeout`] — the half-open-connection case TCP
//!   keepalives are too slow for — so the server can evict it and the
//!   P/τ trigger never wedges on a dead worker;
//! - a clean EOF **between** frames is [`ReadOutcome::Eof`] (orderly
//!   close); an EOF or garbage **inside** a frame is an `Err`.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::frame::{Frame, MAX_FRAME_BYTES};

/// How long one blocking read slice lasts before the loop re-checks the
/// stop flag and the idle budget.
const POLL_SLICE: Duration = Duration::from_millis(100);

/// A deployment endpoint address: `tcp:HOST:PORT` or `uds:/path/to.sock`
/// (a bare path containing `/` is accepted as UDS for convenience).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Endpoint {
    Tcp(String),
    Uds(PathBuf),
}

impl Endpoint {
    pub fn parse(s: &str) -> Result<Endpoint> {
        if let Some(addr) = s.strip_prefix("tcp:") {
            ensure!(addr.contains(':'), "tcp endpoint needs HOST:PORT, got '{addr}'");
            Ok(Endpoint::Tcp(addr.to_string()))
        } else if let Some(path) = s.strip_prefix("uds:") {
            Ok(Endpoint::Uds(PathBuf::from(path)))
        } else if s.contains('/') {
            Ok(Endpoint::Uds(PathBuf::from(s)))
        } else {
            bail!("endpoint '{s}' is neither tcp:HOST:PORT nor uds:/path")
        }
    }

    pub fn label(&self) -> String {
        match self {
            Endpoint::Tcp(a) => format!("tcp:{a}"),
            Endpoint::Uds(p) => format!("uds:{}", p.display()),
        }
    }
}

/// A connected stream over either transport. Cloning duplicates the OS
/// handle (reader thread + writer pump can own halves independently).
pub enum Stream {
    Tcp(TcpStream),
    Uds(UnixStream),
}

impl Stream {
    pub fn connect(ep: &Endpoint) -> Result<Stream> {
        Ok(match ep {
            Endpoint::Tcp(addr) => {
                Stream::Tcp(TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?)
            }
            Endpoint::Uds(path) => Stream::Uds(
                UnixStream::connect(path)
                    .with_context(|| format!("connect {}", path.display()))?,
            ),
        })
    }

    pub fn try_clone(&self) -> Result<Stream> {
        Ok(match self {
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
            Stream::Uds(s) => Stream::Uds(s.try_clone()?),
        })
    }

    pub fn set_read_timeout(&self, t: Option<Duration>) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(t)?,
            Stream::Uds(s) => s.set_read_timeout(t)?,
        }
        Ok(())
    }

    /// Disable Nagle on TCP (frames are latency-sensitive and small); a
    /// no-op on UDS.
    pub fn tune(&self) {
        if let Stream::Tcp(s) = self {
            let _ = s.set_nodelay(true);
        }
    }

    /// Best-effort full shutdown, unblocking any thread mid-read.
    pub fn shutdown(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            Stream::Uds(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }

    fn read_impl(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Uds(s) => s.read(buf),
        }
    }

    /// Write one encoded frame and flush; returns the bytes put on the
    /// socket (the pump's byte-counter input).
    pub fn write_frame(&mut self, frame: &Frame) -> Result<u64> {
        let bytes = frame.encode();
        match self {
            Stream::Tcp(s) => {
                s.write_all(&bytes)?;
                s.flush()?;
            }
            Stream::Uds(s) => {
                s.write_all(&bytes)?;
                s.flush()?;
            }
        }
        Ok(bytes.len() as u64)
    }
}

/// What one framed-read attempt produced.
pub enum ReadOutcome {
    /// A complete, decoded frame plus its total socket footprint in bytes
    /// (length prefix included) — the reader's byte-counter input.
    Frame(Frame, u64),
    /// Orderly close: EOF on a frame boundary.
    Eof,
    /// The peer went silent past the idle budget (half-open connection).
    IdleTimeout,
    /// The stop flag was raised mid-wait; nothing was consumed mid-frame.
    Stopped,
}

/// Read exactly `buf.len()` bytes, polling in [`POLL_SLICE`] slices.
/// `started` is Some once part of a frame has been consumed — then EOF and
/// stop both become hard errors (a frame must never be torn). Returns
/// `Ok(None)` for eof-at-boundary / stop / idle, distinguished by the
/// caller from how much was read.
fn read_full(
    s: &mut Stream,
    buf: &mut [u8],
    stop: &AtomicBool,
    idle: Duration,
    mid_frame: bool,
) -> Result<Option<ReadOutcome>> {
    let mut got = 0usize;
    let mut quiet_since = Instant::now();
    while got < buf.len() {
        match s.read_impl(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 && !mid_frame {
                    return Ok(Some(ReadOutcome::Eof));
                }
                bail!("connection closed mid-frame ({got} of {} bytes)", buf.len());
            }
            Ok(n) => {
                got += n;
                quiet_since = Instant::now();
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) && got == 0 && !mid_frame {
                    return Ok(Some(ReadOutcome::Stopped));
                }
                if quiet_since.elapsed() >= idle {
                    if got == 0 && !mid_frame {
                        return Ok(Some(ReadOutcome::IdleTimeout));
                    }
                    bail!("peer idle mid-frame ({got} of {} bytes)", buf.len());
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(None)
}

/// Read one `[u32 len][u8 kind][body]` frame. The length prefix is
/// validated against [`MAX_FRAME_BYTES`] before the body buffer is sized —
/// a garbage prefix costs at most 4 bytes of reading, never an allocation.
/// The stream must have a read timeout set (≤ [`POLL_SLICE`] granularity
/// is applied by the caller via `set_read_timeout`).
pub fn read_frame(s: &mut Stream, stop: &AtomicBool, idle: Duration) -> Result<ReadOutcome> {
    let mut len_buf = [0u8; 4];
    if let Some(out) = read_full(s, &mut len_buf, stop, idle, false)? {
        return Ok(out);
    }
    let len = u32::from_le_bytes(len_buf);
    ensure!(
        (1..=MAX_FRAME_BYTES).contains(&len),
        "frame length {len} outside (0, {MAX_FRAME_BYTES}]"
    );
    let mut body = vec![0u8; len as usize];
    if read_full(s, &mut body, stop, idle, true)?.is_some() {
        unreachable!("mid-frame reads error instead of yielding an outcome");
    }
    let frame = Frame::decode(body[0], &body[1..])?;
    Ok(ReadOutcome::Frame(frame, 4 + len as u64))
}

/// Blocking frame read for the worker side: no stop flag, a generous idle
/// budget (the server may legitimately be quiet while other nodes hold up
/// a round).
pub fn read_frame_blocking(s: &mut Stream, idle: Duration) -> Result<ReadOutcome> {
    static NEVER: AtomicBool = AtomicBool::new(false);
    read_frame(s, &NEVER, idle)
}

/// A bound listener over either transport, in non-blocking accept mode so
/// the acceptor thread can poll a stop flag.
pub enum Listener {
    Tcp(TcpListener),
    Uds(UnixListener, PathBuf),
}

impl Listener {
    /// Bind and report the *resolved* endpoint (TCP port 0 resolves to the
    /// kernel-assigned port — what the loadgen/smoke connect back to).
    pub fn bind(ep: &Endpoint) -> Result<(Listener, Endpoint)> {
        match ep {
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
                let local = l.local_addr()?;
                l.set_nonblocking(true)?;
                Ok((Listener::Tcp(l), Endpoint::Tcp(local.to_string())))
            }
            Endpoint::Uds(path) => {
                // a stale socket file from a crashed server blocks rebinding
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .with_context(|| format!("bind {}", path.display()))?;
                l.set_nonblocking(true)?;
                Ok((Listener::Uds(l, path.clone()), Endpoint::Uds(path.clone())))
            }
        }
    }

    /// Non-blocking accept: `Ok(None)` when nothing is pending.
    pub fn accept(&self) -> Result<Option<Stream>> {
        let res = match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            Listener::Uds(l, _) => l.accept().map(|(s, _)| Stream::Uds(s)),
        };
        match res {
            Ok(s) => {
                s.tune();
                // per-connection reads poll in short slices
                s.set_read_timeout(Some(POLL_SLICE))?;
                Ok(Some(s))
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e.into()),
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Uds(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair() -> (Stream, Stream) {
        let (a, b) = UnixStream::pair().unwrap();
        (Stream::Uds(a), Stream::Uds(b))
    }

    #[test]
    fn endpoint_parsing() {
        assert_eq!(
            Endpoint::parse("tcp:127.0.0.1:4700").unwrap(),
            Endpoint::Tcp("127.0.0.1:4700".into())
        );
        assert_eq!(
            Endpoint::parse("uds:/tmp/q.sock").unwrap(),
            Endpoint::Uds(PathBuf::from("/tmp/q.sock"))
        );
        assert_eq!(
            Endpoint::parse("/tmp/q.sock").unwrap(),
            Endpoint::Uds(PathBuf::from("/tmp/q.sock"))
        );
        assert!(Endpoint::parse("tcp:noport").is_err());
        assert!(Endpoint::parse("gibberish").is_err());
    }

    /// One frame over a real UDS pair: written bytes == read bytes ==
    /// encoded length, and the frame survives intact.
    #[test]
    fn frame_roundtrip_over_uds() {
        let (mut a, mut b) = pair();
        b.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let f = Frame::Update { node: 3, dx_wire: vec![1, 2, 3, 4], du_wire: vec![5, 6] };
        let wrote = a.write_frame(&f).unwrap();
        let stop = AtomicBool::new(false);
        match read_frame(&mut b, &stop, Duration::from_secs(1)).unwrap() {
            ReadOutcome::Frame(got, bytes) => {
                assert_eq!(got, f);
                assert_eq!(bytes, wrote);
            }
            _ => panic!("expected a frame"),
        }
        // orderly close → Eof at the boundary
        drop(a);
        match read_frame(&mut b, &stop, Duration::from_secs(1)).unwrap() {
            ReadOutcome::Eof => {}
            _ => panic!("expected eof"),
        }
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        let (mut a, mut b) = pair();
        b.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        if let Stream::Uds(s) = &mut a {
            s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        }
        let stop = AtomicBool::new(false);
        let err = read_frame(&mut b, &stop, Duration::from_secs(1)).unwrap_err();
        assert!(err.to_string().contains("outside"), "{err}");
    }

    #[test]
    fn idle_peer_times_out_cleanly() {
        let (_a, mut b) = pair();
        b.set_read_timeout(Some(Duration::from_millis(10))).unwrap();
        let stop = AtomicBool::new(false);
        match read_frame(&mut b, &stop, Duration::from_millis(30)).unwrap() {
            ReadOutcome::IdleTimeout => {}
            _ => panic!("expected idle timeout"),
        }
    }
}
