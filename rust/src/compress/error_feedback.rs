//! Error feedback (§4.1): both endpoints of a link track the *estimate*
//! ŷ of the iterate y, and the sender transmits C(y_new − ŷ), which equals
//! (current change) + (previous compression error) — the telescoping form
//! of eqs. (10)–(11) that cancels accumulated error.
//!
//! The EF-off ablation transmits C(y_new − y_old) instead (pure delta
//! coding), demonstrating the §4.1 error-accumulation argument.

use crate::snapshot::codec::{Pack, Reader, Writer};

/// One endpoint's view of a compressed stream: the shared estimate ŷ plus
/// (for the EF-off ablation only) the last true iterate. With feedback on —
/// the paper's configuration — the delta base *is* the estimate, so no
/// second vector is stored: at engine scale (1000+ nodes × 10k+ dims ×
/// 4 banks) this halves the tracker memory.
#[derive(Clone, Debug)]
pub struct EstimateTracker {
    estimate: Vec<f64>,
    /// Present iff `feedback` is off (pure delta coding needs y_old).
    last_true: Option<Vec<f64>>,
    feedback: bool,
}

impl EstimateTracker {
    pub fn new(initial: Vec<f64>, feedback: bool) -> Self {
        let last_true = (!feedback).then(|| initial.clone());
        Self { estimate: initial, last_true, feedback }
    }

    /// The Δ the sender should compress for the new iterate (and remember
    /// the iterate for the EF-off mode).
    pub fn make_delta(&mut self, current: &[f64]) -> Vec<f64> {
        let mut out = Vec::with_capacity(current.len());
        self.make_delta_into(current, &mut out);
        out
    }

    /// [`Self::make_delta`] into a caller-owned buffer (cleared, then
    /// filled) — the engine hot path reuses one scratch vector per round so
    /// delta construction does no steady-state allocation.
    pub fn make_delta_into(&mut self, current: &[f64], out: &mut Vec<f64>) {
        self.peek_delta_into(current, out);
        self.note_sent(current);
    }

    /// The Δ [`Self::make_delta`] would transmit, **without** committing to
    /// the transmission: no state is touched, so an event-triggered sender
    /// can inspect ‖Δ‖∞ against its dead-band and skip the dispatch. A
    /// skipped dispatch must leave the EF-off `last_true` base untouched
    /// (the delta keeps accumulating against the last value the receiver
    /// actually saw); the legacy `make_delta` path is peek + note_sent.
    pub fn peek_delta_into(&self, current: &[f64], out: &mut Vec<f64>) {
        // The zip below would silently truncate on a length mismatch,
        // shipping a short frame that desynchronizes the two banks forever.
        assert_eq!(
            current.len(),
            self.estimate.len(),
            "delta base length mismatch: iterate has {} coords, tracker {}",
            current.len(),
            self.estimate.len()
        );
        out.clear();
        let base: &[f64] = match &self.last_true {
            Some(lt) if !self.feedback => lt,
            _ => &self.estimate,
        };
        out.extend(current.iter().zip(base).map(|(c, b)| c - b));
    }

    /// Record that `current` was actually transmitted (the EF-off mode's
    /// delta base is the last *sent* iterate). Paired with
    /// [`Self::peek_delta_into`]; call only on a realized transmission.
    pub fn note_sent(&mut self, current: &[f64]) {
        if let Some(lt) = &mut self.last_true {
            assert_eq!(lt.len(), current.len(), "note_sent length mismatch");
            lt.copy_from_slice(current);
        }
    }

    /// Apply a dequantized message to the estimate: ŷ += C(Δ).
    /// Called symmetrically at sender (mirror) and receiver.
    pub fn commit(&mut self, dequantized: &[f64]) {
        assert_eq!(
            dequantized.len(),
            self.estimate.len(),
            "commit length mismatch: message has {} coords, tracker {}",
            dequantized.len(),
            self.estimate.len()
        );
        let mut finite = true;
        for (e, d) in self.estimate.iter_mut().zip(dequantized) {
            finite &= d.is_finite();
            *e += d;
        }
        // Fail loudly at the corruption boundary: folding a NaN/±∞ into
        // the bank is permanent (EF telescopes the error, it never washes
        // out). Every in-tree compressor sanitizes its output, so this
        // firing means a decoded frame or a custom compressor broke the
        // totality contract.
        assert!(
            finite,
            "non-finite dequantized delta would poison the estimate bank permanently"
        );
    }

    /// [`Self::commit`] straight from the wire frame: ŷ += C(Δ) without
    /// materializing the dense vector — sparse frames touch only their k
    /// stored entries. The coordinates a sparse frame omits dequantize to
    /// exactly 0.0, and `e += 0.0` is the identity for every finite e
    /// except that it flips −0.0 to +0.0 — a sign nobody reads and that
    /// every runtime now (not) flips identically, so the cross-engine
    /// parity contract is unaffected. The finiteness guard matches
    /// [`Self::commit`]: a decoded frame carrying NaN/±∞ aborts loudly.
    pub fn commit_frame(&mut self, c: &super::Compressed) -> anyhow::Result<()> {
        let m = c.frame_dim()?;
        assert_eq!(
            m,
            self.estimate.len(),
            "commit length mismatch: message has {} coords, tracker {}",
            m,
            self.estimate.len()
        );
        let mut finite = true;
        let est = &mut self.estimate;
        c.for_each_entry(|j, v| {
            finite &= v.is_finite();
            est[j] += v;
        })?;
        assert!(
            finite,
            "non-finite dequantized delta would poison the estimate bank permanently"
        );
        Ok(())
    }

    pub fn estimate(&self) -> &[f64] {
        &self.estimate
    }

    /// Force the estimate (used for the full-precision initial exchange,
    /// Algorithm 1 lines 1–8).
    pub fn reset(&mut self, value: &[f64]) {
        self.estimate.copy_from_slice(value);
        if let Some(lt) = &mut self.last_true {
            lt.copy_from_slice(value);
        }
    }

    pub fn feedback_enabled(&self) -> bool {
        self.feedback
    }
}

impl Pack for EstimateTracker {
    fn pack(&self, w: &mut Writer) {
        self.estimate.pack(w);
        self.last_true.pack(w);
        w.put_bool(self.feedback);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let estimate = Vec::<f64>::unpack(r)?;
        let last_true = Option::<Vec<f64>>::unpack(r)?;
        let feedback = r.get_bool()?;
        anyhow::ensure!(
            last_true.is_some() == !feedback,
            "snapshot tracker: last_true presence must match EF-off mode"
        );
        if let Some(lt) = &last_true {
            anyhow::ensure!(
                lt.len() == estimate.len(),
                "snapshot tracker: last_true/estimate length mismatch"
            );
        }
        Ok(Self { estimate, last_true, feedback })
    }
}

/// (x̂ᵢ, ûᵢ) estimate-slice pairs of two parallel tracker banks — the
/// consensus-refresh source shared by every runtime's star fan-in (the
/// hierarchical topologies refresh from their aggregator partials
/// instead; see `crate::topology`).
pub fn estimate_rows<'a>(
    xhat: &'a [EstimateTracker],
    uhat: &'a [EstimateTracker],
) -> impl Iterator<Item = (&'a [f64], &'a [f64])> {
    xhat.iter().zip(uhat).map(|(x, u)| (x.estimate(), u.estimate()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::qsgd::Qsgd;
    use crate::compress::Compressor;
    use crate::util::rng::Pcg64;

    /// With EF, the estimate error stays bounded by one quantization step of
    /// the *current* delta (the telescoping identity ŷ = y + δ^(r)); without
    /// EF it accumulates as Σδ^(t).
    #[test]
    fn feedback_bounds_estimate_error() {
        let m = 128;
        let q = Qsgd::new(3);
        let mut rng = Pcg64::seed_from_u64(1);
        let mut y = vec![0.0; m];
        let mut ef = EstimateTracker::new(y.clone(), true);
        let mut no_ef = EstimateTracker::new(y.clone(), false);

        let mut final_err_ef = 0.0f64;
        let mut final_err_no_ef = 0.0f64;
        for r in 0..200 {
            // a drifting iterate with decaying steps
            let g = rng.normal_vec(m, 0.0, 1.0 / (1.0 + r as f64 * 0.1));
            for (yi, gi) in y.iter_mut().zip(&g) {
                *yi += gi;
            }
            let d1 = ef.make_delta(&y);
            let c1 = q.compress(&d1, &mut rng);
            ef.commit_frame(&c1).unwrap();
            let d2 = no_ef.make_delta(&y);
            let c2 = q.compress(&d2, &mut rng);
            no_ef.commit_frame(&c2).unwrap();

            let err_ef = y
                .iter()
                .zip(ef.estimate())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            // EF error ≤ one interval of the *last transmitted* delta
            let dnorm = d1.iter().fold(0.0f64, |mx, v| mx.max(v.abs()));
            assert!(err_ef <= dnorm / q.s() as f64 + 1e-9, "r={r} err={err_ef}");
            final_err_ef = err_ef;
            final_err_no_ef = no_ef
                .estimate()
                .iter()
                .zip(&y)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
        }
        assert!(
            final_err_no_ef > 3.0 * final_err_ef,
            "EF should dominate: no_ef={final_err_no_ef} ef={final_err_ef}"
        );
    }

    #[test]
    fn identical_streams_stay_in_sync() {
        // sender mirror and receiver commit the same dequantized messages ⇒
        // identical estimates (the invariant the coordinator relies on).
        let m = 64;
        let q = Qsgd::new(4);
        let mut rng = Pcg64::seed_from_u64(2);
        let mut y = rng.normal_vec(m, 0.0, 1.0);
        let mut sender = EstimateTracker::new(vec![0.0; m], true);
        let mut receiver = EstimateTracker::new(vec![0.0; m], true);
        for _ in 0..50 {
            for v in &mut y {
                *v += 0.1 * rng.standard_normal();
            }
            let delta = sender.make_delta(&y);
            let c = q.compress(&delta, &mut rng);
            let decoded = q.decode(&c.wire, m).unwrap();
            sender.commit_frame(&c).unwrap();
            receiver.commit(&decoded);
            assert_eq!(sender.estimate(), receiver.estimate());
        }
    }

    /// The fused frame commit agrees bitwise with the dense commit for a
    /// sparse frame on a bank with no −0.0 coordinates (the only value
    /// where `e += 0.0` is not the bitwise identity).
    #[test]
    fn commit_frame_matches_dense_commit_bitwise() {
        use crate::compress::topk::TopK;
        let m = 200;
        let mut rng = Pcg64::seed_from_u64(11);
        let base = rng.normal_vec(m, 1.0, 0.5);
        let delta = rng.normal_vec(m, 0.0, 1.0);
        let c = TopK::new(0.05).compress(&delta, &mut rng);
        let mut fused = EstimateTracker::new(base.clone(), true);
        let mut dense = EstimateTracker::new(base, true);
        fused.commit_frame(&c).unwrap();
        dense.commit(&c.dequantized().unwrap());
        let bits = |t: &EstimateTracker| {
            t.estimate().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        };
        assert_eq!(bits(&fused), bits(&dense));
    }

    /// peek must be pure: with EF off, only note_sent (a realized
    /// transmission) may move the delta base — a skipped dispatch keeps
    /// accumulating against the last value the receiver actually saw.
    #[test]
    fn peek_is_pure_and_skips_accumulate() {
        let mut t = EstimateTracker::new(vec![0.0; 2], false);
        let mut d = Vec::new();
        t.peek_delta_into(&[1.0, 2.0], &mut d);
        assert_eq!(d, vec![1.0, 2.0]);
        // peek again — base unchanged, same delta (a skip happened)
        t.peek_delta_into(&[1.5, 2.0], &mut d);
        assert_eq!(d, vec![1.5, 2.0]);
        // realized transmission moves the base
        t.note_sent(&[1.5, 2.0]);
        t.peek_delta_into(&[2.0, 2.0], &mut d);
        assert_eq!(d, vec![0.5, 0.0]);
        // make_delta == peek + note_sent
        let d2 = t.make_delta(&[3.0, 3.0]);
        assert_eq!(d2, vec![1.0, 1.0]);
        t.peek_delta_into(&[3.0, 3.0], &mut d);
        assert_eq!(d, vec![0.0, 0.0]);
    }

    /// Regression: `current.iter().zip(base)` silently dropped the excess
    /// coordinates on a length mismatch — now it fails loudly.
    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_panic_instead_of_truncating() {
        let mut t = EstimateTracker::new(vec![0.0; 4], true);
        t.make_delta(&[1.0; 3]);
    }

    /// Committing a non-finite message is permanent estimate-bank
    /// poisoning — it must abort loudly, not fold.
    #[test]
    #[should_panic(expected = "poison the estimate bank")]
    fn non_finite_commit_fails_loudly() {
        let mut t = EstimateTracker::new(vec![0.0; 2], true);
        t.commit(&[1.0, f64::NAN]);
    }

    #[test]
    fn reset_overrides() {
        let mut t = EstimateTracker::new(vec![0.0; 3], true);
        t.reset(&[1.0, 2.0, 3.0]);
        assert_eq!(t.estimate(), &[1.0, 2.0, 3.0]);
        let d = t.make_delta(&[1.0, 2.0, 4.0]);
        assert_eq!(d, vec![0.0, 0.0, 1.0]);
    }
}
