//! Compression operators C: R^M → Q^M and the wire codec (§4.1).
//!
//! The paper's compressor is the QSGD-style stochastic multi-level
//! quantizer ([`qsgd`], eq. 17); [`signsgd`], [`topk`] and [`randk`] cover
//! the other families the paper cites ([10,11,14]) and feed the compressor
//! ablation. [`identity`] is the uncompressed baseline ("async ADMM").
//!
//! Contract: the wire frame *is* the dequantized vector — `decode(wire)`
//! reconstructs exactly the values the sender committed to its own estimate
//! mirror, so server and node estimate banks never diverge (lossless
//! transport of the lossy code). [`Compressed`] therefore carries only the
//! frame: consumers fold its entries straight into the Kahan accumulators
//! via the streaming [`wire::entries`] cursor ([`Compressed::fold_into`] —
//! O(k) for sparse frames, scalar-at-a-time dequant for dense ones), and
//! the dense vector is materialized ([`Compressed::dequantized`]) only
//! where a full vector is genuinely needed (the fire's ẑ delta payload,
//! tests). Every compressor reports its exact wire size in bits; the
//! paper's communication metric (eq. 20) is derived solely from these.

pub mod bank;
pub mod error_feedback;
pub mod identity;
pub mod packing;
pub mod qsgd;
pub mod randk;
pub mod signsgd;
pub mod topk;
pub mod wire;

use crate::problems::accumulator::KahanVec;
use crate::snapshot::codec::{Pack, Reader, Writer};
use crate::util::rng::Pcg64;

/// Totality guard shared by every compressor: a non-finite coordinate
/// (diverged local solve, EF residual blow-up) contributes **0** to the
/// frame instead of riding the wire as NaN/±∞ and poisoning both ends'
/// estimate banks at commit. Finite values pass through untouched, so all
/// legacy bitstreams are unchanged; the loud failure for actual state
/// corruption lives in [`error_feedback::EstimateTracker::commit`].
#[inline]
pub fn sanitize(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

/// Result of compressing a vector: the exact wire frame, nothing else.
/// The frame is self-describing (tag + length header) and losslessly
/// carries the dequantized values, so the dense C(Δ) vector that earlier
/// revisions stored alongside it is redundant — consumers stream entries
/// out of the frame instead ([`Self::fold_into`] / [`Self::for_each_entry`])
/// and in-flight memory is the compressed size, not O(m) per message.
#[derive(Clone, Debug)]
pub struct Compressed {
    /// Exact wire encoding (framed; see [`wire`]).
    pub wire: Vec<u8>,
}

impl Compressed {
    /// An empty container for [`Compressor::compress_into`] reuse.
    pub fn empty() -> Self {
        Self { wire: Vec::new() }
    }

    /// True when no frame is held (a drained in-flight slot).
    pub fn is_empty(&self) -> bool {
        self.wire.is_empty()
    }

    pub fn wire_bits(&self) -> u64 {
        self.wire.len() as u64 * 8
    }

    /// The vector length the frame declares, without decoding the payload.
    pub fn frame_dim(&self) -> anyhow::Result<usize> {
        wire::frame_dim(&self.wire)
    }

    /// Visit the frame's stored `(index, value)` entries in ascending index
    /// order — all m coordinates for dense tags, the k stored entries for
    /// sparse ones (absent coordinates dequantize to exactly 0.0). The
    /// per-kind dequant visitor behind every fused fold.
    pub fn for_each_entry(&self, mut f: impl FnMut(usize, f64)) -> anyhow::Result<()> {
        let m = wire::frame_dim(&self.wire)?;
        for e in wire::entries(&self.wire, m)? {
            let (j, v) = e?;
            f(j, v);
        }
        Ok(())
    }

    /// Fold the frame's dequantized entries straight into a Kahan
    /// accumulator: s += C(Δ) without materializing C(Δ). O(k) for sparse
    /// frames. Bitwise identical to folding the [`Self::dequantized`]
    /// vector densely — the accumulator skips ±0.0 addends, so the m − k
    /// coordinates a sparse frame omits touch nothing on either path
    /// (`tests/prop.rs` pins this across all compressor kinds).
    pub fn fold_into(&self, acc: &mut KahanVec) -> anyhow::Result<()> {
        let m = wire::frame_dim(&self.wire)?;
        anyhow::ensure!(
            m == acc.dim(),
            "frame length {m} != accumulator dim {}",
            acc.dim()
        );
        for e in wire::entries(&self.wire, m)? {
            let (j, v) = e?;
            acc.fold_at(j, v);
        }
        Ok(())
    }

    /// Fold −C(Δ) into the accumulator (the error-feedback residual shape:
    /// pending −= what the forwarded frame carries). Same bitwise contract
    /// as [`Self::fold_into`] relative to a dense `sub`.
    pub fn sub_from(&self, acc: &mut KahanVec) -> anyhow::Result<()> {
        let m = wire::frame_dim(&self.wire)?;
        anyhow::ensure!(
            m == acc.dim(),
            "frame length {m} != accumulator dim {}",
            acc.dim()
        );
        for e in wire::entries(&self.wire, m)? {
            let (j, v) = e?;
            acc.fold_at(j, -v);
        }
        Ok(())
    }

    /// Materialize the dense dequantized vector. The escape hatch for call
    /// sites that genuinely need a full vector (the fire's ẑ-delta
    /// broadcast payload, tests, the EF estimate mirrors' dense commits) —
    /// hot fold paths must use [`Self::fold_into`] instead.
    pub fn dequantized(&self) -> anyhow::Result<Vec<f64>> {
        let m = wire::frame_dim(&self.wire)?;
        wire::decode(&self.wire, m)
    }
}

/// Snapshots carry in-flight compressed payloads as the wire frame alone —
/// the frame losslessly encodes the dequantized values (the module
/// contract), so packing both, as container v2 did, doubled every
/// in-flight slot for no information. This is what shrinks mid-timeline
/// checkpoints in container v3.
impl Pack for Compressed {
    fn pack(&self, w: &mut Writer) {
        w.put_bytes(&self.wire);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        Ok(Self { wire: r.get_bytes()? })
    }
}

/// A compression operator. Stateless; all randomness comes from the caller's
/// RNG so trials replay deterministically.
pub trait Compressor: Send {
    fn name(&self) -> String;

    /// Compress `delta`, drawing any randomness from `rng`.
    fn compress(&self, delta: &[f64], rng: &mut Pcg64) -> Compressed;

    /// [`Self::compress`] into a caller-owned [`Compressed`], reusing its
    /// buffer capacity. The engine's dispatch path pools one `Compressed`
    /// pair per node, so steady-state rounds do no per-message allocation.
    /// Must be bit-identical to `compress` (same wire, same dequantized,
    /// same RNG consumption); the default falls back to it. The hot-path
    /// compressors (qsgd, identity, identity32) override with true in-place
    /// encoders; the sparsifier ablations keep the allocating fallback.
    fn compress_into(&self, delta: &[f64], rng: &mut Pcg64, out: &mut Compressed) {
        *out = self.compress(delta, rng);
    }

    /// Decode a wire message produced by this compressor (or any other —
    /// the frame is self-describing). `m` is the expected vector length.
    fn decode(&self, bytes: &[u8], m: usize) -> anyhow::Result<Vec<f64>> {
        wire::decode(bytes, m)
    }

    /// Fold a frame's dequantized entries straight into a Kahan accumulator
    /// — the fused dequant→fold hot path. The frame is self-describing, so
    /// the default dispatches per-tag via [`Compressed::fold_into`]; kinds
    /// with a cheaper-than-generic visitor may override, but must stay
    /// bitwise identical to materialize-then-fold (`tests/prop.rs`).
    fn fold_into(&self, c: &Compressed, acc: &mut KahanVec) -> anyhow::Result<()> {
        c.fold_into(acc)
    }
}

/// Compressor selection for configs/CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompressorKind {
    /// Full precision f64 wire.
    Identity,
    /// Full precision f32 wire (the paper's baseline accounting:
    /// "32-bits per scalar").
    Identity32,
    /// Paper's stochastic multi-level quantizer, q bits/scalar (q ≥ 2).
    Qsgd { bits: u8 },
    /// 1-bit sign + ℓ₁/M scale.
    Sign,
    /// Largest-k magnitudes, k = ceil(frac·M).
    TopK { frac_permille: u16 },
    /// Random-k coordinates (shared-seed indices), k = ceil(frac·M).
    RandK { frac_permille: u16 },
}

impl CompressorKind {
    pub fn build(&self) -> Box<dyn Compressor> {
        match *self {
            CompressorKind::Identity => Box::new(identity::Identity),
            CompressorKind::Identity32 => Box::new(identity::Identity32),
            CompressorKind::Qsgd { bits } => Box::new(qsgd::Qsgd::new(bits)),
            CompressorKind::Sign => Box::new(signsgd::SignSgd),
            CompressorKind::TopK { frac_permille } => {
                Box::new(topk::TopK::new(frac_permille as f64 / 1000.0))
            }
            CompressorKind::RandK { frac_permille } => {
                Box::new(randk::RandK::new(frac_permille as f64 / 1000.0))
            }
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        // forms: identity | qsgd3 | sign | topk50 | randk50  (suffix = ‰)
        if s == "identity" || s == "none" {
            Ok(CompressorKind::Identity)
        } else if s == "identity32" || s == "fp32" {
            Ok(CompressorKind::Identity32)
        } else if s == "sign" {
            Ok(CompressorKind::Sign)
        } else if let Some(q) = s.strip_prefix("qsgd") {
            let bits: u8 = q.parse()?;
            anyhow::ensure!((2..=16).contains(&bits), "qsgd bits must be in 2..=16");
            Ok(CompressorKind::Qsgd { bits })
        } else if let Some(f) = s.strip_prefix("topk") {
            Ok(CompressorKind::TopK { frac_permille: Self::parse_permille(f)? })
        } else if let Some(f) = s.strip_prefix("randk") {
            Ok(CompressorKind::RandK { frac_permille: Self::parse_permille(f)? })
        } else {
            anyhow::bail!("unknown compressor '{s}' (identity|qsgdQ|sign|topkP|randkP)")
        }
    }

    /// A sparsifier fraction in permille must land in (0, 1] — `topk0`
    /// would keep nothing and values over 1000 are not fractions (the
    /// builders assert the same range, so rejecting here turns a later
    /// panic into a parse error).
    fn parse_permille(s: &str) -> anyhow::Result<u16> {
        let p: u16 = s.parse()?;
        anyhow::ensure!(
            (1..=1000).contains(&p),
            "sparsifier permille must be in 1..=1000 (got {p})"
        );
        Ok(p)
    }

    pub fn label(&self) -> String {
        match *self {
            CompressorKind::Identity => "identity".into(),
            CompressorKind::Identity32 => "identity32".into(),
            CompressorKind::Qsgd { bits } => format!("qsgd{bits}"),
            CompressorKind::Sign => "sign".into(),
            CompressorKind::TopK { frac_permille } => format!("topk{frac_permille}"),
            CompressorKind::RandK { frac_permille } => format!("randk{frac_permille}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["identity", "qsgd3", "qsgd8", "sign", "topk50", "randk125", "topk1000"] {
            let k = CompressorKind::parse(s).unwrap();
            assert_eq!(k.label(), s);
            assert_eq!(CompressorKind::parse(&k.label()).unwrap(), k);
        }
        assert!(CompressorKind::parse("qsgd1").is_err()); // S would be 0
        assert!(CompressorKind::parse("bogus").is_err());
        // sparsifier fractions must be in (0, 1]: k = 0 keeps nothing and
        // >1000‰ is not a fraction — both used to parse and then panic in
        // the builder (TopK::new / RandK::new asserts)
        for s in ["topk0", "randk0", "topk1001", "randk2000", "topk70000"] {
            assert!(CompressorKind::parse(s).is_err(), "{s} should be rejected");
        }
    }

    /// compress_into must be bit-identical to compress — same wire bytes
    /// (hence same dequantized values, by the module contract), same RNG
    /// consumption — including when the output buffer is dirty from a
    /// previous (longer) message.
    #[test]
    fn compress_into_matches_compress_for_all_kinds() {
        let kinds = [
            CompressorKind::Identity,
            CompressorKind::Identity32,
            CompressorKind::Qsgd { bits: 2 },
            CompressorKind::Qsgd { bits: 3 },
            CompressorKind::Qsgd { bits: 11 },
            CompressorKind::Sign,
            CompressorKind::TopK { frac_permille: 100 },
            CompressorKind::RandK { frac_permille: 100 },
        ];
        let mut rng = Pcg64::seed_from_u64(31);
        for kind in kinds {
            let c = kind.build();
            let mut out = Compressed::empty();
            // dirty the pooled buffers with a longer vector first
            let long = rng.normal_vec(903, 0.0, 1.0);
            c.compress_into(&long, &mut Pcg64::seed_from_u64(1), &mut out);
            for m in [1usize, 64, 517] {
                let delta = rng.normal_vec(m, 0.0, 2.0);
                let mut r1 = Pcg64::seed_from_u64(77);
                let mut r2 = Pcg64::seed_from_u64(77);
                let a = c.compress(&delta, &mut r1);
                c.compress_into(&delta, &mut r2, &mut out);
                assert_eq!(a.wire, out.wire, "kind={} m={m}", kind.label());
                assert_eq!(r1.next_u64(), r2.next_u64(), "kind={} m={m}", kind.label());
            }
            // zero vector keeps the RNG streams aligned too
            let mut r1 = Pcg64::seed_from_u64(5);
            let mut r2 = Pcg64::seed_from_u64(5);
            let z = vec![0.0; 40];
            let a = c.compress(&z, &mut r1);
            c.compress_into(&z, &mut r2, &mut out);
            assert_eq!(a.wire, out.wire, "kind={} zero", kind.label());
            assert_eq!(r1.next_u64(), r2.next_u64(), "kind={} zero", kind.label());
        }
    }

    /// The cross-compressor contract: decode(wire) is the dequantized
    /// vector, and the header-derived materializer agrees with it exactly.
    #[test]
    fn decode_matches_dequantized_for_all_kinds() {
        let kinds = [
            CompressorKind::Identity,
            CompressorKind::Qsgd { bits: 3 },
            CompressorKind::Qsgd { bits: 8 },
            CompressorKind::Sign,
            CompressorKind::TopK { frac_permille: 100 },
            CompressorKind::RandK { frac_permille: 100 },
        ];
        let mut rng = Pcg64::seed_from_u64(9);
        let delta = rng.normal_vec(517, 0.0, 2.0);
        for kind in kinds {
            let c = kind.build();
            let out = c.compress(&delta, &mut rng);
            assert_eq!(out.frame_dim().unwrap(), delta.len(), "kind={}", kind.label());
            let decoded = c.decode(&out.wire, delta.len()).unwrap();
            assert_eq!(decoded, out.dequantized().unwrap(), "kind={}", kind.label());
        }
    }

    /// Smoke check of the fused path at module level (the exhaustive
    /// 8-kind × poisoned-input property lives in `tests/prop.rs`): folding
    /// a frame's entries equals folding the materialized vector, bitwise.
    #[test]
    fn fold_into_matches_materialized_fold() {
        let mut rng = Pcg64::seed_from_u64(27);
        let delta = rng.normal_vec(301, 0.0, 2.0);
        for kind in [
            CompressorKind::Qsgd { bits: 3 },
            CompressorKind::TopK { frac_permille: 100 },
        ] {
            let c = kind.build();
            let out = c.compress(&delta, &mut rng);
            let mut fused = KahanVec::zeros(delta.len());
            fused.add(&delta); // nonzero starting state
            let mut dense = fused.clone();
            c.fold_into(&out, &mut fused).unwrap();
            dense.add(&out.dequantized().unwrap());
            let bits = |k: &KahanVec| k.value().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&fused), bits(&dense), "kind={}", kind.label());
        }
    }
}
