//! Neural-network consensus problems (§5.2): inexact primal updates — K
//! Adam steps on the prox-augmented local loss — executed entirely inside
//! one AOT-compiled HLO artifact per ADMM iteration (`cnn_local_update` /
//! `mlp_local_update`). The consensus prox for h ≡ 0 is the plain average,
//! computed natively in f64.

use super::mnist::{self, Dataset, IMG_PIXELS};
use super::{Arena, EvalMetrics, Problem};
use crate::runtime::artifacts::{Manifest, ParamSpec};
use crate::runtime::tensor::Tensor;
use crate::runtime::Exec;
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NnArch {
    /// 784–64–10 MLP (fast CI / e2e scale).
    Mlp,
    /// The paper's 6-layer CNN (M = 246,026).
    Cnn,
}

impl NnArch {
    pub fn prefix(&self) -> &'static str {
        match self {
            NnArch::Mlp => "mlp",
            NnArch::Cnn => "cnn",
        }
    }

    /// Image tensor trailing dims in the artifacts.
    fn img_dims(&self) -> Vec<usize> {
        match self {
            NnArch::Mlp => vec![IMG_PIXELS],
            NnArch::Cnn => vec![28, 28, 1],
        }
    }
}

pub struct NnProblem {
    arch: NnArch,
    m: usize,
    k: usize,
    b: usize,
    eval_b: usize,
    n_nodes: usize,
    rho: f64,
    lr: f64,
    exec: Box<dyn Exec + Send>,
    param_specs: Vec<ParamSpec>,
    // Adam state per node (node-local, never communicated).
    adam_m: Vec<Vec<f32>>,
    adam_v: Vec<Vec<f32>>,
    adam_t: Vec<f32>,
    shards: Vec<Dataset>,
    test: Dataset,
    /// Restart Adam at every outer iteration (default true; see
    /// `local_update`). Settable for the ablation.
    pub reset_adam: bool,
    pub data_source: &'static str,
    /// Last evaluated train-loss per node (diagnostics).
    pub last_losses: Vec<f64>,
}

impl NnProblem {
    /// Build from the artifact manifest + a data directory (real MNIST if
    /// present under `data_dir`, otherwise the synthetic corpus).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        arch: NnArch,
        n_nodes: usize,
        rho: f64,
        lr: f64,
        exec: Box<dyn Exec + Send>,
        manifest: &Manifest,
        n_train: usize,
        n_test: usize,
        data_dir: &std::path::Path,
        seed: u64,
    ) -> anyhow::Result<Self> {
        let p = arch.prefix();
        let m = manifest.const_usize(&format!("{p}_m"))?;
        let k = manifest.const_usize(&format!("{p}_k"))?;
        let b = manifest.const_usize(&format!("{p}_b"))?;
        let eval_b = manifest.const_usize("eval_b")?;
        let param_specs = manifest.param_specs(p)?.to_vec();
        let total: usize = param_specs.iter().map(|s| s.size).sum();
        anyhow::ensure!(total == m, "param specs sum {total} != manifest m {m}");

        // round test size up to a whole number of eval batches
        let n_test = n_test.div_ceil(eval_b) * eval_b;
        let (train, test, data_source) =
            mnist::load_or_synthesize(data_dir, n_train, n_test, seed)?;
        let mut rng = Pcg64::seed_from_u64(seed ^ 0x5348_4152_44);
        let shards = train.split(n_nodes, &mut rng);
        let min_shard = shards.iter().map(Dataset::len).min().unwrap_or(0);
        anyhow::ensure!(min_shard >= b, "shard too small: {min_shard} < batch {b}");

        Ok(Self {
            arch,
            m,
            k,
            b,
            eval_b,
            n_nodes,
            rho,
            lr,
            exec,
            param_specs,
            adam_m: vec![vec![0.0; m]; n_nodes],
            adam_v: vec![vec![0.0; m]; n_nodes],
            adam_t: vec![0.0; n_nodes],
            shards,
            test,
            reset_adam: true,
            data_source,
            last_losses: vec![f64::NAN; n_nodes],
        })
    }

    /// He initialization (weights ~ N(0, 2/fan_in), biases 0) in f64.
    pub fn he_init(&self, rng: &mut Pcg64) -> Vec<f64> {
        let mut flat = vec![0.0; self.m];
        for spec in &self.param_specs {
            if spec.name.ends_with("_w") {
                let std = (2.0 / spec.fan_in as f64).sqrt();
                for v in &mut flat[spec.offset..spec.offset + spec.size] {
                    *v = std * rng.standard_normal();
                }
            }
        }
        flat
    }

    fn sample_batches(&self, node: usize, rng: &mut Pcg64) -> (Vec<f32>, Vec<i32>) {
        let shard = &self.shards[node];
        let mut bx = Vec::with_capacity(self.k * self.b * IMG_PIXELS);
        let mut by = Vec::with_capacity(self.k * self.b);
        for _ in 0..self.k * self.b {
            let idx = rng.gen_range(shard.len());
            bx.extend_from_slice(shard.image(idx));
            by.push(shard.labels[idx]);
        }
        (bx, by)
    }

    fn batch_shape(&self) -> Vec<usize> {
        let mut s = vec![self.k, self.b];
        s.extend(self.arch.img_dims());
        s
    }

    fn eval_shape(&self) -> Vec<usize> {
        let mut s = vec![self.eval_b];
        s.extend(self.arch.img_dims());
        s
    }

    /// Evaluate `z` on the held-out test set: (accuracy, mean CE loss).
    pub fn test_metrics(&mut self, z: &[f64]) -> anyhow::Result<(f64, f64)> {
        let name = format!("{}_eval", self.arch.prefix());
        let flat = Tensor::f32_from_f64(z, vec![self.m]);
        let n_batches = self.test.len() / self.eval_b;
        anyhow::ensure!(n_batches > 0, "test set smaller than eval batch");
        let mut correct = 0.0;
        let mut loss_sum = 0.0;
        for batch in 0..n_batches {
            let lo = batch * self.eval_b;
            let hi = lo + self.eval_b;
            let x = Tensor::F32(
                self.test.images[lo * IMG_PIXELS..hi * IMG_PIXELS].to_vec(),
                self.eval_shape(),
            );
            let y = Tensor::vec_i32(self.test.labels[lo..hi].to_vec());
            let out = self.exec.call(&name, &[flat.clone(), x, y])?;
            correct += out[0].scalar()?;
            loss_sum += out[1].scalar()?;
        }
        let total = (n_batches * self.eval_b) as f64;
        Ok((correct / total, loss_sum / n_batches as f64))
    }
}

impl Problem for NnProblem {
    fn dim(&self) -> usize {
        self.m
    }

    fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    fn name(&self) -> String {
        format!(
            "{}(m={},n={},k={},b={},rho={},lr={},data={})",
            self.arch.prefix(),
            self.m,
            self.n_nodes,
            self.k,
            self.b,
            self.rho,
            self.lr,
            self.data_source
        )
    }

    fn init_x(&mut self, rng: &mut Pcg64) -> Vec<f64> {
        self.he_init(rng)
    }

    fn local_update(
        &mut self,
        node: usize,
        zhat: &[f64],
        u: &[f64],
        x_prev: &[f64],
        rng: &mut Pcg64,
    ) -> anyhow::Result<(Vec<f64>, f64)> {
        let name = format!("{}_local_update", self.arch.prefix());
        let (bx, by) = self.sample_batches(node, rng);
        let m = self.m;
        let dummy = vec![0.0f32; m];
        let noise = vec![0.5f32; m];
        // Adam restarts fresh on every outer iteration (the paper: "10
        // iterations of gradient descent ... ADAM with an *initial* learning
        // rate of 0.001"). Persisting moments across outer iterations is
        // unstable: once the training loss is small, stale second moments
        // shrink and the dual-driven prox term overshoots (verified
        // empirically — sync runs diverge after ~25 iterations otherwise).
        if self.reset_adam {
            self.adam_m[node].iter_mut().for_each(|v| *v = 0.0);
            self.adam_v[node].iter_mut().for_each(|v| *v = 0.0);
            self.adam_t[node] = 0.0;
        }
        let inputs = vec![
            Tensor::f32_from_f64(x_prev, vec![m]),
            Tensor::vec_f32(self.adam_m[node].clone()),
            Tensor::vec_f32(self.adam_v[node].clone()),
            Tensor::scalar_f32(self.adam_t[node]),
            Tensor::f32_from_f64(u, vec![m]),
            Tensor::f32_from_f64(zhat, vec![m]),
            Tensor::vec_f32(dummy.clone()), // xhat: feeds only fused quant
            Tensor::vec_f32(dummy),         // uhat
            Tensor::F32(bx, self.batch_shape()),
            Tensor::I32(by, vec![self.k, self.b]),
            Tensor::vec_f32(noise.clone()),
            Tensor::vec_f32(noise),
            Tensor::scalar_f32(self.rho as f32),
            Tensor::scalar_f32(self.lr as f32),
            Tensor::scalar_f32(3.0),
        ];
        let out = self.exec.call(&name, &inputs)?;
        // outputs: x_new m_new v_new t_new u_new cx.. loss
        self.adam_m[node] = out[1].as_f32()?.to_vec();
        self.adam_v[node] = out[2].as_f32()?.to_vec();
        self.adam_t[node] = out[3].scalar()? as f32;
        let x_new = out[0].to_f64_vec();
        let loss = out[11].scalar()?;
        self.last_losses[node] = loss;
        Ok((x_new, loss))
    }

    fn consensus(&mut self, xhat: &[Vec<f64>], uhat: &[Vec<f64>]) -> anyhow::Result<Vec<f64>> {
        // prox of h ≡ 0 is the identity: z = mean(x̂ + û)
        let n = xhat.len();
        let mut sum = vec![0.0; self.m];
        for (xi, ui) in xhat.iter().zip(uhat) {
            for j in 0..self.m {
                sum[j] += xi[j] + ui[j];
            }
        }
        self.consensus_from_sum(&sum, n)
    }

    /// The plain mean from the running sum: z = s/n, O(m).
    fn consensus_from_sum(&mut self, sum: &[f64], n_nodes: usize) -> anyhow::Result<Vec<f64>> {
        let n = n_nodes as f64;
        Ok(sum.iter().map(|s| s / n).collect())
    }

    fn evaluate(&mut self, _x: &Arena, _u: &Arena, z: &[f64]) -> anyhow::Result<EvalMetrics> {
        let (test_acc, test_loss) = self.test_metrics(z)?;
        Ok(EvalMetrics { accuracy: f64::NAN, test_acc, loss: test_loss })
    }
}
