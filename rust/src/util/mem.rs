//! Process-memory introspection for the scale benchmarks.
//!
//! The million-node engine work's acceptance criterion is *peak resident
//! memory*, not allocator counters — fragmentation and transient spikes
//! count. On Linux the kernel already tracks exactly that high-water mark
//! (`VmHWM` in `/proc/self/status`); elsewhere we report `None` rather
//! than a number measured differently on different platforms.

/// Peak resident set size of this process in MiB (`VmHWM`), or `None`
/// where `/proc` is unavailable. The value is a high-water mark: it never
/// decreases over the process lifetime, so read it *after* the workload.
pub fn peak_rss_mb() -> Option<f64> {
    #[cfg(target_os = "linux")]
    {
        let status = std::fs::read_to_string("/proc/self/status").ok()?;
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                // format: "VmHWM:    123456 kB"
                let kb: f64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
                return Some(kb / 1024.0);
            }
        }
        None
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_rss_is_positive_where_supported() {
        match peak_rss_mb() {
            // any running test process occupies at least a few MiB
            Some(mb) => assert!(mb > 1.0 && mb.is_finite(), "VmHWM = {mb} MiB"),
            None => assert!(cfg!(not(target_os = "linux")), "/proc parse failed on linux"),
        }
    }
}
