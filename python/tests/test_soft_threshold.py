"""Soft-threshold Pallas kernel vs oracle + closed-form cases."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels.ref import soft_threshold_ref  # noqa: E402
from compile.kernels.soft_threshold import soft_threshold  # noqa: E402


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=2048),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    kappa=st.floats(min_value=0.0, max_value=5.0),
    dtype=st.sampled_from([np.float32, np.float64]),
)
def test_kernel_matches_ref(m, seed, kappa, dtype):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.standard_normal(m).astype(dtype))
    out_k = soft_threshold(v, kappa)
    out_r = soft_threshold_ref(v, kappa)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=0)


def test_hand_cases():
    v = jnp.asarray(np.array([3.0, -3.0, 0.5, -0.5, 0.0]))
    out = np.asarray(soft_threshold(v, 1.0))
    np.testing.assert_allclose(out, [2.0, -2.0, 0.0, 0.0, 0.0])


def test_prox_optimality():
    """S_κ(v) minimizes κ|z| + ½(z−v)²: check via subgradient conditions."""
    rng = np.random.default_rng(0)
    v = rng.standard_normal(400)
    kappa = 0.3
    z = np.asarray(soft_threshold(jnp.asarray(v), kappa))
    # where z != 0: z - v + κ·sign(z) == 0
    nz = z != 0
    np.testing.assert_allclose(z[nz] - v[nz] + kappa * np.sign(z[nz]), 0, atol=1e-12)
    # where z == 0: |v| ≤ κ
    assert np.all(np.abs(v[~nz]) <= kappa + 1e-12)


def test_kappa_zero_is_identity():
    rng = np.random.default_rng(1)
    v = rng.standard_normal(257)
    out = np.asarray(soft_threshold(jnp.asarray(v), 0.0))
    np.testing.assert_allclose(out, v, atol=0)
