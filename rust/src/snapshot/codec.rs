//! The in-house versioned binary codec behind every run snapshot.
//!
//! No serde in the offline crate universe, and the JSON substrate
//! ([`crate::util::json`]) is the wrong tool for multi-megabyte f64 state
//! (f64 → decimal → f64 is lossy unless printed at full shortest-round-trip
//! precision, and 10× the bytes). So snapshots use a little-endian
//! length-prefixed binary layout behind the [`Pack`] trait, with the
//! human-readable part — what run is this, which round, which config —
//! kept as a JSON header in the container ([`encode_container`]).
//!
//! # Totality contract
//!
//! Decoding arbitrary bytes must never panic and never allocate more than
//! the input could justify: every length prefix is bounds-checked against
//! the remaining input before any allocation, every enum tag is validated,
//! and [`decode_container`] verifies an FNV-1a checksum over the body, so
//! a truncated or bit-flipped snapshot surfaces as `Err`, not as a corrupt
//! resumed run (`tests/prop.rs` drives truncation/corruption the same way
//! it drives the wire-frame decoders).
//!
//! # Determinism contract
//!
//! `pack` writes a canonical form (heap contents sorted, no addresses, no
//! capacities), so `pack(unpack(pack(x))) == pack(x)` byte-for-byte — the
//! property the resume-parity suite leans on.

use std::collections::{BTreeSet, VecDeque};

/// Container magic (8 bytes) — changes only with a breaking layout change.
pub const MAGIC: [u8; 8] = *b"QADMMSNP";

/// Container layout version. Bump on any change to the header/body/checksum
/// framing; the per-state layout is versioned by [`MAGIC`]+this pair, and a
/// reader rejects versions it does not know instead of misparsing.
///
/// v2: event-trigger / adaptive-schedule state ([`crate::admm::trigger`])
/// packed into both runtime bodies, and the event engine's in-flight slots
/// gained a `skipped` flag — v1 snapshots no longer parse.
///
/// v3: in-flight [`crate::compress::Compressed`] payloads pack wire-only
/// (v2 stored the dequantized vector *and* the wire frame; the
/// `decode(wire) == dequantized` contract makes the dense copy redundant),
/// shrinking checkpoints of in-flight-heavy runs — v2 snapshots no longer
/// parse.
///
/// v4: the event engine's body layout changed with the million-node work —
/// estimate banks pack as committed wire frames
/// ([`crate::compress::bank::QuantBank`]) instead of dense rows, the
/// per-node downlink inboxes collapsed into one shared mirror window, and
/// in-flight slots became optional (idle nodes pack one tag byte) — v3
/// snapshots no longer parse.
pub const VERSION: u32 = 4;

const FNV_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a 64-bit over a byte slice (checksums + RNG-state digests).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_update(FNV_SEED, bytes)
}

/// Fold more bytes into a running FNV-1a state (seed with [`fnv1a64`] of
/// the empty slice, i.e. the FNV offset basis). Chaining updates over
/// chunks is exactly equal to one [`fnv1a64`] over the concatenation —
/// what lets the spilling [`Writer`] checksum a body it never holds whole.
pub fn fnv1a64_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Spill threshold for [`Writer::with_sink`]: the buffer drains to the
/// sink whenever it crosses this size, so peak codec memory stays ~1 MiB
/// no matter how large the packed state is.
const SPILL_CHUNK: usize = 1 << 20;

/// IO side of a spilling [`Writer`]: where the drained chunks go, plus the
/// running length/checksum over everything drained so far.
struct Spill {
    sink: Box<dyn std::io::Write>,
    written: u64,
    hash: u64,
    err: Option<std::io::Error>,
}

impl Spill {
    /// Drain `buf` into the sink, folding it into the running checksum.
    /// The first IO error is latched and re-raised by
    /// [`Writer::finish_stream`]; the length/checksum keep tracking the
    /// *intended* bytes so the failure surfaces exactly once, at the end.
    fn drain(&mut self, buf: &mut Vec<u8>) {
        if buf.is_empty() {
            return;
        }
        self.hash = fnv1a64_update(self.hash, buf);
        self.written += buf.len() as u64;
        if self.err.is_none() {
            if let Err(e) = self.sink.write_all(buf) {
                self.err = Some(e);
            }
        }
        buf.clear();
    }
}

/// Append-only little-endian byte sink.
///
/// Two modes share every `put_*` method: the default in-memory buffer
/// ([`Writer::new`], read back with [`Writer::into_inner`]) and a spilling
/// mode ([`Writer::with_sink`]) that drains to an [`std::io::Write`] every
/// [`SPILL_CHUNK`] bytes and finishes with [`Writer::finish_stream`] —
/// used by checkpointing so serializing a multi-GB arena never doubles
/// resident memory.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
    spill: Option<Spill>,
}

impl std::fmt::Debug for Writer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Writer")
            .field("buffered", &self.buf.len())
            .field("spilling", &self.spill.is_some())
            .finish()
    }
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    /// A spilling writer: bytes drain to `sink` in [`SPILL_CHUNK`] pieces.
    /// Must be finished with [`Writer::finish_stream`]; the in-memory
    /// accessors ([`Writer::into_inner`] / [`Writer::as_slice`]) are
    /// unavailable because the writer never holds the full payload.
    pub fn with_sink(sink: Box<dyn std::io::Write>) -> Self {
        Self {
            buf: Vec::with_capacity(SPILL_CHUNK),
            spill: Some(Spill { sink, written: 0, hash: FNV_SEED, err: None }),
        }
    }

    pub fn into_inner(self) -> Vec<u8> {
        assert!(self.spill.is_none(), "into_inner on a spilling Writer");
        self.buf
    }

    pub fn as_slice(&self) -> &[u8] {
        assert!(self.spill.is_none(), "as_slice on a spilling Writer");
        &self.buf
    }

    /// Total bytes written so far (drained + still buffered).
    pub fn len(&self) -> usize {
        self.buf.len() + self.spill.as_ref().map_or(0, |s| s.written as usize)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain the remainder and return `(total_len, fnv1a64(body))` —
    /// exactly what the container framing needs to patch in after the
    /// body. Any IO error from any earlier drain surfaces here.
    pub fn finish_stream(mut self) -> anyhow::Result<(u64, u64)> {
        let mut sp = self.spill.take().expect("finish_stream on a buffered Writer");
        sp.drain(&mut self.buf);
        if let Some(e) = sp.err.take() {
            return Err(anyhow::anyhow!("snapshot stream write failed: {e}"));
        }
        sp.sink
            .flush()
            .map_err(|e| anyhow::anyhow!("snapshot stream flush failed: {e}"))?;
        Ok((sp.written, sp.hash))
    }

    fn maybe_spill(&mut self) {
        if self.buf.len() >= SPILL_CHUNK {
            if let Some(sp) = &mut self.spill {
                sp.drain(&mut self.buf);
            }
        }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
        self.maybe_spill();
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self.maybe_spill();
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self.maybe_spill();
    }

    pub fn put_u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self.maybe_spill();
    }

    /// usize travels as u64 so snapshots are portable across word sizes.
    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// f64 as raw IEEE bits: NaN payloads and signed zeros round-trip
    /// exactly (the bit-identity contract cares).
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_bool(&mut self, v: bool) {
        self.put_u8(v as u8);
    }

    /// Length-prefixed raw bytes.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_usize(v.len());
        self.buf.extend_from_slice(v);
        self.maybe_spill();
    }

    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }
}

/// Bounds-checked little-endian reader over a borrowed byte slice.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            n <= self.remaining(),
            "snapshot truncated: need {n} bytes at offset {}, have {}",
            self.pos,
            self.remaining()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_u128(&mut self) -> anyhow::Result<u128> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }

    pub fn get_usize(&mut self) -> anyhow::Result<usize> {
        let v = self.get_u64()?;
        usize::try_from(v).map_err(|_| anyhow::anyhow!("snapshot value {v} exceeds usize"))
    }

    pub fn get_f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    pub fn get_bool(&mut self) -> anyhow::Result<bool> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => anyhow::bail!("snapshot bool must be 0|1, got {other}"),
        }
    }

    /// A collection length prefix, bounded by the remaining input: every
    /// element of every collection we encode occupies ≥ 1 byte, so a
    /// length larger than the tail is corruption — reject it *before*
    /// allocating (an OOM from a flipped length byte is a panic in
    /// disguise).
    pub fn get_len(&mut self) -> anyhow::Result<usize> {
        let len = self.get_usize()?;
        anyhow::ensure!(
            len <= self.remaining(),
            "snapshot corrupt: length prefix {len} exceeds remaining {} bytes",
            self.remaining()
        );
        Ok(len)
    }

    pub fn get_bytes(&mut self) -> anyhow::Result<Vec<u8>> {
        let len = self.get_len()?;
        Ok(self.take(len)?.to_vec())
    }

    pub fn get_string(&mut self) -> anyhow::Result<String> {
        let bytes = self.get_bytes()?;
        String::from_utf8(bytes).map_err(|_| anyhow::anyhow!("snapshot string is not utf-8"))
    }

    /// Error unless every byte was consumed — trailing garbage means the
    /// reader and writer disagree about the layout.
    pub fn finish(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.remaining() == 0,
            "snapshot has {} undecoded trailing bytes",
            self.remaining()
        );
        Ok(())
    }
}

/// Symmetric binary (de)serialization. Implemented next to each type so
/// private fields stay private; the engines compose these into one
/// `RunState` body per snapshot.
pub trait Pack: Sized {
    fn pack(&self, w: &mut Writer);
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self>;
}

impl Pack for u8 {
    fn pack(&self, w: &mut Writer) {
        w.put_u8(*self);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        r.get_u8()
    }
}

impl Pack for u32 {
    fn pack(&self, w: &mut Writer) {
        w.put_u32(*self);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        r.get_u32()
    }
}

impl Pack for u64 {
    fn pack(&self, w: &mut Writer) {
        w.put_u64(*self);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        r.get_u64()
    }
}

impl Pack for u128 {
    fn pack(&self, w: &mut Writer) {
        w.put_u128(*self);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        r.get_u128()
    }
}

impl Pack for usize {
    fn pack(&self, w: &mut Writer) {
        w.put_usize(*self);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        r.get_usize()
    }
}

impl Pack for f64 {
    fn pack(&self, w: &mut Writer) {
        w.put_f64(*self);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        r.get_f64()
    }
}

impl Pack for bool {
    fn pack(&self, w: &mut Writer) {
        w.put_bool(*self);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        r.get_bool()
    }
}

impl Pack for String {
    fn pack(&self, w: &mut Writer) {
        w.put_str(self);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        r.get_string()
    }
}

impl<T: Pack> Pack for Vec<T> {
    fn pack(&self, w: &mut Writer) {
        w.put_usize(self.len());
        for item in self {
            item.pack(w);
        }
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let len = r.get_len()?;
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(T::unpack(r)?);
        }
        Ok(out)
    }
}

impl<T: Pack> Pack for VecDeque<T> {
    fn pack(&self, w: &mut Writer) {
        w.put_usize(self.len());
        for item in self {
            item.pack(w);
        }
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let len = r.get_len()?;
        let mut out = VecDeque::with_capacity(len);
        for _ in 0..len {
            out.push_back(T::unpack(r)?);
        }
        Ok(out)
    }
}

impl<T: Pack> Pack for Option<T> {
    fn pack(&self, w: &mut Writer) {
        match self {
            None => w.put_u8(0),
            Some(v) => {
                w.put_u8(1);
                v.pack(w);
            }
        }
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        match r.get_u8()? {
            0 => Ok(None),
            1 => Ok(Some(T::unpack(r)?)),
            other => anyhow::bail!("snapshot option tag must be 0|1, got {other}"),
        }
    }
}

impl<A: Pack, B: Pack> Pack for (A, B) {
    fn pack(&self, w: &mut Writer) {
        self.0.pack(w);
        self.1.pack(w);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        Ok((A::unpack(r)?, B::unpack(r)?))
    }
}

impl Pack for BTreeSet<usize> {
    fn pack(&self, w: &mut Writer) {
        w.put_usize(self.len());
        for &v in self {
            w.put_usize(v);
        }
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let len = r.get_len()?;
        let mut out = BTreeSet::new();
        for _ in 0..len {
            let v = r.get_usize()?;
            anyhow::ensure!(out.insert(v), "snapshot set has duplicate element {v}");
        }
        Ok(out)
    }
}

/// Frame a JSON header + binary body into one snapshot container:
///
/// ```text
/// MAGIC(8) | version u32 | header_len u32 | header (pretty JSON, utf-8)
///          | body_len u64 | body | fnv1a64(body) u64
/// ```
///
/// The header stays plain text at the top of the file, so `head -c 400
/// run.qsnap` tells a human what the snapshot is without any tooling.
pub fn encode_container(header: &crate::util::json::Json, body: &[u8]) -> Vec<u8> {
    let header_text = header.to_string_pretty();
    let mut out = Vec::with_capacity(8 + 4 + 4 + header_text.len() + 8 + body.len() + 8);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(header_text.len() as u32).to_le_bytes());
    out.extend_from_slice(header_text.as_bytes());
    out.extend_from_slice(&(body.len() as u64).to_le_bytes());
    out.extend_from_slice(body);
    out.extend_from_slice(&fnv1a64(body).to_le_bytes());
    out
}

/// Inverse of [`encode_container`]. Total: magic/version/length/checksum
/// failures are `Err`, never panics or unbounded allocation.
pub fn decode_container(
    bytes: &[u8],
) -> anyhow::Result<(crate::util::json::Json, Vec<u8>)> {
    let mut r = Reader::new(bytes);
    let magic = r.take(8)?;
    anyhow::ensure!(magic == MAGIC.as_slice(), "not a qadmm snapshot (bad magic)");
    let version = r.get_u32()?;
    anyhow::ensure!(
        version == VERSION,
        "snapshot container version {version} not supported (expected {VERSION}); \
         v4 packs estimate banks as wire frames and the downlink window as a \
         shared mirror table, so older snapshots cannot be migrated — \
         re-record the checkpoint with this build"
    );
    let header_len = r.get_u32()? as usize;
    let header_bytes = r.take(header_len)?;
    let header_text = std::str::from_utf8(header_bytes)
        .map_err(|_| anyhow::anyhow!("snapshot header is not utf-8"))?;
    let header = crate::util::json::Json::parse(header_text)
        .map_err(|e| anyhow::anyhow!("snapshot header is not valid json: {e}"))?;
    let body_len = r.get_u64()?;
    let body_len = usize::try_from(body_len)
        .map_err(|_| anyhow::anyhow!("snapshot body length {body_len} exceeds usize"))?;
    let body = r.take(body_len)?.to_vec();
    let want = r.get_u64()?;
    r.finish()?;
    let got = fnv1a64(&body);
    anyhow::ensure!(
        got == want,
        "snapshot body checksum mismatch (stored {want:#018x}, computed {got:#018x})"
    );
    Ok((header, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn primitives_round_trip() {
        let mut w = Writer::new();
        w.put_u8(7);
        w.put_u32(0xdead_beef);
        w.put_u64(u64::MAX);
        w.put_u128(u128::MAX - 5);
        w.put_usize(42);
        w.put_f64(-0.0);
        w.put_f64(f64::NAN);
        w.put_bool(true);
        w.put_str("ẑ mirrors");
        let bytes = w.into_inner();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xdead_beef);
        assert_eq!(r.get_u64().unwrap(), u64::MAX);
        assert_eq!(r.get_u128().unwrap(), u128::MAX - 5);
        assert_eq!(r.get_usize().unwrap(), 42);
        // signed zero and NaN payloads are preserved bitwise
        assert_eq!(r.get_f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.get_f64().unwrap().is_nan());
        assert!(r.get_bool().unwrap());
        assert_eq!(r.get_string().unwrap(), "ẑ mirrors");
        r.finish().unwrap();
    }

    #[test]
    fn collections_round_trip() {
        let v: Vec<f64> = vec![1.5, -2.25, 0.0];
        let d: VecDeque<u64> = [9u64, 8, 7].into_iter().collect();
        let o: Option<String> = Some("x".into());
        let none: Option<String> = None;
        let s: BTreeSet<usize> = [3usize, 1, 4].into_iter().collect();
        let t: (usize, f64) = (11, 2.5);
        let mut w = Writer::new();
        v.pack(&mut w);
        d.pack(&mut w);
        o.pack(&mut w);
        none.pack(&mut w);
        s.pack(&mut w);
        t.pack(&mut w);
        let bytes = w.into_inner();
        let mut r = Reader::new(&bytes);
        assert_eq!(Vec::<f64>::unpack(&mut r).unwrap(), v);
        assert_eq!(VecDeque::<u64>::unpack(&mut r).unwrap(), d);
        assert_eq!(Option::<String>::unpack(&mut r).unwrap(), o);
        assert_eq!(Option::<String>::unpack(&mut r).unwrap(), none);
        assert_eq!(BTreeSet::<usize>::unpack(&mut r).unwrap(), s);
        assert_eq!(<(usize, f64)>::unpack(&mut r).unwrap(), t);
        r.finish().unwrap();
    }

    #[test]
    fn bad_length_prefix_rejected_before_allocation() {
        // a length prefix claiming more elements than bytes remain must
        // error out instead of allocating terabytes
        let mut w = Writer::new();
        w.put_usize(usize::MAX / 2);
        let bytes = w.into_inner();
        let mut r = Reader::new(&bytes);
        assert!(r.get_len().is_err());
        let mut r2 = Reader::new(&bytes);
        assert!(Vec::<f64>::unpack(&mut r2).is_err());
    }

    #[test]
    fn container_round_trips_and_is_human_headed() {
        let header = Json::obj(vec![
            ("engine", Json::Str("event".into())),
            ("round", Json::Num(17.0)),
        ]);
        let body = vec![1u8, 2, 3, 255, 0, 7];
        let packed = encode_container(&header, &body);
        // the header is visible as plain text near the top of the file
        let text = String::from_utf8_lossy(&packed[..60.min(packed.len())]);
        assert!(text.contains("event"), "header not human-readable: {text}");
        let (h, b) = decode_container(&packed).unwrap();
        assert_eq!(h.get("round").unwrap().as_usize(), Some(17));
        assert_eq!(b, body);
    }

    #[test]
    fn container_rejects_truncation_and_corruption() {
        let header = Json::obj(vec![("engine", Json::Str("seq".into()))]);
        let body: Vec<u8> = (0..257u32).map(|i| (i % 251) as u8).collect();
        let packed = encode_container(&header, &body);
        // every strict prefix errors (never panics, never misdecodes)
        for cut in 0..packed.len() {
            assert!(decode_container(&packed[..cut]).is_err(), "prefix {cut} accepted");
        }
        // any single-bit flip in the body trips the checksum; flips in the
        // framing trip magic/version/length/json checks
        for i in 0..packed.len() {
            let mut p = packed.clone();
            p[i] ^= 0x10;
            match decode_container(&p) {
                Err(_) => {}
                Ok((h, b)) => {
                    // the only survivable flips are inside the JSON header
                    // text that still parse as JSON — body must be intact
                    assert_eq!(b, body, "flip at {i} corrupted the body silently");
                    let _ = h;
                }
            }
        }
    }

    #[test]
    fn wrong_magic_and_version_rejected() {
        let header = Json::obj(vec![]);
        let mut packed = encode_container(&header, &[1, 2, 3]);
        packed[0] ^= 0xff;
        assert!(decode_container(&packed).is_err());
        let mut packed2 = encode_container(&header, &[1, 2, 3]);
        packed2[8] = 0xee; // version byte
        assert!(decode_container(&packed2).is_err());
    }

    /// A v2 checkpoint (pre-wire-only Compressed packing) must be refused
    /// with an actionable message, not misparse into a v3 state.
    #[test]
    fn v2_container_rejected_with_actionable_message() {
        let header = Json::obj(vec![]);
        let mut packed = encode_container(&header, &[1, 2, 3]);
        packed[8..12].copy_from_slice(&2u32.to_le_bytes());
        let err = decode_container(&packed).unwrap_err().to_string();
        assert!(err.contains("version 2 not supported"), "got: {err}");
        assert!(err.contains("re-record"), "got: {err}");
    }

    #[test]
    fn fnv_is_stable() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn fnv_update_chains_like_one_pass() {
        let bytes: Vec<u8> = (0..4096u32).map(|i| (i % 253) as u8).collect();
        let whole = fnv1a64(&bytes);
        let mut h = fnv1a64(b"");
        for chunk in bytes.chunks(97) {
            h = fnv1a64_update(h, chunk);
        }
        assert_eq!(h, whole);
    }

    /// A byte sink the test can read back after the boxed writer is gone.
    #[derive(Clone, Default)]
    struct SharedBuf(std::rc::Rc<std::cell::RefCell<Vec<u8>>>);

    impl std::io::Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.borrow_mut().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    /// The spilling writer must emit exactly the bytes the buffered writer
    /// would — same stream, same length, same checksum — including across
    /// multiple spill chunks (the payload below crosses the 1 MiB
    /// threshold several times).
    #[test]
    fn spilling_writer_matches_buffered_byte_for_byte() {
        let emit = |w: &mut Writer| {
            w.put_str("header-ish");
            for i in 0..400_000u64 {
                w.put_u64(i.wrapping_mul(0x9e37_79b9_7f4a_7c15));
            }
            w.put_bytes(&[7u8; 1234]);
            w.put_bool(true);
        };
        let mut buffered = Writer::new();
        emit(&mut buffered);
        let reference = buffered.into_inner();
        assert!(reference.len() > 3 * SPILL_CHUNK, "payload must force spills");

        let sink = SharedBuf::default();
        let mut spilling = Writer::with_sink(Box::new(sink.clone()));
        emit(&mut spilling);
        assert_eq!(spilling.len(), reference.len());
        let (len, hash) = spilling.finish_stream().unwrap();
        assert_eq!(len as usize, reference.len());
        assert_eq!(hash, fnv1a64(&reference));
        assert_eq!(*sink.0.borrow(), reference);
    }

    /// An IO failure anywhere in the stream surfaces as `Err` from
    /// `finish_stream`, never as a silently short body.
    #[test]
    fn spilling_writer_reports_sink_errors_at_finish() {
        struct FailAfter(usize);
        impl std::io::Write for FailAfter {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                if self.0 < buf.len() {
                    return Err(std::io::Error::new(std::io::ErrorKind::Other, "disk full"));
                }
                self.0 -= buf.len();
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut w = Writer::with_sink(Box::new(FailAfter(SPILL_CHUNK / 2)));
        for i in 0..400_000u64 {
            w.put_u64(i);
        }
        let err = w.finish_stream().unwrap_err().to_string();
        assert!(err.contains("stream write failed"), "got: {err}");
    }
}
