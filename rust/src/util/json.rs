//! Minimal JSON: a recursive-descent parser + pretty writer.
//!
//! Used for `artifacts/manifest.json`, experiment configs and metric dumps.
//! Numbers are stored as `f64` with integer-exactness helpers (every integer
//! we care about — shapes, offsets, counts — is ≤ 2^53 and round-trips).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn expect(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= 2f64.powi(53) => {
                Some(*x as usize)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ---- writer ------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_number(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_number(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; emit null (metric curves may hit inf early).
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 2f64.powi(53) {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x:e}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not produced by us).
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // advance one UTF-8 char; a decode failure (or an
                    // empty tail on a malformed slice) is a parse error,
                    // never a panic — this parser also reads *foreign*
                    // files (snapshot headers, recorded timelines), not
                    // just our own output
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest
                        .chars()
                        .next()
                        .ok_or_else(|| self.err("unterminated string"))?;
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("name", Json::Str("qsgd".into())),
            ("bits", Json::Num(3.0)),
            ("series", Json::arr_f64(&[1.0, 0.5, 0.25])),
            ("flag", Json::Bool(false)),
        ]);
        for text in [v.to_string_pretty(), v.to_string_compact()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn usize_accessor_guards() {
        assert_eq!(Json::Num(5.0).as_usize(), Some(5));
        assert_eq!(Json::Num(5.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    /// Truncated escapes and malformed tails must yield `Err`, never a
    /// panic: the parser reads snapshot headers and recorded timelines,
    /// i.e. files that may be cut off mid-write.
    #[test]
    fn truncated_escapes_error_instead_of_panicking() {
        for s in [
            "\"abc\\",          // backslash at end of input
            "\"\\",             // nothing after the escape
            "\"\\u",            // \u with no digits
            "\"\\u12",          // \u with too few digits
            "\"\\u123",         // one digit short
            "\"\\uzzzz\"",      // non-hex digits
            "\"\\q\"",          // unknown escape
            "{\"k\": \"v\\",    // truncated escape nested in an object
            "[\"a\", \"b\\t",   // truncated string in an array
        ] {
            assert!(Json::parse(s).is_err(), "{s:?} should be a parse error");
        }
        // the happy escapes still work
        assert_eq!(Json::parse("\"\\u0041\\n\"").unwrap(), Json::Str("A\n".into()));
    }

    /// Byte-noise fuzz: arbitrary prefixes/mutations of valid documents
    /// must parse or error, never panic.
    #[test]
    fn garbage_inputs_never_panic() {
        let base = r#"{"a": [1, 2.5e-3, "x\ny", {"b": null}], "c": true}"#;
        for cut in 0..base.len() {
            if base.is_char_boundary(cut) {
                let _ = Json::parse(&base[..cut]);
            }
        }
        let mut rng = crate::util::rng::Pcg64::seed_from_u64(11);
        let bytes = base.as_bytes();
        for _ in 0..500 {
            let mut noisy = bytes.to_vec();
            let i = rng.gen_range(noisy.len());
            noisy[i] = (rng.next_u32() % 128) as u8; // keep it utf-8
            if let Ok(text) = std::str::from_utf8(&noisy) {
                let _ = Json::parse(text);
            }
        }
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"artifacts": {"q": {"file": "q.hlo.txt",
            "inputs": [{"name": "delta", "shape": [200], "dtype": "f64"}],
            "outputs": ["values"], "meta": {}}}}"#;
        let v = Json::parse(text).unwrap();
        let arts = v.get("artifacts").unwrap().as_obj().unwrap();
        let q = &arts["q"];
        assert_eq!(
            q.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()[0]
                .as_usize(),
            Some(200)
        );
    }
}
