//! Problem definitions: what each node optimizes locally and how the server
//! aggregates. Problems expose *pure numeric* updates; compression, error
//! feedback and scheduling live in [`crate::admm`].

pub mod accumulator;
pub mod lasso;
pub mod logreg;
pub mod mnist;
pub mod nn;

use crate::snapshot::codec::{Pack, Reader, Writer};
use crate::util::rng::Pcg64;

/// Contiguous n×m row-major storage for per-node vectors (one row per
/// node). The engines keep their true iterates (x, u) and the downlink
/// mirrors in arenas instead of `Vec<Vec<f64>>`: one allocation instead of
/// n, rows adjacent in memory for the per-round sweeps, and no per-node
/// boxing on the hot path.
#[derive(Clone, Debug)]
pub struct Arena {
    m: usize,
    data: Vec<f64>,
}

impl Arena {
    pub fn zeros(n: usize, m: usize) -> Self {
        Self { m, data: vec![0.0; n * m] }
    }

    /// n copies of one row (e.g. the shared x⁽⁰⁾).
    pub fn broadcast_row(row: &[f64], n: usize) -> Self {
        let mut a = Self::zeros(n, row.len());
        for i in 0..n {
            a.row_mut(i).copy_from_slice(row);
        }
        a
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let m = rows.first().map_or(0, Vec::len);
        Self::from_rows_iter(m, rows.iter().map(Vec::as_slice))
    }

    pub fn from_rows_iter<'a>(m: usize, rows: impl Iterator<Item = &'a [f64]>) -> Self {
        let mut data = Vec::new();
        for r in rows {
            assert_eq!(r.len(), m, "arena row length mismatch");
            data.extend_from_slice(r);
        }
        Self { m, data }
    }

    pub fn n_rows(&self) -> usize {
        if self.m == 0 {
            0
        } else {
            self.data.len() / self.m
        }
    }

    /// Row width M.
    pub fn dim(&self) -> usize {
        self.m
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.m..(i + 1) * self.m]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.m..(i + 1) * self.m]
    }

    pub fn rows(&self) -> std::slice::ChunksExact<'_, f64> {
        self.data.chunks_exact(self.m.max(1))
    }

    /// The whole n·m buffer (row-major).
    pub fn flat(&self) -> &[f64] {
        &self.data
    }
}

impl Pack for Arena {
    fn pack(&self, w: &mut Writer) {
        w.put_usize(self.m);
        self.data.pack(w);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let m = r.get_usize()?;
        let data = Vec::<f64>::unpack(r)?;
        if m == 0 {
            anyhow::ensure!(data.is_empty(), "snapshot arena: zero-width rows with data");
        } else {
            anyhow::ensure!(
                data.len() % m == 0,
                "snapshot arena: {} values do not tile rows of width {m}",
                data.len()
            );
        }
        Ok(Self { m, data })
    }
}

/// One node's inputs to a fanned-out local update (see
/// [`Problem::local_update_batch`]). Each item carries its *own* ẑ view:
/// with per-link downlink delays the nodes of one batch may hold
/// different mirrors of the server's consensus (a straggler computes
/// against an older ẑ than its fast neighbour). Per-node randomness comes
/// from the item's own forked RNG so results are independent of
/// worker-pool size and schedule.
pub struct LocalUpdateItem<'a> {
    pub node: usize,
    /// The node's current estimate of z (its downlink mirror).
    pub zhat: &'a [f64],
    pub u: &'a [f64],
    pub x_prev: &'a [f64],
    pub rng: &'a mut Pcg64,
}

/// Deterministic worker-pool fan-out shared by the native problem
/// families (LASSO exact solves, logistic-regression gradient loops):
/// chunk the batch across scoped threads, run `run_one` per item, merge
/// back in item order — bit-identical to a sequential loop for any pool
/// size. `run_one` must be pure math over per-node data (it gets a shared
/// item reference, so it cannot draw from the item's RNG; problems whose
/// update consumes randomness keep the sequential default).
pub fn fan_out_batch<F>(items: &[LocalUpdateItem<'_>], run_one: F) -> Vec<(Vec<f64>, f64)>
where
    F: Fn(&LocalUpdateItem<'_>) -> (Vec<f64>, f64) + Sync,
{
    // Size check first: fragmented downlink arrivals flush many single-item
    // batches, and available_parallelism() is an uncached syscall.
    if items.len() < 2 {
        return items.iter().map(&run_one).collect();
    }
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    if workers < 2 {
        return items.iter().map(&run_one).collect();
    }
    let chunk = items.len().div_ceil(workers.min(items.len()));
    let results: Vec<Vec<(Vec<f64>, f64)>> = std::thread::scope(|s| {
        let run = &run_one;
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|slice| s.spawn(move || slice.iter().map(run).collect()))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("batch worker panicked"))
            .collect()
    });
    results.into_iter().flatten().collect()
}

/// Metrics a problem can report at evaluation points.
#[derive(Clone, Copy, Debug)]
pub struct EvalMetrics {
    /// Eq. (19): |L − F*| / F* (convex problems; NaN for NN).
    pub accuracy: f64,
    /// Test-set classification accuracy in [0,1] (NN; NaN for LASSO).
    pub test_acc: f64,
    /// Objective value: augmented Lagrangian (LASSO) or test CE loss (NN).
    pub loss: f64,
}

/// A distributed consensus problem (eq. 2): N local objectives + a shared
/// regularizer handled by the server prox.
pub trait Problem {
    /// Dimension M of the consensus variable.
    fn dim(&self) -> usize;

    fn n_nodes(&self) -> usize;

    fn name(&self) -> String;

    /// Initial x⁽⁰⁾ (shared across nodes; NN uses He init, LASSO zeros).
    fn init_x(&mut self, rng: &mut Pcg64) -> Vec<f64>;

    /// Local primal update (eq. 9a): exact argmin or K inexact steps,
    /// starting from `x_prev`, against the node's estimate `zhat` of z and
    /// its dual `u`. Returns (x_new, local training loss).
    fn local_update(
        &mut self,
        node: usize,
        zhat: &[f64],
        u: &[f64],
        x_prev: &[f64],
        rng: &mut Pcg64,
    ) -> anyhow::Result<(Vec<f64>, f64)>;

    /// Fan-out of [`Self::local_update`] over a batch of nodes, each
    /// against its item's ẑ view. Results are returned in item order. The
    /// default runs sequentially; problems whose update is pure math (e.g.
    /// native LASSO, logistic regression) override this with
    /// [`fan_out_batch`] — results must be bit-identical to the sequential
    /// order regardless of pool size.
    fn local_update_batch(
        &mut self,
        items: &mut [LocalUpdateItem<'_>],
    ) -> anyhow::Result<Vec<(Vec<f64>, f64)>> {
        let mut out = Vec::with_capacity(items.len());
        for it in items.iter_mut() {
            out.push(self.local_update(it.node, it.zhat, it.u, it.x_prev, it.rng)?);
        }
        Ok(out)
    }

    /// Server consensus update (eq. 15) on the full estimate banks —
    /// O(n·m). This is the reference entry point (init exchange, tests,
    /// the HLO server-step artifact); the per-round hot path is
    /// [`Self::consensus_from_sum`] fed by an incrementally maintained sum
    /// ([`accumulator::ConsensusAccumulator`]).
    fn consensus(&mut self, xhat: &[Vec<f64>], uhat: &[Vec<f64>]) -> anyhow::Result<Vec<f64>>;

    /// Server consensus update from the precomputed running sum
    /// s = Σᵢ(x̂ᵢ + ûᵢ) over all `n_nodes` banks: z = prox_{h/(ρn)}(s/n),
    /// O(m). Must agree with [`Self::consensus`] whenever
    /// `s == Σᵢ(x̂ᵢ + ûᵢ)` coordinate-wise (the engines' property tests
    /// assert this up to the accumulator's ≤1e-10 drift bound).
    fn consensus_from_sum(&mut self, sum: &[f64], n_nodes: usize) -> anyhow::Result<Vec<f64>>;

    /// Metrics on the *true* iterates (eq. 19 uses x, z, u, not estimates),
    /// stored as n×m arenas (one row per node).
    fn evaluate(&mut self, x: &Arena, u: &Arena, z: &[f64]) -> anyhow::Result<EvalMetrics>;

    /// [`Self::evaluate`] restricted to a node subset (`--metrics-sample`):
    /// at n = 10^6 a full evaluation touches every node's data and
    /// dominates the run, so the engines hand in a small deterministic
    /// sample instead. Implementations should report the sampled objective
    /// rescaled to fleet magnitude (·n/k) so the curve stays comparable to
    /// a full evaluation; quantities that need the whole fleet (eq. 19's
    /// |L−F*|/F*) are NaN. The default ignores the sample and evaluates
    /// everything — correct for any problem, just not cheaper.
    fn evaluate_sample(
        &mut self,
        sample: &[usize],
        x: &Arena,
        u: &Arena,
        z: &[f64],
    ) -> anyhow::Result<EvalMetrics> {
        let _ = sample;
        self.evaluate(x, u, z)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_rows_round_trip() {
        let rows = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let mut a = Arena::from_rows(&rows);
        assert_eq!(a.n_rows(), 3);
        assert_eq!(a.dim(), 2);
        assert_eq!(a.row(1), &[3.0, 4.0]);
        a.row_mut(1)[0] = 9.0;
        assert_eq!(a.row(1), &[9.0, 4.0]);
        let collected: Vec<&[f64]> = a.rows().collect();
        assert_eq!(collected.len(), 3);
        assert_eq!(collected[2], &[5.0, 6.0]);
        assert_eq!(a.flat(), &[1.0, 2.0, 9.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn arena_broadcast_row() {
        let a = Arena::broadcast_row(&[7.0, 8.0], 3);
        assert_eq!(a.n_rows(), 3);
        for i in 0..3 {
            assert_eq!(a.row(i), &[7.0, 8.0]);
        }
    }

    #[test]
    fn fan_out_matches_sequential_order() {
        let mut rngs: Vec<Pcg64> = (0..7).map(|i| Pcg64::seed_from_u64(i)).collect();
        let z = vec![0.0; 4];
        let u = vec![0.0; 4];
        let x = vec![0.0; 4];
        let items: Vec<LocalUpdateItem<'_>> = rngs
            .iter_mut()
            .enumerate()
            .map(|(i, rng)| LocalUpdateItem { node: i, zhat: &z, u: &u, x_prev: &x, rng })
            .collect();
        let run = |it: &LocalUpdateItem<'_>| (vec![it.node as f64; 4], it.node as f64 * 2.0);
        let out = fan_out_batch(&items, run);
        let seq: Vec<(Vec<f64>, f64)> = items.iter().map(run).collect();
        assert_eq!(out, seq);
    }
}
