//! Proximal operators (native twins of the Pallas kernels).

/// Soft-thresholding: prox of κ‖·‖₁, elementwise
/// `S_κ(v) = sgn(v)·max(|v| − κ, 0)`.
pub fn soft_threshold(v: &[f64], kappa: f64) -> Vec<f64> {
    v.iter().map(|&x| soft_threshold_scalar(x, kappa)).collect()
}

#[inline]
pub fn soft_threshold_scalar(x: f64, kappa: f64) -> f64 {
    if x > kappa {
        x - kappa
    } else if x < -kappa {
        x + kappa
    } else {
        0.0
    }
}

pub fn soft_threshold_in_place(v: &mut [f64], kappa: f64) {
    for x in v {
        *x = soft_threshold_scalar(*x, kappa);
    }
}

/// L1 norm.
pub fn l1_norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x.abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_closed_form() {
        let v = vec![3.0, -3.0, 0.5, -0.5, 0.0];
        assert_eq!(soft_threshold(&v, 1.0), vec![2.0, -2.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn kappa_zero_is_identity() {
        let v = vec![1.5, -2.5, 0.0];
        assert_eq!(soft_threshold(&v, 0.0), v);
    }

    #[test]
    fn prox_optimality_conditions() {
        // z = S_κ(v) minimizes κ|z| + ½(z−v)²
        let v: Vec<f64> = (-20..20).map(|i| i as f64 * 0.17).collect();
        let kappa = 0.4;
        let z = soft_threshold(&v, kappa);
        for (zi, vi) in z.iter().zip(&v) {
            if *zi != 0.0 {
                assert!((zi - vi + kappa * zi.signum()).abs() < 1e-12);
            } else {
                assert!(vi.abs() <= kappa + 1e-12);
            }
        }
    }

    #[test]
    fn in_place_matches() {
        let v = vec![2.0, -0.1, 0.3];
        let mut w = v.clone();
        soft_threshold_in_place(&mut w, 0.25);
        assert_eq!(w, soft_threshold(&v, 0.25));
    }

    #[test]
    fn l1() {
        assert_eq!(l1_norm(&[1.0, -2.0, 3.0]), 6.0);
    }
}
