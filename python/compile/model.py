"""L2 compute graphs: one fused HLO per ADMM-iteration side.

Every function here is lowered once by aot.py to artifacts/*.hlo.txt and
executed from the rust coordinator via PJRT. The quantizer (L1 Pallas
kernel) is called *inside* these graphs so compression lowers into the same
HLO as the numeric update — one dispatch per node step / server step.

Conventions
-----------
* LASSO graphs are f64 (the paper's Fig. 3 tracks relative accuracy down to
  1e-10, below f32 resolution); NN graphs are f32.
* All stochasticity enters through explicit uniform-noise inputs.
* Scalars (ρ, θ, S, lr, t) are 0-d inputs so a single artifact serves
  parameter sweeps.
* The exact LASSO solve uses a precomputed M⁻¹ = (2AᵀA + ρI)⁻¹ (factorized
  once per node in rust): the per-iteration update is a single matmul, with
  no LAPACK custom-calls in the HLO (xla_extension 0.5.1 cannot load them).
"""

import jax
import jax.numpy as jnp

from compile import nn
from compile.kernels.quantize import quantize

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


# --------------------------------------------------------------------------
# LASSO (exact primal updates, §5.1)
# --------------------------------------------------------------------------

def lasso_node_step(minv, atb2, zhat, u, xhat, uhat, noise_x, noise_u, rho, s):
    """Node-side QADMM iteration (eqs. 9a, 9b, 10, 11 + C(Δ)).

    f_i(x) = ‖A_i x − b_i‖² so the exact primal update solves
        (2AᵀA + ρI) x = 2Aᵀb + ρ(ẑ − u)
    via the precomputed inverse `minv`; `atb2` = 2Aᵀb.

    Returns (x_new, u_new, cx_val, cx_lvl, cx_norm, cu_val, cu_lvl, cu_norm):
    the new local iterates plus the quantized deltas (dequantized values for
    the error-feedback estimate updates, signed levels + max-norm for the
    wire).
    """
    rhs = atb2 + rho * (zhat - u)
    x_new = minv @ rhs
    u_new = u + (x_new - zhat)
    dx = x_new - xhat  # current change + previous compression error (eq. 10)
    du = u_new - uhat  # (eq. 11)
    cx_val, cx_lvl, cx_norm = quantize(dx, noise_x, s)
    cu_val, cu_lvl, cu_norm = quantize(du, noise_u, s)
    return x_new, u_new, cx_val, cx_lvl, cx_norm, cu_val, cu_lvl, cu_norm


def lasso_lagrangian(x, u, z, ata, atb2, btb, theta, rho):
    """Augmented Lagrangian (eq. 3/4) for the metric (eq. 19), f64.

    x, u: [N, M] stacked true local iterates; ata: [N, M, M] Gram matrices;
    atb2: [N, M] (= 2Aᵀb); btb: [N] (= ‖b‖²).
    f_i(x) = xᵀ(AᵀA)x − (2Aᵀb)ᵀx + bᵀb, and with u = λ/ρ:
        L = Σf_i + θ‖z‖₁ + ρ/2 Σ‖x_i − z + u_i‖² − ρ/2 Σ‖u_i‖².
    """
    quad = jnp.einsum("nm,nmk,nk->n", x, ata, x)
    lin = jnp.einsum("nm,nm->n", atb2, x)
    f = jnp.sum(quad - lin + btb)
    h = theta * jnp.sum(jnp.abs(z))
    resid = x - z[None, :] + u
    penalty = 0.5 * rho * jnp.sum(resid * resid)
    return f + h + penalty - 0.5 * rho * jnp.sum(u * u)


# --------------------------------------------------------------------------
# Neural networks (inexact primal updates, §5.2)
# --------------------------------------------------------------------------

def _local_loss(forward, flat, bx, by, zhat, u, rho):
    """f_i estimate on one batch + the augmented proximal term of eq. (9a)."""
    logits = forward(flat, bx)
    data = nn.cross_entropy(logits, by)
    resid = flat - zhat + u
    return data + 0.5 * rho * jnp.sum(resid * resid)


def _adam_scan(forward, flat, m, v, t, u, zhat, bx, by, rho, lr):
    """K Adam steps (lax.scan) on the prox-augmented local loss.

    bx: [K, B, ...], by: [K, B]. Returns (flat', m', v', t', mean_loss).
    The scan fuses all K gradient steps into one HLO so PJRT dispatch
    overhead is paid once per ADMM iteration, not once per gradient step.
    """
    loss_grad = jax.value_and_grad(
        lambda p, x, y: _local_loss(forward, p, x, y, zhat, u, rho)
    )

    def body(carry, batch):
        p, m, v, t = carry
        x, y = batch
        loss, g = loss_grad(p, x, y)
        t = t + 1.0
        m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
        mhat = m / (1.0 - jnp.power(ADAM_B1, t))
        vhat = v / (1.0 - jnp.power(ADAM_B2, t))
        p = p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        return (p, m, v, t), loss

    (flat, m, v, t), losses = jax.lax.scan(body, (flat, m, v, t), (bx, by))
    return flat, m, v, t, jnp.mean(losses)


def make_nn_local_update(forward):
    """Node-side inexact QADMM iteration for a NN problem.

    Runs K Adam steps of eq. (9a), then the dual update (9b), then computes
    and quantizes both deltas (10)–(11). Adam moments persist across outer
    iterations (node-local state, never communicated).
    """

    def nn_local_update(flat, m, v, t, u, zhat, xhat, uhat, bx, by,
                        noise_x, noise_u, rho, lr, s):
        x_new, m, v, t, mean_loss = _adam_scan(
            forward, flat, m, v, t, u, zhat, bx, by, rho, lr
        )
        u_new = u + (x_new - zhat)
        dx = x_new - xhat
        du = u_new - uhat
        cx_val, cx_lvl, cx_norm = quantize(dx, noise_x, s)
        cu_val, cu_lvl, cu_norm = quantize(du, noise_u, s)
        return (x_new, m, v, t, u_new,
                cx_val, cx_lvl, cx_norm, cu_val, cu_lvl, cu_norm, mean_loss)

    return nn_local_update


def nn_server_step(xhat, uhat, zhat, noise_z, s):
    """Server consensus for NN (h ≡ 0 ⇒ plain average) + downlink C(Δz)."""
    v = jnp.mean(xhat + uhat, axis=0)
    z_new = v  # prox of h ≡ 0 is the identity
    dz = z_new - zhat
    cz_val, cz_lvl, cz_norm = quantize(dz, noise_z, s)
    return z_new, cz_val, cz_lvl, cz_norm


def make_nn_eval(forward):
    """Test-set evaluation: (correct-count, mean CE loss) over one batch."""

    def nn_eval(flat, x, y):
        logits = forward(flat, x)
        return nn.accuracy_count(logits, y), nn.cross_entropy(logits, y)

    return nn_eval


# Concrete variants bound to the two architectures.
cnn_local_update = make_nn_local_update(nn.cnn_forward)
cnn_eval = make_nn_eval(nn.cnn_forward)
mlp_local_update = make_nn_local_update(nn.mlp_forward)
mlp_eval = make_nn_eval(nn.mlp_forward)
