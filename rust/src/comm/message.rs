//! Message vocabulary between nodes and the server.
//!
//! Payloads are wire frames from [`crate::compress::wire`]; their byte
//! length *is* the accounted communication cost. Control fields (node id,
//! iteration) are charged as a fixed per-message header. Under a
//! hierarchical fan-in ([`crate::topology`]) the aggregator→server hop
//! reuses the `Update` frame shape — header + two compressed payloads
//! (the re-quantized partial-sum deltas) — charged to the aggregator's
//! own link; the child inclusion list it carries is control plane, like
//! the `Consensus` frame's, and is not charged.

/// Fixed header overhead charged per message (node id + iteration + kind),
/// matching what a compact real framing would carry.
pub const MSG_HEADER_BYTES: u64 = 12;

/// Bits charged per scalar of the full-precision initial exchange
/// (Algorithm 1 lines 1–8), the paper's stated rate ("e.g., 32-bits per
/// scalar"). Every runtime — sequential simulator, event engine, and the
/// threaded coordinator (which accounts via [`NodeToServer::wire_bits`] /
/// [`ServerToNode::wire_bits`]) — must charge the init exchange at this
/// one rate so their comm-bit curves start from the same offset.
pub const INIT_BITS_PER_SCALAR: u64 = 32;

#[derive(Clone, Debug)]
pub enum NodeToServer {
    /// Quantized (or dense, for the baseline) uplink: C(Δx), C(Δu).
    Update {
        node: usize,
        iter: u64,
        /// Monotone per-node sequence number for duplicate suppression.
        seq: u64,
        dx_wire: Vec<u8>,
        du_wire: Vec<u8>,
    },
    /// Full-precision initial exchange (Algorithm 1 lines 1–4).
    InitFull { node: usize, x0: Vec<f64>, u0: Vec<f64> },
    /// Event-trigger dead-band: the node computed but its EF-adjusted
    /// delta stayed within δ, so nothing ships. The arrival still counts
    /// toward the server's P/τ trigger (it resets the node's staleness),
    /// but eq. (20) charges nothing — in a real deployment this is the
    /// absence of a frame, observed by the server's arrival bookkeeping;
    /// the explicit message is an artifact of the channel transport.
    Skip {
        node: usize,
        /// Same monotone per-node sequence counter as `Update` (the dedup
        /// contract covers skipped dispatches too).
        seq: u64,
    },
    /// Acknowledgement of the `last`-flagged consensus broadcast: the node
    /// applied the final C(Δz) and is exiting. The server's drain-then-
    /// close shutdown waits for one ack per live node, so every frame a
    /// worker charged has landed (or provably never will) before the books
    /// are read — the old sleep-tail bound becomes exact equality.
    ShutdownAck { node: usize },
    /// The node's connection is gone (deploy transport: EOF or I/O error on
    /// its socket, synthesized by the server-side reader — a departing
    /// worker sends nothing). The server evicts the node from the live set
    /// so the P/τ trigger can never wedge on a dead peer.
    Leave { node: usize },
}

impl NodeToServer {
    /// Exact accounted size in bits.
    pub fn wire_bits(&self) -> u64 {
        match self {
            NodeToServer::Update { dx_wire, du_wire, .. } => {
                MSG_HEADER_BYTES * 8 + (dx_wire.len() + du_wire.len()) as u64 * 8
            }
            NodeToServer::InitFull { x0, u0, .. } => {
                MSG_HEADER_BYTES * 8 + (x0.len() + u0.len()) as u64 * INIT_BITS_PER_SCALAR
            }
            // a skipped dispatch is the *absence* of a transmission
            NodeToServer::Skip { .. } => 0,
            // control plane: a tiny fixed frame in the deploy transport,
            // tallied there as socket control bytes — eq. (20) counts data
            NodeToServer::ShutdownAck { .. } => 0,
            // synthesized server-side; nothing travels at all
            NodeToServer::Leave { .. } => 0,
        }
    }

    pub fn node(&self) -> usize {
        match self {
            NodeToServer::Update { node, .. }
            | NodeToServer::InitFull { node, .. }
            | NodeToServer::Skip { node, .. }
            | NodeToServer::ShutdownAck { node }
            | NodeToServer::Leave { node } => *node,
        }
    }
}

#[derive(Clone, Debug)]
pub enum ServerToNode {
    /// Quantized (or dense) downlink broadcast: C(Δz). `included` lists
    /// (ascending) the nodes whose updates were incorporated into this
    /// consensus — a node starts its next local update only once its
    /// previous one has landed (the per-node cadence of the paper's
    /// Fig. 2; at most one update in flight per node). A sparse id set
    /// instead of a u64 bitmask, so deployments are not capped at 64
    /// nodes. The list is control plane and *not* charged by
    /// [`Self::wire_bits`] — eq. (20) counts data, and the in-process
    /// engines (which need no inclusion frame at all) price the broadcast
    /// as header + payload.
    Consensus {
        iter: u64,
        included: Vec<u32>,
        dz_wire: Vec<u8>,
        /// Set on the final round's broadcast: apply the delta, ack with
        /// [`NodeToServer::ShutdownAck`], and exit — do **not** start
        /// another local update. One flag bit rides in the charged header;
        /// it replaces the old post-loop `Shutdown` broadcast + sleepy
        /// drain (the shutdown race PR 3 could only bound, not close).
        last: bool,
    },
    /// Full-precision initial consensus (Algorithm 1 line 8).
    InitZ { z0: Vec<f64> },
    /// Orderly shutdown of a node worker.
    Shutdown,
}

impl ServerToNode {
    /// Exact accounted size in bits. Eq. (20) counts *data* on the wire:
    /// the `Consensus` frame is priced as header + C(Δz) payload — the
    /// sparse inclusion list is control-plane overhead and is **not**
    /// charged, matching how the sequential simulator and the event engine
    /// price the broadcast (the seed charged 4 + 4·|included| extra bytes
    /// per link per round only in the threaded runtime, skewing every
    /// cross-runtime bits-to-target comparison; see
    /// `tests/accounting_parity.rs` for the steady-state contract).
    pub fn wire_bits(&self) -> u64 {
        match self {
            ServerToNode::Consensus { dz_wire, .. } => {
                MSG_HEADER_BYTES * 8 + dz_wire.len() as u64 * 8
            }
            ServerToNode::InitZ { z0 } => {
                MSG_HEADER_BYTES * 8 + z0.len() as u64 * INIT_BITS_PER_SCALAR
            }
            ServerToNode::Shutdown => MSG_HEADER_BYTES * 8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_bits_count_both_payloads() {
        let m = NodeToServer::Update {
            node: 0,
            iter: 1,
            seq: 0,
            dx_wire: vec![0u8; 10],
            du_wire: vec![0u8; 14],
        };
        assert_eq!(m.wire_bits(), (12 + 24) * 8);
    }

    #[test]
    fn init_charged_at_the_papers_32_bit_rate() {
        let m = NodeToServer::InitFull { node: 2, x0: vec![0.0; 5], u0: vec![0.0; 5] };
        assert_eq!(m.wire_bits(), 12 * 8 + 10 * INIT_BITS_PER_SCALAR);
        assert_eq!(m.wire_bits(), 12 * 8 + 10 * 32);
        assert_eq!(m.node(), 2);
        let z = ServerToNode::InitZ { z0: vec![0.0; 7] };
        assert_eq!(z.wire_bits(), 12 * 8 + 7 * 32);
    }

    #[test]
    fn downlink_bits() {
        let m = ServerToNode::Consensus {
            iter: 3,
            included: vec![0, 2],
            dz_wire: vec![0u8; 100],
            last: false,
        };
        // header + payload only: eq. (20) does not count the inclusion list
        assert_eq!(m.wire_bits(), (12 + 100) * 8);
        assert_eq!(ServerToNode::Shutdown.wire_bits(), 96);
    }

    /// Control traffic is never data: the shutdown ack and the synthesized
    /// leave both price at 0 (the deploy transport tallies their real
    /// socket bytes separately, outside eq. 20).
    #[test]
    fn control_frames_charge_nothing() {
        assert_eq!(NodeToServer::ShutdownAck { node: 3 }.wire_bits(), 0);
        assert_eq!(NodeToServer::ShutdownAck { node: 3 }.node(), 3);
        assert_eq!(NodeToServer::Leave { node: 5 }.wire_bits(), 0);
        assert_eq!(NodeToServer::Leave { node: 5 }.node(), 5);
    }

    /// The last-round flag must not change the charged size — it rides in
    /// the fixed header, like the iteration counter.
    #[test]
    fn last_flag_is_free() {
        let frame = |last| ServerToNode::Consensus {
            iter: 9,
            included: vec![1],
            dz_wire: vec![0; 32],
            last,
        };
        let (base, last) = (frame(false), frame(true));
        assert_eq!(base.wire_bits(), last.wire_bits());
    }

    /// A skipped dispatch is the absence of a frame: zero bits, whatever
    /// the fleet or dimension — the event trigger's entire savings rest on
    /// this being exactly 0, not a header charge.
    #[test]
    fn skip_charges_nothing() {
        let m = NodeToServer::Skip { node: 7, seq: 42 };
        assert_eq!(m.wire_bits(), 0);
        assert_eq!(m.node(), 7);
    }

    /// The inclusion list is control plane: its length must not change the
    /// accounted cost (the sim/event engines never see it at all), so the
    /// pricing is identical across all three runtimes at any fleet size.
    #[test]
    fn inclusion_list_is_not_charged() {
        let small = ServerToNode::Consensus {
            iter: 0,
            included: vec![],
            dz_wire: vec![0; 64],
            last: false,
        };
        let large = ServerToNode::Consensus {
            iter: 0,
            included: (0..1000).collect(),
            dz_wire: vec![0; 64],
            last: true,
        };
        assert_eq!(small.wire_bits(), large.wire_bits());
        assert_eq!(small.wire_bits(), (12 + 64) * 8);
    }
}
