//! Incremental server consensus state: the running sum s = Σᵢ(x̂ᵢ + ûᵢ).
//!
//! The paper's server (Algorithm 1 lines 27–43) recomputes the consensus
//! input v = mean(x̂ + û) from every node's estimate bank on every round,
//! an O(n·m) sweep even though only P ≤ n nodes arrived. But the banks
//! evolve *only* by dequantized deltas: `MsgArrive` commits x̂ᵢ += C(Δxᵢ),
//! ûᵢ += C(Δuᵢ) and nothing else ever touches them. So the server can
//! carry s across rounds and fold each arrival in as
//!
//! ```text
//!     s ← s + C(Δxᵢ) + C(Δuᵢ)          (O(m) per arrival)
//! ```
//!
//! after which one fire is `z = prox(s/n)` — O(m) total via
//! [`crate::problems::Problem::consensus_from_sum`] — instead of O(n·m).
//! At n = 1024, m = 10240 that turns a ~160 MB bank sweep per round into a
//! few hundred KB of arrival folds.
//!
//! # Floating-point drift and the two defenses
//!
//! The incremental s is *not* bitwise the recomputed Σ(x̂ᵢ + ûᵢ): addition
//! is non-associative, and after many folds the rounding errors of the two
//! evaluation orders diverge. Two mechanisms keep the gap far below the
//! quantization noise the algorithm already tolerates:
//!
//! * **Kahan compensation on every fold** ([`ConsensusAccumulator::fold`]):
//!   each coordinate keeps a running compensation term, so the error of the
//!   incremental sum stays O(ε)·Σ|δ| instead of growing with the number of
//!   folds. The property suite (`tests/prop.rs`) drives 10k folds without
//!   refresh and bounds the gap at ≤ 1e-10 relative.
//! * **Periodic full recompute** ([`ConsensusAccumulator::refresh`], every
//!   `refresh_every` rounds, default on — see
//!   [`crate::config::ExperimentConfig::consensus_refresh_every`]): the sum
//!   and its compensation are rebuilt from the banks in node order, washing
//!   out whatever drift accumulated. This is the only remaining O(n·m)
//!   server work, amortized to O(n·m / K) per round; `refresh_every = 0`
//!   disables it entirely (the Kahan bound still holds).
//!
//! # Determinism contract
//!
//! The sequential simulator and the event engine share this type and fold
//! in the same order at zero latency (ascending node id within a virtual
//! instant), so the `tests/engine_parity.rs` bit-identity contract holds
//! through the incremental path: same folds, same refresh rounds, same
//! bits. The threaded coordinator folds in real arrival order — no bitwise
//! claim there, only the ≤1e-10 drift bound.

/// Running Kahan-compensated Σᵢ(x̂ᵢ + ûᵢ) with a periodic full-recompute
/// refresh. See the module docs for fold/finalize/refresh semantics.
#[derive(Clone, Debug)]
pub struct ConsensusAccumulator {
    /// s[j] = Σᵢ(x̂ᵢ[j] + ûᵢ[j]), maintained incrementally.
    sum: Vec<f64>,
    /// Per-coordinate Kahan compensation (the low-order bits the last
    /// additions lost).
    comp: Vec<f64>,
    /// Full recompute cadence in consensus rounds (0 = never).
    refresh_every: usize,
}

impl ConsensusAccumulator {
    pub fn new(m: usize, refresh_every: usize) -> Self {
        Self { sum: vec![0.0; m], comp: vec![0.0; m], refresh_every }
    }

    pub fn dim(&self) -> usize {
        self.sum.len()
    }

    /// The current running sum s (pass to
    /// [`crate::problems::Problem::consensus_from_sum`]).
    pub fn sum(&self) -> &[f64] {
        &self.sum
    }

    #[inline]
    fn kahan_add(sum: &mut f64, comp: &mut f64, v: f64) {
        let y = v - *comp;
        let t = *sum + y;
        *comp = (t - *sum) - y;
        *sum = t;
    }

    /// Fold one arrival's dequantized deltas: s += C(Δx) + C(Δu), O(m).
    /// Must be called with exactly the vectors committed into the estimate
    /// banks (the [`crate::compress::Compressed::dequantized`] payloads) so
    /// that s keeps tracking Σᵢ(x̂ᵢ + ûᵢ).
    pub fn fold(&mut self, dx: &[f64], du: &[f64]) {
        debug_assert_eq!(dx.len(), self.sum.len());
        debug_assert_eq!(du.len(), self.sum.len());
        for (j, (s, c)) in self.sum.iter_mut().zip(self.comp.iter_mut()).enumerate() {
            Self::kahan_add(s, c, dx[j]);
            Self::kahan_add(s, c, du[j]);
        }
    }

    /// True when the round about to fire (1-based) is a refresh round. Both
    /// in-process engines call this with their shared round counter, so at
    /// parity they refresh on identical rounds.
    pub fn refresh_due(&self, round: usize) -> bool {
        self.refresh_every > 0 && round % self.refresh_every == 0
    }

    /// Full recompute from the estimate banks, in iteration order, resetting
    /// the compensation: the O(n·m) drift wash-out. `rows` yields each
    /// node's (x̂ᵢ, ûᵢ) estimate slices.
    pub fn refresh<'b>(&mut self, rows: impl Iterator<Item = (&'b [f64], &'b [f64])>) {
        self.sum.iter_mut().for_each(|v| *v = 0.0);
        self.comp.iter_mut().for_each(|v| *v = 0.0);
        for (x, u) in rows {
            self.fold(x, u);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn fold_tracks_plain_sum_on_small_inputs() {
        let mut acc = ConsensusAccumulator::new(3, 0);
        acc.fold(&[1.0, 2.0, 3.0], &[0.5, 0.5, 0.5]);
        acc.fold(&[-1.0, 0.0, 1.0], &[0.0, 0.0, 0.0]);
        assert_eq!(acc.sum(), &[0.5, 2.5, 4.5]);
    }

    #[test]
    fn refresh_matches_direct_fold_from_zero() {
        let mut rng = Pcg64::seed_from_u64(3);
        let m = 17;
        let xs: Vec<Vec<f64>> = (0..5).map(|_| rng.normal_vec(m, 0.0, 1.0)).collect();
        let us: Vec<Vec<f64>> = (0..5).map(|_| rng.normal_vec(m, 0.0, 1.0)).collect();
        let mut a = ConsensusAccumulator::new(m, 4);
        a.refresh(xs.iter().zip(&us).map(|(x, u)| (x.as_slice(), u.as_slice())));
        let mut b = ConsensusAccumulator::new(m, 4);
        for (x, u) in xs.iter().zip(&us) {
            b.fold(x, u);
        }
        assert_eq!(a.sum(), b.sum());
    }

    #[test]
    fn refresh_cadence() {
        let acc = ConsensusAccumulator::new(1, 5);
        assert!(!acc.refresh_due(1));
        assert!(!acc.refresh_due(4));
        assert!(acc.refresh_due(5));
        assert!(acc.refresh_due(10));
        let never = ConsensusAccumulator::new(1, 0);
        for r in 1..100 {
            assert!(!never.refresh_due(r));
        }
    }

    /// Kahan beats naive summation on an adversarial magnitude mix.
    #[test]
    fn kahan_compensates_magnitude_spread() {
        let m = 1;
        let mut acc = ConsensusAccumulator::new(m, 0);
        let mut naive = 0.0f64;
        let big = 1e14;
        acc.fold(&[big], &[0.0]);
        naive += big;
        for _ in 0..10_000 {
            acc.fold(&[0.1], &[0.0]);
            naive += 0.1;
        }
        acc.fold(&[-big], &[0.0]);
        naive += -big;
        let exact = 1000.0;
        let kahan_err = (acc.sum()[0] - exact).abs();
        let naive_err = (naive - exact).abs();
        assert!(kahan_err <= 1e-9, "kahan err {kahan_err}");
        assert!(naive_err > kahan_err, "naive {naive_err} vs kahan {kahan_err}");
    }
}
