//! Real multi-process deployment: the existing wire frames on an actual
//! socket. `qadmm serve` runs the unchanged [`crate::coordinator::server`]
//! fold path behind a TCP or Unix-domain listener; `qadmm worker` is the
//! node side. This is the runtime that makes [`CommAccounting`]
//! **falsifiable**: every byte that crosses a socket is tallied per link
//! and direction in [`LinkBytes`], and [`reconcile`] proves the charged
//! eq. (20) bits equal the socket counters exactly, after subtracting the
//! closed-form framing extras of [`Frame::socket_extra_bytes`]
//! (handshake/init/control frames — steady-state data frames have zero
//! overhead by construction).
//!
//! [`CommAccounting`]: crate::comm::accounting::CommAccounting
//! [`Frame::socket_extra_bytes`]: frame::Frame::socket_extra_bytes

pub mod frame;
pub mod server;
pub mod transport;
pub mod worker;

use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use crate::comm::accounting::CommAccounting;

/// Per-link socket byte counters, split by direction, plus the running sum
/// of per-frame framing extras (bytes on the socket that eq. 20 does not
/// charge: handshake, init-rate difference, control frames).
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkBytes {
    /// Total bytes read off this node's socket (all uplink frames).
    pub up_total: u64,
    /// Total bytes written to this node's socket (all downlink frames).
    pub down_total: u64,
    /// Σ socket_extra_bytes over uplink frames.
    pub up_extra: u64,
    /// Σ socket_extra_bytes over downlink frames.
    pub down_extra: u64,
}

/// Shared per-link books: index = node id. The reactor shards tally both
/// directions into plain per-connection `u64`s — uplink when a complete
/// frame decodes, downlink when a frame's last byte reaches the kernel —
/// and fold them here once per poll batch and definitively on detach.
/// Those are the same points where the eq. (20) charge is recorded, so
/// the two ledgers describe the identical set of frames: partial frames
/// (read or write) at eviction time appear on **neither** ledger.
pub type Books = Arc<Mutex<Vec<LinkBytes>>>;

pub fn new_books(n: usize) -> Books {
    Arc::new(Mutex::new(vec![LinkBytes::default(); n]))
}

/// The falsifiability check: for every link and both directions,
///
/// ```text
///   socket_bytes == charged_bits / 8 + framing_extras      (exactly)
/// ```
///
/// No tolerance band — the framing extras are closed-form per frame, so
/// any drift (a dropped charge, a double-count, a frame that moved bytes
/// off the books) is a hard error naming the link.
pub fn reconcile(books: &[LinkBytes], acc: &CommAccounting) -> Result<()> {
    for (node, b) in books.iter().enumerate() {
        let link = acc.link(node);
        ensure!(
            b.up_total == link.uplink_bits / 8 + b.up_extra,
            "uplink mismatch on link {node}: socket {} != charged {} + extras {}",
            b.up_total,
            link.uplink_bits / 8,
            b.up_extra
        );
        ensure!(
            b.down_total == link.downlink_bits / 8 + b.down_extra,
            "downlink mismatch on link {node}: socket {} != charged {} + extras {}",
            b.down_total,
            link.downlink_bits / 8,
            b.down_extra
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reconcile_flags_any_drift() {
        let mut acc = CommAccounting::new(2);
        acc.record_uplink(0, 100 * 8);
        acc.record_downlink(1, 40 * 8);
        let mut books = vec![LinkBytes::default(); 2];
        books[0].up_total = 107;
        books[0].up_extra = 7;
        books[1].down_total = 45;
        books[1].down_extra = 5;
        assert!(reconcile(&books, &acc).is_ok());
        // one stray byte on the socket that nobody charged
        books[0].up_total += 1;
        let err = reconcile(&books, &acc).unwrap_err();
        assert!(err.to_string().contains("link 0"), "{err}");
    }
}
