//! Topology sweep at event-engine scale: convergence-per-bit of the star
//! fan-in against 2-tier trees and randomized gossip relays.
//!
//! The question an edge-aggregator deployment asks: the tree pays an extra
//! re-quantized hop per update (more wire bits per arrival, more staleness
//! per round trip) but its aggregators batch `P_g` children into *one*
//! upstream frame — so how do total bits to a fixed accuracy compare? The
//! grid crosses topology ∈ {star, tree, gossip} at n ∈ {256, 1024} under
//! compute/uplink stragglers — sizes only the virtual-time engine can
//! sweep (a threaded run would sleep through every injected delay).
//!
//! Invoke with `qadmm topology [--iters N] [--trials N] [--quick]`.

use crate::admm::runner::{self, ProblemFactory};
use crate::comm::latency::LatencyModel;
use crate::comm::profile::LinkConfig;
use crate::compress::CompressorKind;
use crate::config::{presets, EngineKind, ExperimentConfig, OracleConfig, ProblemKind};
use crate::metrics::summary;
use crate::problems::lasso::{LassoConfig, LassoProblem};
use crate::problems::Problem;
use crate::topology::TopologyKind;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct TopologyRow {
    pub label: String,
    pub n: usize,
    pub topology: String,
    pub final_accuracy: f64,
    pub bits_to_target: Option<f64>,
    pub total_bits: f64,
}

impl TopologyRow {
    pub fn render(&self) -> String {
        format!(
            "{:40} final_acc {:>10.3e}  bits@target {:>12}  total_bits/param {:>12.1}",
            self.label,
            self.final_accuracy,
            self.bits_to_target
                .map(|b| format!("{b:.1}"))
                .unwrap_or_else(|| "n/a".into()),
            self.total_bits
        )
    }
}

pub struct TopologySweepOptions {
    pub iters: usize,
    pub mc_trials: usize,
    pub target: f64,
    /// Restrict to n = 256 (CI / smoke); the full grid adds n = 1024.
    pub quick: bool,
}

impl Default for TopologySweepOptions {
    fn default() -> Self {
        Self { iters: 120, mc_trials: 2, target: 1e-6, quick: false }
    }
}

/// (topology, P_g) grid points for an n-leaf fleet: a wide and a narrow
/// 2-tier tree plus a gossip relay ring, each batching half its expected
/// fan-in per forward.
fn grid_points(n: usize) -> Vec<(TopologyKind, usize)> {
    let wide = (n / 16).max(2);
    let narrow = (n / 64).max(2);
    vec![
        (TopologyKind::Star, 1),
        (TopologyKind::Tree { fanout: wide }, (wide / 2).max(1)),
        (TopologyKind::Tree { fanout: narrow }, (narrow / 2).max(1)),
        (TopologyKind::Gossip { k: n.div_ceil(wide) }, (wide / 2).max(1)),
    ]
}

fn sweep_cfg(
    n: usize,
    topology: TopologyKind,
    p_tier: usize,
    opts: &TopologySweepOptions,
) -> ExperimentConfig {
    let mut cfg = presets::ci_lasso();
    // Fig. 3 parameters scaled out to engine-size populations (Woodbury
    // keeps h ≪ m cheap), same base grid as the downlink sweep so rows are
    // comparable across the two experiments.
    cfg.name = format!("topology-{}-n{n}", topology.label().replace(':', ""));
    cfg.problem = ProblemKind::Lasso { m: 256, h: 8, n, rho: 500.0, theta: 0.1 };
    cfg.compressor = CompressorKind::Qsgd { bits: 3 };
    cfg.engine = EngineKind::Event;
    cfg.tau = 4;
    cfg.p_min = (n / 4).max(1);
    cfg.iters = opts.iters;
    cfg.mc_trials = opts.mc_trials;
    cfg.eval_every = 1;
    cfg.oracle = OracleConfig { p_slow: 0.1, p_fast: 0.8, regroup_each_call: false };
    // stragglers on compute + the leaf hop: the regime where aggregator
    // batching has something to batch
    cfg.link = LinkConfig {
        compute: LatencyModel::Exp(0.01),
        uplink: LatencyModel::Exp(0.01),
        downlink: LatencyModel::None,
        clock_drift: 0.05,
    };
    cfg.topology = topology;
    cfg.p_tier = p_tier;
    cfg
}

fn run_one(cfg: &ExperimentConfig, opts: &TopologySweepOptions) -> anyhow::Result<McRow> {
    let lcfg = match cfg.problem {
        ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
        _ => unreachable!(),
    };
    let mut factory: Box<ProblemFactory> = Box::new(move |_seed, data_rng: &mut Pcg64| {
        let mut p = LassoProblem::generate(lcfg, data_rng)?;
        if lcfg.n >= 1024 {
            // F* via thousands of FISTA rounds dominates at this size; the
            // sweep compares *relative* trajectories, so a fixed reference
            // keeps the accuracy metric monotone-comparable.
            p.set_reference_optimum(1.0);
        }
        Ok(Box::new(p) as Box<dyn Problem>)
    });
    let res = runner::run_mc(cfg, factory.as_mut())?;
    drop(factory);
    let rec = res.mean_recorder();
    Ok(McRow {
        final_accuracy: *res.mean_accuracy.last().unwrap(),
        bits_to_target: summary::bits_to_accuracy(&rec.records, opts.target),
        total_bits: *res.mean_comm_bits.last().unwrap(),
    })
}

struct McRow {
    final_accuracy: f64,
    bits_to_target: Option<f64>,
    total_bits: f64,
}

/// Run the topology grid, printing one table per node count.
pub fn run(opts: &TopologySweepOptions) -> anyhow::Result<Vec<TopologyRow>> {
    let sizes: &[usize] = if opts.quick { &[256] } else { &[256, 1024] };
    let mut all = Vec::new();
    for &n in sizes {
        println!("--- topology sweep: n = {n} (star vs tree vs gossip) ---");
        for (topology, p_tier) in grid_points(n) {
            let cfg = sweep_cfg(n, topology, p_tier, opts);
            let r = run_one(&cfg, opts)?;
            let row = TopologyRow {
                label: format!("n={n} topology={} p_tier={p_tier}", topology.label()),
                n,
                topology: topology.label(),
                final_accuracy: r.final_accuracy,
                bits_to_target: r.bits_to_target,
                total_bits: r.total_bits,
            };
            println!("{}", row.render());
            all.push(row);
        }
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One tiny grid point per topology family end-to-end: the sweep config
    /// validates and a delayed tree/gossip event run completes with a sane
    /// accuracy series and nonzero aggregator traffic.
    #[test]
    fn tiny_grid_points_run() {
        let opts =
            TopologySweepOptions { iters: 8, mc_trials: 1, target: 1e-6, quick: true };
        for (topology, p_tier) in [
            (TopologyKind::Tree { fanout: 3 }, 2),
            (TopologyKind::Gossip { k: 3 }, 1),
        ] {
            let mut cfg = sweep_cfg(8, topology, p_tier, &opts);
            cfg.problem = ProblemKind::Lasso { m: 16, h: 6, n: 8, rho: 50.0, theta: 0.1 };
            cfg.validate().unwrap();
            let r = run_one(&cfg, &opts).unwrap();
            assert!(r.final_accuracy.is_finite());
            assert!(r.total_bits > 0.0);
        }
    }

    #[test]
    fn grid_includes_all_families() {
        let kinds: Vec<String> = grid_points(256).iter().map(|(t, _)| t.label()).collect();
        assert!(kinds.iter().any(|l| l == "star"));
        assert!(kinds.iter().filter(|l| l.starts_with("tree:")).count() >= 2);
        assert!(kinds.iter().any(|l| l.starts_with("gossip:")));
        for (t, p) in grid_points(1024) {
            t.validate(1024).unwrap();
            assert!(p >= 1);
        }
    }
}
