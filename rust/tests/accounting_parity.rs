//! Cross-runtime accounting parity: the init exchange *and* steady-state
//! rounds.
//!
//! The threaded coordinator charges messages through
//! `NodeToServer::wire_bits` / `ServerToNode::wire_bits`, while the
//! sequential simulator and the event engine charge with explicit
//! formulas. All three must agree on the paper's 32-bits-per-scalar init
//! rate ([`qadmm::comm::message::INIT_BITS_PER_SCALAR`]) — or their
//! comm-bit curves start from different offsets — *and* on the
//! steady-state per-round pricing (header + payload for every frame; the
//! `Consensus` inclusion list is control plane and not charged) — or every
//! bits-to-target comparison across runtimes is skewed. (The seed charged
//! 64 bits/scalar in the message layer and 32 in the engines, and charged
//! the inclusion list only in the threaded runtime.)

use qadmm::admm::engine::EventEngine;
use qadmm::admm::sim::{AsyncSim, TrialRngs};
use qadmm::comm::message::{
    NodeToServer, ServerToNode, INIT_BITS_PER_SCALAR, MSG_HEADER_BYTES,
};
use qadmm::comm::network::FaultSpec;
use qadmm::compress::{Compressor, CompressorKind};
use qadmm::config::{presets, ExperimentConfig, ProblemKind};
use qadmm::problems::lasso::{LassoConfig, LassoProblem};
use qadmm::util::rng::Pcg64;

fn cfg_and_lasso() -> (ExperimentConfig, LassoConfig) {
    let mut cfg = presets::ci_lasso();
    cfg.compressor = CompressorKind::Identity;
    let l = match cfg.problem {
        ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
        _ => unreachable!(),
    };
    (cfg, l)
}

/// The exact bits the threaded runtime would charge for one node's init
/// exchange, derived from the message types themselves.
fn threaded_init_bits_per_node(m: usize) -> u64 {
    let up = NodeToServer::InitFull { node: 0, x0: vec![0.0; m], u0: vec![0.0; m] };
    let down = ServerToNode::InitZ { z0: vec![0.0; m] };
    up.wire_bits() + down.wire_bits()
}

/// Before any round fires, the simulator's and the event engine's books
/// must equal n × (InitFull + InitZ) *as priced by the message layer* —
/// the same pricing the threaded endpoints apply on send.
#[test]
fn init_exchange_offset_is_identical_across_runtimes() {
    let (cfg, l) = cfg_and_lasso();
    let per_node = threaded_init_bits_per_node(l.m);
    // the message layer charges the paper's 32-bit init rate
    assert_eq!(
        per_node,
        2 * (MSG_HEADER_BYTES * 8) + 3 * l.m as u64 * INIT_BITS_PER_SCALAR
    );
    assert_eq!(INIT_BITS_PER_SCALAR, 32);
    let expect = l.n as u64 * per_node;

    let mut rngs = TrialRngs::new(cfg.seed);
    let mut p = LassoProblem::generate(l, &mut rngs.data).unwrap();
    let sim = AsyncSim::new(&cfg, &mut p, rngs).unwrap();
    assert_eq!(sim.accounting().total_bits(), expect, "simulator init offset");

    let mut rngs = TrialRngs::new(cfg.seed);
    let mut p = LassoProblem::generate(l, &mut rngs.data).unwrap();
    let eng = EventEngine::new(&cfg, &mut p, rngs).unwrap();
    assert_eq!(eng.accounting().total_bits(), expect, "event engine init offset");
}

/// Steady-state rounds must be priced identically by all three runtimes.
/// Lockstep configuration (τ = 1, P = n) makes the message *counts*
/// deterministic even under real threads: every round is exactly n uplink
/// updates + n broadcast links, and the identity compressor's frame size
/// is value-independent. The totals are tied to the message-layer pricing
/// (the same `wire_bits` the threaded endpoints charge on send), so a
/// pricing skew in any runtime — like the seed's inclusion-list charge —
/// breaks this test.
#[test]
fn steady_state_rounds_price_identically_across_runtimes() {
    let (mut cfg, l) = cfg_and_lasso();
    let rounds = 8usize;
    cfg.tau = 1; // synchronous: every node forced every round
    cfg.p_min = l.n;
    cfg.iters = rounds;
    cfg.mc_trials = 1;
    cfg.eval_every = rounds;

    // message-layer pricing for one steady-state round
    let frame = CompressorKind::Identity
        .build()
        .compress(&vec![0.0; l.m], &mut Pcg64::seed_from_u64(0))
        .wire;
    let update_bits = NodeToServer::Update {
        node: 0,
        iter: 0,
        seq: 0,
        dx_wire: frame.clone(),
        du_wire: frame.clone(),
    }
    .wire_bits();
    let consensus_bits =
        ServerToNode::Consensus {
            iter: 0,
            included: (0..l.n as u32).collect(),
            dz_wire: frame,
            last: false,
        }
        .wire_bits();
    let init_per_node = threaded_init_bits_per_node(l.m);
    let expect = l.n as u64 * init_per_node
        + rounds as u64 * l.n as u64 * (update_bits + consensus_bits);

    // sequential simulator
    let mut rngs = TrialRngs::new(cfg.seed);
    let mut p = LassoProblem::generate(l, &mut rngs.data).unwrap();
    p.set_reference_optimum(1.0);
    let mut sim = AsyncSim::new(&cfg, &mut p, rngs).unwrap();
    for _ in 0..rounds {
        sim.step().unwrap();
    }
    assert_eq!(sim.accounting().total_bits(), expect, "simulator steady state");

    // event engine (zero latency: rounds coincide with iterations)
    let mut rngs = TrialRngs::new(cfg.seed);
    let mut p = LassoProblem::generate(l, &mut rngs.data).unwrap();
    p.set_reference_optimum(1.0);
    let mut eng = EventEngine::new(&cfg, &mut p, rngs).unwrap();
    for _ in 0..rounds {
        eng.step_round().unwrap();
    }
    assert_eq!(eng.accounting().total_bits(), expect, "event engine steady state");

    // threaded deployment: with the drain-then-close shutdown (the final
    // broadcast carries `last`, workers ack instead of computing) BOTH
    // directions are fully deterministic — the old 0..=n shutdown-race
    // updates cannot exist, so the bound is equality, same as the
    // in-process engines. Shutdown acks are control plane and charge 0.
    let mut rngs = TrialRngs::new(cfg.seed);
    let mut p = LassoProblem::generate(l, &mut rngs.data).unwrap();
    p.set_reference_optimum(1.0);
    let outcome =
        qadmm::coordinator::run_threaded(&cfg, Box::new(p), FaultSpec::default()).unwrap();
    let init_up = NodeToServer::InitFull { node: 0, x0: vec![0.0; l.m], u0: vec![0.0; l.m] }
        .wire_bits();
    let init_down = ServerToNode::InitZ { z0: vec![0.0; l.m] }.wire_bits();
    let expect_down = l.n as u64 * init_down + rounds as u64 * l.n as u64 * consensus_bits;
    assert_eq!(outcome.downlink_bits, expect_down, "threaded downlink steady state");
    let expect_up = l.n as u64 * init_up + rounds as u64 * l.n as u64 * update_bits;
    assert_eq!(outcome.uplink_bits, expect_up, "threaded uplink steady state");
    assert_eq!(
        outcome.uplink_bits + outcome.downlink_bits,
        expect,
        "threaded total equals the in-process engines exactly"
    );
}

/// Uplink/downlink split of the init offset matches too (the threaded
/// outcome reports these separately).
#[test]
fn init_offset_split_by_direction() {
    let (cfg, l) = cfg_and_lasso();
    let up = NodeToServer::InitFull { node: 0, x0: vec![0.0; l.m], u0: vec![0.0; l.m] }
        .wire_bits();
    let down = ServerToNode::InitZ { z0: vec![0.0; l.m] }.wire_bits();

    let mut rngs = TrialRngs::new(cfg.seed);
    let mut p = LassoProblem::generate(l, &mut rngs.data).unwrap();
    let sim = AsyncSim::new(&cfg, &mut p, rngs).unwrap();
    let acc = sim.accounting();
    assert_eq!(acc.total_uplink_bits(), l.n as u64 * up);
    assert_eq!(acc.total_downlink_bits(), l.n as u64 * down);
}
