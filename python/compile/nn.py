"""Neural-network definitions for the inexact-ADMM experiments (§5.2).

The paper's classifier: 6 layers — five 3×3 conv layers (stride 2, padding
1, channels 16/32/64/128/128) followed by a fully connected layer with 10
outputs. Spatial path on 28×28 input: 28 → 14 → 7 → 4 → 2 → 1, so the FC
sees a 128-dim feature. Parameter count M = 246,026 (the paper reports
246,762; the small gap is their parameter accounting — architecture is
identical).

Parameters live as one flat vector x_i ∈ R^M — that is exactly the iterate
the ADMM consensus runs over and what the quantizer compresses — and are
unflattened by static slicing inside the traced function. The flat layout
(name/shape/offset/fan_in) is exported into artifacts/manifest.json so the
rust coordinator can He-initialize per layer with its own RNG.

A small MLP variant (784–64–10) provides a fast path for CI and the
threaded end-to-end driver.
"""

import jax
import jax.numpy as jnp

CNN_CHANNELS = [(1, 16), (16, 32), (32, 64), (64, 128), (128, 128)]
MLP_WIDTHS = [784, 64, 10]


def cnn_param_specs():
    """Flat-layout spec: list of dicts {name, shape, offset, size, fan_in}."""
    specs = []
    offset = 0

    def add(name, shape, fan_in):
        nonlocal offset
        size = 1
        for d in shape:
            size *= d
        specs.append(
            {"name": name, "shape": list(shape), "offset": offset,
             "size": size, "fan_in": fan_in}
        )
        offset += size

    for i, (cin, cout) in enumerate(CNN_CHANNELS):
        add(f"conv{i}_w", (3, 3, cin, cout), 3 * 3 * cin)
        add(f"conv{i}_b", (cout,), 3 * 3 * cin)
    add("fc_w", (128, 10), 128)
    add("fc_b", (10,), 128)
    return specs


def mlp_param_specs(widths=None):
    widths = widths or MLP_WIDTHS
    specs = []
    offset = 0
    for i, (din, dout) in enumerate(zip(widths[:-1], widths[1:])):
        for name, shape, fan_in in (
            (f"fc{i}_w", (din, dout), din),
            (f"fc{i}_b", (dout,), din),
        ):
            size = 1
            for d in shape:
                size *= d
            specs.append(
                {"name": name, "shape": list(shape), "offset": offset,
                 "size": size, "fan_in": fan_in}
            )
            offset += size
    return specs


def param_count(specs):
    return sum(s["size"] for s in specs)


CNN_PARAMS = param_count(cnn_param_specs())  # 246_026
MLP_PARAMS = param_count(mlp_param_specs())  # 50_890


def _unflatten(flat, specs):
    out = {}
    for s in specs:
        out[s["name"]] = jax.lax.dynamic_slice(
            flat, (s["offset"],), (s["size"],)
        ).reshape(s["shape"])
    return out


def cnn_forward(flat, x):
    """Logits for x: [B, 28, 28, 1] f32 → [B, 10]."""
    p = _unflatten(flat, cnn_param_specs())
    h = x
    for i in range(len(CNN_CHANNELS)):
        w, b = p[f"conv{i}_w"], p[f"conv{i}_b"]
        h = jax.lax.conv_general_dilated(
            h, w, window_strides=(2, 2), padding=[(1, 1), (1, 1)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        h = jax.nn.relu(h + b)
    h = h.reshape(h.shape[0], -1)  # [B, 128]
    return h @ p["fc_w"] + p["fc_b"]


def mlp_forward(flat, x, widths=None):
    """Logits for x: [B, 784] f32 → [B, 10]."""
    widths = widths or MLP_WIDTHS
    p = _unflatten(flat, mlp_param_specs(widths))
    h = x
    n_layers = len(widths) - 1
    for i in range(n_layers):
        h = h @ p[f"fc{i}_w"] + p[f"fc{i}_b"]
        if i + 1 < n_layers:
            h = jax.nn.relu(h)
    return h


def cross_entropy(logits, labels):
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=-1)
    return -jnp.mean(picked)


def accuracy_count(logits, labels):
    """Number of correct argmax predictions (f32 scalar)."""
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.sum((pred == labels.astype(jnp.int32)).astype(jnp.float32))
