//! The sequential QADMM simulator: Algorithm 1, executed deterministically.
//!
//! This is the reproducible engine behind every figure. All randomness is
//! split into disjoint PCG64 streams (data / oracle / quantizer / batches /
//! init) so that two runs with the same seed but different compressors see
//! *identical* data, oracle schedules and batch orders — the comparison the
//! paper's figures make.

use crate::comm::accounting::CommAccounting;
use crate::comm::message::{INIT_BITS_PER_SCALAR, MSG_HEADER_BYTES};
use crate::compress::error_feedback::{estimate_rows, EstimateTracker};
use crate::compress::Compressor;
use crate::config::ExperimentConfig;
use crate::metrics::{IterRecord, RunRecorder};
use crate::problems::accumulator::ConsensusAccumulator;
use crate::problems::{Arena, Problem};
use crate::snapshot::codec::{Pack, Reader, Writer};
use crate::snapshot::SnapshotMeta;
use crate::topology::AggregatorTier;
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

use super::oracle::AsyncOracle;
use super::scheduler::Scheduler;
use super::trigger::{inf_norm, TriggerState};

/// Disjoint RNG streams for one trial. The data stream (fork 1) is consumed
/// by the problem factory; the simulator takes the rest.
pub struct TrialRngs {
    pub data: Pcg64,
    pub oracle: Pcg64,
    pub quant: Pcg64,
    pub batches: Pcg64,
    pub init: Pcg64,
    /// Virtual compute/network delay draws (event engine only). Forked
    /// last, so streams 1–5 are unchanged from before it existed.
    pub latency: Pcg64,
    /// Randomized fan-in routing (gossip relay draws). Forked after
    /// `latency`, so streams 1–6 — and with them every star trajectory —
    /// are unchanged from before topologies existed; star and tree consume
    /// nothing from it.
    pub topology: Pcg64,
}

impl TrialRngs {
    pub fn new(seed: u64) -> Self {
        let mut root = Pcg64::seed_from_u64(seed);
        Self {
            data: root.fork(1),
            oracle: root.fork(2),
            quant: root.fork(3),
            batches: root.fork(4),
            init: root.fork(5),
            latency: root.fork(6),
            topology: root.fork(7),
        }
    }
}

/// Deterministic metrics-sample indices: `k` nodes on a fixed stride over
/// `0..n`, shared by both in-process engines so a seq and an event run of
/// the same config measure the same nodes. Consumes **no** RNG — sampling
/// is observation-only and must not perturb any stream. Empty when
/// sampling is off (`metrics_sample == 0`) or would not shrink the fleet.
pub(crate) fn eval_sample_indices(cfg: &ExperimentConfig, n: usize) -> Vec<usize> {
    let k = cfg.metrics_sample;
    if k > 0 && k < n {
        (0..k).map(|j| j * n / k).collect()
    } else {
        Vec::new()
    }
}

pub struct AsyncSim<'a> {
    cfg: &'a ExperimentConfig,
    problem: &'a mut dyn Problem,
    compressor: Box<dyn Compressor>,
    m: usize,
    n: usize,
    // true iterates, flattened into contiguous n×m arenas
    x: Arena,
    u: Arena,
    z: Vec<f64>,
    // shared estimate banks (server view == node mirrors; transport is the
    // lossless frame of the lossy code, so one copy suffices in-process)
    xhat: Vec<EstimateTracker>,
    uhat: Vec<EstimateTracker>,
    zhat: EstimateTracker,
    /// Incremental server sum s = Σ(x̂+û): folded per active node in node
    /// order, the *same* fold order the event engine's `MsgArrive` stream
    /// produces at zero latency — this is what keeps the parity contract
    /// bit-exact through the incremental consensus path.
    acc: ConsensusAccumulator,
    /// Non-star fan-in: intermediate aggregators between the leaves and
    /// the consensus sum ([`crate::topology`]). `None` for the star — the
    /// pre-existing (bit-exact) path is then untouched. In the lockstep
    /// simulator every active leaf's update reaches its aggregator within
    /// the round, so aggregators always flush at round end (in ascending
    /// id order — the same order the event engine produces at zero link
    /// delay, which is what extends the parity contract to trees).
    tier: Option<AggregatorTier>,
    rng_topology: Pcg64,
    active: Vec<bool>,
    scheduler: Scheduler,
    /// Event-triggered transmission + adaptive level schedule (inert when
    /// `cfg.trigger` is the default — the legacy path is then untouched).
    trigger: TriggerState,
    oracle: AsyncOracle,
    accounting: CommAccounting,
    rng_oracle: Pcg64,
    rng_quant: Pcg64,
    rng_batches: Pcg64,
    recorder: RunRecorder,
    /// Metrics-sample node set ([`eval_sample_indices`]); empty = evaluate
    /// the full fleet.
    eval_sample: Vec<usize>,
    clock: Stopwatch,
    iter: usize,
}

impl<'a> AsyncSim<'a> {
    /// Initialize per Algorithm 1 lines 1–9 (full-precision first exchange).
    pub fn new(
        cfg: &'a ExperimentConfig,
        problem: &'a mut dyn Problem,
        mut rngs: TrialRngs,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        let m = problem.dim();
        let n = problem.n_nodes();
        let ef = cfg.error_feedback;
        let x0 = problem.init_x(&mut rngs.init);
        anyhow::ensure!(x0.len() == m, "init_x returned wrong dimension");
        let x = Arena::broadcast_row(&x0, n);
        let u = Arena::zeros(n, m);

        let n_aggs = cfg.topology.n_aggregators(n);
        let mut accounting = CommAccounting::new(n + n_aggs);
        // lines 1–4: nodes transmit x⁰, u⁰ at full precision, charged at the
        // paper's stated rate ("e.g., 32-bits per scalar")
        for i in 0..n {
            accounting.record_uplink(
                i,
                MSG_HEADER_BYTES * 8 + 2 * m as u64 * INIT_BITS_PER_SCALAR,
            );
        }
        let xhat: Vec<EstimateTracker> =
            (0..n).map(|_| EstimateTracker::new(x0.clone(), ef)).collect();
        let uhat: Vec<EstimateTracker> =
            (0..n).map(|_| EstimateTracker::new(vec![0.0; m], ef)).collect();

        // Non-star fan-in: seed each aggregator's server-side partial with
        // its children's init state and charge the aggregated full-precision
        // forward on the aggregator's own link (one (x, u) pair per agg).
        let mut tier = AggregatorTier::new(cfg.topology, n, m, cfg.p_tier, ef);
        if let Some(t) = &mut tier {
            for leaf in 0..n {
                t.seed_partial(
                    cfg.topology.static_parent(leaf),
                    xhat[leaf].estimate(),
                    uhat[leaf].estimate(),
                );
            }
            for g in 0..n_aggs {
                accounting.record_uplink(
                    n + g,
                    MSG_HEADER_BYTES * 8 + 2 * m as u64 * INIT_BITS_PER_SCALAR,
                );
            }
        }

        // line 7: z⁰ from the (exact) estimates via the incremental path
        // seeded with a full bank sweep (from the ŝ_g partials when an
        // aggregator tier owns the fan-in); line 8: broadcast full precision
        let mut acc = ConsensusAccumulator::new(m, cfg.consensus_refresh_every);
        match &tier {
            Some(t) => acc.refresh(t.refresh_rows()),
            None => acc.refresh(estimate_rows(&xhat, &uhat)),
        }
        let z = problem.consensus_from_sum(acc.sum(), n)?;
        accounting.record_broadcast_to(n, MSG_HEADER_BYTES * 8 + m as u64 * INIT_BITS_PER_SCALAR);
        let zhat = EstimateTracker::new(z.clone(), ef);

        let oracle = AsyncOracle::new(n, cfg.oracle, &mut rngs.oracle);
        Ok(Self {
            compressor: cfg.compressor.build(),
            m,
            n,
            x,
            u,
            z,
            xhat,
            uhat,
            zhat,
            acc,
            tier,
            rng_topology: rngs.topology,
            active: vec![true; n], // A₀ = V: every node computes first
            scheduler: Scheduler::new(n, cfg.tau, cfg.p_min),
            trigger: TriggerState::new(cfg, n),
            oracle,
            accounting,
            rng_oracle: rngs.oracle,
            rng_quant: rngs.quant,
            rng_batches: rngs.batches,
            recorder: RunRecorder::new(),
            eval_sample: eval_sample_indices(cfg, n),
            clock: Stopwatch::new(),
            iter: 0,
            cfg,
            problem,
        })
    }

    /// One iteration of Algorithm 1 (node updates for A_r, uplink
    /// compression, server consensus, downlink broadcast, scheduling).
    pub fn step(&mut self) -> anyhow::Result<()> {
        let active_count = self.active.iter().filter(|&&a| a).count();
        let mut train_loss = 0.0;
        // --- nodes in A_r (lines 18–22) ---
        for i in 0..self.n {
            if !self.active[i] {
                continue;
            }
            let (x_new, loss) = self.problem.local_update(
                i,
                self.zhat.estimate(),
                self.u.row(i),
                self.x.row(i),
                &mut self.rng_batches,
            )?;
            anyhow::ensure!(x_new.len() == self.m, "local_update wrong dim");
            // eq. (9b): u ← u + (x_new − ẑ)
            {
                let zhat_view = self.zhat.estimate();
                let ui = self.u.row_mut(i);
                for j in 0..self.m {
                    ui[j] += x_new[j] - zhat_view[j];
                }
            }
            self.x.row_mut(i).copy_from_slice(&x_new);
            train_loss += loss;

            // eqs. (10)–(14) under the optional event trigger: peek the
            // EF-adjusted deltas first, and below the dead-band skip the
            // dispatch entirely — no frame, no quantizer RNG draw, no
            // bank/accumulator mutation. The node still counts as active
            // (it computed; "nothing worth sending" is itself a report),
            // so scheduling and liveness are exactly as if it had sent.
            // peek + note_sent == the old make_delta, so the disabled
            // path is byte-for-byte the pre-trigger behavior.
            let mut dx = Vec::with_capacity(self.m);
            let mut du = Vec::with_capacity(self.m);
            self.xhat[i].peek_delta_into(self.x.row(i), &mut dx);
            self.uhat[i].peek_delta_into(self.u.row(i), &mut du);
            if self.trigger.enabled() {
                let norm = inf_norm(&dx).max(inf_norm(&du));
                self.trigger.observe(i, norm);
                if !self.trigger.should_send(norm) {
                    self.trigger.note_skip();
                    continue;
                }
            }
            self.xhat[i].note_sent(self.x.row(i));
            self.uhat[i].note_sent(self.u.row(i));
            let (cx, cu) = match self.trigger.compressor_for(i) {
                // adaptive schedule: this node's current QSGD width
                Some(q) => (
                    q.compress(&dx, &mut self.rng_quant),
                    q.compress(&du, &mut self.rng_quant),
                ),
                None => (
                    self.compressor.compress(&dx, &mut self.rng_quant),
                    self.compressor.compress(&du, &mut self.rng_quant),
                ),
            };
            self.accounting.record_uplink(
                i,
                MSG_HEADER_BYTES * 8 + cx.wire_bits() + cu.wire_bits(),
            );
            self.xhat[i].commit_frame(&cx)?;
            self.uhat[i].commit_frame(&cu)?;
            match &mut self.tier {
                // star: fold the wire frames straight into the server sum
                None => self.acc.fold_frames(&cx, &cu)?,
                // tree/gossip: the update lands at its aggregator instead
                // (the leaf-hop bits above were already charged to link i)
                Some(t) => {
                    t.route(i, &mut self.rng_topology);
                    t.deliver(i, &cx, &cu, 0.0)?;
                }
            }
        }

        // --- aggregator tier: every pending partial flushes upstream (in
        // lockstep no child is ever still in flight at round end), charged
        // per aggregator link and folded in ascending id order ---
        if let Some(t) = &mut self.tier {
            for g in 0..t.n_aggregators() {
                if !t.has_pending(g) {
                    continue;
                }
                // aggregator dead-band: a pending partial below δ is held
                // back (credit-only — zero wire bits, mass keeps pending)
                if self.trigger.delta() > 0.0
                    && t.pending_inf_norm(g) <= self.trigger.delta()
                {
                    let _ = t.credit_only_flush(g);
                    continue;
                }
                let fw = t.flush(g, self.compressor.as_ref(), &mut self.rng_quant);
                self.accounting.record_uplink(
                    self.n + g,
                    MSG_HEADER_BYTES * 8 + fw.cx.wire_bits() + fw.cu.wire_bits(),
                );
                t.commit(g, &fw.cx, &fw.cu)?;
                self.acc.fold_frames(&fw.cx, &fw.cu)?;
            }
        }

        // --- server (lines 27–43): consensus from the incremental sum,
        // with the periodic full-recompute drift wash-out (rebuilt from the
        // aggregator partials ŝ_g when a tier owns the fan-in — refreshing
        // from the leaf banks would leak information past the re-quantized
        // hop) ---
        if self.acc.refresh_due(self.iter + 1) {
            match &self.tier {
                Some(t) => self.acc.refresh(t.refresh_rows()),
                None => self.acc.refresh(estimate_rows(&self.xhat, &self.uhat)),
            }
        }
        self.z = self.problem.consensus_from_sum(self.acc.sum(), self.n)?;
        let dz = self.zhat.make_delta(&self.z);
        let cz = self.compressor.compress(&dz, &mut self.rng_quant);
        self.accounting.record_broadcast_to(self.n, MSG_HEADER_BYTES * 8 + cz.wire_bits());
        // dense commit of the materialized broadcast, matching the event
        // engine's shared-downlink-payload order exactly
        self.zhat.commit(&cz.dequantized()?);

        let next = self
            .scheduler
            .advance(&self.active, || self.oracle.sample(&mut self.rng_oracle));
        self.active = next;
        self.iter += 1;

        if self.iter % self.cfg.eval_every == 0 {
            let metrics = if self.eval_sample.is_empty() {
                self.problem.evaluate(&self.x, &self.u, &self.z)?
            } else {
                self.problem.evaluate_sample(&self.eval_sample, &self.x, &self.u, &self.z)?
            };
            self.recorder.push(IterRecord {
                iter: self.iter,
                comm_bits: self.accounting.normalized_bits(self.m),
                accuracy: metrics.accuracy,
                test_acc: metrics.test_acc,
                loss: if metrics.loss.is_nan() {
                    train_loss / active_count.max(1) as f64
                } else {
                    metrics.loss
                },
                active_nodes: active_count,
                wall_s: self.clock.elapsed_secs(),
            });
        }
        Ok(())
    }

    pub fn run(mut self, iters: usize) -> anyhow::Result<RunRecorder> {
        for _ in 0..iters {
            self.step()?;
        }
        Ok(self.recorder)
    }

    // ---- state accessors (tests + invariant checks) ----

    pub fn iter(&self) -> usize {
        self.iter
    }

    pub fn z(&self) -> &[f64] {
        &self.z
    }

    /// True iterates, one row per node.
    pub fn x(&self) -> &Arena {
        &self.x
    }

    pub fn u(&self) -> &Arena {
        &self.u
    }

    pub fn x_estimate(&self, i: usize) -> &[f64] {
        self.xhat[i].estimate()
    }

    pub fn z_estimate(&self) -> &[f64] {
        self.zhat.estimate()
    }

    pub fn accounting(&self) -> &CommAccounting {
        &self.accounting
    }

    pub fn recorder(&self) -> &RunRecorder {
        &self.recorder
    }

    pub fn active(&self) -> &[bool] {
        &self.active
    }

    /// Per-node staleness counters (invariant: ≤ τ−1; see the scheduler).
    pub fn staleness(&self) -> &[usize] {
        self.scheduler.staleness()
    }

    /// The aggregator tier, when a non-star topology owns the fan-in.
    pub fn tier(&self) -> Option<&AggregatorTier> {
        self.tier.as_ref()
    }

    /// Event-trigger / adaptive-schedule state (skip counters, per-node
    /// bit widths).
    pub fn trigger(&self) -> &TriggerState {
        &self.trigger
    }

    // ---- snapshot / resume ----

    /// Human-readable header for a snapshot taken now.
    pub fn snapshot_meta(&self) -> SnapshotMeta {
        SnapshotMeta {
            engine: "seq".into(),
            round: self.iter,
            n: self.n,
            m: self.m,
            seed: self.cfg.seed,
            config: self.cfg.to_json(),
        }
    }

    /// Serialize the simulator's complete mutable run state (the lockstep
    /// analogue of [`super::engine::EventEngine::snapshot_body`]): arenas,
    /// estimate banks, the Kahan-compensated consensus sum, the aggregator
    /// tier, the active set, scheduler counters, oracle grouping, wire-bit
    /// books, the metric series, every RNG stream and the round counter.
    /// Call between [`Self::step`] calls.
    pub fn snapshot_body(&self) -> Vec<u8> {
        let mut w = Writer::new();
        self.write_snapshot_body(&mut w);
        w.into_inner()
    }

    /// [`Self::snapshot_body`] into a caller-supplied writer — the
    /// checkpoint path hands in a spilling writer
    /// ([`crate::snapshot::write_file_streamed`]) so the packed state
    /// streams to disk instead of materializing a second copy in memory.
    pub fn write_snapshot_body(&self, w: &mut Writer) {
        self.x.pack(w);
        self.u.pack(w);
        self.z.pack(w);
        self.xhat.pack(w);
        self.uhat.pack(w);
        self.zhat.pack(w);
        self.acc.pack(w);
        self.tier.pack(w);
        self.rng_topology.pack(w);
        self.active.pack(w);
        self.scheduler.pack(w);
        self.oracle.pack(w);
        self.accounting.pack(w);
        self.rng_oracle.pack(w);
        self.rng_quant.pack(w);
        self.rng_batches.pack(w);
        self.recorder.pack(w);
        self.trigger.pack(w);
        w.put_usize(self.iter);
    }

    /// Rebuild a simulator from [`Self::snapshot_body`] — bit-identical
    /// continuation, with the problem re-derived from the same seed by the
    /// caller (snapshots store no problem data).
    pub fn resume(
        cfg: &'a ExperimentConfig,
        problem: &'a mut dyn Problem,
        body: &[u8],
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        let m = problem.dim();
        let n = problem.n_nodes();
        let n_aggs = cfg.topology.n_aggregators(n);
        let mut r = Reader::new(body);

        let x = Arena::unpack(&mut r)?;
        let u = Arena::unpack(&mut r)?;
        let z = Vec::<f64>::unpack(&mut r)?;
        let xhat = Vec::<EstimateTracker>::unpack(&mut r)?;
        let uhat = Vec::<EstimateTracker>::unpack(&mut r)?;
        let zhat = EstimateTracker::unpack(&mut r)?;
        let acc = ConsensusAccumulator::unpack(&mut r)?;
        let tier = Option::<AggregatorTier>::unpack(&mut r)?;
        let rng_topology = Pcg64::unpack(&mut r)?;
        let active = Vec::<bool>::unpack(&mut r)?;
        let scheduler = Scheduler::unpack(&mut r)?;
        let oracle = AsyncOracle::unpack(&mut r)?;
        let accounting = CommAccounting::unpack(&mut r)?;
        let rng_oracle = Pcg64::unpack(&mut r)?;
        let rng_quant = Pcg64::unpack(&mut r)?;
        let rng_batches = Pcg64::unpack(&mut r)?;
        let recorder = RunRecorder::unpack(&mut r)?;
        let trigger = TriggerState::unpack(&mut r)?;
        let iter = r.get_usize()?;
        r.finish()?;

        anyhow::ensure!(
            x.n_rows() == n && x.dim() == m && u.n_rows() == n && u.dim() == m,
            "snapshot iterate arenas sized {}x{}, problem is {n}x{m}",
            x.n_rows(),
            x.dim()
        );
        anyhow::ensure!(z.len() == m, "snapshot z has wrong dimension");
        anyhow::ensure!(
            xhat.len() == n && uhat.len() == n,
            "snapshot estimate banks sized for a different fleet"
        );
        for t in xhat.iter().chain(&uhat).chain(std::iter::once(&zhat)) {
            anyhow::ensure!(t.estimate().len() == m, "snapshot estimate bank wrong dim");
            anyhow::ensure!(
                t.feedback_enabled() == cfg.error_feedback,
                "snapshot error-feedback mode disagrees with config"
            );
        }
        anyhow::ensure!(acc.dim() == m, "snapshot accumulator wrong dim");
        anyhow::ensure!(
            tier.is_some() == (n_aggs > 0),
            "snapshot topology disagrees with config ({})",
            cfg.topology.label()
        );
        if let Some(t) = &tier {
            anyhow::ensure!(
                t.kind() == cfg.topology
                    && t.n_aggregators() == n_aggs
                    && t.p_tier() == cfg.p_tier.max(1)
                    && t.error_feedback() == cfg.error_feedback,
                "snapshot tier parameters disagree with config"
            );
        }
        anyhow::ensure!(active.len() == n, "snapshot active set wrong fleet size");
        anyhow::ensure!(
            trigger.matches(cfg, n),
            "snapshot trigger/adaptive-schedule state disagrees with config"
        );
        anyhow::ensure!(
            scheduler.staleness().len() == n
                && scheduler.tau() == cfg.tau
                && scheduler.p_min() == cfg.p_min,
            "snapshot scheduler disagrees with config"
        );
        anyhow::ensure!(oracle.fast_mask().len() == n, "snapshot oracle wrong fleet size");
        anyhow::ensure!(
            accounting.n_nodes() == n + n_aggs,
            "snapshot accounting has {} links, expected {}",
            accounting.n_nodes(),
            n + n_aggs
        );

        Ok(Self {
            compressor: cfg.compressor.build(),
            m,
            n,
            x,
            u,
            z,
            xhat,
            uhat,
            zhat,
            acc,
            tier,
            rng_topology,
            active,
            scheduler,
            trigger,
            oracle,
            accounting,
            rng_oracle,
            rng_quant,
            rng_batches,
            recorder,
            eval_sample: eval_sample_indices(cfg, n),
            clock: Stopwatch::new(),
            iter,
            cfg,
            problem,
        })
    }

    /// FNV digest over the raw state of every RNG stream the simulator
    /// owns (resume-parity contract).
    pub fn rng_digest(&self) -> u64 {
        let mut w = Writer::new();
        self.rng_oracle.pack(&mut w);
        self.rng_quant.pack(&mut w);
        self.rng_batches.pack(&mut w);
        self.rng_topology.pack(&mut w);
        crate::snapshot::codec::fnv1a64(w.as_slice())
    }
}
