//! Latency models: per-node delay distributions that reproduce the
//! heterogeneous-network conditions (stragglers) that motivate
//! asynchronous ADMM. One [`LatencyModel`] describes a single delay
//! source; [`super::profile::LinkProfile`] composes three of them
//! (compute, uplink, downlink) plus a clock-drift factor into the full
//! per-link decomposition used by both the event engine and the threaded
//! runtime.

use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LatencyModel {
    /// No injected delay (pure compute speed).
    None,
    /// Fixed delay in seconds.
    Const(f64),
    /// Exponential with the given mean (seconds).
    Exp(f64),
    /// Straggler mixture: fast constant delay w.p. (1−p_slow), slow w.p. p_slow.
    Mixture { fast: f64, slow: f64, p_slow: f64 },
}

impl LatencyModel {
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        match *self {
            LatencyModel::None => 0.0,
            LatencyModel::Const(s) => s,
            LatencyModel::Exp(mean) => rng.exponential(mean),
            LatencyModel::Mixture { fast, slow, p_slow } => {
                if rng.bernoulli(p_slow) {
                    slow
                } else {
                    fast
                }
            }
        }
    }

    /// Expected delay (for analytic wall-clock estimates in benches).
    pub fn mean(&self) -> f64 {
        match *self {
            LatencyModel::None => 0.0,
            LatencyModel::Const(s) => s,
            LatencyModel::Exp(mean) => mean,
            LatencyModel::Mixture { fast, slow, p_slow } => {
                fast * (1.0 - p_slow) + slow * p_slow
            }
        }
    }

    /// Compact textual form (CLI / config JSON): `none`, `const:S`,
    /// `exp:MEAN`, `mix:FAST,SLOW,P_SLOW`.
    pub fn label(&self) -> String {
        match *self {
            LatencyModel::None => "none".into(),
            LatencyModel::Const(s) => format!("const:{s}"),
            LatencyModel::Exp(mean) => format!("exp:{mean}"),
            LatencyModel::Mixture { fast, slow, p_slow } => {
                format!("mix:{fast},{slow},{p_slow}")
            }
        }
    }

    /// Inverse of [`Self::label`].
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let bad_num =
            |v: &str| anyhow::anyhow!("latency model: '{v}' is not a number (in '{s}')");
        if s == "none" {
            return Ok(LatencyModel::None);
        }
        let (kind, rest) = s.split_once(':').ok_or_else(|| {
            anyhow::anyhow!("latency model '{s}': expected none|const:S|exp:MEAN|mix:FAST,SLOW,P")
        })?;
        let num = |v: &str| -> anyhow::Result<f64> {
            let x: f64 = v.trim().parse().map_err(|_| bad_num(v))?;
            anyhow::ensure!(
                x.is_finite() && x >= 0.0,
                "latency model '{s}': negative or non-finite value"
            );
            Ok(x)
        };
        match kind {
            "const" => Ok(LatencyModel::Const(num(rest)?)),
            "exp" => Ok(LatencyModel::Exp(num(rest)?)),
            "mix" => {
                let parts: Vec<&str> = rest.split(',').collect();
                anyhow::ensure!(
                    parts.len() == 3,
                    "latency model '{s}': mix needs FAST,SLOW,P_SLOW"
                );
                let p_slow = num(parts[2])?;
                anyhow::ensure!(p_slow <= 1.0, "latency model '{s}': p_slow must be in [0,1]");
                Ok(LatencyModel::Mixture { fast: num(parts[0])?, slow: num(parts[1])?, p_slow })
            }
            other => anyhow::bail!("unknown latency model kind '{other}' (none|const|exp|mix)"),
        }
    }
}

/// Heterogeneous per-node variants of one base model: odd-indexed nodes are
/// "slow" with 4× the configured delay (mixture nodes get 4× the slow
/// probability, capped), mirroring the straggler conditions that motivate
/// asynchronous ADMM. Shared by the threaded coordinator and the
/// event-driven engine so both model the same population.
pub fn per_node_latencies(base: LatencyModel, n: usize) -> Vec<LatencyModel> {
    (0..n)
        .map(|i| match base {
            LatencyModel::None => LatencyModel::None,
            LatencyModel::Const(s) => {
                LatencyModel::Const(if i % 2 == 0 { s } else { 4.0 * s })
            }
            LatencyModel::Exp(mu) => LatencyModel::Exp(if i % 2 == 0 { mu } else { 4.0 * mu }),
            LatencyModel::Mixture { fast, slow, p_slow } => LatencyModel::Mixture {
                fast,
                slow,
                p_slow: if i % 2 == 0 { p_slow } else { (4.0 * p_slow).min(0.9) },
            },
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_node_slows_odd_nodes() {
        let v = per_node_latencies(LatencyModel::Const(0.1), 4);
        assert_eq!(v[0], LatencyModel::Const(0.1));
        assert_eq!(v[1], LatencyModel::Const(0.4));
        assert_eq!(v[2], LatencyModel::Const(0.1));
        assert!(per_node_latencies(LatencyModel::None, 3)
            .iter()
            .all(|l| *l == LatencyModel::None));
        match per_node_latencies(LatencyModel::Mixture { fast: 0.0, slow: 1.0, p_slow: 0.5 }, 2)[1]
        {
            LatencyModel::Mixture { p_slow, .. } => assert_eq!(p_slow, 0.9),
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn const_and_none() {
        let mut rng = Pcg64::seed_from_u64(0);
        assert_eq!(LatencyModel::None.sample(&mut rng), 0.0);
        assert_eq!(LatencyModel::Const(0.25).sample(&mut rng), 0.25);
    }

    #[test]
    fn empirical_means_match() {
        let mut rng = Pcg64::seed_from_u64(1);
        for model in [
            LatencyModel::Exp(0.2),
            LatencyModel::Mixture { fast: 0.01, slow: 0.5, p_slow: 0.3 },
        ] {
            let n = 50_000;
            let mean: f64 =
                (0..n).map(|_| model.sample(&mut rng)).sum::<f64>() / n as f64;
            assert!(
                (mean - model.mean()).abs() < 0.01,
                "{model:?}: {mean} vs {}",
                model.mean()
            );
        }
    }

    #[test]
    fn label_parse_roundtrips() {
        for model in [
            LatencyModel::None,
            LatencyModel::Const(0.25),
            LatencyModel::Exp(0.01),
            LatencyModel::Mixture { fast: 0.002, slow: 0.25, p_slow: 0.15 },
        ] {
            assert_eq!(LatencyModel::parse(&model.label()).unwrap(), model);
        }
        assert!(LatencyModel::parse("warp:1").is_err());
        assert!(LatencyModel::parse("const:abc").is_err());
        assert!(LatencyModel::parse("exp:-1").is_err());
        assert!(LatencyModel::parse("mix:0.1,0.2").is_err());
        assert!(LatencyModel::parse("mix:0.1,0.2,1.5").is_err());
    }

    #[test]
    fn samples_nonnegative() {
        let mut rng = Pcg64::seed_from_u64(2);
        let model = LatencyModel::Exp(0.1);
        for _ in 0..1000 {
            assert!(model.sample(&mut rng) >= 0.0);
        }
    }
}
