//! Reactor-specific deployment invariants, alongside (not instead of) the
//! churn suite:
//!
//! * a **slow consumer** — a connection that stops draining its socket —
//!   is detached with a synthesized `Leave` once its bounded write queue
//!   overflows, its unwritten frames are discarded uncharged, and the
//!   per-link byte books still reconcile **exactly**;
//! * the server's thread bill is **O(shards)**, not O(connections): a
//!   64-worker loadgen runs with `io_threads + 1` server threads;
//! * the shared-broadcast encode (one buffer per `Consensus` round, the
//!   excluded variant a one-byte flag flip) is byte-identical to two
//!   independent encodes, so per-recipient charge and length never drift.

use std::io::{Read, Write};
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use qadmm::config::ProblemKind;
use qadmm::deploy::frame::{Frame, FLAG_INCLUDED, PROTO_VERSION};
use qadmm::deploy::server::{config_digest, serve_tuned, ReactorOptions, ServeOptions};
use qadmm::deploy::transport::Endpoint;
use qadmm::deploy::worker::{run_worker, WorkerOptions, WorkerReport};
use qadmm::exp::deploy::{make_native_problem, serve_with_threads_tuned, smoke_cfg};

fn sock_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("qadmm-{tag}-{}.sock", std::process::id()))
}

/// Node 1 is a fake client that handshakes, uploads its init state, and
/// then **never reads again**. With `m` large enough that the `InitZ`
/// broadcast overflows the socket buffer, the frame sticks in the fake's
/// write queue; the next round's `Consensus` pushes the queue past
/// `write_queue_limit = 1` and the reactor must evict. Node 0 is a real
/// worker that carries the run to completion alone (`p_min = 1`).
#[test]
fn slow_consumer_is_evicted_and_books_reconcile() {
    let mut cfg = smoke_cfg(2, 8);
    // InitZ ≈ 9 + 8m bytes ≈ 512 KiB — past the default UDS send buffer,
    // so an unread broadcast provably wedges in the write queue
    let ProblemKind::Lasso { m, .. } = &mut cfg.problem else { unreachable!() };
    *m = 65_536;
    let dim = 65_536usize;

    let listen = Endpoint::Uds(sock_path("slow"));
    let opts = ServeOptions { idle_timeout: Duration::from_secs(10) };
    let reactor = ReactorOptions { io_threads: Some(2), write_queue_limit: 1 };
    let worker: Mutex<Option<JoinHandle<anyhow::Result<WorkerReport>>>> = Mutex::new(None);
    let fake: Mutex<Option<JoinHandle<()>>> = Mutex::new(None);
    let done = Arc::new(AtomicBool::new(false));

    let report = serve_tuned(
        &cfg,
        make_native_problem(&cfg).unwrap(),
        &listen,
        &opts,
        &reactor,
        |ep| {
            let (wcfg, wep) = (cfg.clone(), ep.clone());
            *worker.lock().unwrap() = Some(std::thread::spawn(move || {
                run_worker(&wcfg, make_native_problem(&wcfg)?, &wep, &WorkerOptions::new(0))
            }));
            let Endpoint::Uds(path) = ep.clone() else { unreachable!() };
            let digest = config_digest(&cfg);
            let done = done.clone();
            *fake.lock().unwrap() = Some(std::thread::spawn(move || {
                let mut s = UnixStream::connect(path).unwrap();
                s.write_all(
                    &Frame::Hello { proto: PROTO_VERSION, node: 1, m: dim as u32, digest }
                        .encode(),
                )
                .unwrap();
                // Welcome is a fixed 5-byte frame (4-byte length + kind)
                let mut welcome = [0u8; 5];
                s.read_exact(&mut welcome).unwrap();
                assert_eq!(welcome, [1, 0, 0, 0, 2], "expected a Welcome frame");
                s.write_all(
                    &Frame::InitFull { node: 1, x0: vec![0.0; dim], u0: vec![0.0; dim] }
                        .encode(),
                )
                .unwrap();
                // ... and now stop draining the socket entirely
                while !done.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(20));
                }
            }));
            Ok(())
        },
    )
    .expect("run must complete despite the slow consumer");
    done.store(true, Ordering::Relaxed);

    let wr = worker
        .into_inner()
        .unwrap()
        .unwrap()
        .join()
        .expect("worker thread panicked")
        .expect("worker 0 failed");
    assert!(wr.acked_shutdown, "worker 0 must carry the run through the drain: {wr:?}");
    fake.into_inner().unwrap().unwrap().join().unwrap();

    // all 8 rounds fired — the evicted node never wedged the P/τ trigger
    assert_eq!(report.timeline.rounds.len(), 8);
    assert_eq!(report.io_threads, 2);
    // exact reconciliation through the eviction: the fake's partially
    // written InitZ and its discarded queued Consensus appear on NEITHER
    // ledger, so the equality holds to the byte
    qadmm::deploy::reconcile(&report.books, &report.accounting).unwrap();
    // the fake's downlink books hold exactly the one completed frame (the
    // 5-byte Welcome): the wedged InitZ was never booked, never charged
    assert_eq!(report.books[1].down_total, 5, "fake downlink: {:?}", report.books[1]);
    assert_eq!(report.books[1].down_extra, 5);
    // its uplink books hold the Hello + the (charged) InitFull
    assert!(report.books[1].up_total > 16 * dim as u64);
    assert!(report.accounting.link(1).uplink_msgs == 1); // the InitFull
}

#[cfg(target_os = "linux")]
fn task_count() -> usize {
    std::fs::read_dir("/proc/self/task").map(|d| d.count()).unwrap_or(0)
}

/// 64 in-process workers over a UDS: the server side must stay at
/// `io_threads + 1` threads — O(shards), not the old 2n+1 — while the run
/// completes, drains, and reconciles exactly.
#[cfg(target_os = "linux")]
#[test]
fn loadgen_64_keeps_server_threads_o_shards() {
    const NODES: usize = 64;
    const SHARDS: usize = 4;
    let cfg = smoke_cfg(NODES, 6);
    let listen = Endpoint::Uds(sock_path("loadgen64"));
    let opts = ServeOptions { idle_timeout: Duration::from_secs(30) };
    let reactor = ReactorOptions { io_threads: Some(SHARDS), ..Default::default() };

    // sample the process task count while the fleet is live
    let peak = Arc::new(AtomicUsize::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let (peak, stop) = (peak.clone(), stop.clone());
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                peak.fetch_max(task_count(), Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(5));
            }
        })
    };

    let report =
        serve_with_threads_tuned(&cfg, &listen, NODES, &opts, &reactor).expect("loadgen run");
    stop.store(true, Ordering::Relaxed);
    sampler.join().unwrap();

    assert_eq!(report.io_threads, SHARDS);
    assert_eq!(report.timeline.rounds.len(), 6);
    qadmm::deploy::reconcile(&report.books, &report.accounting).unwrap();

    // Thread bill: NODES worker threads + (SHARDS + 1) server threads +
    // harness slack (the test runner, the sampler, sibling tests). The old
    // thread-per-connection server would add 2·NODES + 1 ≈ 129 more and
    // blow far past this ceiling.
    let peak = peak.load(Ordering::Relaxed);
    assert!(peak > 0, "task sampler read nothing");
    assert!(
        peak <= NODES + SHARDS + 1 + 32,
        "server thread count is not O(shards): peak {peak} tasks for {NODES} workers"
    );
}

/// The shared-broadcast encode contract: the excluded recipient's frame is
/// the included frame with exactly one flag bit cleared — same length,
/// same charge — so encoding once and flipping byte 5 is byte-exact.
#[test]
fn consensus_variants_differ_only_in_the_flag_byte() {
    let dz_wire = vec![7u8; 33];
    let incl =
        Frame::Consensus { round: 12, included: true, last: true, dz_wire: dz_wire.clone() }
            .encode();
    let excl =
        Frame::Consensus { round: 12, included: false, last: true, dz_wire }.encode();
    assert_eq!(incl.len(), excl.len());
    let mut flipped = incl.clone();
    flipped[5] &= !FLAG_INCLUDED;
    assert_eq!(flipped, excl, "flag flip must reproduce the excluded encode exactly");
    // and the flip commutes with decode
    let f = Frame::decode(flipped[4], &flipped[5..]).unwrap();
    let Frame::Consensus { included, last, .. } = f else { panic!("wrong kind") };
    assert!(!included && last);
}
