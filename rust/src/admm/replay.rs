//! Offline replay of a **deploy** recording: drive the Algorithm 1 state
//! machines through exactly the round schedule a production `qadmm serve`
//! captured ([`RecordedTimeline`] with `engine == "deploy"`), with no
//! sockets, no threads, and no wall-clock — the reverse of the PR 5
//! bridge (which replayed an *event-engine* recording through the
//! threaded runtime). This is the offline-diagnosis leg: a schedule
//! observed in production replays on a laptop, and the replay validates
//! the recording against the protocol's own invariants as it goes:
//!
//! - **cadence** — a node may arrive in round r only if it was dispatched
//!   (included in a broadcast, or the init) and has not arrived since:
//!   at most one update in flight per node (the paper's Fig. 2 cadence);
//! - **arrival fidelity** — the replay folds exactly the recorded arrival
//!   sets; the returned `round_arrivals` must equal the recording's
//!   verbatim (the deploy smoke asserts this).
//!
//! The replay reproduces the *schedule*, not the deployment's bit-exact
//! trajectory — within-round fold order here is ascending node id, while a
//! real deployment folds in arrival order (the same scope note as the
//! PR 5 bridge; bit-identity across runtimes is only ever claimed at
//! matching fold order).

use anyhow::{ensure, Result};

use crate::admm::trigger::{inf_norm, TriggerState};
use crate::comm::accounting::CommAccounting;
use crate::comm::message::{NodeToServer, ServerToNode};
use crate::compress::error_feedback::EstimateTracker;
use crate::compress::Compressed;
use crate::config::ExperimentConfig;
use crate::problems::{Arena, Problem};
use crate::snapshot::timeline::RecordedTimeline;
use crate::topology::TopologyKind;
use crate::util::rng::Pcg64;

/// What one node has staged for the server.
enum InFlight {
    /// Dispatched but its update has not been folded yet.
    Payload(Compressed, Compressed),
    /// Dead-banded dispatch: arrival credit, no payload.
    SkipCredit,
    /// Nothing in flight — the node is waiting to be dispatched.
    None,
}

pub struct ReplayOutcome {
    /// Realized arrival set per fired round (ascending) — equals the
    /// recording's `rounds[r].arrivals` when the replay succeeds.
    pub round_arrivals: Vec<Vec<usize>>,
    /// eq. (20) bits the replayed schedule charges (init + every realized
    /// transmission), normalized by M.
    pub comm_bits: f64,
    /// Final suboptimality under the replayed schedule.
    pub accuracy: f64,
}

/// Replay a deploy recording through the in-process state machines.
pub fn replay_timeline(
    cfg: &ExperimentConfig,
    mut problem: Box<dyn Problem + Send>,
    tl: &RecordedTimeline,
) -> Result<ReplayOutcome> {
    cfg.validate()?;
    ensure!(
        tl.engine == "deploy",
        "this driver replays deploy recordings (got '{}'); event recordings \
         replay via coordinator::run_threaded_replay",
        tl.engine
    );
    let n = problem.n_nodes();
    let m = problem.dim();
    ensure!(tl.n == n, "recording is for n={} nodes, problem has n={n}", tl.n);
    ensure!(
        cfg.topology == TopologyKind::Star,
        "deploy recordings are star fan-in"
    );

    // Identical state derivation to serve/worker. `fork` advances the
    // parent, so order matters: each deploy process draws fork(100) then
    // its own stream as the *second* draw from a fresh root — reproduce
    // node i's rng from its own root, exactly like the worker that drew it.
    let mut root = Pcg64::seed_from_u64(cfg.seed ^ 0x7468_7265_6164);
    let mut init_rng = root.fork(100);
    let x0 = problem.init_x(&mut init_rng);
    let mut server_rng = root.fork(300);
    let mut node_rngs: Vec<Pcg64> = (0..n)
        .map(|i| {
            let mut r = Pcg64::seed_from_u64(cfg.seed ^ 0x7468_7265_6164);
            let _ = r.fork(100);
            r.fork(200 + i as u64)
        })
        .collect();

    let ef = cfg.error_feedback;
    let mut xs: Vec<Vec<f64>> = vec![x0.clone(); n];
    let mut us: Vec<Vec<f64>> = vec![vec![0.0; m]; n];
    let mut xhat: Vec<EstimateTracker> =
        (0..n).map(|_| EstimateTracker::new(x0.clone(), ef)).collect();
    let mut uhat: Vec<EstimateTracker> =
        (0..n).map(|_| EstimateTracker::new(vec![0.0; m], ef)).collect();
    // per-node ẑ basis at dispatch time (each worker computes against the
    // consensus estimate it had when it was told to go)
    let mut z_seen: Vec<Vec<f64>>;
    let mut triggers: Vec<TriggerState> =
        (0..n).map(|_| TriggerState::new(cfg, 1)).collect();
    let compressor = cfg.compressor.build();
    let mut acc = CommAccounting::new(n);

    // init exchange, charged at the paper's 32-bit rate like every runtime
    for i in 0..n {
        acc.record_uplink(
            i,
            NodeToServer::InitFull { node: i, x0: x0.clone(), u0: us[i].clone() }
                .wire_bits(),
        );
    }
    let sum0: Vec<f64> = (0..m)
        .map(|j| (0..n).map(|i| xs[i][j] + us[i][j]).sum::<f64>())
        .collect();
    let z = problem.consensus_from_sum(&sum0, n)?;
    acc.record_broadcast(ServerToNode::InitZ { z0: z.clone() }.wire_bits());
    let mut zhat = EstimateTracker::new(z, true);
    z_seen = vec![zhat.estimate().to_vec(); n];

    // every node is dispatched by InitZ: compute the first update now
    let mut inflight: Vec<InFlight> = Vec::with_capacity(n);
    for i in 0..n {
        let staged = compute(
            i,
            problem.as_mut(),
            &z_seen[i],
            &mut xs[i],
            &mut us[i],
            &mut xhat[i],
            &mut uhat[i],
            &mut triggers[i],
            compressor.as_ref(),
            &mut node_rngs[i],
            &mut acc,
        )?;
        inflight.push(staged);
    }

    let mut round_arrivals = Vec::with_capacity(tl.rounds.len());
    for (r, round) in tl.rounds.iter().enumerate() {
        // fold exactly the recorded arrivals (ascending id order)
        for &i in &round.arrivals {
            ensure!(i < n, "round {r}: arrival node {i} out of range");
            match std::mem::replace(&mut inflight[i], InFlight::None) {
                InFlight::Payload(cx, cu) => {
                    xhat[i].commit_frame(&cx)?;
                    uhat[i].commit_frame(&cu)?;
                }
                InFlight::SkipCredit => {}
                InFlight::None => anyhow::bail!(
                    "round {r}: node {i} arrives without a dispatch in flight \
                     (cadence violation in the recording)"
                ),
            }
        }
        round_arrivals.push(round.arrivals.clone());

        // fire: z = prox(Σ(x̂+û)/n), broadcast the compressed delta
        let sum: Vec<f64> = (0..m)
            .map(|j| {
                (0..n)
                    .map(|i| xhat[i].estimate()[j] + uhat[i].estimate()[j])
                    .sum::<f64>()
            })
            .collect();
        let z = problem.consensus_from_sum(&sum, n)?;
        let dz = zhat.make_delta(&z);
        let cz = compressor.compress(&dz, &mut server_rng);
        let dz_deq = cz.dequantized()?;
        acc.record_broadcast(
            ServerToNode::Consensus {
                iter: r as u64,
                included: Vec::new(),
                dz_wire: cz.wire,
                last: round.dispatches.is_empty(),
            }
            .wire_bits(),
        );
        zhat.commit(&dz_deq);

        // recorded dispatches recompute against the ẑ estimate they see
        for &i in &round.dispatches {
            ensure!(i < n, "round {r}: dispatch node {i} out of range");
            ensure!(
                matches!(inflight[i], InFlight::None),
                "round {r}: node {i} dispatched with an update already in flight"
            );
            z_seen[i] = zhat.estimate().to_vec();
            inflight[i] = compute(
                i,
                problem.as_mut(),
                &z_seen[i],
                &mut xs[i],
                &mut us[i],
                &mut xhat[i],
                &mut uhat[i],
                &mut triggers[i],
                compressor.as_ref(),
                &mut node_rngs[i],
                &mut acc,
            )?;
        }
    }

    let xa = Arena::from_rows_iter(m, xhat.iter().map(|t| t.estimate()));
    let ua = Arena::from_rows_iter(m, uhat.iter().map(|t| t.estimate()));
    let metrics = problem.evaluate(&xa, &ua, zhat.estimate())?;
    Ok(ReplayOutcome {
        round_arrivals,
        comm_bits: acc.normalized_bits(m),
        accuracy: metrics.accuracy,
    })
}

/// One node's local update + staging, mirroring the worker's
/// `compute_and_send` (trigger dead-band, adaptive quantizer, EF banks,
/// frame-commit-before-send order). Charges the uplink for realized
/// payloads only.
#[allow(clippy::too_many_arguments)]
fn compute(
    node: usize,
    problem: &mut (dyn Problem + Send),
    z: &[f64],
    x: &mut Vec<f64>,
    u: &mut Vec<f64>,
    xhat: &mut EstimateTracker,
    uhat: &mut EstimateTracker,
    trigger: &mut TriggerState,
    compressor: &dyn crate::compress::Compressor,
    rng: &mut Pcg64,
    acc: &mut CommAccounting,
) -> Result<InFlight> {
    let m = x.len();
    let (x_new, _loss) = problem.local_update(node, z, u, x, rng)?;
    for j in 0..m {
        u[j] += x_new[j] - z[j];
    }
    *x = x_new;
    let mut dx = Vec::with_capacity(m);
    let mut du = Vec::with_capacity(m);
    xhat.peek_delta_into(x, &mut dx);
    uhat.peek_delta_into(u, &mut du);
    if trigger.enabled() {
        let norm = inf_norm(&dx).max(inf_norm(&du));
        trigger.observe(0, norm);
        if !trigger.should_send(norm) {
            trigger.note_skip();
            return Ok(InFlight::SkipCredit);
        }
    }
    xhat.note_sent(x);
    uhat.note_sent(u);
    let (cx, cu) = match trigger.compressor_for(0) {
        Some(q) => (q.compress(&dx, rng), q.compress(&du, rng)),
        None => (compressor.compress(&dx, rng), compressor.compress(&du, rng)),
    };
    acc.record_uplink(
        node,
        crate::comm::message::MSG_HEADER_BYTES * 8
            + (cx.wire.len() + cu.wire.len()) as u64 * 8,
    );
    Ok(InFlight::Payload(cx, cu))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::admm::runner::trial_seed;
    use crate::admm::sim::TrialRngs;
    use crate::config::presets;
    use crate::config::ProblemKind;
    use crate::problems::lasso::{LassoConfig, LassoProblem};
    use crate::snapshot::timeline::RecordedTimeline;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = presets::ci_lasso();
        cfg.iters = 4;
        cfg
    }

    fn tiny_problem(cfg: &ExperimentConfig) -> Box<dyn Problem + Send> {
        let ProblemKind::Lasso { m, h, n, rho, theta } = cfg.problem.clone() else {
            unreachable!("ci preset is lasso")
        };
        let mut rngs = TrialRngs::new(trial_seed(cfg.seed, 0));
        let mut p = LassoProblem::generate(LassoConfig { m, h, n, rho, theta }, &mut rngs.data)
            .expect("problem");
        p.set_reference_optimum(1.0);
        Box::new(p)
    }

    /// A full-participation schedule replays cleanly and reproduces its
    /// own arrival sets.
    #[test]
    fn full_participation_schedule_replays() {
        let cfg = tiny_cfg();
        let n = tiny_problem(&cfg).n_nodes();
        let mut tl = RecordedTimeline::new("deploy", n, cfg.seed);
        let all: Vec<usize> = (0..n).collect();
        for r in 0..4usize {
            let disp = if r == 3 { Vec::new() } else { all.clone() };
            tl.push_round(r as f64, all.clone(), disp);
        }
        let out = replay_timeline(&cfg, tiny_problem(&cfg), &tl).unwrap();
        assert_eq!(out.round_arrivals, vec![all.clone(); 4]);
        assert!(out.comm_bits > 0.0);
        assert!(out.accuracy.is_finite());
    }

    /// An arrival with no dispatch in flight is a cadence violation, not
    /// a silent mis-fold.
    #[test]
    fn cadence_violation_is_an_error() {
        let cfg = tiny_cfg();
        let n = tiny_problem(&cfg).n_nodes();
        let mut tl = RecordedTimeline::new("deploy", n, cfg.seed);
        // node 0 arrives twice without being re-dispatched in between
        tl.push_round(0.0, vec![0], vec![]);
        tl.push_round(1.0, vec![0], vec![]);
        let err = replay_timeline(&cfg, tiny_problem(&cfg), &tl).unwrap_err();
        assert!(err.to_string().contains("cadence"), "{err}");
    }

    /// Event recordings are routed to the other replay path.
    #[test]
    fn event_recordings_are_rejected() {
        let cfg = tiny_cfg();
        let tl = RecordedTimeline::new("event", 4, cfg.seed);
        assert!(replay_timeline(&cfg, tiny_problem(&cfg), &tl).is_err());
    }
}
