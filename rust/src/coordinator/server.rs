//! Server loop: arrival-driven Algorithm 1. Triggers a consensus round once
//! at least `P` nodes have reported *and* every node at staleness τ−1 is
//! among them (the bounded-delay rule); broadcasts the compressed consensus
//! delta; repeats for the configured number of rounds.

use std::collections::BTreeSet;
use std::time::Duration;

use crate::comm::message::{NodeToServer, ServerToNode};
use crate::comm::network::{ServerEndpoint, SharedAccounting};
use crate::compress::error_feedback::EstimateTracker;
use crate::compress::{wire, Compressor};
use crate::config::ExperimentConfig;
use crate::metrics::{IterRecord, RunRecorder};
use crate::problems::accumulator::ConsensusAccumulator;
use crate::problems::Arena;
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

use super::SharedProblem;

pub struct ServerLoop {
    ep: ServerEndpoint,
    problem: SharedProblem,
    accounting: SharedAccounting,
    compressor: Box<dyn Compressor>,
    m: usize,
    n: usize,
    tau: usize,
    p_min: usize,
    iters: usize,
    eval_every: usize,
    xhat: Vec<EstimateTracker>,
    uhat: Vec<EstimateTracker>,
    zhat: Option<EstimateTracker>,
    /// Incremental consensus sum: each decoded arrival folds its deltas in
    /// (real arrival order — no bitwise replay claim in the deployment
    /// shape, only the accumulator's drift bound), so the per-round
    /// consensus is O(m) + the every-K-rounds refresh.
    acc: ConsensusAccumulator,
    d: Vec<usize>,
    pending: BTreeSet<usize>,
    rng: Pcg64,
    /// How long the server will wait for a required (stale) node before
    /// declaring the deployment wedged.
    pub stall_timeout: Duration,
}

impl ServerLoop {
    pub fn new(
        ep: ServerEndpoint,
        problem: SharedProblem,
        accounting: SharedAccounting,
        cfg: &ExperimentConfig,
        x0: Vec<f64>,
        m: usize,
        rng: Pcg64,
    ) -> Self {
        let n = ep.n_nodes();
        let ef = cfg.error_feedback;
        Self {
            ep,
            problem,
            accounting,
            compressor: cfg.compressor.build(),
            m,
            n,
            tau: cfg.tau,
            p_min: cfg.p_min,
            iters: cfg.iters,
            eval_every: cfg.eval_every,
            xhat: (0..n).map(|_| EstimateTracker::new(x0.clone(), ef)).collect(),
            uhat: (0..n).map(|_| EstimateTracker::new(vec![0.0; m], ef)).collect(),
            zhat: None,
            acc: ConsensusAccumulator::new(m, cfg.consensus_refresh_every),
            d: vec![0; n],
            pending: BTreeSet::new(),
            rng,
            stall_timeout: Duration::from_secs(60),
        }
    }

    pub fn run(mut self) -> anyhow::Result<RunRecorder> {
        let clock = Stopwatch::new();
        let mut recorder = RunRecorder::new();

        // ---- init: collect full-precision (x⁰, u⁰) from every node ----
        // (idempotent per node: the fault injector may duplicate InitFull)
        let mut inited = vec![false; self.n];
        while inited.iter().any(|i| !i) {
            match self.ep.recv()? {
                NodeToServer::InitFull { node, x0, u0 } => {
                    self.xhat[node].reset(&x0);
                    self.uhat[node].reset(&u0);
                    inited[node] = true;
                }
                NodeToServer::Update { .. } => {
                    anyhow::bail!("update before init handshake completed")
                }
            }
        }
        // seed the incremental sum with one full bank sweep, then fold
        // arrivals in as they land
        self.refresh_sum();
        let z = self.consensus()?;
        self.ep.broadcast(&ServerToNode::InitZ { z0: z.clone() })?;
        self.zhat = Some(EstimateTracker::new(z, true));

        // ---- main rounds ----
        for r in 0..self.iters {
            self.gather_batch()?;
            if self.acc.refresh_due(r + 1) {
                self.refresh_sum();
            }
            let z = self.consensus()?;
            let dz = self.zhat.as_mut().unwrap().make_delta(&z);
            let cz = self.compressor.compress(&dz, &mut self.rng);
            // BTreeSet iteration is ascending, matching the wire contract.
            let included: Vec<u32> = self.pending.iter().map(|&i| i as u32).collect();
            self.ep.broadcast(&ServerToNode::Consensus {
                iter: r as u64,
                included,
                dz_wire: cz.wire,
            })?;
            self.zhat.as_mut().unwrap().commit(&cz.dequantized);

            let batch_size = self.pending.len();
            for i in 0..self.n {
                if self.pending.contains(&i) {
                    self.d[i] = 0;
                } else {
                    self.d[i] += 1;
                }
            }
            self.pending.clear();

            if (r + 1) % self.eval_every == 0 {
                let xs =
                    Arena::from_rows_iter(self.m, self.xhat.iter().map(|t| t.estimate()));
                let us =
                    Arena::from_rows_iter(self.m, self.uhat.iter().map(|t| t.estimate()));
                let metrics = self.problem.lock().unwrap().evaluate(&xs, &us, &z)?;
                let comm_bits =
                    self.accounting.lock().unwrap().normalized_bits(self.m);
                recorder.push(IterRecord {
                    iter: r + 1,
                    comm_bits,
                    accuracy: metrics.accuracy,
                    test_acc: metrics.test_acc,
                    loss: metrics.loss,
                    active_nodes: batch_size,
                    wall_s: clock.elapsed_secs(),
                });
            }
        }

        // orderly shutdown: stop the nodes, then drain in-flight uplinks
        self.ep.broadcast(&ServerToNode::Shutdown)?;
        self.ep.drain(Duration::from_millis(100));
        Ok(recorder)
    }

    /// Wait until ≥ P arrivals and every τ−1-stale node has reported.
    fn gather_batch(&mut self) -> anyhow::Result<()> {
        loop {
            let stale_ok = (0..self.n)
                .filter(|i| self.d[*i] >= self.tau - 1)
                .all(|i| self.pending.contains(&i));
            if self.pending.len() >= self.p_min && stale_ok {
                return Ok(());
            }
            match self.ep.recv_timeout(self.stall_timeout)? {
                Some(NodeToServer::Update { node, dx_wire, du_wire, .. }) => {
                    let dx = wire::decode(&dx_wire, self.m)?;
                    let du = wire::decode(&du_wire, self.m)?;
                    self.xhat[node].commit(&dx);
                    self.uhat[node].commit(&du);
                    // O(m) fold keeps s = Σ(x̂+û) current without the
                    // per-round bank sweep
                    self.acc.fold(&dx, &du);
                    self.pending.insert(node);
                }
                // Duplicated InitFull frames (fault injection) are ignored —
                // the handshake already completed.
                Some(NodeToServer::InitFull { .. }) => {}
                None => anyhow::bail!(
                    "server stalled: {} arrivals, staleness {:?}",
                    self.pending.len(),
                    self.d
                ),
            }
        }
    }

    /// z = prox(s/n) from the incremental sum — O(m) per round.
    fn consensus(&mut self) -> anyhow::Result<Vec<f64>> {
        self.problem.lock().unwrap().consensus_from_sum(self.acc.sum(), self.n)
    }

    /// Full O(n·m) rebuild of the sum from the banks (init + every-K-rounds
    /// drift wash-out).
    fn refresh_sum(&mut self) {
        self.acc
            .refresh(self.xhat.iter().zip(&self.uhat).map(|(x, u)| (x.estimate(), u.estimate())));
    }
}
