# Make `pytest python/tests` work from the repo root: the compile package
# lives in this directory.
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
