//! Virtual-time event substrate for the event-driven engine.
//!
//! A binary-heap priority queue over `(time, seq)` where `time` is virtual
//! seconds and `seq` is the insertion order. Ties on `time` are broken by
//! insertion order, which makes the whole timeline deterministic: two runs
//! that push the same events in the same order pop them in the same order,
//! even when every delay is 0.0 (the parity configuration, where the
//! engine must replay the sequential simulator bit-for-bit).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::snapshot::codec::{Pack, Reader, Writer};

/// What happened at a virtual instant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Node finished its local primal update (uplink send begins).
    ComputeDone { node: usize },
    /// Node's compressed update arrived at the server.
    MsgArrive { node: usize },
    /// The server's compressed Δz broadcast reached this node's ẑ mirror
    /// (payloads ride a per-node FIFO inbox; arrival times are clamped
    /// monotone per link, so broadcasts never overtake each other).
    DownlinkArrive { node: usize },
    /// An intermediate aggregator's re-quantized partial sum reached the
    /// server (non-star topologies only): the payload rides a per-agg FIFO
    /// with monotone arrival clamps, exactly like the downlink inboxes, and
    /// carries the arrival credit of every child folded into it.
    AggregateArrive { agg: usize },
}

impl EventKind {
    /// Stable label for timeline recordings ([`crate::snapshot::timeline`]).
    pub fn label(&self) -> &'static str {
        match self {
            EventKind::ComputeDone { .. } => "compute-done",
            EventKind::MsgArrive { .. } => "msg-arrive",
            EventKind::DownlinkArrive { .. } => "downlink-arrive",
            EventKind::AggregateArrive { .. } => "aggregate-arrive",
        }
    }

    /// The node (or aggregator) index the event belongs to.
    pub fn index(&self) -> usize {
        match *self {
            EventKind::ComputeDone { node }
            | EventKind::MsgArrive { node }
            | EventKind::DownlinkArrive { node } => node,
            EventKind::AggregateArrive { agg } => agg,
        }
    }
}

/// One scheduled event. Ordered by `(time, seq)` with `f64::total_cmp`,
/// so NaN-free timelines have a total deterministic order.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub time: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.total_cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// Min-heap of events in virtual time.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Event>>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `kind` at virtual time `time` (seconds). Delays must be
    /// finite and non-negative; a NaN time would corrupt the ordering.
    pub fn push(&mut self, time: f64, kind: EventKind) {
        debug_assert!(time.is_finite() && time >= 0.0, "bad virtual time {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Event { time, seq, kind }));
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(e)| e)
    }

    /// Virtual time of the next event, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse(e)| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// All scheduled events, in unspecified order (snapshot validation).
    pub fn events(&self) -> impl Iterator<Item = &Event> {
        self.heap.iter().map(|Reverse(e)| e)
    }
}

impl Pack for EventKind {
    fn pack(&self, w: &mut Writer) {
        let (tag, idx): (u8, usize) = match *self {
            EventKind::ComputeDone { node } => (0, node),
            EventKind::MsgArrive { node } => (1, node),
            EventKind::DownlinkArrive { node } => (2, node),
            EventKind::AggregateArrive { agg } => (3, agg),
        };
        w.put_u8(tag);
        w.put_usize(idx);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let tag = r.get_u8()?;
        let idx = r.get_usize()?;
        Ok(match tag {
            0 => EventKind::ComputeDone { node: idx },
            1 => EventKind::MsgArrive { node: idx },
            2 => EventKind::DownlinkArrive { node: idx },
            3 => EventKind::AggregateArrive { agg: idx },
            other => anyhow::bail!("unknown event kind tag {other}"),
        })
    }
}

impl Pack for Event {
    fn pack(&self, w: &mut Writer) {
        w.put_f64(self.time);
        w.put_u64(self.seq);
        self.kind.pack(w);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let time = r.get_f64()?;
        anyhow::ensure!(
            time.is_finite() && time >= 0.0,
            "snapshot event has bad virtual time {time}"
        );
        let seq = r.get_u64()?;
        let kind = EventKind::unpack(r)?;
        Ok(Self { time, seq, kind })
    }
}

/// Snapshots serialize the heap as a *sorted* `(time, seq)` list — heap
/// layout is an implementation detail, but the sorted order is canonical,
/// so pack∘unpack∘pack is byte-stable.
impl Pack for EventQueue {
    fn pack(&self, w: &mut Writer) {
        let mut evs: Vec<Event> = self.heap.iter().map(|Reverse(e)| *e).collect();
        evs.sort();
        evs.pack(w);
        w.put_u64(self.next_seq);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let evs = Vec::<Event>::unpack(r)?;
        let next_seq = r.get_u64()?;
        for e in &evs {
            anyhow::ensure!(
                e.seq < next_seq,
                "snapshot event seq {} not below counter {next_seq}",
                e.seq
            );
        }
        Ok(Self { heap: evs.into_iter().map(Reverse).collect(), next_seq })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::MsgArrive { node: 0 });
        q.push(0.5, EventKind::ComputeDone { node: 1 });
        q.push(1.0, EventKind::ComputeDone { node: 2 });
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|e| e.time)).collect();
        assert_eq!(times, vec![0.5, 1.0, 2.0]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for node in 0..5 {
            q.push(0.0, EventKind::ComputeDone { node });
        }
        for node in 0..5 {
            let e = q.pop().unwrap();
            assert_eq!(e.kind, EventKind::ComputeDone { node });
        }
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_pushes_stay_deterministic() {
        // two identical push sequences produce identical pop sequences
        let run = || {
            let mut q = EventQueue::new();
            q.push(1.0, EventKind::ComputeDone { node: 0 });
            q.push(1.0, EventKind::MsgArrive { node: 1 });
            q.push(0.0, EventKind::ComputeDone { node: 2 });
            q.push(1.0, EventKind::ComputeDone { node: 3 });
            std::iter::from_fn(|| q.pop().map(|e| (e.time, e.kind))).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn queue_snapshot_restores_order_and_seq_counter() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::ComputeDone { node: 0 });
        q.push(1.0, EventKind::MsgArrive { node: 1 });
        q.push(0.5, EventKind::DownlinkArrive { node: 2 });
        q.push(2.0, EventKind::AggregateArrive { agg: 0 });
        let _ = q.pop(); // consume one so next_seq != len
        let mut w = Writer::new();
        q.pack(&mut w);
        let bytes = w.into_inner();
        let mut restored = EventQueue::unpack(&mut Reader::new(&bytes)).unwrap();
        // restored queue pops identically AND assigns the same future seqs
        q.push(1.0, EventKind::ComputeDone { node: 9 });
        restored.push(1.0, EventKind::ComputeDone { node: 9 });
        loop {
            let (a, b) = (q.pop(), restored.pop());
            assert_eq!(a.map(|e| (e.time, e.seq, e.kind)), b.map(|e| (e.time, e.seq, e.kind)));
            if a.is_none() {
                break;
            }
        }
        // pack is canonical: repacking the restored queue is byte-identical
        let mut q2 = EventQueue::new();
        q2.push(3.0, EventKind::MsgArrive { node: 4 });
        q2.push(1.0, EventKind::ComputeDone { node: 2 });
        let mut w1 = Writer::new();
        q2.pack(&mut w1);
        let restored2 = EventQueue::unpack(&mut Reader::new(w1.as_slice())).unwrap();
        let mut w2 = Writer::new();
        restored2.pack(&mut w2);
        assert_eq!(w1.as_slice(), w2.as_slice());
    }

    #[test]
    fn queue_unpack_rejects_bad_times_and_seqs() {
        // NaN time
        let mut w = Writer::new();
        vec![Event { time: f64::NAN, seq: 0, kind: EventKind::ComputeDone { node: 0 } }]
            .pack(&mut w);
        w.put_u64(1);
        assert!(EventQueue::unpack(&mut Reader::new(w.as_slice())).is_err());
        // seq not below the counter
        let mut w = Writer::new();
        vec![Event { time: 0.0, seq: 5, kind: EventKind::ComputeDone { node: 0 } }]
            .pack(&mut w);
        w.put_u64(5);
        assert!(EventQueue::unpack(&mut Reader::new(w.as_slice())).is_err());
        // unknown kind tag
        let mut w = Writer::new();
        w.put_usize(1);
        w.put_f64(0.0);
        w.put_u64(0);
        w.put_u8(9);
        w.put_usize(0);
        w.put_u64(1);
        assert!(EventQueue::unpack(&mut Reader::new(w.as_slice())).is_err());
    }

    #[test]
    fn peek_time_matches_next_pop() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(3.5, EventKind::MsgArrive { node: 9 });
        q.push(0.25, EventKind::MsgArrive { node: 4 });
        assert_eq!(q.peek_time(), Some(0.25));
        assert_eq!(q.pop().unwrap().time, 0.25);
        assert_eq!(q.peek_time(), Some(3.5));
        assert_eq!(q.len(), 1);
    }
}
