//! Rand-k sparsifier: k uniformly random coordinates, index set derived
//! from a seed shared on the wire (8 bytes instead of k indices). Unscaled
//! (biased); error feedback supplies convergence, as with top-k.

use super::wire::{encode_randk, randk_indices};
use super::{sanitize, Compressed, Compressor};
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug)]
pub struct RandK {
    frac: f64,
}

impl RandK {
    pub fn new(frac: f64) -> Self {
        assert!(frac > 0.0 && frac <= 1.0, "randk fraction must be in (0, 1]");
        Self { frac }
    }

    pub fn k_for(&self, m: usize) -> usize {
        ((self.frac * m as f64).ceil() as usize).clamp(1, m)
    }
}

impl Compressor for RandK {
    fn name(&self) -> String {
        // clamped to the parser's 1..=1000 permille range, as in TopK::name
        format!("randk{}", ((self.frac * 1000.0).round() as u64).clamp(1, 1000))
    }

    fn compress(&self, delta: &[f64], rng: &mut Pcg64) -> Compressed {
        let m = delta.len();
        let k = self.k_for(m);
        let seed = rng.next_u64();
        let idx = randk_indices(m, k, seed);
        // a sampled non-finite coordinate is dropped (0.0), not transmitted
        let values: Vec<f64> = idx.iter().map(|&i| sanitize(delta[i])).collect();
        Compressed { wire: encode_randk(m, seed, &values) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_reconstructs_via_shared_seed() {
        let mut rng = Pcg64::seed_from_u64(5);
        let delta = rng.normal_vec(300, 0.0, 1.0);
        let r = RandK::new(0.1);
        let c = r.compress(&delta, &mut rng);
        let dq = c.dequantized().unwrap();
        assert_eq!(r.decode(&c.wire, 300).unwrap(), dq);
        let kept = dq.iter().filter(|&&v| v != 0.0).count();
        assert!(kept <= r.k_for(300)); // ties to zero entries allowed
    }

    #[test]
    fn kept_values_match_delta() {
        let mut rng = Pcg64::seed_from_u64(6);
        let delta = rng.normal_vec(100, 0.0, 1.0);
        let c = RandK::new(0.2).compress(&delta, &mut rng);
        for (d, v) in delta.iter().zip(&c.dequantized().unwrap()) {
            assert!(*v == 0.0 || v == d);
        }
    }

    #[test]
    fn different_calls_pick_different_supports() {
        let mut rng = Pcg64::seed_from_u64(7);
        let delta = vec![1.0; 200];
        let r = RandK::new(0.05);
        let a = r.compress(&delta, &mut rng);
        let b = r.compress(&delta, &mut rng);
        assert_ne!(a.dequantized().unwrap(), b.dequantized().unwrap());
    }
}
