//! Quickstart: solve a small distributed LASSO with QADMM (q = 3 bits) and
//! compare against the unquantized async-ADMM baseline.
//!
//!     cargo run --release --example quickstart
//!
//! Demonstrates the public API end to end: configure an experiment, build a
//! problem, run the Monte-Carlo harness, read the headline numbers.

use qadmm::admm::runner::{self, ProblemFactory};
use qadmm::compress::CompressorKind;
use qadmm::config::presets;
use qadmm::metrics::summary;
use qadmm::problems::lasso::{LassoConfig, LassoProblem};
use qadmm::problems::Problem;
use qadmm::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    // A small instance of the paper's §5.1 workload (native f64 backend).
    let mut cfg = presets::ci_lasso();
    cfg.iters = 300;
    cfg.mc_trials = 3;

    let lasso = LassoConfig { m: 64, h: 48, n: 8, rho: 100.0, theta: 0.1 };
    match &mut cfg.problem {
        qadmm::config::ProblemKind::Lasso { m, h, n, rho, theta } => {
            (*m, *h, *n, *rho, *theta) =
                (lasso.m, lasso.h, lasso.n, lasso.rho, lasso.theta);
        }
        _ => unreachable!(),
    }

    let mut results = Vec::new();
    for compressor in [CompressorKind::Qsgd { bits: 3 }, CompressorKind::Identity] {
        cfg.compressor = compressor;
        cfg.name = format!("quickstart-{}", compressor.label());
        let mut factory: Box<ProblemFactory> =
            Box::new(move |_seed, data_rng: &mut Pcg64| {
                Ok(Box::new(LassoProblem::generate(lasso, data_rng)?) as Box<dyn Problem>)
            });
        let res = runner::run_mc(&cfg, factory.as_mut())?;
        drop(factory);
        let rec = res.mean_recorder();
        let last = rec.last().unwrap().clone();
        println!(
            "{:24} final accuracy {:.3e}   total wire {:.1} bits/param",
            compressor.label(),
            last.accuracy,
            last.comm_bits
        );
        results.push(rec);
    }

    let target = 1e-8;
    let q = summary::bits_to_accuracy(&results[0].records, target);
    let b = summary::bits_to_accuracy(&results[1].records, target);
    println!("{}", summary::headline_row("quickstart", "accuracy 1e-8", q, b));

    let (q, b) = (q.expect("qadmm reached target"), b.expect("baseline reached target"));
    assert!(q < b, "quantized run should need fewer bits");
    println!("OK: QADMM reached 1e-8 with {:.1}% of the baseline's bits", 100.0 * q / b);
    Ok(())
}
