//! Event-driven virtual-time QADMM engine (Algorithm 1 at 1000+ nodes).
//!
//! The sequential simulator ([`super::sim`]) advances in lockstep rounds;
//! the threaded coordinator ([`crate::coordinator`]) burns real wall-clock
//! on injected `thread::sleep` latency. This engine keeps the *semantics*
//! of genuine asynchrony — per-node compute and network delays, the
//! server firing on `P` arrivals, force-waiting any node at staleness τ−1 —
//! but advances a **virtual clock** through a calendar-queue event
//! timeline ([`super::events`], O(1) amortized push/pop), so a 1000-node
//! straggler run finishes in milliseconds of wall time and an n = 10^6
//! fleet is event-rate-bound rather than heap-depth-bound.
//!
//! The server's per-round cost scales with the **arrival set**, not the
//! fleet: each `MsgArrive` folds its wire frames into the running
//! sum s = Σ(x̂+û) ([`ConsensusAccumulator`], O(k) per sparse arrival,
//! O(m) dense — no dense intermediate is materialized), so a fire
//! is `consensus_from_sum(s)` — O(m) — instead of the old O(n·m) bank
//! sweep; the dispatch path reuses pooled delta/compression buffers (no
//! steady-state per-message allocation).
//!
//! Per-node memory is O(active), not O(n·m): the server estimate banks
//! are stored **quantized-at-rest** ([`QuantBank`] — committed wire
//! frames, dense rows materialized through a bounded LRU scratch pool),
//! the n ẑ mirrors collapse into a [`MirrorTable`] of shared broadcast
//! prefix states (O(window·m + n) instead of an n×m arena plus n inbox
//! FIFOs), and in-flight outboxes are lazily boxed (`None` for every idle
//! node, recycled through a bounded slot pool). The true x/u iterates
//! remain dense arenas — they are the algorithm's state proper, touched
//! by every local update.
//!
//! The consensus **fan-in** is owned by the configured topology
//! ([`crate::topology`]): under the star every `MsgArrive` is an arrival
//! *at the server*; under `tree:<fanout>` / `gossip:<k>` it lands at an
//! intermediate aggregator, which folds it into a pending partial sum and
//! — once its per-tier threshold P_g is met, or nothing further is in
//! flight toward it — forwards the re-quantized partial delta on its own
//! accounted link (`AggregateArrive` carries the children's arrival
//! credit to the server). The star path is byte-for-byte the pre-existing
//! one: no tier state is even allocated.
//!
//! Timeline per consensus round (each delay leg drawn from the node's
//! [`LinkProfile`] — compute scaled by its clock drift, uplink and
//! downlink on the server's clock):
//! 1. the server fires: consensus from the incremental sum, compressed Δz
//!    broadcast (accounted per link), scheduler advance (oracle selection +
//!    τ−1 forcing — the same [`super::scheduler::Scheduler`] the simulator
//!    uses, consuming the same oracle RNG stream). The broadcast does
//!    **not** land instantly: each node gets a `DownlinkArrive` event at
//!    `now + downlink_delay` (clamped monotone per link, so broadcasts
//!    never overtake each other) with the Δz payload queued in its FIFO
//!    inbox;
//! 2. `DownlinkArrive` commits Δz into the node's private ẑ **mirror** —
//!    the server's `zhat` bank and a node's view of it are now distinct
//!    states that agree only once every broadcast has landed. If the node
//!    was selected at fire time (and idle), its local update starts *here*:
//!    all dispatches born in one virtual instant run as one batch through
//!    [`crate::problems::Problem::local_update_batch`] (worker-pool
//!    parallel for native LASSO, merged in node order), each item reading
//!    its own mirror; deltas are compressed with per-node RNG forks and a
//!    `ComputeDone` event is scheduled at `+ compute_delay / clock_rate`
//!    (fast-clocked nodes finish sooner);
//! 3. `ComputeDone` accounts the uplink and schedules `MsgArrive` at
//!    `+ uplink_delay`; `MsgArrive` commits the wire frames into the
//!    server's estimate banks and joins the sparse arrival set;
//! 4. between distinct virtual instants the server checks the trigger:
//!    |arrivals| ≥ P **and** every node whose staleness has reached τ−1
//!    has arrived. Nodes selected while still in flight are not
//!    re-dispatched (at most one update in flight per node, the Fig. 2
//!    cadence), and their eventual arrival counts toward the next round.
//!
//! **Parity contract** (see `tests/engine_parity.rs`): with zero delay on
//! every link leg and the identity compressor, every broadcast and every
//! arrival lands in the same virtual instant as its dispatch, each mirror
//! equals the server's `zhat`, rounds coincide exactly with simulator
//! iterations, and the `z` trajectory and bit accounting are bit-identical
//! to [`super::sim::AsyncSim`]. Any nonzero downlink leg breaks the
//! collapse: nodes compute against a stale ẑ, which is precisely the
//! asymmetric staleness of the paper's Fig. 2.

use std::collections::{BTreeSet, VecDeque};

use crate::comm::accounting::CommAccounting;
use crate::comm::message::{INIT_BITS_PER_SCALAR, MSG_HEADER_BYTES};
use crate::comm::profile::{per_node_profiles, LinkProfile};
use crate::compress::bank::QuantBank;
use crate::compress::error_feedback::EstimateTracker;
use crate::compress::{Compressed, Compressor};
use crate::config::ExperimentConfig;
use crate::metrics::{IterRecord, RunRecorder};
use crate::problems::accumulator::ConsensusAccumulator;
use crate::problems::{Arena, LocalUpdateItem, Problem};
use crate::snapshot::codec::{Pack, Reader, Writer};
use crate::snapshot::timeline::RecordedTimeline;
use crate::snapshot::SnapshotMeta;
use crate::topology::{AggForward, AggregatorTier};
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

use super::events::{EventKind, EventQueue};
use super::oracle::AsyncOracle;
use super::scheduler::Scheduler;
use super::sim::TrialRngs;
use super::trigger::{inf_norm, TriggerState};

/// A compressed update sitting in a node's outbox / on the virtual wire.
/// A node holds a slot only while its update is computing or in transit
/// (`in_flight[i]` is `None` otherwise — idle nodes cost nothing);
/// drained slots recycle through a bounded pool, and `compress_into`
/// refills the pooled [`Compressed`] wire buffers on every dispatch, so
/// the steady-state round does no per-message allocation. The slot holds
/// the wire frames only (no materialized dense vectors): arrival commits
/// and folds consume the frames directly, so in-flight memory is the
/// compressed size per message, not O(m).
struct InFlightSlot {
    cx: Compressed,
    cu: Compressed,
    bits: u64,
    loss: f64,
    /// Dead-banded dispatch: the slot traverses the same compute+uplink
    /// timeline but carries no payload — its arrival grants scheduler
    /// credit only (zero wire bits, no bank commits, no fold).
    skipped: bool,
}

impl InFlightSlot {
    fn empty() -> Self {
        Self {
            cx: Compressed::empty(),
            cu: Compressed::empty(),
            bits: 0,
            loss: 0.0,
            skipped: false,
        }
    }
}

/// Drained in-flight slots kept for reuse (bounded — beyond this the box
/// is simply dropped; the cap only has to cover the steady-state arrival
/// burst, not the fleet).
const SLOT_POOL_CAP: usize = 256;

/// One broadcast still in downlink transit: its Δz, the (ascending) nodes
/// it dispatches on landing, and how many nodes have yet to apply it.
struct BroadcastRec {
    dz: Vec<f64>,
    dispatch: Vec<usize>,
    remaining: usize,
}

/// All n per-node views of ẑ, stored as shared broadcast **prefix states**
/// instead of an n×m arena with n inbox FIFOs. Every broadcast reaches
/// every node in FIFO order on its downlink (the monotone per-link clamp
/// guarantees no overtaking), so a node that has applied k broadcasts has
/// mirror S_k = z⁰ + Δz_1 + … + Δz_k — the *same* vector for every such
/// node. The table keeps one dense state per broadcast still in transit
/// (O(window·m), where the window is bounded by the downlink delay
/// spread) plus an O(n) applied-counter. Each prefix state is built by
/// the identical `+=` addition sequence the per-node mirror commits used
/// to run, so every materialized row is bit-for-bit the arena row it
/// replaces (the engine-parity suites pin this).
struct MirrorTable {
    m: usize,
    n: usize,
    /// Global index of the oldest retained broadcast record.
    base_idx: u64,
    /// Prefix states S_{base_idx} … S_{base_idx + recs.len()} — always
    /// exactly `recs.len() + 1` entries (front = fully-applied floor).
    states: VecDeque<Vec<f64>>,
    recs: VecDeque<BroadcastRec>,
    /// Broadcasts applied per node (global count; row = states[applied −
    /// base_idx]).
    applied: Vec<u64>,
}

impl MirrorTable {
    fn new(z0: &[f64], n: usize) -> Self {
        Self {
            m: z0.len(),
            n,
            base_idx: 0,
            states: VecDeque::from([z0.to_vec()]),
            recs: VecDeque::new(),
            applied: vec![0; n],
        }
    }

    /// Server fired: append the broadcast. The new prefix state commits
    /// Δz with the same per-coordinate `+=` the node mirrors ran.
    fn push_broadcast(&mut self, dz: Vec<f64>, dispatch: Vec<usize>) {
        debug_assert_eq!(dz.len(), self.m);
        debug_assert!(dispatch.windows(2).all(|w| w[0] < w[1]));
        let mut next = self.states.back().expect("mirror table keeps >= 1 state").clone();
        for (s, d) in next.iter_mut().zip(&dz) {
            *s += d;
        }
        self.states.push_back(next);
        self.recs.push_back(BroadcastRec { dz, dispatch, remaining: self.n });
    }

    /// A `DownlinkArrive` fired for `node`: advance its applied counter
    /// past the next in-transit broadcast and say whether that broadcast
    /// dispatches the node. Fully-applied front records are trimmed, so
    /// the window always spans exactly the broadcasts someone has yet to
    /// receive.
    fn deliver(&mut self, node: usize) -> anyhow::Result<bool> {
        let j = (self.applied[node] - self.base_idx) as usize;
        anyhow::ensure!(j < self.recs.len(), "DownlinkArrive with empty inbox (node {node})");
        self.applied[node] += 1;
        let rec = &mut self.recs[j];
        rec.remaining -= 1;
        let dispatch = rec.dispatch.binary_search(&node).is_ok();
        while self.recs.front().is_some_and(|r| r.remaining == 0) {
            self.recs.pop_front();
            self.states.pop_front();
            self.base_idx += 1;
        }
        Ok(dispatch)
    }

    /// Node `node`'s current view of ẑ.
    fn row(&self, node: usize) -> &[f64] {
        &self.states[(self.applied[node] - self.base_idx) as usize]
    }

    fn n_nodes(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.m
    }
}

/// Timeline counters the property tests assert on.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Consensus rounds fired so far.
    pub rounds: usize,
    /// Virtual seconds elapsed.
    pub virtual_time: f64,
    /// Events processed (ComputeDone + MsgArrive + DownlinkArrive +
    /// AggregateArrive).
    pub events: u64,
    /// Local updates dispatched.
    pub dispatches: u64,
    /// Re-quantized partial-sum forwards sent by the aggregator tier
    /// (0 under the star topology).
    pub agg_forwards: u64,
    /// Smallest arrival set that ever triggered a round (must be ≥ P);
    /// `None` until the first round fires, so reading stats early can
    /// never leak a `usize::MAX` sentinel to callers.
    pub min_arrivals: Option<usize>,
    /// Largest per-node staleness counter ever observed (must be ≤ τ−1).
    pub max_staleness: usize,
    /// Largest event-queue population ever reached (updated on every
    /// push — the timeline's working-set high-water mark).
    pub queue_peak: usize,
    /// Events pushed onto the timeline (processed + still pending;
    /// `events` counts only the processed ones).
    pub events_scheduled: u64,
}

pub struct EventEngine<'a> {
    cfg: &'a ExperimentConfig,
    problem: &'a mut dyn Problem,
    compressor: Box<dyn Compressor>,
    m: usize,
    n: usize,
    // true iterates, flattened into contiguous n×m arenas
    x: Arena,
    u: Arena,
    z: Vec<f64>,
    // server-side estimate banks (committed only on MsgArrive), stored
    // quantized-at-rest: wire frames + a bounded dense scratch pool, so
    // idle nodes cost O(1) instead of two dense rows each
    xhat: QuantBank,
    uhat: QuantBank,
    zhat: EstimateTracker,
    /// Incremental server sum s = Σ(x̂+û): every `MsgArrive` folds its
    /// committed deltas in (O(m)), so `fire` is O(m) instead of the old
    /// O(n·m) bank sweep — see [`ConsensusAccumulator`] for the Kahan +
    /// periodic-refresh drift contract.
    acc: ConsensusAccumulator,
    /// Each node's private view of ẑ, as shared broadcast prefix states:
    /// a node's row advances only when a broadcast lands on its downlink
    /// (`DownlinkArrive`), never at fire time. `dispatch` reads this, not
    /// `zhat`.
    mirrors: MirrorTable,
    /// Last scheduled downlink arrival per node (monotonicity clamp: a
    /// later broadcast never overtakes an earlier one on the same link).
    downlink_last: Vec<f64>,
    /// Nodes whose downlink landed with a dispatch flag in the instant
    /// being drained; flushed as one batch between instants (buffer is
    /// recycled across flushes).
    pending_dispatch: Vec<usize>,
    /// Non-star fan-in: intermediate aggregators between leaf arrivals and
    /// the consensus sum ([`crate::topology`]). `None` for the star, whose
    /// pre-existing (bit-exact) path is untouched.
    tier: Option<AggregatorTier>,
    /// Aggregators that received a child arrival in the instant being
    /// drained; their forward condition is checked between instants in
    /// ascending id order (recycled buffer, like `pending_dispatch`).
    touched_aggs: Vec<usize>,
    /// Per-aggregator FIFO of forwards in transit toward the server.
    agg_inbox: Vec<VecDeque<AggForward>>,
    /// Monotonicity clamp for aggregator→server arrivals (a later forward
    /// never overtakes an earlier one on the same link).
    agg_last: Vec<f64>,
    /// Aggregator link profiles (uplink leg used; realized from the same
    /// population spec as the leaves, independently of the leaf count).
    agg_links: Vec<LinkProfile>,
    /// Gossip relay draws (dedicated stream, shared with the simulator).
    rng_topology: Pcg64,
    /// Sparse arrival set for the round being assembled (no n ≤ 64 mask).
    arrived: BTreeSet<usize>,
    /// Overdue nodes (staleness = τ−1) that have not arrived yet, counted
    /// so the per-instant trigger check is O(1) instead of an O(n)
    /// staleness scan — fragmented arrival patterns used to make rounds
    /// O(n²). Recomputed after each `fire`, decremented on `MsgArrive`.
    overdue_pending: usize,
    /// Node has an update computing or in transit (one in flight max).
    busy: Vec<bool>,
    /// Outboxes, allocated only while an update is in flight (`None` for
    /// every idle node — the O(active) half of the memory contract).
    in_flight: Vec<Option<Box<InFlightSlot>>>,
    /// Drained slots kept for reuse (bounded; never serialized).
    slot_pool: Vec<Box<InFlightSlot>>,
    /// Loss delivered with each node's last arrival (round-loss fallback).
    arrived_loss: Vec<f64>,
    /// Scratch for delta construction (reused across all nodes/rounds).
    delta_buf: Vec<f64>,
    /// Second delta scratch: the trigger gate needs both peeked deltas
    /// alive at once (‖Δx‖∞ and ‖Δu‖∞ are compared against δ together).
    delta_buf_u: Vec<f64>,
    /// Reusable arrival mask handed to the scheduler each fire.
    arrived_mask: Vec<bool>,
    /// Event-triggered transmission + adaptive level schedule (inert when
    /// `cfg.trigger` is the default — the legacy path is then untouched).
    trigger: TriggerState,
    scheduler: Scheduler,
    oracle: AsyncOracle,
    accounting: CommAccounting,
    queue: EventQueue,
    /// Per-node link profiles: compute/uplink/downlink legs + clock drift
    /// (straggler heterogeneity).
    links: Vec<LinkProfile>,
    rng_latency: Pcg64,
    rng_oracle: Pcg64,
    /// Per-node quantizer streams (forked once; order-independent).
    node_quant: Vec<Pcg64>,
    /// Server-side quantizer stream for the broadcast compression.
    server_quant: Pcg64,
    /// Per-aggregator quantizer streams (re-quantized upstream forwards).
    agg_quant: Vec<Pcg64>,
    /// Per-node batch-sampling streams for inexact problems.
    node_batch: Vec<Pcg64>,
    recorder: RunRecorder,
    /// Deterministic node sample for the eval hook (`--metrics-sample`):
    /// empty = evaluate the full fleet. A pure stride over the node range
    /// derived from the config (no RNG consumed, nothing to snapshot).
    eval_sample: Vec<usize>,
    clock: Stopwatch,
    vtime: f64,
    stats: EngineStats,
    /// When recording (`--record-timeline`): the realized event stream and
    /// per-round arrival/dispatch sets, replayable by the threaded runtime.
    timeline: Option<RecordedTimeline>,
}

impl<'a> EventEngine<'a> {
    /// Initialize per Algorithm 1 lines 1–9 — the exact same full-precision
    /// exchange (and accounting) as [`super::sim::AsyncSim::new`] — then
    /// dispatch A₀ = V at virtual time 0.
    pub fn new(
        cfg: &'a ExperimentConfig,
        problem: &'a mut dyn Problem,
        mut rngs: TrialRngs,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        let m = problem.dim();
        let n = problem.n_nodes();
        let ef = cfg.error_feedback;
        let x0 = problem.init_x(&mut rngs.init);
        anyhow::ensure!(x0.len() == m, "init_x returned wrong dimension");
        let x = Arena::broadcast_row(&x0, n);
        let u = Arena::zeros(n, m);

        let n_aggs = cfg.topology.n_aggregators(n);
        let mut accounting = CommAccounting::new(n + n_aggs);
        for i in 0..n {
            accounting.record_uplink(
                i,
                MSG_HEADER_BYTES * 8 + 2 * m as u64 * INIT_BITS_PER_SCALAR,
            );
        }
        // Quantized-at-rest banks: every row starts at the shared init row
        // (x⁰ / zeros) with no per-node allocation at all.
        let xhat = QuantBank::new(n, x0.clone(), ef);
        let uhat = QuantBank::new(n, vec![0.0; m], ef);
        let zeros = vec![0.0; m];
        // Non-star fan-in: seed each aggregator's server-side partial from
        // its children's init state (x̂ᵢ = x⁰, ûᵢ = 0 — the banks hold
        // exactly these rows) and charge the aggregated full-precision
        // forward on the aggregator's own link (identically to the sim).
        let mut tier = AggregatorTier::new(cfg.topology, n, m, cfg.p_tier, ef);
        if let Some(t) = &mut tier {
            for leaf in 0..n {
                t.seed_partial(cfg.topology.static_parent(leaf), &x0, &zeros);
            }
            for g in 0..n_aggs {
                accounting.record_uplink(
                    n + g,
                    MSG_HEADER_BYTES * 8 + 2 * m as u64 * INIT_BITS_PER_SCALAR,
                );
            }
        }
        // z⁰ via the incremental path seeded with a full bank sweep — the
        // identical fold order (and, under a tier, the identical ŝ_g
        // partial source) the simulator uses, so the parity contract
        // starts bit-exact. Every star row is (x⁰, 0) at init, so the
        // sweep streams the shared rows without touching the banks.
        let mut acc = ConsensusAccumulator::new(m, cfg.consensus_refresh_every);
        match &tier {
            Some(t) => acc.refresh(t.refresh_rows()),
            None => {
                acc.refresh_begin();
                for _ in 0..n {
                    acc.refresh_fold_row(&x0, &zeros);
                }
            }
        }
        let z = problem.consensus_from_sum(acc.sum(), n)?;
        accounting.record_broadcast_to(n, MSG_HEADER_BYTES * 8 + m as u64 * INIT_BITS_PER_SCALAR);
        let zhat = EstimateTracker::new(z.clone(), ef);

        // Every node's mirror starts at the full-precision z⁰ it received
        // in the (synchronous) init broadcast: one shared prefix state.
        let mirrors = MirrorTable::new(&z, n);
        let oracle = AsyncOracle::new(n, cfg.oracle, &mut rngs.oracle);
        let mut qroot = rngs.quant;
        let node_quant: Vec<Pcg64> = (0..n).map(|i| qroot.fork(i as u64)).collect();
        let server_quant = qroot.fork(n as u64);
        // per-aggregator quantizer streams for the re-quantized forwards
        // (forked after the server's, so star consumption is unchanged)
        let agg_quant: Vec<Pcg64> =
            (0..n_aggs).map(|g| qroot.fork(n as u64 + 1 + g as u64)).collect();
        let mut broot = rngs.batches;
        let node_batch: Vec<Pcg64> = (0..n).map(|i| broot.fork(i as u64)).collect();

        // Initial staleness is all-zero, so only τ = 1 starts with overdue
        // nodes (every node is then force-waited each round).
        let overdue_pending = if cfg.tau == 1 { n } else { 0 };
        let mut engine = Self {
            compressor: cfg.compressor.build(),
            m,
            n,
            x,
            u,
            z,
            xhat,
            uhat,
            zhat,
            acc,
            mirrors,
            downlink_last: vec![0.0; n],
            pending_dispatch: Vec::new(),
            tier,
            touched_aggs: Vec::new(),
            agg_inbox: (0..n_aggs).map(|_| VecDeque::new()).collect(),
            agg_last: vec![0.0; n_aggs],
            agg_links: per_node_profiles(cfg.link, n_aggs),
            rng_topology: rngs.topology,
            arrived: BTreeSet::new(),
            overdue_pending,
            busy: vec![false; n],
            in_flight: (0..n).map(|_| None).collect(),
            slot_pool: Vec::new(),
            arrived_loss: vec![0.0; n],
            delta_buf: Vec::with_capacity(m),
            delta_buf_u: Vec::with_capacity(m),
            arrived_mask: vec![false; n],
            trigger: TriggerState::new(cfg, n),
            scheduler: Scheduler::new(n, cfg.tau, cfg.p_min),
            oracle,
            accounting,
            queue: EventQueue::new(),
            server_quant,
            agg_quant,
            links: per_node_profiles(cfg.link, n),
            // per-trial stream: MC trials must be independent replicates
            // over network randomness, not replays of one delay sequence
            rng_latency: rngs.latency,
            rng_oracle: rngs.oracle,
            node_quant,
            node_batch,
            recorder: RunRecorder::new(),
            eval_sample: Self::eval_sample_for(cfg, n),
            clock: Stopwatch::new(),
            vtime: 0.0,
            stats: EngineStats::default(),
            timeline: None,
            cfg,
            problem,
        };
        // A₀ = V: every node computes first (same as the simulator).
        let all: Vec<usize> = (0..n).collect();
        engine.dispatch(&all)?;
        Ok(engine)
    }

    /// The `--metrics-sample` node set: a pure stride over the fleet
    /// (deterministic, consumes no RNG — the trial RNG fork order is part
    /// of the reproducibility contract). Empty = evaluate everyone.
    /// Shared with the simulator so both engines measure the same nodes.
    fn eval_sample_for(cfg: &ExperimentConfig, n: usize) -> Vec<usize> {
        super::sim::eval_sample_indices(cfg, n)
    }

    /// Every timeline push goes through here so the queue's high-water
    /// mark and total scheduled-event count are maintained exactly (not
    /// sampled). Associated fn over disjoint fields: call sites hold other
    /// `self` borrows (e.g. the aggregator tier).
    fn push_event(queue: &mut EventQueue, stats: &mut EngineStats, at: f64, kind: EventKind) {
        queue.push(at, kind);
        stats.events_scheduled += 1;
        stats.queue_peak = stats.queue_peak.max(queue.len());
    }

    /// Return a drained outbox to the bounded recycle pool (cleared so a
    /// pooled slot is indistinguishable from a fresh one).
    fn recycle_slot(pool: &mut Vec<Box<InFlightSlot>>, mut slot: Box<InFlightSlot>) {
        if pool.len() < SLOT_POOL_CAP {
            slot.cx.wire.clear();
            slot.cu.wire.clear();
            slot.bits = 0;
            slot.loss = 0.0;
            slot.skipped = false;
            pool.push(slot);
        }
    }

    /// Advance virtual time until exactly one more consensus round fires —
    /// the event-driven analogue of [`super::sim::AsyncSim::step`].
    pub fn step_round(&mut self) -> anyhow::Result<()> {
        loop {
            // Flush local updates born in the instant just drained: every
            // node whose downlink landed here (with a dispatch flag) runs
            // in one batch, so uniform delays keep the worker-pool fan-out
            // of the zero-latency timeline.
            if !self.pending_dispatch.is_empty() {
                let mut nodes = std::mem::take(&mut self.pending_dispatch);
                nodes.sort_unstable();
                self.dispatch(&nodes)?;
                // recycle the buffer: fragmented downlink arrivals flush up
                // to n single-node batches per round, and reallocating the
                // list each flush is avoidable churn
                nodes.clear();
                if self.pending_dispatch.is_empty() {
                    self.pending_dispatch = nodes;
                }
            }
            // Aggregators touched by arrivals in the drained instant check
            // their forward condition *after* this instant's dispatches
            // registered their routes (so "nothing further in flight" is
            // evaluated against the freshest picture), in ascending id
            // order — the simulator's flush order, which is what keeps
            // tree/gossip runs bit-exact across engines at zero delay.
            if !self.touched_aggs.is_empty() {
                self.forward_ready_aggs();
            }
            if self.trigger_satisfied() {
                return self.fire();
            }
            let Some(t) = self.queue.peek_time() else {
                anyhow::bail!(
                    "event queue drained before the trigger (round {}, {} arrivals, staleness {:?})",
                    self.stats.rounds,
                    self.arrived.len(),
                    self.scheduler.staleness()
                );
            };
            debug_assert!(t >= self.vtime, "virtual time went backwards");
            self.vtime = t;
            // Consume the whole virtual instant before re-checking the
            // trigger: simultaneous arrivals are indistinguishable in
            // virtual time, so the server sees them as one batch. This is
            // what makes the zero-latency timeline collapse onto the
            // sequential simulator's rounds.
            while self.queue.peek_time() == Some(t) {
                let ev = self.queue.pop().unwrap();
                if let Some(tl) = &mut self.timeline {
                    tl.push_event(ev.time, ev.seq, ev.kind.label(), ev.kind.index());
                }
                self.handle(ev.kind)?;
            }
        }
    }

    /// |arrivals| ≥ P and every τ−1-stale node has reported. O(1): the
    /// force-wait half is the maintained [`Self::overdue_pending`] counter
    /// (staleness only changes inside `fire`, arrivals only in `MsgArrive`,
    /// and both keep the counter in sync), so checking the trigger once per
    /// virtual instant no longer costs an O(n) staleness scan — under
    /// fragmented arrivals (≈ n instants per round) that scan made rounds
    /// O(n²).
    fn trigger_satisfied(&self) -> bool {
        let fast = self.arrived.len() >= self.cfg.p_min && self.overdue_pending == 0;
        // Cross-check against the direct scan on small fleets (debug only).
        #[cfg(debug_assertions)]
        if self.n <= 128 {
            let tau = self.cfg.tau;
            let slow = self.arrived.len() >= self.cfg.p_min
                && self
                    .scheduler
                    .staleness()
                    .iter()
                    .enumerate()
                    .all(|(i, &d)| d + 1 < tau || self.arrived.contains(&i));
            debug_assert_eq!(fast, slow, "overdue counter out of sync");
        }
        fast
    }

    fn handle(&mut self, kind: EventKind) -> anyhow::Result<()> {
        self.stats.events += 1;
        match kind {
            EventKind::ComputeDone { node } => {
                let Some(slot) = self.in_flight[node].as_deref() else {
                    anyhow::bail!("ComputeDone without outbox (node {node})");
                };
                let (skipped, bits) = (slot.skipped, slot.bits);
                // a dead-banded dispatch ships nothing: zero wire bits, no
                // message counted — only the timeline legs are traversed
                if !skipped {
                    self.accounting.record_uplink(node, bits);
                }
                let delay = self.links[node].sample_uplink(&mut self.rng_latency);
                Self::push_event(
                    &mut self.queue,
                    &mut self.stats,
                    self.vtime + delay,
                    EventKind::MsgArrive { node },
                );
            }
            EventKind::MsgArrive { node } => {
                let slot = self.in_flight[node].take().ok_or_else(|| {
                    anyhow::anyhow!("MsgArrive without payload (node {node})")
                })?;
                if slot.skipped {
                    // credit-only arrival: the node answered "nothing to
                    // report" — it counts toward P, resets its staleness,
                    // and releases the busy latch, but no bank, partial sum
                    // or accumulator moves (even under a tier: the empty
                    // report needs no aggregation hop)
                    self.arrived_loss[node] = slot.loss;
                    if self.arrived.insert(node)
                        && self.scheduler.staleness()[node] + 1 >= self.cfg.tau
                    {
                        self.overdue_pending -= 1;
                    }
                    self.busy[node] = false;
                    Self::recycle_slot(&mut self.slot_pool, slot);
                    return Ok(());
                }
                self.xhat.commit_frame(node, &slot.cx)?;
                self.uhat.commit_frame(node, &slot.cu)?;
                match &mut self.tier {
                    None => {
                        // star: the update reached the server — keep
                        // s = Σ(x̂+û) in lockstep with the bank commits,
                        // folding straight from the wire frames (O(k) for
                        // sparse compressors)
                        self.acc.fold_frames(&slot.cx, &slot.cu)?;
                        self.arrived_loss[node] = slot.loss;
                        if self.arrived.insert(node)
                            && self.scheduler.staleness()[node] + 1 >= self.cfg.tau
                        {
                            // an overdue (τ−1-stale) node just reported
                            self.overdue_pending -= 1;
                        }
                        self.busy[node] = false;
                    }
                    Some(t) => {
                        // tree/gossip: the update landed one hop down, at
                        // its aggregator; arrival credit (and the busy
                        // release) waits for the re-quantized forward to
                        // reach the server (`AggregateArrive`)
                        let agg = t.deliver(node, &slot.cx, &slot.cu, slot.loss)?;
                        self.touched_aggs.push(agg);
                    }
                }
                Self::recycle_slot(&mut self.slot_pool, slot);
            }
            EventKind::DownlinkArrive { node } => {
                // advance the node onto the next broadcast prefix state
                // (same error as the per-node FIFO raised on underflow)
                if self.mirrors.deliver(node)? {
                    self.pending_dispatch.push(node);
                }
            }
            EventKind::AggregateArrive { agg } => {
                let fw = self.agg_inbox[agg].pop_front().ok_or_else(|| {
                    anyhow::anyhow!("AggregateArrive with empty inbox (agg {agg})")
                })?;
                let tier = self.tier.as_mut().expect("AggregateArrive without a tier");
                // ŝ_g += C(Δpartial), and the global sum folds the same
                // wire frames so s keeps tracking Σ_g ŝ_g. A credit-only
                // forward (aggregator dead-band) carries empty payloads:
                // only the children's arrival credit flows.
                if !fw.cx.is_empty() {
                    tier.commit(agg, &fw.cx, &fw.cu)?;
                    self.acc.fold_frames(&fw.cx, &fw.cu)?;
                }
                let tau = self.cfg.tau;
                for (child, loss) in fw.children {
                    self.arrived_loss[child] = loss;
                    if self.arrived.insert(child)
                        && self.scheduler.staleness()[child] + 1 >= tau
                    {
                        self.overdue_pending -= 1;
                    }
                    self.busy[child] = false;
                }
            }
        }
        Ok(())
    }

    /// Check the forward condition of every aggregator touched in the
    /// instant just drained (ascending id, deduplicated) and put ready
    /// partial sums on the aggregator→server wire: compress the pending
    /// delta with the aggregator's quantizer stream (error-feedback
    /// residual stays behind), charge the frame to link n + g, and
    /// schedule `AggregateArrive` after the aggregator's uplink leg
    /// (monotone per link, like the downlink clamps).
    fn forward_ready_aggs(&mut self) {
        let mut aggs = std::mem::take(&mut self.touched_aggs);
        aggs.sort_unstable();
        aggs.dedup();
        let tier = self.tier.as_mut().expect("touched aggregators without a tier");
        for &g in &aggs {
            if !tier.ready(g) {
                // below P_g with children still in flight: the next child
                // arrival re-touches this aggregator
                continue;
            }
            // Aggregator dead-band: a ready partial below δ is withheld —
            // the children's arrival credit still travels upstream (as a
            // zero-payload, zero-bit forward: a silent aggregator may never
            // wedge the server's P/τ trigger), but the pending mass stays
            // put and no compressor or accounting runs.
            let fw = if self.trigger.delta() > 0.0
                && tier.pending_inf_norm(g) <= self.trigger.delta()
            {
                AggForward {
                    cx: Compressed::empty(),
                    cu: Compressed::empty(),
                    children: tier.credit_only_flush(g),
                }
            } else {
                let fw = tier.flush(g, self.compressor.as_ref(), &mut self.agg_quant[g]);
                self.accounting.record_uplink(
                    self.n + g,
                    MSG_HEADER_BYTES * 8 + fw.cx.wire_bits() + fw.cu.wire_bits(),
                );
                self.stats.agg_forwards += 1;
                fw
            };
            let delay = self.agg_links[g].sample_uplink(&mut self.rng_latency);
            let at = (self.vtime + delay).max(self.agg_last[g]);
            self.agg_last[g] = at;
            self.agg_inbox[g].push_back(fw);
            Self::push_event(
                &mut self.queue,
                &mut self.stats,
                at,
                EventKind::AggregateArrive { agg: g },
            );
        }
        // recycle the buffer (fragmented arrivals touch aggregators once
        // per instant, like the dispatch list)
        aggs.clear();
        if self.touched_aggs.is_empty() {
            self.touched_aggs = aggs;
        }
    }

    /// One consensus round: mirrors `AsyncSim::step`'s server phase —
    /// consensus from the incremental sum (O(m); the arrivals already
    /// folded their deltas in), compressed broadcast, scheduler advance,
    /// eval — then puts the broadcast (with the next selection's dispatch
    /// flags) on every node's downlink. The only O(n·m) work left on this
    /// path is the every-K-rounds accumulator refresh.
    fn fire(&mut self) -> anyhow::Result<()> {
        let batch = self.arrived.len();
        debug_assert!(batch >= self.cfg.p_min);
        let train_loss: f64 = self.arrived.iter().map(|&i| self.arrived_loss[i]).sum();
        // Timeline recording captures the arrival set before it is cleared
        // (ascending — BTreeSet order — exactly what the replay bridge pins).
        let tl_arrivals: Option<Vec<usize>> =
            self.timeline.as_ref().map(|_| self.arrived.iter().copied().collect());

        if self.acc.refresh_due(self.stats.rounds + 1) {
            // tree/gossip rebuild from the ŝ_g partials (O(A·m)); the star
            // streams the per-node banks (O(n·m), one materialized row at
            // a time — the serial fold order, which the sharded refresh is
            // property-pinned bitwise-equal to)
            match &self.tier {
                Some(t) => self.acc.refresh(t.refresh_rows()),
                None => {
                    self.acc.refresh_begin();
                    for i in 0..self.n {
                        self.acc.refresh_fold_row(self.xhat.row(i), self.uhat.row(i));
                    }
                }
            }
        }
        self.z = self.problem.consensus_from_sum(self.acc.sum(), self.n)?;
        let dz = self.zhat.make_delta(&self.z);
        let cz = self.compressor.compress(&dz, &mut self.server_quant);
        self.accounting.record_broadcast_to(self.n, MSG_HEADER_BYTES * 8 + cz.wire_bits());
        // The one sanctioned materialization on the hot path: the broadcast
        // payload is shared dense across all n downlinks, so decode once.
        let dz_deq = cz.dequantized()?;
        self.zhat.commit(&dz_deq);

        for (i, a) in self.arrived_mask.iter_mut().enumerate() {
            *a = self.arrived.contains(&i);
        }
        let arrived_mask = &self.arrived_mask;
        let next = self
            .scheduler
            .advance(arrived_mask, || self.oracle.sample(&mut self.rng_oracle));
        self.arrived.clear();
        self.stats.rounds += 1;
        self.stats.virtual_time = self.vtime;
        self.stats.min_arrivals =
            Some(self.stats.min_arrivals.map_or(batch, |prev| prev.min(batch)));
        let max_d = self.scheduler.staleness().iter().copied().max().unwrap_or(0);
        self.stats.max_staleness = self.stats.max_staleness.max(max_d);
        debug_assert!(max_d + 1 <= self.cfg.tau, "staleness bound violated: {max_d}");
        // The arrival set was just cleared, so the overdue count for the
        // next round is simply |{i : dᵢ = τ−1}| under the fresh staleness
        // counters (one O(n) pass per *round*, not per instant).
        let tau = self.cfg.tau;
        self.overdue_pending =
            self.scheduler.staleness().iter().filter(|&&d| d + 1 >= tau).count();

        if self.stats.rounds % self.cfg.eval_every == 0 {
            // --metrics-sample: score a deterministic k-node stride instead
            // of the full fleet (the only O(n·m) eval left at n = 10^6)
            let metrics = if self.eval_sample.is_empty() {
                self.problem.evaluate(&self.x, &self.u, &self.z)?
            } else {
                self.problem.evaluate_sample(&self.eval_sample, &self.x, &self.u, &self.z)?
            };
            self.recorder.push(IterRecord {
                iter: self.stats.rounds,
                comm_bits: self.accounting.normalized_bits(self.m),
                accuracy: metrics.accuracy,
                test_acc: metrics.test_acc,
                loss: if metrics.loss.is_nan() {
                    train_loss / batch.max(1) as f64
                } else {
                    metrics.loss
                },
                active_nodes: batch,
                wall_s: self.clock.elapsed_secs(),
            });
        }

        // Put the broadcast on every downlink. A selected idle node is
        // marked busy *now* (it cannot be re-selected while the broadcast
        // is in transit) but starts computing only when its DownlinkArrive
        // fires and its mirror has caught up.
        let mut dispatch_set: Vec<usize> = Vec::new();
        for i in 0..self.n {
            if next[i] && !self.busy[i] {
                self.busy[i] = true;
                dispatch_set.push(i);
            }
            let delay = self.links[i].sample_downlink(&mut self.rng_latency);
            let at = (self.vtime + delay).max(self.downlink_last[i]);
            self.downlink_last[i] = at;
            Self::push_event(
                &mut self.queue,
                &mut self.stats,
                at,
                EventKind::DownlinkArrive { node: i },
            );
        }
        if let Some(tl) = &mut self.timeline {
            tl.push_round(self.vtime, tl_arrivals.unwrap_or_default(), dispatch_set.clone());
        }
        // One shared Δz (and one prefix state) for all n downlinks; a
        // node's mirror advances when its DownlinkArrive fires, not here.
        self.mirrors.push_broadcast(dz_deq, dispatch_set);
        Ok(())
    }

    /// Fan the local updates of `nodes` (ascending) out through the
    /// problem's batch hook (worker-pool parallel where supported), each
    /// item reading the node's own ẑ **mirror** — never the server's
    /// `zhat`, which may be ahead of what this node has received — apply
    /// the primal/dual updates in node order, compress with per-node RNG
    /// forks, and put the messages on the virtual wire.
    fn dispatch(&mut self, nodes: &[usize]) -> anyhow::Result<()> {
        if nodes.is_empty() {
            return Ok(());
        }
        let results = {
            let u = &self.u;
            let x = &self.x;
            let zm = &self.mirrors;
            let mut items: Vec<LocalUpdateItem<'_>> = Vec::with_capacity(nodes.len());
            // O(|nodes|) carve-out of the per-node RNG forks (split_at_mut
            // is pointer arithmetic): with fragmented downlink arrivals a
            // round can flush n single-node batches, so an O(n) scan per
            // flush would make the round quadratic in n.
            let mut rest: &mut [Pcg64] = &mut self.node_batch;
            let mut offset = 0usize;
            for &i in nodes {
                let (_, tail) = rest.split_at_mut(i - offset);
                let (rng, tail) = tail.split_first_mut().expect("node id out of range");
                items.push(LocalUpdateItem {
                    node: i,
                    zhat: zm.row(i),
                    u: u.row(i),
                    x_prev: x.row(i),
                    rng,
                });
                rest = tail;
                offset = i + 1;
            }
            self.problem.local_update_batch(&mut items)?
        };
        anyhow::ensure!(results.len() == nodes.len(), "batch result count mismatch");
        for (&node, (x_new, loss)) in nodes.iter().zip(results) {
            anyhow::ensure!(x_new.len() == self.m, "local_update wrong dim");
            // eq. (9b): u ← u + (x_new − ẑᵢ), against the node's mirror
            {
                let zrow = self.mirrors.row(node);
                let urow = self.u.row_mut(node);
                for j in 0..self.m {
                    urow[j] += x_new[j] - zrow[j];
                }
            }
            self.x.row_mut(node).copy_from_slice(&x_new);
            // eqs. (10)–(14) under the optional event trigger: peek both
            // EF-adjusted deltas against the node's estimate banks (== the
            // server banks: its previous update has landed), and below the
            // dead-band dispatch a *skipped* slot — same compute/uplink
            // timeline, but no frame, no quantizer draw, no bank mutation.
            // peek + note_sent == the old make_delta, so the disabled path
            // is byte-for-byte the pre-trigger behavior; all buffers stay
            // pooled (no steady-state allocation on this path).
            debug_assert!(self.in_flight[node].is_none(), "dispatch into an occupied outbox");
            let mut slot =
                self.slot_pool.pop().unwrap_or_else(|| Box::new(InFlightSlot::empty()));
            self.xhat.peek_delta_into(node, self.x.row(node), &mut self.delta_buf);
            self.uhat.peek_delta_into(node, self.u.row(node), &mut self.delta_buf_u);
            let skip = if self.trigger.enabled() {
                let norm = inf_norm(&self.delta_buf).max(inf_norm(&self.delta_buf_u));
                self.trigger.observe(node, norm);
                !self.trigger.should_send(norm)
            } else {
                false
            };
            if skip {
                self.trigger.note_skip();
                slot.cx.wire.clear();
                slot.cu.wire.clear();
                slot.bits = 0;
            } else {
                self.xhat.note_sent(node, self.x.row(node));
                self.uhat.note_sent(node, self.u.row(node));
                match self.trigger.compressor_for(node) {
                    // adaptive schedule: this node's current QSGD width
                    Some(q) => {
                        q.compress_into(
                            &self.delta_buf,
                            &mut self.node_quant[node],
                            &mut slot.cx,
                        );
                        q.compress_into(
                            &self.delta_buf_u,
                            &mut self.node_quant[node],
                            &mut slot.cu,
                        );
                    }
                    None => {
                        self.compressor.compress_into(
                            &self.delta_buf,
                            &mut self.node_quant[node],
                            &mut slot.cx,
                        );
                        self.compressor.compress_into(
                            &self.delta_buf_u,
                            &mut self.node_quant[node],
                            &mut slot.cu,
                        );
                    }
                }
                slot.bits =
                    MSG_HEADER_BYTES * 8 + slot.cx.wire_bits() + slot.cu.wire_bits();
            }
            slot.loss = loss;
            slot.skipped = skip;
            self.in_flight[node] = Some(slot);
            self.busy[node] = true;
            self.stats.dispatches += 1;
            // non-star fan-in: bind this update to its aggregator now (the
            // same per-dispatch draw order the simulator uses, so gossip
            // routes replay identically at zero link delay). A skipped
            // dispatch routes nowhere — its credit-only arrival goes
            // straight to the server.
            if !skip {
                if let Some(t) = &mut self.tier {
                    t.route(node, &mut self.rng_topology);
                }
            }
            let delay = self.links[node].sample_compute(&mut self.rng_latency);
            Self::push_event(
                &mut self.queue,
                &mut self.stats,
                self.vtime + delay,
                EventKind::ComputeDone { node },
            );
        }
        Ok(())
    }

    pub fn run(mut self, rounds: usize) -> anyhow::Result<RunRecorder> {
        for _ in 0..rounds {
            self.step_round()?;
        }
        Ok(self.recorder)
    }

    // ---- state accessors (tests + invariant checks) ----

    pub fn z(&self) -> &[f64] {
        &self.z
    }

    pub fn accounting(&self) -> &CommAccounting {
        &self.accounting
    }

    pub fn recorder(&self) -> &RunRecorder {
        &self.recorder
    }

    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    pub fn virtual_time(&self) -> f64 {
        self.vtime
    }

    pub fn staleness(&self) -> &[usize] {
        self.scheduler.staleness()
    }

    /// Node `i`'s current view of ẑ (advances only on downlink arrival).
    pub fn z_mirror(&self, node: usize) -> &[f64] {
        self.mirrors.row(node)
    }

    /// The server's own ẑ estimate (what the mirrors converge to once
    /// every broadcast has landed).
    pub fn z_estimate(&self) -> &[f64] {
        self.zhat.estimate()
    }

    /// The aggregator tier, when a non-star topology owns the fan-in
    /// (conservation property tests read its tracked mass).
    pub fn tier(&self) -> Option<&AggregatorTier> {
        self.tier.as_ref()
    }

    /// Event-trigger / adaptive-schedule state (skip counters, per-node
    /// bit widths).
    pub fn trigger(&self) -> &TriggerState {
        &self.trigger
    }

    /// Σ per coordinate of everything the fan-in currently holds:
    /// committed partials ŝ_g + pending buffers + forwards still on the
    /// aggregator→server wire. At any instant this equals
    /// Σ_leaves(x̂ᵢ + ûᵢ) to Kahan precision — re-quantization shuffles
    /// error into the pending residuals, it never creates or destroys
    /// mass (the conservation half of the gossip property tests).
    pub fn fan_in_tracked_mass(&self) -> Option<Vec<f64>> {
        let t = self.tier.as_ref()?;
        let mut mass = t.tracked_mass();
        for inbox in &self.agg_inbox {
            for fw in inbox {
                for c in [&fw.cx, &fw.cu] {
                    if c.is_empty() {
                        continue; // credit-only forward
                    }
                    c.for_each_entry(|j, v| mass[j] += v)
                        .expect("in-flight forward frame must decode");
                }
            }
        }
        Some(mass)
    }

    /// Node i's x̂ estimate bank (the lossless state of its first hop).
    /// Owned: the quantized-at-rest bank materializes the row on demand
    /// (`&mut` for the scratch-pool LRU), bit-identical to the dense bank.
    pub fn x_estimate(&mut self, i: usize) -> Vec<f64> {
        self.xhat.estimate(i)
    }

    /// Node i's û estimate bank.
    pub fn u_estimate(&mut self, i: usize) -> Vec<f64> {
        self.uhat.estimate(i)
    }

    // ---- snapshot / resume / timeline recording ----

    /// Start recording the realized timeline (event stream + per-round
    /// arrival/dispatch sets). Rounds fired before this call are not in
    /// the recording.
    pub fn record_timeline(&mut self) {
        self.timeline = Some(RecordedTimeline::new("event", self.n, self.cfg.seed));
    }

    /// Take the recording accumulated so far (ends recording).
    pub fn take_timeline(&mut self) -> Option<RecordedTimeline> {
        self.timeline.take()
    }

    /// Human-readable header for a snapshot taken now.
    pub fn snapshot_meta(&self) -> SnapshotMeta {
        SnapshotMeta {
            engine: "event".into(),
            round: self.stats.rounds,
            n: self.n,
            m: self.m,
            seed: self.cfg.seed,
            config: self.cfg.to_json(),
        }
    }

    /// Serialize the complete mutable run state — arenas, estimate banks,
    /// accumulator (with Kahan compensations), ẑ mirrors, FIFO inboxes and
    /// monotone clamps, aggregator tier, in-flight slots, arrival set and
    /// overdue counter, scheduler, oracle, accounting, the event queue
    /// with its seq counter, every RNG stream, the metric series, virtual
    /// time and stats — into one binary body for
    /// [`crate::snapshot::encode`]. Everything else (compressor, link
    /// profiles, scratch buffers) is a pure function of the config and is
    /// rebuilt by [`Self::resume`]. Call between rounds (after
    /// [`Self::step_round`] returns), which is the only boundary the
    /// bit-identity contract is defined at.
    pub fn snapshot_body(&self) -> Vec<u8> {
        let mut w = Writer::new();
        self.write_snapshot_body(&mut w);
        w.into_inner()
    }

    /// [`Self::snapshot_body`] into a caller-supplied [`Writer`] — the
    /// streamed-checkpoint entry point: with a spill sink attached
    /// ([`Writer::with_sink`]) the body flushes in bounded chunks instead
    /// of materializing all ~O(n·m) bytes, so checkpointing an n = 10^6
    /// run does not double peak RSS. The byte stream is identical either
    /// way (the parity suites pin the resumed trajectory).
    pub fn write_snapshot_body(&self, w: &mut Writer) {
        self.x.pack(w);
        self.u.pack(w);
        self.z.pack(w);
        self.xhat.pack(w);
        self.uhat.pack(w);
        self.zhat.pack(w);
        self.acc.pack(w);
        self.mirrors.pack(w);
        self.downlink_last.pack(w);
        self.pending_dispatch.pack(w);
        self.tier.pack(w);
        self.touched_aggs.pack(w);
        self.agg_inbox.pack(w);
        self.agg_last.pack(w);
        self.rng_topology.pack(w);
        self.arrived.pack(w);
        w.put_usize(self.overdue_pending);
        self.busy.pack(w);
        self.in_flight.pack(w);
        self.arrived_loss.pack(w);
        self.scheduler.pack(w);
        self.oracle.pack(w);
        self.accounting.pack(w);
        self.queue.pack(w);
        self.rng_latency.pack(w);
        self.rng_oracle.pack(w);
        self.node_quant.pack(w);
        self.server_quant.pack(w);
        self.agg_quant.pack(w);
        self.node_batch.pack(w);
        self.recorder.pack(w);
        self.trigger.pack(w);
        w.put_f64(self.vtime);
        self.stats.pack(w);
    }

    /// Rebuild an engine from a [`Self::snapshot_body`], continuing the
    /// interrupted timeline **bit-identically**. The problem must be
    /// re-derived from the same seed (the snapshot stores no problem
    /// data); config-derived state (compressor, link profiles) is rebuilt
    /// from `cfg`, which the caller must have validated against the
    /// snapshot header's config digest.
    pub fn resume(
        cfg: &'a ExperimentConfig,
        problem: &'a mut dyn Problem,
        body: &[u8],
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        let m = problem.dim();
        let n = problem.n_nodes();
        let n_aggs = cfg.topology.n_aggregators(n);
        let mut r = Reader::new(body);

        let x = Arena::unpack(&mut r)?;
        let u = Arena::unpack(&mut r)?;
        let z = Vec::<f64>::unpack(&mut r)?;
        let xhat = QuantBank::unpack(&mut r)?;
        let uhat = QuantBank::unpack(&mut r)?;
        let zhat = EstimateTracker::unpack(&mut r)?;
        let acc = ConsensusAccumulator::unpack(&mut r)?;
        let mirrors = MirrorTable::unpack(&mut r)?;
        let downlink_last = Vec::<f64>::unpack(&mut r)?;
        let pending_dispatch = Vec::<usize>::unpack(&mut r)?;
        let tier = Option::<AggregatorTier>::unpack(&mut r)?;
        let touched_aggs = Vec::<usize>::unpack(&mut r)?;
        let agg_inbox = Vec::<VecDeque<AggForward>>::unpack(&mut r)?;
        let agg_last = Vec::<f64>::unpack(&mut r)?;
        let rng_topology = Pcg64::unpack(&mut r)?;
        let arrived = BTreeSet::<usize>::unpack(&mut r)?;
        let overdue_pending = r.get_usize()?;
        let busy = Vec::<bool>::unpack(&mut r)?;
        let in_flight = Vec::<Option<Box<InFlightSlot>>>::unpack(&mut r)?;
        let arrived_loss = Vec::<f64>::unpack(&mut r)?;
        let scheduler = Scheduler::unpack(&mut r)?;
        let oracle = AsyncOracle::unpack(&mut r)?;
        let accounting = CommAccounting::unpack(&mut r)?;
        let queue = EventQueue::unpack(&mut r)?;
        let rng_latency = Pcg64::unpack(&mut r)?;
        let rng_oracle = Pcg64::unpack(&mut r)?;
        let node_quant = Vec::<Pcg64>::unpack(&mut r)?;
        let server_quant = Pcg64::unpack(&mut r)?;
        let agg_quant = Vec::<Pcg64>::unpack(&mut r)?;
        let node_batch = Vec::<Pcg64>::unpack(&mut r)?;
        let recorder = RunRecorder::unpack(&mut r)?;
        let trigger = TriggerState::unpack(&mut r)?;
        let vtime = r.get_f64()?;
        let stats = EngineStats::unpack(&mut r)?;
        r.finish()?;

        // ---- cross-validate the state against the problem + config ----
        let dims_ok = |a: &Arena, what: &str| -> anyhow::Result<()> {
            anyhow::ensure!(
                a.n_rows() == n && a.dim() == m,
                "snapshot {what} is {}x{}, problem is {n}x{m}",
                a.n_rows(),
                a.dim()
            );
            Ok(())
        };
        dims_ok(&x, "x")?;
        dims_ok(&u, "u")?;
        anyhow::ensure!(z.len() == m, "snapshot z has wrong dimension");
        anyhow::ensure!(
            xhat.len() == n && uhat.len() == n,
            "snapshot estimate banks sized for a different fleet"
        );
        anyhow::ensure!(
            xhat.dim() == m && uhat.dim() == m && zhat.estimate().len() == m,
            "snapshot estimate bank wrong dim"
        );
        anyhow::ensure!(
            xhat.feedback_enabled() == cfg.error_feedback
                && uhat.feedback_enabled() == cfg.error_feedback
                && zhat.feedback_enabled() == cfg.error_feedback,
            "snapshot was taken with error feedback {}",
            if cfg.error_feedback { "off" } else { "on" }
        );
        anyhow::ensure!(acc.dim() == m, "snapshot accumulator wrong dim");
        anyhow::ensure!(
            mirrors.n_nodes() == n && mirrors.dim() == m,
            "snapshot mirror table is {}x{}, problem is {n}x{m}",
            mirrors.n_nodes(),
            mirrors.dim()
        );
        anyhow::ensure!(
            downlink_last.len() == n
                && busy.len() == n
                && in_flight.len() == n
                && arrived_loss.len() == n
                && node_quant.len() == n
                && node_batch.len() == n,
            "snapshot per-node tables sized for a different fleet"
        );
        for slot in in_flight.iter().flatten() {
            if slot.skipped {
                anyhow::ensure!(
                    slot.bits == 0 && slot.cx.is_empty(),
                    "snapshot skipped in-flight slot must carry no payload"
                );
            } else {
                anyhow::ensure!(
                    slot.cx.frame_dim()? == m && slot.cu.frame_dim()? == m,
                    "snapshot in-flight payload wrong dim"
                );
            }
        }
        anyhow::ensure!(
            tier.is_some() == (n_aggs > 0),
            "snapshot topology ({}) disagrees with config ({})",
            if tier.is_some() { "tiered" } else { "star" },
            cfg.topology.label()
        );
        if let Some(t) = &tier {
            anyhow::ensure!(
                t.kind() == cfg.topology
                    && t.p_tier() == cfg.p_tier.max(1)
                    && t.error_feedback() == cfg.error_feedback,
                "snapshot tier parameters disagree with config"
            );
            anyhow::ensure!(t.n_aggregators() == n_aggs, "snapshot tier aggregator count");
        }
        anyhow::ensure!(
            agg_inbox.len() == n_aggs && agg_last.len() == n_aggs && agg_quant.len() == n_aggs,
            "snapshot aggregator tables sized for a different tier"
        );
        // forwards still on the aggregator→server wire must be usable as-is:
        // their payloads fold into m-dim banks and their children index
        // per-node tables, so bad values must be Err here, not a panic at
        // the next AggregateArrive
        for inbox in &agg_inbox {
            for fw in inbox {
                // credit-only forwards (aggregator dead-band) are empty
                anyhow::ensure!(
                    (fw.cx.is_empty() && fw.cu.is_empty())
                        || (fw.cx.frame_dim()? == m && fw.cu.frame_dim()? == m),
                    "snapshot aggregator forward payload wrong dim"
                );
                anyhow::ensure!(
                    fw.children.iter().all(|(leaf, _)| *leaf < n),
                    "snapshot aggregator forward credits a leaf out of range"
                );
            }
        }
        anyhow::ensure!(
            scheduler.staleness().len() == n
                && scheduler.tau() == cfg.tau
                && scheduler.p_min() == cfg.p_min,
            "snapshot scheduler disagrees with config"
        );
        anyhow::ensure!(oracle.fast_mask().len() == n, "snapshot oracle wrong fleet size");
        anyhow::ensure!(
            accounting.n_nodes() == n + n_aggs,
            "snapshot accounting has {} links, expected {}",
            accounting.n_nodes(),
            n + n_aggs
        );
        anyhow::ensure!(
            arrived.iter().all(|&i| i < n)
                && pending_dispatch.iter().all(|&i| i < n)
                && touched_aggs.iter().all(|&g| g < n_aggs),
            "snapshot pending sets out of range"
        );
        for ev in queue.events() {
            let ok = match ev.kind {
                EventKind::ComputeDone { node }
                | EventKind::MsgArrive { node }
                | EventKind::DownlinkArrive { node } => node < n,
                EventKind::AggregateArrive { agg } => tier.is_some() && agg < n_aggs,
            };
            anyhow::ensure!(ok, "snapshot event {:?} out of range", ev.kind);
        }
        anyhow::ensure!(
            vtime.is_finite() && vtime >= 0.0,
            "snapshot virtual time {vtime} invalid"
        );
        anyhow::ensure!(
            trigger.matches(cfg, n),
            "snapshot trigger/adaptive-schedule state disagrees with config"
        );

        Ok(Self {
            compressor: cfg.compressor.build(),
            m,
            n,
            x,
            u,
            z,
            xhat,
            uhat,
            zhat,
            acc,
            mirrors,
            downlink_last,
            pending_dispatch,
            tier,
            touched_aggs,
            agg_inbox,
            agg_last,
            agg_links: per_node_profiles(cfg.link, n_aggs),
            rng_topology,
            arrived,
            overdue_pending,
            busy,
            in_flight,
            slot_pool: Vec::new(),
            arrived_loss,
            delta_buf: Vec::with_capacity(m),
            delta_buf_u: Vec::with_capacity(m),
            arrived_mask: vec![false; n],
            trigger,
            scheduler,
            oracle,
            accounting,
            queue,
            server_quant,
            agg_quant,
            links: per_node_profiles(cfg.link, n),
            rng_latency,
            rng_oracle,
            node_quant,
            node_batch,
            recorder,
            eval_sample: Self::eval_sample_for(cfg, n),
            clock: Stopwatch::new(),
            vtime,
            stats,
            timeline: None,
            cfg,
            problem,
        })
    }

    /// FNV digest over the raw state of every RNG stream the engine owns —
    /// the "final RNG states" leg of the resume-parity contract.
    pub fn rng_digest(&self) -> u64 {
        let mut w = Writer::new();
        self.rng_latency.pack(&mut w);
        self.rng_oracle.pack(&mut w);
        self.rng_topology.pack(&mut w);
        self.server_quant.pack(&mut w);
        self.node_quant.pack(&mut w);
        self.agg_quant.pack(&mut w);
        self.node_batch.pack(&mut w);
        crate::snapshot::codec::fnv1a64(w.as_slice())
    }
}

impl Pack for EngineStats {
    fn pack(&self, w: &mut Writer) {
        w.put_usize(self.rounds);
        w.put_f64(self.virtual_time);
        w.put_u64(self.events);
        w.put_u64(self.dispatches);
        w.put_u64(self.agg_forwards);
        self.min_arrivals.pack(w);
        w.put_usize(self.max_staleness);
        w.put_usize(self.queue_peak);
        w.put_u64(self.events_scheduled);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        Ok(Self {
            rounds: r.get_usize()?,
            virtual_time: r.get_f64()?,
            events: r.get_u64()?,
            dispatches: r.get_u64()?,
            agg_forwards: r.get_u64()?,
            min_arrivals: Option::<usize>::unpack(r)?,
            max_staleness: r.get_usize()?,
            queue_peak: r.get_usize()?,
            events_scheduled: r.get_u64()?,
        })
    }
}

impl Pack for InFlightSlot {
    fn pack(&self, w: &mut Writer) {
        self.cx.pack(w);
        self.cu.pack(w);
        w.put_u64(self.bits);
        w.put_f64(self.loss);
        w.put_bool(self.skipped);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        Ok(Self {
            cx: Compressed::unpack(r)?,
            cu: Compressed::unpack(r)?,
            bits: r.get_u64()?,
            loss: r.get_f64()?,
            skipped: r.get_bool()?,
        })
    }
}

impl Pack for Box<InFlightSlot> {
    fn pack(&self, w: &mut Writer) {
        (**self).pack(w);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        Ok(Box::new(InFlightSlot::unpack(r)?))
    }
}

/// Snapshots store the mirror window as its *history* — the oldest retained
/// state plus each broadcast's Δz in commit order — and restore replays the
/// same `clone-then-+=` walk that built the in-memory states, so the
/// restored window is bitwise identical to the live one.
impl Pack for MirrorTable {
    fn pack(&self, w: &mut Writer) {
        w.put_usize(self.n);
        w.put_u64(self.base_idx);
        self.states[0].pack(w);
        w.put_usize(self.recs.len());
        for rec in &self.recs {
            rec.dz.pack(w);
            rec.dispatch.pack(w);
        }
        self.applied.pack(w);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let n = r.get_usize()?;
        let base_idx = r.get_u64()?;
        let front = Vec::<f64>::unpack(r)?;
        let m = front.len();
        let n_recs = r.get_len()?;
        let mut states = VecDeque::with_capacity(n_recs + 1);
        states.push_back(front);
        let mut recs = VecDeque::with_capacity(n_recs);
        for _ in 0..n_recs {
            let dz = Vec::<f64>::unpack(r)?;
            anyhow::ensure!(
                dz.len() == m,
                "snapshot mirror broadcast has {} coords, table is {m}-dimensional",
                dz.len()
            );
            let dispatch = Vec::<usize>::unpack(r)?;
            anyhow::ensure!(
                dispatch.windows(2).all(|w| w[0] < w[1])
                    && dispatch.last().map_or(true, |&i| i < n),
                "snapshot mirror dispatch set is not a sorted subset of 0..{n}"
            );
            let mut next = states.back().expect("states is never empty").clone();
            for (s, d) in next.iter_mut().zip(dz.iter()) {
                *s += *d;
            }
            states.push_back(next);
            recs.push_back(BroadcastRec { dz, dispatch, remaining: 0 });
        }
        let applied = Vec::<u64>::unpack(r)?;
        anyhow::ensure!(
            applied.len() == n,
            "snapshot mirror table tracks {} nodes, expected {n}",
            applied.len()
        );
        for &a in &applied {
            anyhow::ensure!(
                a >= base_idx && a - base_idx <= n_recs as u64,
                "snapshot mirror cursor {a} outside retained window \
                 [{base_idx}, {}]",
                base_idx + n_recs as u64
            );
        }
        for (k, rec) in recs.iter_mut().enumerate() {
            rec.remaining =
                applied.iter().filter(|&&a| a <= base_idx + k as u64).count();
        }
        if let Some(front_rec) = recs.front() {
            anyhow::ensure!(
                front_rec.remaining > 0,
                "snapshot mirror window retains a fully-applied broadcast"
            );
        }
        Ok(Self { m, n, base_idx, states, recs, applied })
    }
}
