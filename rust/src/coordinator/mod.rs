//! Threaded deployment of QADMM: a real server thread + N node worker
//! threads over the accounted star network, with injected per-node latency
//! (stragglers) and genuine asynchrony — the server triggers on `P`
//! arrivals and waits for nodes whose staleness hits τ−1.
//!
//! The sequential simulator ([`crate::admm::sim`]) is the reproducible
//! engine behind the figures; this module is the *deployment* shape: the
//! same state machines driven by actual message arrival order. HLO compute
//! is served by the [`crate::runtime::service::ComputeService`] thread (the
//! PJRT client is not `Send`), and node threads hold `ComputeClient`s.

pub mod node;
pub mod server;

use std::sync::{Arc, Mutex};

use crate::comm::network::{self, FaultSpec};
use crate::comm::profile::{per_node_profiles, LinkProfile};
use crate::config::ExperimentConfig;
use crate::metrics::RunRecorder;
use crate::problems::Problem;
use crate::snapshot::timeline::RecordedTimeline;
use crate::topology::TopologyKind;
use crate::util::rng::Pcg64;

/// Problems are shared behind a mutex: node threads lock for their own
/// `local_update` (per-node state inside the problem is disjoint, and on
/// this testbed compute is serialized by the single PJRT service anyway).
pub type SharedProblem = Arc<Mutex<Box<dyn Problem + Send>>>;

pub struct ThreadedOutcome {
    pub recorder: RunRecorder,
    /// Total bits on the wire, normalized by M (eq. 20).
    pub normalized_bits: f64,
    pub uplink_bits: u64,
    pub downlink_bits: u64,
    /// Replay mode only: the arrival set each fired round folded
    /// (ascending), which must equal the recording's round list verbatim
    /// — the contract `tests/snapshot_parity.rs` enforces. Empty for
    /// normal (non-replay) runs.
    pub round_arrivals: Vec<Vec<usize>>,
}

/// Run a full threaded deployment for `cfg.iters` server rounds.
pub fn run_threaded(
    cfg: &ExperimentConfig,
    problem: Box<dyn Problem + Send>,
    faults: FaultSpec,
) -> anyhow::Result<ThreadedOutcome> {
    run_threaded_inner(cfg, problem, faults, None)
}

/// Replay a recorded event-engine timeline through the threaded runtime:
/// the server folds exactly the recording's per-round arrival sets (early
/// arrivals are held back, see `server::ServerLoop::gather_replay`) and
/// fires exactly its round count, so a deployment-shaped run reproduces
/// the straggler schedule the virtual-time engine discovered — with **no
/// injected wall-clock sleeps** (the recording already encodes who was
/// late; sleeping through the delays again would only slow the replay).
///
/// Scope: star fan-in only (aggregator routing consumes RNG draws the
/// recording never made), and the fleet size must match the recording.
/// The replay reproduces the *schedule* — arrival sets and round count —
/// not the engine's bit-exact z trajectory: the threaded runtime folds
/// within a round in real arrival order, which bit-identity was never
/// claimed for (see `ROADMAP.md`).
pub fn run_threaded_replay(
    cfg: &ExperimentConfig,
    problem: Box<dyn Problem + Send>,
    faults: FaultSpec,
    timeline: &RecordedTimeline,
) -> anyhow::Result<ThreadedOutcome> {
    anyhow::ensure!(
        timeline.engine == "event",
        "replay needs an event-engine recording (got '{}')",
        timeline.engine
    );
    anyhow::ensure!(
        timeline.n == problem.n_nodes(),
        "recording is for n={} nodes, problem has n={}",
        timeline.n,
        problem.n_nodes()
    );
    anyhow::ensure!(
        cfg.topology == TopologyKind::Star,
        "timeline replay drives the star fan-in only (topology={} routes through \
         aggregators whose RNG draws the recording does not contain)",
        cfg.topology.label()
    );
    run_threaded_inner(cfg, problem, faults, Some(timeline))
}

fn run_threaded_inner(
    cfg: &ExperimentConfig,
    problem: Box<dyn Problem + Send>,
    faults: FaultSpec,
    replay: Option<&RecordedTimeline>,
) -> anyhow::Result<ThreadedOutcome> {
    cfg.validate()?;
    let n = problem.n_nodes();
    let m = problem.dim();
    let mut root = Pcg64::seed_from_u64(cfg.seed ^ 0x7468_7265_6164);
    let mut init_rng = root.fork(100);

    // Per-node link profiles: half the nodes are "slow" with 4x the
    // configured delay on every leg (compute / uplink / downlink) plus a
    // deterministic clock-drift spread, mirroring the heterogeneous-network
    // motivation. (The old n ≤ 64 cap is gone: inclusion travels as a
    // sparse id set, and node counts are bounded only by thread resources —
    // virtual-time runs at 1000+ nodes belong to admm::engine.)
    // Under replay every injected sleep is dropped: the recorded schedule,
    // not the wall clock, decides which round an update lands in.
    let profiles: Vec<LinkProfile> = if replay.is_some() {
        vec![LinkProfile::none(); n]
    } else {
        per_node_profiles(cfg.link, n)
    };

    // Non-star topologies colocate the aggregator tier with the server
    // thread (see `server::ServerLoop`); each aggregator still gets its
    // own accounted link after the n node links.
    let n_aggs = cfg.topology.n_aggregators(n);
    let (server_ep, node_eps, accounting) =
        network::star(n, &profiles, faults, cfg.seed, n_aggs);
    let shared: SharedProblem = Arc::new(Mutex::new(problem));

    // Initial state (Algorithm 1 lines 1–9) is assembled centrally and the
    // full-precision init exchange accounted explicitly by the server.
    let x0 = shared.lock().unwrap().init_x(&mut init_rng);

    let mut handles = Vec::new();
    for ep in node_eps {
        let rng = root.fork(200 + ep.node as u64);
        let worker = node::NodeWorker::new(ep, shared.clone(), cfg, x0.clone(), rng);
        handles.push(
            std::thread::Builder::new()
                .name(format!("qadmm-node-{}", worker.node_id()))
                .spawn(move || worker.run())?,
        );
    }

    let mut srv = server::ServerLoop::new(
        server_ep,
        shared,
        accounting.clone(),
        cfg,
        x0,
        m,
        root.fork(300),
    );
    if let Some(tl) = replay {
        srv.set_replay(tl.rounds.iter().map(|r| r.arrivals.clone()).collect());
    }
    let out = srv.run()?;

    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("node thread panicked"))??;
    }
    let acc = accounting.lock().unwrap();
    Ok(ThreadedOutcome {
        recorder: out.recorder,
        normalized_bits: acc.normalized_bits(m),
        uplink_bits: acc.total_uplink_bits(),
        downlink_bits: acc.total_downlink_bits(),
        round_arrivals: out.round_arrivals,
    })
}
