//! Deploy smoke: the end-to-end falsifiability check behind the CI
//! `deploy-smoke` job. For each transport (Unix-domain socket, TCP on
//! localhost) it runs a real `serve` with a fleet of workers — OS
//! processes via the `qadmm worker` subcommand in CI, in-process threads
//! under `--threads`/cargo tests — solves the ci LASSO instance to a
//! target suboptimality, and then asserts the three claims the deploy
//! runtime makes:
//!
//! 1. **byte reconciliation** — per link and direction, raw socket bytes
//!    equal charged eq. (20) bits/8 plus the closed-form framing extras,
//!    *exactly* ([`crate::deploy::reconcile`]);
//! 2. **capture→replay** — the timeline the server recorded replays
//!    offline through [`crate::admm::replay`] with identical per-round
//!    arrival sets and no cadence violation;
//! 3. **convergence** — the deployment actually solves the problem (final
//!    eq. (19) suboptimality below the target), so 1–2 are claims about a
//!    working run, not a stalled one.

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::Mutex;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::admm::replay::replay_timeline;
use crate::admm::runner::trial_seed;
use crate::admm::sim::TrialRngs;
use crate::config::{presets, Backend, ExperimentConfig, ProblemKind};
use crate::deploy::server::{serve, serve_tuned, ReactorOptions, ServeOptions, ServeReport};
use crate::deploy::transport::Endpoint;
use crate::deploy::worker::{run_worker, WorkerOptions, WorkerReport};
use crate::problems::lasso::{LassoConfig, LassoProblem};
use crate::problems::Problem;

pub struct DeploySmokeOptions {
    /// Fleet size (worker count == LASSO node count).
    pub nodes: usize,
    pub iters: usize,
    /// Final eq. (19) suboptimality the deployment must reach.
    pub target: f64,
    /// `Some(exe)`: spawn one OS process per worker via `exe worker …`
    /// (the CI shape). `None`: in-process worker threads.
    pub worker_exe: Option<PathBuf>,
}

impl Default for DeploySmokeOptions {
    fn default() -> Self {
        Self { nodes: 8, iters: 150, target: 1e-3, worker_exe: None }
    }
}

/// The smoke configuration: the ci LASSO preset scaled to the requested
/// fleet. Workers launched as processes rebuild this from
/// `--preset ci-lasso --nodes N` — `iters` is deliberately excluded from
/// the handshake digest (run length is the server's business; the `last`
/// flag tells workers when to stop).
pub fn smoke_cfg(nodes: usize, iters: usize) -> ExperimentConfig {
    let mut cfg = presets::ci_lasso();
    cfg.name = "deploy-smoke".into();
    cfg.iters = iters;
    if let ProblemKind::Lasso { n, .. } = &mut cfg.problem {
        *n = nodes;
    }
    cfg
}

/// Build the problem a deploy endpoint runs: native LASSO, seeded exactly
/// like trial 0 of the in-process engines so server, workers, and the
/// offline replay all regenerate identical data from the config alone.
pub fn make_native_problem(cfg: &ExperimentConfig) -> Result<Box<dyn Problem + Send>> {
    ensure!(
        cfg.backend == Backend::Native,
        "deploy endpoints rebuild the problem from the config; that requires \
         the native backend (HLO execs are not shareable across processes)"
    );
    let ProblemKind::Lasso { m, h, n, rho, theta } = cfg.problem.clone() else {
        bail!("deploy currently serves native LASSO (NN problems need the PJRT service)")
    };
    let mut rngs = TrialRngs::new(trial_seed(cfg.seed, 0));
    let p = LassoProblem::generate(LassoConfig { m, h, n, rho, theta }, &mut rngs.data)?;
    Ok(Box::new(p))
}

/// Run the full smoke over both transports.
pub fn run(opts: &DeploySmokeOptions) -> Result<()> {
    let sock = std::env::temp_dir().join(format!("qadmm-smoke-{}.sock", std::process::id()));
    let transports = [
        Endpoint::Uds(sock),
        Endpoint::Tcp("127.0.0.1:0".into()), // port 0: kernel-assigned
    ];
    for listen in &transports {
        println!("== deploy smoke over {} ==", listen.label());
        run_one(listen, opts)?;
    }
    println!("deploy smoke OK: both transports reconciled and replayed");
    Ok(())
}

fn run_one(listen: &Endpoint, opts: &DeploySmokeOptions) -> Result<()> {
    let cfg = smoke_cfg(opts.nodes, opts.iters);
    let report = match &opts.worker_exe {
        Some(exe) => serve_with_processes(&cfg, listen, exe, opts.nodes)?,
        None => serve_with_threads(&cfg, listen, opts.nodes, &ServeOptions::default())?,
    };

    // (1) exact byte reconciliation, per link, both directions
    crate::deploy::reconcile(&report.books, &report.accounting)
        .context("socket byte counters drifted from the charged eq. (20) bits")?;
    let (up, down): (u64, u64) = report
        .books
        .iter()
        .fold((0, 0), |(u, d), b| (u + b.up_total, d + b.down_total));

    // (2) capture -> replay with identical arrival sets
    let rp = replay_timeline(&cfg, make_native_problem(&cfg)?, &report.timeline)
        .context("recorded deploy timeline did not replay")?;
    let recorded: Vec<&[usize]> =
        report.timeline.rounds.iter().map(|r| r.arrivals.as_slice()).collect();
    let realized: Vec<&[usize]> =
        rp.round_arrivals.iter().map(|a| a.as_slice()).collect();
    ensure!(
        recorded == realized,
        "replay arrival sets diverged from the recording"
    );

    // (3) the run converged
    let last = report
        .recorder
        .records
        .last()
        .ok_or_else(|| anyhow::anyhow!("server recorded no iterations"))?;
    ensure!(
        last.accuracy <= opts.target,
        "deployment finished at suboptimality {:.3e} > target {:.1e}",
        last.accuracy,
        opts.target
    );

    println!(
        "   {} rounds in {:.2}s ({:.1} rounds/s), {} B up / {} B down, \
         final accuracy {:.3e}, replay {} rounds OK",
        report.timeline.rounds.len(),
        report.wall_s,
        report.timeline.rounds.len() as f64 / report.wall_s.max(1e-9),
        up,
        down,
        last.accuracy,
        rp.round_arrivals.len(),
    );
    Ok(())
}

/// Serve with `nodes` in-process worker threads against the socket — the
/// loadgen shape (`qadmm serve --loadgen N`) and the cargo-test shape of
/// the smoke. Joins the fleet and insists every worker drained cleanly.
pub fn serve_with_threads(
    cfg: &ExperimentConfig,
    listen: &Endpoint,
    nodes: usize,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    serve_with_threads_tuned(cfg, listen, nodes, opts, &ReactorOptions::default())
}

/// [`serve_with_threads`] with explicit reactor tuning (shard count,
/// write-queue bound) — the loadgen sweep and the reactor tests use this.
pub fn serve_with_threads_tuned(
    cfg: &ExperimentConfig,
    listen: &Endpoint,
    nodes: usize,
    opts: &ServeOptions,
    reactor: &ReactorOptions,
) -> Result<ServeReport> {
    let handles: Mutex<Vec<JoinHandle<Result<WorkerReport>>>> = Mutex::new(Vec::new());
    let report = serve_tuned(
        cfg,
        make_native_problem(cfg)?,
        listen,
        opts,
        reactor,
        |ep| {
            let mut hs = handles.lock().unwrap();
            for node in 0..nodes {
                let (cfg, ep) = (cfg.clone(), ep.clone());
                let problem = make_native_problem(&cfg)?;
                hs.push(std::thread::spawn(move || {
                    run_worker(&cfg, problem, &ep, &WorkerOptions::new(node))
                }));
            }
            Ok(())
        },
    )?;
    for (node, h) in handles.into_inner().unwrap().into_iter().enumerate() {
        let wr = h
            .join()
            .map_err(|_| anyhow::anyhow!("worker {node} panicked"))?
            .with_context(|| format!("worker {node} failed"))?;
        ensure!(wr.acked_shutdown, "worker {node} exited without acking the drain");
    }
    Ok(report)
}

/// One `serve --loadgen` style measurement, summarized for the bench
/// harness and the CLI sweep.
#[derive(Debug, Clone)]
pub struct LoadgenResult {
    pub nodes: usize,
    pub rounds: usize,
    pub wall_s: f64,
    pub rounds_per_s: f64,
    /// Reactor shard count (server thread total is `io_threads + 1`).
    pub io_threads: usize,
    /// Round-interval percentiles in seconds (None below two rounds).
    pub p50_s: Option<f64>,
    pub p99_s: Option<f64>,
    pub bytes_up: u64,
    pub bytes_down: u64,
}

/// Run an N-worker in-process loadgen over a UDS, reconcile the byte
/// books exactly, and summarize throughput + latency. This is the unit the
/// `deploy_loadgen` bench section and `qadmm serve --loadgen` both record.
pub fn run_loadgen(nodes: usize, iters: usize) -> Result<LoadgenResult> {
    let sock = std::env::temp_dir()
        .join(format!("qadmm-loadgen-{}-{nodes}.sock", std::process::id()));
    let cfg = smoke_cfg(nodes, iters);
    let report = serve_with_threads(&cfg, &Endpoint::Uds(sock), nodes, &ServeOptions::default())?;
    crate::deploy::reconcile(&report.books, &report.accounting)
        .context("loadgen byte books drifted")?;
    Ok(summarize_loadgen(nodes, &report))
}

/// Fold a [`ServeReport`] into the loadgen summary shape.
pub fn summarize_loadgen(nodes: usize, report: &ServeReport) -> LoadgenResult {
    let rounds = report.timeline.rounds.len();
    let times: Vec<f64> = report.timeline.rounds.iter().map(|r| r.time).collect();
    let pcts = round_latency_stats(&times);
    let (up, down) = report
        .books
        .iter()
        .fold((0u64, 0u64), |(u, d), b| (u + b.up_total, d + b.down_total));
    LoadgenResult {
        nodes,
        rounds,
        wall_s: report.wall_s,
        rounds_per_s: rounds as f64 / report.wall_s.max(1e-9),
        io_threads: report.io_threads,
        p50_s: pcts.map(|(p50, _)| p50),
        p99_s: pcts.map(|(_, p99)| p99),
        bytes_up: up,
        bytes_down: down,
    }
}

fn serve_with_processes(
    cfg: &ExperimentConfig,
    listen: &Endpoint,
    exe: &std::path::Path,
    nodes: usize,
) -> Result<ServeReport> {
    let children: Mutex<Vec<Child>> = Mutex::new(Vec::new());
    let serve_res = serve(
        cfg,
        make_native_problem(cfg)?,
        listen,
        &ServeOptions::default(),
        |ep| {
            let mut cs = children.lock().unwrap();
            for node in 0..nodes {
                let child = Command::new(exe)
                    .args([
                        "worker",
                        "--preset",
                        "ci-lasso",
                        "--nodes",
                        &nodes.to_string(),
                        "--connect",
                        &ep.label(),
                        "--node",
                        &node.to_string(),
                    ])
                    .stdout(Stdio::null())
                    .stderr(Stdio::inherit())
                    .spawn()
                    .with_context(|| format!("spawning worker {node}"))?;
                cs.push(child);
            }
            Ok(())
        },
    );
    // reap unconditionally: a serve error must not leave orphans around
    let mut failures = Vec::new();
    for (node, mut child) in children.into_inner().unwrap().into_iter().enumerate() {
        if serve_res.is_err() {
            let _ = child.kill();
        }
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => failures.push(format!("worker {node} exited with {status}")),
            Err(e) => failures.push(format!("worker {node} unreapable: {e}")),
        }
    }
    let report = serve_res?;
    ensure!(failures.is_empty(), "worker processes failed: {}", failures.join("; "));
    Ok(report)
}

/// Round-interval percentiles off the captured timeline (used by both the
/// smoke headline and `serve --loadgen` reporting).
pub fn round_latency_stats(times: &[f64]) -> Option<(f64, f64)> {
    if times.len() < 2 {
        return None;
    }
    let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
    Some((crate::util::stats::quantile(&gaps, 0.5), crate::util::stats::quantile(&gaps, 0.99)))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The in-process smoke (threads over a UDS) is cheap enough to be a
    /// unit test: it exercises handshake, fold, drain, reconciliation, and
    /// replay end to end.
    #[test]
    fn uds_thread_smoke_reconciles_and_replays() {
        let sock =
            std::env::temp_dir().join(format!("qadmm-test-smoke-{}.sock", std::process::id()));
        let opts = DeploySmokeOptions {
            nodes: 4,
            iters: 40,
            target: 1.0, // convergence is integration-tested; keep this fast
            worker_exe: None,
        };
        run_one(&Endpoint::Uds(sock), &opts).unwrap();
    }

    #[test]
    fn latency_stats_need_two_rounds() {
        assert!(round_latency_stats(&[0.0]).is_none());
        let (p50, p99) = round_latency_stats(&[0.0, 1.0, 2.0, 4.0]).unwrap();
        assert!(p50 >= 1.0 && p99 <= 2.0 + 1e-9, "{p50} {p99}");
    }
}
