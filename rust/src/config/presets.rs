//! Named experiment presets matching the paper's §5 setups.

use super::{Backend, EngineKind, ExperimentConfig, OracleConfig, ProblemKind, TriggerConfig};
use crate::comm::latency::LatencyModel;
use crate::comm::profile::LinkConfig;
use crate::compress::CompressorKind;
use crate::topology::TopologyKind;

/// Default full-recompute cadence for the incremental consensus sum: one
/// O(n·m) bank sweep every 64 rounds amortizes to < 2% of the old per-round
/// cost while bounding drift far below quantization noise.
pub const DEFAULT_CONSENSUS_REFRESH: usize = 64;

/// Fig. 3: LASSO, (M, ρ, θ, N, H) = (200, 500, 0.1, 16, 100), q = 3,
/// 10 MC trials, fixed two-group oracle (p = 0.1 / 0.8), P = 1.
/// τ = 1 is the synchronous curve; the paper also plots τ = 3.
pub fn fig3(tau: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("fig3-tau{tau}"),
        problem: ProblemKind::Lasso { m: 200, h: 100, n: 16, rho: 500.0, theta: 0.1 },
        compressor: CompressorKind::Qsgd { bits: 3 },
        error_feedback: true,
        tau,
        p_min: 1,
        iters: 700,
        mc_trials: 10,
        seed: 2025,
        oracle: OracleConfig { p_slow: 0.1, p_fast: 0.8, regroup_each_call: false },
        backend: Backend::Hlo,
        engine: EngineKind::Seq,
        eval_every: 1,
        consensus_refresh_every: DEFAULT_CONSENSUS_REFRESH,
        link: LinkConfig::none(),
        topology: TopologyKind::Star,
        p_tier: 1,
        trigger: TriggerConfig::default(),
        metrics_sample: 0,
    }
}

/// Fig. 4: paper's 6-layer CNN on MNIST, N = 3, q = 3, τ = 3, inexact
/// primal = 10 Adam steps of batch 64 at lr 1e-3, 5 MC trials.
/// `iters`/`mc_trials` here are the CPU-budget defaults; `fig4_full()`
/// restores the paper-scale run.
pub fn fig4() -> ExperimentConfig {
    ExperimentConfig {
        name: "fig4".into(),
        problem: ProblemKind::Cnn { n: 3, rho: 1.0, lr: 1e-3 },
        compressor: CompressorKind::Qsgd { bits: 3 },
        error_feedback: true,
        tau: 3,
        p_min: 1,
        iters: 60,
        mc_trials: 2,
        seed: 2025,
        oracle: OracleConfig { p_slow: 0.1, p_fast: 0.8, regroup_each_call: true },
        backend: Backend::Hlo,
        engine: EngineKind::Seq,
        eval_every: 2,
        consensus_refresh_every: DEFAULT_CONSENSUS_REFRESH,
        link: LinkConfig::none(),
        topology: TopologyKind::Star,
        p_tier: 1,
        trigger: TriggerConfig::default(),
        metrics_sample: 0,
    }
}

/// Fig. 4 at the paper's full scale (long CPU run).
pub fn fig4_full() -> ExperimentConfig {
    let mut cfg = fig4();
    cfg.name = "fig4-full".into();
    cfg.iters = 400;
    cfg.mc_trials = 5;
    cfg
}

/// Small LASSO for CI and integration tests (fast, still representative).
pub fn ci_lasso() -> ExperimentConfig {
    ExperimentConfig {
        name: "ci-lasso".into(),
        problem: ProblemKind::Lasso { m: 32, h: 24, n: 4, rho: 50.0, theta: 0.1 },
        compressor: CompressorKind::Qsgd { bits: 3 },
        error_feedback: true,
        tau: 3,
        p_min: 1,
        iters: 200,
        mc_trials: 2,
        seed: 7,
        oracle: OracleConfig::default(),
        backend: Backend::Native,
        engine: EngineKind::Seq,
        eval_every: 1,
        consensus_refresh_every: DEFAULT_CONSENSUS_REFRESH,
        link: LinkConfig::none(),
        topology: TopologyKind::Star,
        p_tier: 1,
        trigger: TriggerConfig::default(),
        metrics_sample: 0,
    }
}

/// End-to-end threaded driver: MLP federated training with stragglers.
pub fn e2e_mlp() -> ExperimentConfig {
    ExperimentConfig {
        name: "e2e-mlp".into(),
        problem: ProblemKind::Mlp { n: 4, rho: 1.0, lr: 1e-3 },
        compressor: CompressorKind::Qsgd { bits: 3 },
        error_feedback: true,
        tau: 3,
        p_min: 2,
        iters: 150,
        mc_trials: 1,
        seed: 42,
        oracle: OracleConfig { p_slow: 0.1, p_fast: 0.8, regroup_each_call: true },
        backend: Backend::Hlo,
        engine: EngineKind::Seq,
        eval_every: 5,
        consensus_refresh_every: DEFAULT_CONSENSUS_REFRESH,
        // the seed runtime injected this on the uplink send only
        link: LinkConfig::uplink_only(LatencyModel::Mixture {
            fast: 0.0,
            slow: 0.004,
            p_slow: 0.2,
        }),
        topology: TopologyKind::Star,
        p_tier: 1,
        trigger: TriggerConfig::default(),
        metrics_sample: 0,
    }
}

/// Resolve a preset by name.
pub fn by_name(name: &str) -> anyhow::Result<ExperimentConfig> {
    match name {
        "fig3" | "fig3-tau3" => Ok(fig3(3)),
        "fig3-tau1" | "fig3-sync" => Ok(fig3(1)),
        "fig4" => Ok(fig4()),
        "fig4-full" => Ok(fig4_full()),
        "ci-lasso" => Ok(ci_lasso()),
        "e2e-mlp" => Ok(e2e_mlp()),
        _ => anyhow::bail!(
            "unknown preset '{name}' (fig3|fig3-tau1|fig4|fig4-full|ci-lasso|e2e-mlp)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_matches_paper_parameters() {
        let cfg = fig3(3);
        match cfg.problem {
            ProblemKind::Lasso { m, h, n, rho, theta } => {
                assert_eq!((m, h, n), (200, 100, 16));
                assert_eq!(rho, 500.0);
                assert_eq!(theta, 0.1);
            }
            _ => panic!("wrong problem"),
        }
        assert_eq!(cfg.compressor, CompressorKind::Qsgd { bits: 3 });
        assert_eq!(cfg.mc_trials, 10);
        assert!(!cfg.oracle.regroup_each_call);
    }

    #[test]
    fn fig4_matches_paper_parameters() {
        let cfg = fig4_full();
        match cfg.problem {
            ProblemKind::Cnn { n, .. } => assert_eq!(n, 3),
            _ => panic!("wrong problem"),
        }
        assert_eq!(cfg.tau, 3);
        assert_eq!(cfg.mc_trials, 5);
        assert!(cfg.oracle.regroup_each_call);
    }

    #[test]
    fn by_name_resolves() {
        assert!(by_name("fig3").is_ok());
        assert!(by_name("nope").is_err());
        assert_eq!(by_name("fig3-tau1").unwrap().tau, 1);
    }
}
