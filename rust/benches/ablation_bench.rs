//! Reduced ablation sweeps (q bits, error feedback, compressor family,
//! τ/P), printing the per-variant table used in DESIGN.md's design-choice
//! discussion. Scale with QADMM_ABLATION_ITERS / QADMM_ABLATION_TRIALS.

use qadmm::exp::ablation::{run_all, AblationOptions};
use qadmm::util::timer::Stopwatch;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let opts = AblationOptions {
        iters: env_usize("QADMM_ABLATION_ITERS", 250),
        mc_trials: env_usize("QADMM_ABLATION_TRIALS", 2),
        target: 1e-8,
    };
    let sw = Stopwatch::new();
    let rows = run_all(&opts).expect("ablation");
    println!("ablation bench: {} rows in {:.2}s", rows.len(), sw.elapsed_secs());
}
