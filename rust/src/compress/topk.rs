//! Top-k sparsifier [10,14]: keep the k largest-magnitude coordinates,
//! zero the rest. Indices gap-coded with Elias-γ on the wire.
//! Biased, so it *requires* error feedback to converge — which is exactly
//! what the EF ablation demonstrates.

use super::wire::encode_topk;
use super::{Compressed, Compressor};
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug)]
pub struct TopK {
    frac: f64,
}

impl TopK {
    pub fn new(frac: f64) -> Self {
        assert!(frac > 0.0 && frac <= 1.0, "topk fraction must be in (0, 1]");
        Self { frac }
    }

    pub fn k_for(&self, m: usize) -> usize {
        ((self.frac * m as f64).ceil() as usize).clamp(1, m)
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("topk{}", (self.frac * 1000.0).round() as u64)
    }

    fn compress(&self, delta: &[f64], _rng: &mut Pcg64) -> Compressed {
        let m = delta.len();
        let k = self.k_for(m);
        let mut order: Vec<usize> = (0..m).collect();
        order.select_nth_unstable_by(k - 1, |&a, &b| {
            delta[b].abs().partial_cmp(&delta[a].abs()).unwrap()
        });
        let mut keep: Vec<usize> = order[..k].to_vec();
        keep.sort_unstable();
        let entries: Vec<(usize, f64)> = keep.iter().map(|&i| (i, delta[i])).collect();
        let mut dequantized = vec![0.0; m];
        for &(i, v) in &entries {
            dequantized[i] = v;
        }
        Compressed { dequantized, wire: encode_topk(m, &entries) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_largest_magnitudes() {
        let delta = vec![0.1, -5.0, 0.2, 3.0, -0.05];
        let c = TopK::new(0.4).compress(&delta, &mut Pcg64::seed_from_u64(0));
        assert_eq!(c.dequantized, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn decode_matches() {
        let mut rng = Pcg64::seed_from_u64(1);
        let delta = rng.normal_vec(400, 0.0, 1.0);
        let t = TopK::new(0.05);
        let c = t.compress(&delta, &mut rng);
        assert_eq!(t.decode(&c.wire, 400).unwrap(), c.dequantized);
        assert_eq!(c.dequantized.iter().filter(|&&v| v != 0.0).count(), t.k_for(400));
    }

    #[test]
    fn k_at_least_one() {
        assert_eq!(TopK::new(0.001).k_for(10), 1);
        assert_eq!(TopK::new(1.0).k_for(10), 10);
    }

    #[test]
    fn wire_much_smaller_than_dense_for_sparse_k() {
        let mut rng = Pcg64::seed_from_u64(2);
        let delta = rng.normal_vec(10_000, 0.0, 1.0);
        let c = TopK::new(0.01).compress(&delta, &mut rng);
        assert!(c.wire.len() < 10_000 * 8 / 10, "wire={}", c.wire.len());
    }
}
