//! Aggregation topologies: who owns the consensus fan-in.
//!
//! The paper's Algorithm 1 assumes a **star**: every node's compressed
//! (Δx, Δu) update travels one hop to the server, which folds it into the
//! running consensus sum. The sparse arrival set, the event queue and the
//! bounded-staleness scheduler were always topology-agnostic — only the
//! fan-in hard-coded the star. This module makes the fan-in pluggable:
//!
//! * [`TopologyKind::Star`] — the paper's shape, byte-for-byte the
//!   pre-existing path (the engines skip every aggregator branch, so the
//!   `tests/engine_parity.rs` bit-identity contract is untouched).
//! * [`TopologyKind::Tree`] — a 2-tier k-ary tree: leaves are partitioned
//!   into ⌈n/fanout⌉ groups, each owned by an **intermediate aggregator**
//!   that folds child arrivals into a pending partial sum (O(m) per
//!   arrival, Kahan-compensated) and forwards the *re-quantized* partial
//!   delta upstream once its per-tier threshold `P_g`
//!   ([`crate::config::ExperimentConfig::p_tier`]) is met — or as soon as
//!   no further child update is in flight, which keeps the server trigger
//!   live for any (P, P_g) combination.
//! * [`TopologyKind::Gossip`] — randomized neighbor exchange: `k` relay
//!   aggregators, and each dispatched update picks its relay uniformly at
//!   random (a fresh draw per dispatch from the dedicated topology RNG
//!   stream, identical across the sequential and event engines).
//!
//! # Per-hop compression, error feedback, and accounting
//!
//! Each aggregator→server hop reuses the experiment's compressor: the
//! pending partial delta is compressed with the aggregator's own quantizer
//! stream, the wire frame is charged to the aggregator's *own* link (index
//! `n + g` in [`crate::comm::accounting::CommAccounting`], realized from
//! the same [`crate::comm::profile::LinkConfig`] as the leaves), and the
//! quantization residual stays in the pending buffer (error feedback per
//! hop — with `--no-ef` the residual is dropped instead, extending the
//! §4.1 ablation across tiers). Communication accounting therefore
//! *composes*: a tree run's total bits = leaf-hop bits + aggregator-hop
//! bits + broadcast bits, each priced per link.
//!
//! # Staleness across tiers
//!
//! τ is enforced end-to-end at the server: a leaf's staleness counter
//! advances per consensus round until its update *arrives at the server*,
//! which with an intermediate tier means compute + leaf-hop transit +
//! aggregator batching (P_g) + aggregator-hop transit. Every hop consumes
//! the same τ budget — per-hop delay composes additively into the
//! asymmetric staleness of the paper's Fig. 2 — and the server still
//! force-waits any τ−1-stale leaf, so the bounded-delay guarantee is
//! unchanged. The ẑ broadcast fan-*out* remains direct server→leaf
//! (aggregation is a fan-in optimization; relaying the broadcast through
//! the tier would add nothing to the bits story, since the frame must
//! reach every leaf either way).
//!
//! # Conservation invariant
//!
//! Everything that ever arrived is either already in the server's sum or
//! still pending at an aggregator:
//! Σ_leaves(x̂ᵢ+ûᵢ) = Σ_g(ŝ_g) + Σ_g(pending_g) to Kahan precision —
//! `tests/prop.rs` drives this under randomized gossip routing.

mod tier;

pub use tier::{AggForward, AggregatorTier};

/// Which aggregation topology owns the consensus fan-in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    /// Single server, every leaf reports directly (the paper's shape).
    Star,
    /// 2-tier k-ary tree: ⌈n/fanout⌉ intermediate aggregators, leaf i
    /// parented by aggregator i / fanout.
    Tree { fanout: usize },
    /// Randomized neighbor exchange through `k` relay aggregators; the
    /// relay is redrawn per dispatched update.
    Gossip { k: usize },
}

impl TopologyKind {
    /// Parse `star` | `tree:<fanout>` | `gossip:<k>`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        if s == "star" {
            return Ok(TopologyKind::Star);
        }
        if let Some(f) = s.strip_prefix("tree:") {
            let fanout: usize = f
                .parse()
                .map_err(|_| anyhow::anyhow!("topology 'tree:{f}': fanout is not an integer"))?;
            anyhow::ensure!(fanout >= 1, "topology 'tree:{f}': fanout must be >= 1");
            return Ok(TopologyKind::Tree { fanout });
        }
        if let Some(k) = s.strip_prefix("gossip:") {
            let k_num: usize = k
                .parse()
                .map_err(|_| anyhow::anyhow!("topology 'gossip:{k}': k is not an integer"))?;
            anyhow::ensure!(k_num >= 1, "topology 'gossip:{k}': k must be >= 1");
            return Ok(TopologyKind::Gossip { k: k_num });
        }
        anyhow::bail!("unknown topology '{s}' (star|tree:<fanout>|gossip:<k>)")
    }

    /// Inverse of [`Self::parse`].
    pub fn label(&self) -> String {
        match *self {
            TopologyKind::Star => "star".into(),
            TopologyKind::Tree { fanout } => format!("tree:{fanout}"),
            TopologyKind::Gossip { k } => format!("gossip:{k}"),
        }
    }

    /// Number of intermediate aggregators for an `n`-leaf fleet (0 = the
    /// star's direct fan-in).
    pub fn n_aggregators(&self, n_leaves: usize) -> usize {
        match *self {
            TopologyKind::Star => 0,
            TopologyKind::Tree { fanout } => n_leaves.div_ceil(fanout),
            TopologyKind::Gossip { k } => k.min(n_leaves),
        }
    }

    /// The deterministic parent used for the full-precision init exchange
    /// (gossip has no fixed parent, so init partials are assigned
    /// round-robin — any fixed assignment preserves Σ over leaves).
    pub fn static_parent(&self, leaf: usize) -> usize {
        match *self {
            TopologyKind::Star => 0,
            TopologyKind::Tree { fanout } => leaf / fanout,
            TopologyKind::Gossip { k } => leaf % k,
        }
    }

    pub fn validate(&self, n_leaves: usize) -> anyhow::Result<()> {
        match *self {
            TopologyKind::Star => Ok(()),
            TopologyKind::Tree { fanout } => {
                anyhow::ensure!(fanout >= 1, "tree fanout must be >= 1");
                Ok(())
            }
            TopologyKind::Gossip { k } => {
                anyhow::ensure!(
                    (1..=n_leaves).contains(&k),
                    "gossip k must be in 1..={n_leaves} (got {k})"
                );
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_label_roundtrip() {
        for s in ["star", "tree:8", "tree:1", "gossip:4"] {
            let k = TopologyKind::parse(s).unwrap();
            assert_eq!(k.label(), s);
            assert_eq!(TopologyKind::parse(&k.label()).unwrap(), k);
        }
        for s in ["mesh", "tree:0", "tree:x", "gossip:0", "gossip:", "tree"] {
            assert!(TopologyKind::parse(s).is_err(), "{s} should be rejected");
        }
    }

    #[test]
    fn aggregator_counts() {
        assert_eq!(TopologyKind::Star.n_aggregators(16), 0);
        assert_eq!(TopologyKind::Tree { fanout: 4 }.n_aggregators(16), 4);
        assert_eq!(TopologyKind::Tree { fanout: 5 }.n_aggregators(16), 4); // ceil
        assert_eq!(TopologyKind::Tree { fanout: 1 }.n_aggregators(7), 7); // degenerate
        assert_eq!(TopologyKind::Tree { fanout: 100 }.n_aggregators(16), 1);
        assert_eq!(TopologyKind::Gossip { k: 3 }.n_aggregators(16), 3);
        assert_eq!(TopologyKind::Gossip { k: 30 }.n_aggregators(16), 16); // capped
    }

    #[test]
    fn tree_parents_partition_leaves() {
        let t = TopologyKind::Tree { fanout: 3 };
        let parents: Vec<usize> = (0..8).map(|i| t.static_parent(i)).collect();
        assert_eq!(parents, vec![0, 0, 0, 1, 1, 1, 2, 2]);
        for i in 0..8 {
            assert!(t.static_parent(i) < t.n_aggregators(8));
        }
    }

    #[test]
    fn validate_bounds() {
        assert!(TopologyKind::Star.validate(4).is_ok());
        assert!(TopologyKind::Tree { fanout: 9 }.validate(4).is_ok());
        assert!(TopologyKind::Gossip { k: 4 }.validate(4).is_ok());
        assert!(TopologyKind::Gossip { k: 5 }.validate(4).is_err());
    }
}
