//! Ablation benches over the design choices DESIGN.md calls out:
//! * quantizer resolution q ∈ {2..8}
//! * error feedback on/off (the §4.1 error-accumulation argument)
//! * compressor family (qsgd / sign / top-k / rand-k / identity)
//! * staleness bound τ and arrival threshold P
//! * execution engine (sequential simulator vs event-driven virtual time)
//!
//! All on the Fig-3 LASSO workload (native backend for speed), reporting
//! bits-to-target and final accuracy per variant.

use crate::admm::runner::{self, ProblemFactory};
use crate::comm::latency::LatencyModel;
use crate::comm::profile::LinkConfig;
use crate::compress::CompressorKind;
use crate::config::{presets, EngineKind, ExperimentConfig, ProblemKind};
use crate::metrics::summary;
use crate::problems::lasso::{LassoConfig, LassoProblem};
use crate::problems::Problem;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct AblationRow {
    pub label: String,
    pub final_accuracy: f64,
    pub bits_to_target: Option<f64>,
    pub total_bits: f64,
}

impl AblationRow {
    pub fn render(&self) -> String {
        format!(
            "{:32} final_acc {:>10.3e}  bits@target {:>12}  total_bits/param {:>12.1}",
            self.label,
            self.final_accuracy,
            self.bits_to_target
                .map(|b| format!("{b:.1}"))
                .unwrap_or_else(|| "n/a".into()),
            self.total_bits
        )
    }
}

fn base_cfg(iters: usize, trials: usize) -> ExperimentConfig {
    let mut cfg = presets::fig3(3);
    cfg.backend = crate::config::Backend::Native;
    cfg.iters = iters;
    cfg.mc_trials = trials;
    cfg
}

fn run_one(cfg: &ExperimentConfig, target: f64) -> anyhow::Result<AblationRow> {
    let lcfg = match cfg.problem {
        ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
        _ => unreachable!(),
    };
    let mut factory: Box<ProblemFactory> = Box::new(move |_seed, data_rng: &mut Pcg64| {
        Ok(Box::new(LassoProblem::generate(lcfg, data_rng)?) as Box<dyn Problem>)
    });
    let res = runner::run_mc(cfg, factory.as_mut())?;
    drop(factory);
    let rec = res.mean_recorder();
    Ok(AblationRow {
        label: cfg.name.clone(),
        final_accuracy: *res.mean_accuracy.last().unwrap(),
        bits_to_target: summary::bits_to_accuracy(&rec.records, target),
        total_bits: *res.mean_comm_bits.last().unwrap(),
    })
}

pub struct AblationOptions {
    pub iters: usize,
    pub mc_trials: usize,
    pub target: f64,
}

impl Default for AblationOptions {
    fn default() -> Self {
        Self { iters: 400, mc_trials: 3, target: 1e-8 }
    }
}

/// q-bit sweep: resolution vs bits-to-target.
pub fn sweep_q(opts: &AblationOptions) -> anyhow::Result<Vec<AblationRow>> {
    let mut rows = Vec::new();
    for q in [2u8, 3, 4, 6, 8] {
        let mut cfg = base_cfg(opts.iters, opts.mc_trials);
        cfg.compressor = CompressorKind::Qsgd { bits: q };
        cfg.name = format!("q={q}");
        rows.push(run_one(&cfg, opts.target)?);
    }
    for (kind, name) in [
        (CompressorKind::Identity32, "q=32(identity32)"),
        (CompressorKind::Identity, "q=64(identity)"),
    ] {
        let mut cfg = base_cfg(opts.iters, opts.mc_trials);
        cfg.compressor = kind;
        cfg.name = name.into();
        rows.push(run_one(&cfg, opts.target)?);
    }
    Ok(rows)
}

/// Error feedback on/off, for the biased (top-k) and unbiased (qsgd)
/// compressors — EF should matter far more for the biased one.
pub fn sweep_error_feedback(opts: &AblationOptions) -> anyhow::Result<Vec<AblationRow>> {
    let mut rows = Vec::new();
    for (comp, label) in [
        (CompressorKind::Qsgd { bits: 3 }, "qsgd3"),
        (CompressorKind::TopK { frac_permille: 100 }, "topk100"),
        (CompressorKind::Sign, "sign"),
    ] {
        for ef in [true, false] {
            let mut cfg = base_cfg(opts.iters, opts.mc_trials);
            cfg.compressor = comp;
            cfg.error_feedback = ef;
            cfg.name = format!("{label}_ef={}", if ef { "on" } else { "off" });
            rows.push(run_one(&cfg, opts.target)?);
        }
    }
    Ok(rows)
}

/// Compressor-family sweep at matched (approximate) bit budgets.
pub fn sweep_compressors(opts: &AblationOptions) -> anyhow::Result<Vec<AblationRow>> {
    let kinds = [
        CompressorKind::Qsgd { bits: 3 },
        CompressorKind::Sign,
        CompressorKind::TopK { frac_permille: 50 },
        CompressorKind::RandK { frac_permille: 50 },
        CompressorKind::Identity,
    ];
    let mut rows = Vec::new();
    for kind in kinds {
        let mut cfg = base_cfg(opts.iters, opts.mc_trials);
        cfg.compressor = kind;
        cfg.name = kind.label();
        rows.push(run_one(&cfg, opts.target)?);
    }
    Ok(rows)
}

/// τ and P sweeps: how much staleness/batching the convergence tolerates.
pub fn sweep_async(opts: &AblationOptions) -> anyhow::Result<Vec<AblationRow>> {
    let mut rows = Vec::new();
    for tau in [1usize, 3, 6] {
        let mut cfg = base_cfg(opts.iters, opts.mc_trials);
        cfg.tau = tau;
        cfg.name = format!("tau={tau}");
        rows.push(run_one(&cfg, opts.target)?);
    }
    for p in [1usize, 4, 8] {
        let mut cfg = base_cfg(opts.iters, opts.mc_trials);
        cfg.p_min = p;
        cfg.name = format!("P={p}");
        rows.push(run_one(&cfg, opts.target)?);
    }
    Ok(rows)
}

/// Execution-engine sweep: the sequential simulator vs the event-driven
/// virtual-time engine. At zero latency the two rows must be *identical*
/// for the identity compressor (the parity contract) and statistically
/// indistinguishable for qsgd; the straggler rows show the event engine's
/// whole point — heterogeneous delays change arrival batching (and hence
/// the trajectory) without costing any wall-clock sleeps. The downlink
/// row additionally delays ẑ delivery, so nodes compute against stale
/// mirrors (the Fig. 2 asymmetry the τ bound has to absorb).
pub fn sweep_engine(opts: &AblationOptions) -> anyhow::Result<Vec<AblationRow>> {
    let delayed_downlink = LinkConfig {
        compute: LatencyModel::Exp(0.01),
        uplink: LatencyModel::Exp(0.01),
        downlink: LatencyModel::Exp(0.05),
        clock_drift: 0.1,
    };
    let mut rows = Vec::new();
    for (engine, link, label) in [
        (EngineKind::Seq, LinkConfig::none(), "engine=seq"),
        (EngineKind::Event, LinkConfig::none(), "engine=event"),
        (
            EngineKind::Event,
            LinkConfig::symmetric(LatencyModel::Exp(0.01)),
            "engine=event+stragglers",
        ),
        (EngineKind::Event, delayed_downlink, "engine=event+downlink"),
    ] {
        let mut cfg = base_cfg(opts.iters, opts.mc_trials);
        cfg.engine = engine;
        cfg.link = link;
        cfg.name = label.into();
        rows.push(run_one(&cfg, opts.target)?);
    }
    Ok(rows)
}

/// Run every sweep, printing a table per group.
pub fn run_all(opts: &AblationOptions) -> anyhow::Result<Vec<AblationRow>> {
    let mut all = Vec::new();
    for (title, rows) in [
        ("quantizer resolution (q bits/scalar)", sweep_q(opts)?),
        ("error feedback", sweep_error_feedback(opts)?),
        ("compressor family", sweep_compressors(opts)?),
        ("asynchrony (tau, P)", sweep_async(opts)?),
        ("execution engine (seq vs event)", sweep_engine(opts)?),
    ] {
        println!("--- ablation: {title} ---");
        for r in &rows {
            println!("{}", r.render());
        }
        all.extend(rows);
    }
    Ok(all)
}
