//! Experiment configuration: problem, compressor, asynchrony, backend.
//!
//! Presets mirror the paper's §5 setups exactly; every field is also
//! overridable from the CLI. Configs serialize to JSON so each run's
//! metrics file embeds the exact configuration that produced it.

pub mod presets;

use crate::comm::profile::LinkConfig;
use crate::compress::CompressorKind;
use crate::topology::TopologyKind;
use crate::util::json::Json;

/// Which problem instance to run.
#[derive(Clone, Debug, PartialEq)]
pub enum ProblemKind {
    /// LASSO (§5.1): exact primal updates.
    Lasso { m: usize, h: usize, n: usize, rho: f64, theta: f64 },
    /// MLP classifier on the synthetic-MNIST corpus (CI / e2e scale).
    Mlp { n: usize, rho: f64, lr: f64 },
    /// Paper's 6-layer CNN on (synthetic-)MNIST (§5.2): inexact updates.
    Cnn { n: usize, rho: f64, lr: f64 },
}

impl ProblemKind {
    pub fn n_nodes(&self) -> usize {
        match *self {
            ProblemKind::Lasso { n, .. }
            | ProblemKind::Mlp { n, .. }
            | ProblemKind::Cnn { n, .. } => n,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ProblemKind::Lasso { .. } => "lasso",
            ProblemKind::Mlp { .. } => "mlp",
            ProblemKind::Cnn { .. } => "cnn",
        }
    }
}

/// Where the per-iteration numeric updates execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust f64 path (LASSO only) — used for cross-validation and the
    /// 1e-10 accuracy regime.
    Native,
    /// AOT-compiled HLO artifacts via PJRT (the production path).
    Hlo,
}

/// Which execution engine drives Algorithm 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Sequential round-based simulator ([`crate::admm::sim`]) — the
    /// reproducible reference behind every figure.
    Seq,
    /// Event-driven virtual-time engine ([`crate::admm::engine`]) —
    /// genuine asynchrony (per-node compute/network delays, P-arrival
    /// trigger, τ−1 force-wait) without wall-clock sleeps; scales to
    /// 1000+ nodes and matches the simulator bit-for-bit at zero latency.
    Event,
    /// Real threads over the accounted star network
    /// ([`crate::coordinator`]) — the deployment shape.
    Threaded,
}

impl EngineKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "seq" | "sequential" | "sim" => Ok(EngineKind::Seq),
            "event" | "virtual" => Ok(EngineKind::Event),
            "threaded" | "threads" => Ok(EngineKind::Threaded),
            other => anyhow::bail!("unknown engine '{other}' (seq|event|threaded)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            EngineKind::Seq => "seq",
            EngineKind::Event => "event",
            EngineKind::Threaded => "threaded",
        }
    }
}

/// Event-triggered transmission + adaptive quantization (the dead-band /
/// level-schedule layer over the compressor + EF pipeline).
///
/// `delta == 0.0` and `adapt == false` (the default) disables the layer
/// entirely: every selected node transmits every dispatch at the configured
/// quantizer resolution — byte-for-byte the pre-trigger behavior (a strict
/// `‖Δ‖∞ > 0` gate would already diverge: today a zero delta still ships a
/// frame, charges bits, and consumes quantizer RNG).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TriggerConfig {
    /// Dead-band threshold δ: a node transmits only when its EF-adjusted
    /// delta satisfies ‖Δ‖∞ > δ (the larger of the x and u delta norms —
    /// one uplink frame carries both payloads). A skipped dispatch still
    /// counts as an arrival for the P/τ trigger (liveness via the τ−1
    /// force-wait) but puts **0 bits on the wire** (eq. 20 charges only
    /// realized transmissions).
    pub delta: f64,
    /// Per-node adaptive QSGD level schedule: start coarse
    /// ([`ADAPT_START_BITS`]) and refine one bit per stage as the realized
    /// delta magnitude shrinks below `base·ADAPT_REFINE^(stage+1)`, where
    /// `base` is the node's first observed ‖Δ‖∞. Requires a `qsgdQ`
    /// compressor (the schedule is a level count).
    pub adapt: bool,
}

impl Default for TriggerConfig {
    fn default() -> Self {
        Self { delta: 0.0, adapt: false }
    }
}

/// First stage of the adaptive schedule: 2-bit QSGD (or the configured
/// bit-width when that is already coarser).
pub const ADAPT_START_BITS: u8 = 2;

/// Per-stage refinement threshold decay: stage s+1 begins once the realized
/// ‖Δ‖∞ drops below `base_scale · ADAPT_REFINE^(s+1)`.
pub const ADAPT_REFINE: f64 = 0.25;

impl TriggerConfig {
    /// Anything beyond the bit-exact legacy path?
    pub fn enabled(&self) -> bool {
        self.delta > 0.0 || self.adapt
    }

    /// The dead-band gate. `delta == 0` means *disabled*, not "transmit
    /// only nonzero deltas" — see the struct docs.
    pub fn should_send(&self, norm_inf: f64) -> bool {
        self.delta == 0.0 || norm_inf > self.delta
    }
}

/// The `simulate-async()` oracle (§5.1/§5.2): two groups with selection
/// probabilities 0.1 / 0.8.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OracleConfig {
    pub p_slow: f64,
    pub p_fast: f64,
    /// §5.1 splits the nodes once; §5.2 regroups on every call.
    pub regroup_each_call: bool,
}

impl Default for OracleConfig {
    fn default() -> Self {
        Self { p_slow: 0.1, p_fast: 0.8, regroup_each_call: false }
    }
}

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub problem: ProblemKind,
    pub compressor: CompressorKind,
    /// Error feedback on (paper) or off (ablation: pure delta coding).
    pub error_feedback: bool,
    /// Maximum staleness in iterations; τ = 1 ⇒ synchronous.
    pub tau: usize,
    /// Minimum arrivals that trigger a server update.
    pub p_min: usize,
    pub iters: usize,
    pub mc_trials: usize,
    pub seed: u64,
    pub oracle: OracleConfig,
    pub backend: Backend,
    /// Which engine executes Algorithm 1 (seq | event | threaded).
    pub engine: EngineKind,
    /// Evaluate metrics every this many iterations (NN eval is expensive).
    pub eval_every: usize,
    /// Full-recompute cadence of the incremental consensus sum
    /// ([`crate::problems::accumulator::ConsensusAccumulator`]): every this
    /// many rounds the server rebuilds s = Σ(x̂+û) from the estimate banks
    /// to wash out floating-point drift (the only remaining O(n·m) server
    /// work). 0 disables the refresh — the Kahan-compensated fold alone
    /// keeps drift ≤ 1e-10 relative over 10k+ rounds (see tests/prop.rs).
    pub consensus_refresh_every: usize,
    /// Per-link latency decomposition (compute / uplink / downlink legs +
    /// clock drift): injected sleeps for the threaded runtime, virtual
    /// delays for the event engine (unused by the sequential simulator).
    pub link: LinkConfig,
    /// Aggregation topology owning the consensus fan-in
    /// ([`crate::topology`]): `star` is the paper's direct fan-in (and the
    /// bit-exact pre-existing path); `tree:<fanout>` and `gossip:<k>`
    /// interpose re-quantizing intermediate aggregators.
    pub topology: TopologyKind,
    /// Per-tier arrival threshold P_g: an intermediate aggregator forwards
    /// its re-quantized partial sum once this many children are pending
    /// (it forwards earlier when no further child update is in flight, so
    /// the server trigger stays live). Ignored by `topology = star`.
    pub p_tier: usize,
    /// Event-triggered transmission + adaptive level schedule
    /// ([`TriggerConfig`]); the default is the bit-exact legacy path.
    pub trigger: TriggerConfig,
    /// `--metrics-sample k`: evaluate the loss on a deterministic k-node
    /// stride instead of the full fleet (0 = everyone). At n = 10^6 a full
    /// evaluation touches every node's data each eval round and dominates
    /// the run; the sampled Lagrangian is scaled back to fleet magnitude
    /// (n/k) so curves stay comparable. Observation-only: the trajectory,
    /// wire bits and every RNG stream are untouched (it is excluded from
    /// the resume digest for the same reason).
    pub metrics_sample: usize,
}

impl ExperimentConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        let n = self.problem.n_nodes();
        anyhow::ensure!(n >= 1, "need at least one node");
        anyhow::ensure!(self.tau >= 1, "tau must be >= 1 (1 = synchronous)");
        anyhow::ensure!(
            (1..=n).contains(&self.p_min),
            "p_min must be in 1..={n} (got {})",
            self.p_min
        );
        anyhow::ensure!(self.iters >= 1, "iters must be >= 1");
        anyhow::ensure!(self.mc_trials >= 1, "mc_trials must be >= 1");
        anyhow::ensure!(self.eval_every >= 1, "eval_every must be >= 1");
        if matches!(self.problem, ProblemKind::Mlp { .. } | ProblemKind::Cnn { .. }) {
            anyhow::ensure!(
                self.backend == Backend::Hlo,
                "NN problems only run on the HLO backend"
            );
        }
        let (p_slow, p_fast) = (self.oracle.p_slow, self.oracle.p_fast);
        anyhow::ensure!(
            (0.0..=1.0).contains(&p_slow) && (0.0..=1.0).contains(&p_fast),
            "oracle probabilities must be in [0,1]"
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&self.link.clock_drift),
            "clock_drift must be in [0,1) so drifted clock rates stay positive (got {})",
            self.link.clock_drift
        );
        self.topology.validate(n)?;
        anyhow::ensure!(self.p_tier >= 1, "p_tier must be >= 1");
        anyhow::ensure!(
            self.trigger.delta.is_finite() && self.trigger.delta >= 0.0,
            "trigger delta must be finite and >= 0 (got {}); 0 disables the dead-band",
            self.trigger.delta
        );
        if self.trigger.adapt {
            anyhow::ensure!(
                matches!(self.compressor, CompressorKind::Qsgd { .. }),
                "--adapt-levels schedules QSGD level counts; compressor is '{}'",
                self.compressor.label()
            );
        }
        anyhow::ensure!(
            self.metrics_sample <= n,
            "metrics_sample must be <= n = {n} (got {}); 0 evaluates the full fleet",
            self.metrics_sample
        );
        Ok(())
    }

    /// Dimension M of the consensus variable.
    pub fn model_dim(&self, manifest_dim: Option<usize>) -> usize {
        match self.problem {
            ProblemKind::Lasso { m, .. } => m,
            // NN dims come from the artifact manifest.
            ProblemKind::Mlp { .. } | ProblemKind::Cnn { .. } => {
                manifest_dim.expect("NN problems need the artifact manifest for M")
            }
        }
    }

    /// The config identity a resume must match: everything except the run
    /// *length* knobs and the cosmetic name — see
    /// [`crate::snapshot::config_resume_digest`].
    pub fn resume_digest(&self) -> String {
        crate::snapshot::config_resume_digest(&self.to_json())
    }

    pub fn to_json(&self) -> Json {
        let problem = match self.problem {
            ProblemKind::Lasso { m, h, n, rho, theta } => Json::obj(vec![
                ("kind", Json::Str("lasso".into())),
                ("m", Json::Num(m as f64)),
                ("h", Json::Num(h as f64)),
                ("n", Json::Num(n as f64)),
                ("rho", Json::Num(rho)),
                ("theta", Json::Num(theta)),
            ]),
            ProblemKind::Mlp { n, rho, lr } => Json::obj(vec![
                ("kind", Json::Str("mlp".into())),
                ("n", Json::Num(n as f64)),
                ("rho", Json::Num(rho)),
                ("lr", Json::Num(lr)),
            ]),
            ProblemKind::Cnn { n, rho, lr } => Json::obj(vec![
                ("kind", Json::Str("cnn".into())),
                ("n", Json::Num(n as f64)),
                ("rho", Json::Num(rho)),
                ("lr", Json::Num(lr)),
            ]),
        };
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("problem", problem),
            ("compressor", Json::Str(self.compressor.label())),
            ("error_feedback", Json::Bool(self.error_feedback)),
            ("tau", Json::Num(self.tau as f64)),
            ("p_min", Json::Num(self.p_min as f64)),
            ("iters", Json::Num(self.iters as f64)),
            ("mc_trials", Json::Num(self.mc_trials as f64)),
            ("seed", Json::Num(self.seed as f64)),
            (
                "oracle",
                Json::obj(vec![
                    ("p_slow", Json::Num(self.oracle.p_slow)),
                    ("p_fast", Json::Num(self.oracle.p_fast)),
                    ("regroup_each_call", Json::Bool(self.oracle.regroup_each_call)),
                ]),
            ),
            (
                "backend",
                Json::Str(match self.backend {
                    Backend::Native => "native".into(),
                    Backend::Hlo => "hlo".into(),
                }),
            ),
            ("engine", Json::Str(self.engine.label().into())),
            ("eval_every", Json::Num(self.eval_every as f64)),
            (
                "consensus_refresh_every",
                Json::Num(self.consensus_refresh_every as f64),
            ),
            (
                "link",
                Json::obj(vec![
                    ("compute", Json::Str(self.link.compute.label())),
                    ("uplink", Json::Str(self.link.uplink.label())),
                    ("downlink", Json::Str(self.link.downlink.label())),
                    ("clock_drift", Json::Num(self.link.clock_drift)),
                ]),
            ),
            ("topology", Json::Str(self.topology.label())),
            ("p_tier", Json::Num(self.p_tier as f64)),
            ("trigger_delta", Json::Num(self.trigger.delta)),
            ("adapt_levels", Json::Bool(self.trigger.adapt)),
            ("metrics_sample", Json::Num(self.metrics_sample as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ExperimentConfig {
        presets::fig3(3)
    }

    #[test]
    fn presets_validate() {
        for cfg in [
            presets::fig3(1),
            presets::fig3(3),
            presets::fig4(),
            presets::ci_lasso(),
            presets::e2e_mlp(),
        ] {
            cfg.validate().unwrap_or_else(|e| panic!("{}: {e}", cfg.name));
        }
    }

    #[test]
    fn validation_catches_bad_fields() {
        let mut c = base();
        c.tau = 0;
        assert!(c.validate().is_err());
        let mut c = base();
        c.p_min = 0;
        assert!(c.validate().is_err());
        let mut c = base();
        c.p_min = 100;
        assert!(c.validate().is_err());
        let mut c = presets::e2e_mlp();
        c.backend = Backend::Native;
        assert!(c.validate().is_err());
        let mut c = base();
        c.link.clock_drift = 1.0;
        assert!(c.validate().is_err());
        let mut c = base();
        c.link.clock_drift = -0.1;
        assert!(c.validate().is_err());
        let mut c = base();
        c.p_tier = 0;
        assert!(c.validate().is_err());
        let mut c = base();
        // gossip relays are drawn from the leaves, so k cannot exceed n
        c.topology = crate::topology::TopologyKind::Gossip { k: 1000 };
        assert!(c.validate().is_err());
        let mut c = base();
        c.topology = crate::topology::TopologyKind::Tree { fanout: 4 };
        c.validate().unwrap();
        // metrics sample cannot exceed the fleet; 0 and n are both fine
        let mut c = base();
        c.metrics_sample = c.problem.n_nodes() + 1;
        assert!(c.validate().is_err());
        c.metrics_sample = c.problem.n_nodes();
        c.validate().unwrap();
    }

    #[test]
    fn engine_kind_parses_and_labels() {
        for (s, k) in [
            ("seq", EngineKind::Seq),
            ("event", EngineKind::Event),
            ("threaded", EngineKind::Threaded),
        ] {
            assert_eq!(EngineKind::parse(s).unwrap(), k);
            assert_eq!(k.label(), s);
        }
        assert_eq!(EngineKind::parse("virtual").unwrap(), EngineKind::Event);
        assert!(EngineKind::parse("warp").is_err());
    }

    #[test]
    fn json_has_key_fields() {
        let j = base().to_json();
        assert_eq!(j.get("tau").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("engine").unwrap().as_str(), Some("seq"));
        assert_eq!(
            j.get("consensus_refresh_every").unwrap().as_usize(),
            Some(presets::DEFAULT_CONSENSUS_REFRESH)
        );
        assert_eq!(
            j.get("link").unwrap().get("downlink").unwrap().as_str(),
            Some("none")
        );
        assert_eq!(j.get("topology").unwrap().as_str(), Some("star"));
        assert_eq!(j.get("p_tier").unwrap().as_usize(), Some(1));
        assert_eq!(
            j.get("problem").unwrap().get("kind").unwrap().as_str(),
            Some("lasso")
        );
        // round-trips through the parser
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("compressor").unwrap().as_str(), Some("qsgd3"));
    }

    #[test]
    fn trigger_validation_and_semantics() {
        // defaults are the disabled legacy path
        let c = base();
        assert!(!c.trigger.enabled());
        assert!(c.trigger.should_send(0.0), "delta=0 means disabled, not a >0 gate");
        let mut c = base();
        c.trigger.delta = -1.0;
        assert!(c.validate().is_err());
        let mut c = base();
        c.trigger.delta = f64::NAN;
        assert!(c.validate().is_err());
        let mut c = base();
        c.trigger.adapt = true;
        c.compressor = CompressorKind::Identity;
        assert!(c.validate().is_err(), "adaptive levels need a QSGD compressor");
        let mut c = base();
        c.trigger = TriggerConfig { delta: 1e-3, adapt: true };
        c.validate().unwrap();
        assert!(c.trigger.enabled());
        assert!(!c.trigger.should_send(1e-3), "gate is strict: ‖Δ‖∞ > δ");
        assert!(c.trigger.should_send(2e-3));
        // trigger knobs are part of the resume identity
        let j = c.to_json();
        assert_eq!(j.get("trigger_delta").unwrap().as_f64(), Some(1e-3));
        assert_eq!(j.get("adapt_levels"), Some(&Json::Bool(true)));
        assert_ne!(c.resume_digest(), base().resume_digest());
    }

    #[test]
    fn model_dim() {
        assert_eq!(base().model_dim(None), 200);
        assert_eq!(presets::e2e_mlp().model_dim(Some(50890)), 50890);
    }
}
