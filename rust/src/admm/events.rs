//! Virtual-time event substrate for the event-driven engine.
//!
//! A binary-heap priority queue over `(time, seq)` where `time` is virtual
//! seconds and `seq` is the insertion order. Ties on `time` are broken by
//! insertion order, which makes the whole timeline deterministic: two runs
//! that push the same events in the same order pop them in the same order,
//! even when every delay is 0.0 (the parity configuration, where the
//! engine must replay the sequential simulator bit-for-bit).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// What happened at a virtual instant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Node finished its local primal update (uplink send begins).
    ComputeDone { node: usize },
    /// Node's compressed update arrived at the server.
    MsgArrive { node: usize },
    /// The server's compressed Δz broadcast reached this node's ẑ mirror
    /// (payloads ride a per-node FIFO inbox; arrival times are clamped
    /// monotone per link, so broadcasts never overtake each other).
    DownlinkArrive { node: usize },
    /// An intermediate aggregator's re-quantized partial sum reached the
    /// server (non-star topologies only): the payload rides a per-agg FIFO
    /// with monotone arrival clamps, exactly like the downlink inboxes, and
    /// carries the arrival credit of every child folded into it.
    AggregateArrive { agg: usize },
}

/// One scheduled event. Ordered by `(time, seq)` with `f64::total_cmp`,
/// so NaN-free timelines have a total deterministic order.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub time: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.total_cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// Min-heap of events in virtual time.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Event>>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `kind` at virtual time `time` (seconds). Delays must be
    /// finite and non-negative; a NaN time would corrupt the ordering.
    pub fn push(&mut self, time: f64, kind: EventKind) {
        debug_assert!(time.is_finite() && time >= 0.0, "bad virtual time {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Event { time, seq, kind }));
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(e)| e)
    }

    /// Virtual time of the next event, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse(e)| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::MsgArrive { node: 0 });
        q.push(0.5, EventKind::ComputeDone { node: 1 });
        q.push(1.0, EventKind::ComputeDone { node: 2 });
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|e| e.time)).collect();
        assert_eq!(times, vec![0.5, 1.0, 2.0]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for node in 0..5 {
            q.push(0.0, EventKind::ComputeDone { node });
        }
        for node in 0..5 {
            let e = q.pop().unwrap();
            assert_eq!(e.kind, EventKind::ComputeDone { node });
        }
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_pushes_stay_deterministic() {
        // two identical push sequences produce identical pop sequences
        let run = || {
            let mut q = EventQueue::new();
            q.push(1.0, EventKind::ComputeDone { node: 0 });
            q.push(1.0, EventKind::MsgArrive { node: 1 });
            q.push(0.0, EventKind::ComputeDone { node: 2 });
            q.push(1.0, EventKind::ComputeDone { node: 3 });
            std::iter::from_fn(|| q.pop().map(|e| (e.time, e.kind))).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn peek_time_matches_next_pop() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(3.5, EventKind::MsgArrive { node: 9 });
        q.push(0.25, EventKind::MsgArrive { node: 4 });
        assert_eq!(q.peek_time(), Some(0.25));
        assert_eq!(q.pop().unwrap().time, 0.25);
        assert_eq!(q.peek_time(), Some(3.5));
        assert_eq!(q.len(), 1);
    }
}
