//! Socket codec for the deployment protocol: `[u32 len][u8 kind][body]`,
//! little-endian, `len` counting kind + body. Decoding is bounds-checked
//! end to end (the `compress::wire::FrameReader` discipline): the length
//! prefix is validated against [`MAX_FRAME_BYTES`] **before** any
//! allocation, every field read checks the remaining budget, and a decoded
//! frame must consume its body exactly — truncation, oversize, or trailing
//! garbage is a clean `Err`, never a panic or an unbounded allocation.
//!
//! Byte-accounting contract (what makes `CommAccounting` falsifiable): the
//! steady-state data frames are framed in **exactly**
//! [`MSG_HEADER_BYTES`](crate::comm::message::MSG_HEADER_BYTES) bytes of
//! overhead — `Update` is `4 len + 1 kind + 2 node + 1 flags + 4 dx_len`
//! = 12 bytes before the two wire payloads, `Consensus` is `4 len + 1 kind
//! + 1 flags + 4 round + 2 rsv` = 12 bytes before C(Δz) — so the socket
//! byte counter equals the charged bits/8 *exactly* for every data frame.
//! Only the handshake/init frames (which ship f64 but are charged at the
//! paper's 32-bit init rate) and the tiny control frames differ, by the
//! closed-form amounts in [`Frame::socket_extra_bytes`].

use anyhow::{bail, ensure, Result};

use crate::comm::message::{NodeToServer, ServerToNode};
use crate::compress::wire::FrameReader;

/// Protocol version carried in the `Hello` handshake; bumped on any layout
/// change so a stale worker is rejected instead of misparsed.
pub const PROTO_VERSION: u16 = 1;

/// Hard ceiling on one frame's `len` field (256 MiB): a garbage or hostile
/// length prefix is rejected before any buffer is sized from it.
pub const MAX_FRAME_BYTES: u32 = 1 << 28;

pub const KIND_HELLO: u8 = 1;
pub const KIND_WELCOME: u8 = 2;
pub const KIND_REJECT: u8 = 3;
pub const KIND_INIT_FULL: u8 = 4;
pub const KIND_INIT_Z: u8 = 5;
pub const KIND_UPDATE: u8 = 6;
pub const KIND_CONSENSUS: u8 = 7;
pub const KIND_SKIP: u8 = 8;
pub const KIND_SHUTDOWN: u8 = 9;
pub const KIND_SHUTDOWN_ACK: u8 = 10;

/// One protocol frame. Data frames mirror [`NodeToServer`]/[`ServerToNode`]
/// minus what the socket makes redundant: no `seq` (TCP/UDS deliver
/// in-order exactly-once per connection; the server stamps sequence
/// numbers on receipt) and no per-broadcast inclusion *list* (each node's
/// copy carries one `included` flag instead — the unicast pump knows its
/// recipient).
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Worker → server opener: protocol version, claimed node id, problem
    /// dimension, and the config resume digest — both sides must be running
    /// the same experiment, byte for byte.
    Hello { proto: u16, node: u32, m: u32, digest: Vec<u8> },
    /// Server → worker: handshake accepted, start the init upload.
    Welcome,
    /// Server → worker: handshake refused (version/digest/dimension/slot
    /// mismatch); the connection closes after this frame.
    Reject { reason: String },
    InitFull { node: u32, x0: Vec<f64>, u0: Vec<f64> },
    InitZ { z0: Vec<f64> },
    Update { node: u16, dx_wire: Vec<u8>, du_wire: Vec<u8> },
    Consensus { round: u32, included: bool, last: bool, dz_wire: Vec<u8> },
    Skip { node: u16 },
    Shutdown,
    ShutdownAck { node: u16 },
}

/// `Consensus.flags` bit 0: the recipient's update was folded into this
/// round (it may compute again).
pub const FLAG_INCLUDED: u8 = 1;
/// `Consensus.flags` bit 1: final round — apply, ack, exit.
pub const FLAG_LAST: u8 = 2;

impl Frame {
    /// Encode as a complete wire frame, length prefix included.
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::new();
        let kind = match self {
            Frame::Hello { proto, node, m, digest } => {
                body.extend_from_slice(&proto.to_le_bytes());
                body.extend_from_slice(&node.to_le_bytes());
                body.extend_from_slice(&m.to_le_bytes());
                body.extend_from_slice(&(digest.len() as u16).to_le_bytes());
                body.extend_from_slice(digest);
                KIND_HELLO
            }
            Frame::Welcome => KIND_WELCOME,
            Frame::Reject { reason } => {
                let r = reason.as_bytes();
                body.extend_from_slice(&(r.len() as u16).to_le_bytes());
                body.extend_from_slice(r);
                KIND_REJECT
            }
            Frame::InitFull { node, x0, u0 } => {
                body.extend_from_slice(&node.to_le_bytes());
                body.extend_from_slice(&(x0.len() as u32).to_le_bytes());
                for v in x0.iter().chain(u0) {
                    body.extend_from_slice(&v.to_le_bytes());
                }
                KIND_INIT_FULL
            }
            Frame::InitZ { z0 } => {
                body.extend_from_slice(&(z0.len() as u32).to_le_bytes());
                for v in z0 {
                    body.extend_from_slice(&v.to_le_bytes());
                }
                KIND_INIT_Z
            }
            Frame::Update { node, dx_wire, du_wire } => {
                body.extend_from_slice(&node.to_le_bytes());
                body.push(0); // flags, reserved
                body.extend_from_slice(&(dx_wire.len() as u32).to_le_bytes());
                body.extend_from_slice(dx_wire);
                body.extend_from_slice(du_wire);
                KIND_UPDATE
            }
            Frame::Consensus { round, included, last, dz_wire } => {
                let flags = (*included as u8) * FLAG_INCLUDED + (*last as u8) * FLAG_LAST;
                body.push(flags);
                body.extend_from_slice(&round.to_le_bytes());
                body.extend_from_slice(&0u16.to_le_bytes()); // rsv: pads to 12
                body.extend_from_slice(dz_wire);
                KIND_CONSENSUS
            }
            Frame::Skip { node } => {
                body.extend_from_slice(&node.to_le_bytes());
                KIND_SKIP
            }
            Frame::Shutdown => KIND_SHUTDOWN,
            Frame::ShutdownAck { node } => {
                body.extend_from_slice(&node.to_le_bytes());
                KIND_SHUTDOWN_ACK
            }
        };
        let mut out = Vec::with_capacity(5 + body.len());
        out.extend_from_slice(&(1 + body.len() as u32).to_le_bytes());
        out.push(kind);
        out.extend_from_slice(&body);
        out
    }

    /// Decode one frame from its kind byte + body (the transport has
    /// already stripped and validated the length prefix). The body must be
    /// consumed exactly: trailing bytes are corruption, not slack.
    pub fn decode(kind: u8, body: &[u8]) -> Result<Frame> {
        let mut r = FrameReader::new(body);
        let frame = match kind {
            KIND_HELLO => {
                let proto = r.u16()?;
                let node = r.u32()?;
                let m = r.u32()?;
                let dlen = r.u16()? as usize;
                let digest = r.take_bytes(dlen)?.to_vec();
                Frame::Hello { proto, node, m, digest }
            }
            KIND_WELCOME => Frame::Welcome,
            KIND_REJECT => {
                let rlen = r.u16()? as usize;
                let reason = String::from_utf8_lossy(r.take_bytes(rlen)?).into_owned();
                Frame::Reject { reason }
            }
            KIND_INIT_FULL => {
                let node = r.u32()?;
                let m = r.u32()? as usize;
                // the length budget is already bounded by MAX_FRAME_BYTES;
                // this check just makes the error precise
                ensure!(body.len() == 8 + 16 * m, "init_full body/dim mismatch");
                let mut x0 = Vec::with_capacity(m);
                let mut u0 = Vec::with_capacity(m);
                for _ in 0..m {
                    x0.push(r.f64()?);
                }
                for _ in 0..m {
                    u0.push(r.f64()?);
                }
                Frame::InitFull { node, x0, u0 }
            }
            KIND_INIT_Z => {
                let m = r.u32()? as usize;
                ensure!(body.len() == 4 + 8 * m, "init_z body/dim mismatch");
                let mut z0 = Vec::with_capacity(m);
                for _ in 0..m {
                    z0.push(r.f64()?);
                }
                Frame::InitZ { z0 }
            }
            KIND_UPDATE => {
                let node = r.u16()?;
                let _flags = r.u8()?;
                let dx_len = r.u32()? as usize;
                let dx_wire = r.take_bytes(dx_len)?.to_vec();
                let du_wire = r.rest().to_vec();
                return Ok(Frame::Update { node, dx_wire, du_wire });
            }
            KIND_CONSENSUS => {
                let flags = r.u8()?;
                let round = r.u32()?;
                let _rsv = r.u16()?;
                let dz_wire = r.rest().to_vec();
                return Ok(Frame::Consensus {
                    round,
                    included: flags & FLAG_INCLUDED != 0,
                    last: flags & FLAG_LAST != 0,
                    dz_wire,
                });
            }
            KIND_SKIP => Frame::Skip { node: r.u16()? },
            KIND_SHUTDOWN => Frame::Shutdown,
            KIND_SHUTDOWN_ACK => Frame::ShutdownAck { node: r.u16()? },
            k => bail!("unknown frame kind {k}"),
        };
        ensure!(r.remaining() == 0, "frame kind {kind} has trailing bytes");
        Ok(frame)
    }

    /// Socket bytes this frame occupies beyond what eq. (20) charges for
    /// the message it carries — the closed-form per-frame tolerance the
    /// smoke reconciliation subtracts. Data frames (`Update`, `Consensus`)
    /// are exactly 0: their 12 framing bytes *are* the charged
    /// `MSG_HEADER_BYTES`. Init frames ship f64 on the socket but are
    /// charged at the 32-bit init rate; control frames charge nothing.
    pub fn socket_extra_bytes(&self) -> u64 {
        let total = 5 + match self {
            Frame::Hello { digest, .. } => 12 + digest.len() as u64,
            Frame::Welcome | Frame::Shutdown => 0,
            Frame::Reject { reason } => 2 + reason.len() as u64,
            Frame::InitFull { x0, u0, .. } => 8 + 8 * (x0.len() + u0.len()) as u64,
            Frame::InitZ { z0 } => 4 + 8 * z0.len() as u64,
            Frame::Update { dx_wire, du_wire, .. } => {
                7 + (dx_wire.len() + du_wire.len()) as u64
            }
            Frame::Consensus { dz_wire, .. } => 7 + dz_wire.len() as u64,
            Frame::Skip { .. } | Frame::ShutdownAck { .. } => 2,
        };
        total - self.charged_bytes()
    }

    /// eq. (20) charge for this frame, in bytes (what the in-process
    /// runtimes put on the books for the same message).
    pub fn charged_bytes(&self) -> u64 {
        match self {
            Frame::InitFull { x0, u0, .. } => {
                NodeToServer::InitFull { node: 0, x0: x0.clone(), u0: u0.clone() }.wire_bits()
                    / 8
            }
            Frame::InitZ { z0 } => ServerToNode::InitZ { z0: z0.clone() }.wire_bits() / 8,
            Frame::Update { dx_wire, du_wire, .. } => {
                12 + (dx_wire.len() + du_wire.len()) as u64
            }
            Frame::Consensus { dz_wire, .. } => 12 + dz_wire.len() as u64,
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::message::MSG_HEADER_BYTES;

    fn roundtrip(f: Frame) -> Frame {
        let enc = f.encode();
        let len = u32::from_le_bytes(enc[..4].try_into().unwrap()) as usize;
        assert_eq!(len, enc.len() - 4, "length prefix counts kind + body");
        Frame::decode(enc[4], &enc[5..]).unwrap()
    }

    #[test]
    fn all_kinds_roundtrip() {
        let frames = vec![
            Frame::Hello { proto: PROTO_VERSION, node: 3, m: 32, digest: vec![9; 16] },
            Frame::Welcome,
            Frame::Reject { reason: "digest mismatch".into() },
            Frame::InitFull { node: 1, x0: vec![1.5, -2.0], u0: vec![0.0, 3.25] },
            Frame::InitZ { z0: vec![0.5, 0.25, -1.0] },
            Frame::Update { node: 7, dx_wire: vec![1, 2, 3], du_wire: vec![4, 5] },
            Frame::Consensus { round: 42, included: true, last: false, dz_wire: vec![8; 6] },
            Frame::Consensus { round: 0, included: false, last: true, dz_wire: vec![] },
            Frame::Skip { node: 2 },
            Frame::Shutdown,
            Frame::ShutdownAck { node: 5 },
        ];
        for f in frames {
            assert_eq!(roundtrip(f.clone()), f);
        }
    }

    /// The falsifiability anchor: data frames occupy exactly their charged
    /// bytes on the socket — 12 framing bytes == MSG_HEADER_BYTES.
    #[test]
    fn data_frames_have_zero_socket_overhead() {
        let up = Frame::Update { node: 1, dx_wire: vec![0; 33], du_wire: vec![0; 17] };
        assert_eq!(up.encode().len() as u64, up.charged_bytes());
        assert_eq!(up.socket_extra_bytes(), 0);
        assert_eq!(up.charged_bytes(), MSG_HEADER_BYTES + 33 + 17);
        let down = Frame::Consensus { round: 9, included: true, last: true, dz_wire: vec![0; 40] };
        assert_eq!(down.encode().len() as u64, down.charged_bytes());
        assert_eq!(down.socket_extra_bytes(), 0);
    }

    /// Init frames ship f64 but charge the paper's 32-bit init rate: the
    /// socket extra is the closed form the smoke tolerance uses.
    #[test]
    fn init_frame_extras_match_closed_form() {
        let m = 11usize;
        let f = Frame::InitFull { node: 0, x0: vec![0.0; m], u0: vec![0.0; m] };
        assert_eq!(f.encode().len() as u64, f.charged_bytes() + f.socket_extra_bytes());
        assert_eq!(f.socket_extra_bytes(), 1 + 8 * m as u64);
        let z = Frame::InitZ { z0: vec![0.0; m] };
        assert_eq!(z.encode().len() as u64, z.charged_bytes() + z.socket_extra_bytes());
        assert_eq!(z.socket_extra_bytes(), 4 * m as u64 - 3);
    }

    #[test]
    fn malformed_bodies_reject_cleanly() {
        // truncated hello (digest length says 16, body has 4)
        let mut enc = Frame::Hello { proto: 1, node: 0, m: 8, digest: vec![7; 16] }.encode();
        enc.truncate(enc.len() - 12);
        assert!(Frame::decode(enc[4], &enc[5..]).is_err());
        // trailing garbage after a well-formed skip
        let mut enc = Frame::Skip { node: 1 }.encode();
        enc.push(0xEE);
        assert!(Frame::decode(enc[4], &enc[5..]).is_err());
        // dimension lying about the payload size
        let mut enc = Frame::InitZ { z0: vec![0.0; 4] }.encode();
        enc[5..9].copy_from_slice(&100u32.to_le_bytes());
        assert!(Frame::decode(enc[4], &enc[5..]).is_err());
        // unknown kind
        assert!(Frame::decode(0xFF, &[]).is_err());
    }
}
