//! Property-based tests with an in-tree generator (proptest is not in the
//! offline crate universe): randomized inputs over many seeds, with the
//! failing seed printed for reproduction.

use qadmm::admm::scheduler::Scheduler;
use qadmm::compress::packing::{pack_levels, unpack_levels};
use qadmm::compress::{Compressor, CompressorKind};
use qadmm::util::rng::Pcg64;

/// Run `f` over `cases` random seeds; panic with the seed on failure.
fn for_all(cases: usize, base: u64, f: impl Fn(&mut Pcg64)) {
    for c in 0..cases {
        let seed = base.wrapping_add(c as u64);
        let mut rng = Pcg64::seed_from_u64(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            panic!("property failed for seed {seed}: {e:?}");
        }
    }
}

fn random_vec(rng: &mut Pcg64) -> Vec<f64> {
    let m = 1 + rng.gen_range(600);
    let scale = 10f64.powf(rng.uniform_f64() * 8.0 - 4.0); // 1e-4 .. 1e4
    match rng.gen_range(4) {
        0 => vec![0.0; m],                                      // degenerate
        1 => (0..m).map(|_| rng.standard_normal() * scale).collect(),
        2 => {
            // sparse
            let mut v = vec![0.0; m];
            for _ in 0..1 + m / 10 {
                let i = rng.gen_range(m);
                v[i] = rng.standard_normal() * scale;
            }
            v
        }
        _ => (0..m).map(|i| ((i as f64) - m as f64 / 2.0) * scale).collect(), // ramp
    }
}

#[test]
fn prop_packing_roundtrips() {
    for_all(300, 11, |rng| {
        let q = 2 + rng.gen_range(13) as u8; // 2..=14
        let s = (1i32 << (q - 1)) - 1;
        let m = 1 + rng.gen_range(400);
        let levels: Vec<i32> =
            (0..m).map(|_| rng.gen_range((2 * s + 1) as usize) as i32 - s).collect();
        let bytes = pack_levels(&levels, q);
        assert_eq!(unpack_levels(&bytes, m, q).unwrap(), levels);
    });
}

#[test]
fn prop_decode_equals_dequantized_for_every_compressor() {
    let kinds = [
        CompressorKind::Identity,
        CompressorKind::Qsgd { bits: 2 },
        CompressorKind::Qsgd { bits: 3 },
        CompressorKind::Qsgd { bits: 11 },
        CompressorKind::Sign,
        CompressorKind::TopK { frac_permille: 37 },
        CompressorKind::RandK { frac_permille: 211 },
    ];
    for_all(150, 22, |rng| {
        let delta = random_vec(rng);
        for kind in kinds {
            let c = kind.build();
            let out = c.compress(&delta, rng);
            let decoded = c.decode(&out.wire, delta.len()).unwrap();
            assert_eq!(decoded, out.dequantized, "{}", kind.label());
        }
    });
}

#[test]
fn prop_qsgd_error_bounded_and_sign_preserving() {
    for_all(200, 33, |rng| {
        let q = 2 + rng.gen_range(7) as u8;
        let comp = CompressorKind::Qsgd { bits: q }.build();
        let delta = random_vec(rng);
        let out = comp.compress(&delta, rng);
        let norm = delta.iter().fold(0.0f64, |m, x| m.max(x.abs()));
        let s = ((1i32 << (q - 1)) - 1) as f64;
        for (d, v) in delta.iter().zip(&out.dequantized) {
            assert!((d - v).abs() <= norm / s * (1.0 + 1e-12) + 1e-300);
            assert!(*v == 0.0 || v.signum() == d.signum());
        }
    });
}

#[test]
fn prop_scheduler_never_exceeds_staleness_bound() {
    for_all(100, 44, |rng| {
        let n = 2 + rng.gen_range(30);
        let tau = 1 + rng.gen_range(6);
        let p_min = 1 + rng.gen_range(n);
        let p_sel = rng.uniform_f64();
        let mut sched = Scheduler::new(n, tau, p_min);
        let mut active = vec![true; n];
        let mut last_active = vec![0usize; n];
        for round in 1..=120usize {
            let mut oracle_rng = rng.fork(round as u64);
            let next = sched.advance(&active, || {
                (0..n).map(|_| oracle_rng.bernoulli(p_sel)).collect()
            });
            assert!(next.iter().filter(|&&a| a).count() >= p_min);
            for i in 0..n {
                if next[i] {
                    last_active[i] = round;
                } else {
                    // the bounded-delay guarantee
                    assert!(
                        round - last_active[i] <= tau - 1 || tau == 1,
                        "node {i} stale for {} with tau={tau}",
                        round - last_active[i]
                    );
                }
            }
            active = next;
        }
    });
}

#[test]
fn prop_wire_decode_rejects_corruption_or_stays_sane() {
    // flipping bytes must never panic; it either errors or returns a
    // finite-length vector (decoder robustness)
    for_all(150, 55, |rng| {
        let delta = random_vec(rng);
        let comp = CompressorKind::Qsgd { bits: 3 }.build();
        let mut wire = comp.compress(&delta, rng).wire;
        let idx = rng.gen_range(wire.len());
        wire[idx] ^= 1 << rng.gen_range(8);
        match comp.decode(&wire, delta.len()) {
            Ok(v) => assert_eq!(v.len(), delta.len()),
            Err(_) => {}
        }
    });
}

#[test]
fn prop_json_roundtrip_numbers() {
    use qadmm::util::json::Json;
    for_all(300, 66, |rng| {
        let x = match rng.gen_range(3) {
            0 => (rng.next_u64() % (1 << 53)) as f64,
            1 => rng.standard_normal() * 10f64.powf(rng.uniform_f64() * 200.0 - 100.0),
            _ => -((rng.next_u64() % 1000) as f64),
        };
        let text = Json::Num(x).to_string_compact();
        let back = Json::parse(&text).unwrap();
        let y = back.as_f64().unwrap();
        let rel = if x == 0.0 { y.abs() } else { ((x - y) / x).abs() };
        assert!(rel < 1e-12, "{x} -> {text} -> {y}");
    });
}
