//! Dense f64 linear algebra: row-major matrices, Cholesky, Gram products.
//!
//! Sized for the paper's problems (M ≤ a few hundred for LASSO); the NN
//! path never touches this (its compute lives in the HLO artifacts).

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        assert!(rows.iter().all(|row| row.len() == c), "ragged rows");
        Self { rows: r, cols: c, data: rows.concat() }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// y = A x
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            y[i] = acc;
        }
        y
    }

    /// y = Aᵀ x
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            let xi = x[i];
            for (yj, a) in y.iter_mut().zip(row) {
                *yj += a * xi;
            }
        }
        y
    }

    /// C = A B
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows);
        let mut c = Mat::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += aik * bv;
                }
            }
        }
        c
    }

    /// Gram matrix AᵀA (symmetric, [cols × cols]).
    pub fn gram(&self) -> Mat {
        let n = self.cols;
        let mut g = Mat::zeros(n, n);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..n {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                let grow = &mut g.data[i * n..(i + 1) * n];
                for (gv, rv) in grow[i..].iter_mut().zip(&row[i..]) {
                    *gv += ri * rv;
                }
            }
        }
        // mirror upper → lower
        for i in 0..n {
            for j in 0..i {
                g.data[i * n + j] = g.data[j * n + i];
            }
        }
        g
    }

    /// Rows-Gram matrix AAᵀ (symmetric, [rows × rows]). Complements
    /// [`Self::gram`]; the LASSO Woodbury solver inverts this h×h system
    /// instead of the m×m normal equations when h < m.
    pub fn gram_rows(&self) -> Mat {
        let n = self.rows;
        let mut g = Mat::zeros(n, n);
        for i in 0..n {
            let ri = self.row(i);
            for j in i..n {
                let mut acc = 0.0;
                for (a, b) in ri.iter().zip(self.row(j)) {
                    acc += a * b;
                }
                g.data[i * n + j] = acc;
                g.data[j * n + i] = acc;
            }
        }
        g
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    pub fn scale_in_place(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_diag_in_place(&mut self, d: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += d;
        }
    }

    /// Cholesky factorization A = L Lᵀ (A must be SPD). Returns lower L.
    pub fn cholesky(&self) -> anyhow::Result<Mat> {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = self[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 {
                        anyhow::bail!("matrix not positive definite (pivot {i}: {sum})");
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Ok(l)
    }

    /// Solve A x = b given L from [`Mat::cholesky`] (forward + back subst).
    pub fn cholesky_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
        let n = l.rows;
        assert_eq!(b.len(), n);
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= l[(i, k)] * y[k];
            }
            y[i] = sum / l[(i, i)];
        }
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in i + 1..n {
                sum -= l[(k, i)] * x[k];
            }
            x[i] = sum / l[(i, i)];
        }
        x
    }

    /// A⁻¹ via Cholesky (A SPD). Used once per node to precompute
    /// (2AᵀA + ρI)⁻¹ for the exact-update artifact.
    pub fn spd_inverse(&self) -> anyhow::Result<Mat> {
        let l = self.cholesky()?;
        let n = self.rows;
        let mut inv = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = Mat::cholesky_solve(&l, &e);
            e[j] = 0.0;
            for i in 0..n {
                inv[(i, j)] = col[i];
            }
        }
        Ok(inv)
    }

    /// Largest eigenvalue of a symmetric PSD matrix via power iteration.
    pub fn spectral_norm_sym(&self, iters: usize) -> f64 {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut v = vec![1.0 / (n as f64).sqrt(); n];
        let mut lam = 0.0;
        for _ in 0..iters {
            let w = self.matvec(&v);
            let norm = norm2(&w);
            if norm == 0.0 {
                return 0.0;
            }
            for (vi, wi) in v.iter_mut().zip(&w) {
                *vi = wi / norm;
            }
            lam = norm;
        }
        lam
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

// ---- vector helpers ------------------------------------------------------

pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

pub fn norm_inf(a: &[f64]) -> f64 {
    a.iter().fold(0.0, |m, x| m.max(x.abs()))
}

pub fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

pub fn add(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_mat(rng: &mut Pcg64, r: usize, c: usize) -> Mat {
        Mat { rows: r, cols: c, data: rng.normal_vec(r * c, 0.0, 1.0) }
    }

    #[test]
    fn gram_rows_is_a_a_transpose() {
        let mut rng = Pcg64::seed_from_u64(21);
        let a = random_mat(&mut rng, 5, 9);
        let g = a.gram_rows();
        let expect = a.matmul(&a.transpose());
        assert_eq!(g.rows, 5);
        assert_eq!(g.cols, 5);
        for i in 0..5 {
            for j in 0..5 {
                assert!((g[(i, j)] - expect[(i, j)]).abs() < 1e-12);
                assert_eq!(g[(i, j)], g[(j, i)]);
            }
        }
    }

    #[test]
    fn matvec_identity() {
        let i = Mat::eye(4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(i.matvec(&x), x);
    }

    #[test]
    fn matmul_matches_manual() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn gram_matches_matmul() {
        let mut rng = Pcg64::seed_from_u64(1);
        let a = random_mat(&mut rng, 7, 5);
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        for (x, y) in g.data.iter().zip(&g2.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn cholesky_solves() {
        let mut rng = Pcg64::seed_from_u64(2);
        let a = random_mat(&mut rng, 12, 8);
        let mut spd = a.gram();
        spd.add_diag_in_place(2.0);
        let l = spd.cholesky().unwrap();
        let x_true = rng.normal_vec(8, 0.0, 1.0);
        let b = spd.matvec(&x_true);
        let x = Mat::cholesky_solve(&l, &b);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-9, "{xi} vs {ti}");
        }
    }

    #[test]
    fn spd_inverse_roundtrip() {
        let mut rng = Pcg64::seed_from_u64(3);
        let a = random_mat(&mut rng, 10, 6);
        let mut spd = a.gram();
        spd.add_diag_in_place(1.5);
        let inv = spd.spd_inverse().unwrap();
        let prod = spd.matmul(&inv);
        for i in 0..6 {
            for j in 0..6 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((prod[(i, j)] - expect).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let m = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalues 3, -1
        assert!(m.cholesky().is_err());
    }

    #[test]
    fn spectral_norm_of_diag() {
        let mut d = Mat::eye(3);
        d[(0, 0)] = 5.0;
        d[(1, 1)] = 2.0;
        let lam = d.spectral_norm_sym(200);
        assert!((lam - 5.0).abs() < 1e-6);
    }

    #[test]
    fn vector_ops() {
        let a = vec![3.0, 4.0];
        assert_eq!(norm2(&a), 5.0);
        assert_eq!(norm_inf(&[-7.0, 2.0]), 7.0);
        let mut y = vec![1.0, 1.0];
        axpy(&mut y, 2.0, &a);
        assert_eq!(y, vec![7.0, 9.0]);
        assert_eq!(sub(&a, &[1.0, 1.0]), vec![2.0, 3.0]);
        assert_eq!(add(&a, &[1.0, 1.0]), vec![4.0, 5.0]);
    }
}
