//! Event-triggered transmission + per-node adaptive quantization schedule.
//!
//! A node that computed an update does not necessarily *transmit* it: with
//! a dead-band δ > 0 configured ([`crate::config::TriggerConfig`]), the
//! dispatch is skipped whenever the EF-adjusted delta satisfies
//! ‖Δ‖∞ ≤ δ — the frame the receiver would decode moves every estimate by
//! at most δ per coordinate, so dropping it costs a bounded modeling error
//! while saving the entire uplink frame. A skipped dispatch still counts as
//! an *arrival* for the server's P/τ trigger (the node answered "nothing to
//! report", which is information), it just carries zero wire bits.
//!
//! Independently, `adapt` activates a per-node quantization-level schedule:
//! nodes start coarse ([`ADAPT_START_BITS`] bits) and refine one bit at a
//! time as their realized delta magnitude shrinks below per-stage
//! thresholds `base · ADAPT_REFINE^(stage+1)`, capped at the configured
//! QSGD bit width. Early rounds — where deltas are large and the iterate
//! is far from convergence anyway — ship cheap frames; precision arrives
//! when the residual actually needs it.
//!
//! This state is shared verbatim by all three runtimes (sequential
//! simulator, event engine, threaded coordinator) so the trigger decisions
//! are engine-independent given the same delta stream.

use crate::compress::qsgd::Qsgd;
use crate::compress::CompressorKind;
use crate::config::{ExperimentConfig, ADAPT_REFINE, ADAPT_START_BITS};
use crate::snapshot::codec::{Pack, Reader, Writer};

/// ‖v‖∞ for the trigger gate. Any non-finite coordinate makes the norm
/// +∞ — a diverged delta must always *transmit* (the compressors sanitize
/// it on the way out), never hide inside the dead-band where the server
/// would keep crediting a silently broken node.
pub fn inf_norm(v: &[f64]) -> f64 {
    v.iter().fold(0.0f64, |m, x| if x.is_finite() { m.max(x.abs()) } else { f64::INFINITY })
}

/// Per-fleet trigger + adaptive-schedule state. Constructed for every run
/// (disabled instances are inert and pack a few bytes of zeros), mutated
/// only through [`Self::observe`] / [`Self::note_skip`], and packed into
/// snapshots so a resumed run continues the schedule bit-identically.
#[derive(Clone, Debug)]
pub struct TriggerState {
    delta: f64,
    adapt: bool,
    /// The configured QSGD width — the schedule's refinement ceiling.
    /// 0 when `adapt` is off (no schedule; the run's compressor rules).
    target_bits: u8,
    /// Refinement stage per node: bits = min(target, START + stage).
    stage: Vec<u32>,
    /// First observed ‖Δ‖∞ per node — the schedule's reference scale.
    /// 0.0 = not yet observed.
    base_scale: Vec<f64>,
    /// Dispatches suppressed by the dead-band (stats only).
    skipped: u64,
}

impl TriggerState {
    pub fn new(cfg: &ExperimentConfig, n: usize) -> Self {
        let target_bits = match (cfg.trigger.adapt, cfg.compressor) {
            (true, CompressorKind::Qsgd { bits }) => bits,
            _ => 0, // validate() rejects adapt without QSGD
        };
        // The per-node schedule vectors exist only when the adaptive
        // schedule can read them: a fleet with the trigger disabled (the
        // common case at n = 10^6) carries zero per-node trigger state.
        let per_node = if cfg.trigger.adapt { n } else { 0 };
        Self {
            delta: cfg.trigger.delta,
            adapt: cfg.trigger.adapt,
            target_bits,
            stage: vec![0; per_node],
            base_scale: vec![0.0; per_node],
            skipped: 0,
        }
    }

    /// Whether any trigger machinery is active. False ⇒ the caller must
    /// take its legacy path untouched (byte-for-byte pre-trigger behavior).
    pub fn enabled(&self) -> bool {
        self.delta > 0.0 || self.adapt
    }

    /// δ = 0 disables the dead-band entirely (even a zero delta ships a
    /// frame, exactly as before the trigger existed); otherwise strict
    /// ‖Δ‖∞ > δ.
    pub fn should_send(&self, norm_inf: f64) -> bool {
        self.delta == 0.0 || norm_inf > self.delta
    }

    /// Feed one dispatch-time ‖Δ‖∞ observation into node `i`'s schedule:
    /// the first positive finite norm anchors the reference scale, then
    /// each observation below `base · ADAPT_REFINE^(stage+1)` advances one
    /// refinement stage (possibly several at once after a long skip
    /// streak). Called on every dispatch decision — skipped or sent — so
    /// the schedule depends only on the delta stream, not on δ.
    pub fn observe(&mut self, i: usize, norm_inf: f64) {
        if !self.adapt || !norm_inf.is_finite() {
            return;
        }
        if self.base_scale[i] == 0.0 {
            if norm_inf > 0.0 {
                self.base_scale[i] = norm_inf;
            }
            return;
        }
        while self.bits(i) < self.target_bits
            && norm_inf < self.base_scale[i] * ADAPT_REFINE.powi(self.stage[i] as i32 + 1)
        {
            self.stage[i] += 1;
        }
    }

    /// Current wire width for node `i` under the schedule.
    pub fn bits(&self, i: usize) -> u8 {
        let b = u32::from(ADAPT_START_BITS).saturating_add(self.stage[i]);
        b.min(u32::from(self.target_bits)) as u8
    }

    /// The compressor node `i` must use for this dispatch: a scheduled
    /// QSGD when `adapt` is on, `None` to use the run's configured
    /// compressor (sharing its wire format and RNG discipline).
    pub fn compressor_for(&self, i: usize) -> Option<Qsgd> {
        self.adapt.then(|| Qsgd::new(self.bits(i).max(2)))
    }

    pub fn note_skip(&mut self) {
        self.skipped += 1;
    }

    pub fn skipped(&self) -> u64 {
        self.skipped
    }

    pub fn n_nodes(&self) -> usize {
        self.stage.len()
    }

    pub fn delta(&self) -> f64 {
        self.delta
    }

    pub fn adapt(&self) -> bool {
        self.adapt
    }

    /// Resume-time consistency check against the config the snapshot
    /// claims to continue.
    pub fn matches(&self, cfg: &ExperimentConfig, n: usize) -> bool {
        let per_node = if self.adapt { n } else { 0 };
        self.delta == cfg.trigger.delta
            && self.adapt == cfg.trigger.adapt
            && self.stage.len() == per_node
            && self.base_scale.len() == per_node
    }
}

impl Pack for TriggerState {
    fn pack(&self, w: &mut Writer) {
        w.put_f64(self.delta);
        w.put_bool(self.adapt);
        w.put_u8(self.target_bits);
        self.stage.pack(w);
        self.base_scale.pack(w);
        w.put_u64(self.skipped);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let delta = r.get_f64()?;
        let adapt = r.get_bool()?;
        let target_bits = r.get_u8()?;
        let stage = Vec::<u32>::unpack(r)?;
        let base_scale = Vec::<f64>::unpack(r)?;
        let skipped = r.get_u64()?;
        anyhow::ensure!(
            stage.len() == base_scale.len(),
            "snapshot trigger state: stage/base_scale length mismatch"
        );
        anyhow::ensure!(
            delta.is_finite() && delta >= 0.0,
            "snapshot trigger delta must be finite and non-negative"
        );
        Ok(Self { delta, adapt, target_bits, stage, base_scale, skipped })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn cfg_with(delta: f64, adapt: bool) -> ExperimentConfig {
        let mut cfg = presets::ci_lasso();
        cfg.trigger.delta = delta;
        cfg.trigger.adapt = adapt;
        if adapt {
            cfg.compressor = CompressorKind::Qsgd { bits: 4 };
        }
        cfg
    }

    #[test]
    fn disabled_state_is_inert() {
        let t = TriggerState::new(&cfg_with(0.0, false), 3);
        assert!(!t.enabled());
        assert!(t.should_send(0.0)); // δ=0: even a zero delta ships
        assert!(t.compressor_for(0).is_none());
    }

    #[test]
    fn dead_band_gates_strictly() {
        let t = TriggerState::new(&cfg_with(1e-3, false), 2);
        assert!(t.enabled());
        assert!(!t.should_send(1e-3)); // boundary: ≤ δ skips
        assert!(t.should_send(1e-3 + 1e-9));
        // non-finite deltas always transmit (sanitized downstream)
        assert!(t.should_send(inf_norm(&[f64::NAN, 0.0])));
    }

    #[test]
    fn inf_norm_forces_transmission_on_non_finite() {
        assert_eq!(inf_norm(&[1.0, -3.0, 2.0]), 3.0);
        assert_eq!(inf_norm(&[0.0, f64::INFINITY]), f64::INFINITY);
        assert_eq!(inf_norm(&[f64::NAN]), f64::INFINITY);
        assert_eq!(inf_norm(&[]), 0.0);
    }

    #[test]
    fn schedule_refines_as_the_residual_shrinks() {
        let mut t = TriggerState::new(&cfg_with(0.0, true), 1);
        assert_eq!(t.bits(0), ADAPT_START_BITS);
        t.observe(0, 8.0); // anchors base scale
        assert_eq!(t.bits(0), ADAPT_START_BITS);
        t.observe(0, 7.9); // above 8·0.25 = 2 → no advance
        assert_eq!(t.bits(0), ADAPT_START_BITS);
        t.observe(0, 1.9); // below 2 → stage 1
        assert_eq!(t.bits(0), ADAPT_START_BITS + 1);
        t.observe(0, 1e-6); // collapses through every remaining stage…
        assert_eq!(t.bits(0), 4); // …but never past the configured width
        assert_eq!(t.compressor_for(0).unwrap().bits(), 4);
        // non-finite observations never move the schedule
        t.observe(0, f64::INFINITY);
        assert_eq!(t.bits(0), 4);
    }

    #[test]
    fn schedule_is_per_node() {
        let mut t = TriggerState::new(&cfg_with(0.0, true), 2);
        t.observe(0, 4.0);
        t.observe(0, 0.5);
        t.observe(1, 4.0);
        assert_eq!(t.bits(0), ADAPT_START_BITS + 1);
        assert_eq!(t.bits(1), ADAPT_START_BITS);
    }

    #[test]
    fn pack_round_trips() {
        let mut t = TriggerState::new(&cfg_with(0.5, true), 3);
        t.observe(1, 2.0);
        t.observe(1, 0.1);
        t.note_skip();
        let mut w = Writer::new();
        t.pack(&mut w);
        let bytes = w.into_inner();
        let mut r = Reader::new(&bytes);
        let back = TriggerState::unpack(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(back.bits(1), t.bits(1));
        assert_eq!(back.skipped(), 1);
        assert!(back.matches(&cfg_with(0.5, true), 3));
        assert!(!back.matches(&cfg_with(0.4, true), 3));
    }
}
