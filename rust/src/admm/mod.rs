//! The paper's coordination contribution: asynchronous consensus ADMM with
//! compressed, error-fed-back exchange (QADMM, Algorithm 1).
//!
//! * [`oracle`] — the `simulate-async()` oracle (§5: two groups with
//!   selection probabilities 0.1 / 0.8).
//! * [`scheduler`] — the server's bounded-staleness bookkeeping (minimum
//!   arrivals `P`, per-node staleness counters `d_i`, forcing at τ−1).
//! * [`sim`] — the deterministic sequential simulator executing Algorithm 1
//!   verbatim (the reproducible path behind every figure).
//! * [`events`] / [`engine`] — the event-driven virtual-time engine: a
//!   binary-heap timeline of per-node `ComputeDone` / `MsgArrive` /
//!   `DownlinkArrive` (and, under hierarchical fan-in, `AggregateArrive`)
//!   events, with per-node ẑ mirrors that advance only when the server's
//!   broadcast lands on that node's downlink.
//! * [`runner`] — the Monte-Carlo trial harness and series averaging.
//!
//! The consensus fan-in itself is owned by the configured
//! [`crate::topology`]: all three engines run the star directly (the
//! bit-exact reference path) or route arrivals through re-quantizing
//! intermediate aggregators (`tree:<fanout>` / `gossip:<k>`).
//!
//! # Choosing an engine
//!
//! Three engines execute the same node/server state machines; pick by what
//! the experiment needs (CLI: `--engine seq|event|threaded`):
//!
//! | engine | module | use when |
//! |---|---|---|
//! | `seq` | [`sim`] | regenerating figures: lockstep rounds, one shared RNG stream per concern, the bit-exact reference |
//! | `event` | [`engine`] | studying asynchrony at scale: per-link compute/uplink/downlink delays + clock drift in *virtual* seconds ([`crate::comm::profile::LinkProfile`]), P-arrival trigger, τ−1 force-wait, worker-pool fan-out — 1000+ nodes in milliseconds of wall time |
//! | `threaded` | [`crate::coordinator`] | exercising the deployment shape: real server/node threads over accounted channels, injected `thread::sleep` per-link latency, fault injection |
//!
//! `event` with zero delay on every link leg and the identity compressor
//! reproduces `seq` bit-for-bit (`tests/engine_parity.rs` enforces it), so
//! results migrate between the two without re-validation.

pub mod engine;
pub mod events;
pub mod oracle;
pub mod replay;
pub mod runner;
pub mod scheduler;
pub mod sim;
pub mod trigger;
