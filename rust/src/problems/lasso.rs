//! LASSO consensus problem (§5.1):
//!     minimize Σᵢ ‖Aᵢx − bᵢ‖² + θ‖x‖₁
//! with exact primal updates. Two native solvers:
//!
//! * **dense** (h ≥ m): (2AᵀAᵢ + ρI) is inverted once per node, each update
//!   is one M×M matvec (the same precomputed inverse the HLO path uploads);
//! * **Woodbury** (h < m): (ρI + 2AᵀA)⁻¹v = (v − Aᵀ(ρ/2·I + AAᵀ)⁻¹Av)/ρ,
//!   so only an h×h factor is stored and each update costs O(h·m). This is
//!   what makes 1000-node × 10k-dim engine-scale runs feasible — no m×m
//!   inverse is ever formed.
//!
//! Data generation follows the paper exactly: Aᵢ ~ N(0,1), b = A z₀ + n with
//! z₀ sparse (0.2·M nonzeros ~ N(0,1)) and n ~ N(0, 0.01).

use super::{fan_out_batch, Arena, EvalMetrics, LocalUpdateItem, Problem};
use crate::config::Backend;
use crate::runtime::tensor::Tensor;
use crate::runtime::Exec;
use crate::solver::linalg::{add, dot, Mat};
use crate::solver::prox;
use crate::util::rng::Pcg64;

/// Per-node factor for the exact primal solve.
enum PrimalSolver {
    /// (2AᵀA + ρI)⁻¹ per node, [m × m].
    Dense(Vec<Mat>),
    /// (ρ/2·I + AAᵀ)⁻¹ per node, [h × h] (Woodbury identity).
    Woodbury(Vec<Mat>),
}

/// x = (2AᵀA + ρI)⁻¹ rhs through whichever factor is available.
fn apply_primal_solver(
    solver: &PrimalSolver,
    a: &Mat,
    rho: f64,
    node: usize,
    rhs: &[f64],
) -> Vec<f64> {
    match solver {
        PrimalSolver::Dense(minv) => minv[node].matvec(rhs),
        PrimalSolver::Woodbury(w) => {
            let t = a.matvec(rhs);
            let s = w[node].matvec(&t);
            let back = a.matvec_t(&s);
            rhs.iter().zip(&back).map(|(v, c)| (v - c) / rho).collect()
        }
    }
}

/// Eq. (9a) exact solve: argmin fᵢ(x) + ρ/2‖x − ẑ + u‖². Free function so
/// the sequential path and the worker-pool fan-out share one body.
fn native_primal(
    a: &Mat,
    atb2: &[f64],
    solver: &PrimalSolver,
    node: usize,
    rho: f64,
    zhat: &[f64],
    u: &[f64],
) -> Vec<f64> {
    let rhs: Vec<f64> = atb2
        .iter()
        .zip(zhat.iter().zip(u))
        .map(|(atb, (zj, uj))| atb + rho * (zj - uj))
        .collect();
    apply_primal_solver(solver, a, rho, node, &rhs)
}

/// fᵢ(x) = ‖Ax‖² − (2Aᵀb)ᵀx + bᵀb via the residual form (O(h·m)).
fn native_loss(a: &Mat, atb2: &[f64], btb: f64, x: &[f64]) -> f64 {
    let ax = a.matvec(x);
    dot(&ax, &ax) - dot(atb2, x) + btb
}

#[derive(Clone, Copy, Debug)]
pub struct LassoConfig {
    pub m: usize,
    pub h: usize,
    pub n: usize,
    pub rho: f64,
    pub theta: f64,
}

pub struct LassoProblem {
    pub cfg: LassoConfig,
    /// Per-node data matrices Aᵢ [h × m] and targets bᵢ.
    a: Vec<Mat>,
    b: Vec<Vec<f64>>,
    /// Precomputed per-node quantities.
    atb2: Vec<Vec<f64>>, // 2Aᵀb
    btb: Vec<f64>,      // ‖b‖²
    solver: PrimalSolver,
    backend: Backend,
    exec: Option<Box<dyn Exec + Send>>,
    /// Unique namespace for device-pinned constants: trials/variants each
    /// get fresh problem instances whose matrices must never collide in the
    /// runtime's const cache.
    instance: u64,
    /// Reference optimum F* for the accuracy metric (eq. 19), lazy.
    fstar: Option<f64>,
    /// The sparse ground truth (diagnostics).
    pub z0: Vec<f64>,
}

impl LassoProblem {
    /// Generate a problem instance from the paper's distributions.
    pub fn generate(cfg: LassoConfig, rng: &mut Pcg64) -> anyhow::Result<Self> {
        let LassoConfig { m, h, n, rho, .. } = cfg;
        anyhow::ensure!(m > 0 && h > 0 && n > 0, "bad lasso dims");
        let mut z0 = vec![0.0; m];
        let nnz = ((0.2 * m as f64).round() as usize).max(1);
        for &i in rng.choose_k(m, nnz).iter() {
            z0[i] = rng.standard_normal();
        }
        let mut a = Vec::with_capacity(n);
        let mut b = Vec::with_capacity(n);
        for _ in 0..n {
            let ai = Mat { rows: h, cols: m, data: rng.normal_vec(h * m, 0.0, 1.0) };
            // noise ~ N(0, 0.01) ⇒ std 0.1
            let mut bi = ai.matvec(&z0);
            for v in &mut bi {
                *v += 0.1 * rng.standard_normal();
            }
            a.push(ai);
            b.push(bi);
        }
        let mut atb2 = Vec::with_capacity(n);
        let mut btb = Vec::with_capacity(n);
        for i in 0..n {
            atb2.push(a[i].matvec_t(&b[i]).iter().map(|v| 2.0 * v).collect());
            btb.push(dot(&b[i], &b[i]));
        }
        let solver = if h < m {
            // Woodbury: only the h×h rows-Gram is ever inverted; no m×m
            // matrix is formed (memory O(h·m) per node instead of O(m²)).
            let mut w = Vec::with_capacity(n);
            for ai in &a {
                let mut sys = ai.gram_rows();
                sys.add_diag_in_place(rho / 2.0);
                w.push(sys.spd_inverse()?);
            }
            PrimalSolver::Woodbury(w)
        } else {
            let mut minv = Vec::with_capacity(n);
            for ai in &a {
                let mut sys = ai.gram();
                sys.scale_in_place(2.0);
                sys.add_diag_in_place(rho);
                minv.push(sys.spd_inverse()?);
            }
            PrimalSolver::Dense(minv)
        };
        static INSTANCE: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);
        Ok(Self {
            cfg,
            a,
            b,
            atb2,
            btb,
            solver,
            backend: Backend::Native,
            exec: None,
            instance: INSTANCE.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            fstar: None,
            z0,
        })
    }

    /// Switch to the HLO backend (artifact `lasso_node_step`; the server
    /// prox stays native f64 — see [`Problem::consensus_from_sum`]).
    /// Requires the artifact dimensions to match.
    pub fn with_hlo(
        mut self,
        exec: Box<dyn Exec + Send>,
        art_m: usize,
        art_n: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            self.cfg.m == art_m && self.cfg.n == art_n,
            "HLO artifacts are compiled for (m={art_m}, n={art_n}); config has (m={}, n={})",
            self.cfg.m,
            self.cfg.n
        );
        // The artifact takes the dense (2AᵀA+ρI)⁻¹ as a pinned constant, so
        // materialize it if generate() chose the Woodbury factor.
        if matches!(self.solver, PrimalSolver::Woodbury(_)) {
            let mut minv = Vec::with_capacity(self.cfg.n);
            for ai in &self.a {
                let mut sys = ai.gram();
                sys.scale_in_place(2.0);
                sys.add_diag_in_place(self.cfg.rho);
                minv.push(sys.spd_inverse()?);
            }
            self.solver = PrimalSolver::Dense(minv);
        }
        self.backend = Backend::Hlo;
        self.exec = Some(exec);
        Ok(self)
    }

    /// Augmented Lagrangian (eq. 3/4) with λ = ρu, in exact f64. `x`/`u`
    /// are the n×m iterate arenas (one row per node).
    pub fn lagrangian(&self, x: &Arena, u: &Arena, z: &[f64]) -> f64 {
        let LassoConfig { n, rho, theta, .. } = self.cfg;
        let mut total = 0.0;
        for i in 0..n {
            let (xi, ui) = (x.row(i), u.row(i));
            // f_i = ‖Ax‖² − (2Aᵀb)ᵀx + bᵀb  (O(h·m), no Gram needed)
            let ax = self.a[i].matvec(xi);
            total += dot(&ax, &ax) - dot(&self.atb2[i], xi) + self.btb[i];
            let mut pen = 0.0;
            let mut unorm = 0.0;
            for j in 0..self.cfg.m {
                let r = xi[j] - z[j] + ui[j];
                pen += r * r;
                unorm += ui[j] * ui[j];
            }
            total += 0.5 * rho * (pen - unorm);
        }
        total + theta * prox::l1_norm(z)
    }

    /// Plain objective of problem (18) at consensus point z.
    pub fn objective(&self, z: &[f64]) -> f64 {
        let mut total = 0.0;
        for i in 0..self.cfg.n {
            let r: Vec<f64> =
                self.a[i].matvec(z).iter().zip(&self.b[i]).map(|(p, q)| p - q).collect();
            total += dot(&r, &r);
        }
        total + self.cfg.theta * prox::l1_norm(z)
    }

    /// F*: run exact synchronous unquantized ADMM to (near) machine
    /// precision. Cached. This matches how the paper's metric normalizes.
    pub fn reference_optimum(&mut self, iters: usize) -> f64 {
        if let Some(f) = self.fstar {
            return f;
        }
        let LassoConfig { m, n, .. } = self.cfg;
        let mut x = vec![vec![0.0; m]; n];
        let mut u = vec![vec![0.0; m]; n];
        let mut z = vec![0.0; m];
        for _ in 0..iters {
            for i in 0..n {
                x[i] = self.exact_primal_native(i, &z, &u[i]);
                let xi = x[i].clone();
                for j in 0..m {
                    u[i][j] += xi[j] - z[j];
                }
            }
            z = self.consensus_native(&x, &u);
        }
        let f = self.lagrangian(&Arena::from_rows(&x), &Arena::from_rows(&u), &z);
        self.fstar = Some(f);
        f
    }

    /// Override F* (used when one MC-trial harness shares the reference).
    pub fn set_reference_optimum(&mut self, f: f64) {
        self.fstar = Some(f);
    }

    fn exact_primal_native(&self, node: usize, zhat: &[f64], u: &[f64]) -> Vec<f64> {
        native_primal(&self.a[node], &self.atb2[node], &self.solver, node, self.cfg.rho, zhat, u)
    }

    fn consensus_native(&self, xhat: &[Vec<f64>], uhat: &[Vec<f64>]) -> Vec<f64> {
        let LassoConfig { m, n, rho, theta, .. } = self.cfg;
        let mut v = vec![0.0; m];
        for i in 0..n {
            for j in 0..m {
                v[j] += xhat[i][j] + uhat[i][j];
            }
        }
        for vj in &mut v {
            *vj /= n as f64;
        }
        prox::soft_threshold_in_place(&mut v, theta / (rho * n as f64));
        v
    }

    fn exact_primal_hlo(
        &self,
        node: usize,
        zhat: &[f64],
        u: &[f64],
    ) -> anyhow::Result<Vec<f64>> {
        let m = self.cfg.m;
        let exec = self.exec.as_ref().expect("hlo backend without exec");
        // per-node factor (2AᵀA+ρI)⁻¹ and 2Aᵀb are constant across
        // iterations: pinned on device once, keyed by node (§Perf).
        let PrimalSolver::Dense(minv) = &self.solver else {
            anyhow::bail!("HLO backend requires the dense factor (with_hlo materializes it)")
        };
        let consts = [
            Tensor::F64(minv[node].data.clone(), vec![m, m]),
            Tensor::vec_f64(self.atb2[node].clone()),
        ];
        let zeros = vec![0.5; m]; // unused noise lanes (fused quant outputs ignored)
        let varying = [
            Tensor::vec_f64(zhat.to_vec()),
            Tensor::vec_f64(u.to_vec()),
            Tensor::vec_f64(vec![0.0; m]), // xhat (only feeds fused quant)
            Tensor::vec_f64(vec![0.0; m]), // uhat
            Tensor::vec_f64(zeros.clone()),
            Tensor::vec_f64(zeros),
            Tensor::scalar_f64(self.cfg.rho),
            Tensor::scalar_f64(3.0),
        ];
        let key = (self.instance << 16) | node as u64;
        let out = exec.call_prefixed("lasso_node_step", key, &consts, &varying)?;
        Ok(out[0].as_f64()?.to_vec())
    }

    /// Stacked (AᵀA [n·m·m], 2Aᵀb [n·m], ‖b‖² [n]) tensors for the HLO
    /// Lagrangian artifact (parity tests). The Grams are built on demand —
    /// they are no longer kept resident (O(n·m²) memory).
    pub fn gram_tensors(&self) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let ata = self.a.iter().flat_map(|m| m.gram().data).collect();
        let atb2 = self.atb2.concat();
        (ata, atb2, self.btb.clone())
    }

    /// f_i value (local training loss) at x, via the residual form.
    fn local_loss(&self, node: usize, x: &[f64]) -> f64 {
        native_loss(&self.a[node], &self.atb2[node], self.btb[node], x)
    }
}

impl Problem for LassoProblem {
    fn dim(&self) -> usize {
        self.cfg.m
    }

    fn n_nodes(&self) -> usize {
        self.cfg.n
    }

    fn name(&self) -> String {
        format!(
            "lasso(m={},h={},n={},rho={},theta={},{})",
            self.cfg.m,
            self.cfg.h,
            self.cfg.n,
            self.cfg.rho,
            self.cfg.theta,
            match self.backend {
                Backend::Native => "native",
                Backend::Hlo => "hlo",
            }
        )
    }

    fn init_x(&mut self, _rng: &mut Pcg64) -> Vec<f64> {
        vec![0.0; self.cfg.m]
    }

    fn local_update(
        &mut self,
        node: usize,
        zhat: &[f64],
        u: &[f64],
        _x_prev: &[f64],
        _rng: &mut Pcg64,
    ) -> anyhow::Result<(Vec<f64>, f64)> {
        let x = match self.backend {
            Backend::Native => self.exact_primal_native(node, zhat, u),
            Backend::Hlo => self.exact_primal_hlo(node, zhat, u)?,
        };
        let loss = self.local_loss(node, &x);
        Ok((x, loss))
    }

    /// Deterministic worker-pool fan-out ([`fan_out_batch`]): the native
    /// update is pure math over per-node data, so chunks run on scoped
    /// threads and merge back in item order — bit-identical to the
    /// sequential path for any pool size. HLO execution is serialized by
    /// the compute service, so that backend keeps the sequential default.
    fn local_update_batch(
        &mut self,
        items: &mut [LocalUpdateItem<'_>],
    ) -> anyhow::Result<Vec<(Vec<f64>, f64)>> {
        if self.backend != Backend::Native {
            let mut out = Vec::with_capacity(items.len());
            for it in items.iter_mut() {
                out.push(self.local_update(it.node, it.zhat, it.u, it.x_prev, it.rng)?);
            }
            return Ok(out);
        }
        let (a, atb2, btb) = (&self.a, &self.atb2, &self.btb);
        let (solver, rho) = (&self.solver, self.cfg.rho);
        Ok(fan_out_batch(items, |it: &LocalUpdateItem<'_>| {
            let node = it.node;
            let x = native_primal(&a[node], &atb2[node], solver, node, rho, it.zhat, it.u);
            let loss = native_loss(&a[node], &atb2[node], btb[node], &x);
            (x, loss)
        }))
    }

    /// Soft-thresholded mean over the full banks — native f64 on every
    /// backend. The `lasso_server_step` HLO artifact that used to serve
    /// this entry point under `backend=hlo` is retired: no runtime path
    /// reached it once the per-round server prox moved to
    /// [`Self::consensus_from_sum`] (re-wire as a fused fold+prox kernel
    /// if the server step ever moves on-device — see ROADMAP).
    fn consensus(&mut self, xhat: &[Vec<f64>], uhat: &[Vec<f64>]) -> anyhow::Result<Vec<f64>> {
        Ok(self.consensus_native(xhat, uhat))
    }

    /// Eq. 15 from the running sum: z = S_{θ/(ρn)}(s/n), O(m). Computed in
    /// native f64 on every backend: the incremental path needs only the
    /// running sum, never the stacked banks.
    fn consensus_from_sum(&mut self, sum: &[f64], n_nodes: usize) -> anyhow::Result<Vec<f64>> {
        let LassoConfig { rho, theta, .. } = self.cfg;
        let n = n_nodes as f64;
        let mut v: Vec<f64> = sum.iter().map(|s| s / n).collect();
        prox::soft_threshold_in_place(&mut v, theta / (rho * n));
        Ok(v)
    }

    fn evaluate(&mut self, x: &Arena, u: &Arena, z: &[f64]) -> anyhow::Result<EvalMetrics> {
        let fstar = self.reference_optimum(6000);
        let lag = self.lagrangian(x, u, z);
        Ok(EvalMetrics {
            accuracy: (lag - fstar).abs() / fstar.abs().max(f64::MIN_POSITIVE),
            test_acc: f64::NAN,
            loss: lag,
        })
    }

    /// Sampled Lagrangian for `--metrics-sample`: the per-node terms of
    /// eq. 3/4 over the sample only, rescaled by n/k to fleet magnitude,
    /// plus the (global, O(m)) θ‖z‖₁ term. No reference optimum is
    /// computed — eq. 19's F* needs a fleet-scale exact solve, which is
    /// precisely what sampling exists to avoid — so `accuracy` is NaN
    /// (serialized as null in the metrics file).
    fn evaluate_sample(
        &mut self,
        sample: &[usize],
        x: &Arena,
        u: &Arena,
        z: &[f64],
    ) -> anyhow::Result<EvalMetrics> {
        if sample.is_empty() {
            return self.evaluate(x, u, z);
        }
        let LassoConfig { m, n, rho, theta, .. } = self.cfg;
        let mut total = 0.0;
        for &i in sample {
            anyhow::ensure!(i < n, "metrics sample index {i} out of range (n = {n})");
            let (xi, ui) = (x.row(i), u.row(i));
            let ax = self.a[i].matvec(xi);
            total += dot(&ax, &ax) - dot(&self.atb2[i], xi) + self.btb[i];
            let mut pen = 0.0;
            let mut unorm = 0.0;
            for j in 0..m {
                let r = xi[j] - z[j] + ui[j];
                pen += r * r;
                unorm += ui[j] * ui[j];
            }
            total += 0.5 * rho * (pen - unorm);
        }
        let scaled = total * (n as f64 / sample.len() as f64);
        Ok(EvalMetrics {
            accuracy: f64::NAN,
            test_acc: f64::NAN,
            loss: scaled + theta * prox::l1_norm(z),
        })
    }
}

impl Drop for LassoProblem {
    fn drop(&mut self) {
        // evict this instance's pinned device constants
        if let Some(exec) = &self.exec {
            let keys: Vec<u64> =
                (0..self.cfg.n).map(|i| (self.instance << 16) | i as u64).collect();
            exec.drop_consts("lasso_node_step", &keys);
        }
    }
}

/// Convenience: the consensus input v = mean(x̂+û) (used by tests/benches).
pub fn consensus_input(xhat: &[Vec<f64>], uhat: &[Vec<f64>]) -> Vec<f64> {
    let n = xhat.len();
    let mut v = add(&xhat[0], &uhat[0]);
    for i in 1..n {
        for j in 0..v.len() {
            v[j] += xhat[i][j] + uhat[i][j];
        }
    }
    for vj in &mut v {
        *vj /= n as f64;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::fista;

    fn small() -> (LassoProblem, Pcg64) {
        let mut rng = Pcg64::seed_from_u64(11);
        let cfg = LassoConfig { m: 24, h: 20, n: 4, rho: 20.0, theta: 0.2 };
        (LassoProblem::generate(cfg, &mut rng).unwrap(), rng)
    }

    #[test]
    fn primal_update_satisfies_kkt() {
        let (mut p, mut rng) = small();
        let zhat = rng.normal_vec(24, 0.0, 1.0);
        let u = rng.normal_vec(24, 0.0, 0.1);
        let (x, _) = p.local_update(0, &zhat, &u, &vec![0.0; 24], &mut rng).unwrap();
        // 2AᵀA x − 2Aᵀb + ρ(x − ẑ + u) = 0
        let ax = p.a[0].matvec(&x);
        let gx = p.a[0].matvec_t(&ax);
        for j in 0..24 {
            let grad = 2.0 * gx[j] - p.atb2[0][j] + p.cfg.rho * (x[j] - zhat[j] + u[j]);
            assert!(grad.abs() < 1e-9, "grad[{j}]={grad}");
        }
    }

    /// small() has h = 20 < m = 24, so the Woodbury factor is selected; it
    /// must agree with the explicit (2AᵀA + ρI)⁻¹ to solver precision.
    #[test]
    fn woodbury_matches_dense_inverse() {
        let (p, mut rng) = small();
        assert!(matches!(p.solver, PrimalSolver::Woodbury(_)));
        let rhs = rng.normal_vec(24, 0.0, 1.0);
        let x = apply_primal_solver(&p.solver, &p.a[0], p.cfg.rho, 0, &rhs);
        let mut sys = p.a[0].gram();
        sys.scale_in_place(2.0);
        sys.add_diag_in_place(p.cfg.rho);
        let dense = sys.spd_inverse().unwrap().matvec(&rhs);
        for (a, b) in x.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    /// The worker-pool fan-out must be bit-identical to node-by-node calls.
    #[test]
    fn batch_update_matches_sequential() {
        let (mut p, mut rng) = small();
        let zhat = rng.normal_vec(24, 0.0, 1.0);
        let us: Vec<Vec<f64>> = (0..4).map(|_| rng.normal_vec(24, 0.0, 0.1)).collect();
        let x_prev = vec![0.0; 24];
        let seq: Vec<(Vec<f64>, f64)> = (0..4)
            .map(|i| p.local_update(i, &zhat, &us[i], &x_prev, &mut rng).unwrap())
            .collect();
        let mut rngs: Vec<Pcg64> = (0..4).map(|i| Pcg64::seed_from_u64(i as u64)).collect();
        let mut items: Vec<LocalUpdateItem> = rngs
            .iter_mut()
            .enumerate()
            .map(|(i, rng)| LocalUpdateItem {
                node: i,
                zhat: &zhat,
                u: &us[i],
                x_prev: &x_prev,
                rng,
            })
            .collect();
        let batch = p.local_update_batch(&mut items).unwrap();
        assert_eq!(seq, batch);
    }

    #[test]
    fn consensus_is_soft_thresholded_mean() {
        let (mut p, mut rng) = small();
        let xhat: Vec<Vec<f64>> = (0..4).map(|_| rng.normal_vec(24, 0.0, 1.0)).collect();
        let uhat: Vec<Vec<f64>> = (0..4).map(|_| rng.normal_vec(24, 0.0, 0.1)).collect();
        let z = p.consensus(&xhat, &uhat).unwrap();
        let v = consensus_input(&xhat, &uhat);
        let kappa = p.cfg.theta / (p.cfg.rho * 4.0);
        for j in 0..24 {
            assert!((z[j] - prox::soft_threshold_scalar(v[j], kappa)).abs() < 1e-12);
        }
    }

    /// consensus_from_sum fed the exact Σ(x̂+û) must reproduce the bank-
    /// based consensus bit-for-bit (same division and prox order).
    #[test]
    fn consensus_from_sum_matches_bank_consensus_bitwise() {
        let (mut p, mut rng) = small();
        let xhat: Vec<Vec<f64>> = (0..4).map(|_| rng.normal_vec(24, 0.0, 1.0)).collect();
        let uhat: Vec<Vec<f64>> = (0..4).map(|_| rng.normal_vec(24, 0.0, 0.1)).collect();
        let z_banks = p.consensus(&xhat, &uhat).unwrap();
        // the same left-to-right summation order consensus_native uses
        let mut sum = vec![0.0; 24];
        for i in 0..4 {
            for j in 0..24 {
                sum[j] += xhat[i][j] + uhat[i][j];
            }
        }
        let z_sum = p.consensus_from_sum(&sum, 4).unwrap();
        for (a, b) in z_banks.iter().zip(&z_sum) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn reference_optimum_agrees_with_fista() {
        let (mut p, _) = small();
        let admm_fstar = p.reference_optimum(4000);
        // stack all nodes into one big (nh × m) system for FISTA
        let rows: Vec<Vec<f64>> = p
            .a
            .iter()
            .flat_map(|ai| (0..ai.rows).map(move |r| ai.row(r).to_vec()))
            .collect();
        let big_a = Mat::from_rows(&rows);
        let big_b: Vec<f64> = p.b.concat();
        let res = fista::solve(&big_a, &big_b, p.cfg.theta, 1e-14, 30_000);
        let rel = (admm_fstar - res.objective).abs() / res.objective.abs();
        assert!(rel < 1e-6, "admm={admm_fstar} fista={}", res.objective);
    }

    #[test]
    fn lagrangian_converges_to_fstar_under_sync_admm() {
        let (mut p, mut rng) = small();
        let fstar = p.reference_optimum(4000);
        let (n, m) = (4, 24);
        let mut x = vec![vec![0.0; m]; n];
        let mut u = vec![vec![0.0; m]; n];
        let mut z = vec![0.0; m];
        for _ in 0..400 {
            for i in 0..n {
                let (xi, _) = p.local_update(i, &z, &u[i], &x[i], &mut rng).unwrap();
                x[i] = xi;
                for j in 0..m {
                    u[i][j] += x[i][j] - z[j];
                }
            }
            z = p.consensus(&x, &u).unwrap();
        }
        let metrics =
            p.evaluate(&Arena::from_rows(&x), &Arena::from_rows(&u), &z).unwrap();
        assert!(metrics.accuracy < 1e-6, "accuracy={}", metrics.accuracy);
        assert!((metrics.loss - fstar).abs() / fstar < 1e-6);
    }

    /// The full-fleet "sample" walks the same per-node terms in the same
    /// order as the exact Lagrangian with scale n/k = 1 — bitwise equal.
    /// Partial samples rescale to fleet magnitude and report NaN accuracy
    /// (no F* is computed). Out-of-range indices are refused.
    #[test]
    fn sampled_evaluation_scales_to_fleet_magnitude() {
        let (mut p, mut rng) = small();
        let xr: Vec<Vec<f64>> = (0..4).map(|_| rng.normal_vec(24, 0.0, 1.0)).collect();
        let ur: Vec<Vec<f64>> = (0..4).map(|_| rng.normal_vec(24, 0.0, 0.1)).collect();
        let (x, u) = (Arena::from_rows(&xr), Arena::from_rows(&ur));
        let z = rng.normal_vec(24, 0.0, 1.0);
        let full = p.evaluate_sample(&[0, 1, 2, 3], &x, &u, &z).unwrap();
        assert_eq!(full.loss.to_bits(), p.lagrangian(&x, &u, &z).to_bits());
        assert!(full.accuracy.is_nan() && full.test_acc.is_nan());
        let half = p.evaluate_sample(&[0, 2], &x, &u, &z).unwrap();
        assert!(half.loss.is_finite());
        // an empty sample falls back to the exact evaluation
        let exact = p.evaluate_sample(&[], &x, &u, &z).unwrap();
        assert!(exact.accuracy.is_finite());
        assert!(p.evaluate_sample(&[7], &x, &u, &z).is_err());
    }

    #[test]
    fn data_matches_paper_distributions() {
        let mut rng = Pcg64::seed_from_u64(5);
        let cfg = LassoConfig { m: 100, h: 400, n: 2, rho: 10.0, theta: 0.1 };
        let p = LassoProblem::generate(cfg, &mut rng).unwrap();
        let nnz = p.z0.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nnz, 20); // 0.2 · M
        // A entries ~ N(0,1): sample mean/var
        let data = &p.a[0].data;
        let mean: f64 = data.iter().sum::<f64>() / data.len() as f64;
        let var: f64 = data.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / data.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
