"""AOT manifest + artifact sanity: every registered graph lowers, the
manifest signatures match the registry, and the HLO is text-parseable."""

import json
import os

import jax
import pytest

jax.config.update("jax_enable_x64", True)

from compile import aot, nn  # noqa: E402

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_registry_is_well_formed():
    arts = aot.registry()
    assert len(arts) >= 12
    for name, (fn, inputs, outputs, meta) in arts.items():
        assert callable(fn)
        assert inputs and outputs
        names = [n for n, _ in inputs]
        assert len(set(names)) == len(names), f"dup input names in {name}"


def test_manifest_matches_registry():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        manifest = json.load(f)
    arts = aot.registry()
    for name, (fn, inputs, outputs, meta) in arts.items():
        entry = manifest["artifacts"][name]
        assert entry["outputs"] == outputs
        assert [i["name"] for i in entry["inputs"]] == [n for n, _ in inputs]
        for (iname, s), mi in zip(inputs, entry["inputs"]):
            assert list(s.shape) == mi["shape"]
        hlo_path = os.path.join(ART_DIR, entry["file"])
        assert os.path.exists(hlo_path)
        with open(hlo_path) as hf:
            text = hf.read()
        assert "ENTRY" in text and "HloModule" in text


def test_manifest_param_specs():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        manifest = json.load(f)
    assert manifest["params"]["cnn"] == nn.cnn_param_specs()
    assert manifest["params"]["mlp"] == nn.mlp_param_specs()
    assert manifest["consts"]["cnn_m"] == nn.CNN_PARAMS == 246_026


def test_lowering_is_deterministic():
    """Same registry entry lowers to identical HLO text (hermetic AOT)."""
    arts = aot.registry()
    fn, inputs, _, _ = arts["quantize_f64_m200"]
    specs = [s for _, s in inputs]
    t1 = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert t1 == t2
