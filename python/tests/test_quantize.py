"""Pallas quantizer vs pure-jnp oracle + algebraic properties of C(Δ).

This is the core L1 correctness signal: the same kernel lowers into every
node/server artifact, so any semantic drift here corrupts the whole stack.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels.quantize import quantize  # noqa: E402
from compile.kernels.ref import quantize_ref  # noqa: E402


def levels_for_bits(q):
    """S = 2^(q-1) − 1 (one bit is the sign)."""
    return 2 ** (q - 1) - 1


def make(m, seed, dtype, scale=1.0):
    rng = np.random.default_rng(seed)
    delta = (rng.standard_normal(m) * scale).astype(dtype)
    noise = rng.random(m).astype(dtype)
    return jnp.asarray(delta), jnp.asarray(noise)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=2048),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    q=st.integers(min_value=1, max_value=8),
    dtype=st.sampled_from([np.float32, np.float64]),
)
def test_kernel_matches_ref(m, seed, q, dtype):
    s = float(max(levels_for_bits(q), 1))
    delta, noise = make(m, seed, dtype)
    val_k, lvl_k, norm_k = quantize(delta, noise, s)
    val_r, lvl_r, norm_r = quantize_ref(delta, noise, s)
    np.testing.assert_array_equal(np.asarray(lvl_k), np.asarray(lvl_r))
    np.testing.assert_allclose(np.asarray(val_k), np.asarray(val_r), rtol=0, atol=0)
    assert float(norm_k) == float(norm_r)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=512),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    q=st.integers(min_value=2, max_value=8),
)
def test_elementwise_error_bound(m, seed, q):
    """|C(Δ)_m − Δ_m| ≤ ‖Δ‖_max / S — one lattice interval."""
    s = float(levels_for_bits(q))
    delta, noise = make(m, seed, np.float64)
    val, _, norm = quantize(delta, noise, s)
    err = np.abs(np.asarray(val) - np.asarray(delta))
    assert err.max() <= float(norm) / s + 1e-12


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=2, max_value=512),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    q=st.integers(min_value=1, max_value=8),
)
def test_levels_in_range_and_signs(m, seed, q):
    s_int = max(levels_for_bits(q), 1)
    delta, noise = make(m, seed, np.float64)
    val, lvl, _ = quantize(delta, noise, float(s_int))
    lvl = np.asarray(lvl)
    assert lvl.max() <= s_int and lvl.min() >= -s_int
    # level sign agrees with delta sign wherever the level is nonzero
    d = np.asarray(delta)
    nz = lvl != 0
    assert np.all(np.sign(lvl[nz]) == np.sign(d[nz]))
    # dequantized value reconstructs from (level, norm): the wire only
    # carries levels + norm, so this identity is what the rust decoder uses.
    norm = np.abs(d).max()
    np.testing.assert_allclose(np.asarray(val), lvl * norm / s_int, atol=1e-12)


def test_max_element_is_exact():
    """y == S at the max element ⇒ always rounds up ⇒ exact."""
    delta = jnp.asarray(np.array([0.1, -3.0, 0.5], dtype=np.float64))
    noise = jnp.asarray(np.array([0.999999, 0.999999, 0.999999]))
    val, lvl, norm = quantize(delta, noise, 3.0)
    assert float(norm) == 3.0
    assert float(val[1]) == -3.0
    assert int(lvl[1]) == -3


def test_zero_vector():
    delta = jnp.zeros(300, dtype=jnp.float64)
    noise = jnp.zeros(300, dtype=jnp.float64)
    val, lvl, norm = quantize(delta, noise, 3.0)
    assert float(norm) == 0.0
    assert np.all(np.asarray(val) == 0.0)
    assert np.all(np.asarray(lvl) == 0)


def test_unbiasedness():
    """E[C(Δ)] = Δ over the Bernoulli draws (the QSGD property that makes
    error feedback converge). Monte-Carlo with a tight tolerance."""
    m, trials, s = 64, 4000, 3.0
    rng = np.random.default_rng(7)
    delta = jnp.asarray(rng.standard_normal(m))
    acc = np.zeros(m)
    for t in range(trials):
        noise = jnp.asarray(rng.random(m))
        val, _, _ = quantize(delta, noise, s)
        acc += np.asarray(val)
    mean = acc / trials
    # std of one draw ≤ norm/(2S); CLT bound with generous 6 sigma
    norm = float(jnp.max(jnp.abs(delta)))
    tol = 6 * (norm / (2 * s)) / np.sqrt(trials)
    np.testing.assert_allclose(mean, np.asarray(delta), atol=tol)


def test_deterministic_given_noise():
    delta, noise = make(513, 11, np.float64)
    a = quantize(delta, noise, 7.0)
    b = quantize(delta, noise, 7.0)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("block", [32, 256, 1024])
def test_block_size_invariance(block):
    """The BlockSpec tiling must not change semantics."""
    delta, noise = make(1000, 3, np.float64)
    v0, l0, n0 = quantize(delta, noise, 3.0, block=256)
    v1, l1, n1 = quantize(delta, noise, 3.0, block=block)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v1))
    assert float(n0) == float(n1)
