//! Resume-parity smoke sweep (`qadmm resume`): the executable form of the
//! snapshot subsystem's contract, small enough for CI.
//!
//! For every (engine × topology) cell it runs the same seeded LASSO
//! experiment twice — once straight through, once checkpointed at round k,
//! torn down, and resumed from the snapshot with the problem re-derived
//! from the seed — and diffs the *entire* observable run bit-for-bit:
//! per-round z trajectories (as raw f64 bits), per-round staleness
//! vectors, per-link wire-bit totals, the metric series (minus wall
//! clock), and the final state of every RNG stream. Any mismatch is a
//! hard error (CI fails).
//!
//! It then records an event-engine timeline under straggler latency,
//! round-trips it through the JSON file format, replays it on the
//! threaded runtime, and checks the deployment reproduced the recorded
//! arrival sets and round count exactly — the bridge half of the
//! contract. The recording is left in `--out` (CI uploads it as an
//! artifact).

use std::path::{Path, PathBuf};

use crate::admm::engine::EventEngine;
use crate::admm::sim::{AsyncSim, TrialRngs};
use crate::comm::latency::LatencyModel;
use crate::comm::network::FaultSpec;
use crate::comm::profile::LinkConfig;
use crate::compress::CompressorKind;
use crate::config::{presets, EngineKind, ExperimentConfig, ProblemKind};
use crate::coordinator;
use crate::problems::lasso::{LassoConfig, LassoProblem};
use crate::snapshot;
use crate::topology::TopologyKind;
use crate::util::timer::Stopwatch;

pub struct ResumeSmokeOptions {
    /// Rounds per cell.
    pub iters: usize,
    /// Checkpoint round (must be in 1..iters).
    pub k: usize,
    /// Where the recorded timeline (and one on-disk snapshot) land.
    pub out_dir: PathBuf,
    /// Smaller fleet / fewer rounds.
    pub quick: bool,
}

impl Default for ResumeSmokeOptions {
    fn default() -> Self {
        Self { iters: 48, k: 19, out_dir: PathBuf::from("out"), quick: false }
    }
}

/// Everything the bit-identity contract covers, in compare-exactly form.
#[derive(PartialEq)]
struct RunTrace {
    /// Per-round z as raw IEEE bits.
    z: Vec<Vec<u64>>,
    /// Per-round staleness counters.
    staleness: Vec<Vec<usize>>,
    /// Per-link (uplink_bits, downlink_bits, uplink_msgs, downlink_msgs).
    links: Vec<(u64, u64, u64, u64)>,
    /// Metric series minus wall clock (iter, comm/accuracy/loss bits, |A|).
    records: Vec<(usize, u64, u64, u64, usize)>,
    /// FNV digest over every RNG stream's raw state.
    rng_digest: u64,
}

fn cell_cfg(opts: &ResumeSmokeOptions, engine: EngineKind, topo: TopologyKind) -> ExperimentConfig {
    let (n, m, h) = if opts.quick { (8, 16, 8) } else { (16, 24, 12) };
    let mut cfg = presets::ci_lasso();
    cfg.name = format!("resume-smoke-{}-{}", engine.label(), topo.label());
    cfg.problem = ProblemKind::Lasso { m, h, n, rho: 30.0, theta: 0.1 };
    cfg.compressor = CompressorKind::Qsgd { bits: 3 };
    cfg.engine = engine;
    cfg.topology = topo;
    cfg.p_tier = 2;
    cfg.tau = 3;
    cfg.p_min = 2;
    cfg.iters = opts.iters;
    cfg.mc_trials = 1;
    cfg.eval_every = 1;
    // a refresh cadence that straddles the checkpoint round, so the
    // resumed run must hit the same refresh rounds to stay bit-exact
    cfg.consensus_refresh_every = 8;
    if engine == EngineKind::Event {
        // nonzero delay on every leg: the checkpoint lands mid-timeline
        // with events in flight, the regime worth testing
        cfg.link = LinkConfig {
            compute: LatencyModel::Exp(0.01),
            uplink: LatencyModel::Exp(0.01),
            downlink: LatencyModel::Exp(0.02),
            clock_drift: 0.1,
        };
    }
    cfg
}

fn lasso_of(cfg: &ExperimentConfig) -> LassoConfig {
    match cfg.problem {
        ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
        _ => unreachable!("smoke cells are lasso"),
    }
}

fn make_problem(cfg: &ExperimentConfig) -> anyhow::Result<(LassoProblem, TrialRngs)> {
    let seed = crate::admm::runner::trial_seed(cfg.seed, 0);
    let mut rngs = TrialRngs::new(seed);
    let mut p = LassoProblem::generate(lasso_of(cfg), &mut rngs.data)?;
    p.set_reference_optimum(1.0); // parity cares about bits, not F*
    Ok((p, rngs))
}

fn trace_links(acc: &crate::comm::accounting::CommAccounting) -> Vec<(u64, u64, u64, u64)> {
    (0..acc.n_nodes())
        .map(|i| {
            let l = acc.link(i);
            (l.uplink_bits, l.downlink_bits, l.uplink_msgs, l.downlink_msgs)
        })
        .collect()
}

fn trace_records(rec: &crate::metrics::RunRecorder) -> Vec<(usize, u64, u64, u64, usize)> {
    rec.records
        .iter()
        .map(|r| {
            (r.iter, r.comm_bits.to_bits(), r.accuracy.to_bits(), r.loss.to_bits(), r.active_nodes)
        })
        .collect()
}

/// Run a seq cell; `interrupt_at = Some(k)` snapshots at round k, drops
/// everything, re-derives the problem and resumes.
fn run_seq(cfg: &ExperimentConfig, interrupt_at: Option<usize>) -> anyhow::Result<RunTrace> {
    let mut z = Vec::new();
    let mut staleness = Vec::new();
    let (mut problem, rngs) = make_problem(cfg)?;
    let mut sim = AsyncSim::new(cfg, &mut problem, rngs)?;
    let k = interrupt_at.unwrap_or(cfg.iters);
    for _ in 0..k {
        sim.step()?;
        z.push(sim.z().iter().map(|v| v.to_bits()).collect());
        staleness.push(sim.staleness().to_vec());
    }
    if interrupt_at.is_some() && k < cfg.iters {
        let bytes = snapshot::encode(&sim.snapshot_meta(), &sim.snapshot_body());
        drop(sim); // the "crash"
        let (meta, body) = snapshot::decode(&bytes)?;
        anyhow::ensure!(meta.round == k, "snapshot header round mismatch");
        let (mut problem2, _) = make_problem(cfg)?;
        let mut sim = AsyncSim::resume(cfg, &mut problem2, &body)?;
        while sim.iter() < cfg.iters {
            sim.step()?;
            z.push(sim.z().iter().map(|v| v.to_bits()).collect());
            staleness.push(sim.staleness().to_vec());
        }
        return Ok(RunTrace {
            z,
            staleness,
            links: trace_links(sim.accounting()),
            records: trace_records(sim.recorder()),
            rng_digest: sim.rng_digest(),
        });
    }
    Ok(RunTrace {
        z,
        staleness,
        links: trace_links(sim.accounting()),
        records: trace_records(sim.recorder()),
        rng_digest: sim.rng_digest(),
    })
}

/// Event-engine twin of [`run_seq`]; `via_disk` additionally round-trips
/// the snapshot through a real file.
fn run_event(
    cfg: &ExperimentConfig,
    interrupt_at: Option<usize>,
    via_disk: Option<&Path>,
) -> anyhow::Result<RunTrace> {
    let mut z = Vec::new();
    let mut staleness = Vec::new();
    let (mut problem, rngs) = make_problem(cfg)?;
    let mut eng = EventEngine::new(cfg, &mut problem, rngs)?;
    let k = interrupt_at.unwrap_or(cfg.iters);
    for _ in 0..k {
        eng.step_round()?;
        z.push(eng.z().iter().map(|v| v.to_bits()).collect());
        staleness.push(eng.staleness().to_vec());
    }
    if interrupt_at.is_some() && k < cfg.iters {
        let meta = eng.snapshot_meta();
        let body = eng.snapshot_body();
        drop(eng); // the "crash"
        let restored = match via_disk {
            Some(dir) => {
                let path = dir.join(format!("{}.qsnap", cfg.name));
                snapshot::write_file(&path, &meta, &body)?;
                let (meta2, body2) = snapshot::read_file(&path)?;
                anyhow::ensure!(meta2.round == k, "snapshot file round mismatch");
                body2
            }
            None => body,
        };
        let (mut problem2, _) = make_problem(cfg)?;
        let mut eng = EventEngine::resume(cfg, &mut problem2, &restored)?;
        while eng.stats().rounds < cfg.iters {
            eng.step_round()?;
            z.push(eng.z().iter().map(|v| v.to_bits()).collect());
            staleness.push(eng.staleness().to_vec());
        }
        return Ok(RunTrace {
            z,
            staleness,
            links: trace_links(eng.accounting()),
            records: trace_records(eng.recorder()),
            rng_digest: eng.rng_digest(),
        });
    }
    Ok(RunTrace {
        z,
        staleness,
        links: trace_links(eng.accounting()),
        records: trace_records(eng.recorder()),
        rng_digest: eng.rng_digest(),
    })
}

fn check_cell(
    opts: &ResumeSmokeOptions,
    engine: EngineKind,
    topo: TopologyKind,
) -> anyhow::Result<()> {
    let cfg = cell_cfg(opts, engine, topo);
    anyhow::ensure!(
        (1..cfg.iters).contains(&opts.k),
        "--k must be in 1..{} (got {})",
        cfg.iters,
        opts.k
    );
    let clock = Stopwatch::new();
    // the event × star cell also exercises the on-disk container
    let via_disk = (engine == EngineKind::Event && topo == TopologyKind::Star)
        .then(|| opts.out_dir.clone());
    let (straight, resumed) = match engine {
        EngineKind::Seq => (run_seq(&cfg, None)?, run_seq(&cfg, Some(opts.k))?),
        EngineKind::Event => (
            run_event(&cfg, None, None)?,
            run_event(&cfg, Some(opts.k), via_disk.as_deref())?,
        ),
        EngineKind::Threaded => unreachable!("threaded is the replay half"),
    };
    anyhow::ensure!(
        straight.z == resumed.z,
        "{}: z trajectory diverged after resume at round {}",
        cfg.name,
        opts.k
    );
    anyhow::ensure!(straight.staleness == resumed.staleness, "{}: staleness diverged", cfg.name);
    anyhow::ensure!(straight.links == resumed.links, "{}: per-link wire bits diverged", cfg.name);
    anyhow::ensure!(straight.records == resumed.records, "{}: metric series diverged", cfg.name);
    anyhow::ensure!(
        straight.rng_digest == resumed.rng_digest,
        "{}: final RNG states diverged",
        cfg.name
    );
    println!(
        "  PASS {:32} checkpoint@{:<3} resume bit-identical ({} rounds, {:.2}s)",
        cfg.name,
        opts.k,
        cfg.iters,
        clock.elapsed_secs()
    );
    Ok(())
}

/// Record an event-engine timeline under stragglers, replay it through the
/// threaded runtime, and require the deployment to reproduce the recorded
/// arrival sets and round count exactly.
fn check_replay_bridge(opts: &ResumeSmokeOptions) -> anyhow::Result<PathBuf> {
    let mut cfg = presets::ci_lasso();
    cfg.name = "resume-smoke-bridge".into();
    cfg.engine = EngineKind::Event;
    cfg.iters = if opts.quick { 12 } else { 20 };
    cfg.mc_trials = 1;
    cfg.eval_every = cfg.iters;
    cfg.tau = 4;
    cfg.p_min = 2;
    cfg.link = LinkConfig {
        compute: LatencyModel::Exp(0.004),
        uplink: LatencyModel::Exp(0.006),
        downlink: LatencyModel::None,
        clock_drift: 0.0,
    };
    let clock = Stopwatch::new();

    let (mut problem, rngs) = make_problem(&cfg)?;
    let mut eng = EventEngine::new(&cfg, &mut problem, rngs)?;
    eng.record_timeline();
    for _ in 0..cfg.iters {
        eng.step_round()?;
    }
    let tl = eng.take_timeline().expect("recording enabled");
    drop(eng);
    let path = opts.out_dir.join("timeline.json");
    tl.write(&path)?;
    // the replay consumes the *file*, proving the format round-trips
    let tl = crate::snapshot::timeline::RecordedTimeline::load(&path)?;

    let mut thr_cfg = cfg.clone();
    thr_cfg.engine = EngineKind::Threaded;
    let (problem, _) = make_problem(&thr_cfg)?;
    let outcome = coordinator::run_threaded_replay(
        &thr_cfg,
        Box::new(problem),
        FaultSpec::default(),
        &tl,
    )?;
    anyhow::ensure!(
        outcome.round_arrivals.len() == tl.rounds.len(),
        "bridge: replay fired {} rounds, recording has {}",
        outcome.round_arrivals.len(),
        tl.rounds.len()
    );
    for (r, (got, want)) in
        outcome.round_arrivals.iter().zip(tl.rounds.iter().map(|x| &x.arrivals)).enumerate()
    {
        anyhow::ensure!(
            got == want,
            "bridge: round {r} folded {got:?}, recording prescribes {want:?}"
        );
    }
    println!(
        "  PASS {:32} threaded replay == recorded schedule ({} rounds, {:.2}s)",
        "resume-smoke-bridge",
        tl.rounds.len(),
        clock.elapsed_secs()
    );
    Ok(path)
}

pub fn run(opts: &ResumeSmokeOptions) -> anyhow::Result<()> {
    println!("--- resume-parity smoke: checkpoint@k -> resume must be bit-identical ---");
    std::fs::create_dir_all(&opts.out_dir)?;
    let topologies =
        [TopologyKind::Star, TopologyKind::Tree { fanout: 4 }, TopologyKind::Gossip { k: 3 }];
    for engine in [EngineKind::Seq, EngineKind::Event] {
        for topo in topologies {
            check_cell(opts, engine, topo)?;
        }
    }
    let tl_path = check_replay_bridge(opts)?;
    println!(
        "--- resume smoke OK; recorded timeline at {} ---",
        tl_path.display()
    );
    Ok(())
}
