//! Monte-Carlo trial harness: run one configuration over `mc_trials`
//! independent trials (fresh data, oracle schedule and quantizer noise per
//! trial, all derived from `seed + trial`), then average the metric series
//! — exactly how the paper's figures are produced.

use std::path::PathBuf;

use crate::config::{EngineKind, ExperimentConfig, ProblemKind};
use crate::metrics::RunRecorder;
use crate::problems::Problem;
use crate::snapshot;
use crate::util::stats;

use super::engine::EventEngine;
use super::sim::{AsyncSim, TrialRngs};

/// Averaged curves across trials (aligned on the eval grid).
#[derive(Clone, Debug)]
pub struct McResult {
    pub trials: Vec<RunRecorder>,
    pub iters: Vec<f64>,
    pub mean_accuracy: Vec<f64>,
    pub mean_test_acc: Vec<f64>,
    pub mean_loss: Vec<f64>,
    pub mean_comm_bits: Vec<f64>,
}

impl McResult {
    fn from_trials(trials: Vec<RunRecorder>) -> Self {
        assert!(!trials.is_empty());
        let len = trials.iter().map(|t| t.records.len()).min().unwrap();
        let trimmed: Vec<Vec<&crate::metrics::IterRecord>> =
            trials.iter().map(|t| t.records.iter().take(len).collect()).collect();
        let series = |f: &dyn Fn(&crate::metrics::IterRecord) -> f64| -> Vec<Vec<f64>> {
            trimmed.iter().map(|t| t.iter().map(|r| f(r)).collect()).collect()
        };
        let iters = trimmed[0].iter().map(|r| r.iter as f64).collect();
        let mean_accuracy = stats::mean_series(&series(&|r| r.accuracy));
        let mean_test_acc = stats::mean_series(&series(&|r| r.test_acc));
        let mean_loss = stats::mean_series(&series(&|r| r.loss));
        let mean_comm_bits = stats::mean_series(&series(&|r| r.comm_bits));
        Self { trials, iters, mean_accuracy, mean_test_acc, mean_loss, mean_comm_bits }
    }

    /// A recorder carrying the averaged series (for the summary helpers).
    pub fn mean_recorder(&self) -> RunRecorder {
        let mut rec = RunRecorder::new();
        for i in 0..self.iters.len() {
            rec.push(crate::metrics::IterRecord {
                iter: self.iters[i] as usize,
                comm_bits: self.mean_comm_bits[i],
                accuracy: self.mean_accuracy[i],
                test_acc: self.mean_test_acc[i],
                loss: self.mean_loss[i],
                active_nodes: 0,
                wall_s: 0.0,
            });
        }
        rec
    }
}

/// Builds a fresh problem for each trial. Receives the trial seed and the
/// dedicated data RNG (fork 1 of the trial root) so that, for a fixed seed,
/// every configuration sees identical data.
pub type ProblemFactory<'f> =
    dyn FnMut(u64, &mut crate::util::rng::Pcg64) -> anyhow::Result<Box<dyn Problem>> + 'f;

pub fn trial_seed(base_seed: u64, trial: usize) -> u64 {
    base_seed.wrapping_add(1_000_003u64.wrapping_mul(trial as u64 + 1))
}

/// Run `cfg.mc_trials` trials and average. `cfg.engine` picks the in-process
/// engine (seq | event); the threaded deployment has its own entry point
/// ([`crate::coordinator::run_threaded`]) because it needs `Problem + Send`.
pub fn run_mc(cfg: &ExperimentConfig, factory: &mut ProblemFactory) -> anyhow::Result<McResult> {
    cfg.validate()?;
    let mut trials = Vec::with_capacity(cfg.mc_trials);
    for t in 0..cfg.mc_trials {
        let seed = trial_seed(cfg.seed, t);
        let mut rngs = TrialRngs::new(seed);
        let mut problem = factory(seed, &mut rngs.data)?;
        let recorder = match cfg.engine {
            EngineKind::Seq => AsyncSim::new(cfg, problem.as_mut(), rngs)?.run(cfg.iters)?,
            EngineKind::Event => {
                EventEngine::new(cfg, problem.as_mut(), rngs)?.run(cfg.iters)?
            }
            EngineKind::Threaded => anyhow::bail!(
                "run_mc drives in-process engines; use coordinator::run_threaded for engine=threaded"
            ),
        };
        crate::util::log::debug(
            "runner",
            &format!("{}: trial {t} done ({} records)", cfg.name, recorder.records.len()),
        );
        trials.push(recorder);
    }
    Ok(McResult::from_trials(trials))
}

/// Checkpoint / resume / timeline-recording knobs for a single-trial run
/// (`qadmm run --checkpoint-every K | --resume-from P | --record-timeline P`).
#[derive(Clone, Debug, Default)]
pub struct SingleRunOptions {
    /// Write a snapshot every this many consensus rounds (0 = never).
    pub checkpoint_every: usize,
    /// Where the snapshot goes; each write atomically replaces the
    /// previous one. The CLI defaults this to `<--out>/<name>.qsnap` so a
    /// run's artifacts stay together; `None` here falls back to
    /// `out/<name>.qsnap`.
    pub checkpoint_path: Option<PathBuf>,
    /// Resume from this snapshot instead of starting at round 0.
    pub resume_from: Option<PathBuf>,
    /// Event engine only: record the realized timeline here (JSON),
    /// replayable with `--engine threaded --replay-timeline`.
    pub record_timeline: Option<PathBuf>,
}

impl SingleRunOptions {
    pub fn is_active(&self) -> bool {
        self.checkpoint_every > 0
            || self.resume_from.is_some()
            || self.record_timeline.is_some()
    }
}

/// One checkpointable trial of an in-process engine. This is `run_mc` for
/// the long-run shape: a single trial (checkpoints of an averaged MC sweep
/// would be n_trials interleaved states — resume the trials separately if
/// that is what you need), with a periodic snapshot, an optional resume
/// point, and an optional timeline recording.
///
/// A resumed run is **bit-identical** to the uninterrupted one — z
/// trajectory, staleness, wire bits, RNG streams (`tests/snapshot_parity.rs`)
/// — because the snapshot carries every piece of mutable run state and the
/// problem is re-derived from the same seed. That re-derivation is also the
/// boundary of support: problems that hold *runtime* state outside the
/// engine (the NN families keep Adam moments and pinned tensors in the
/// compute service) are refused rather than resumed wrong.
pub fn run_single(
    cfg: &ExperimentConfig,
    factory: &mut ProblemFactory,
    opts: &SingleRunOptions,
) -> anyhow::Result<RunRecorder> {
    cfg.validate()?;
    anyhow::ensure!(
        cfg.engine != EngineKind::Threaded,
        "run_single drives the in-process engines; the threaded runtime replays \
         recorded timelines instead (see --replay-timeline)"
    );
    if opts.checkpoint_every > 0 || opts.resume_from.is_some() {
        anyhow::ensure!(
            matches!(cfg.problem, ProblemKind::Lasso { .. }),
            "checkpoint/resume re-derives the problem from the seed; {} holds \
             runtime state outside the engine and cannot be resumed faithfully",
            cfg.problem.label()
        );
        // The snapshot header (and the resume digest) carry the seed
        // through JSON f64, which is integer-exact only below 2^53 —
        // beyond that two different seeds can collide after rounding and
        // a resume would silently re-derive the wrong problem data.
        anyhow::ensure!(
            cfg.seed < (1u64 << 53),
            "checkpoint/resume requires --seed below 2^53 (the snapshot header \
             stores it as a JSON number); got {}",
            cfg.seed
        );
    }
    if opts.record_timeline.is_some() {
        anyhow::ensure!(
            cfg.engine == EngineKind::Event,
            "--record-timeline captures the event engine's virtual timeline \
             (engine={} has none)",
            cfg.engine.label()
        );
    }

    let seed = trial_seed(cfg.seed, 0);
    let mut rngs = TrialRngs::new(seed);
    let mut problem = factory(seed, &mut rngs.data)?;

    // Resume point: validate the header before touching the body.
    let resumed: Option<(snapshot::SnapshotMeta, Vec<u8>)> = match &opts.resume_from {
        Some(path) => {
            let (meta, body) = snapshot::read_file(path)?;
            anyhow::ensure!(
                meta.engine == cfg.engine.label(),
                "snapshot was written by engine={}, run requests engine={}",
                meta.engine,
                cfg.engine.label()
            );
            anyhow::ensure!(
                snapshot::config_resume_digest(&meta.config) == cfg.resume_digest(),
                "snapshot config does not match this run (only iters/trials/name may \
                 differ on resume); snapshot header: {}",
                meta.config.to_string_compact()
            );
            anyhow::ensure!(
                meta.round <= cfg.iters,
                "snapshot already at round {} >= --iters {}; nothing to resume",
                meta.round,
                cfg.iters
            );
            crate::util::log::debug(
                "runner",
                &format!("resuming {} from round {} ({})", cfg.name, meta.round, path.display()),
            );
            Some((meta, body))
        }
        None => None,
    };

    let ck_path = opts
        .checkpoint_path
        .clone()
        .unwrap_or_else(|| PathBuf::from(format!("out/{}.qsnap", cfg.name)));

    match cfg.engine {
        EngineKind::Seq => {
            let mut sim = match &resumed {
                Some((_, body)) => AsyncSim::resume(cfg, problem.as_mut(), body)?,
                None => AsyncSim::new(cfg, problem.as_mut(), rngs)?,
            };
            while sim.iter() < cfg.iters {
                sim.step()?;
                if opts.checkpoint_every > 0 && sim.iter() % opts.checkpoint_every == 0 {
                    snapshot::write_file_streamed(&ck_path, &sim.snapshot_meta(), |w| {
                        sim.write_snapshot_body(w)
                    })?;
                }
            }
            Ok(sim.recorder().clone())
        }
        EngineKind::Event => {
            let mut eng = match &resumed {
                Some((_, body)) => EventEngine::resume(cfg, problem.as_mut(), body)?,
                None => EventEngine::new(cfg, problem.as_mut(), rngs)?,
            };
            if opts.record_timeline.is_some() {
                eng.record_timeline();
            }
            while eng.stats().rounds < cfg.iters {
                eng.step_round()?;
                if opts.checkpoint_every > 0
                    && eng.stats().rounds % opts.checkpoint_every == 0
                {
                    snapshot::write_file_streamed(&ck_path, &eng.snapshot_meta(), |w| {
                        eng.write_snapshot_body(w)
                    })?;
                }
            }
            if let Some(path) = &opts.record_timeline {
                let tl = eng.take_timeline().expect("recording was enabled");
                tl.write(path)?;
                crate::util::log::debug(
                    "runner",
                    &format!("recorded {} rounds to {}", tl.rounds.len(), path.display()),
                );
            }
            Ok(eng.recorder().clone())
        }
        EngineKind::Threaded => unreachable!("rejected above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::problems::lasso::{LassoConfig, LassoProblem};

    fn lasso_factory(
        cfg: &ExperimentConfig,
    ) -> impl FnMut(u64, &mut crate::util::rng::Pcg64) -> anyhow::Result<Box<dyn Problem>> + '_
    {
        move |_seed, data_rng| {
            let (m, h, n, rho, theta) = match cfg.problem {
                crate::config::ProblemKind::Lasso { m, h, n, rho, theta } => {
                    (m, h, n, rho, theta)
                }
                _ => unreachable!(),
            };
            let p =
                LassoProblem::generate(LassoConfig { m, h, n, rho, theta }, data_rng)?;
            Ok(Box::new(p) as Box<dyn Problem>)
        }
    }

    #[test]
    fn qadmm_converges_on_small_lasso() {
        let mut cfg = presets::ci_lasso();
        cfg.mc_trials = 2;
        cfg.iters = 250;
        let mut factory = lasso_factory(&cfg);
        let res = run_mc(&cfg, &mut factory).unwrap();
        assert_eq!(res.trials.len(), 2);
        let last = *res.mean_accuracy.last().unwrap();
        let first = res.mean_accuracy[0];
        assert!(last < 1e-6, "final accuracy {last}");
        assert!(last < first * 1e-3, "no convergence: {first} -> {last}");
        // comm bits strictly increasing
        assert!(res.mean_comm_bits.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn event_engine_matches_seq_in_parity_config() {
        // identity compressor + zero latency: the virtual timeline collapses
        // onto the simulator's rounds and the curves are bit-identical
        let mut cfg = presets::ci_lasso();
        cfg.compressor = crate::compress::CompressorKind::Identity;
        cfg.iters = 60;
        cfg.mc_trials = 1;
        let mut f1 = lasso_factory(&cfg);
        let seq = run_mc(&cfg, &mut f1).unwrap();
        let mut cfg2 = cfg.clone();
        cfg2.engine = crate::config::EngineKind::Event;
        let mut f2 = lasso_factory(&cfg2);
        let ev = run_mc(&cfg2, &mut f2).unwrap();
        assert_eq!(seq.mean_accuracy, ev.mean_accuracy);
        assert_eq!(seq.mean_comm_bits, ev.mean_comm_bits);
    }

    #[test]
    fn identical_seed_identical_trajectories() {
        let cfg = presets::ci_lasso();
        let mut f1 = lasso_factory(&cfg);
        let a = run_mc(&cfg, &mut f1).unwrap();
        let mut f2 = lasso_factory(&cfg);
        let b = run_mc(&cfg, &mut f2).unwrap();
        assert_eq!(a.mean_accuracy, b.mean_accuracy);
        assert_eq!(a.mean_comm_bits, b.mean_comm_bits);
    }

    /// The CLI-level glue: run_single writes a checkpoint file at the
    /// cadence, a second run_single resumes from it, and the resumed
    /// recorder continues the same series (bit-exact tail) that a straight
    /// run produces.
    #[test]
    fn run_single_checkpoints_and_resumes_through_the_file() {
        let dir = std::env::temp_dir().join("qadmm-run-single-test");
        let _ = std::fs::remove_dir_all(&dir);
        let ck = dir.join("run.qsnap");
        let mut cfg = presets::ci_lasso();
        cfg.engine = EngineKind::Event;
        cfg.iters = 20;
        cfg.mc_trials = 1;

        let mut f1 = lasso_factory(&cfg);
        let straight = run_single(&cfg, &mut f1, &SingleRunOptions::default()).unwrap();

        // interrupted plan: checkpoint every 7 rounds, stop at 14
        let mut short = cfg.clone();
        short.iters = 14;
        let mut f2 = lasso_factory(&short);
        let opts = SingleRunOptions {
            checkpoint_every: 7,
            checkpoint_path: Some(ck.clone()),
            ..Default::default()
        };
        let _ = run_single(&short, &mut f2, &opts).unwrap();
        assert!(ck.exists(), "checkpoint file not written");

        // resume with the full plan (iters differ — the digest permits it)
        let mut f3 = lasso_factory(&cfg);
        let opts = SingleRunOptions { resume_from: Some(ck.clone()), ..Default::default() };
        let resumed = run_single(&cfg, &mut f3, &opts).unwrap();

        assert_eq!(straight.records.len(), resumed.records.len());
        for (a, b) in straight.records.iter().zip(&resumed.records) {
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.comm_bits.to_bits(), b.comm_bits.to_bits());
            assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
            assert_eq!(a.active_nodes, b.active_nodes);
        }

        // a config drift must be refused
        let mut other = cfg.clone();
        other.tau = cfg.tau + 1;
        let mut f4 = lasso_factory(&other);
        let opts = SingleRunOptions { resume_from: Some(ck.clone()), ..Default::default() };
        assert!(run_single(&other, &mut f4, &opts).is_err());

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn baseline_uses_more_bits_for_same_iterations() {
        let cfg = presets::ci_lasso();
        let mut f = lasso_factory(&cfg);
        let q = run_mc(&cfg, &mut f).unwrap();
        let mut base_cfg = cfg.clone();
        base_cfg.compressor = crate::compress::CompressorKind::Identity;
        let mut f2 = lasso_factory(&base_cfg);
        let b = run_mc(&base_cfg, &mut f2).unwrap();
        let q_bits = *q.mean_comm_bits.last().unwrap();
        let b_bits = *b.mean_comm_bits.last().unwrap();
        assert!(
            q_bits < 0.2 * b_bits,
            "expected ≥80% wire reduction: qadmm={q_bits} baseline={b_bits}"
        );
        // and both converge comparably
        let qa = *q.mean_accuracy.last().unwrap();
        let ba = *b.mean_accuracy.last().unwrap();
        assert!(qa < 1e-6 && ba < 1e-6, "qadmm={qa} baseline={ba}");
    }
}
