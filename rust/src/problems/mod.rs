//! Problem definitions: what each node optimizes locally and how the server
//! aggregates. Problems expose *pure numeric* updates; compression, error
//! feedback and scheduling live in [`crate::admm`].

pub mod lasso;
pub mod logreg;
pub mod mnist;
pub mod nn;

use crate::util::rng::Pcg64;

/// One node's inputs to a fanned-out local update (see
/// [`Problem::local_update_batch`]). Each item carries its *own* ẑ view:
/// with per-link downlink delays the nodes of one batch may hold
/// different mirrors of the server's consensus (a straggler computes
/// against an older ẑ than its fast neighbour). Per-node randomness comes
/// from the item's own forked RNG so results are independent of
/// worker-pool size and schedule.
pub struct LocalUpdateItem<'a> {
    pub node: usize,
    /// The node's current estimate of z (its downlink mirror).
    pub zhat: &'a [f64],
    pub u: &'a [f64],
    pub x_prev: &'a [f64],
    pub rng: &'a mut Pcg64,
}

/// Metrics a problem can report at evaluation points.
#[derive(Clone, Copy, Debug)]
pub struct EvalMetrics {
    /// Eq. (19): |L − F*| / F* (convex problems; NaN for NN).
    pub accuracy: f64,
    /// Test-set classification accuracy in [0,1] (NN; NaN for LASSO).
    pub test_acc: f64,
    /// Objective value: augmented Lagrangian (LASSO) or test CE loss (NN).
    pub loss: f64,
}

/// A distributed consensus problem (eq. 2): N local objectives + a shared
/// regularizer handled by the server prox.
pub trait Problem {
    /// Dimension M of the consensus variable.
    fn dim(&self) -> usize;

    fn n_nodes(&self) -> usize;

    fn name(&self) -> String;

    /// Initial x⁽⁰⁾ (shared across nodes; NN uses He init, LASSO zeros).
    fn init_x(&mut self, rng: &mut Pcg64) -> Vec<f64>;

    /// Local primal update (eq. 9a): exact argmin or K inexact steps,
    /// starting from `x_prev`, against the node's estimate `zhat` of z and
    /// its dual `u`. Returns (x_new, local training loss).
    fn local_update(
        &mut self,
        node: usize,
        zhat: &[f64],
        u: &[f64],
        x_prev: &[f64],
        rng: &mut Pcg64,
    ) -> anyhow::Result<(Vec<f64>, f64)>;

    /// Fan-out of [`Self::local_update`] over a batch of nodes, each
    /// against its item's ẑ view. Results are returned in item order. The
    /// default runs sequentially; problems whose update is pure math (e.g.
    /// native LASSO) override this with a deterministic worker pool —
    /// results must be bit-identical to the sequential order regardless of
    /// pool size.
    fn local_update_batch(
        &mut self,
        items: &mut [LocalUpdateItem<'_>],
    ) -> anyhow::Result<Vec<(Vec<f64>, f64)>> {
        let mut out = Vec::with_capacity(items.len());
        for it in items.iter_mut() {
            out.push(self.local_update(it.node, it.zhat, it.u, it.x_prev, it.rng)?);
        }
        Ok(out)
    }

    /// Server consensus update (eq. 15) on the estimate banks.
    fn consensus(&mut self, xhat: &[Vec<f64>], uhat: &[Vec<f64>]) -> anyhow::Result<Vec<f64>>;

    /// Metrics on the *true* iterates (eq. 19 uses x, z, u, not estimates).
    fn evaluate(
        &mut self,
        x: &[Vec<f64>],
        u: &[Vec<f64>],
        z: &[f64],
    ) -> anyhow::Result<EvalMetrics>;
}
