//! Cross-runtime accounting parity for the full-precision init exchange.
//!
//! The threaded coordinator charges messages through
//! `NodeToServer::wire_bits` / `ServerToNode::wire_bits`, while the
//! sequential simulator and the event engine charge the init exchange with
//! explicit formulas. All three must agree on the paper's 32-bits-per-
//! scalar init rate ([`qadmm::comm::message::INIT_BITS_PER_SCALAR`]) or
//! their comm-bit curves start from different offsets and every
//! bits-to-target comparison across runtimes is skewed. (The seed charged
//! 64 bits/scalar in the message layer and 32 in the engines.)

use qadmm::admm::engine::EventEngine;
use qadmm::admm::sim::{AsyncSim, TrialRngs};
use qadmm::comm::message::{
    NodeToServer, ServerToNode, INIT_BITS_PER_SCALAR, MSG_HEADER_BYTES,
};
use qadmm::compress::CompressorKind;
use qadmm::config::{presets, ExperimentConfig, ProblemKind};
use qadmm::problems::lasso::{LassoConfig, LassoProblem};

fn cfg_and_lasso() -> (ExperimentConfig, LassoConfig) {
    let mut cfg = presets::ci_lasso();
    cfg.compressor = CompressorKind::Identity;
    let l = match cfg.problem {
        ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
        _ => unreachable!(),
    };
    (cfg, l)
}

/// The exact bits the threaded runtime would charge for one node's init
/// exchange, derived from the message types themselves.
fn threaded_init_bits_per_node(m: usize) -> u64 {
    let up = NodeToServer::InitFull { node: 0, x0: vec![0.0; m], u0: vec![0.0; m] };
    let down = ServerToNode::InitZ { z0: vec![0.0; m] };
    up.wire_bits() + down.wire_bits()
}

/// Before any round fires, the simulator's and the event engine's books
/// must equal n × (InitFull + InitZ) *as priced by the message layer* —
/// the same pricing the threaded endpoints apply on send.
#[test]
fn init_exchange_offset_is_identical_across_runtimes() {
    let (cfg, l) = cfg_and_lasso();
    let per_node = threaded_init_bits_per_node(l.m);
    // the message layer charges the paper's 32-bit init rate
    assert_eq!(
        per_node,
        2 * (MSG_HEADER_BYTES * 8) + 3 * l.m as u64 * INIT_BITS_PER_SCALAR
    );
    assert_eq!(INIT_BITS_PER_SCALAR, 32);
    let expect = l.n as u64 * per_node;

    let mut rngs = TrialRngs::new(cfg.seed);
    let mut p = LassoProblem::generate(l, &mut rngs.data).unwrap();
    let sim = AsyncSim::new(&cfg, &mut p, rngs).unwrap();
    assert_eq!(sim.accounting().total_bits(), expect, "simulator init offset");

    let mut rngs = TrialRngs::new(cfg.seed);
    let mut p = LassoProblem::generate(l, &mut rngs.data).unwrap();
    let eng = EventEngine::new(&cfg, &mut p, rngs).unwrap();
    assert_eq!(eng.accounting().total_bits(), expect, "event engine init offset");
}

/// Uplink/downlink split of the init offset matches too (the threaded
/// outcome reports these separately).
#[test]
fn init_offset_split_by_direction() {
    let (cfg, l) = cfg_and_lasso();
    let up = NodeToServer::InitFull { node: 0, x0: vec![0.0; l.m], u0: vec![0.0; l.m] }
        .wire_bits();
    let down = ServerToNode::InitZ { z0: vec![0.0; l.m] }.wire_bits();

    let mut rngs = TrialRngs::new(cfg.seed);
    let mut p = LassoProblem::generate(l, &mut rngs.data).unwrap();
    let sim = AsyncSim::new(&cfg, &mut p, rngs).unwrap();
    let acc = sim.accounting();
    assert_eq!(acc.total_uplink_bits(), l.n as u64 * up);
    assert_eq!(acc.total_downlink_bits(), l.n as u64 * down);
}
