"""NN graphs: parameter accounting, forward shapes, learning sanity, and the
inexact local update's ADMM bookkeeping."""

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile import model, nn  # noqa: E402


def he_init(specs, seed):
    rng = np.random.default_rng(seed)
    flat = np.zeros(nn.param_count(specs), dtype=np.float32)
    for s in specs:
        if s["name"].endswith("_w"):
            std = np.sqrt(2.0 / s["fan_in"])
            flat[s["offset"]:s["offset"] + s["size"]] = (
                rng.standard_normal(s["size"]) * std
            )
    return jnp.asarray(flat)


def test_cnn_param_count_matches_paper_architecture():
    # 5 convs (3x3, stride 2, pad 1, channels 16/32/64/128/128) + FC(128,10)
    assert nn.CNN_PARAMS == 246_026
    specs = nn.cnn_param_specs()
    assert specs[-1]["offset"] + specs[-1]["size"] == nn.CNN_PARAMS
    # offsets are contiguous and sorted
    off = 0
    for s in specs:
        assert s["offset"] == off
        off += s["size"]


def test_cnn_forward_shape_and_grad():
    flat = he_init(nn.cnn_param_specs(), 0)
    x = jnp.asarray(np.random.default_rng(1).random((4, 28, 28, 1), dtype=np.float32))
    logits = nn.cnn_forward(flat, x)
    assert logits.shape == (4, 10)
    y = jnp.asarray(np.array([0, 1, 2, 3], dtype=np.int32))
    g = jax.grad(lambda p: nn.cross_entropy(nn.cnn_forward(p, x), y))(flat)
    assert g.shape == flat.shape
    assert float(jnp.sum(jnp.abs(g))) > 0


def test_mlp_param_count():
    assert nn.MLP_PARAMS == 784 * 64 + 64 + 64 * 10 + 10


def test_cross_entropy_and_accuracy():
    logits = jnp.asarray(np.array([[10.0, 0, 0], [0, 10.0, 0]], dtype=np.float32))
    y = jnp.asarray(np.array([0, 0], dtype=np.int32))
    assert float(nn.accuracy_count(logits, y)) == 1.0
    ce = float(nn.cross_entropy(logits, y))
    assert 0 < ce < 6


def test_mlp_local_update_bookkeeping():
    """u' = u + x' − ẑ and Δ = x' − x̂ must hold regardless of the inner
    optimizer trajectory (that is the ADMM contract)."""
    m = nn.MLP_PARAMS
    k, b = 2, 8
    rng = np.random.default_rng(3)
    flat = he_init(nn.mlp_param_specs(), 2)
    zeros = jnp.zeros(m, dtype=jnp.float32)
    u = jnp.asarray(rng.standard_normal(m).astype(np.float32) * 0.01)
    zhat = jnp.asarray(rng.standard_normal(m).astype(np.float32) * 0.01)
    xhat = jnp.asarray(rng.standard_normal(m).astype(np.float32) * 0.01)
    uhat = jnp.asarray(rng.standard_normal(m).astype(np.float32) * 0.01)
    bx = jnp.asarray(rng.random((k, b, 784), dtype=np.float32))
    by = jnp.asarray(rng.integers(0, 10, size=(k, b)).astype(np.int32))
    nx = jnp.asarray(rng.random(m, dtype=np.float32))
    nu = jnp.asarray(rng.random(m, dtype=np.float32))
    out = model.mlp_local_update(
        flat, zeros, zeros, jnp.float32(0.0), u, zhat, xhat, uhat,
        bx, by, nx, nu, jnp.float32(0.1), jnp.float32(1e-3), jnp.float32(3.0)
    )
    (x_new, m_new, v_new, t_new, u_new,
     cx_val, cx_lvl, cx_norm, cu_val, cu_lvl, cu_norm, loss) = out
    np.testing.assert_allclose(
        np.asarray(u_new), np.asarray(u + (x_new - zhat)), atol=1e-6
    )
    assert float(t_new) == float(k)
    dx = np.asarray(x_new - xhat)
    assert abs(float(cx_norm) - np.abs(dx).max()) < 1e-6
    # quantization error bound per element
    assert np.abs(np.asarray(cx_val) - dx).max() <= float(cx_norm) / 3.0 + 1e-6
    assert float(loss) > 0


def test_mlp_learns_toy_problem():
    """K-step Adam local updates reduce the data loss on a separable toy
    task — the inexact primal update must actually optimize f_i."""
    m = nn.MLP_PARAMS
    k, b = 5, 32
    rng = np.random.default_rng(4)
    flat = he_init(nn.mlp_param_specs(), 5)
    # class c has a bump at pixels [78c, 78c+40)
    def make_batch():
        y = rng.integers(0, 10, size=b).astype(np.int32)
        x = rng.random((b, 784), dtype=np.float32) * 0.1
        for j, c in enumerate(y):
            x[j, 78 * c: 78 * c + 40] += 1.0
        return x, y

    zeros = jnp.zeros(m, dtype=jnp.float32)
    state = (flat, zeros, zeros, jnp.float32(0.0))
    losses = []
    for it in range(8):
        bxs, bys = [], []
        for _ in range(k):
            x, y = make_batch()
            bxs.append(x)
            bys.append(y)
        bx = jnp.asarray(np.stack(bxs))
        by = jnp.asarray(np.stack(bys))
        out = model.mlp_local_update(
            state[0], state[1], state[2], state[3],
            zeros, state[0], zeros, zeros,  # u=0, zhat=x ⇒ pure f_i descent
            bx, by, jnp.zeros(m, jnp.float32), jnp.zeros(m, jnp.float32),
            jnp.float32(0.0), jnp.float32(1e-3), jnp.float32(3.0)
        )
        state = (out[0], out[1], out[2], out[3])
        losses.append(float(out[11]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_nn_server_step_average():
    m, n = 64, 3
    rng = np.random.default_rng(6)
    xhat = jnp.asarray(rng.standard_normal((n, m)).astype(np.float32))
    uhat = jnp.asarray(rng.standard_normal((n, m)).astype(np.float32))
    zhat = jnp.zeros(m, dtype=jnp.float32)
    noise = jnp.asarray(rng.random(m, dtype=np.float32))
    z_new, cz_val, cz_lvl, cz_norm = model.nn_server_step(
        xhat, uhat, zhat, noise, jnp.float32(3.0)
    )
    np.testing.assert_allclose(
        np.asarray(z_new), np.asarray(jnp.mean(xhat + uhat, axis=0)), atol=1e-6
    )
