//! `simulate-async()`: which nodes complete their compute + communication
//! within the next iteration.
//!
//! §5.1 (LASSO): the N nodes are split once into two fixed halves; members
//! of the slow half are selected w.p. 0.1 each iteration, the fast half
//! w.p. 0.8. §5.2 (MNIST): the grouping is redrawn on every call with equal
//! probability per node.

use crate::config::OracleConfig;
use crate::snapshot::codec::{Pack, Reader, Writer};
use crate::util::rng::Pcg64;

pub struct AsyncOracle {
    cfg: OracleConfig,
    /// true = fast group (selection probability `p_fast`).
    fast: Vec<bool>,
}

impl AsyncOracle {
    pub fn new(n: usize, cfg: OracleConfig, rng: &mut Pcg64) -> Self {
        let mut o = Self { cfg, fast: vec![false; n] };
        o.assign_groups(rng);
        o
    }

    fn assign_groups(&mut self, rng: &mut Pcg64) {
        let n = self.fast.len();
        if self.cfg.regroup_each_call {
            // §5.2: independent fair coin per node, per call
            for f in &mut self.fast {
                *f = rng.bernoulli(0.5);
            }
        } else {
            // §5.1: a fixed random half-split
            self.fast = vec![false; n];
            for &i in rng.choose_k(n, n / 2).iter() {
                self.fast[i] = true;
            }
        }
    }

    /// One oracle draw: the set of nodes that will complete next iteration.
    pub fn sample(&mut self, rng: &mut Pcg64) -> Vec<bool> {
        if self.cfg.regroup_each_call {
            self.assign_groups(rng);
        }
        self.fast
            .iter()
            .map(|&fast| rng.bernoulli(if fast { self.cfg.p_fast } else { self.cfg.p_slow }))
            .collect()
    }

    pub fn fast_mask(&self) -> &[bool] {
        &self.fast
    }
}

/// Snapshots capture the realized group assignment (the §5.1 half-split is
/// drawn once at construction and must survive a resume verbatim) plus the
/// selection probabilities, so a restored oracle consumes its RNG stream
/// exactly like the uninterrupted one.
impl Pack for AsyncOracle {
    fn pack(&self, w: &mut Writer) {
        w.put_f64(self.cfg.p_slow);
        w.put_f64(self.cfg.p_fast);
        w.put_bool(self.cfg.regroup_each_call);
        self.fast.pack(w);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let p_slow = r.get_f64()?;
        let p_fast = r.get_f64()?;
        let regroup_each_call = r.get_bool()?;
        anyhow::ensure!(
            (0.0..=1.0).contains(&p_slow) && (0.0..=1.0).contains(&p_fast),
            "snapshot oracle: probabilities out of [0,1]"
        );
        let fast = Vec::<bool>::unpack(r)?;
        Ok(Self { cfg: OracleConfig { p_slow, p_fast, regroup_each_call }, fast })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_split_is_half_and_stable() {
        let mut rng = Pcg64::seed_from_u64(1);
        let mut o = AsyncOracle::new(16, OracleConfig::default(), &mut rng);
        assert_eq!(o.fast_mask().iter().filter(|&&f| f).count(), 8);
        let before = o.fast_mask().to_vec();
        let _ = o.sample(&mut rng);
        assert_eq!(o.fast_mask(), &before[..]);
    }

    #[test]
    fn selection_rates_match_probabilities() {
        let mut rng = Pcg64::seed_from_u64(2);
        let cfg = OracleConfig { p_slow: 0.1, p_fast: 0.8, regroup_each_call: false };
        let mut o = AsyncOracle::new(16, cfg, &mut rng);
        let fast = o.fast_mask().to_vec();
        let trials = 20_000;
        let mut counts = vec![0usize; 16];
        for _ in 0..trials {
            for (c, sel) in counts.iter_mut().zip(o.sample(&mut rng)) {
                *c += sel as usize;
            }
        }
        for (i, c) in counts.iter().enumerate() {
            let rate = *c as f64 / trials as f64;
            let expect = if fast[i] { 0.8 } else { 0.1 };
            assert!((rate - expect).abs() < 0.02, "node {i}: rate={rate} expect={expect}");
        }
    }

    #[test]
    fn regroup_mode_selects_at_mixture_rate() {
        let mut rng = Pcg64::seed_from_u64(3);
        let cfg = OracleConfig { p_slow: 0.1, p_fast: 0.8, regroup_each_call: true };
        let mut o = AsyncOracle::new(8, cfg, &mut rng);
        let trials = 20_000;
        let mut total = 0usize;
        for _ in 0..trials {
            total += o.sample(&mut rng).iter().filter(|&&s| s).count();
        }
        let rate = total as f64 / (trials * 8) as f64;
        // mixture: 0.5·0.1 + 0.5·0.8 = 0.45
        assert!((rate - 0.45).abs() < 0.01, "rate={rate}");
    }
}
